//! In-situ inference serving (paper Fig 1b + §4's deployment phase): the
//! simulation streams flow snapshots, the trained encoder runs *inside* the
//! database (RedisAI-analogue) on the node's GPU slots, and only the latent
//! codes are kept — the "much richer time history" use case.
//!
//! The encoder is served through the versioned model registry: a publisher
//! hot-swaps a new checkpoint mid-storm while every in-flight request keeps
//! succeeding, and ranks sharing a GPU slot are coalesced by the adaptive
//! micro-batcher (run with more than 4 ranks to see batches form).
//!
//! Reports per-request latency percentiles, throughput, the achieved
//! compression factor, and the registry/batching counters.
//!
//! Run: `cargo run --release --example inference_serving -- [ranks] [steps]`

use std::time::Duration;

use situ::ai::ModelRuntime;
use situ::client::{tensor_key, Client, DataStore, Pipeline};
use situ::db::{DbServer, ServerConfig};
use situ::runtime::Manifest;
use situ::sim::cfd::{ChannelFlow, Grid, MeshSampler};
use situ::telemetry::{StatAccum, Stopwatch, Table};
use situ::util::fmt;

fn main() -> situ::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let ranks: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(4);
    let steps: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(30);

    let artifacts = situ::db::server::artifacts_dir();
    let manifest = Manifest::load_dir(&artifacts)?;
    let server = DbServer::start(ServerConfig::default())?;
    println!("database up at {}; loading encoder into the model registry", server.addr);
    let encoder_path = artifacts.join(&manifest.artifact("encoder").unwrap().file);
    {
        let mut c = Client::connect(server.addr)?;
        let v = c.put_model_from_file("encoder", &encoder_path)?;
        println!("encoder published as version {v} (live)");
        // Stage the encoder parameters once; every rank references them.
        let state = situ::ml::ParamState::load_init(&manifest, &artifacts)?;
        for name in &manifest.enc_param_order {
            let i = manifest.param_order.iter().position(|p| p == name).unwrap();
            c.put_tensor(&format!("param_{name}"), &state.params[i])?;
        }
    }

    // Producer: one shared flow, per-rank partitions (as in the e2e driver).
    let sampler = MeshSampler::load(&artifacts.join("mesh_coords.bin"))?;
    let mut flow = ChannelFlow::new(Grid::channel(20, 14, 10), 2e-3, 1, 0.1);
    let addr = server.addr;

    let mut handles = Vec::new();
    let barrier = std::sync::Arc::new(std::sync::Barrier::new(ranks));
    // Pre-generate snapshots per step so rank threads only measure the
    // serving path.
    let mut snaps = Vec::new();
    for _ in 0..steps {
        flow.step();
        snaps.push(sampler.snapshot(&flow));
    }
    let snaps = std::sync::Arc::new(snaps);

    // A trainer stand-in: republish the encoder mid-storm.  The registry
    // allocates version 2 and atomically swaps the live pointer; requests
    // already executing on version 1 finish on it, later ones pick up v2.
    let publisher = std::thread::spawn(move || -> situ::Result<u64> {
        std::thread::sleep(Duration::from_millis(40));
        let mut c = Client::connect(addr)?;
        c.put_model_from_file("encoder", &encoder_path)
    });

    let t0 = Stopwatch::start();
    for rank in 0..ranks {
        let snaps = std::sync::Arc::clone(&snaps);
        let barrier = std::sync::Arc::clone(&barrier);
        let enc_params: Vec<String> = manifest
            .enc_param_order
            .iter()
            .map(|n| format!("param_{n}"))
            .collect();
        handles.push(std::thread::spawn(move || -> situ::Result<(StatAccum, usize, usize)> {
            let mut c = Client::connect_retry(addr, 50, Duration::from_millis(10))?;
            let device = ModelRuntime::device_for_rank(rank);
            let mut lat = StatAccum::new();
            let mut in_bytes = 0;
            let mut out_bytes = 0;
            barrier.wait();
            for (step, snap) in snaps.iter().enumerate() {
                let in_key = tensor_key("snap", rank, step as u64);
                let z_key = tensor_key("latent", rank, step as u64);
                let sw = Stopwatch::start();
                // The whole serving step — send input, run the encoder,
                // retrieve the latent, drop the raw snapshot — is one
                // pipelined frame instead of four round trips.
                let mut keys = enc_params.clone();
                keys.push(in_key.clone());
                let mut pipe = Pipeline::new();
                pipe.put_tensor(&in_key, snap)
                    .run_model("encoder", &keys, &[z_key.clone()], device)
                    .get_tensor(&z_key)
                    .del_tensor(&in_key);
                let mut results = c.execute(pipe)?;
                let z = results.remove(2).expect_tensor(&z_key)?;
                for r in results {
                    // put, run, del all report Ok (del: the key existed).
                    r.expect_ok()?;
                }
                lat.add(sw.stop());
                in_bytes += snap.nbytes();
                out_bytes += z.nbytes();
            }
            Ok((lat, in_bytes, out_bytes))
        }));
    }

    let mut all = StatAccum::new();
    let (mut tot_in, mut tot_out) = (0usize, 0usize);
    for h in handles {
        let (lat, ib, ob) = h.join().expect("rank panicked")?;
        all.merge(&lat);
        tot_in += ib;
        tot_out += ob;
    }
    let wall = t0.stop();

    let mut table = Table::new(
        "in situ inference serving (encoder inside the DB)",
        &["metric", "value"],
    );
    table.row(&["ranks".into(), ranks.to_string()]);
    table.row(&["requests".into(), format!("{}", all.count())]);
    table.row(&["latency mean".into(), fmt::duration(all.mean())]);
    table.row(&["latency σ".into(), fmt::duration(all.std())]);
    table.row(&["latency min/max".into(), format!("{} / {}", fmt::duration(all.min()), fmt::duration(all.max()))]);
    table.row(&["throughput".into(), format!("{:.1} req/s", all.count() as f64 / wall)]);
    table.row(&["data ingested".into(), fmt::bytes(tot_in as u64)]);
    table.row(&["latents kept".into(), fmt::bytes(tot_out as u64)]);
    table.row(&[
        "compression".into(),
        format!("{:.0}x (manifest: {:.0}x)", tot_in as f64 / tot_out as f64, manifest.model.compression_factor),
    ]);
    table.print();

    let swapped_to = publisher.join().expect("publisher panicked")?;
    println!("hot-swapped to encoder version {swapped_to} mid-storm; zero failed requests");
    let mut c = Client::connect(addr)?;
    situ::telemetry::models_table(&c.list_models()?).print();
    situ::telemetry::model_stats_table(&c.model_stats()?).print();
    situ::telemetry::serving_table(&c.info()?).print();
    Ok(())
}
