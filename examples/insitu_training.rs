//! END-TO-END DRIVER (paper §4): in-situ training of the QuadConv
//! autoencoder from a live Navier-Stokes simulation.
//!
//! The orchestrator deploys a co-located database; the CFD producer (the
//! PHASTA stand-in) integrates a turbulent channel flow and publishes
//! (p,u,v,w) snapshots every 2 steps; the distributed trainer gathers them
//! each epoch and runs fused PJRT `train_step`s (fwd+bwd+Adam).  Output: the
//! paper's Table 1 / Table 2 overhead accounting and the Fig-10 convergence
//! curve.  Results are recorded in EXPERIMENTS.md.
//!
//! Run: `cargo run --release --example insitu_training -- [epochs] [steps]`

use situ::orchestrator::driver::{run_insitu_training, InSituTrainingConfig};
use situ::telemetry::Table;

fn main() -> situ::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let epochs: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(200);
    let steps: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(120);

    let cfg = InSituTrainingConfig {
        grid: (24, 16, 12),
        nu: 2e-3,
        sim_ranks: 4,
        ml_ranks: 2,
        epochs,
        snapshot_every: 2,
        solver_steps: steps,
        seed: 0,
        ..Default::default()
    };
    println!(
        "== in situ training: {} epochs, {} solver steps, {} sim ranks : {} ml ranks ==",
        cfg.epochs, cfg.solver_steps, cfg.sim_ranks, cfg.ml_ranks
    );
    let t0 = std::time::Instant::now();
    let report = run_insitu_training(&cfg)?;
    let wall = t0.elapsed().as_secs_f64();

    report.solver_table.print();
    report.trainer_table.print();

    let mut curve = Table::new(
        "Fig 10: convergence of training loss, validation loss and validation error",
        &["epoch", "train_loss", "val_loss", "val_rel_err"],
    );
    let stride = (report.history.len() / 25).max(1);
    for log in report.history.iter().step_by(stride) {
        curve.row(&[
            log.epoch.to_string(),
            format!("{:.6}", log.train_loss),
            format!("{:.6}", log.val_loss),
            format!("{:.4}", log.val_rel_err),
        ]);
    }
    if let Some(last) = report.history.last() {
        curve.row(&[
            last.epoch.to_string(),
            format!("{:.6}", last.train_loss),
            format!("{:.6}", last.val_loss),
            format!("{:.4}", last.val_rel_err),
        ]);
    }
    curve.print();

    let first = report.history.first().unwrap();
    let last = report.history.last().unwrap();
    println!("loss reduction: {:.2}x over {} epochs", first.train_loss / last.train_loss, epochs);
    println!(
        "validation relative error: {:.1}% -> {:.1}%  (paper converges to ~10%)",
        first.val_rel_err * 100.0,
        last.val_rel_err * 100.0
    );
    println!(
        "framework overhead on solver: {:.4}% of PDE integration (paper: <<1%)",
        report.solver_overhead_frac * 100.0
    );
    println!("spatial compression factor: {:.0}x", report.compression_factor);
    println!(
        "db footprint: {} resident / {} high-water bytes, {} keys evicted, {} busy rejections",
        report.db.bytes,
        report.db.high_water_bytes,
        report.db.evicted_keys,
        report.db.busy_rejections
    );
    println!(
        "backpressure: {} snapshots published, {} skipped, {} dropped, {} busy retries, \
         {} trainer generations skipped",
        report.governor.published,
        report.governor.skipped,
        report.governor.dropped,
        report.governor.busy_retries,
        report.trainer_skipped_generations
    );
    println!("wall time: {wall:.1} s");
    Ok(())
}
