//! Quickstart: the paper's "one line per operation" coupling claim, written
//! once against the [`DataStore`] trait and run against *both* deployments.
//!
//! Launches a co-located database and a 2-shard cluster, drives the
//! identical workflow through `dyn DataStore` on each, pipelines a
//! multi-tensor publish into one round trip, and (when artifacts are built)
//! uploads a model and runs in-database inference.
//!
//! Run: `cargo run --release --example quickstart`
//!
//! # Retention + backpressure tuning
//!
//! Long runs must bound the store.  Pick a publishing mode first, then set
//! the knobs (`situ serve --retention-window W --max-bytes B --ttl-ms T`;
//! `situ train` adds `--busy-retries N --busy-backoff-ms MS
//! --governor-max-stride K`):
//!
//! 1. **Append + window** (`tensor_key`, `--retention-window W`) — the
//!    default for in-situ *training*: the trainer consumes a moving window
//!    of the newest `W` generations (`gather_window`), older ones retire
//!    automatically.  Choose `W ≥` the trainer's window; add
//!    `--db-max-bytes` as a hard ceiling for mixed workloads.
//! 2. **Overwrite** (`stable_key`, `--overwrite`) — bounded by
//!    construction (one generation per field); the right mode when the
//!    consumer only ever wants the newest snapshot (steering, live
//!    inference).  No window needed; memory is flat with zero eviction.
//! 3. **Governed append under a byte cap** (`--db-max-bytes B` +
//!    `--busy-retries`/`--governor-max-stride`) — for shared or tightly
//!    provisioned databases: when the cap would be exceeded and nothing is
//!    evictable the put gets `Error::Busy` *backpressure*; a
//!    [`RetryPolicy`] rides out transient stalls and the producer's
//!    adaptive governor skips/merges snapshots under sustained pressure so
//!    the solver never stops.  Use when consumer stalls are possible and
//!    completing the run matters more than capturing every snapshot.
//!
//! Add `--db-ttl-ms T` (wall-clock TTL) in any mode to reclaim data from
//! producers that stall mid-run and never advance their window.  Inspect
//! pressure live with `situ info`: per-field resident bytes vs. the cap,
//! eviction rates, TTL expiry and busy-rejection counters.
//!
//! # Spill-to-disk cold tier (replaying retired generations)
//!
//! By default eviction *discards* retired snapshots.  Add `--spill-dir DIR`
//! (plus optional `--spill-max-bytes B`) to `situ serve` / `situ train`
//! and every victim of the retention pipeline — window retirement,
//! byte-cap eviction, TTL expiry — is instead appended to a
//! CRC-checksummed segment log by a background thread, off the put hot
//! path.  Retired generations stay readable:
//!
//! * `cold_list(prefix)` / `cold_get(key)` on any [`DataStore`] read the
//!   cold tier directly (post-hoc analysis, offline re-training);
//! * `DataLoader::gather_window` falls back to the cold tier
//!   transparently, so a deep training window spanning retired steps
//!   completes instead of skipping them;
//! * the log is crash-safe: torn tails from a killed writer are truncated
//!   on reopen and corrupted records are skipped cleanly (see
//!   `tests/spill_recovery.rs` for the battery that proves it).
//!
//! `situ info` reports spilled keys/bytes, segment count, and cold hits —
//! per field and globally.  The `cold_tier_demo` below walks the whole
//! loop: publish, evict, replay byte-exact.

use situ::client::{Client, ClusterClient, DataStore, Pipeline, PollConfig, RetryPolicy};
use situ::db::{DbServer, RetentionConfig, ServerConfig, SpillConfig};
use situ::error::Error;
use situ::proto::Device;
use situ::tensor::Tensor;

/// The whole coupling workflow, deployment-agnostic: the same function
/// serves the co-located single database and the sharded cluster.
fn demo(store: &mut dyn DataStore, label: &str) -> situ::Result<()> {
    // -- the one-line client API ------------------------------------------
    let field = Tensor::from_f32(&[4, 8], (0..32).map(|i| i as f32).collect())?;
    store.put_tensor("field_rank0_step0", &field)?; // 1 line: send
    let back = store.get_tensor("field_rank0_step0")?; // 1 line: retrieve
    assert_eq!(back, field);

    // -- pipelined publish: N tensors + metadata, one round trip ----------
    let mut pipe = Pipeline::new();
    for rank in 1..4 {
        pipe.put_tensor(&situ::client::tensor_key("field", rank, 0), &field);
    }
    pipe.put_meta("latest_step", "0");
    for r in store.execute(pipe)? {
        r.expect_ok()?;
    }

    // -- batched gather + server-side wait --------------------------------
    let keys: Vec<String> = (0..4).map(|r| situ::client::tensor_key("field", r, 0)).collect();
    store.poll_keys(&keys, &PollConfig::default())?; // blocks server-side
    let gathered = store.mget_tensors(&keys)?; // one frame per shard
    assert_eq!(gathered.len(), 4);

    // -- metadata ----------------------------------------------------------
    println!("[{label}] latest_step = {:?}", store.get_meta("latest_step")?);

    let info = store.info()?;
    println!(
        "[{label}] db: {} keys, {} bytes, {} ops (engine {})",
        info.keys, info.bytes, info.ops, info.engine
    );
    store.flush_all()?;
    Ok(())
}

/// Retention + backpressure in action (see the module docs for when to
/// pick each mode): a windowed byte-capped store retires old generations,
/// answers un-placeable writes with `Busy`, and a retry policy rides out
/// the pressure once the consumer frees space.
fn retention_demo(store: &mut dyn DataStore) -> situ::Result<()> {
    let snap = Tensor::from_f32(&[16], vec![0.5; 16])?; // 64 B per snapshot
    // Keep the newest 2 generations per field, cap the store at exactly
    // that footprint, and retire stalled fields after 60 s.
    store.set_retention(RetentionConfig { window: 2, max_bytes: 128, ttl_ms: 60_000 })?;
    for step in 0..5 {
        store.put_tensor(&situ::client::tensor_key("field", 0, step), &snap)?;
    }
    let keys = store.list_keys("field_")?;
    assert_eq!(keys.len(), 2, "window retired the older generations");

    // A second field cannot fit under the cap — explicit backpressure.
    let err = store.put_tensor(&situ::client::tensor_key("other", 0, 0), &snap).unwrap_err();
    assert!(matches!(err, Error::Busy(_)), "flow control, not failure: {err}");

    // A retrying put lands once space frees up (here: the consumer drops
    // the old field; in a live run, the window advancing does the same).
    store.del_keys(&keys)?;
    let retries = store.put_tensor_retry(
        &situ::client::tensor_key("other", 0, 0),
        &snap,
        &RetryPolicy::backoff(std::time::Duration::from_millis(1), 3),
    )?;
    let info = store.info()?;
    println!(
        "[retention] busy_rejections={} evicted_keys={} retries={retries} fields={:?}",
        info.busy_rejections,
        info.evicted_keys,
        info.fields.iter().map(|f| f.field.as_str()).collect::<Vec<_>>()
    );
    store.set_retention(RetentionConfig::UNBOUNDED)?;
    store.flush_all()?;
    Ok(())
}

/// The cold-read pass: a windowed store with a spill directory retires old
/// generations to disk, and they replay byte-exact after eviction — the
/// post-hoc-analysis workflow the bounded-memory deployments need.
fn cold_tier_demo() -> situ::Result<()> {
    let spill_dir = std::env::temp_dir().join(format!("situ_quickstart_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&spill_dir);
    let server = DbServer::start(ServerConfig {
        with_models: false,
        retention: RetentionConfig::windowed(2, 0),
        spill: Some(SpillConfig::new(&spill_dir)),
        ..Default::default()
    })?;
    let mut c = Client::connect(server.addr)?;
    // Publish 5 generations under a 2-generation window: steps 0-2 retire.
    for step in 0..5u64 {
        let snap = Tensor::from_f32(&[8], vec![step as f32; 8])?;
        c.put_tensor(&situ::client::tensor_key("field", 0, step), &snap)?;
    }
    assert_eq!(c.list_keys("field_")?.len(), 2, "window retired the rest");

    // 1 line: list what spilled.  1 line: read a retired generation back.
    let cold = c.cold_list("field_")?;
    let replayed = c.cold_get(&situ::client::tensor_key("field", 0, 0))?;
    assert_eq!(replayed.to_f32()?, vec![0.0; 8], "byte-exact after eviction");
    let info = c.info()?;
    println!(
        "[cold-tier] retired {:?} to disk ({} segment(s)); replayed step 0 byte-exact, \
         cold_hits={}",
        cold, info.spill_segments, info.cold_hits
    );
    let _ = std::fs::remove_dir_all(&spill_dir);
    Ok(())
}

fn main() -> situ::Result<()> {
    // -- deployment A: one co-located database -----------------------------
    let server = DbServer::start(ServerConfig::default())?;
    println!("co-located database up at {} (engine={})", server.addr, server.config.engine.name());
    let mut single = Client::connect(server.addr)?;
    demo(&mut single, "co-located")?;
    retention_demo(&mut single)?;
    cold_tier_demo()?;

    // -- deployment B: a 2-shard clustered database ------------------------
    let shard_cfg = ServerConfig { with_models: false, ..Default::default() };
    let s1 = DbServer::start(shard_cfg.clone())?;
    let s2 = DbServer::start(shard_cfg)?;
    println!("clustered database up at {} + {}", s1.addr, s2.addr);
    let mut cluster = ClusterClient::connect(&[s1.addr, s2.addr])?;
    demo(&mut cluster, "clustered")?; // same code, different deployment

    // -- in-database inference (RedisAI-analogue, 3 lines) ----------------
    let artifacts = situ::db::server::artifacts_dir();
    if artifacts.join("resnet_lite_b1.hlo.txt").exists() {
        let mut client = single;
        client.put_model_from_file("resnet", &artifacts.join("resnet_lite_b1.hlo.txt"))?;
        let x = Tensor::from_f32(&[1, 3, 64, 64], vec![0.1; 3 * 64 * 64])?;
        client.put_tensor("img", &x)?; // step 1: send input
        client.run_model("resnet", &["img".into()], &["logits".into()], Device::Gpu(0))?; // step 2
        let logits = client.get_tensor("logits")?; // step 3: retrieve
        let (mean, mn, mx) = logits.f32_stats()?;
        println!("inference OK: logits {:?} mean={mean:.4} min={mn:.4} max={mx:.4}", logits.shape);
    } else {
        println!("(artifacts not built — run `make artifacts` to enable the inference demo)");
    }
    Ok(())
}
