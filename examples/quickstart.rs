//! Quickstart: the paper's "one line per operation" coupling claim.
//!
//! Launches a co-located database, connects a client, sends and retrieves a
//! tensor, uploads a model and runs in-database inference — the complete
//! SmartRedis-analogue surface in a dozen lines of user code.
//!
//! Run: `cargo run --release --example quickstart`

use situ::client::Client;
use situ::db::{DbServer, ServerConfig};
use situ::proto::Device;
use situ::tensor::Tensor;

fn main() -> situ::Result<()> {
    // -- deployment: one co-located database -----------------------------
    let server = DbServer::start(ServerConfig::default())?;
    println!("database up at {} (engine={})", server.addr, server.config.engine.name());

    // -- the one-line client API ------------------------------------------
    let mut client = Client::connect(server.addr)?; // 1 line: init
    let field = Tensor::from_f32(&[4, 8], (0..32).map(|i| i as f32).collect())?;
    client.put_tensor("field_rank0_step0", &field)?; // 1 line: send
    let back = client.get_tensor("field_rank0_step0")?; // 1 line: retrieve
    assert_eq!(back, field);
    println!("send/retrieve round trip OK ({} bytes)", field.nbytes());

    // -- metadata ----------------------------------------------------------
    client.put_meta("latest_step", "0")?;
    println!("latest_step = {:?}", client.get_meta("latest_step")?);

    // -- in-database inference (RedisAI-analogue, 3 lines) ----------------
    let artifacts = situ::db::server::artifacts_dir();
    if artifacts.join("resnet_lite_b1.hlo.txt").exists() {
        client.put_model_from_file("resnet", &artifacts.join("resnet_lite_b1.hlo.txt"))?;
        let x = Tensor::from_f32(&[1, 3, 64, 64], vec![0.1; 3 * 64 * 64])?;
        client.put_tensor("img", &x)?; // step 1: send input
        client.run_model("resnet", &["img".into()], &["logits".into()], Device::Gpu(0))?; // step 2
        let logits = client.get_tensor("logits")?; // step 3: retrieve
        let (mean, mn, mx) = logits.f32_stats()?;
        println!("inference OK: logits {:?} mean={mean:.4} min={mn:.4} max={mx:.4}", logits.shape);
    } else {
        println!("(artifacts not built — run `make artifacts` to enable the inference demo)");
    }

    let (keys, bytes, ops, models, _) = client.info()?;
    println!("db: {keys} keys, {bytes} bytes, {ops} ops, {models} models");
    Ok(())
}
