//! Quickstart: the paper's "one line per operation" coupling claim, written
//! once against the [`DataStore`] trait and run against *both* deployments.
//!
//! Launches a co-located database and a 2-shard cluster, drives the
//! identical workflow through `dyn DataStore` on each, pipelines a
//! multi-tensor publish into one round trip, and (when artifacts are built)
//! uploads a model and runs in-database inference.
//!
//! Run: `cargo run --release --example quickstart`

use situ::client::{Client, ClusterClient, DataStore, Pipeline, PollConfig};
use situ::db::{DbServer, ServerConfig};
use situ::proto::Device;
use situ::tensor::Tensor;

/// The whole coupling workflow, deployment-agnostic: the same function
/// serves the co-located single database and the sharded cluster.
fn demo(store: &mut dyn DataStore, label: &str) -> situ::Result<()> {
    // -- the one-line client API ------------------------------------------
    let field = Tensor::from_f32(&[4, 8], (0..32).map(|i| i as f32).collect())?;
    store.put_tensor("field_rank0_step0", &field)?; // 1 line: send
    let back = store.get_tensor("field_rank0_step0")?; // 1 line: retrieve
    assert_eq!(back, field);

    // -- pipelined publish: N tensors + metadata, one round trip ----------
    let mut pipe = Pipeline::new();
    for rank in 1..4 {
        pipe.put_tensor(&situ::client::tensor_key("field", rank, 0), &field);
    }
    pipe.put_meta("latest_step", "0");
    for r in store.execute(pipe)? {
        r.expect_ok()?;
    }

    // -- batched gather + server-side wait --------------------------------
    let keys: Vec<String> = (0..4).map(|r| situ::client::tensor_key("field", r, 0)).collect();
    store.poll_keys(&keys, &PollConfig::default())?; // blocks server-side
    let gathered = store.mget_tensors(&keys)?; // one frame per shard
    assert_eq!(gathered.len(), 4);

    // -- metadata ----------------------------------------------------------
    println!("[{label}] latest_step = {:?}", store.get_meta("latest_step")?);

    let info = store.info()?;
    println!(
        "[{label}] db: {} keys, {} bytes, {} ops (engine {})",
        info.keys, info.bytes, info.ops, info.engine
    );
    store.flush_all()?;
    Ok(())
}

fn main() -> situ::Result<()> {
    // -- deployment A: one co-located database -----------------------------
    let server = DbServer::start(ServerConfig::default())?;
    println!("co-located database up at {} (engine={})", server.addr, server.config.engine.name());
    let mut single = Client::connect(server.addr)?;
    demo(&mut single, "co-located")?;

    // -- deployment B: a 2-shard clustered database ------------------------
    let shard_cfg = ServerConfig { with_models: false, ..Default::default() };
    let s1 = DbServer::start(shard_cfg.clone())?;
    let s2 = DbServer::start(shard_cfg)?;
    println!("clustered database up at {} + {}", s1.addr, s2.addr);
    let mut cluster = ClusterClient::connect(&[s1.addr, s2.addr])?;
    demo(&mut cluster, "clustered")?; // same code, different deployment

    // -- in-database inference (RedisAI-analogue, 3 lines) ----------------
    let artifacts = situ::db::server::artifacts_dir();
    if artifacts.join("resnet_lite_b1.hlo.txt").exists() {
        let mut client = single;
        client.put_model_from_file("resnet", &artifacts.join("resnet_lite_b1.hlo.txt"))?;
        let x = Tensor::from_f32(&[1, 3, 64, 64], vec![0.1; 3 * 64 * 64])?;
        client.put_tensor("img", &x)?; // step 1: send input
        client.run_model("resnet", &["img".into()], &["logits".into()], Device::Gpu(0))?; // step 2
        let logits = client.get_tensor("logits")?; // step 3: retrieve
        let (mean, mn, mx) = logits.f32_stats()?;
        println!("inference OK: logits {:?} mean={mean:.4} min={mn:.4} max={mx:.4}", logits.shape);
    } else {
        println!("(artifacts not built — run `make artifacts` to enable the inference demo)");
    }
    Ok(())
}
