//! Scaling sweep across deployments and engines on the simulated Polaris
//! substrate — prints the paper-style series for Figs 3-6 in one run.
//!
//! Run: `cargo run --release --example scaling_sweep`

use situ::cluster::netmodel::CostModel;
use situ::cluster::scaling::sim_data_transfer;
use situ::config::{Deployment, RunConfig};
use situ::db::Engine;
use situ::telemetry::Table;
use situ::util::fmt;

fn main() {
    let model = CostModel::default();

    // Fig 3: DB core sweep, co-located, both engines.
    let mut t = Table::new(
        "Fig 3 — send+retrieve vs DB cores (co-located, 24 ranks x 256KB)",
        &["db cores", "redis", "keydb"],
    );
    for cores in [2usize, 4, 8, 16, 32] {
        let mut row = vec![cores.to_string()];
        for engine in [Engine::Redis, Engine::KeyDb] {
            let mut cfg = RunConfig::default();
            cfg.db_cores = cores;
            cfg.engine = engine;
            let st = sim_data_transfer(&cfg, &model, 42);
            row.push(fmt::duration(st.send.mean() + st.retrieve.mean()));
        }
        t.row(&row);
    }
    t.print();

    // Fig 5a: weak scaling co-located.
    let mut t = Table::new(
        "Fig 5a — weak scaling, co-located (256KB/rank, 24 ranks/node)",
        &["nodes", "ranks", "redis send", "redis retr", "keydb send", "keydb retr"],
    );
    for nodes in [1usize, 4, 16, 64, 192, 448] {
        let mut row = vec![nodes.to_string(), (nodes * 24).to_string()];
        for engine in [Engine::Redis, Engine::KeyDb] {
            let mut cfg = RunConfig::default();
            cfg.nodes = nodes;
            cfg.engine = engine;
            let st = sim_data_transfer(&cfg, &model, 42);
            row.push(fmt::duration(st.send.mean()));
            row.push(fmt::duration(st.retrieve.mean()));
        }
        t.row(&row);
    }
    t.print();

    // Fig 5b: clustered with fixed and proportional DB sizes.
    let mut t = Table::new(
        "Fig 5b — weak scaling, clustered (redis; rows: ranks, cols: DB nodes)",
        &["sim nodes", "ranks", "1 DB node", "4 DB nodes", "16 DB nodes"],
    );
    for nodes in [1usize, 4, 16, 64] {
        let mut row = vec![nodes.to_string(), (nodes * 24).to_string()];
        for db_nodes in [1usize, 4, 16] {
            let mut cfg = RunConfig::default();
            cfg.nodes = nodes;
            cfg.deployment = Deployment::Clustered { db_nodes };
            let st = sim_data_transfer(&cfg, &model, 42);
            row.push(fmt::duration(st.send.mean()));
        }
        t.row(&row);
    }
    t.print();

    // Fig 6: strong scaling, 384MB total.
    let total = 384usize << 20;
    let mut t = Table::new(
        "Fig 6 — strong scaling, co-located redis (384MB total)",
        &["nodes", "ranks", "bytes/rank", "send", "retrieve"],
    );
    for nodes in [1usize, 4, 16, 64, 192, 448] {
        let mut cfg = RunConfig::default();
        cfg.nodes = nodes;
        cfg.bytes_per_rank = (total / cfg.total_ranks()).max(1024);
        let st = sim_data_transfer(&cfg, &model, 42);
        t.row(&[
            nodes.to_string(),
            cfg.total_ranks().to_string(),
            fmt::bytes(cfg.bytes_per_rank as u64),
            fmt::duration(st.send.mean()),
            fmt::duration(st.retrieve.mean()),
        ]);
    }
    t.print();

    println!("(constants from CostModel::default(); run `situ calibrate` to refit on this host)");
}
