"""AOT compile path: lower every L2 graph to HLO *text* + emit the manifest.

This is the only place Python touches the pipeline; ``make artifacts`` runs it
once and the rust binary is self-contained afterwards.

Interchange format is HLO **text**, not ``.serialize()``: jax >= 0.5 emits
HloModuleProtos with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published ``xla`` 0.1.6 crate binds) rejects with
``proto.id() <= INT_MAX``.  The HLO text parser reassigns ids, so text
round-trips cleanly (see /opt/xla-example/README.md).

Artifacts (written to --outdir, default ../artifacts):

  train_step.hlo.txt     params+m+v+step+batch -> params'+m'+v'+step'+loss
  grad_step.hlo.txt      params+batch          -> loss+grads       (DDP path)
  apply_adam.hlo.txt     params+m+v+step+grads -> params'+m'+v'+step'
  eval_step.hlo.txt      params+batch          -> loss+rel_err     (Eq. 1)
  encoder.hlo.txt        enc_params+f          -> z                (Pallas path)
  decoder.hlo.txt        dec_params+z          -> f~               (Pallas path)
  autoencoder.hlo.txt    params+f              -> f~               (Pallas path)
  resnet_lite_b{N}.hlo.txt  x[N,3,64,64] -> logits[N,1000] (weights baked)
  params_init.bin        f32-LE concat of initial params (canonical order)
  mesh_coords.bin        f32-LE level-0 coords [N,3] (rust CFD sampler input)
  mesh_weights.bin       f32-LE level-0 quadrature weights [N]
  manifest.json          signatures, param table, hyperparams, mesh info
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import mesh as mesh_mod
from compile import model as model_mod


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True is load-bearing: the default text form elides
    # big literals as ``constant({...})``, which the rust-side parser would
    # happily re-materialize as zeros — silently corrupting baked weights and
    # mesh tables.
    return comp.as_hlo_text(print_large_constants=True)


def _sig(args) -> list[dict]:
    out = []
    for name, a in args:
        out.append(
            {
                "name": name,
                "dtype": str(a.dtype),
                "shape": [int(s) for s in a.shape],
            }
        )
    return out


class Emitter:
    def __init__(self, outdir: str):
        self.outdir = outdir
        self.artifacts = {}
        os.makedirs(outdir, exist_ok=True)

    def emit(self, name: str, fn, in_args: list[tuple[str, jax.ShapeDtypeStruct]],
             out_names: list[str]):
        """Lower ``fn(*specs)`` and record its signature in the manifest."""
        t0 = time.time()
        specs = [a for _, a in in_args]
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        path = os.path.join(self.outdir, fname)
        with open(path, "w") as f:
            f.write(text)
        # Output signature from abstract evaluation.
        out_shapes = jax.eval_shape(fn, *specs)
        flat, _ = jax.tree.flatten(out_shapes)
        assert len(flat) == len(out_names), (name, len(flat), len(out_names))
        self.artifacts[name] = {
            "file": fname,
            "inputs": _sig(in_args),
            "outputs": _sig(list(zip(out_names, flat))),
            "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
            "bytes": len(text),
        }
        print(f"  {fname:28s} {len(text)/1e6:7.2f} MB  ({time.time()-t0:.1f}s)")


def spec_like(x) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(x.shape, x.dtype)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--latent", type=int, default=model_mod.LATENT_DEFAULT)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--lr", type=float, default=model_mod.LEARNING_RATE)
    ap.add_argument("--resnet-batches", default="1,4,16",
                    help="comma list of resnet_lite batch sizes to lower")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = model_mod.ModelConfig(latent=args.latent, batch=args.batch, lr=args.lr)
    hier = mesh_mod.build_hierarchy()
    params = model_mod.init_params(cfg, hier, seed=args.seed)
    order = model_mod.param_order(params)
    enc_order = [k for k in order if k.startswith(("enc0_mlp", "enc1_mlp", "enc_lin"))]
    dec_order = [k for k in order if k.startswith(("dec0_mlp", "dec1_mlp", "dec_lin"))]

    n0 = hier.levels[0].n
    c = model_mod.CHANNELS
    f_spec = jax.ShapeDtypeStruct((c, n0), jnp.float32)
    batch_spec = jax.ShapeDtypeStruct((cfg.batch, c, n0), jnp.float32)
    z_spec = jax.ShapeDtypeStruct((cfg.latent,), jnp.float32)
    step_spec = jax.ShapeDtypeStruct((), jnp.int32)
    p_specs = [(k, spec_like(params[k])) for k in order]

    em = Emitter(args.outdir)
    print(f"AOT lowering to {args.outdir} (latent={cfg.latent}, batch={cfg.batch})")

    # --- train_step: the fully fused fwd+bwd+Adam artifact ------------------
    np_ = len(order)

    def train_step_flat(*flat):
        p = dict(zip(order, flat[:np_]))
        m = dict(zip(order, flat[np_: 2 * np_]))
        v = dict(zip(order, flat[2 * np_: 3 * np_]))
        step, batch = flat[3 * np_], flat[3 * np_ + 1]
        new_p, new_m, new_v, new_step, loss = model_mod.train_step(
            p, m, v, step, batch, hier, lr=cfg.lr
        )
        return (
            tuple(new_p[k] for k in order)
            + tuple(new_m[k] for k in order)
            + tuple(new_v[k] for k in order)
            + (new_step, loss)
        )

    train_in = (
        p_specs
        + [(f"m.{k}", s) for k, s in p_specs]
        + [(f"v.{k}", s) for k, s in p_specs]
        + [("step", step_spec), ("batch", batch_spec)]
    )
    train_out = (
        order
        + [f"m.{k}" for k in order]
        + [f"v.{k}" for k in order]
        + ["step", "loss"]
    )
    em.emit("train_step", train_step_flat, train_in, train_out)

    # --- grad_step / apply_adam: DDP-style allreduce decomposition ----------
    def grad_step_flat(*flat):
        p = dict(zip(order, flat[:np_]))
        batch = flat[np_]
        loss, grads = model_mod.grad_flat(p, batch, hier)
        return (loss,) + tuple(grads[k] for k in order)

    em.emit(
        "grad_step",
        grad_step_flat,
        p_specs + [("batch", batch_spec)],
        ["loss"] + [f"g.{k}" for k in order],
    )

    def apply_adam_flat(*flat):
        p = dict(zip(order, flat[:np_]))
        m = dict(zip(order, flat[np_: 2 * np_]))
        v = dict(zip(order, flat[2 * np_: 3 * np_]))
        step = flat[3 * np_]
        g = dict(zip(order, flat[3 * np_ + 1:]))
        new_p, new_m, new_v, new_step = model_mod.apply_adam(p, m, v, step, g, lr=cfg.lr)
        return (
            tuple(new_p[k] for k in order)
            + tuple(new_m[k] for k in order)
            + tuple(new_v[k] for k in order)
            + (new_step,)
        )

    em.emit(
        "apply_adam",
        apply_adam_flat,
        p_specs
        + [(f"m.{k}", s) for k, s in p_specs]
        + [(f"v.{k}", s) for k, s in p_specs]
        + [("step", step_spec)]
        + [(f"g.{k}", s) for k, s in p_specs],
        order + [f"m.{k}" for k in order] + [f"v.{k}" for k in order] + ["step"],
    )

    # --- eval_step: val loss + Eq.(1) relative error -------------------------
    def eval_step_flat(*flat):
        p = dict(zip(order, flat[:np_]))
        batch = flat[np_]
        return model_mod.eval_step(p, batch, hier)

    em.emit("eval_step", eval_step_flat, p_specs + [("batch", batch_spec)],
            ["loss", "rel_err"])

    # --- inference artifacts (Pallas kernel path) ----------------------------
    def encoder_flat(*flat):
        p = dict(zip(enc_order, flat[:-1]))
        return (model_mod.encode(p, flat[-1], hier, use_pallas=True),)

    em.emit(
        "encoder",
        encoder_flat,
        [(k, spec_like(params[k])) for k in enc_order] + [("f", f_spec)],
        ["z"],
    )

    def decoder_flat(*flat):
        p = dict(zip(dec_order, flat[:-1]))
        return (model_mod.decode(p, flat[-1], hier, use_pallas=True),)

    em.emit(
        "decoder",
        decoder_flat,
        [(k, spec_like(params[k])) for k in dec_order] + [("z", z_spec)],
        ["f_recon"],
    )

    def autoencoder_flat(*flat):
        p = dict(zip(order, flat[:-1]))
        return (model_mod.autoencode(p, flat[-1], hier, use_pallas=True),)

    em.emit("autoencoder", autoencoder_flat, p_specs + [("f", f_spec)], ["f_recon"])

    # --- resnet_lite inference models (weights baked as constants) ----------
    rparams = model_mod.init_resnet_params()
    for b in [int(x) for x in args.resnet_batches.split(",") if x]:
        x_spec = jax.ShapeDtypeStruct((b, 3, model_mod.RESNET_HW, model_mod.RESNET_HW),
                                      jnp.float32)
        em.emit(
            f"resnet_lite_b{b}",
            lambda x: (model_mod.resnet_lite(rparams, x),),
            [("x", x_spec)],
            ["logits"],
        )

    # --- binary blobs for the rust side --------------------------------------
    def write_bin(name: str, arr: np.ndarray):
        path = os.path.join(args.outdir, name)
        np.asarray(arr, dtype="<f4").tofile(path)
        print(f"  {name:28s} {os.path.getsize(path)/1e3:7.1f} KB")

    flat_init = np.concatenate([np.asarray(params[k]).ravel() for k in order])
    write_bin("params_init.bin", flat_init)
    write_bin("mesh_coords.bin", hier.levels[0].coords)
    write_bin("mesh_weights.bin", hier.levels[0].weights)

    param_table, off = [], 0
    for k in order:
        n = int(np.prod(params[k].shape))
        param_table.append(
            {"name": k, "shape": [int(s) for s in params[k].shape], "offset": off, "len": n}
        )
        off += n

    manifest = {
        "format": 1,
        "generated_unix": int(time.time()),
        "model": {
            "channels": c,
            "n_points": n0,
            "latent": cfg.latent,
            "batch": cfg.batch,
            "lr": cfg.lr,
            "adam": {"b1": model_mod.ADAM_B1, "b2": model_mod.ADAM_B2,
                      "eps": model_mod.ADAM_EPS},
            "n_param_tensors": np_,
            "n_params_total": int(off),
            "compression_factor": (c * n0) / cfg.latent,
        },
        "mesh": {
            "levels": [list(l.shape) for l in hier.levels],
            "domain": [mesh_mod.LX, mesh_mod.LY, mesh_mod.LZ],
            "beta": mesh_mod.BETA,
            "k_enc": hier.k_enc,
            "k_dec": hier.k_dec,
        },
        "param_order": order,
        "enc_param_order": enc_order,
        "dec_param_order": dec_order,
        "param_table": param_table,
        "artifacts": em.artifacts,
    }
    with open(os.path.join(args.outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"  manifest.json                ({len(em.artifacts)} artifacts)")


if __name__ == "__main__":
    main()
