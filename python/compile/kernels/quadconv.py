"""L1 Pallas kernels: the QuadConv quadrature contraction (and the MLP filter
evaluation) as explicit TPU-style blocked kernels.

HARDWARE ADAPTATION (see DESIGN.md §Hardware-Adaptation).  The original
PyTorch-QuadConv package targets GPUs: one CUDA threadblock per output-point
tile, features staged through shared memory, the channel contraction on the
tensor cores.  The TPU re-think:

  * the output-point axis ``J`` becomes the Pallas *grid*; each grid step owns
    a ``BLOCK_J`` tile whose operand slices (``g``, ``fg``, ``wq``) are staged
    HBM->VMEM by ``BlockSpec`` (VMEM plays the scratchpad role shared memory
    played on the GPU);
  * the (k, ci) reduction is flattened so the inner contraction is a single
    ``dot_general`` of shape [BLOCK_J, CO, K*CI] x [BLOCK_J, K*CI] — a batched
    matrix-vector product the MXU executes as (CO x K*CI) matmuls;
  * neighbor gathering is *hoisted out* of the kernel: the mesh is static, so
    the gather indices are AOT constants and XLA performs one fused gather
    feeding the kernel.  The kernel body is branch-free and fully vectorized
    (no scatter/atomics, unlike the GPU scatter-based implementation).

``interpret=True`` is mandatory here: real TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute.  Numerics are validated
against ``ref.py``; TPU VMEM/MXU estimates live in DESIGN.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile along the output-point axis.  At CO=CI=16, K=16 this stages
#   g:  64*16*16*16*4B = 1.0 MiB
#   fg: 64*16*16*4B    = 64 KiB
#   wq: 64*16*4B       = 4 KiB
# per step — comfortably inside a TPU core's ~16 MiB VMEM with double
# buffering (DESIGN.md §Perf).
DEFAULT_BLOCK_J = 64


def _contract_kernel(g_ref, v_ref, o_ref):
    """out[j, co] = sum_l g[j, co, l] * v[j, l]   (l = flattened (k, ci))."""
    g = g_ref[...]  # [BJ, CO, L]
    v = v_ref[...]  # [BJ, L]
    # Batched mat-vec on the MXU: contract l, batch j.
    o_ref[...] = jax.lax.dot_general(
        g,
        v,
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )


def quadconv_contract(
    g: jnp.ndarray,  # [J, K, CO, CI]
    fg: jnp.ndarray,  # [J, K, CI]
    wq: jnp.ndarray,  # [J, K]
    *,
    block_j: int = DEFAULT_BLOCK_J,
    interpret: bool = True,
) -> jnp.ndarray:
    """Pallas quadrature contraction; semantics == ref.quadconv_contract_ref.

    Returns [J, CO].
    """
    j, k, co, ci = g.shape
    bj = min(block_j, j)
    if j % bj != 0:
        # Pad the output-point axis up to a tile multiple; padded rows compute
        # garbage that is sliced off (weights are NOT consulted there).
        pad = (-j) % bj
        g = jnp.pad(g, ((0, pad), (0, 0), (0, 0), (0, 0)))
        fg = jnp.pad(fg, ((0, pad), (0, 0), (0, 0)))
        wq = jnp.pad(wq, ((0, pad), (0, 0)))
        out = quadconv_contract(g, fg, wq, block_j=bj, interpret=interpret)
        return out[:j]

    # Pre-scale the gathered features by the quadrature weights and flatten
    # the reduction axis:  v[j, k*ci] = wq[j,k] * fg[j,k,ci].
    v = (fg * wq[:, :, None]).reshape(j, k * ci)
    gf = jnp.transpose(g, (0, 2, 1, 3)).reshape(j, co, k * ci)

    grid = (j // bj,)
    return pl.pallas_call(
        _contract_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bj, co, k * ci), lambda i: (i, 0, 0)),
            pl.BlockSpec((bj, k * ci), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bj, co), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((j, co), jnp.float32),
        interpret=interpret,
    )(gf, v)


def _mlp_tile_kernel(n_layers: int, d_ref, *refs):
    """Five-layer MLP filter evaluated on a tile of coordinate offsets.

    refs = (w0, b0, w1, b1, ..., o_ref).  Hidden activations live in VMEM for
    the whole tile; the matmuls hit the MXU.
    """
    o_ref = refs[-1]
    h = d_ref[...]  # [T, 3]
    for i in range(n_layers):
        w = refs[2 * i][...]
        b = refs[2 * i + 1][...]
        h = (
            jax.lax.dot_general(
                h, w, dimension_numbers=(((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
            + b
        )
        if i + 1 < n_layers:
            h = jnp.tanh(h)
    o_ref[...] = h


def mlp_filter(
    params: dict,
    dcoords: jnp.ndarray,  # [..., 3]
    c_out: int,
    c_in: int,
    *,
    block_t: int = 256,
    interpret: bool = True,
) -> jnp.ndarray:
    """Pallas MLP filter evaluation; semantics == ref.mlp_filter_ref.

    The leading axes are flattened into a point axis tiled by ``block_t``.
    Returns [..., c_out, c_in].
    """
    n_layers = len([kk for kk in params if kk.startswith("w")])
    lead = dcoords.shape[:-1]
    t = 1
    for s in lead:
        t *= s
    d = dcoords.reshape(t, 3)
    bt = min(block_t, t)
    pad = (-t) % bt
    if pad:
        d = jnp.pad(d, ((0, pad), (0, 0)))
    tp = d.shape[0]

    ws = [params[f"w{i}"] for i in range(n_layers)]
    bs = [params[f"b{i}"] for i in range(n_layers)]
    out_dim = ws[-1].shape[1]

    in_specs = [pl.BlockSpec((bt, 3), lambda i: (i, 0))]
    for w, b in zip(ws, bs):
        in_specs.append(pl.BlockSpec(w.shape, lambda i: (0, 0)))
        in_specs.append(pl.BlockSpec(b.shape, lambda i: (0,)))

    out = pl.pallas_call(
        functools.partial(_mlp_tile_kernel, n_layers),
        grid=(tp // bt,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bt, out_dim), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((tp, out_dim), jnp.float32),
        interpret=interpret,
    )(d, *[x for pair in zip(ws, bs) for x in pair])
    out = out[:t]
    return out.reshape(lead + (c_out, c_in))


def quadconv(
    f: jnp.ndarray,  # [CI, N_in]
    mlp_params: dict,
    out_coords: jnp.ndarray,  # [J, 3]
    in_coords: jnp.ndarray,  # [N_in, 3]
    weights: jnp.ndarray,  # [N_in]
    idx: jnp.ndarray,  # [J, K] int32
    c_out: int,
    *,
    interpret: bool = True,
) -> jnp.ndarray:
    """Full QuadConv layer on the Pallas path; semantics == ref.quadconv_ref.

    Gather is hoisted to XLA (static mesh => fused gather); the MLP filter and
    the quadrature contraction are Pallas kernels.  Returns [c_out, J].
    """
    c_in = f.shape[0]
    dcoords = in_coords[idx] - out_coords[:, None, :]  # [J, K, 3]
    g = mlp_filter(mlp_params, dcoords, c_out, c_in, interpret=interpret)
    fg = jnp.transpose(f, (1, 0))[idx]  # [J, K, CI]
    wq = weights[idx]  # [J, K]
    out = quadconv_contract(g, fg, wq, interpret=interpret)  # [J, CO]
    return jnp.transpose(out, (1, 0))
