"""Pure-jnp correctness oracles for the L1 Pallas kernels.

These are the *reference semantics* against which the Pallas kernels in
``quadconv.py`` are validated by pytest/hypothesis.  They are also the
implementation used inside the differentiable training graph (``train_step``):
XLA fuses the einsum contraction well, autodiff is exact, and the Pallas
kernel (validated equal to this) is used on the inference/encode artifacts.
"""

from __future__ import annotations

import jax.numpy as jnp


def mlp_filter_ref(params: dict, dcoords: jnp.ndarray, c_out: int, c_in: int) -> jnp.ndarray:
    """Continuous convolution kernel K(x_i - y_j) parameterized by an MLP.

    ``params`` holds ``w0..w4`` / ``b0..b4`` of a five-layer MLP mapping a 3D
    coordinate offset to a (c_out, c_in) matrix (paper §4: "filters map 3D
    spatial coordinates through a five layer MLP to R^{16x16}").

    dcoords: [..., 3]  ->  returns [..., c_out, c_in]
    """
    h = dcoords
    n_layers = len([k for k in params if k.startswith("w")])
    for i in range(n_layers):
        h = h @ params[f"w{i}"] + params[f"b{i}"]
        if i + 1 < n_layers:
            h = jnp.tanh(h)
    return h.reshape(h.shape[:-1] + (c_out, c_in))


def quadconv_contract_ref(
    g: jnp.ndarray,  # [J, K, CO, CI]  MLP-evaluated kernel at (out j, nbr k)
    fg: jnp.ndarray,  # [J, K, CI]     features gathered at neighbor points
    wq: jnp.ndarray,  # [J, K]         quadrature weights at neighbor points
) -> jnp.ndarray:
    """Quadrature contraction: out[j, co] = sum_{k,ci} wq[j,k] g[j,k,co,ci] fg[j,k,ci].

    This single weighted sum is the QuadConv operator's approximation of the
    continuous convolution integral (Doherty et al. 2023) and is the compute
    hot-spot the Pallas kernel implements.
    """
    return jnp.einsum("jkoc,jkc,jk->jo", g, fg, wq)


def quadconv_ref(
    f: jnp.ndarray,  # [CI, N_in]  input features
    mlp_params: dict,
    out_coords: jnp.ndarray,  # [J, 3]
    in_coords: jnp.ndarray,  # [N_in, 3]
    weights: jnp.ndarray,  # [N_in] quadrature weights of the input level
    idx: jnp.ndarray,  # [J, K] neighbor indices into the input level
    c_out: int,
) -> jnp.ndarray:
    """Full QuadConv layer (gather + MLP filter + contraction), reference path.

    Returns [c_out, J].
    """
    c_in = f.shape[0]
    # [J, K, 3] offsets from each output point to its quadrature neighbors.
    dcoords = in_coords[idx] - out_coords[:, None, :]
    g = mlp_filter_ref(mlp_params, dcoords, c_out, c_in)  # [J, K, CO, CI]
    fg = jnp.transpose(f, (1, 0))[idx]  # [J, K, CI]
    wq = weights[idx]  # [J, K]
    out = quadconv_contract_ref(g, fg, wq)  # [J, CO]
    return jnp.transpose(out, (1, 0))
