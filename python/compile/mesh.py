"""Stretched near-wall mesh hierarchy for the QuadConv autoencoder.

The paper trains on per-rank partitions of a flat-plate turbulent boundary
layer DNS mesh (36M elements globally).  Each PHASTA rank owns a partition
whose points are clustered toward the wall.  We reproduce a single-rank
partition as a structured-but-non-uniform lattice: uniform in the streamwise
(x) and spanwise (z) directions, tanh-stretched toward the wall in the
wall-normal (y) direction — exactly the situation QuadConv was designed for
(convolutions on non-uniform point sets via quadrature).

The encoder downsamples through a hierarchy of coarser lattices; for each
level we precompute, at AOT time (the mesh is static for the whole run):

  * point coordinates               [N, 3]      float32
  * trapezoidal quadrature weights  [N]         float32
  * K-nearest-neighbor index table  [N_out, K]  int32   (output pt -> input pts)

These tables are baked into the lowered HLO as constants and also exported to
``artifacts/`` so the rust CFD producer samples its fields on the identical
point set.
"""

from __future__ import annotations

import dataclasses

import numpy as np

# Domain extents (channel half-height units), matching the rust solver's
# sampling box (rust/src/sim/cfd/sampler.rs).
LX, LY, LZ = 4.0, 2.0, 2.0
# Wall-normal stretching factor: y_j = tanh(beta * s) / tanh(beta), s in [0,1].
BETA = 2.2

# Lattice shapes per level. level 0 is the input resolution (N = 1024 points
# per rank, the paper's per-rank sample is O(10^5) -- scaled down with the
# problem, see DESIGN.md).  Products: 1024 -> 256 -> 64.
LEVELS = ((16, 8, 8), (8, 8, 4), (4, 4, 4))


def _axis_coords(n: int, length: float, stretched: bool) -> np.ndarray:
    """Node coordinates along one axis (cell-centered)."""
    s = (np.arange(n, dtype=np.float64) + 0.5) / n
    if stretched:
        y = np.tanh(BETA * s) / np.tanh(BETA)
        return (y * length).astype(np.float64)
    return (s * length).astype(np.float64)


def _axis_weights(x: np.ndarray, length: float) -> np.ndarray:
    """Trapezoidal quadrature weights for possibly non-uniform nodes."""
    n = len(x)
    w = np.zeros(n, dtype=np.float64)
    if n == 1:
        w[0] = length
        return w
    # Cell widths via midpoints, with the boundary cells extended to the
    # domain edges so the weights integrate constants exactly.
    mid = 0.5 * (x[1:] + x[:-1])
    edges = np.concatenate([[0.0], mid, [length]])
    w = edges[1:] - edges[:-1]
    return w


@dataclasses.dataclass(frozen=True)
class Level:
    """One resolution level of the mesh hierarchy."""

    shape: tuple[int, int, int]
    coords: np.ndarray  # [N, 3] float32
    weights: np.ndarray  # [N] float32 (quadrature weights, sum == volume)

    @property
    def n(self) -> int:
        return int(np.prod(self.shape))


def build_level(shape: tuple[int, int, int]) -> Level:
    nx, ny, nz = shape
    xs = _axis_coords(nx, LX, stretched=False)
    ys = _axis_coords(ny, LY, stretched=True)
    zs = _axis_coords(nz, LZ, stretched=False)
    wx = _axis_weights(xs, LX)
    wy = _axis_weights(ys, LY)
    wz = _axis_weights(zs, LZ)
    X, Y, Z = np.meshgrid(xs, ys, zs, indexing="ij")
    coords = np.stack([X.ravel(), Y.ravel(), Z.ravel()], axis=1)
    W = (
        wx[:, None, None] * wy[None, :, None] * wz[None, None, :]
    ).ravel()
    return Level(
        shape=shape,
        coords=coords.astype(np.float32),
        weights=W.astype(np.float32),
    )


def knn_indices(out_coords: np.ndarray, in_coords: np.ndarray, k: int) -> np.ndarray:
    """Indices of the k nearest input points for every output point.

    Brute force (N is small at AOT time); ties broken by index order for
    determinism.
    """
    d2 = ((out_coords[:, None, :] - in_coords[None, :, :]) ** 2).sum(axis=2)
    idx = np.argsort(d2, axis=1, kind="stable")[:, :k]
    return idx.astype(np.int32)


@dataclasses.dataclass(frozen=True)
class MeshHierarchy:
    """Everything the model needs about the (static) mesh."""

    levels: tuple[Level, ...]
    # Encoder neighbor tables: enc_idx[l] maps level l+1 output points to
    # level l input points, shape [N_{l+1}, K_enc].
    enc_idx: tuple[np.ndarray, ...]
    # Decoder neighbor tables: dec_idx[l] maps level l output points to
    # level l+1 input points, shape [N_l, K_dec].
    dec_idx: tuple[np.ndarray, ...]
    k_enc: int
    k_dec: int


def build_hierarchy(
    levels: tuple[tuple[int, int, int], ...] = LEVELS,
    k_enc: int = 16,
    k_dec: int = 9,
) -> MeshHierarchy:
    lvls = tuple(build_level(s) for s in levels)
    enc_idx = tuple(
        knn_indices(lvls[l + 1].coords, lvls[l].coords, k_enc)
        for l in range(len(lvls) - 1)
    )
    dec_idx = tuple(
        knn_indices(lvls[l].coords, lvls[l + 1].coords, k_dec)
        for l in range(len(lvls) - 1)
    )
    return MeshHierarchy(
        levels=lvls, enc_idx=enc_idx, dec_idx=dec_idx, k_enc=k_enc, k_dec=k_dec
    )


def volume() -> float:
    return LX * LY * LZ
