"""L2: the paper's compute graphs in JAX, AOT-lowered for the rust runtime.

Two model families:

* **QuadConv autoencoder** (paper §4, adapted from Doherty et al. 2023): a
  2-block encoder / mirrored decoder over the static mesh hierarchy built in
  ``mesh.py``.  Filters are 5-layer coordinate MLPs (spectral norm removed for
  traceability, exactly as the paper did).  The *training* graph
  (``train_step``: fwd + bwd + fused Adam) uses the differentiable reference
  QuadConv path; the *inference* graphs (``encode``/``decode``/``autoencoder``)
  call the L1 Pallas kernels, which pytest proves bit-compatible (to fp32
  tolerance) with the reference path.

* **resnet_lite**: the inference-benchmark model standing in for ResNet50
  (substitution documented in DESIGN.md): a 3-stage residual CNN with the same
  (n, 3, H, W) -> (n, 1000) signature.

Everything here runs at build time only; the lowered HLO text is the
interchange artifact executed by ``rust/src/runtime``.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from compile import mesh as mesh_mod
from compile.kernels import quadconv as qc
from compile.kernels import ref as kref

# ---------------------------------------------------------------------------
# Configuration
# ---------------------------------------------------------------------------

CHANNELS = 4  # (p, u, v, w) — pressure + three velocity components
HIDDEN_CH = 16  # internal data channels (paper: 16)
MLP_HIDDEN = 32  # width of the filter MLPs
MLP_LAYERS = 5  # paper: "five layer MLP"
LATENT_DEFAULT = 100  # paper: latent dimension 100 (1700x compression study)

ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.999, 1e-8
LEARNING_RATE = 1e-4  # paper: 0.0001, scaled linearly with ranks by the caller


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    latent: int = LATENT_DEFAULT
    batch: int = 4
    lr: float = LEARNING_RATE

    @property
    def n_points(self) -> int:
        return int(np.prod(mesh_mod.LEVELS[0]))


# ---------------------------------------------------------------------------
# Parameter initialization
# ---------------------------------------------------------------------------


def _init_mlp(key, c_out: int, c_in: int) -> dict:
    """Filter MLP: 3 -> MLP_HIDDEN^(L-1) -> c_out*c_in, Glorot init."""
    dims = [3] + [MLP_HIDDEN] * (MLP_LAYERS - 1) + [c_out * c_in]
    params = {}
    for i in range(MLP_LAYERS):
        key, sub = jax.random.split(key)
        scale = jnp.sqrt(2.0 / (dims[i] + dims[i + 1]))
        params[f"w{i}"] = scale * jax.random.normal(
            sub, (dims[i], dims[i + 1]), jnp.float32
        )
        params[f"b{i}"] = jnp.zeros((dims[i + 1],), jnp.float32)
    return params


def init_params(cfg: ModelConfig, hier: mesh_mod.MeshHierarchy, seed: int = 0) -> dict:
    """Flat ``{name: array}`` parameter dict (flatness keeps the AOT manifest
    and the rust-side buffer management trivially ordered)."""
    key = jax.random.key(seed)
    n2 = hier.levels[2].n
    flat_dim = HIDDEN_CH * n2
    keys = jax.random.split(key, 8)
    params = {}
    # Encoder block 0: CHANNELS -> HIDDEN_CH, level0 -> level1.
    for name, p in _init_mlp(keys[0], HIDDEN_CH, CHANNELS).items():
        params[f"enc0_mlp.{name}"] = p
    # Encoder block 1: HIDDEN_CH -> HIDDEN_CH, level1 -> level2.
    for name, p in _init_mlp(keys[1], HIDDEN_CH, HIDDEN_CH).items():
        params[f"enc1_mlp.{name}"] = p
    scale = jnp.sqrt(2.0 / (flat_dim + cfg.latent))
    params["enc_lin.w"] = scale * jax.random.normal(
        keys[2], (flat_dim, cfg.latent), jnp.float32
    )
    params["enc_lin.b"] = jnp.zeros((cfg.latent,), jnp.float32)
    params["dec_lin.w"] = scale * jax.random.normal(
        keys[3], (cfg.latent, flat_dim), jnp.float32
    )
    params["dec_lin.b"] = jnp.zeros((flat_dim,), jnp.float32)
    # Decoder block 1: HIDDEN_CH -> HIDDEN_CH, level2 -> level1.
    for name, p in _init_mlp(keys[4], HIDDEN_CH, HIDDEN_CH).items():
        params[f"dec1_mlp.{name}"] = p
    # Decoder block 0: HIDDEN_CH -> CHANNELS, level1 -> level0.
    for name, p in _init_mlp(keys[5], CHANNELS, HIDDEN_CH).items():
        params[f"dec0_mlp.{name}"] = p
    return params


def param_order(params: dict) -> list[str]:
    """Canonical (sorted) parameter ordering shared with the rust runtime."""
    return sorted(params.keys())


def _sub(params: dict, prefix: str) -> dict:
    return {k.split(".", 1)[1]: v for k, v in params.items() if k.startswith(prefix + ".")}


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------


def _quadconv_layer(use_pallas: bool) -> Callable:
    return qc.quadconv if use_pallas else kref.quadconv_ref


def encode(params: dict, f: jnp.ndarray, hier: mesh_mod.MeshHierarchy,
           *, use_pallas: bool) -> jnp.ndarray:
    """f: [CHANNELS, N0] -> latent [latent]."""
    layer = _quadconv_layer(use_pallas)
    l0, l1, l2 = hier.levels
    h = layer(f, _sub(params, "enc0_mlp"), l1.coords, l0.coords, l0.weights,
              hier.enc_idx[0], HIDDEN_CH)
    h = jax.nn.gelu(h)
    h = layer(h, _sub(params, "enc1_mlp"), l2.coords, l1.coords, l1.weights,
              hier.enc_idx[1], HIDDEN_CH)
    h = jax.nn.gelu(h)
    z = h.reshape(-1) @ params["enc_lin.w"] + params["enc_lin.b"]
    return z


def decode(params: dict, z: jnp.ndarray, hier: mesh_mod.MeshHierarchy,
           *, use_pallas: bool) -> jnp.ndarray:
    """latent [latent] -> reconstruction [CHANNELS, N0]."""
    layer = _quadconv_layer(use_pallas)
    l0, l1, l2 = hier.levels
    h = z @ params["dec_lin.w"] + params["dec_lin.b"]
    h = jax.nn.gelu(h).reshape(HIDDEN_CH, l2.n)
    h = layer(h, _sub(params, "dec1_mlp"), l1.coords, l2.coords, l2.weights,
              hier.dec_idx[1], HIDDEN_CH)
    h = jax.nn.gelu(h)
    h = layer(h, _sub(params, "dec0_mlp"), l0.coords, l1.coords, l1.weights,
              hier.dec_idx[0], CHANNELS)
    return h


def autoencode(params: dict, f: jnp.ndarray, hier: mesh_mod.MeshHierarchy,
               *, use_pallas: bool) -> jnp.ndarray:
    return decode(params, encode(params, f, hier, use_pallas=use_pallas), hier,
                  use_pallas=use_pallas)


def batch_loss(params: dict, batch: jnp.ndarray, hier: mesh_mod.MeshHierarchy,
               *, use_pallas: bool = False) -> jnp.ndarray:
    """MSE over a batch [B, CHANNELS, N0] (paper: standard MSE loss)."""
    recon = jax.vmap(lambda f: autoencode(params, f, hier, use_pallas=use_pallas))(batch)
    return jnp.mean((recon - batch) ** 2)


def relative_error(params: dict, batch: jnp.ndarray, hier: mesh_mod.MeshHierarchy,
                   *, use_pallas: bool = False) -> jnp.ndarray:
    """Paper Eq. (1): mean over samples of ||F - F~||_F / ||F||_F."""
    recon = jax.vmap(lambda f: autoencode(params, f, hier, use_pallas=use_pallas))(batch)
    num = jnp.sqrt(jnp.sum((batch - recon) ** 2, axis=(1, 2)))
    den = jnp.sqrt(jnp.sum(batch ** 2, axis=(1, 2)))
    return jnp.mean(num / den)


# ---------------------------------------------------------------------------
# Training step (fwd + bwd + Adam, one fused artifact)
# ---------------------------------------------------------------------------


def train_step(params: dict, m: dict, v: dict, step: jnp.ndarray,
               batch: jnp.ndarray, hier: mesh_mod.MeshHierarchy,
               lr: float = LEARNING_RATE):
    """One Adam step on the MSE loss.  Entirely inside one HLO module so the
    rust trainer performs a step with a single PJRT execute (no per-layer
    dispatch on the request path)."""
    loss, grads = jax.value_and_grad(batch_loss)(params, batch, hier)
    step = step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - ADAM_B1 ** t
    bc2 = 1.0 - ADAM_B2 ** t
    new_p, new_m, new_v = {}, {}, {}
    for k in params:
        g = grads[k]
        new_m[k] = ADAM_B1 * m[k] + (1.0 - ADAM_B1) * g
        new_v[k] = ADAM_B2 * v[k] + (1.0 - ADAM_B2) * g * g
        mh = new_m[k] / bc1
        vh = new_v[k] / bc2
        new_p[k] = params[k] - lr * mh / (jnp.sqrt(vh) + ADAM_EPS)
    return new_p, new_m, new_v, step, loss


def eval_step(params: dict, batch: jnp.ndarray, hier: mesh_mod.MeshHierarchy):
    """Validation loss + paper-Eq.(1) relative error, one artifact."""
    return (
        batch_loss(params, batch, hier),
        relative_error(params, batch, hier),
    )


def grad_flat(params: dict, batch: jnp.ndarray, hier: mesh_mod.MeshHierarchy):
    """(loss, grads) — exported separately so the rust trainer can implement
    data-parallel gradient allreduce across ranks before applying Adam."""
    loss, grads = jax.value_and_grad(batch_loss)(params, batch, hier)
    return loss, grads


def apply_adam(params: dict, m: dict, v: dict, step: jnp.ndarray, grads: dict,
               lr: float = LEARNING_RATE):
    """Adam update given externally-reduced gradients (DDP-style)."""
    step = step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - ADAM_B1 ** t
    bc2 = 1.0 - ADAM_B2 ** t
    new_p, new_m, new_v = {}, {}, {}
    for k in params:
        g = grads[k]
        new_m[k] = ADAM_B1 * m[k] + (1.0 - ADAM_B1) * g
        new_v[k] = ADAM_B2 * v[k] + (1.0 - ADAM_B2) * g * g
        mh = new_m[k] / bc1
        vh = new_v[k] / bc2
        new_p[k] = params[k] - lr * mh / (jnp.sqrt(vh) + ADAM_EPS)
    return new_p, new_m, new_v, step


# ---------------------------------------------------------------------------
# resnet_lite — the ResNet50 stand-in for the inference benchmarks (Figs 7-8)
# ---------------------------------------------------------------------------

RESNET_STAGES = (16, 32, 64)  # channels per stage, 2 residual blocks each
RESNET_CLASSES = 1000
RESNET_HW = 64  # input is (n, 3, 64, 64); see DESIGN.md substitutions


def _conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )


def init_resnet_params(seed: int = 7) -> dict:
    key = jax.random.key(seed)
    params = {}

    def conv_w(key, c_out, c_in, kh=3, kw=3):
        scale = jnp.sqrt(2.0 / (c_in * kh * kw))
        return scale * jax.random.normal(key, (c_out, c_in, kh, kw), jnp.float32)

    keys = iter(jax.random.split(key, 64))
    params["stem.w"] = conv_w(next(keys), RESNET_STAGES[0], 3)
    c_prev = RESNET_STAGES[0]
    for s, c in enumerate(RESNET_STAGES):
        for b in range(2):
            cin = c_prev if b == 0 else c
            params[f"s{s}b{b}.w1"] = conv_w(next(keys), c, cin)
            params[f"s{s}b{b}.b1"] = jnp.zeros((c,), jnp.float32)
            params[f"s{s}b{b}.w2"] = conv_w(next(keys), c, c)
            params[f"s{s}b{b}.b2"] = jnp.zeros((c,), jnp.float32)
            if cin != c:
                params[f"s{s}b{b}.proj"] = conv_w(next(keys), c, cin, 1, 1)
        c_prev = c
    scale = jnp.sqrt(2.0 / (RESNET_STAGES[-1] + RESNET_CLASSES))
    params["head.w"] = scale * jax.random.normal(
        next(keys), (RESNET_STAGES[-1], RESNET_CLASSES), jnp.float32
    )
    params["head.b"] = jnp.zeros((RESNET_CLASSES,), jnp.float32)
    return params


def resnet_lite(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    """x: [n, 3, 64, 64] -> logits [n, 1000]."""
    h = _conv(x, params["stem.w"], stride=2)  # 32x32
    for s, c in enumerate(RESNET_STAGES):
        stride = 1 if s == 0 else 2
        for b in range(2):
            inp = h
            st = stride if b == 0 else 1
            h = _conv(h, params[f"s{s}b{b}.w1"], stride=st)
            h = jax.nn.relu(h + params[f"s{s}b{b}.b1"][None, :, None, None])
            h = _conv(h, params[f"s{s}b{b}.w2"])
            h = h + params[f"s{s}b{b}.b2"][None, :, None, None]
            if f"s{s}b{b}.proj" in params:
                inp = _conv(inp, params[f"s{s}b{b}.proj"], stride=st)
            elif st != 1:
                inp = _conv(inp, jnp.eye(h.shape[1], inp.shape[1])[:, :, None, None], stride=st)
            h = jax.nn.relu(h + inp)
    h = h.mean(axis=(2, 3))  # global average pool
    return h @ params["head.w"] + params["head.b"]
