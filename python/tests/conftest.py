import numpy as np
import pytest

from compile import mesh as mesh_mod
from compile import model as model_mod


@pytest.fixture(scope="session")
def hier():
    return mesh_mod.build_hierarchy()


@pytest.fixture(scope="session")
def cfg():
    return model_mod.ModelConfig()


@pytest.fixture(scope="session")
def params(cfg, hier):
    return model_mod.init_params(cfg, hier, seed=0)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(1234)
