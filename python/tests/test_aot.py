"""AOT pipeline tests: lowering produces parseable, complete HLO text and a
manifest consistent with the emitted files."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, mesh as mesh_mod, model as model_mod

ARTDIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_to_hlo_text_basic():
    lowered = jax.jit(lambda x, y: (x @ y + 2.0,)).lower(
        jax.ShapeDtypeStruct((2, 2), jnp.float32),
        jax.ShapeDtypeStruct((2, 2), jnp.float32),
    )
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "dot" in text


def test_to_hlo_text_prints_large_constants():
    """Regression: the default as_hlo_text elides big literals as
    ``constant({...})`` which would load as garbage in rust."""
    big = jnp.arange(4096, dtype=jnp.float32)
    lowered = jax.jit(lambda x: (x + big,)).lower(
        jax.ShapeDtypeStruct((4096,), jnp.float32)
    )
    text = aot.to_hlo_text(lowered)
    assert "constant({...})" not in text
    assert "4095" in text  # the last element is actually spelled out


def test_pallas_kernel_lowers_to_plain_hlo(hier, params):
    """interpret=True Pallas must lower to ops a CPU PJRT client can run —
    no Mosaic/custom-call in the encoder artifact graph."""
    enc_order = [k for k in model_mod.param_order(params)
                 if k.startswith(("enc0_mlp", "enc1_mlp", "enc_lin"))]

    def encoder_flat(*flat):
        p = dict(zip(enc_order, flat[:-1]))
        return (model_mod.encode(p, flat[-1], hier, use_pallas=True),)

    specs = [jax.ShapeDtypeStruct(params[k].shape, params[k].dtype) for k in enc_order]
    specs.append(jax.ShapeDtypeStruct((model_mod.CHANNELS, hier.levels[0].n), jnp.float32))
    text = aot.to_hlo_text(jax.jit(encoder_flat).lower(*specs))
    assert "custom-call" not in text.lower() or "mosaic" not in text.lower()


@pytest.mark.skipif(not os.path.exists(os.path.join(ARTDIR, "manifest.json")),
                    reason="artifacts not built (run `make artifacts`)")
class TestManifest:
    @pytest.fixture(scope="class")
    def manifest(self):
        with open(os.path.join(ARTDIR, "manifest.json")) as f:
            return json.load(f)

    def test_all_artifact_files_exist(self, manifest):
        for name, art in manifest["artifacts"].items():
            path = os.path.join(ARTDIR, art["file"])
            assert os.path.exists(path), name
            assert os.path.getsize(path) == art["bytes"]

    def test_no_elided_constants_in_any_artifact(self, manifest):
        for art in manifest["artifacts"].values():
            with open(os.path.join(ARTDIR, art["file"])) as f:
                assert "constant({...})" not in f.read(), art["file"]

    def test_param_table_matches_bin(self, manifest):
        total = manifest["model"]["n_params_total"]
        path = os.path.join(ARTDIR, "params_init.bin")
        assert os.path.getsize(path) == 4 * total
        last = manifest["param_table"][-1]
        assert last["offset"] + last["len"] == total

    def test_param_table_order_and_contiguity(self, manifest):
        off = 0
        for row, name in zip(manifest["param_table"], manifest["param_order"]):
            assert row["name"] == name
            assert row["offset"] == off
            assert row["len"] == int(np.prod(row["shape"]))
            off += row["len"]

    def test_train_step_signature(self, manifest):
        art = manifest["artifacts"]["train_step"]
        npt = manifest["model"]["n_param_tensors"]
        assert len(art["inputs"]) == 3 * npt + 2
        assert len(art["outputs"]) == 3 * npt + 2
        assert art["inputs"][-1]["name"] == "batch"
        assert art["outputs"][-1]["name"] == "loss"
        assert art["outputs"][-1]["shape"] == []

    def test_params_init_matches_model_init(self, manifest):
        """The exported initial parameters are exactly init_params(seed=0)."""
        cfg = model_mod.ModelConfig(latent=manifest["model"]["latent"],
                                    batch=manifest["model"]["batch"])
        hier = mesh_mod.build_hierarchy()
        params = model_mod.init_params(cfg, hier, seed=0)
        order = model_mod.param_order(params)
        got = np.fromfile(os.path.join(ARTDIR, "params_init.bin"), dtype="<f4")
        want = np.concatenate([np.asarray(params[k]).ravel() for k in order])
        np.testing.assert_allclose(got, want, atol=0, rtol=0)

    def test_mesh_coords_roundtrip(self, manifest):
        hier = mesh_mod.build_hierarchy()
        got = np.fromfile(os.path.join(ARTDIR, "mesh_coords.bin"), dtype="<f4")
        np.testing.assert_allclose(got, hier.levels[0].coords.ravel(), atol=0)
