"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

This is the CORE correctness signal for the kernel layer.  Hypothesis sweeps
shapes and data; every property asserts allclose against the reference
semantics that the differentiable training graph uses.
"""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import quadconv as qc
from compile.kernels import ref


def _np(rng, *shape):
    return rng.normal(size=shape).astype(np.float32)


@settings(max_examples=40, deadline=None)
@given(
    j=st.integers(1, 96),
    k=st.integers(1, 16),
    co=st.integers(1, 8),
    ci=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_contract_matches_ref(j, k, co, ci, seed):
    rng = np.random.default_rng(seed)
    g = jnp.asarray(_np(rng, j, k, co, ci))
    fg = jnp.asarray(_np(rng, j, k, ci))
    wq = jnp.asarray(_np(rng, j, k))
    want = ref.quadconv_contract_ref(g, fg, wq)
    got = qc.quadconv_contract(g, fg, wq)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5)


@settings(max_examples=15, deadline=None)
@given(
    j=st.integers(1, 40),
    block=st.sampled_from([1, 2, 8, 64, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_contract_block_size_invariance(j, block, seed):
    """Result must not depend on the tile size (incl. padding path)."""
    rng = np.random.default_rng(seed)
    k, co, ci = 4, 3, 2
    g = jnp.asarray(_np(rng, j, k, co, ci))
    fg = jnp.asarray(_np(rng, j, k, ci))
    wq = jnp.asarray(_np(rng, j, k))
    want = ref.quadconv_contract_ref(g, fg, wq)
    got = qc.quadconv_contract(g, fg, wq, block_j=block)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5)


def test_contract_zero_weights_zero_output(rng):
    g = jnp.asarray(_np(rng, 8, 4, 3, 2))
    fg = jnp.asarray(_np(rng, 8, 4, 2))
    wq = jnp.zeros((8, 4), jnp.float32)
    got = qc.quadconv_contract(g, fg, wq)
    assert float(jnp.abs(got).max()) == 0.0


def test_contract_linearity(rng):
    """Contraction is linear in the features."""
    g = jnp.asarray(_np(rng, 16, 4, 3, 2))
    f1 = jnp.asarray(_np(rng, 16, 4, 2))
    f2 = jnp.asarray(_np(rng, 16, 4, 2))
    wq = jnp.asarray(_np(rng, 16, 4))
    lhs = qc.quadconv_contract(g, f1 + 2.0 * f2, wq)
    rhs = qc.quadconv_contract(g, f1, wq) + 2.0 * qc.quadconv_contract(g, f2, wq)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), atol=3e-5, rtol=3e-5)


def _mlp_params(rng, co, ci, hidden=16, layers=5):
    dims = [3] + [hidden] * (layers - 1) + [co * ci]
    p = {}
    for i in range(layers):
        p[f"w{i}"] = jnp.asarray(_np(rng, dims[i], dims[i + 1]) * 0.5)
        p[f"b{i}"] = jnp.asarray(_np(rng, dims[i + 1]) * 0.1)
    return p


@settings(max_examples=20, deadline=None)
@given(
    t=st.integers(1, 300),
    co=st.integers(1, 6),
    ci=st.integers(1, 6),
    block=st.sampled_from([32, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_mlp_filter_matches_ref(t, co, ci, block, seed):
    rng = np.random.default_rng(seed)
    p = _mlp_params(rng, co, ci)
    d = jnp.asarray(_np(rng, t, 3))
    want = ref.mlp_filter_ref(p, d, co, ci)
    got = qc.mlp_filter(p, d, co, ci, block_t=block)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5, rtol=3e-5)


def test_mlp_filter_leading_axes(rng):
    """Filter evaluation must be shape-polymorphic over leading axes."""
    p = _mlp_params(rng, 4, 3)
    d = jnp.asarray(_np(rng, 5, 7, 3))
    want = ref.mlp_filter_ref(p, d, 4, 3)
    got = qc.mlp_filter(p, d, 4, 3)
    assert got.shape == (5, 7, 4, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5, rtol=3e-5)


def test_full_quadconv_layer_on_mesh(hier, rng):
    """Gather + filter + contraction on the real mesh hierarchy."""
    l0, l1 = hier.levels[0], hier.levels[1]
    p = _mlp_params(rng, 8, 4)
    f = jnp.asarray(_np(rng, 4, l0.n))
    want = ref.quadconv_ref(
        f, p, jnp.asarray(l1.coords), jnp.asarray(l0.coords),
        jnp.asarray(l0.weights), jnp.asarray(hier.enc_idx[0]), 8,
    )
    got = qc.quadconv(
        f, p, jnp.asarray(l1.coords), jnp.asarray(l0.coords),
        jnp.asarray(l0.weights), jnp.asarray(hier.enc_idx[0]), 8,
    )
    assert got.shape == (8, l1.n)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4, rtol=1e-4)


def test_contract_bf16_loose(rng):
    """bf16 inputs run and stay within bf16-appropriate tolerance."""
    g = jnp.asarray(_np(rng, 32, 8, 4, 4)).astype(jnp.bfloat16).astype(jnp.float32)
    fg = jnp.asarray(_np(rng, 32, 8, 4)).astype(jnp.bfloat16).astype(jnp.float32)
    wq = jnp.asarray(_np(rng, 32, 8)).astype(jnp.bfloat16).astype(jnp.float32)
    want = ref.quadconv_contract_ref(g, fg, wq)
    got = qc.quadconv_contract(g, fg, wq)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4, rtol=1e-3)
