"""Mesh hierarchy invariants: quadrature exactness, neighbor-table sanity."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import mesh as mesh_mod


def test_level_sizes(hier):
    assert [l.n for l in hier.levels] == [1024, 256, 64]


def test_weights_integrate_constants_exactly(hier):
    """Trapezoid weights must integrate 1 to the exact domain volume."""
    for l in hier.levels:
        np.testing.assert_allclose(l.weights.sum(), mesh_mod.volume(), rtol=1e-5)


def test_weights_positive(hier):
    for l in hier.levels:
        assert (l.weights > 0).all()


def test_coords_inside_domain(hier):
    for l in hier.levels:
        assert (l.coords[:, 0] >= 0).all() and (l.coords[:, 0] <= mesh_mod.LX).all()
        assert (l.coords[:, 1] >= 0).all() and (l.coords[:, 1] <= mesh_mod.LY).all()
        assert (l.coords[:, 2] >= 0).all() and (l.coords[:, 2] <= mesh_mod.LZ).all()


def test_wall_normal_stretching(hier):
    """y-spacings must be monotonically increasing away from the wall
    (tanh clustering toward y=0... actually tanh(beta s)/tanh(beta) clusters
    toward the far end; verify spacing is monotone, i.e. genuinely
    non-uniform in one direction)."""
    ny = hier.levels[0].shape[1]
    ys = np.unique(hier.levels[0].coords[:, 1])
    assert len(ys) == ny
    dys = np.diff(ys)
    assert (dys > 0).all()
    # Non-uniform: the largest spacing is materially bigger than the smallest.
    assert dys.max() / dys.min() > 1.5


def test_knn_indices_valid(hier):
    for l, idx in enumerate(hier.enc_idx):
        n_in = hier.levels[l].n
        assert idx.min() >= 0 and idx.max() < n_in
        assert idx.shape == (hier.levels[l + 1].n, hier.k_enc)
    for l, idx in enumerate(hier.dec_idx):
        n_in = hier.levels[l + 1].n
        assert idx.min() >= 0 and idx.max() < n_in
        assert idx.shape == (hier.levels[l].n, hier.k_dec)


def test_knn_rows_unique(hier):
    """A neighbor must not appear twice for one output point."""
    for idx in list(hier.enc_idx) + list(hier.dec_idx):
        for row in idx:
            assert len(set(row.tolist())) == len(row)


def test_knn_first_is_nearest(hier):
    """Column 0 must hold the true nearest input point."""
    out_c = hier.levels[1].coords
    in_c = hier.levels[0].coords
    d2 = ((out_c[:, None, :] - in_c[None, :, :]) ** 2).sum(axis=2)
    np.testing.assert_array_equal(hier.enc_idx[0][:, 0], d2.argmin(axis=1))


def test_knn_sorted_by_distance(hier):
    out_c = hier.levels[1].coords
    in_c = hier.levels[0].coords
    idx = hier.enc_idx[0]
    for j in range(0, out_c.shape[0], 37):
        d = ((in_c[idx[j]] - out_c[j]) ** 2).sum(axis=1)
        assert (np.diff(d) >= -1e-12).all()


@settings(max_examples=20, deadline=None)
@given(
    n_out=st.integers(1, 30),
    n_in=st.integers(1, 60),
    seed=st.integers(0, 2**31 - 1),
)
def test_knn_property_random_clouds(n_out, n_in, seed):
    rng = np.random.default_rng(seed)
    k = min(4, n_in)
    a = rng.normal(size=(n_out, 3))
    b = rng.normal(size=(n_in, 3))
    idx = mesh_mod.knn_indices(a, b, k)
    d2 = ((a[:, None, :] - b[None, :, :]) ** 2).sum(axis=2)
    # Every selected neighbor is at least as close as every non-selected one.
    for j in range(n_out):
        sel = set(idx[j].tolist())
        dmax = d2[j, idx[j]].max()
        others = [d2[j, i] for i in range(n_in) if i not in sel]
        if others:
            assert dmax <= min(others) + 1e-12


def test_quadrature_linear_exactness(hier):
    """Tensor-trapezoid weights on these node sets integrate linears to a few
    percent (they are cell-measure weights, not interpolatory weights)."""
    l = hier.levels[0]
    f = 2.0 + 3.0 * l.coords[:, 0]
    exact = (2.0 + 3.0 * mesh_mod.LX / 2.0) * mesh_mod.volume()
    approx = (f * l.weights).sum()
    assert abs(approx - exact) / abs(exact) < 0.05
