"""L2 model correctness: shapes, pallas-vs-ref parity, training dynamics."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as model_mod


def _snapshot(rng, hier, b=None):
    """Synthetic smooth + fluctuating (p,u,v,w) field batch."""
    n = hier.levels[0].n
    c = model_mod.CHANNELS
    shape = (c, n) if b is None else (b, c, n)
    x = hier.levels[0].coords
    base = np.stack(
        [np.sin(2 * np.pi * x[:, 0] / 4.0 + i) * np.cos(np.pi * x[:, 1]) for i in range(c)]
    ).astype(np.float32)
    if b is not None:
        base = np.stack([base] * b)
    noise = 0.1 * rng.normal(size=shape).astype(np.float32)
    return jnp.asarray(base + noise)


def test_encode_decode_shapes(params, hier, cfg, rng):
    f = _snapshot(rng, hier)
    z = model_mod.encode(params, f, hier, use_pallas=False)
    assert z.shape == (cfg.latent,)
    f2 = model_mod.decode(params, z, hier, use_pallas=False)
    assert f2.shape == f.shape


def test_pallas_matches_ref_end_to_end(params, hier, rng):
    """The inference (Pallas) path must agree with the training (ref) path."""
    f = _snapshot(rng, hier)
    a = model_mod.autoencode(params, f, hier, use_pallas=False)
    b = model_mod.autoencode(params, f, hier, use_pallas=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4, rtol=1e-4)


def test_relative_error_zero_for_identity(params, hier, rng):
    f = _snapshot(rng, hier, b=2)
    num = jnp.sqrt(jnp.sum((f - f) ** 2, axis=(1, 2)))
    den = jnp.sqrt(jnp.sum(f ** 2, axis=(1, 2)))
    assert float(jnp.mean(num / den)) == 0.0


def test_relative_error_range(params, hier, rng):
    f = _snapshot(rng, hier, b=2)
    err = model_mod.relative_error(params, f, hier)
    assert 0.0 < float(err) < 10.0


def test_train_step_decreases_loss(params, hier, cfg, rng):
    """A few Adam steps on a fixed batch must reduce the MSE."""
    batch = _snapshot(rng, hier, b=cfg.batch)
    p = params
    m = {k: jnp.zeros_like(v) for k, v in p.items()}
    v = {k: jnp.zeros_like(x) for k, x in p.items()}
    step = jnp.int32(0)
    ts = jax.jit(lambda p, m, v, s, b: model_mod.train_step(p, m, v, s, b, hier, lr=3e-3))
    losses = []
    for _ in range(30):
        p, m, v, step, loss = ts(p, m, v, step, batch)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.95, losses
    # The tail of the trajectory should be consistently below the head.
    assert max(losses[-5:]) < min(losses[:3]), losses
    assert int(step) == 30


def test_grad_plus_apply_matches_train_step(params, hier, cfg, rng):
    """The DDP decomposition (grad_step + apply_adam) must equal the fused
    train_step after one step."""
    batch = _snapshot(rng, hier, b=cfg.batch)
    m = {k: jnp.zeros_like(v) for k, v in params.items()}
    v = {k: jnp.zeros_like(x) for k, x in params.items()}
    step = jnp.int32(0)
    p1, m1, v1, s1, loss1 = model_mod.train_step(params, m, v, step, batch, hier)
    loss2, grads = model_mod.grad_flat(params, batch, hier)
    p2, m2, v2, s2 = model_mod.apply_adam(params, m, v, step, grads)
    np.testing.assert_allclose(float(loss1), float(loss2), rtol=1e-6)
    for k in params:
        np.testing.assert_allclose(
            np.asarray(p1[k]), np.asarray(p2[k]), atol=1e-6, rtol=1e-6
        )


def test_adam_bias_correction_first_step(params, hier, cfg, rng):
    """After one step from zero moments, update direction == -lr * sign-ish:
    |Δp| <= lr * (1 + eps slack) elementwise (Adam's step-size bound)."""
    batch = _snapshot(rng, hier, b=cfg.batch)
    m = {k: jnp.zeros_like(v) for k, v in params.items()}
    v = {k: jnp.zeros_like(x) for k, x in params.items()}
    p1, _, _, _, _ = model_mod.train_step(params, m, v, jnp.int32(0), batch, hier,
                                          lr=model_mod.LEARNING_RATE)
    for k in params:
        dp = np.abs(np.asarray(p1[k] - params[k]))
        assert dp.max() <= model_mod.LEARNING_RATE * 1.01


def test_param_order_stable(params):
    order = model_mod.param_order(params)
    assert order == sorted(order)
    assert len(order) == len(params)


def test_resnet_lite_shapes():
    p = model_mod.init_resnet_params()
    for b in (1, 2):
        x = jnp.zeros((b, 3, model_mod.RESNET_HW, model_mod.RESNET_HW), jnp.float32)
        y = model_mod.resnet_lite(p, x)
        assert y.shape == (b, model_mod.RESNET_CLASSES)


def test_resnet_lite_batch_consistency(rng):
    """Per-sample results must be independent of batching."""
    p = model_mod.init_resnet_params()
    x = jnp.asarray(rng.normal(size=(4, 3, 64, 64)).astype(np.float32))
    full = model_mod.resnet_lite(p, x)
    single = jnp.concatenate([model_mod.resnet_lite(p, x[i : i + 1]) for i in range(4)])
    np.testing.assert_allclose(np.asarray(full), np.asarray(single), atol=2e-4, rtol=2e-4)
