//! Fig 10 — convergence of the training loss, validation loss and
//! validation relative reconstruction error (Eq. 1) during in-situ training
//! of the QuadConv autoencoder.
//!
//! Paper shape: train and validation losses decrease smoothly by ~2 orders
//! of magnitude over 500 epochs; the validation error decreases by ~1 order
//! to ~10%.  This bench runs a shortened schedule and checks monotone-ish
//! decrease; the full run is examples/insitu_training.rs (EXPERIMENTS.md).

use situ::orchestrator::driver::{run_insitu_training, InSituTrainingConfig};
use situ::telemetry::Table;

fn main() {
    let artifacts = situ::db::server::artifacts_dir();
    if !artifacts.join("manifest.json").exists() {
        println!("fig10 SKIPPED: artifacts not built");
        return;
    }
    let cfg = InSituTrainingConfig {
        artifacts_dir: artifacts,
        grid: (20, 14, 10),
        nu: 2e-3,
        sim_ranks: 4,
        ml_ranks: 1, // fused train_step fast path
        epochs: 50,
        snapshot_every: 2,
        solver_steps: 50,
        seed: 0,
        ..Default::default()
    };
    let report = run_insitu_training(&cfg).expect("in situ run");

    let mut t = Table::new(
        "Fig 10: convergence during in situ training (shortened schedule)",
        &["epoch", "train_loss", "val_loss", "val_rel_err"],
    );
    for log in report.history.iter().step_by(5) {
        t.row(&[
            log.epoch.to_string(),
            format!("{:.6}", log.train_loss),
            format!("{:.6}", log.val_loss),
            format!("{:.4}", log.val_rel_err),
        ]);
    }
    t.print();

    let first = &report.history[0];
    let last = report.history.last().unwrap();
    println!(
        "train loss: {:.4} -> {:.4} ({:.1}x); val err: {:.1}% -> {:.1}%",
        first.train_loss,
        last.train_loss,
        first.train_loss / last.train_loss,
        first.val_rel_err * 100.0,
        last.val_rel_err * 100.0
    );
    assert!(last.train_loss < first.train_loss, "training must converge");
    assert!(last.val_loss.is_finite() && last.val_rel_err.is_finite());
    println!("fig10 OK (full 2-orders-of-magnitude run: examples/insitu_training.rs)");
}
