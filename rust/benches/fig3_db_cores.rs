//! Fig 3 — cost of data send/retrieve vs CPU cores assigned to the
//! co-located database, Redis vs KeyDB (24 ranks × 256KB × 40 iterations).
//!
//! Paper shape: both engines flat for ≥8 cores with similar plateaus; KeyDB
//! already performant at 4 cores; Redis degraded below 8.

use situ::cluster::netmodel::CostModel;
use situ::cluster::scaling::sim_data_transfer;
use situ::config::RunConfig;
use situ::db::Engine;
use situ::telemetry::Table;
use situ::util::fmt;

fn main() {
    let model = CostModel::default();
    let mut table = Table::new(
        "Fig 3: send + retrieve cost vs DB cores (co-located, 24 ranks x 256KB x 40 iters)",
        &["db cores", "redis send", "redis retrieve", "keydb send", "keydb retrieve"],
    );
    let mut plateau = std::collections::BTreeMap::new();
    for cores in [2usize, 4, 8, 16, 32] {
        let mut row = vec![cores.to_string()];
        for engine in [Engine::Redis, Engine::KeyDb] {
            let mut cfg = RunConfig::default();
            cfg.db_cores = cores;
            cfg.engine = engine;
            let st = sim_data_transfer(&cfg, &model, 42);
            row.push(fmt::duration(st.send.mean()));
            row.push(fmt::duration(st.retrieve.mean()));
            plateau.insert((engine.name(), cores), st.send.mean() + st.retrieve.mean());
        }
        table.row(&row);
    }
    table.print();

    // Shape assertions (the paper's qualitative claims).
    let r = |c: usize| plateau[&("redis", c)];
    let k = |c: usize| plateau[&("keydb", c)];
    println!("shape checks:");
    println!(
        "  redis flat >=8 cores: 8c/16c ratio = {:.3} (paper: ~1.0)",
        r(8) / r(16)
    );
    println!(
        "  redis degraded at 4 cores: 4c/8c ratio = {:.2} (paper: >1)",
        r(4) / r(8)
    );
    println!(
        "  keydb performant at 4 cores: keydb4/redis8 = {:.3} (paper: ~1.0)",
        k(4) / r(8)
    );
    assert!((r(8) / r(16) - 1.0).abs() < 0.05);
    assert!(r(4) / r(8) > 1.5);
    assert!((k(4) / r(8) - 1.0).abs() < 0.1);
    println!("fig3 OK");
}
