//! Fig 4 — send/retrieve time and throughput vs per-rank data size, for
//! both deployments and both engines (24 ranks, 40 iterations).
//!
//! Paper shape: (i) send ≈ retrieve, redis ≈ keydb; (ii) co-located ≈
//! clustered at this scale (network not a bottleneck on Slingshot);
//! (iii) cost ~constant below 256KB (fixed request cost) and ~linear above
//! (constant throughput, most efficient 256KB–16MB).
//!
//! The DES sweep is additionally grounded by REAL TCP-server measurements
//! on this host for the sizes that fit a single machine.

use situ::cluster::netmodel::CostModel;
use situ::cluster::scaling::sim_data_transfer;
use situ::config::{Deployment, RunConfig};
use situ::db::{DbServer, Engine, ServerConfig};
use situ::sim::reproducer::{run_data_loop, ReproducerConfig};
use situ::telemetry::Table;
use situ::util::fmt;

fn main() {
    let model = CostModel::default();
    let sizes: Vec<usize> = (0..=14).map(|p| 1024usize << p).collect(); // 1KB..16MB

    let mut time_t = Table::new(
        "Fig 4a: transfer time vs size/rank (24 ranks, 40 iters)",
        &["size/rank", "coloc redis send", "coloc keydb send", "clustered redis send", "coloc redis retr"],
    );
    let mut thr_t = Table::new(
        "Fig 4b: throughput vs size/rank",
        &["size/rank", "co-located redis", "clustered redis"],
    );
    for &bytes in &sizes {
        let mut cfg = RunConfig::default();
        cfg.bytes_per_rank = bytes;
        let coloc_redis = sim_data_transfer(&cfg, &model, 1);
        cfg.engine = Engine::KeyDb;
        let coloc_keydb = sim_data_transfer(&cfg, &model, 1);
        cfg.engine = Engine::Redis;
        cfg.deployment = Deployment::Clustered { db_nodes: 1 };
        let clustered = sim_data_transfer(&cfg, &model, 1);
        time_t.row(&[
            fmt::bytes(bytes as u64),
            fmt::duration(coloc_redis.send.mean()),
            fmt::duration(coloc_keydb.send.mean()),
            fmt::duration(clustered.send.mean()),
            fmt::duration(coloc_redis.retrieve.mean()),
        ]);
        thr_t.row(&[
            fmt::bytes(bytes as u64),
            fmt::throughput(coloc_redis.throughput_per_rank(bytes)),
            fmt::throughput(clustered.throughput_per_rank(bytes)),
        ]);
    }
    time_t.print();
    thr_t.print();

    // --- real-host grounding (single node, scaled-down rank count) --------
    let server = DbServer::start(ServerConfig { with_models: false, ..Default::default() })
        .expect("server");
    let mut real_t = Table::new(
        "Fig 4 (real TCP server on this host, 4 ranks x 10 iters)",
        &["size/rank", "send", "retrieve", "throughput"],
    );
    // The upper sizes (16–64 MiB) are where the zero-copy data plane shows:
    // payloads move socket→store→socket with one allocation per direction.
    for bytes in [1024usize, 16 * 1024, 256 * 1024, 4 << 20, 16 << 20, 64 << 20] {
        let times = run_data_loop(&ReproducerConfig {
            addr: server.addr,
            ranks: 4,
            bytes_per_rank: bytes,
            iterations: 10,
            warmup: 2,
            compute_secs: 0.0,
            retry: situ::client::RetryPolicy::Fail,
        })
        .expect("reproducer");
        let snap = times.snapshot();
        let send = snap["send"].mean();
        let retr = snap["retrieve"].mean();
        real_t.row(&[
            fmt::bytes(bytes as u64),
            fmt::duration(send),
            fmt::duration(retr),
            fmt::throughput(2.0 * bytes as f64 / (send + retr)),
        ]);
    }
    real_t.print();
    println!("fig4 OK");
}
