//! Fig 5 — weak scaling of send/retrieve to the full machine (448 nodes,
//! 10 752 ranks; 256KB/rank, 24 ranks/node).
//!
//! Paper shape: (5a) co-located = horizontal lines for both ops and both
//! engines (the headline "perfect scaling efficiency"); (5b) clustered with
//! a fixed DB degrades ~linearly beyond a threshold, restored by sharding
//! the DB proportionally.

use situ::cluster::netmodel::CostModel;
use situ::cluster::scaling::sim_data_transfer;
use situ::config::{Deployment, RunConfig};
use situ::db::Engine;
use situ::telemetry::Table;
use situ::util::fmt;

fn main() {
    let model = CostModel::default();
    let node_counts = [1usize, 2, 4, 8, 16, 48, 112, 224, 448];

    // --- 5a: co-located ----------------------------------------------------
    let mut t = Table::new(
        "Fig 5a: weak scaling, co-located DB (256KB/rank)",
        &["nodes", "ranks", "redis send", "redis retr", "keydb send", "keydb retr"],
    );
    let mut base = None;
    let mut worst_ratio: f64 = 1.0;
    for &nodes in &node_counts {
        let mut row = vec![nodes.to_string(), (nodes * 24).to_string()];
        for engine in [Engine::Redis, Engine::KeyDb] {
            let mut cfg = RunConfig::default();
            cfg.nodes = nodes;
            cfg.engine = engine;
            let st = sim_data_transfer(&cfg, &model, 42);
            if engine == Engine::Redis {
                let total = st.send.mean() + st.retrieve.mean();
                let b = *base.get_or_insert(total);
                worst_ratio = worst_ratio.max(total / b).max(b / total);
            }
            row.push(fmt::duration(st.send.mean()));
            row.push(fmt::duration(st.retrieve.mean()));
        }
        t.row(&row);
    }
    t.print();
    println!(
        "co-located scaling efficiency: worst deviation from flat = {:.2}% (paper: perfect)",
        (worst_ratio - 1.0) * 100.0
    );
    assert!(worst_ratio < 1.05, "co-located weak scaling must be flat");

    // --- 5b: clustered -------------------------------------------------------
    let mut t = Table::new(
        "Fig 5b: weak scaling, clustered DB (redis, send; columns = DB nodes)",
        &["sim nodes", "ranks", "1 DB", "4 DB", "16 DB"],
    );
    let mut fixed_small = 0.0;
    let mut fixed_big = 0.0;
    let mut prop = Vec::new();
    for &nodes in &[1usize, 4, 16, 64] {
        let mut row = vec![nodes.to_string(), (nodes * 24).to_string()];
        for db_nodes in [1usize, 4, 16] {
            let mut cfg = RunConfig::default();
            cfg.nodes = nodes;
            cfg.deployment = Deployment::Clustered { db_nodes };
            let st = sim_data_transfer(&cfg, &model, 42);
            let v = st.send.mean();
            row.push(fmt::duration(v));
            if db_nodes == 1 && nodes == 1 {
                fixed_small = v;
            }
            if db_nodes == 1 && nodes == 64 {
                fixed_big = v;
            }
            if db_nodes == nodes {
                prop.push(v);
            }
        }
        t.row(&row);
    }
    t.print();
    println!(
        "fixed 1-node DB degradation at 64 nodes: {:.1}x (paper: ~linear in ranks)",
        fixed_big / fixed_small
    );
    let prop_dev = prop.iter().cloned().fold(0.0f64, f64::max)
        / prop.iter().cloned().fold(f64::INFINITY, f64::min);
    println!(
        "proportional sharding (1:1 DB:sim nodes) deviation from flat: {:.2}%",
        (prop_dev - 1.0) * 100.0
    );
    assert!(fixed_big / fixed_small > 10.0, "fixed DB must bottleneck");
    assert!(prop_dev < 1.15, "proportional sharding restores scaling");
    println!("fig5 OK");
}
