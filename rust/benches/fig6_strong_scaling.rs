//! Fig 6 — strong scaling of send/retrieve with the co-located deployment:
//! total payload fixed at 384MB (a 230³ grid's pressure+velocity fields),
//! per-rank size shrinking as the run scales out.
//!
//! Paper shape: times fall linearly with rank count while the per-rank size
//! stays ≥256KB, then flatten toward the fixed-request-cost floor.

use situ::cluster::netmodel::CostModel;
use situ::cluster::scaling::sim_data_transfer;
use situ::config::RunConfig;
use situ::telemetry::Table;
use situ::util::fmt;

fn main() {
    let model = CostModel::default();
    let total = 384usize << 20;
    let mut t = Table::new(
        "Fig 6: strong scaling, co-located redis (384MB total)",
        &["nodes", "ranks", "bytes/rank", "send", "retrieve", "ideal send"],
    );
    let node_counts = [1usize, 2, 4, 8, 16, 48, 112, 224, 448];
    let mut first = None;
    let mut series = Vec::new();
    for &nodes in &node_counts {
        let mut cfg = RunConfig::default();
        cfg.nodes = nodes;
        cfg.bytes_per_rank = (total / cfg.total_ranks()).max(256);
        let st = sim_data_transfer(&cfg, &model, 9);
        let send = st.send.mean();
        let (n0, s0) = *first.get_or_insert((nodes, send));
        let ideal = s0 * n0 as f64 / nodes as f64;
        series.push((nodes, cfg.bytes_per_rank, send, ideal));
        t.row(&[
            nodes.to_string(),
            cfg.total_ranks().to_string(),
            fmt::bytes(cfg.bytes_per_rank as u64),
            fmt::duration(send),
            fmt::duration(st.retrieve.mean()),
            fmt::duration(ideal),
        ]);
    }
    t.print();

    // Shape checks: near-ideal while bytes/rank is clearly above the 256KB
    // knee; the knee itself is soft (fixed cost ~ byte cost there); floor
    // below.
    for &(nodes, bytes, send, ideal) in &series {
        let eff = ideal / send;
        if bytes >= 1024 * 1024 {
            println!("  nodes={nodes}: efficiency {:.2} (>=1MB regime)", eff);
            assert!(eff > 0.75, "strong scaling efficiency at {nodes} nodes: {eff}");
        } else if bytes >= 256 * 1024 {
            println!("  nodes={nodes}: efficiency {:.2} (knee region)", eff);
            assert!(eff > 0.4, "knee efficiency at {nodes} nodes: {eff}");
        }
    }
    let last = series.last().unwrap();
    assert!(
        last.2 > last.3,
        "sub-256KB regime must sit above the ideal line (fixed-cost floor)"
    );
    println!("fig6 OK");
}
