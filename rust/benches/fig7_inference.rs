//! Fig 7 — single-node inference cost split (send / model evaluation /
//! retrieve) vs batch size, compared against the tightly-coupled (in line)
//! baseline — the paper's LibTorch bridge, here a direct in-process PJRT
//! call.
//!
//! Everything in this bench is REAL execution on this host: the TCP
//! database with the RedisAI-analogue registry, and the PJRT runtime
//! underneath both paths.
//!
//! Paper shape: send + eval dominate; transfer grows linearly with batch
//! while eval grows sub-linearly; the in-line baseline wins by ~2x at batch
//! 1 and more at larger batches (the framework trades performance for
//! integration simplicity — <10 LoC vs >70 LoC).

use situ::db::{DbServer, ServerConfig};
use situ::runtime::Executor;
use situ::sim::reproducer::{run_inference_loop, run_inline_baseline, InferenceConfig};
use situ::telemetry::Table;
use situ::util::fmt;

fn main() {
    let artifacts = situ::db::server::artifacts_dir();
    if !artifacts.join("manifest.json").exists() {
        println!("fig7 SKIPPED: artifacts not built (run `make artifacts`)");
        return;
    }
    let server = DbServer::start(ServerConfig::default()).expect("server");
    use situ::client::DataStore;
    let mut c = situ::client::Client::connect(server.addr).expect("client");
    let exec = Executor::new().expect("executor");

    let mut table = Table::new(
        "Fig 7: inference cost split vs batch (framework) and in-line baseline",
        &["batch", "send", "eval", "retrieve", "total", "in-line", "speedup", "send share"],
    );
    let ranks = 2; // scaled: the paper uses 24 ranks on a 32-core node
    for batch in [1usize, 4, 16] {
        let model_key = format!("resnet_lite_b{batch}");
        let path = artifacts.join(format!("{model_key}.hlo.txt"));
        c.put_model_from_file(&model_key, &path).expect("put_model");
        exec.load_artifact(&model_key, &path).expect("load");

        let times = run_inference_loop(&InferenceConfig {
            addr: server.addr,
            ranks,
            model_key: model_key.clone(),
            in_shape: vec![batch, 3, 64, 64],
            iterations: 8,
            warmup: 2,
        })
        .expect("inference loop");
        let snap = times.snapshot();
        let (send, eval, retr, total) = (
            snap["send"].mean(),
            snap["eval"].mean(),
            snap["retrieve"].mean(),
            snap["total"].mean(),
        );
        let inline = run_inline_baseline(&exec, &model_key, &[batch, 3, 64, 64], 8, 2)
            .expect("baseline")
            .mean();
        table.row(&[
            batch.to_string(),
            fmt::duration(send),
            fmt::duration(eval),
            fmt::duration(retr),
            fmt::duration(total),
            fmt::duration(inline),
            format!("{:.1}x", total / inline),
            format!("{:.0}%", 100.0 * send / total),
        ]);
    }
    table.print();
    println!(
        "paper: speedup 2x at batch 1 rising to ~4.6x; send share grows with batch\n\
         integration cost: framework <10 LoC (see examples/quickstart.rs) vs\n\
         in-line bridge >70 LoC (the paper's Fortran/C++/LibTorch shim)"
    );
    println!("fig7 OK");
}
