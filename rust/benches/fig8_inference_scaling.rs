//! Fig 8 — weak and strong scaling of in-situ inference (co-located Redis,
//! ResNet-lite, 4 GPU slots per node, 6 ranks pinned per GPU).
//!
//! The GPU service times come from REAL PJRT executions measured on this
//! host at each batch size; the cluster is the calibrated DES.
//!
//! Paper shape: weak scaling perfectly flat for both model-evaluation and
//! total cost; strong scaling: eval degrades at small batch but the faster
//! transfers amortize it — total cost still scales perfectly.

use std::collections::BTreeMap;

use situ::cluster::netmodel::CostModel;
use situ::cluster::scaling::sim_inference;
use situ::config::RunConfig;
use situ::runtime::Executor;
use situ::sim::reproducer::run_inline_baseline;
use situ::telemetry::Table;
use situ::util::fmt;

fn main() {
    let artifacts = situ::db::server::artifacts_dir();
    // Measure real eval times per batch (falls back to a linear model if
    // artifacts are missing).
    let mut eval_times: BTreeMap<usize, f64> = BTreeMap::new();
    if artifacts.join("manifest.json").exists() {
        let exec = Executor::new().expect("executor");
        for b in [1usize, 4, 16] {
            let name = format!("resnet_lite_b{b}");
            exec.load_artifact(&name, &artifacts.join(format!("{name}.hlo.txt"))).expect("load");
            let t = run_inline_baseline(&exec, &name, &[b, 3, 64, 64], 6, 2).expect("bench").mean();
            eval_times.insert(b, t);
            println!("measured eval time batch {b}: {}", fmt::duration(t));
        }
    } else {
        println!("(artifacts missing; using analytic eval model)");
        for b in [1usize, 4, 16] {
            eval_times.insert(b, 1.5e-3 + 0.8e-3 * b as f64);
        }
    }
    let eval = |b: usize| -> f64 {
        // Piecewise-linear interpolation over measured points (sub-linear in
        // batch, exactly the paper's observation).
        if let Some(t) = eval_times.get(&b) {
            return *t;
        }
        let (b0, t0) = eval_times.range(..b).next_back().map(|(k, v)| (*k, *v)).unwrap_or((1, eval_times[&1]));
        let (b1, t1) = eval_times.range(b..).next().map(|(k, v)| (*k, *v)).unwrap_or((16, eval_times[&16]));
        if b1 == b0 {
            t0
        } else {
            t0 + (t1 - t0) * (b - b0) as f64 / (b1 - b0) as f64
        }
    };

    let model = CostModel::default();
    let nodes_list = [1usize, 4, 16, 64, 192, 448];

    // --- weak scaling: batch fixed at 4 ---------------------------------
    let mut t = Table::new(
        "Fig 8 (weak): batch 4 per rank, co-located redis",
        &["nodes", "ranks", "eval", "total"],
    );
    let mut base_total = None;
    let mut worst: f64 = 1.0;
    for &nodes in &nodes_list {
        let mut cfg = RunConfig::default();
        cfg.nodes = nodes;
        let batch = 4usize;
        let st = sim_inference(
            &cfg,
            &model,
            batch,
            batch * 3 * 64 * 64 * 4,
            batch * 1000 * 4,
            &eval,
            3,
        );
        let total = st.total.mean();
        let b = *base_total.get_or_insert(total);
        worst = worst.max(total / b).max(b / total);
        t.row(&[
            nodes.to_string(),
            cfg.total_ranks().to_string(),
            fmt::duration(st.eval.mean()),
            fmt::duration(total),
        ]);
    }
    t.print();
    println!("weak-scaling deviation from flat: {:.2}% (paper: perfect)", (worst - 1.0) * 100.0);
    assert!(worst < 1.05);

    // --- strong scaling: total batch fixed, per-rank batch shrinks -------
    let mut t = Table::new(
        "Fig 8 (strong): total batch 16*24 fixed, per-rank batch = 16/nodes",
        &["nodes", "ranks", "batch/rank", "eval", "total", "ideal total"],
    );
    let mut first = None;
    for &nodes in &[1usize, 2, 4, 8, 16] {
        let mut cfg = RunConfig::default();
        cfg.nodes = nodes;
        let batch = (16 / nodes).max(1);
        let st = sim_inference(
            &cfg,
            &model,
            batch,
            batch * 3 * 64 * 64 * 4,
            batch * 1000 * 4,
            &eval,
            3,
        );
        let total = st.total.mean();
        let (n0, t0) = *first.get_or_insert((nodes, total));
        let ideal = t0 * n0 as f64 / nodes as f64 * 1.0_f64.max(batch as f64 * nodes as f64 / 16.0);
        t.row(&[
            nodes.to_string(),
            cfg.total_ranks().to_string(),
            batch.to_string(),
            fmt::duration(st.eval.mean()),
            fmt::duration(total),
            fmt::duration(ideal),
        ]);
    }
    t.print();
    println!(
        "paper: eval departs from ideal at small batch; total stays near-linear\n\
         because the shrinking transfers amortize the eval degradation"
    );
    println!("fig8 OK");
}
