//! fig_backpressure — the sharded retention index and the adaptive
//! backpressure pipeline under load.
//!
//! Two experiments:
//!
//! 1. **Governed put scaling** — N producer threads, each publishing its
//!    own field against one governed store (window + byte cap armed).
//!    Under the old global retention-index mutex every governed put
//!    serialized; with the field-sharded index aggregate throughput scales
//!    with producer count.  Reported as ops/s per producer count, plus a
//!    same-field baseline (per-field serialization is expected — that's
//!    the generation-boundary discipline, not a regression).
//! 2. **Stalled-consumer survival** — a producer publishes over TCP under
//!    a byte cap whose budget a stalled field has pinned.  With the
//!    governor the run completes via snapshot skipping (recorded), the
//!    cap holds, and once the stall clears the publish rate recovers.
//!
//! `SITU_BENCH_SMOKE=1` shortens the run for CI; `SITU_BENCH_JSON=path`
//! records the numbers (the BENCH_PR4.json acceptance record).

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use situ::client::{tensor_key, Client, DataStore, GovernorConfig, PublishGovernor, RetryPolicy};
use situ::db::{DbServer, Engine, RetentionConfig, ServerConfig, Store};
use situ::telemetry::Table;
use situ::tensor::Tensor;

fn t_const(v: f32, n: usize) -> Tensor {
    Tensor::from_f32(&[n], vec![v; n]).unwrap()
}

struct ScalePoint {
    producers: usize,
    distinct_fields: bool,
    total_puts: u64,
    secs: f64,
    ops_per_sec: f64,
}

/// N threads × `steps` governed puts; distinct fields or one shared field.
fn governed_put_scaling(
    producers: usize,
    steps: u64,
    elems: usize,
    window: u64,
    distinct_fields: bool,
) -> ScalePoint {
    let payload = (elems * 4) as u64;
    let store = Arc::new(Store::new());
    // Cap sized so the run is governed (cap armed, reservation path taken)
    // but never starves: steady-state residency is `window` generations ×
    // one member per producer (whether those members are spread over
    // `producers` fields or stacked in one), plus slack for in-flight
    // generation boundaries.
    store.set_retention(RetentionConfig::windowed(
        window,
        (window + 4) * producers as u64 * payload,
    ));
    let start = Instant::now();
    let mut handles = Vec::new();
    for p in 0..producers {
        let store = Arc::clone(&store);
        let field = if distinct_fields { format!("bp{p}") } else { "bp".to_string() };
        handles.push(std::thread::spawn(move || {
            for step in 0..steps {
                let key = tensor_key(&field, p, step);
                store.put_tensor(&key, t_const(step as f32, elems)).expect("governed put");
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let secs = start.elapsed().as_secs_f64();
    let total_puts = producers as u64 * steps;
    ScalePoint {
        producers,
        distinct_fields,
        total_puts,
        secs,
        ops_per_sec: total_puts as f64 / secs.max(1e-9),
    }
}

struct SurvivalResult {
    opportunities: u64,
    published: u64,
    skipped: u64,
    dropped: u64,
    busy_retries: u64,
    busy_rejections: u64,
    peak_bytes: u64,
    cap: u64,
}

/// Stalled-consumer survival over TCP: a hog field pins the byte budget
/// inside its protected window for the first half of the run.
fn stalled_consumer_survival(opportunities: u64, elems: usize) -> SurvivalResult {
    let payload = (elems * 4) as u64;
    let cap = 2 * payload;
    let server = DbServer::start(ServerConfig {
        engine: Engine::KeyDb,
        with_models: false,
        retention: RetentionConfig::windowed(2, cap),
        conn_read_timeout: Duration::from_millis(50),
        ..Default::default()
    })
    .expect("server");
    let mut c = Client::connect(server.addr).expect("client");
    c.put_tensor(&tensor_key("hog", 0, 0), &t_const(0.0, elems)).unwrap();
    c.put_tensor(&tensor_key("hog", 0, 1), &t_const(1.0, elems)).unwrap();

    let mut gov = PublishGovernor::new(GovernorConfig {
        retry: RetryPolicy::Backoff {
            initial: Duration::from_micros(200),
            cap: Duration::from_millis(2),
            retries: 2,
        },
        max_stride: 8,
    });
    let mut published = 0u64;
    let mut peak_bytes = 0u64;
    for opp in 0..opportunities {
        if opp == opportunities / 2 {
            // The consumer drains the stalled window mid-run.
            c.del_keys(&[tensor_key("hog", 0, 0), tensor_key("hog", 0, 1)]).unwrap();
        }
        if !gov.should_publish() {
            continue;
        }
        let placed = gov
            .publish(|| c.put_tensor(&tensor_key("live", 0, published), &t_const(2.0, elems)))
            .expect("governed publish survives Busy");
        if placed.is_some() {
            published += 1;
        }
        peak_bytes = peak_bytes.max(server.store().n_bytes());
    }
    let stats = gov.stats();
    let busy_rejections = server.store().counters.busy_rejections.load(Ordering::Relaxed);
    SurvivalResult {
        opportunities,
        published,
        skipped: stats.skipped,
        dropped: stats.dropped,
        busy_retries: stats.busy_retries,
        busy_rejections,
        peak_bytes,
        cap,
    }
}

fn main() {
    let smoke = std::env::var("SITU_BENCH_SMOKE").is_ok();
    let steps: u64 = std::env::var("SITU_BENCH_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if smoke { 200 } else { 5000 });
    let elems = 4 * 1024usize; // 16 KiB per tensor
    let window = 4u64;

    // --- experiment 1: governed put throughput vs producer count ----------
    let mut table = Table::new(
        "governed multi-producer put throughput (field-sharded retention index)",
        &["producers", "fields", "puts", "secs", "ops/s"],
    );
    let mut points: Vec<ScalePoint> = Vec::new();
    for producers in [1usize, 2, 4, 8] {
        let p = governed_put_scaling(producers, steps, elems, window, true);
        table.row(&[
            p.producers.to_string(),
            "distinct".into(),
            p.total_puts.to_string(),
            format!("{:.3}", p.secs),
            format!("{:.0}", p.ops_per_sec),
        ]);
        points.push(p);
    }
    // Same-field baseline: all producers publish one field (per-field
    // serialization on generation boundaries is the intended discipline).
    let shared = governed_put_scaling(8, steps, elems, window, false);
    table.row(&[
        shared.producers.to_string(),
        "shared".into(),
        shared.total_puts.to_string(),
        format!("{:.3}", shared.secs),
        format!("{:.0}", shared.ops_per_sec),
    ]);
    table.print();

    // Structural assertions (CI smoke): every point completed all its puts
    // under governance with exact steady state.
    for p in &points {
        assert_eq!(p.total_puts, p.producers as u64 * steps);
    }

    // --- experiment 2: stalled-consumer survival ---------------------------
    let survival = stalled_consumer_survival(if smoke { 40 } else { 200 }, elems);
    let mut st = Table::new(
        "stalled-consumer survival (adaptive publish governor)",
        &["opportunities", "published", "skipped", "dropped", "busy retries", "peak bytes"],
    );
    st.row(&[
        survival.opportunities.to_string(),
        survival.published.to_string(),
        survival.skipped.to_string(),
        survival.dropped.to_string(),
        survival.busy_retries.to_string(),
        format!("{} (cap {})", survival.peak_bytes, survival.cap),
    ]);
    st.print();
    assert!(survival.published > 0, "run recovered after the stall");
    assert!(survival.dropped > 0, "pressure phase exercised drops");
    assert!(survival.skipped > 0, "adaptive stride engaged");
    assert!(survival.peak_bytes <= survival.cap, "byte cap held throughout");

    if let Ok(path) = std::env::var("SITU_BENCH_JSON") {
        let mut s = String::from("{\n  \"bench\": \"fig_backpressure\",\n");
        s.push_str(&format!(
            "  \"config\": {{\"steps\": {steps}, \"payload_bytes\": {}, \"window\": {window}}},\n",
            elems * 4
        ));
        s.push_str("  \"governed_put_scaling\": [\n");
        for (i, p) in points.iter().chain(std::iter::once(&shared)).enumerate() {
            s.push_str(&format!(
                "    {{\"producers\": {}, \"distinct_fields\": {}, \"total_puts\": {}, \
                 \"secs\": {:.6}, \"ops_per_sec\": {:.1}}}{}\n",
                p.producers,
                p.distinct_fields,
                p.total_puts,
                p.secs,
                p.ops_per_sec,
                if i == points.len() { "" } else { "," }
            ));
        }
        s.push_str("  ],\n");
        s.push_str(&format!(
            "  \"stalled_consumer\": {{\"opportunities\": {}, \"published\": {}, \
             \"skipped\": {}, \"dropped\": {}, \"busy_retries\": {}, \
             \"busy_rejections\": {}, \"peak_bytes\": {}, \"cap\": {}}}\n",
            survival.opportunities,
            survival.published,
            survival.skipped,
            survival.dropped,
            survival.busy_retries,
            survival.busy_rejections,
            survival.peak_bytes,
            survival.cap
        ));
        s.push_str("}\n");
        std::fs::write(&path, &s).expect("write SITU_BENCH_JSON");
        println!("bench results written to {path}");
    }
}
