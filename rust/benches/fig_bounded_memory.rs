//! fig_bounded_memory — steady-state store footprint under memory
//! governance.
//!
//! An appending producer publishes step generations over TCP against three
//! store configurations:
//!
//! * `append_unbounded` — the seed behavior: resident bytes grow linearly
//!   with step count (the OOM trajectory on long runs);
//! * `append_windowed`  — sliding-window retention + byte cap: bytes
//!   plateau at `window` generations and stay flat;
//! * `overwrite`        — the paper's stable-key republish: flat at one
//!   generation by construction.
//!
//! Prints a per-mode summary and, with `SITU_BENCH_JSON=path`, records the
//! bytes-vs-step series and eviction counters (the BENCH_PR3.json
//! acceptance numbers).  `SITU_BENCH_SMOKE=1` shortens the run for CI;
//! `SITU_BENCH_STEPS=N` overrides the step count.

use situ::client::{stable_key, tensor_key, Client, DataStore};
use situ::db::{DbServer, Engine, RetentionConfig, ServerConfig};
use situ::telemetry::Table;
use situ::tensor::Tensor;

struct ModeResult {
    name: &'static str,
    steps: u64,
    final_bytes: u64,
    peak_bytes: u64,
    high_water: u64,
    evicted_keys: u64,
    flat_after_warmup: bool,
    series: Vec<u64>,
}

fn main() {
    let smoke = std::env::var("SITU_BENCH_SMOKE").is_ok();
    let steps: u64 = std::env::var("SITU_BENCH_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if smoke { 30 } else { 250 });
    let ranks = 4usize;
    let elems = 16 * 1024usize; // 64 KiB per tensor
    let payload = (elems * 4) as u64;
    let window = 4u64;
    let cap = (window + 2) * ranks as u64 * payload;

    let modes: Vec<(&'static str, RetentionConfig, bool)> = vec![
        ("append_unbounded", RetentionConfig::UNBOUNDED, false),
        ("append_windowed", RetentionConfig::windowed(window, cap), false),
        ("overwrite", RetentionConfig::UNBOUNDED, true),
    ];

    let mut table = Table::new(
        "bounded-memory steady state: store bytes vs producer steps",
        &["mode", "steps", "final bytes", "peak bytes", "evicted keys", "flat?"],
    );
    let mut results: Vec<ModeResult> = Vec::new();

    for (name, retention, overwrite) in modes {
        let server = DbServer::start(ServerConfig {
            engine: Engine::KeyDb,
            with_models: false,
            retention,
            ..Default::default()
        })
        .expect("server");
        let mut c = Client::connect(server.addr).expect("client");
        let mut series: Vec<u64> = Vec::with_capacity(steps as usize);
        for step in 0..steps {
            for r in 0..ranks {
                let snap = Tensor::from_f32(&[elems], vec![step as f32; elems]).unwrap();
                let key = if overwrite {
                    stable_key("fig", r)
                } else {
                    tensor_key("fig", r, step)
                };
                c.put_tensor(&key, &snap).expect("put under governance");
            }
            series.push(server.store().n_bytes());
        }
        let info = c.info().expect("info");
        // "Flat" = bytes constant over the post-warmup half of the run.
        let warm = (steps as usize) / 2;
        let tail = &series[warm..];
        let flat = tail.iter().max() == tail.iter().min();
        table.row(&[
            name.to_string(),
            steps.to_string(),
            info.bytes.to_string(),
            series.iter().max().copied().unwrap_or(0).to_string(),
            info.evicted_keys.to_string(),
            flat.to_string(),
        ]);
        results.push(ModeResult {
            name,
            steps,
            final_bytes: info.bytes,
            peak_bytes: series.iter().max().copied().unwrap_or(0),
            high_water: info.high_water_bytes,
            evicted_keys: info.evicted_keys,
            flat_after_warmup: flat,
            series,
        });
    }
    table.print();

    // Smoke-mode structural assertions (CI runs this bench): governance
    // holds memory flat where unbounded append grows linearly.
    let unbounded = &results[0];
    let windowed = &results[1];
    let overwrite = &results[2];
    assert_eq!(
        unbounded.final_bytes,
        steps * ranks as u64 * payload,
        "unbounded append grows linearly"
    );
    assert!(windowed.flat_after_warmup, "windowed run must plateau");
    assert_eq!(windowed.final_bytes, window * ranks as u64 * payload);
    assert!(windowed.peak_bytes <= cap, "byte cap respected");
    assert!(windowed.evicted_keys > 0);
    assert!(overwrite.flat_after_warmup);
    assert_eq!(overwrite.final_bytes, ranks as u64 * payload);
    println!(
        "steady state: unbounded={} windowed={} overwrite={} bytes after {} steps",
        unbounded.final_bytes, windowed.final_bytes, overwrite.final_bytes, steps
    );

    if let Ok(path) = std::env::var("SITU_BENCH_JSON") {
        let mut s = String::from("{\n  \"bench\": \"fig_bounded_memory\",\n");
        s.push_str(&format!(
            "  \"config\": {{\"ranks\": {ranks}, \"payload_bytes\": {payload}, \
             \"window\": {window}, \"max_bytes\": {cap}}},\n"
        ));
        s.push_str("  \"modes\": [\n");
        for (i, r) in results.iter().enumerate() {
            // Thin the series to at most 32 samples to keep the JSON small.
            let stride = (r.series.len() / 32).max(1);
            let sampled: Vec<String> = r
                .series
                .iter()
                .step_by(stride)
                .map(|b| b.to_string())
                .collect();
            s.push_str(&format!(
                "    {{\"mode\": \"{}\", \"steps\": {}, \"final_bytes\": {}, \
                 \"peak_bytes\": {}, \"high_water_bytes\": {}, \"evicted_keys\": {}, \
                 \"flat_after_warmup\": {}, \"bytes_series\": [{}]}}{}\n",
                r.name,
                r.steps,
                r.final_bytes,
                r.peak_bytes,
                r.high_water,
                r.evicted_keys,
                r.flat_after_warmup,
                sampled.join(", "),
                if i + 1 == results.len() { "" } else { "," }
            ));
        }
        s.push_str("  ]\n}\n");
        std::fs::write(&path, &s).expect("write SITU_BENCH_JSON");
        println!("bench results written to {path}");
    }
}
