//! fig_chaos — replicated writes, shard-kill failover, and what they cost.
//!
//! Two experiments over a 3-shard cluster:
//!
//! 1. **Replication write overhead** — the same put sweep at `replicas = 1`
//!    vs `replicas = 2`.  Replicated puts pay one extra frame per copy, so
//!    the expected cost ratio is ~2×, not N× round trips.
//! 2. **Shard-kill failover** — write every generation at `replicas = 2`,
//!    kill one shard, and re-read everything: the sweep must come back
//!    **zero-loss byte-exact** through replica failover, and the degraded
//!    read rate is reported next to the healthy baseline.
//!
//! `SITU_BENCH_SMOKE=1` shortens the run for CI; `SITU_BENCH_JSON=path`
//! records the numbers (the BENCH_PR6.json acceptance record).

use std::net::SocketAddr;
use std::time::{Duration, Instant};

use situ::client::{tensor_key, ClusterClient, ClusterConfig, DataStore};
use situ::db::{DbServer, Engine, ServerConfig};
use situ::telemetry::Table;
use situ::tensor::Tensor;

fn payload(gen: u64, rank: usize, elems: usize) -> Tensor {
    let vals: Vec<f32> = (0..elems)
        .map(|i| (gen * 100_000 + rank as u64 * 1000 + i as u64) as f32)
        .collect();
    Tensor::from_f32(&[elems], vals).unwrap()
}

fn start_shards(n: usize) -> Vec<DbServer> {
    (0..n)
        .map(|_| {
            DbServer::start(ServerConfig {
                engine: Engine::KeyDb,
                with_models: false,
                conn_read_timeout: Duration::from_millis(50),
                ..Default::default()
            })
            .expect("shard")
        })
        .collect()
}

fn connect(addrs: &[SocketAddr], replicas: usize) -> ClusterClient {
    ClusterClient::connect_with(addrs, ClusterConfig { replicas, ..ClusterConfig::default() })
        .expect("cluster client")
}

struct WritePoint {
    replicas: usize,
    puts: u64,
    secs: f64,
    ops_per_sec: f64,
    replicated_writes: u64,
}

fn write_sweep(replicas: usize, gens: u64, ranks: usize, elems: usize) -> WritePoint {
    let mut servers = start_shards(3);
    let addrs: Vec<SocketAddr> = servers.iter().map(|s| s.addr).collect();
    let mut c = connect(&addrs, replicas);
    let start = Instant::now();
    for gen in 0..gens {
        for rank in 0..ranks {
            c.put_tensor(&tensor_key("fc", rank, gen), &payload(gen, rank, elems))
                .expect("replicated put");
        }
    }
    let secs = start.elapsed().as_secs_f64();
    let puts = gens * ranks as u64;
    let stats = c.failover_stats();
    // Every put must have landed `replicas` copies on a healthy cluster.
    assert_eq!(stats.replicated_writes, puts * (replicas as u64 - 1));
    assert_eq!(stats.degraded_ops, 0, "healthy cluster writes are never degraded");
    for s in &mut servers {
        s.shutdown();
    }
    WritePoint {
        replicas,
        puts,
        secs,
        ops_per_sec: puts as f64 / secs.max(1e-9),
        replicated_writes: stats.replicated_writes,
    }
}

struct FailoverResult {
    keys: u64,
    healthy_secs: f64,
    degraded_secs: f64,
    read_failovers: u64,
    lost: u64,
}

fn shard_kill_failover(gens: u64, ranks: usize, elems: usize) -> FailoverResult {
    let mut servers = start_shards(3);
    let addrs: Vec<SocketAddr> = servers.iter().map(|s| s.addr).collect();
    let mut c = connect(&addrs, 2);
    for gen in 0..gens {
        for rank in 0..ranks {
            c.put_tensor(&tensor_key("fk", rank, gen), &payload(gen, rank, elems)).unwrap();
        }
    }
    let sweep = |c: &mut ClusterClient| -> (f64, u64) {
        let start = Instant::now();
        let mut lost = 0u64;
        for gen in 0..gens {
            for rank in 0..ranks {
                match c.get_tensor(&tensor_key("fk", rank, gen)) {
                    Ok(t) if t == payload(gen, rank, elems) => {}
                    _ => lost += 1,
                }
            }
        }
        (start.elapsed().as_secs_f64(), lost)
    };
    let (healthy_secs, healthy_lost) = sweep(&mut c);
    assert_eq!(healthy_lost, 0, "healthy sweep is lossless");

    servers[1].simulate_crash();
    let (degraded_secs, lost) = sweep(&mut c);
    let stats = c.failover_stats();
    servers[0].shutdown();
    servers[2].shutdown();
    FailoverResult {
        keys: gens * ranks as u64,
        healthy_secs,
        degraded_secs,
        read_failovers: stats.read_failovers,
        lost,
    }
}

fn main() {
    let smoke = std::env::var("SITU_BENCH_SMOKE").is_ok();
    let gens: u64 = std::env::var("SITU_BENCH_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if smoke { 20 } else { 200 });
    let ranks = 4usize;
    let elems = 4 * 1024usize; // 16 KiB per tensor

    // --- experiment 1: replication write overhead --------------------------
    let mut table = Table::new(
        "replicated write overhead (3 shards)",
        &["replicas", "puts", "secs", "ops/s", "replica copies"],
    );
    let mut points = Vec::new();
    for replicas in [1usize, 2] {
        let p = write_sweep(replicas, gens, ranks, elems);
        table.row(&[
            p.replicas.to_string(),
            p.puts.to_string(),
            format!("{:.3}", p.secs),
            format!("{:.0}", p.ops_per_sec),
            p.replicated_writes.to_string(),
        ]);
        points.push(p);
    }
    table.print();

    // --- experiment 2: shard-kill failover ---------------------------------
    let f = shard_kill_failover(gens, ranks, elems);
    let mut ft = Table::new(
        "shard-kill read failover (replicas = 2, one of 3 shards killed)",
        &["keys", "healthy secs", "degraded secs", "read failovers", "lost"],
    );
    ft.row(&[
        f.keys.to_string(),
        format!("{:.3}", f.healthy_secs),
        format!("{:.3}", f.degraded_secs),
        f.read_failovers.to_string(),
        f.lost.to_string(),
    ]);
    ft.print();

    // The fig_chaos gate: zero data loss through a shard kill, failover
    // actually exercised, replication actually replicated.
    assert_eq!(f.lost, 0, "zero-loss failover is the acceptance gate");
    assert!(f.read_failovers > 0, "the killed shard's keys failed over");
    assert!(points[1].replicated_writes > 0 && points[0].replicated_writes == 0);

    if let Ok(path) = std::env::var("SITU_BENCH_JSON") {
        let mut s = String::from("{\n  \"bench\": \"fig_chaos\",\n");
        s.push_str(&format!(
            "  \"config\": {{\"gens\": {gens}, \"ranks\": {ranks}, \"payload_bytes\": {}, \
             \"shards\": 3}},\n",
            elems * 4
        ));
        s.push_str("  \"write_overhead\": [\n");
        for (i, p) in points.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"replicas\": {}, \"puts\": {}, \"secs\": {:.6}, \"ops_per_sec\": {:.1}, \
                 \"replicated_writes\": {}}}{}\n",
                p.replicas,
                p.puts,
                p.secs,
                p.ops_per_sec,
                p.replicated_writes,
                if i + 1 == points.len() { "" } else { "," }
            ));
        }
        s.push_str("  ],\n");
        s.push_str(&format!(
            "  \"shard_kill_failover\": {{\"keys\": {}, \"healthy_secs\": {:.6}, \
             \"degraded_secs\": {:.6}, \"read_failovers\": {}, \"lost\": {}}}\n",
            f.keys, f.healthy_secs, f.degraded_secs, f.read_failovers, f.lost
        ));
        s.push_str("}\n");
        std::fs::write(&path, &s).expect("write SITU_BENCH_JSON");
        println!("bench results written to {path}");
    }
}
