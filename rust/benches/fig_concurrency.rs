//! fig_concurrency — throughput and tail latency vs concurrent clients.
//!
//! The async-core acceptance harness: one readiness-driven reactor serves
//! every connection, so client count scales past the thread-per-connection
//! ceiling.  Experiments:
//!
//! 1. **Co-located sweep** — C ∈ 1 → 10k concurrent connections against one
//!    in-process server, ≤ 16 driver threads multiplexing tagged requests
//!    (depth 1 per connection).  Reports throughput, sampled p99, and the
//!    process OS-thread count while all C connections are open — the
//!    no-per-connection-thread gate.
//! 2. **Clustered sweep** — the same shape against a 3-shard cluster via
//!    the routed blocking `ClusterClient` API.
//! 3. **Cold accept** — connect + first-reply latency for fresh sockets;
//!    p99 must beat 10 ms (the old accept backoff ladder slept up to 50 ms).
//! 4. **Tagged interleave under faults** — pipelined puts/gets stay
//!    byte-exact with a seeded delay plan active on every socket op.
//! 5. **Batch-poll bound** — a batch of polls waits ≈ max(entry timeouts),
//!    never the sum.
//! 6. **Multi-reactor sweep** — the co-located shape against a 4-reactor
//!    server; the fixed thread budget must hold with the connections
//!    spread across reactors.
//! 7. **Gather fan-out structure** — a cluster `mget` spanning 3 shards
//!    issues its per-shard sub-batches in ONE multiplexed round (one
//!    request frame per shard), asserted from the mux and frame counters.
//! 8. **Write-triggered wakeup** — a poll parked on a 200 ms backoff
//!    interval resolves within milliseconds of the satisfying put, via the
//!    hub's key-indexed waiter map rather than the probe clock.
//!
//! `SITU_BENCH_SMOKE=1` shortens the sweep for CI (and keeps the socket
//! count inside default fd limits); `SITU_BENCH_JSON=path` records the
//! numbers (the BENCH_PR8/PR9 acceptance records).  The full 10k point
//! wants ~4 GiB of socket buffers and a generous `ulimit -n`.

use std::net::SocketAddr;
use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

use situ::client::{Client, ClusterClient, ClusterConfig, DataStore};
use situ::db::{DbServer, Engine, ServerConfig};
use situ::proto::{Request, Response};
use situ::telemetry::Table;
use situ::tensor::Tensor;
use situ::util::fault::{FaultConfig, FaultPlan};

const MAX_WORKERS: usize = 16;

fn payload(i: usize, elems: usize) -> Tensor {
    let vals: Vec<f32> = (0..elems).map(|j| (i * 1_000 + j) as f32).collect();
    Tensor::from_f32(&[elems], vals).unwrap()
}

/// OS threads in this process, from /proc (None off Linux).
fn os_threads() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    status
        .lines()
        .find(|l| l.starts_with("Threads:"))?
        .split_whitespace()
        .nth(1)?
        .parse()
        .ok()
}

fn p99_ms(lats: &mut [Duration]) -> f64 {
    if lats.is_empty() {
        return 0.0;
    }
    lats.sort_unstable();
    lats[(lats.len() * 99 / 100).min(lats.len() - 1)].as_secs_f64() * 1e3
}

struct Point {
    clients: usize,
    ops: u64,
    secs: f64,
    ops_per_sec: f64,
    p99_ms: f64,
    threads: Option<u64>,
}

/// One co-located sweep point: C connections split over ≤ 16 driver
/// threads, each wave sends one tagged GET per connection then collects the
/// tagged replies — C requests in flight at once on C sockets, no blocking
/// driver per connection.
fn colocated_point(addr: SocketAddr, clients: usize, ops_per_conn: usize, n_keys: usize) -> Point {
    let workers = clients.min(MAX_WORKERS);
    // Two rendezvous: all conns open (main samples the thread count), then go.
    let open = Arc::new(Barrier::new(workers + 1));
    let go = Arc::new(Barrier::new(workers + 1));
    let handles: Vec<_> = (0..workers)
        .map(|w| {
            let n_conns = clients / workers + usize::from(w < clients % workers);
            let (open, go) = (open.clone(), go.clone());
            std::thread::spawn(move || {
                let mut conns: Vec<Client> =
                    (0..n_conns).map(|_| Client::connect(addr).expect("connect")).collect();
                open.wait();
                go.wait();
                let mut lats = Vec::with_capacity(n_conns * ops_per_conn);
                let mut tags = vec![0u32; conns.len()];
                let mut sent = vec![Instant::now(); conns.len()];
                for round in 0..ops_per_conn {
                    for (i, conn) in conns.iter_mut().enumerate() {
                        let key = format!("k{}", (w + i * MAX_WORKERS + round) % n_keys);
                        sent[i] = Instant::now();
                        tags[i] = conn.send_tagged(&Request::GetTensor { key }).expect("send");
                    }
                    for (i, conn) in conns.iter_mut().enumerate() {
                        match conn.recv_tagged(tags[i]).expect("recv") {
                            Response::Tensor(_) => lats.push(sent[i].elapsed()),
                            other => panic!("expected tensor, got {other:?}"),
                        }
                    }
                }
                lats
            })
        })
        .collect();
    open.wait();
    let threads = os_threads();
    let started = Instant::now();
    go.wait();
    let mut lats: Vec<Duration> =
        handles.into_iter().flat_map(|h| h.join().expect("worker")).collect();
    let secs = started.elapsed().as_secs_f64();
    let ops = lats.len() as u64;
    Point {
        clients,
        ops,
        secs,
        ops_per_sec: ops as f64 / secs.max(1e-9),
        p99_ms: p99_ms(&mut lats),
        threads,
    }
}

/// One clustered sweep point: C routed `ClusterClient`s (3 sockets each)
/// split over ≤ 16 driver threads issuing blocking gets.
fn clustered_point(
    addrs: &[SocketAddr],
    clients: usize,
    ops_per_client: usize,
    n_keys: usize,
) -> Point {
    let workers = clients.min(MAX_WORKERS);
    let open = Arc::new(Barrier::new(workers + 1));
    let go = Arc::new(Barrier::new(workers + 1));
    let handles: Vec<_> = (0..workers)
        .map(|w| {
            let n_clients = clients / workers + usize::from(w < clients % workers);
            let (open, go) = (open.clone(), go.clone());
            let addrs = addrs.to_vec();
            std::thread::spawn(move || {
                let mut cs: Vec<ClusterClient> = (0..n_clients)
                    .map(|_| {
                        ClusterClient::connect_with(&addrs, ClusterConfig::default())
                            .expect("cluster connect")
                    })
                    .collect();
                open.wait();
                go.wait();
                let mut lats = Vec::with_capacity(n_clients * ops_per_client);
                for round in 0..ops_per_client {
                    for (i, c) in cs.iter_mut().enumerate() {
                        let key = format!("cc{}", (w + i * MAX_WORKERS + round) % n_keys);
                        let t0 = Instant::now();
                        c.get_tensor(&key).expect("clustered get");
                        lats.push(t0.elapsed());
                    }
                }
                lats
            })
        })
        .collect();
    open.wait();
    let threads = os_threads();
    let started = Instant::now();
    go.wait();
    let mut lats: Vec<Duration> =
        handles.into_iter().flat_map(|h| h.join().expect("worker")).collect();
    let secs = started.elapsed().as_secs_f64();
    let ops = lats.len() as u64;
    Point {
        clients,
        ops,
        secs,
        ops_per_sec: ops as f64 / secs.max(1e-9),
        p99_ms: p99_ms(&mut lats),
        threads,
    }
}

/// Connect + first-reply latency for fresh sockets against a live server.
fn cold_accept(addr: SocketAddr, samples: usize) -> Vec<Duration> {
    (0..samples)
        .map(|_| {
            let t0 = Instant::now();
            let mut c = Client::connect(addr).expect("cold connect");
            c.exists("warm").expect("first op");
            t0.elapsed()
        })
        .collect()
}

/// Pipelined tagged puts then gets with a seeded delay plan on every socket
/// op; returns (byte_exact, delayed_ops).
fn fault_interleave(ops: usize) -> (bool, u64) {
    let plan = Arc::new(FaultPlan::new(FaultConfig {
        seed: 1234,
        delay_p: 0.25,
        delay: Duration::from_micros(200),
        ..FaultConfig::default()
    }));
    let mut server = DbServer::start(ServerConfig {
        engine: Engine::KeyDb,
        with_models: false,
        fault: Some(plan.clone()),
        ..Default::default()
    })
    .expect("fault server");
    let mut c = Client::connect(server.addr).expect("connect");
    let puts: Vec<Request> = (0..ops)
        .map(|i| Request::PutTensor { key: format!("f{i}"), tensor: payload(i, 64) })
        .collect();
    let mut exact = c
        .call_pipelined(&puts)
        .expect("pipelined puts")
        .iter()
        .all(|r| matches!(r, Response::Ok));
    let gets: Vec<Request> =
        (0..ops).map(|i| Request::GetTensor { key: format!("f{i}") }).collect();
    for (i, r) in c.call_pipelined(&gets).expect("pipelined gets").into_iter().enumerate() {
        match r {
            Response::Tensor(t) if t == payload(i, 64) => {}
            _ => exact = false,
        }
    }
    let delayed = plan.counters().delayed_ops;
    server.shutdown();
    (exact, delayed)
}

/// Elapsed seconds for a batch of `n` polls on absent keys, each with the
/// same per-entry timeout — bounded by max, not sum, under the shared
/// batch deadline.
fn batch_poll_secs(addr: SocketAddr, n: usize, timeout_ms: u64) -> f64 {
    let mut c = Client::connect(addr).expect("connect");
    let entries: Vec<Request> = (0..n)
        .map(|i| Request::PollKeys {
            keys: vec![format!("absent{i}")],
            timeout_ms,
            initial_us: 1_000,
            cap_us: 20_000,
        })
        .collect();
    let t0 = Instant::now();
    match c.call(&Request::Batch(entries)).expect("batch poll") {
        Response::Batch(rs) => assert!(rs.iter().all(|r| matches!(r, Response::Bool(false)))),
        other => panic!("expected batch reply, got {other:?}"),
    }
    t0.elapsed().as_secs_f64()
}

fn main() {
    let smoke = std::env::var("SITU_BENCH_SMOKE").is_ok();
    // Smoke stays inside a 1024-fd default ulimit; full climbs to 10k conns.
    let co_sweep: Vec<usize> =
        if smoke { vec![1, 16, 128, 256] } else { vec![1, 8, 64, 256, 1024, 4096, 10_000] };
    let cl_sweep: Vec<usize> = if smoke { vec![1, 8] } else { vec![1, 16, 64, 256] };
    let n_keys = 64usize;
    let elems = 256usize; // 1 KiB payloads — latency-oriented

    // --- experiment 1: co-located concurrency sweep ------------------------
    let mut server = DbServer::start(ServerConfig {
        engine: Engine::KeyDb,
        with_models: false,
        ..Default::default()
    })
    .expect("server");
    {
        let mut seed = Client::connect(server.addr).expect("seed connect");
        for i in 0..n_keys {
            seed.put_tensor(&format!("k{i}"), &payload(i, elems)).expect("seed put");
        }
        seed.put_tensor("warm", &payload(0, 4)).expect("seed put");
    }
    let mut co_table = Table::new(
        "co-located: throughput / p99 vs concurrent connections",
        &["clients", "ops", "secs", "ops/s", "p99 ms", "os threads"],
    );
    let mut co_points = Vec::new();
    for &c in &co_sweep {
        let ops_per_conn = if smoke { (256 / c).max(4) } else { (4096 / c).max(8) };
        let p = colocated_point(server.addr, c, ops_per_conn, n_keys);
        co_table.row(&[
            p.clients.to_string(),
            p.ops.to_string(),
            format!("{:.3}", p.secs),
            format!("{:.0}", p.ops_per_sec),
            format!("{:.3}", p.p99_ms),
            p.threads.map_or("n/a".into(), |t| t.to_string()),
        ]);
        co_points.push(p);
    }
    co_table.print();

    // --- experiment 3: cold accept -----------------------------------------
    let mut cold = cold_accept(server.addr, if smoke { 30 } else { 200 });
    let cold_p99_ms = p99_ms(&mut cold);
    let cold_p50_ms = cold[cold.len() / 2].as_secs_f64() * 1e3;

    // --- experiment 5: batch-poll bound ------------------------------------
    let poll_ms = if smoke { 200u64 } else { 400 };
    let batch_secs = batch_poll_secs(server.addr, 3, poll_ms);
    server.shutdown();

    // --- experiment 2: clustered sweep -------------------------------------
    let mut shards: Vec<DbServer> = (0..3)
        .map(|_| {
            DbServer::start(ServerConfig {
                engine: Engine::KeyDb,
                with_models: false,
                ..Default::default()
            })
            .expect("shard")
        })
        .collect();
    let shard_addrs: Vec<SocketAddr> = shards.iter().map(|s| s.addr).collect();
    {
        let mut seed = ClusterClient::connect_with(&shard_addrs, ClusterConfig::default())
            .expect("cluster seed");
        for i in 0..n_keys {
            seed.put_tensor(&format!("cc{i}"), &payload(i, elems)).expect("cluster seed put");
        }
    }
    let mut cl_table = Table::new(
        "clustered (3 shards): throughput / p99 vs concurrent clients",
        &["clients", "ops", "secs", "ops/s", "p99 ms"],
    );
    let mut cl_points = Vec::new();
    for &c in &cl_sweep {
        let ops_per_client = if smoke { (128 / c).max(4) } else { (2048 / c).max(8) };
        let p = clustered_point(&shard_addrs, c, ops_per_client, n_keys);
        cl_table.row(&[
            p.clients.to_string(),
            p.ops.to_string(),
            format!("{:.3}", p.secs),
            format!("{:.0}", p.ops_per_sec),
            format!("{:.3}", p.p99_ms),
        ]);
        cl_points.push(p);
    }
    cl_table.print();

    // --- experiment 7: gather fan-out structure ----------------------------
    // One mget spanning every shard must cost exactly ONE multiplexed round
    // (per-shard sub-batches issued before any reply is collected) and ONE
    // request frame per shard — the max-of-shards, not sum-of-shards shape.
    let frames_of = |s: &DbServer| {
        s.store().counters.frames.load(std::sync::atomic::Ordering::Relaxed)
    };
    let mut fan = ClusterClient::connect_with(&shard_addrs, ClusterConfig::default())
        .expect("fanout client");
    // Warm the routed connections so lazy dials don't blur the deltas.
    fan.get_tensor("cc0").expect("warm gather conn");
    let frames_before: Vec<u64> = shards.iter().map(frames_of).collect();
    let (rounds_before, subs_before) = fan.mux_counters();
    let gather_keys: Vec<String> = (0..n_keys).map(|i| format!("cc{i}")).collect();
    let got = fan.mget_tensors(&gather_keys).expect("fanout gather");
    assert_eq!(got.len(), n_keys, "gather dropped entries");
    let (rounds_after, subs_after) = fan.mux_counters();
    let fanout_rounds = rounds_after - rounds_before;
    let fanout_subs = subs_after - subs_before;
    let fanout_frames: Vec<u64> = shards
        .iter()
        .zip(&frames_before)
        .map(|(s, b)| frames_of(s) - b)
        .collect();
    drop(fan);

    for s in &mut shards {
        s.shutdown();
    }

    // --- experiment 4: tagged interleave under faults ----------------------
    let (byte_exact, delayed_ops) = fault_interleave(if smoke { 64 } else { 512 });

    // --- experiment 6: multi-reactor co-located sweep ----------------------
    let mut mr_server = DbServer::start(ServerConfig {
        engine: Engine::KeyDb,
        with_models: false,
        reactors: 4,
        ..Default::default()
    })
    .expect("multi-reactor server");
    let mr_reactors = mr_server.reactors();
    assert_eq!(mr_reactors, 4, "4-reactor topology requested");
    {
        let mut seed = Client::connect(mr_server.addr).expect("mr seed connect");
        for i in 0..n_keys {
            seed.put_tensor(&format!("k{i}"), &payload(i, elems)).expect("mr seed put");
        }
    }
    let mr_sweep: Vec<usize> = if smoke { vec![64, 128] } else { vec![64, 256, 1024] };
    let mut mr_table = Table::new(
        "co-located, 4 reactors: throughput / p99 vs concurrent connections",
        &["clients", "ops", "secs", "ops/s", "p99 ms", "os threads"],
    );
    let mut mr_points = Vec::new();
    for &c in &mr_sweep {
        let ops_per_conn = if smoke { (256 / c).max(4) } else { (4096 / c).max(8) };
        let p = colocated_point(mr_server.addr, c, ops_per_conn, n_keys);
        mr_table.row(&[
            p.clients.to_string(),
            p.ops.to_string(),
            format!("{:.3}", p.secs),
            format!("{:.0}", p.ops_per_sec),
            format!("{:.3}", p.p99_ms),
            p.threads.map_or("n/a".into(), |t| t.to_string()),
        ]);
        mr_points.push(p);
    }
    mr_table.print();

    // --- experiment 8: write-triggered poll wakeup -------------------------
    // initial == cap == 200 ms: once the immediate verification probe
    // misses, the probe clock alone could not answer for another 200 ms.
    // The put must resolve the parked waiter through the hub's key index.
    let wake_samples = if smoke { 10 } else { 50 };
    let mut wake_lats: Vec<Duration> = Vec::with_capacity(wake_samples);
    {
        let mut waiter = Client::connect(mr_server.addr).expect("waiter connect");
        let mut producer = Client::connect(mr_server.addr).expect("producer connect");
        for i in 0..wake_samples {
            let key = format!("wake{i}");
            let tag = waiter
                .send_tagged(&Request::PollKeys {
                    keys: vec![key.clone()],
                    timeout_ms: 5_000,
                    initial_us: 200_000,
                    cap_us: 200_000,
                })
                .expect("park poll");
            // Let the waiter park and its verification probe miss first.
            std::thread::sleep(Duration::from_millis(10));
            let t0 = Instant::now();
            producer.put_tensor(&key, &payload(i, 4)).expect("waking put");
            match waiter.recv_tagged(tag).expect("poll reply") {
                Response::Bool(true) => wake_lats.push(t0.elapsed()),
                other => panic!("expected Bool(true), got {other:?}"),
            }
        }
    }
    let wake_p99_ms = p99_ms(&mut wake_lats);
    let hub_wakeups = mr_server.poll_write_wakeups();
    mr_server.shutdown();

    let mut gate_table = Table::new(
        "gates",
        &[
            "cold p99 ms",
            "batch 3×poll secs",
            "byte exact",
            "delayed ops",
            "fanout rounds",
            "fanout subs",
            "wake p99 ms",
            "hub wakeups",
        ],
    );
    gate_table.row(&[
        format!("{cold_p99_ms:.3}"),
        format!("{batch_secs:.3}"),
        byte_exact.to_string(),
        delayed_ops.to_string(),
        fanout_rounds.to_string(),
        fanout_subs.to_string(),
        format!("{wake_p99_ms:.3}"),
        hub_wakeups.to_string(),
    ]);
    gate_table.print();

    // --- the fig_concurrency acceptance gates ------------------------------
    // Cold accepts are readiness-driven, not backoff-ladder paced.
    assert!(cold_p99_ms < 10.0, "cold accept p99 {cold_p99_ms:.3} ms ≥ 10 ms");
    // No per-connection OS thread: at every C ≥ 64 the process runs a small
    // fixed thread budget (reactor + hub + ≤16 executors + ≤16 drivers).
    for p in &co_points {
        if p.clients >= 64 {
            if let Some(t) = p.threads {
                assert!(t < 100, "{} threads with {} connections open", t, p.clients);
            }
        }
    }
    // Tagged replies pair correctly under reordering pressure.
    assert!(byte_exact, "tagged interleave lost byte-exactness under faults");
    assert!(delayed_ops > 0, "fault plan never fired — interleave gate is vacuous");
    // Batch polls share one deadline: bounded by max, never the sum.
    let max_secs = poll_ms as f64 / 1e3;
    assert!(batch_secs < 2.2 * max_secs, "batch polls summed timeouts: {batch_secs:.3}s");
    assert!(batch_secs >= 0.7 * max_secs, "batch polls returned early: {batch_secs:.3}s");
    // The thread gate survives reactor sharding: 4 reactors add 3 threads
    // to the budget, not one per connection.
    for p in &mr_points {
        if p.clients >= 64 {
            if let Some(t) = p.threads {
                assert!(t < 100, "{t} threads with {} connections on 4 reactors", p.clients);
            }
        }
    }
    // A full-cluster gather is ONE multiplexed round: every shard's
    // sub-batch in flight together, one request frame per shard.
    assert_eq!(fanout_rounds, 1, "gather took {fanout_rounds} fan-out rounds, want 1");
    assert_eq!(fanout_subs, 3, "gather issued {fanout_subs} sub-batches, want one per shard");
    for (i, d) in fanout_frames.iter().enumerate() {
        assert_eq!(*d, 1, "shard {i} saw {d} request frames for one gather, want 1");
    }
    // Writes resolve parked waiters through the hub's key index: within
    // milliseconds of the put, strictly before the 200 ms probe clock.
    assert!(
        wake_p99_ms < 50.0,
        "write wakeup p99 {wake_p99_ms:.3} ms — probe clock, not key-indexed wakeup"
    );
    assert!(hub_wakeups > 0, "poll hub never saw a write notification");

    if let Ok(path) = std::env::var("SITU_BENCH_JSON") {
        let point_json = |p: &Point| {
            format!(
                "{{\"clients\": {}, \"ops\": {}, \"secs\": {:.6}, \"ops_per_sec\": {:.1}, \
                 \"p99_ms\": {:.4}, \"os_threads\": {}}}",
                p.clients,
                p.ops,
                p.secs,
                p.ops_per_sec,
                p.p99_ms,
                p.threads.map_or("null".into(), |t| t.to_string()),
            )
        };
        let mut s = String::from("{\n  \"bench\": \"fig_concurrency\",\n");
        s.push_str(&format!(
            "  \"config\": {{\"smoke\": {smoke}, \"payload_bytes\": {}, \"n_keys\": {n_keys}, \
             \"max_driver_threads\": {MAX_WORKERS}}},\n",
            elems * 4
        ));
        s.push_str("  \"colocated\": [\n");
        for (i, p) in co_points.iter().enumerate() {
            s.push_str(&format!(
                "    {}{}\n",
                point_json(p),
                if i + 1 == co_points.len() { "" } else { "," }
            ));
        }
        s.push_str("  ],\n  \"clustered\": [\n");
        for (i, p) in cl_points.iter().enumerate() {
            s.push_str(&format!(
                "    {}{}\n",
                point_json(p),
                if i + 1 == cl_points.len() { "" } else { "," }
            ));
        }
        s.push_str("  ],\n  \"colocated_4_reactors\": [\n");
        for (i, p) in mr_points.iter().enumerate() {
            s.push_str(&format!(
                "    {}{}\n",
                point_json(p),
                if i + 1 == mr_points.len() { "" } else { "," }
            ));
        }
        s.push_str("  ],\n");
        s.push_str(&format!(
            "  \"fanout\": {{\"rounds\": {fanout_rounds}, \"sub_batches\": {fanout_subs}, \
             \"frames_per_shard\": {fanout_frames:?}}},\n"
        ));
        s.push_str(&format!(
            "  \"write_wakeup\": {{\"samples\": {wake_samples}, \"p99_ms\": {wake_p99_ms:.4}, \
             \"hub_wakeups\": {hub_wakeups}}},\n"
        ));
        s.push_str(&format!(
            "  \"cold_accept\": {{\"samples\": {}, \"p50_ms\": {cold_p50_ms:.4}, \
             \"p99_ms\": {cold_p99_ms:.4}}},\n",
            cold.len()
        ));
        s.push_str(&format!(
            "  \"gates\": {{\"cold_accept_p99_under_10ms\": {}, \"byte_exact_under_faults\": \
             {byte_exact}, \"delayed_ops\": {delayed_ops}, \"batch_poll_secs\": {batch_secs:.4}, \
             \"batch_poll_entry_timeout_secs\": {max_secs:.4}, \
             \"thread_budget_holds_with_4_reactors\": true, \"gather_one_round\": \
             {}, \"write_wakeup_p99_under_50ms\": {}}}\n",
            cold_p99_ms < 10.0,
            fanout_rounds == 1 && fanout_subs == 3,
            wake_p99_ms < 50.0
        ));
        s.push_str("}\n");
        std::fs::write(&path, &s).expect("write SITU_BENCH_JSON");
        println!("bench results written to {path}");
    }
}
