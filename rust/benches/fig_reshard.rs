//! fig_reshard — live 3 → 4 reshard: zero-loss cutover and transfer cost.
//!
//! One experiment over a 4-server cluster seeded as 3 enforced shards:
//!
//! 1. write every generation at `replicas = 2` under the 3-shard table;
//! 2. gather a training window (the trainer's read path);
//! 3. `reshard` the cluster live onto all 4 shards;
//! 4. gather the same window again and re-read every key.
//!
//! Gates:
//!
//! - **Zero loss** — every key byte-exact after the cutover, and the
//!   post-reshard gather equals the pre-reshard gather tensor-for-tensor.
//! - **Transfer cost is max-of-shards** — each streamed window costs one
//!   read round plus **one** multiplexed tagged write round covering the
//!   whole destination ring, so `transfer_rounds ≤ 2 × windows` — it does
//!   not scale with the ring width (`replicas`), which is the claim the
//!   multiplexed fan-out earns.
//! - **Completeness** — `moved_keys` equals the number of distinct keys
//!   hashing into the ranges that changed owner (computed independently
//!   from the slot tables).
//!
//! `SITU_BENCH_SMOKE=1` shortens the run for CI; `SITU_BENCH_JSON=path`
//! records the numbers (the BENCH_PR10.json acceptance record).

use std::net::SocketAddr;
use std::time::{Duration, Instant};

use situ::client::{tensor_key, ClusterClient, ClusterConfig, DataStore};
use situ::db::cluster::{hash_slot, SlotEpoch};
use situ::db::{DbServer, Engine, ServerConfig};
use situ::ml::DataLoader;
use situ::orchestrator::{reshard, ReshardConfig};
use situ::telemetry::Table;
use situ::tensor::Tensor;

fn payload(gen: u64, rank: usize, elems: usize) -> Tensor {
    let vals: Vec<f32> = (0..elems)
        .map(|i| (gen * 100_000 + rank as u64 * 1000 + i as u64) as f32)
        .collect();
    Tensor::from_f32(&[elems], vals).unwrap()
}

fn start_shards(n: usize) -> Vec<DbServer> {
    (0..n)
        .map(|_| {
            DbServer::start(ServerConfig {
                engine: Engine::KeyDb,
                with_models: false,
                conn_read_timeout: Duration::from_millis(50),
                ..Default::default()
            })
            .expect("shard")
        })
        .collect()
}

fn connect(addrs: &[SocketAddr], replicas: usize) -> ClusterClient {
    let mut c = ClusterClient::connect_with(
        addrs,
        ClusterConfig { replicas, ..ClusterConfig::default() },
    )
    .expect("cluster client");
    c.refresh_slot_table().expect("fetch slot table");
    c
}

fn main() {
    let smoke = std::env::var("SITU_BENCH_SMOKE").is_ok();
    let gens: u64 = std::env::var("SITU_BENCH_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if smoke { 16 } else { 120 });
    let ranks = 4usize;
    let elems = 4 * 1024usize; // 16 KiB per tensor
    let window = 8usize;

    let mut servers = start_shards(4);
    let addrs: Vec<SocketAddr> = servers.iter().map(|s| s.addr).collect();
    let first3 = addrs[..3].to_vec();

    // Seed: converge the 3 original shards on a committed epoch table,
    // then load every generation under it.
    let seeded = reshard(&ReshardConfig {
        addrs: first3,
        from_shards: 0,
        to_shards: 0,
        replicas: 2,
        window: 0,
    })
    .expect("seed 3-shard table");
    assert_eq!(seeded.moved_keys, 0);

    let mut c = connect(&addrs, 2);
    let write_start = Instant::now();
    for gen in 0..gens {
        for rank in 0..ranks {
            c.put_tensor(&tensor_key("fr", rank, gen), &payload(gen, rank, elems)).unwrap();
        }
    }
    let write_secs = write_start.elapsed().as_secs_f64();

    let latest = gens - 1;
    let win = gens.min(4);
    let mut dl = DataLoader::new(connect(&addrs, 2), (0..ranks).collect(), "fr", 5);
    let before = dl.gather_window(latest, win).expect("pre-reshard gather");

    // The measured live reshard, 3 → 4.
    let reshard_start = Instant::now();
    let report = reshard(&ReshardConfig {
        addrs: addrs.clone(),
        from_shards: 0,
        to_shards: 0,
        replicas: 2,
        window,
    })
    .expect("live reshard");
    let reshard_secs = reshard_start.elapsed().as_secs_f64();

    // Windowed-loader parity across the cutover.
    let after = dl.gather_window(latest, win).expect("post-reshard gather");
    assert_eq!(before.len(), after.len());
    let mut parity_mismatch = 0u64;
    for (b, a) in before.iter().zip(&after) {
        if b != a {
            parity_mismatch += 1;
        }
    }

    // Full zero-loss sweep against ground truth through a fresh client.
    let mut post = connect(&addrs, 2);
    let mut lost = 0u64;
    for gen in 0..gens {
        for rank in 0..ranks {
            match post.get_tensor(&tensor_key("fr", rank, gen)) {
                Ok(t) if t == payload(gen, rank, elems) => {}
                _ => lost += 1,
            }
        }
    }

    // Independent accounting: which keys were in ranges that changed
    // owner, and how many streaming windows that implies per range.
    let moves = SlotEpoch::initial(3).moved_ranges(&SlotEpoch::initial(4));
    let mut moved_expected = 0u64;
    let mut windows_expected = 0u64;
    for &(lo, hi, _, _) in &moves {
        let in_range = (0..gens)
            .flat_map(|g| (0..ranks).map(move |r| tensor_key("fr", r, g)))
            .filter(|k| (lo..=hi).contains(&hash_slot(k)))
            .count() as u64;
        moved_expected += in_range;
        windows_expected += in_range.div_ceil(window as u64);
    }

    let mut table = Table::new(
        "live reshard 3 -> 4 (replicas = 2)",
        &["keys", "moved", "rounds", "windows", "reshard secs", "MB/s", "lost"],
    );
    table.row(&[
        (gens * ranks as u64).to_string(),
        report.moved_keys.to_string(),
        report.transfer_rounds.to_string(),
        windows_expected.to_string(),
        format!("{reshard_secs:.3}"),
        format!("{:.1}", report.moved_bytes as f64 / 1e6 / reshard_secs.max(1e-9)),
        lost.to_string(),
    ]);
    table.print();

    // The fig_reshard gates.
    assert_eq!(lost, 0, "zero-loss cutover is the acceptance gate");
    assert_eq!(parity_mismatch, 0, "the training window reads identically across the cutover");
    assert_eq!(
        report.moved_keys, moved_expected,
        "every key in a moved range streamed exactly once"
    );
    assert!(
        report.transfer_rounds <= 2 * windows_expected,
        "transfer cost is max-of-shards: {} rounds for {} windows (a write round \
         covers the whole destination ring via tagged multiplexing)",
        report.transfer_rounds,
        windows_expected
    );
    assert_eq!(report.from_epoch + 2, report.to_epoch, "install + commit");
    assert!(report.unreachable_shards.is_empty());

    if let Ok(path) = std::env::var("SITU_BENCH_JSON") {
        let mut s = String::from("{\n  \"bench\": \"fig_reshard\",\n");
        s.push_str(&format!(
            "  \"config\": {{\"gens\": {gens}, \"ranks\": {ranks}, \"payload_bytes\": {}, \
             \"shards_from\": 3, \"shards_to\": 4, \"replicas\": 2, \"window\": {window}}},\n",
            elems * 4
        ));
        s.push_str(&format!(
            "  \"reshard\": {{\"from_epoch\": {}, \"to_epoch\": {}, \"moved_ranges\": {}, \
             \"moved_keys\": {}, \"moved_bytes\": {}, \"transfer_rounds\": {}, \
             \"windows_expected\": {windows_expected}, \"secs\": {reshard_secs:.6}, \
             \"stream_mb_per_sec\": {:.2}}},\n",
            report.from_epoch,
            report.to_epoch,
            report.moved_ranges,
            report.moved_keys,
            report.moved_bytes,
            report.transfer_rounds,
            report.moved_bytes as f64 / 1e6 / reshard_secs.max(1e-9),
        ));
        s.push_str(&format!(
            "  \"verify\": {{\"keys\": {}, \"lost\": {lost}, \"gather_parity_mismatch\": \
             {parity_mismatch}, \"write_secs\": {write_secs:.6}}}\n",
            gens * ranks as u64
        ));
        s.push_str("}\n");
        std::fs::write(&path, &s).expect("write SITU_BENCH_JSON");
        println!("bench results written to {path}");
    }

    for s in &mut servers {
        s.shutdown();
    }
}
