//! fig_serving — the versioned serving subsystem: adaptive micro-batching
//! throughput and the hybrid ML/numeric pressure solve.
//!
//! Two experiments:
//!
//! 1. **Micro-batching under concurrency** — the same 8-thread inference
//!    storm against one GPU slot, once with the batcher in pass-through
//!    mode (zero window — every request executes alone, the pre-batching
//!    behavior) and once with an adaptive window.  Coalescing amortizes
//!    the per-execution overhead (slot acquisition, stats, dispatch)
//!    across the batch, so batched throughput must be at least the
//!    unbatched baseline — that inequality is the acceptance gate.
//! 2. **Hybrid solver** — the end-to-end serving scenario: a CFD run whose
//!    pressure solve is served by the database's live surrogate with
//!    per-step validation, while checkpoints improve mid-run.  Gates: the
//!    numeric fallback engaged (early, weak checkpoints), predictions were
//!    accepted (late, converged checkpoint), and the hot-swap counter
//!    moved.  A pure-numeric run of the same integration is timed next to
//!    it for scale.
//!
//! `SITU_BENCH_SMOKE=1` shortens the run for CI; `SITU_BENCH_JSON=path`
//! records the numbers (the BENCH_PR7.json acceptance record).

use std::sync::Arc;
use std::time::{Duration, Instant};

use situ::ai::{BatcherConfig, ModelRuntime};
use situ::db::{DbServer, ServerConfig};
use situ::orchestrator::driver::{run_hybrid_serving, HybridServingConfig};
use situ::proto::Device;
use situ::sim::cfd::{ChannelFlow, Grid};
use situ::telemetry::Table;
use situ::tensor::Tensor;

const THREADS: usize = 8;
const ELEMS: usize = 128;

struct ServingPoint {
    label: &'static str,
    requests: u64,
    secs: f64,
    ops_per_sec: f64,
    batches: u64,
    batched_requests: u64,
    backend_execs: u64,
}

/// Storm the model runtime in-process: 8 threads looping `run_model` on
/// the same (key, live version, device) lane.  The store and registry are
/// the real server's; only the TCP hop is skipped, so the measured cost is
/// the serving runtime itself.
fn serving_sweep(label: &'static str, window: Duration, iters: u64) -> ServingPoint {
    let exec = situ::runtime::Executor::new().expect("executor");
    let models = ModelRuntime::with_batcher(
        exec,
        BatcherConfig {
            window,
            max_batch: 2 * THREADS,
            // Make every storm arrival count as a burst so the window
            // (when nonzero) is actually exercised.
            adapt_arrival: Duration::from_secs(600),
        },
    );
    let server =
        DbServer::start_with(ServerConfig::default(), Some(Arc::new(models))).expect("server");
    let models = Arc::clone(server.models().unwrap());
    let store = Arc::clone(server.store());

    models.put_model("m", "situ-native v1\naffine 1 2.5\n").unwrap();
    for w in 0..THREADS {
        let x: Vec<f32> = (0..ELEMS).map(|i| (w * ELEMS + i) as f32).collect();
        store.put_tensor(&format!("in_{w}"), Tensor::from_f32(&[ELEMS], x).unwrap()).unwrap();
    }

    let start = Instant::now();
    let mut handles = Vec::new();
    for w in 0..THREADS {
        let models = Arc::clone(&models);
        let store = Arc::clone(&store);
        handles.push(std::thread::spawn(move || {
            let ik = format!("in_{w}");
            let ok = format!("out_{w}");
            for _ in 0..iters {
                models
                    .run_model(&store, "m", 0, &[ik.clone()], &[ok.clone()], Device::Gpu(0))
                    .expect("run_model");
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let secs = start.elapsed().as_secs_f64();

    // De-stacked outputs must be each caller's own slice, not a neighbor's.
    for w in 0..THREADS {
        let y = store.get_tensor(&format!("out_{w}")).unwrap().to_f32().unwrap();
        assert_eq!(y.len(), ELEMS);
        assert_eq!(y[0], (w * ELEMS) as f32 + 2.5, "caller {w} got someone else's batch slice");
    }

    let requests = THREADS as u64 * iters;
    let (batches, batched_requests) = models.batch_counters();
    let backend_execs = models.model_entries()[0].executions;
    ServingPoint {
        label,
        requests,
        secs,
        ops_per_sec: requests as f64 / secs.max(1e-9),
        batches,
        batched_requests,
        backend_execs,
    }
}

fn main() {
    let smoke = std::env::var("SITU_BENCH_SMOKE").is_ok();
    let iters: u64 = std::env::var("SITU_BENCH_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if smoke { 400 } else { 4000 });

    // --- experiment 1: micro-batching under concurrency --------------------
    let unbatched = serving_sweep("unbatched (window 0)", Duration::ZERO, iters);
    let batched = serving_sweep("batched (100 µs window)", Duration::from_micros(100), iters);
    let mut table = Table::new(
        "adaptive micro-batching (8 threads, one GPU slot, 128-elem f32)",
        &["mode", "requests", "secs", "req/s", "batches", "batched reqs", "backend execs"],
    );
    for p in [&unbatched, &batched] {
        table.row(&[
            p.label.to_string(),
            p.requests.to_string(),
            format!("{:.3}", p.secs),
            format!("{:.0}", p.ops_per_sec),
            p.batches.to_string(),
            p.batched_requests.to_string(),
            p.backend_execs.to_string(),
        ]);
    }
    table.print();

    // --- experiment 2: hybrid pressure solve -------------------------------
    let h_cfg = HybridServingConfig {
        steps: if smoke { 9 } else { 18 },
        publish_every: 3,
        checkpoint_iters: vec![3, 2000],
        ..HybridServingConfig::default()
    };
    let numeric_secs = {
        let grid = Grid::channel(h_cfg.grid.0, h_cfg.grid.1, h_cfg.grid.2);
        let mut flow = ChannelFlow::new(grid, h_cfg.nu, h_cfg.seed, 0.08);
        let start = Instant::now();
        for _ in 0..h_cfg.steps {
            flow.step();
        }
        start.elapsed().as_secs_f64()
    };
    let start = Instant::now();
    let report = run_hybrid_serving(&h_cfg).expect("hybrid serving run");
    let hybrid_secs = start.elapsed().as_secs_f64();
    let s = &report.stats;
    let mut ht = Table::new(
        "hybrid pressure solve vs pure numeric",
        &["steps", "accepted", "fallbacks", "infer errors", "swaps", "hybrid secs", "numeric secs"],
    );
    ht.row(&[
        s.steps.to_string(),
        s.accepted.to_string(),
        s.fallbacks.to_string(),
        s.surrogate_errors.to_string(),
        report.db.model_swaps.to_string(),
        format!("{:.3}", hybrid_secs),
        format!("{:.3}", numeric_secs),
    ]);
    ht.print();

    // --- the fig_serving gates ---------------------------------------------
    assert!(
        batched.ops_per_sec >= unbatched.ops_per_sec,
        "batched throughput ({:.0}/s) fell below the unbatched baseline ({:.0}/s)",
        batched.ops_per_sec,
        unbatched.ops_per_sec
    );
    assert!(batched.batches >= 1, "the window never coalesced anything");
    assert!(
        batched.backend_execs < batched.requests,
        "stacking saved no backend executions"
    );
    assert_eq!(s.steps, h_cfg.steps, "hybrid run completed every step");
    assert!(s.fallbacks > 0, "the numeric fallback never engaged");
    assert!(s.accepted > 0, "no surrogate prediction was ever accepted");
    assert!(report.db.model_swaps >= 1, "mid-run checkpoints never hot-swapped");
    assert!(
        report.mean_abs_divergence < 0.1,
        "hybrid flow lost projection quality: {}",
        report.mean_abs_divergence
    );

    if let Ok(path) = std::env::var("SITU_BENCH_JSON") {
        let point = |p: &ServingPoint| {
            format!(
                "{{\"requests\": {}, \"secs\": {:.6}, \"ops_per_sec\": {:.1}, \
                 \"batches\": {}, \"batched_requests\": {}, \"backend_execs\": {}}}",
                p.requests, p.secs, p.ops_per_sec, p.batches, p.batched_requests, p.backend_execs
            )
        };
        let mut out = String::from("{\n  \"bench\": \"fig_serving\",\n");
        out.push_str(&format!(
            "  \"config\": {{\"threads\": {THREADS}, \"elems\": {ELEMS}, \"iters\": {iters}, \
             \"hybrid_steps\": {}}},\n",
            h_cfg.steps
        ));
        out.push_str(&format!("  \"unbatched\": {},\n", point(&unbatched)));
        out.push_str(&format!("  \"batched\": {},\n", point(&batched)));
        out.push_str(&format!(
            "  \"hybrid\": {{\"steps\": {}, \"accepted\": {}, \"fallbacks\": {}, \
             \"surrogate_errors\": {}, \"model_swaps\": {}, \"batches\": {}, \
             \"batched_requests\": {}, \"secs\": {:.6}, \"numeric_secs\": {:.6}, \
             \"mean_abs_divergence\": {:.6e}}}\n",
            s.steps,
            s.accepted,
            s.fallbacks,
            s.surrogate_errors,
            report.db.model_swaps,
            report.db.batches,
            report.db.batched_requests,
            hybrid_secs,
            numeric_secs,
            report.mean_abs_divergence
        ));
        out.push_str("}\n");
        std::fs::write(&path, &out).expect("write SITU_BENCH_JSON");
        println!("bench results written to {path}");
    }
}
