//! fig_spill — governed put throughput with the spill-to-disk cold tier
//! on vs off.
//!
//! The cold tier's design goal is "durability off the hot path": eviction
//! hands retired tensors to a background writer thread (refcount bump, no
//! copy, no inline disk I/O), so governed put throughput with spill on
//! must stay within noise of spill off.  This bench drives an appending
//! TCP producer against a windowed byte-capped store in both modes, times
//! the wall clock, and then proves the spilled data is actually there by
//! replaying an early evicted generation byte-exact.
//!
//! `SITU_BENCH_SMOKE=1` shortens the run for CI (structural assertions
//! only — the throughput *ratio* is recorded, and gated loosely, since CI
//! wall clocks are noisy); `SITU_BENCH_JSON=path` records the results.

use std::time::Instant;

use situ::client::{tensor_key, Client, DataStore};
use situ::db::{DbServer, Engine, RetentionConfig, ServerConfig, SpillConfig};
use situ::telemetry::Table;
use situ::tensor::Tensor;

struct ModeResult {
    name: &'static str,
    elapsed_s: f64,
    puts_per_s: f64,
    spilled_keys: u64,
    spilled_bytes: u64,
    spill_segments: u64,
}

fn main() {
    let smoke = std::env::var("SITU_BENCH_SMOKE").is_ok();
    let steps: u64 = std::env::var("SITU_BENCH_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if smoke { 40 } else { 300 });
    let ranks = 4usize;
    let elems = 16 * 1024usize; // 64 KiB per tensor
    let payload = (elems * 4) as u64;
    let window = 4u64;
    let cap = (window + 2) * ranks as u64 * payload;
    let spill_base = std::env::temp_dir()
        .join(format!("situ_fig_spill_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&spill_base);

    let mut results: Vec<ModeResult> = Vec::new();
    let mut table = Table::new(
        "governed put throughput: spill-to-disk cold tier on vs off",
        &["mode", "steps", "elapsed", "puts/s", "spilled keys", "segments"],
    );

    for (name, spill) in [
        ("spill_off", None),
        ("spill_on", Some(SpillConfig::new(spill_base.join("on")))),
    ] {
        let server = DbServer::start(ServerConfig {
            engine: Engine::KeyDb,
            with_models: false,
            retention: RetentionConfig::windowed(window, cap),
            spill,
            ..Default::default()
        })
        .expect("server");
        let mut c = Client::connect(server.addr).expect("client");
        let t0 = Instant::now();
        for step in 0..steps {
            for r in 0..ranks {
                let snap = Tensor::from_f32(&[elems], vec![step as f32; elems]).unwrap();
                c.put_tensor(&tensor_key("fig", r, step), &snap).expect("governed put");
            }
        }
        let elapsed = t0.elapsed().as_secs_f64();
        let info = c.info().expect("info"); // syncs the spill writer
        let total_puts = (steps * ranks as u64) as f64;
        table.row(&[
            name.to_string(),
            steps.to_string(),
            format!("{elapsed:.3}s"),
            format!("{:.0}", total_puts / elapsed),
            info.spilled_keys.to_string(),
            info.spill_segments.to_string(),
        ]);

        if name == "spill_on" {
            // The durability half of the claim: an early evicted
            // generation replays byte-exact from the cold tier.
            assert_eq!(info.spilled_keys, info.evicted_keys, "every eviction spilled");
            assert!(info.spilled_keys > 0);
            for r in 0..ranks {
                let back = c.cold_get(&tensor_key("fig", r, 0)).expect("cold read");
                assert_eq!(
                    back.to_f32().unwrap(),
                    vec![0.0; elems],
                    "spill replay byte-exact"
                );
            }
        } else {
            assert_eq!(info.spilled_keys, 0);
        }
        results.push(ModeResult {
            name,
            elapsed_s: elapsed,
            puts_per_s: total_puts / elapsed,
            spilled_keys: info.spilled_keys,
            spilled_bytes: info.spilled_bytes,
            spill_segments: info.spill_segments,
        });
    }
    table.print();

    let off = &results[0];
    let on = &results[1];
    let ratio = on.puts_per_s / off.puts_per_s;
    println!(
        "spill-on throughput is {:.1}% of spill-off ({:.0} vs {:.0} puts/s)",
        ratio * 100.0,
        on.puts_per_s,
        off.puts_per_s
    );
    // Acceptance: spill stays off the hot path (within 10% in quiet full
    // runs).  CI smoke boxes share noisy wall clocks, so the smoke gate is
    // deliberately loose — it catches "spill serialized the put path", not
    // scheduler jitter.
    let floor = if smoke { 0.5 } else { 0.9 };
    assert!(
        ratio >= floor,
        "spill-on throughput {:.2}x spill-off is below the {floor} floor",
        ratio
    );

    if let Ok(path) = std::env::var("SITU_BENCH_JSON") {
        let mut s = String::from("{\n  \"bench\": \"fig_spill\",\n");
        s.push_str(&format!(
            "  \"config\": {{\"ranks\": {ranks}, \"payload_bytes\": {payload}, \
             \"window\": {window}, \"max_bytes\": {cap}, \"steps\": {steps}}},\n"
        ));
        s.push_str(&format!("  \"throughput_ratio_on_over_off\": {ratio:.4},\n"));
        s.push_str("  \"modes\": [\n");
        for (i, r) in results.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"mode\": \"{}\", \"elapsed_s\": {:.4}, \"puts_per_s\": {:.1}, \
                 \"spilled_keys\": {}, \"spilled_bytes\": {}, \"spill_segments\": {}}}{}\n",
                r.name,
                r.elapsed_s,
                r.puts_per_s,
                r.spilled_keys,
                r.spilled_bytes,
                r.spill_segments,
                if i + 1 == results.len() { "" } else { "," }
            ));
        }
        s.push_str("  ]\n}\n");
        std::fs::write(&path, &s).expect("write SITU_BENCH_JSON");
        println!("bench results written to {path}");
    }
    let _ = std::fs::remove_dir_all(&spill_base);
}
