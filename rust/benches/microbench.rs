//! Microbenchmarks of the framework hot paths (the §Perf instrument):
//! protocol codec, store ops, DES event rate, literal conversion, the
//! end-to-end TCP round trip, and the batched-vs-sequential gather
//! comparison (round-trip counts from the server's frame counter).
//! Before/after numbers live in EXPERIMENTS.md §Perf; the zero-copy sweep
//! is recorded in BENCH_PR1.json and the gather round-trip comparison in
//! BENCH_PR2.json — set `SITU_BENCH_JSON=path.json` to dump
//! machine-readable results, `SITU_BENCH_SMOKE=1` to run every benchmark
//! for a single iteration (the CI wiring that keeps this binary compiling
//! and running).

use std::time::Instant;

use situ::client::{DataStore, Pipeline};
use situ::cluster::des::Server;
use situ::db::Store;
use situ::proto::{Request, Response};
use situ::telemetry::Table;
use situ::tensor::{DType, Tensor};
use situ::util::fmt;
use situ::util::rng::Rng;

struct BenchResult {
    name: String,
    per_op_s: f64,
    ops_per_s: f64,
    bytes_per_s: f64,
}

fn smoke() -> bool {
    std::env::var("SITU_BENCH_SMOKE").is_ok()
}

fn bench(
    name: &str,
    table: &mut Table,
    results: &mut Vec<BenchResult>,
    mut f: impl FnMut() -> usize,
) {
    // Warm up, then time enough iterations for >=0.2s (smoke mode: one
    // iteration, no warm-up — CI checks the paths run, not their speed).
    let mut iters = 1usize;
    let smoke = smoke();
    loop {
        let t0 = Instant::now();
        let mut work = 0usize;
        for _ in 0..iters {
            work += f();
        }
        let dt = t0.elapsed().as_secs_f64();
        if smoke || dt > 0.2 || iters > 1 << 22 {
            let per = dt / iters as f64;
            let bytes_per_s = work as f64 / dt;
            table.row(&[
                name.to_string(),
                fmt::duration(per),
                format!("{:.2e} ops/s", iters as f64 / dt),
                if work > 0 {
                    fmt::throughput(bytes_per_s)
                } else {
                    "-".into()
                },
            ]);
            results.push(BenchResult {
                name: name.to_string(),
                per_op_s: per,
                ops_per_s: iters as f64 / dt,
                bytes_per_s: if work > 0 { bytes_per_s } else { 0.0 },
            });
            return;
        }
        iters = (iters as f64 * (0.25 / dt.max(1e-9))).ceil() as usize;
        iters = iters.clamp(1, 1 << 22);
    }
}

fn main() {
    let mut table = Table::new(
        "framework microbenchmarks (hot paths)",
        &["path", "per-op", "rate", "payload throughput"],
    );
    let mut results: Vec<BenchResult> = Vec::new();
    let mut rng = Rng::new(1);

    // Protocol codec, 256KB tensor (the paper's canonical size).
    let payload = Tensor::from_f32(&[65536], rng.normal_vec_f32(65536)).unwrap();
    let req = Request::PutTensor { key: "field_rank0_step0".into(), tensor: payload.clone() };
    let mut buf = Vec::with_capacity(300 * 1024);
    bench("proto encode 256KB", &mut table, &mut results, || {
        buf.clear();
        req.encode(&mut buf);
        buf.len()
    });
    let encoded = buf.clone();
    bench("proto decode 256KB", &mut table, &mut results, || {
        let r = Request::decode(&encoded).unwrap();
        match r {
            Request::PutTensor { tensor, .. } => tensor.nbytes(),
            _ => 0,
        }
    });
    // The server-side path: decode sharing the frame body (view, no copy).
    let shared_body = situ::Bytes::from_vec(encoded.clone());
    bench("proto decode_shared 256KB", &mut table, &mut results, || {
        let r = Request::decode_shared(&shared_body).unwrap();
        match r {
            Request::PutTensor { tensor, .. } => tensor.nbytes(),
            _ => 0,
        }
    });
    let resp = Response::Tensor(payload.clone());
    bench("proto encode resp 256KB", &mut table, &mut results, || {
        buf.clear();
        resp.encode(&mut buf);
        buf.len()
    });

    // Store ops.
    let store = Store::new();
    store.put_tensor("k", payload.clone()).unwrap();
    bench("store put 256KB", &mut table, &mut results, || {
        store.put_tensor("k", payload.clone()).unwrap();
        payload.nbytes()
    });
    bench("store get 256KB", &mut table, &mut results, || {
        store.get_tensor("k").unwrap().nbytes()
    });
    let small = Tensor::from_f32(&[16], vec![0.0; 16]).unwrap();
    store.put_tensor("s", small.clone()).unwrap();
    bench("store get 64B", &mut table, &mut results, || {
        store.get_tensor("s").unwrap().nbytes()
    });

    // DES reservation rate.
    bench("des reserve x1000", &mut table, &mut results, || {
        let mut s = Server::new(4);
        for i in 0..1000 {
            s.reserve(i as f64 * 1e-6, 3e-6);
        }
        0
    });

    // Tensor <-> f32 conversion (the client-side pack/unpack cost).
    bench("tensor to_f32 256KB", &mut table, &mut results, || {
        payload.to_f32().unwrap().len() * 4
    });

    // Real TCP round trip (client + server on this host).
    let server = situ::db::DbServer::start(situ::db::ServerConfig {
        with_models: false,
        ..Default::default()
    })
    .unwrap();
    let mut client = situ::client::Client::connect(server.addr).unwrap();
    bench("tcp put+get 256KB", &mut table, &mut results, || {
        client.put_tensor("b", &payload).unwrap();
        client.get_tensor("b").unwrap();
        2 * payload.nbytes()
    });
    bench("tcp put+get 1KB", &mut table, &mut results, || {
        client.put_tensor("c", &small).unwrap();
        client.get_tensor("c").unwrap();
        2 * small.nbytes()
    });

    // Zero-copy data-plane sweep (the BENCH_PR1.json acceptance numbers):
    // store and TCP put/get throughput on 1–64 MiB payloads, where the
    // per-request memcpy/allocator traffic used to dominate.
    for mib in [1usize, 4, 16, 64] {
        let n = (mib << 20) / 4;
        let big = Tensor::zeros(DType::F32, &[n]);
        let key = format!("sweep_{mib}mib");
        store.put_tensor(&key, big.clone()).unwrap();
        bench(&format!("store put {mib}MiB"), &mut table, &mut results, || {
            store.put_tensor(&key, big.clone()).unwrap();
            big.nbytes()
        });
        bench(&format!("store get {mib}MiB"), &mut table, &mut results, || {
            store.get_tensor(&key).unwrap().nbytes()
        });
        bench(&format!("tcp put {mib}MiB"), &mut table, &mut results, || {
            client.put_tensor(&key, &big).unwrap();
            big.nbytes()
        });
        bench(&format!("tcp get {mib}MiB"), &mut table, &mut results, || {
            client.get_tensor(&key).unwrap().nbytes()
        });
    }

    // Batched vs sequential gather (the PR-2 pipelining numbers): one ML
    // rank fetching its 6 per-epoch snapshots (paper Table 2) as 6
    // get_tensor round trips vs a single MGetTensors frame, plus the
    // pipelined publish.  Round-trip counts come from the server's frame
    // counter, so the "1 vs N" claim is measured, not asserted.
    let gather_n = 6usize;
    let gather_keys: Vec<String> = (0..gather_n)
        .map(|r| situ::client::tensor_key("bench", r, 0))
        .collect();
    for k in &gather_keys {
        client.put_tensor(k, &payload).unwrap();
    }
    let count_frames = |server: &situ::db::DbServer| {
        server.store().counters.frames.load(std::sync::atomic::Ordering::Relaxed)
    };
    let f0 = count_frames(&server);
    for k in &gather_keys {
        client.get_tensor(k).unwrap();
    }
    let gather_seq_frames = count_frames(&server) - f0;
    let f0 = count_frames(&server);
    client.mget_tensors(&gather_keys).unwrap();
    let gather_batched_frames = count_frames(&server) - f0;
    bench("gather x6 sequential 256KB", &mut table, &mut results, || {
        gather_keys
            .iter()
            .map(|k| client.get_tensor(k).unwrap().nbytes())
            .sum()
    });
    bench("gather x6 mget 256KB", &mut table, &mut results, || {
        client
            .mget_tensors(&gather_keys)
            .unwrap()
            .iter()
            .map(|t| t.nbytes())
            .sum()
    });
    bench("publish x6 pipeline 256KB", &mut table, &mut results, || {
        let mut pipe = Pipeline::new();
        for k in &gather_keys {
            pipe.put_tensor(k, &payload);
        }
        pipe.put_meta("latest_step", "0");
        for r in client.execute(pipe).unwrap() {
            r.expect_ok().unwrap();
        }
        gather_n * payload.nbytes()
    });

    table.print();
    println!(
        "gather round trips for {gather_n} keys: sequential={gather_seq_frames} \
         batched={gather_batched_frames}"
    );

    if let Ok(path) = std::env::var("SITU_BENCH_JSON") {
        let mut s = String::from("{\n  \"bench\": \"microbench\",\n");
        s.push_str(&format!(
            "  \"gather_round_trips\": {{\"keys\": {gather_n}, \"sequential\": \
             {gather_seq_frames}, \"batched\": {gather_batched_frames}}},\n"
        ));
        s.push_str("  \"results\": [\n");
        for (i, r) in results.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"name\": \"{}\", \"per_op_s\": {:.9}, \"ops_per_s\": {:.3}, \"bytes_per_s\": {:.3}}}{}\n",
                r.name,
                r.per_op_s,
                r.ops_per_s,
                r.bytes_per_s,
                if i + 1 == results.len() { "" } else { "," }
            ));
        }
        s.push_str("  ]\n}\n");
        std::fs::write(&path, &s).expect("write SITU_BENCH_JSON");
        println!("bench results written to {path}");
    }
}
