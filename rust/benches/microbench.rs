//! Microbenchmarks of the framework hot paths (the §Perf instrument):
//! protocol codec, store ops, DES event rate, literal conversion, and the
//! end-to-end TCP round trip.  Before/after numbers live in
//! EXPERIMENTS.md §Perf.

use std::time::Instant;

use situ::cluster::des::Server;
use situ::db::Store;
use situ::proto::{Request, Response};
use situ::telemetry::Table;
use situ::tensor::Tensor;
use situ::util::fmt;
use situ::util::rng::Rng;

fn bench(name: &str, table: &mut Table, mut f: impl FnMut() -> usize) {
    // Warm up, then time enough iterations for >=0.2s.
    let mut iters = 1usize;
    loop {
        let t0 = Instant::now();
        let mut work = 0usize;
        for _ in 0..iters {
            work += f();
        }
        let dt = t0.elapsed().as_secs_f64();
        if dt > 0.2 || iters > 1 << 22 {
            let per = dt / iters as f64;
            table.row(&[
                name.to_string(),
                fmt::duration(per),
                format!("{:.2e} ops/s", iters as f64 / dt),
                if work > 0 {
                    fmt::throughput(work as f64 / dt)
                } else {
                    "-".into()
                },
            ]);
            return;
        }
        iters = (iters as f64 * (0.25 / dt.max(1e-9))).ceil() as usize;
        iters = iters.clamp(1, 1 << 22);
    }
}

fn main() {
    let mut table = Table::new(
        "framework microbenchmarks (hot paths)",
        &["path", "per-op", "rate", "payload throughput"],
    );
    let mut rng = Rng::new(1);

    // Protocol codec, 256KB tensor (the paper's canonical size).
    let payload = Tensor::from_f32(&[65536], rng.normal_vec_f32(65536)).unwrap();
    let req = Request::PutTensor { key: "field_rank0_step0".into(), tensor: payload.clone() };
    let mut buf = Vec::with_capacity(300 * 1024);
    bench("proto encode 256KB", &mut table, || {
        buf.clear();
        req.encode(&mut buf);
        buf.len()
    });
    let encoded = buf.clone();
    bench("proto decode 256KB", &mut table, || {
        let r = Request::decode(&encoded).unwrap();
        match r {
            Request::PutTensor { tensor, .. } => tensor.nbytes(),
            _ => 0,
        }
    });
    let resp = Response::Tensor(payload.clone());
    bench("proto encode resp 256KB", &mut table, || {
        buf.clear();
        resp.encode(&mut buf);
        buf.len()
    });

    // Store ops.
    let store = Store::new();
    store.put_tensor("k", payload.clone()).unwrap();
    bench("store put 256KB", &mut table, || {
        store.put_tensor("k", payload.clone()).unwrap();
        payload.nbytes()
    });
    bench("store get 256KB", &mut table, || store.get_tensor("k").unwrap().nbytes());
    let small = Tensor::from_f32(&[16], vec![0.0; 16]).unwrap();
    store.put_tensor("s", small.clone()).unwrap();
    bench("store get 64B", &mut table, || store.get_tensor("s").unwrap().nbytes());

    // DES reservation rate.
    bench("des reserve x1000", &mut table, || {
        let mut s = Server::new(4);
        for i in 0..1000 {
            s.reserve(i as f64 * 1e-6, 3e-6);
        }
        0
    });

    // Tensor <-> f32 conversion (the client-side pack/unpack cost).
    bench("tensor to_f32 256KB", &mut table, || payload.to_f32().unwrap().len() * 4);

    // Real TCP round trip (client + server on this host).
    let server = situ::db::DbServer::start(situ::db::ServerConfig {
        with_models: false,
        ..Default::default()
    })
    .unwrap();
    let mut client = situ::client::Client::connect(server.addr).unwrap();
    bench("tcp put+get 256KB", &mut table, || {
        client.put_tensor("b", &payload).unwrap();
        client.get_tensor("b").unwrap();
        2 * payload.nbytes()
    });
    bench("tcp put+get 1KB", &mut table, || {
        client.put_tensor("c", &small).unwrap();
        client.get_tensor("c").unwrap();
        2 * small.nbytes()
    });

    table.print();
}
