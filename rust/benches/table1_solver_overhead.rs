//! Table 1 — PHASTA(-standin) solver components during in situ training,
//! averaged across ranks: equation formation, equation solution, client
//! initialization, metadata transfer, training data send.
//!
//! Paper numbers (36M elements, 960 ranks): formation 45.4s, solution
//! 453.4s, client init 0.002s, metadata 0.065s, send 0.120s — framework
//! overhead ≪1% of PDE integration.  Here the solver is the real in-repo
//! NS solver at host scale; the claim under test is the *ratio*.
//!
//! The "training data send" component exercises the zero-copy data plane
//! end to end: the sampler packs the snapshot payload once, the client
//! split-writes it from that same buffer, and the server stores the frame
//! it read — so the overhead numerator contains one socket copy per
//! direction and no allocator churn beyond it.

use situ::orchestrator::driver::{run_insitu_training, InSituTrainingConfig};

fn main() {
    let artifacts = situ::db::server::artifacts_dir();
    if !artifacts.join("manifest.json").exists() {
        println!("table1 SKIPPED: artifacts not built");
        return;
    }
    let cfg = InSituTrainingConfig {
        artifacts_dir: artifacts,
        grid: (32, 24, 16), // big enough that the solve dominates
        nu: 2e-3,
        sim_ranks: 4,
        ml_ranks: 1,
        epochs: 10,
        snapshot_every: 2,
        solver_steps: 30,
        seed: 0,
        ..Default::default()
    };
    let report = run_insitu_training(&cfg).expect("in situ run");
    report.solver_table.print();
    println!(
        "framework overhead on solver: {:.4}% of PDE integration (paper: <<1%)",
        report.solver_overhead_frac * 100.0
    );
    // The paper's claim scaled to this host: overhead well under the PDE
    // integration cost.  (The absolute floor differs — our solver step is
    // milliseconds, not minutes — so the bound is looser here.)
    assert!(
        report.solver_overhead_frac < 0.25,
        "framework overhead too large: {:.3}",
        report.solver_overhead_frac
    );
    println!("table1 OK");
}
