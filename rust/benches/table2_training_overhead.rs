//! Table 2 — ML training components during in situ training, averaged
//! across ranks: total training, client initialization, metadata transfer,
//! training data retrieve.
//!
//! Paper numbers (160 GPUs, 500 epochs): total 332.7s, client init 0.002s,
//! metadata 14.8s (4.4%, dominated by waiting for the first snapshot),
//! retrieve 4.5s (~1%).  The claim under test is the overhead *fractions*.

use situ::orchestrator::driver::{run_insitu_training, InSituTrainingConfig};

fn main() {
    let artifacts = situ::db::server::artifacts_dir();
    if !artifacts.join("manifest.json").exists() {
        println!("table2 SKIPPED: artifacts not built");
        return;
    }
    let cfg = InSituTrainingConfig {
        artifacts_dir: artifacts,
        grid: (16, 12, 10),
        nu: 2e-3,
        sim_ranks: 4,
        ml_ranks: 2,
        epochs: 25,
        snapshot_every: 2,
        solver_steps: 60,
        seed: 1,
        ..Default::default()
    };
    let report = run_insitu_training(&cfg).expect("in situ run");
    report.trainer_table.print();

    // Overhead fractions relative to total training time.
    let md = report.trainer_table.render_csv();
    let mut comp = std::collections::BTreeMap::new();
    for line in md.lines().skip(2) {
        let cells: Vec<&str> = line.split(',').collect();
        if cells.len() >= 4 {
            let mean: f64 = cells[1].parse().unwrap_or(0.0);
            let count: f64 = cells[3].parse().unwrap_or(0.0);
            comp.insert(cells[0].to_string(), mean * count);
        }
    }
    let total = comp.get("total_training").copied().unwrap_or(0.0);
    if total > 0.0 {
        for key in ["client_init", "metadata", "retrieve"] {
            let frac = comp.get(key).copied().unwrap_or(0.0) / total;
            println!("  {key}: {:.2}% of total training (paper: ~1-4%)", frac * 100.0);
        }
        let retr_frac = comp.get("retrieve").copied().unwrap_or(0.0) / total;
        assert!(retr_frac < 0.30, "retrieve overhead too large: {retr_frac:.3}");
    }
    println!("table2 OK");
}
