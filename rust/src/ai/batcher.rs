//! Server-side adaptive micro-batching for `run_model`.
//!
//! Concurrent requests for the same `(key, version, device)` lane coalesce
//! into one stacked backend execution.  The window adapts to arrival rate:
//! a request arriving after an idle gap passes straight through (no added
//! latency at low concurrency), while a request arriving hot on the heels
//! of another — within [`ADAPT_ARRIVAL`] — elects a leader that holds the
//! lane open for the configured window (or until [`BatcherConfig::max_batch`]
//! entries queue) before executing everything at once.
//!
//! The lane key pins the *resolved* version, so a batch is structurally
//! incapable of mixing versions: a hot-swap mid-storm splits traffic into
//! an old-version lane (draining) and a new-version lane (filling), and
//! each executes under its own `Arc<ModelVersion>`.
//!
//! Leader/follower protocol: every request enqueues an entry carrying its
//! reply channel.  The first arrival on an idle lane becomes leader,
//! optionally waits out the window on the lane condvar, then takes the
//! whole queue and runs the caller-supplied execution closure; followers
//! just block on their reply channel.  Per-entry errors mirror
//! `Request::Batch` semantics — one bad request never poisons its
//! batchmates.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::tensor::Tensor;

/// Two arrivals closer than this are treated as a burst worth batching.
pub const ADAPT_ARRIVAL: Duration = Duration::from_millis(2);

/// Environment override for the batching window in microseconds;
/// `SITU_BATCH_WINDOW_US=0` disables coalescing entirely (the unbatched
/// baseline in `fig_serving`).
pub const WINDOW_ENV: &str = "SITU_BATCH_WINDOW_US";

#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// How long a leader holds a bursting lane open.
    pub window: Duration,
    /// Execute immediately once this many entries queue.
    pub max_batch: usize,
    /// Inter-arrival gap below which the lane counts as bursting.
    pub adapt_arrival: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            window: Duration::from_micros(500),
            max_batch: 32,
            adapt_arrival: ADAPT_ARRIVAL,
        }
    }
}

impl BatcherConfig {
    /// Default config with the `SITU_BATCH_WINDOW_US` override applied.
    pub fn from_env() -> BatcherConfig {
        let mut cfg = BatcherConfig::default();
        if let Ok(v) = std::env::var(WINDOW_ENV) {
            if let Ok(us) = v.trim().parse::<u64>() {
                cfg.window = Duration::from_micros(us);
            }
        }
        cfg
    }
}

/// Lane identity: `(model key, resolved version, device byte)`.
pub type LaneKey = (String, u64, u8);

/// One queued request: its gathered inputs and where the de-stacked
/// result goes.
pub struct BatchEntry {
    pub inputs: Vec<Tensor>,
    reply: mpsc::Sender<Result<Vec<Tensor>>>,
}

impl BatchEntry {
    /// Deliver this entry's outputs (or its own error).
    pub fn respond(self, r: Result<Vec<Tensor>>) {
        let _ = self.reply.send(r);
    }
}

struct LaneState {
    pending: Vec<BatchEntry>,
    leader_active: bool,
    last_arrival: Option<Instant>,
}

struct Lane {
    m: Mutex<LaneState>,
    cv: Condvar,
}

/// The batcher: one lane per `(key, version, device)` in flight.
pub struct Batcher {
    cfg: BatcherConfig,
    lanes: Mutex<HashMap<LaneKey, Arc<Lane>>>,
    /// Stacked executions that actually coalesced (≥ 2 requests).
    pub batches: AtomicU64,
    /// Requests served through such coalesced executions.
    pub batched_requests: AtomicU64,
}

impl Batcher {
    pub fn new(cfg: BatcherConfig) -> Batcher {
        Batcher {
            cfg,
            lanes: Mutex::new(HashMap::new()),
            batches: AtomicU64::new(0),
            batched_requests: AtomicU64::new(0),
        }
    }

    pub fn window(&self) -> Duration {
        self.cfg.window
    }

    fn lane(&self, key: &LaneKey) -> Arc<Lane> {
        let mut lanes = self.lanes.lock().unwrap();
        lanes
            .entry(key.clone())
            .or_insert_with(|| {
                Arc::new(Lane {
                    m: Mutex::new(LaneState {
                        pending: Vec::new(),
                        leader_active: false,
                        last_arrival: None,
                    }),
                    cv: Condvar::new(),
                })
            })
            .clone()
    }

    /// Submit one request to its lane and block until its outputs arrive.
    ///
    /// `run` executes a collected batch; only the elected leader's closure
    /// runs, and it must `respond` to every entry exactly once.  Callers
    /// validate everything request-specific (device range, gathered
    /// inputs) *before* submitting so the closure is infallible per lane.
    pub fn submit(
        &self,
        lane_key: LaneKey,
        inputs: Vec<Tensor>,
        run: impl FnOnce(Vec<BatchEntry>),
    ) -> Result<Vec<Tensor>> {
        let lane = self.lane(&lane_key);
        let (tx, rx) = mpsc::channel();
        let leads = {
            let mut st = lane.m.lock().unwrap();
            let now = Instant::now();
            let burst = st
                .last_arrival
                .map(|t| now.saturating_duration_since(t) <= self.cfg.adapt_arrival)
                .unwrap_or(false);
            st.last_arrival = Some(now);
            st.pending.push(BatchEntry { inputs, reply: tx });
            if st.leader_active {
                if st.pending.len() >= self.cfg.max_batch {
                    lane.cv.notify_all();
                }
                None
            } else {
                st.leader_active = true;
                Some(burst)
            }
        };

        if let Some(burst) = leads {
            let wait =
                if burst && !self.cfg.window.is_zero() { self.cfg.window } else { Duration::ZERO };
            let batch = {
                let mut st = lane.m.lock().unwrap();
                if !wait.is_zero() {
                    let deadline = Instant::now() + wait;
                    while st.pending.len() < self.cfg.max_batch {
                        let now = Instant::now();
                        if now >= deadline {
                            break;
                        }
                        let (g, _) = lane.cv.wait_timeout(st, deadline - now).unwrap();
                        st = g;
                    }
                }
                st.leader_active = false;
                std::mem::take(&mut st.pending)
            };
            if batch.len() > 1 {
                self.batches.fetch_add(1, Ordering::Relaxed);
                self.batched_requests.fetch_add(batch.len() as u64, Ordering::Relaxed);
            }
            run(batch);
        }

        rx.recv()
            .map_err(|_| Error::Invalid("batch leader dropped a reply".into()))?
    }
}

/// Duplicate an error for fan-out to every entry of a failed batch,
/// preserving the variants whose rendering is load-bearing on the wire
/// (`busy: `, `model not found: `, ...).
pub fn clone_err(e: &Error) -> Error {
    match e {
        Error::Protocol(s) => Error::Protocol(s.clone()),
        Error::KeyNotFound(s) => Error::KeyNotFound(s.clone()),
        Error::ModelNotFound(s) => Error::ModelNotFound(s.clone()),
        Error::Shape(s) => Error::Shape(s.clone()),
        Error::Xla(s) => Error::Xla(s.clone()),
        Error::Parse(s) => Error::Parse(s.clone()),
        Error::Remote(s) => Error::Remote(s.clone()),
        Error::Invalid(s) => Error::Invalid(s.clone()),
        Error::Timeout(s) => Error::Timeout(s.clone()),
        Error::Busy(s) => Error::Busy(s.clone()),
        Error::Io(e) => Error::Remote(format!("io error: {e}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    fn t(v: f32) -> Tensor {
        Tensor::scalar_f32(v)
    }

    #[test]
    fn single_request_passes_through() {
        let b = Batcher::new(BatcherConfig::default());
        let out = b
            .submit(("m".into(), 1, 0xff), vec![t(2.0)], |batch| {
                assert_eq!(batch.len(), 1);
                for e in batch {
                    let r = e.inputs.clone();
                    e.respond(Ok(r));
                }
            })
            .unwrap();
        assert_eq!(out[0].first_f32().unwrap(), 2.0);
        assert_eq!(b.batches.load(Ordering::Relaxed), 0, "lone request is not a batch");
    }

    #[test]
    fn burst_coalesces_into_one_execution() {
        // A huge adapt_arrival makes every post-prime arrival a burst, so
        // the test exercises the coalescing path deterministically.
        let b = Arc::new(Batcher::new(BatcherConfig {
            window: Duration::from_millis(100),
            max_batch: 32,
            adapt_arrival: Duration::from_secs(60),
        }));
        let executions = Arc::new(AtomicUsize::new(0));
        // Prime the arrival clock so the storm below counts as a burst.
        b.submit(("m".into(), 1, 0), vec![t(0.0)], |batch| {
            for e in batch {
                e.respond(Ok(vec![]));
            }
        })
        .unwrap();

        let n = 8;
        let barrier = Arc::new(std::sync::Barrier::new(n));
        let mut handles = Vec::new();
        for i in 0..n {
            let b = b.clone();
            let executions = executions.clone();
            let barrier = barrier.clone();
            handles.push(std::thread::spawn(move || {
                barrier.wait();
                b.submit(("m".into(), 1, 0), vec![t(i as f32)], |batch| {
                    executions.fetch_add(1, Ordering::Relaxed);
                    for e in batch {
                        let r = e.inputs.clone();
                        e.respond(Ok(r));
                    }
                })
                .unwrap()
            }));
        }
        let mut seen = Vec::new();
        for h in handles {
            let out = h.join().unwrap();
            seen.push(out[0].first_f32().unwrap());
        }
        seen.sort_by(f32::total_cmp);
        assert_eq!(seen, (0..n).map(|i| i as f32).collect::<Vec<_>>());
        let execs = executions.load(Ordering::Relaxed);
        assert!(execs < n, "storm of {n} must coalesce, got {execs} executions");
        assert!(b.batched_requests.load(Ordering::Relaxed) >= 2);
    }

    #[test]
    fn max_batch_releases_leader_early() {
        let b = Arc::new(Batcher::new(BatcherConfig {
            window: Duration::from_secs(30), // far beyond test patience
            max_batch: 4,
            adapt_arrival: Duration::from_secs(60), // every arrival bursts
        }));
        // Prime the burst detector.
        b.submit(("m".into(), 2, 1), vec![t(-1.0)], |batch| {
            for e in batch {
                e.respond(Ok(vec![]));
            }
        })
        .unwrap();
        let start = Instant::now();
        let mut handles = Vec::new();
        for i in 0..4 {
            let b = b.clone();
            handles.push(std::thread::spawn(move || {
                b.submit(("m".into(), 2, 1), vec![t(i as f32)], |batch| {
                    for e in batch {
                        let r = e.inputs.clone();
                        e.respond(Ok(r));
                    }
                })
                .unwrap()
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert!(
            start.elapsed() < Duration::from_secs(10),
            "max_batch must release the 30 s window early"
        );
    }

    #[test]
    fn lanes_are_isolated_and_errors_per_entry() {
        let b = Batcher::new(BatcherConfig::default());
        let err = b
            .submit(("m".into(), 1, 0xff), vec![t(1.0)], |batch| {
                for e in batch {
                    e.respond(Err(Error::ModelNotFound("m".into())));
                }
            })
            .unwrap_err();
        assert!(err.to_string().contains("model not found"));
        // Distinct version → distinct lane: a fresh submit still works.
        let ok = b
            .submit(("m".into(), 2, 0xff), vec![t(1.0)], |batch| {
                for e in batch {
                    e.respond(Ok(vec![t(9.0)]));
                }
            })
            .unwrap();
        assert_eq!(ok[0].first_f32().unwrap(), 9.0);
    }

    #[test]
    fn clone_err_preserves_load_bearing_variants() {
        let b = clone_err(&Error::Busy("cap".into()));
        assert!(b.to_string().starts_with("busy: "));
        let m = clone_err(&Error::ModelNotFound("k".into()));
        assert!(matches!(m, Error::ModelNotFound(_)));
        let io = clone_err(&Error::Io(std::io::Error::new(std::io::ErrorKind::Other, "x")));
        assert!(matches!(io, Error::Remote(_)));
    }
}
