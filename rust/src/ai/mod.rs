//! RedisAI analogue: the model registry and in-database model execution.
//!
//! The paper's in situ inference flow (Fig 1b) is three client calls:
//! `put_tensor(input)` → `run_model(key, in, out, device)` →
//! `unpack_tensor(output)`.  The model itself lives *inside* the database
//! process and executes on a node-local device pool (Polaris: 4 A100s, with
//! 6 simulation ranks pinned per GPU).  Here the registry compiles uploaded
//! HLO-text artifacts through the PJRT [`crate::runtime::Executor`] and the
//! device pool tracks per-slot queueing exactly like RedisAI's GPU contexts.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::db::Store;
use crate::error::{Error, Result};
use crate::proto::Device;
use crate::runtime::Executor;
use crate::telemetry::StatAccum;

/// Number of GPU slots per node (Polaris nodes carry 4 A100s).
pub const GPUS_PER_NODE: usize = 4;

/// Per-device execution statistics.
#[derive(Debug, Default)]
pub struct DeviceStats {
    pub executions: AtomicU64,
    pub eval: Mutex<StatAccum>,
    pub queue_wait: Mutex<StatAccum>,
}

/// Model registry + device pool living inside one DB server.
pub struct ModelRuntime {
    exec: Executor,
    /// One lock per GPU slot; executions targeting a slot serialize on it,
    /// reproducing RedisAI's per-device run queue.
    gpu_slots: Vec<Arc<Mutex<()>>>,
    pub cpu_stats: DeviceStats,
    pub gpu_stats: Vec<DeviceStats>,
    models: Mutex<Vec<String>>,
}

impl ModelRuntime {
    pub fn new(exec: Executor) -> ModelRuntime {
        ModelRuntime {
            exec,
            gpu_slots: (0..GPUS_PER_NODE).map(|_| Arc::new(Mutex::new(()))).collect(),
            cpu_stats: DeviceStats::default(),
            gpu_stats: (0..GPUS_PER_NODE).map(|_| DeviceStats::default()).collect(),
            models: Mutex::new(Vec::new()),
        }
    }

    /// Upload + compile a model from HLO text (the `AI.MODELSET` analogue).
    pub fn put_model(&self, key: &str, hlo_text: &str) -> Result<()> {
        self.exec.load_hlo_text(key, hlo_text)?;
        let mut m = self.models.lock().unwrap();
        if !m.iter().any(|k| k == key) {
            m.push(key.to_string());
        }
        Ok(())
    }

    /// Load + compile a model from an artifact file (driver-side upload).
    pub fn put_model_from_file(&self, key: &str, path: &std::path::Path) -> Result<()> {
        self.exec.load_artifact(key, path)?;
        let mut m = self.models.lock().unwrap();
        if !m.iter().any(|k| k == key) {
            m.push(key.to_string());
        }
        Ok(())
    }

    pub fn n_models(&self) -> u64 {
        self.models.lock().unwrap().len() as u64
    }

    pub fn has_model(&self, key: &str) -> bool {
        self.models.lock().unwrap().iter().any(|k| k == key)
    }

    /// The `AI.MODELRUN` analogue: gather inputs from the store, execute on
    /// the requested device slot, scatter outputs back into the store.
    ///
    /// The gather is zero-copy: each input is a refcount clone of the
    /// stored payload, so model I/O never duplicates tensors in host
    /// memory before they reach the PJRT literal conversion.
    pub fn run_model(
        &self,
        store: &Store,
        key: &str,
        in_keys: &[String],
        out_keys: &[String],
        device: Device,
    ) -> Result<()> {
        if !self.has_model(key) {
            return Err(Error::ModelNotFound(key.to_string()));
        }
        let inputs = in_keys
            .iter()
            .map(|k| store.get_tensor(k))
            .collect::<Result<Vec<_>>>()?;

        let (stats, _slot_guard) = match device {
            Device::Cpu => (&self.cpu_stats, None),
            Device::Gpu(i) => {
                let i = i as usize;
                if i >= self.gpu_slots.len() {
                    return Err(Error::Invalid(format!("gpu slot {i} out of range")));
                }
                let qw = crate::telemetry::Stopwatch::start();
                let guard = self.gpu_slots[i].lock().unwrap();
                self.gpu_stats[i]
                    .queue_wait
                    .lock()
                    .unwrap()
                    .add(qw.stop());
                (&self.gpu_stats[i], Some(guard))
            }
        };

        let sw = crate::telemetry::Stopwatch::start();
        let outputs = self.exec.execute(key, inputs)?;
        stats.eval.lock().unwrap().add(sw.stop());
        stats.executions.fetch_add(1, Ordering::Relaxed);

        if outputs.len() != out_keys.len() {
            return Err(Error::Shape(format!(
                "model '{key}' produced {} outputs, client named {}",
                outputs.len(),
                out_keys.len()
            )));
        }
        for (k, t) in out_keys.iter().zip(outputs) {
            store.put_tensor(k, t)?;
        }
        Ok(())
    }

    /// Round-robin device assignment used by clients: the paper pins 6
    /// simulation ranks to each of the 4 GPUs.
    pub fn device_for_rank(rank: usize) -> Device {
        Device::Gpu((rank % GPUS_PER_NODE) as u8)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn device_pinning_balances() {
        let mut counts = [0usize; GPUS_PER_NODE];
        for r in 0..24 {
            match ModelRuntime::device_for_rank(r) {
                Device::Gpu(i) => counts[i as usize] += 1,
                Device::Cpu => panic!("rank must map to a gpu"),
            }
        }
        assert_eq!(counts, [6, 6, 6, 6], "paper: 6 clients pinned per GPU");
    }
}
