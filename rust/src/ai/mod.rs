//! RedisAI analogue: the model registry and in-database model execution.
//!
//! The paper's in situ inference flow (Fig 1b) is three client calls:
//! `put_tensor(input)` → `run_model(key, in, out, device)` →
//! `unpack_tensor(output)`.  The model itself lives *inside* the database
//! process and executes on a node-local device pool (Polaris: 4 A100s, with
//! 6 simulation ranks pinned per GPU).
//!
//! Serving is three layers:
//!
//! * [`registry::Registry`] — versioned artifacts with an atomically
//!   hot-swapped live pointer per key (`registry.rs`);
//! * [`batcher::Batcher`] — adaptive micro-batching that coalesces
//!   concurrent same-`(key, version, device)` requests into one stacked
//!   backend execution (`batcher.rs`);
//! * the device pool here, which tracks per-slot queueing exactly like
//!   RedisAI's GPU contexts.

pub mod batcher;
pub mod registry;

pub use batcher::{Batcher, BatcherConfig};
pub use registry::{NativeModel, Registry, NATIVE_MAGIC};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

use crate::db::Store;
use crate::error::{Error, Result};
use crate::proto::{Device, ModelDeviceStat, ModelEntry};
use crate::runtime::Executor;
use crate::telemetry::StatAccum;

/// Number of GPU slots per node (Polaris nodes carry 4 A100s).
pub const GPUS_PER_NODE: usize = 4;

/// Per-device execution statistics.
#[derive(Debug, Default)]
pub struct DeviceStats {
    pub executions: AtomicU64,
    pub eval: Mutex<StatAccum>,
    pub queue_wait: Mutex<StatAccum>,
}

/// Lane-key byte for a device (mirrors the wire encoding: `0xff` CPU).
fn device_byte(d: Device) -> u8 {
    match d {
        Device::Cpu => 0xff,
        Device::Gpu(i) => i,
    }
}

/// Model registry + device pool living inside one DB server.
pub struct ModelRuntime {
    exec: Executor,
    registry: Registry,
    batcher: Batcher,
    /// One lock per GPU slot; executions targeting a slot serialize on it,
    /// reproducing RedisAI's per-device run queue.
    gpu_slots: Vec<Arc<Mutex<()>>>,
    pub cpu_stats: DeviceStats,
    pub gpu_stats: Vec<DeviceStats>,
}

impl ModelRuntime {
    pub fn new(exec: Executor) -> ModelRuntime {
        ModelRuntime::with_batcher(exec, BatcherConfig::from_env())
    }

    pub fn with_batcher(exec: Executor, cfg: BatcherConfig) -> ModelRuntime {
        ModelRuntime {
            registry: Registry::new(exec.clone()),
            batcher: Batcher::new(cfg),
            exec,
            gpu_slots: (0..GPUS_PER_NODE).map(|_| Arc::new(Mutex::new(()))).collect(),
            cpu_stats: DeviceStats::default(),
            gpu_stats: (0..GPUS_PER_NODE).map(|_| DeviceStats::default()).collect(),
        }
    }

    /// Upload a model from HLO or native text (the `AI.MODELSET`
    /// analogue).  Re-publishing an existing key hot-swaps the live
    /// pointer.  Returns the published version.
    pub fn put_model(&self, key: &str, text: &str) -> Result<u64> {
        self.registry.publish_text(key, text)
    }

    /// Publish a model from an artifact file (driver-side upload).
    pub fn put_model_from_file(&self, key: &str, path: &std::path::Path) -> Result<u64> {
        self.registry.publish_file(key, path)
    }

    /// Distinct live model keys (not upload attempts).
    pub fn n_models(&self) -> u64 {
        self.registry.n_live()
    }

    pub fn has_model(&self, key: &str) -> bool {
        self.registry.has_model(key)
    }

    /// Total live-pointer swaps (checkpoint republications).
    pub fn swaps(&self) -> u64 {
        self.registry.swaps_total()
    }

    /// Coalesced executions / requests served through them.
    pub fn batch_counters(&self) -> (u64, u64) {
        (
            self.batcher.batches.load(Ordering::Relaxed),
            self.batcher.batched_requests.load(Ordering::Relaxed),
        )
    }

    /// Per-key registry listing (`ListModels`).
    pub fn model_entries(&self) -> Vec<ModelEntry> {
        self.registry.entries()
    }

    /// Per-device stat rows (`ModelStats`): one row per device that has
    /// executed or queued anything.
    pub fn device_stat_rows(&self) -> Vec<ModelDeviceStat> {
        let mut rows = Vec::new();
        let mut push = |device: Device, st: &DeviceStats| {
            let executions = st.executions.load(Ordering::Relaxed);
            let eval = st.eval.lock().unwrap();
            let queue = st.queue_wait.lock().unwrap();
            if executions == 0 && eval.count() == 0 && queue.count() == 0 {
                return;
            }
            rows.push(ModelDeviceStat {
                device,
                executions,
                eval_count: eval.count(),
                eval_mean_s: eval.mean(),
                eval_std_s: eval.std(),
                queue_count: queue.count(),
                queue_mean_s: queue.mean(),
                queue_std_s: queue.std(),
            });
        };
        push(Device::Cpu, &self.cpu_stats);
        for (i, st) in self.gpu_stats.iter().enumerate() {
            push(Device::Gpu(i as u8), st);
        }
        rows
    }

    /// Acquire the device's run slot (queue wait is timed for GPUs) and
    /// return the stats bucket to record into.
    fn slot(&self, device: Device) -> Result<(&DeviceStats, Option<MutexGuard<'_, ()>>)> {
        match device {
            Device::Cpu => Ok((&self.cpu_stats, None)),
            Device::Gpu(i) => {
                let i = i as usize;
                if i >= self.gpu_slots.len() {
                    return Err(Error::Invalid(format!("gpu slot {i} out of range")));
                }
                let qw = crate::telemetry::Stopwatch::start();
                let guard = self.gpu_slots[i].lock().unwrap();
                self.gpu_stats[i].queue_wait.lock().unwrap().add(qw.stop());
                Ok((&self.gpu_stats[i], Some(guard)))
            }
        }
    }

    /// The `AI.MODELRUN` analogue: gather inputs from the store, execute on
    /// the requested device slot, scatter outputs back into the store.
    ///
    /// `version` 0 resolves the live pointer; a nonzero version pins an
    /// exact published checkpoint.  Concurrent calls for the same resolved
    /// `(key, version, device)` coalesce in the micro-batcher; outputs are
    /// de-stacked per request, and a failing entry only fails its own
    /// caller.
    ///
    /// The gather is zero-copy: each input is a refcount clone of the
    /// stored payload, so model I/O never duplicates tensors in host
    /// memory before they reach the backend.
    pub fn run_model(
        &self,
        store: &Store,
        key: &str,
        version: u64,
        in_keys: &[String],
        out_keys: &[String],
        device: Device,
    ) -> Result<()> {
        let model = self.registry.resolve(key, version)?;
        // Everything request-specific fails here, before the request joins
        // a lane: the batch execution closure is then infallible per lane.
        if let Device::Gpu(i) = device {
            if i as usize >= self.gpu_slots.len() {
                return Err(Error::Invalid(format!("gpu slot {i} out of range")));
            }
        }
        let inputs = in_keys
            .iter()
            .map(|k| store.get_tensor(k))
            .collect::<Result<Vec<_>>>()?;

        let lane = (model.key.clone(), model.version, device_byte(device));
        let outputs = self
            .batcher
            .submit(lane, inputs, |batch| self.execute_batch(&model, device, batch))?;

        if outputs.len() != out_keys.len() {
            return Err(Error::Shape(format!(
                "model '{key}' produced {} outputs, client named {}",
                outputs.len(),
                out_keys.len()
            )));
        }
        for (k, t) in out_keys.iter().zip(outputs) {
            store.put_tensor(k, t)?;
        }
        Ok(())
    }

    /// Leader path: run a collected batch under one device-slot hold.
    ///
    /// Stackable models execute once over the concatenated input lists and
    /// the outputs are split back by each entry's input arity; other
    /// backends run per entry while still amortizing the single queue
    /// wait.  Every entry is answered exactly once.
    fn execute_batch(
        &self,
        model: &registry::ModelVersion,
        device: Device,
        batch: Vec<batcher::BatchEntry>,
    ) {
        let (stats, _slot_guard) = match self.slot(device) {
            Ok(x) => x,
            Err(e) => {
                // Unreachable in practice: run_model validates pre-submit.
                for entry in batch {
                    entry.respond(Err(batcher::clone_err(&e)));
                }
                return;
            }
        };
        if model.stackable() && batch.len() > 1 {
            let arities: Vec<usize> = batch.iter().map(|e| e.inputs.len()).collect();
            let stacked: Vec<_> = batch.iter().flat_map(|e| e.inputs.iter().cloned()).collect();
            let sw = crate::telemetry::Stopwatch::start();
            let result = model.execute(&self.exec, stacked);
            stats.eval.lock().unwrap().add(sw.stop());
            stats.executions.fetch_add(1, Ordering::Relaxed);
            match result {
                Ok(outputs) => {
                    let mut rest = outputs;
                    for (entry, arity) in batch.into_iter().zip(arities) {
                        if rest.len() < arity {
                            entry.respond(Err(Error::Shape(
                                "stacked execution returned too few outputs".into(),
                            )));
                            continue;
                        }
                        let tail = rest.split_off(arity);
                        let mine = std::mem::replace(&mut rest, tail);
                        entry.respond(Ok(mine));
                    }
                }
                Err(e) => {
                    for entry in batch {
                        entry.respond(Err(batcher::clone_err(&e)));
                    }
                }
            }
        } else {
            for mut entry in batch {
                let inputs = std::mem::take(&mut entry.inputs);
                let sw = crate::telemetry::Stopwatch::start();
                let result = model.execute(&self.exec, inputs);
                stats.eval.lock().unwrap().add(sw.stop());
                stats.executions.fetch_add(1, Ordering::Relaxed);
                entry.respond(result);
            }
        }
    }

    /// Round-robin device assignment used by clients: the paper pins 6
    /// simulation ranks to each of the 4 GPUs.
    pub fn device_for_rank(rank: usize) -> Device {
        Device::Gpu((rank % GPUS_PER_NODE) as u8)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::Store;
    use crate::tensor::Tensor;

    #[test]
    fn device_pinning_balances() {
        let mut counts = [0usize; GPUS_PER_NODE];
        for r in 0..24 {
            match ModelRuntime::device_for_rank(r) {
                Device::Gpu(i) => counts[i as usize] += 1,
                Device::Cpu => panic!("rank must map to a gpu"),
            }
        }
        assert_eq!(counts, [6, 6, 6, 6], "paper: 6 clients pinned per GPU");
    }

    #[test]
    fn run_model_native_end_to_end() {
        let rt = ModelRuntime::new(Executor::new().unwrap());
        let store = Store::new();
        let v = rt.put_model("scaler", "situ-native v1\naffine 3.0 1.0\n").unwrap();
        assert_eq!(v, 1);
        assert_eq!(rt.n_models(), 1);
        store
            .put_tensor("x", Tensor::from_f32(&[2], vec![1.0, 2.0]).unwrap())
            .unwrap();
        rt.run_model(
            &store,
            "scaler",
            0,
            &["x".into()],
            &["y".into()],
            Device::Gpu(1),
        )
        .unwrap();
        let y = store.get_tensor("y").unwrap();
        assert_eq!(y.to_f32().unwrap(), vec![4.0, 7.0]);

        // Version pinning: an exact version works, a missing one errors.
        rt.run_model(&store, "scaler", 1, &["x".into()], &["y2".into()], Device::Cpu)
            .unwrap();
        let err = rt
            .run_model(&store, "scaler", 9, &["x".into()], &["y3".into()], Device::Cpu)
            .unwrap_err();
        assert!(err.to_string().contains("model not found"));

        // Republish hot-swaps: version 2 becomes live.
        let v2 = rt.put_model("scaler", "situ-native v1\naffine 1.0 -1.0\n").unwrap();
        assert_eq!(v2, 2);
        assert_eq!(rt.swaps(), 1);
        assert_eq!(rt.n_models(), 1, "distinct live keys, not upload attempts");
        rt.run_model(&store, "scaler", 0, &["x".into()], &["z".into()], Device::Gpu(1))
            .unwrap();
        assert_eq!(store.get_tensor("z").unwrap().to_f32().unwrap(), vec![0.0, 1.0]);

        let rows = rt.device_stat_rows();
        assert!(rows.iter().any(|r| r.device == Device::Gpu(1) && r.executions >= 2));
        let entries = rt.model_entries();
        assert_eq!(entries.len(), 1);
        assert!(entries[0].executions >= 4);
    }

    #[test]
    fn run_model_surfaces_request_errors_early() {
        let rt = ModelRuntime::new(Executor::new().unwrap());
        let store = Store::new();
        let err = rt
            .run_model(&store, "ghost", 0, &[], &[], Device::Cpu)
            .unwrap_err();
        assert!(matches!(err, Error::ModelNotFound(_)));
        rt.put_model("m", "situ-native v1\naffine 1.0 0.0\n").unwrap();
        let err = rt
            .run_model(&store, "m", 0, &["missing".into()], &["o".into()], Device::Cpu)
            .unwrap_err();
        assert!(matches!(err, Error::KeyNotFound(_)));
        let err = rt
            .run_model(&store, "m", 0, &[], &[], Device::Gpu(9))
            .unwrap_err();
        assert!(err.to_string().contains("gpu slot 9 out of range"));
    }
}
