//! Versioned model registry with atomic hot-swap.
//!
//! `publish` creates an immutable `(key, version)` artifact and swaps the
//! per-key "live" pointer to it.  Versions are per-key monotonic starting at
//! 1; version 0 on the wire means "whatever is live".  In-flight executions
//! hold an `Arc<ModelVersion>`, so a publish mid-run never tears an ongoing
//! call: requests that resolved version N complete on N while new arrivals
//! pick up N+1.  This is the SmartSim/RedisAI checkpoint-republish flow
//! (`AI.MODELSET` over an existing key) made explicit.
//!
//! Two backends live behind one `ModelVersion`:
//!
//! * **PJRT** — HLO-text artifacts compiled through the
//!   [`crate::runtime::Executor`], cached under `"key@vN"` so distinct
//!   versions never collide in the executor cache.
//! * **Native** — the `situ-native v1` textual format, interpreted in
//!   process.  It exists so serving-path semantics (hot-swap, batching, the
//!   hybrid solver loop) are testable without AOT artifacts on disk.  Two
//!   ops: `affine <scale> <offset>` (elementwise `y = scale*x + offset`,
//!   one output per input, stackable across requests) and
//!   `poisson <nx> <ny> <nz> <tol> <max_iter>` (CG pressure solve on the
//!   channel grid; inputs `[rhs]` or `[rhs, p0]` for a warm start).

use std::collections::{BTreeMap, HashMap};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::error::{Error, Result};
use crate::proto::ModelEntry;
use crate::runtime::Executor;
use crate::sim::cfd::grid::Grid;
use crate::sim::cfd::poisson;
use crate::tensor::{DType, Tensor};

/// Versions kept resolvable per key.  Older versions are pruned from the
/// map (and unloaded from the executor cache) on publish; in-flight `Arc`
/// holders keep a pruned version alive until their call completes.
pub const KEPT_VERSIONS: usize = 4;

/// Magic first line of the in-process interpreted model format.
pub const NATIVE_MAGIC: &str = "situ-native v1";

/// One op of the interpreted backend.
#[derive(Debug, Clone, PartialEq)]
pub enum NativeOp {
    /// Elementwise `y = scale * x + offset` on f32/f64 inputs; one output
    /// per input, so a stacked execution is exact.
    Affine { scale: f64, offset: f64 },
    /// CG solve of `∇²p = rhs` on `Grid::channel(nx, ny, nz)` with a fixed
    /// iteration budget.  Inputs `[rhs]` or `[rhs, p0]` (f64), output `[p]`.
    Poisson { nx: usize, ny: usize, nz: usize, tol: f64, max_iter: usize },
}

/// A parsed `situ-native v1` model.
#[derive(Debug, Clone, PartialEq)]
pub struct NativeModel {
    pub op: NativeOp,
}

impl NativeModel {
    /// Does this text claim to be a native model (vs PJRT HLO text)?
    pub fn is_native(text: &str) -> bool {
        text.trim_start().starts_with(NATIVE_MAGIC)
    }

    pub fn parse(text: &str) -> Result<NativeModel> {
        let mut lines = text
            .lines()
            .map(str::trim)
            .filter(|l| !l.is_empty() && !l.starts_with('#'));
        match lines.next() {
            Some(NATIVE_MAGIC) => {}
            other => {
                return Err(Error::Parse(format!(
                    "native model must start with '{NATIVE_MAGIC}', got {other:?}"
                )))
            }
        }
        let op_line = lines
            .next()
            .ok_or_else(|| Error::Parse("native model has no op line".into()))?;
        if let Some(extra) = lines.next() {
            return Err(Error::Parse(format!("trailing content in native model: '{extra}'")));
        }
        let toks: Vec<&str> = op_line.split_whitespace().collect();
        let op = match toks.as_slice() {
            ["affine", scale, offset] => NativeOp::Affine {
                scale: parse_f64("scale", scale)?,
                offset: parse_f64("offset", offset)?,
            },
            ["poisson", nx, ny, nz, tol, max_iter] => NativeOp::Poisson {
                nx: parse_usize("nx", nx)?,
                ny: parse_usize("ny", ny)?,
                nz: parse_usize("nz", nz)?,
                tol: parse_f64("tol", tol)?,
                max_iter: parse_usize("max_iter", max_iter)?,
            },
            _ => return Err(Error::Parse(format!("unknown native op line '{op_line}'"))),
        };
        Ok(NativeModel { op })
    }

    /// Can concurrent requests be stacked into one execution and split
    /// back exactly?  True when the op is elementwise with one output per
    /// input tensor.
    pub fn stackable(&self) -> bool {
        matches!(self.op, NativeOp::Affine { .. })
    }

    /// Interpret the model: one call, N inputs in, M outputs out.
    pub fn execute(&self, inputs: Vec<Tensor>) -> Result<Vec<Tensor>> {
        match self.op {
            NativeOp::Affine { scale, offset } => {
                if inputs.is_empty() {
                    return Err(Error::Shape("affine wants at least one input".into()));
                }
                inputs
                    .iter()
                    .map(|t| match t.dtype {
                        DType::F32 => {
                            let v: Vec<f32> = t
                                .to_f32()?
                                .into_iter()
                                .map(|x| (scale * x as f64 + offset) as f32)
                                .collect();
                            Tensor::from_f32(&t.shape, v)
                        }
                        DType::F64 => {
                            let v: Vec<f64> =
                                t.to_f64()?.into_iter().map(|x| scale * x + offset).collect();
                            Tensor::from_f64(&t.shape, v)
                        }
                        other => {
                            Err(Error::Shape(format!("affine wants f32/f64 input, got {other}")))
                        }
                    })
                    .collect()
            }
            NativeOp::Poisson { nx, ny, nz, tol, max_iter } => {
                let g = Grid::channel(nx, ny, nz);
                let rhs_t = inputs
                    .first()
                    .ok_or_else(|| Error::Shape("poisson wants [rhs] or [rhs, p0]".into()))?;
                if inputs.len() > 2 {
                    return Err(Error::Shape(format!(
                        "poisson wants 1 or 2 inputs, got {}",
                        inputs.len()
                    )));
                }
                let rhs = rhs_t.to_f64()?;
                if rhs.len() != g.n() {
                    return Err(Error::Shape(format!(
                        "poisson rhs has {} cells, grid {}x{}x{} wants {}",
                        rhs.len(),
                        nx,
                        ny,
                        nz,
                        g.n()
                    )));
                }
                let mut p = match inputs.get(1) {
                    Some(p0_t) => {
                        let p0 = p0_t.to_f64()?;
                        if p0.len() != g.n() {
                            return Err(Error::Shape(format!(
                                "poisson warm start has {} cells, wants {}",
                                p0.len(),
                                g.n()
                            )));
                        }
                        p0
                    }
                    None => g.zeros(),
                };
                let _ = poisson::solve_cg(&g, &rhs, &mut p, tol, max_iter);
                Ok(vec![Tensor::from_f64(&rhs_t.shape, p)?])
            }
        }
    }
}

fn parse_f64(name: &str, s: &str) -> Result<f64> {
    s.parse::<f64>()
        .map_err(|_| Error::Parse(format!("native model: bad {name} '{s}'")))
}

fn parse_usize(name: &str, s: &str) -> Result<usize> {
    s.parse::<usize>()
        .map_err(|_| Error::Parse(format!("native model: bad {name} '{s}'")))
}

/// Where a version's computation actually runs.
enum Backend {
    /// Compiled through PJRT, cached in the executor under `exec_name`.
    Pjrt { exec_name: String },
    /// Interpreted in process.
    Native(NativeModel),
}

/// One immutable published version of a model.
pub struct ModelVersion {
    pub key: String,
    pub version: u64,
    backend: Backend,
    /// Backend executions of this version (a stacked batch counts once).
    pub executions: AtomicU64,
}

impl ModelVersion {
    pub fn stackable(&self) -> bool {
        match &self.backend {
            Backend::Pjrt { .. } => false,
            Backend::Native(m) => m.stackable(),
        }
    }

    /// Run one backend execution.
    pub fn execute(&self, exec: &Executor, inputs: Vec<Tensor>) -> Result<Vec<Tensor>> {
        self.executions.fetch_add(1, Ordering::Relaxed);
        match &self.backend {
            Backend::Pjrt { exec_name } => exec.execute(exec_name, inputs),
            Backend::Native(m) => m.execute(inputs),
        }
    }
}

struct KeyState {
    live: Arc<ModelVersion>,
    versions: BTreeMap<u64, Arc<ModelVersion>>,
    /// Times the live pointer moved off an existing version.
    swaps: u64,
    next_version: u64,
    /// Executions accumulated by versions pruned from `versions`.
    retired_executions: u64,
}

impl KeyState {
    fn executions(&self) -> u64 {
        self.retired_executions
            + self
                .versions
                .values()
                .map(|v| v.executions.load(Ordering::Relaxed))
                .sum::<u64>()
    }
}

/// The registry: per-key version chains plus the live pointer.
pub struct Registry {
    exec: Executor,
    keys: Mutex<HashMap<String, KeyState>>,
}

impl Registry {
    pub fn new(exec: Executor) -> Registry {
        Registry { exec, keys: Mutex::new(HashMap::new()) }
    }

    /// Publish from model text (wire `put_model`).  Returns the version.
    ///
    /// The registry lock is held across compilation, which serializes
    /// publishes per server — checkpoints are seconds apart, and it keeps
    /// version allocation trivially race-free.
    pub fn publish_text(&self, key: &str, text: &str) -> Result<u64> {
        let mut keys = self.keys.lock().unwrap();
        let next = keys.get(key).map(|s| s.next_version).unwrap_or(1);
        let backend = if NativeModel::is_native(text) {
            Backend::Native(NativeModel::parse(text)?)
        } else {
            let exec_name = format!("{key}@v{next}");
            self.exec.load_hlo_text(&exec_name, text)?;
            Backend::Pjrt { exec_name }
        };
        Ok(self.install(&mut keys, key, next, backend))
    }

    /// Publish from an artifact file (driver-side upload).
    pub fn publish_file(&self, key: &str, path: &Path) -> Result<u64> {
        if let Ok(text) = std::fs::read_to_string(path) {
            if NativeModel::is_native(&text) {
                return self.publish_text(key, &text);
            }
        }
        let mut keys = self.keys.lock().unwrap();
        let next = keys.get(key).map(|s| s.next_version).unwrap_or(1);
        let exec_name = format!("{key}@v{next}");
        self.exec.load_artifact(&exec_name, path)?;
        Ok(self.install(&mut keys, key, next, Backend::Pjrt { exec_name }))
    }

    fn install(
        &self,
        keys: &mut HashMap<String, KeyState>,
        key: &str,
        version: u64,
        backend: Backend,
    ) -> u64 {
        let mv = Arc::new(ModelVersion {
            key: key.to_string(),
            version,
            backend,
            executions: AtomicU64::new(0),
        });
        match keys.get_mut(key) {
            Some(st) => {
                st.versions.insert(version, mv.clone());
                st.next_version = version + 1;
                // Atomic hot-swap: replacing the Arc is the entire cutover.
                st.live = mv;
                st.swaps += 1;
                while st.versions.len() > KEPT_VERSIONS {
                    let (&oldest, _) = st.versions.iter().next().unwrap();
                    if let Some(old) = st.versions.remove(&oldest) {
                        st.retired_executions += old.executions.load(Ordering::Relaxed);
                        if let Backend::Pjrt { exec_name } = &old.backend {
                            let _ = self.exec.unload(exec_name);
                        }
                    }
                }
            }
            None => {
                let mut versions = BTreeMap::new();
                versions.insert(version, mv.clone());
                keys.insert(
                    key.to_string(),
                    KeyState {
                        live: mv,
                        versions,
                        swaps: 0,
                        next_version: version + 1,
                        retired_executions: 0,
                    },
                );
            }
        }
        version
    }

    /// Resolve `(key, version)` to an immutable version handle.
    /// Version 0 means "live".
    pub fn resolve(&self, key: &str, version: u64) -> Result<Arc<ModelVersion>> {
        let keys = self.keys.lock().unwrap();
        let st = keys
            .get(key)
            .ok_or_else(|| Error::ModelNotFound(key.to_string()))?;
        if version == 0 {
            return Ok(st.live.clone());
        }
        st.versions
            .get(&version)
            .cloned()
            .ok_or_else(|| Error::ModelNotFound(format!("{key}@v{version}")))
    }

    pub fn has_model(&self, key: &str) -> bool {
        self.keys.lock().unwrap().contains_key(key)
    }

    /// Distinct live keys — what `DbInfo.models` reports.
    pub fn n_live(&self) -> u64 {
        self.keys.lock().unwrap().len() as u64
    }

    /// Total live-pointer swaps across keys.
    pub fn swaps_total(&self) -> u64 {
        self.keys.lock().unwrap().values().map(|s| s.swaps).sum()
    }

    /// Per-key listing for the `ListModels` wire op, sorted by key.
    pub fn entries(&self) -> Vec<ModelEntry> {
        let keys = self.keys.lock().unwrap();
        let mut out: Vec<ModelEntry> = keys
            .iter()
            .map(|(k, st)| ModelEntry {
                key: k.clone(),
                live_version: st.live.version,
                n_versions: st.versions.len() as u64,
                swaps: st.swaps,
                executions: st.executions(),
            })
            .collect();
        out.sort_by(|a, b| a.key.cmp(&b.key));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn affine_text(scale: f64, offset: f64) -> String {
        format!("{NATIVE_MAGIC}\naffine {scale} {offset}\n")
    }

    #[test]
    fn native_parse_accepts_and_rejects() {
        let m = NativeModel::parse("situ-native v1\n# comment\naffine 2.0 -0.5\n").unwrap();
        assert_eq!(m.op, NativeOp::Affine { scale: 2.0, offset: -0.5 });
        assert!(m.stackable());

        let p = NativeModel::parse("situ-native v1\npoisson 8 8 8 1e-8 200\n").unwrap();
        assert!(!p.stackable());

        assert!(NativeModel::parse("HloModule foo").is_err());
        assert!(NativeModel::parse("situ-native v1\n").is_err());
        assert!(NativeModel::parse("situ-native v1\naffine 1.0\n").is_err());
        assert!(NativeModel::parse("situ-native v1\naffine 1.0 2.0\naffine 3.0 4.0\n").is_err());
        assert!(NativeModel::parse("situ-native v1\nwavelet 1 2 3\n").is_err());
        assert!(NativeModel::is_native("  situ-native v1\naffine 1 0"));
        assert!(!NativeModel::is_native("HloModule foo"));
    }

    #[test]
    fn affine_executes_elementwise_both_dtypes() {
        let m = NativeModel::parse(&affine_text(2.0, 1.0)).unwrap();
        let a = Tensor::from_f32(&[3], vec![1.0, 2.0, 3.0]).unwrap();
        let b = Tensor::from_f64(&[2], vec![-1.0, 0.5]).unwrap();
        let out = m.execute(vec![a, b]).unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].to_f32().unwrap(), vec![3.0, 5.0, 7.0]);
        assert_eq!(out[1].to_f64().unwrap(), vec![-1.0, 2.0]);
        assert!(m.execute(vec![]).is_err());
        let bad = Tensor::scalar_i32(1);
        assert!(m.execute(vec![bad]).is_err());
    }

    #[test]
    fn poisson_native_reduces_residual_and_warm_starts() {
        let (nx, ny, nz) = (8, 6, 4);
        let g = Grid::channel(nx, ny, nz);
        let m = NativeModel::parse(&format!(
            "{NATIVE_MAGIC}\npoisson {nx} {ny} {nz} 1e-10 500\n"
        ))
        .unwrap();
        let mut rhs = vec![0.0; g.n()];
        for (i, r) in rhs.iter_mut().enumerate() {
            *r = ((i * 37) % 11) as f64 - 5.0;
        }
        poisson::project_zero_mean(&mut rhs);
        let rhs_t = Tensor::from_f64(&[g.n()], rhs.clone()).unwrap();
        let out = m.execute(vec![rhs_t.clone()]).unwrap();
        assert_eq!(out.len(), 1);
        let p = out[0].to_f64().unwrap();
        let mut lp = g.zeros();
        poisson::apply_laplacian(&g, &p, &mut lp);
        let rn: f64 = lp.iter().zip(&rhs).map(|(a, b)| (a - b) * (a - b)).sum::<f64>().sqrt();
        let bn: f64 = rhs.iter().map(|x| x * x).sum::<f64>().sqrt();
        assert!(rn <= 1e-6 * bn, "residual {rn} vs |b| {bn}");

        // Warm start from the exact answer converges immediately.
        let p_t = Tensor::from_f64(&[g.n()], p).unwrap();
        let again = m.execute(vec![rhs_t, p_t]).unwrap();
        assert_eq!(again.len(), 1);

        // Shape guard: wrong cell count is a shape error.
        let small = Tensor::from_f64(&[4], vec![0.0; 4]).unwrap();
        assert!(m.execute(vec![small]).is_err());
    }

    #[test]
    fn publish_resolves_monotonic_versions_and_swaps() {
        let reg = Registry::new(Executor::new().unwrap());
        assert!(reg.resolve("m", 0).is_err());
        let v1 = reg.publish_text("m", &affine_text(1.0, 1.0)).unwrap();
        let v2 = reg.publish_text("m", &affine_text(1.0, 2.0)).unwrap();
        assert_eq!((v1, v2), (1, 2));
        assert_eq!(reg.resolve("m", 0).unwrap().version, 2);
        assert_eq!(reg.resolve("m", 1).unwrap().version, 1);
        assert!(reg.resolve("m", 3).is_err());
        assert_eq!(reg.n_live(), 1);
        assert_eq!(reg.swaps_total(), 1);

        let e = reg.entries();
        assert_eq!(e.len(), 1);
        assert_eq!(e[0].key, "m");
        assert_eq!(e[0].live_version, 2);
        assert_eq!(e[0].n_versions, 2);
        assert_eq!(e[0].swaps, 1);

        // A bad publish leaves the live version untouched.
        assert!(reg.publish_text("m", "situ-native v1\nbogus\n").is_err());
        assert_eq!(reg.resolve("m", 0).unwrap().version, 2);
    }

    #[test]
    fn pruning_keeps_recent_versions_and_inflight_arcs() {
        let reg = Registry::new(Executor::new().unwrap());
        let held = {
            reg.publish_text("m", &affine_text(1.0, 1.0)).unwrap();
            reg.resolve("m", 1).unwrap()
        };
        held.executions.fetch_add(5, Ordering::Relaxed);
        for k in 2..=(KEPT_VERSIONS as u64 + 2) {
            reg.publish_text("m", &affine_text(1.0, k as f64)).unwrap();
        }
        // v1 pruned from the map, but the held Arc still executes.
        assert!(reg.resolve("m", 1).is_err());
        let exec = Executor::new().unwrap();
        let out = held
            .execute(&exec, vec![Tensor::from_f64(&[1], vec![0.0]).unwrap()])
            .unwrap();
        assert_eq!(out[0].to_f64().unwrap(), vec![1.0]);
        // Retired executions survive in the per-key total.
        let e = reg.entries();
        assert_eq!(e[0].n_versions as usize, KEPT_VERSIONS);
        assert!(e[0].executions >= 5, "retired count lost: {}", e[0].executions);
    }
}
