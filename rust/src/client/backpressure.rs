//! Busy-aware backpressure: retry policies and the adaptive publish
//! governor.
//!
//! A bounded store answers writes it cannot fit with [`Error::Busy`] — a
//! *flow-control signal*, not a failure.  This module turns that signal
//! into producer behavior:
//!
//! * [`RetryPolicy`] decides how a single operation reacts to `Busy`:
//!   surface it immediately, retry with capped exponential backoff a fixed
//!   number of times, or retry until a deadline.  Every variant obeys the
//!   sleep audit: a sleep only ever happens *between* attempts — never
//!   after the final one — and a deadline is a hard bound, so a retrying
//!   producer never spins past server shutdown (a shutdown surfaces as a
//!   non-`Busy` I/O error and stops the loop on the spot).
//! * [`PublishGovernor`] decides how the *publish loop* reacts to
//!   sustained pressure: when a snapshot cannot be placed even after
//!   retries, the governor drops it and doubles its publish stride
//!   (publish every k-th snapshot opportunity), halving the stride back on
//!   success.  Skipping is semantically a *merge*: the solver keeps
//!   integrating, so the next published snapshot carries the latest state
//!   and the skipped intermediates are subsumed by it.  The paper's
//!   premise — in situ transfer must never stall the solver — survives
//!   consumer stalls this way instead of aborting on `Busy`.
//!
//! All skip/retry/drop activity is counted in [`GovernorStats`] and
//! surfaced through the run report and `situ info` tables.

use std::time::{Duration, Instant};

use crate::error::{Error, Result};

/// Which errors a [`RetryPolicy`] run treats as retryable.  Orthogonal to
/// the policy shape (how long and how often to wait), so existing
/// `RetryPolicy` values keep their exact meaning: `run`/`run_with` are the
/// `Busy`-only class.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RetryClass {
    /// Retry only [`Error::Busy`] flow control (the default, and the only
    /// class before replication existed).
    #[default]
    Busy,
    /// Additionally retry transient transport failures
    /// ([`Error::is_transient_io`]) — connection resets, socket-deadline
    /// expiries, refused reconnects.  The class to wrap around replicated
    /// cluster ops, where a retry lands on a healthy replica (or a
    /// reconnected shard) instead of the carcass that just failed.
    BusyOrTransientIo,
}

impl RetryClass {
    fn retryable(&self, e: &Error) -> bool {
        match self {
            RetryClass::Busy => matches!(e, Error::Busy(_)),
            RetryClass::BusyOrTransientIo => matches!(e, Error::Busy(_)) || e.is_transient_io(),
        }
    }
}

/// How an operation reacts to [`Error::Busy`] backpressure.  Non-`Busy`
/// errors always surface immediately — only flow control is retried.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RetryPolicy {
    /// Surface `Busy` to the caller on the first rejection (the pre-PR
    /// behavior; the right choice when a higher layer governs pacing).
    #[default]
    Fail,
    /// Up to `retries` extra attempts with exponential backoff starting at
    /// `initial` and saturating at `cap`.
    Backoff { initial: Duration, cap: Duration, retries: u32 },
    /// Retry with the same backoff shape until `deadline` has elapsed
    /// since the first attempt, then surface `Busy`.  The last sleep is
    /// clamped to the remaining budget, so the loop is bounded by the
    /// deadline — it never sleeps past it and never spins.
    Deadline { initial: Duration, cap: Duration, deadline: Duration },
}

impl RetryPolicy {
    /// Capped exponential backoff with the default 32× interval ceiling.
    pub fn backoff(initial: Duration, retries: u32) -> RetryPolicy {
        RetryPolicy::Backoff { initial, cap: initial.saturating_mul(32), retries }
    }

    /// Deadline-bounded backoff with the default 32× interval ceiling.
    pub fn deadline(initial: Duration, deadline: Duration) -> RetryPolicy {
        RetryPolicy::Deadline { initial, cap: initial.saturating_mul(32), deadline }
    }

    /// Run `op`, retrying `Busy` per the policy.  Returns the final result
    /// and how many retries (sleeps) were taken.
    pub fn run<T>(&self, op: impl FnMut() -> Result<T>) -> (Result<T>, u64) {
        self.run_with(op, std::thread::sleep)
    }

    /// `run` with an injectable sleeper (tests audit the sleep discipline
    /// without wall-clock flakiness).
    pub fn run_with<T>(
        &self,
        op: impl FnMut() -> Result<T>,
        sleep: impl FnMut(Duration),
    ) -> (Result<T>, u64) {
        self.run_with_class(RetryClass::Busy, op, sleep)
    }

    /// Run `op`, retrying errors in `class` per the policy (wall-clock
    /// sleeper).
    pub fn run_class<T>(
        &self,
        class: RetryClass,
        op: impl FnMut() -> Result<T>,
    ) -> (Result<T>, u64) {
        self.run_with_class(class, op, std::thread::sleep)
    }

    /// The general retry loop: `class` picks which errors are retryable,
    /// the policy picks the wait schedule.  Same sleep audit as always —
    /// the decision whether another attempt is allowed happens *before*
    /// sleeping, so no sleep ever follows the final attempt, and deadline
    /// sleeps are clamped to the remaining budget.
    pub fn run_with_class<T>(
        &self,
        class: RetryClass,
        mut op: impl FnMut() -> Result<T>,
        mut sleep: impl FnMut(Duration),
    ) -> (Result<T>, u64) {
        let started = Instant::now();
        let mut interval = match *self {
            RetryPolicy::Fail => Duration::ZERO,
            RetryPolicy::Backoff { initial, .. } | RetryPolicy::Deadline { initial, .. } => {
                initial
            }
        };
        let mut retries = 0u64;
        loop {
            match op() {
                Err(e) if class.retryable(&e) => {
                    let wait = match *self {
                        RetryPolicy::Fail => None,
                        RetryPolicy::Backoff { cap, retries: max, .. } => {
                            (retries < max as u64).then_some(interval.min(cap))
                        }
                        RetryPolicy::Deadline { cap, deadline, .. } => {
                            let remaining = deadline.saturating_sub(started.elapsed());
                            (!remaining.is_zero()).then_some(interval.min(cap).min(remaining))
                        }
                    };
                    match wait {
                        None => return (Err(e), retries),
                        Some(d) => {
                            sleep(d);
                            retries += 1;
                            interval = interval.saturating_mul(2);
                        }
                    }
                }
                other => return (other, retries),
            }
        }
    }
}

/// Producer-side flow-control configuration, threaded `RunConfig` →
/// `DeploymentPlan` → the CFD producer (and exposed as CLI flags).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GovernorConfig {
    /// Per-publish retry discipline for `Busy` rejections.
    pub retry: RetryPolicy,
    /// Ceiling for the adaptive publish stride.  `1` disables skipping: a
    /// publish that stays `Busy` after retries is then a hard error (the
    /// pre-PR behavior).
    pub max_stride: u64,
}

impl Default for GovernorConfig {
    fn default() -> Self {
        GovernorConfig { retry: RetryPolicy::Fail, max_stride: 1 }
    }
}

/// Counters the governor accumulates (reported in the run report and the
/// backpressure telemetry table).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct GovernorStats {
    /// Snapshots successfully placed in the store.
    pub published: u64,
    /// Snapshot opportunities skipped by the adaptive stride.
    pub skipped: u64,
    /// `Busy` retries taken across all publishes.
    pub busy_retries: u64,
    /// Snapshots dropped after retry exhaustion (stride then doubled).
    pub dropped: u64,
}

/// Adaptive publish governor: multiplicative-increase of the publish
/// stride on sustained `Busy`, multiplicative-decrease back toward 1 on
/// success.
pub struct PublishGovernor {
    cfg: GovernorConfig,
    stride: u64,
    /// Snapshot opportunities seen since the last publish attempt.
    since_attempt: u64,
    stats: GovernorStats,
}

impl PublishGovernor {
    pub fn new(cfg: GovernorConfig) -> PublishGovernor {
        PublishGovernor {
            cfg: GovernorConfig { max_stride: cfg.max_stride.max(1), ..cfg },
            stride: 1,
            since_attempt: 0,
            stats: GovernorStats::default(),
        }
    }

    /// Call once per snapshot opportunity.  `false` means this snapshot is
    /// skipped under the current stride (counted); the caller publishes
    /// only on `true`.
    pub fn should_publish(&mut self) -> bool {
        self.since_attempt += 1;
        if self.since_attempt >= self.stride {
            true
        } else {
            self.stats.skipped += 1;
            false
        }
    }

    /// Run a publish closure under the retry policy, adapting the stride.
    ///
    /// * `Ok(Some(v))` — published; stride decays toward 1.
    /// * `Ok(None)` — dropped under sustained pressure (stride doubled up
    ///   to `max_stride`); the run continues.  Only possible when
    ///   `max_stride > 1`.
    /// * `Err(Busy)` — retry exhausted and skipping is disabled.
    /// * `Err(other)` — real failure (I/O, shutdown, …), surfaced as-is.
    pub fn publish<T>(&mut self, op: impl FnMut() -> Result<T>) -> Result<Option<T>> {
        self.since_attempt = 0;
        let (res, retries) = self.cfg.retry.run(op);
        self.stats.busy_retries += retries;
        match res {
            Ok(v) => {
                self.stats.published += 1;
                self.stride = (self.stride / 2).max(1);
                Ok(Some(v))
            }
            Err(Error::Busy(m)) => {
                if self.cfg.max_stride > 1 {
                    self.stats.dropped += 1;
                    self.stride = (self.stride * 2).clamp(2, self.cfg.max_stride);
                    Ok(None)
                } else {
                    Err(Error::Busy(m))
                }
            }
            Err(e) => Err(e),
        }
    }

    /// Current publish stride (1 = every snapshot opportunity).
    pub fn stride(&self) -> u64 {
        self.stride
    }

    pub fn stats(&self) -> GovernorStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::cell::RefCell;

    fn busy() -> Error {
        Error::Busy("full".into())
    }

    /// Run a policy against an op failing `fail_n` times, recording sleeps.
    fn drive(policy: RetryPolicy, fail_n: u64) -> (Result<u64>, u64, Vec<Duration>) {
        let sleeps = RefCell::new(Vec::new());
        let mut calls = 0u64;
        let (res, retries) = policy.run_with(
            || {
                calls += 1;
                if calls <= fail_n {
                    Err(busy())
                } else {
                    Ok(calls)
                }
            },
            |d| sleeps.borrow_mut().push(d),
        );
        let sleeps = sleeps.into_inner();
        (res, retries, sleeps)
    }

    #[test]
    fn fail_policy_never_sleeps() {
        let (res, retries, sleeps) = drive(RetryPolicy::Fail, 1);
        assert!(matches!(res, Err(Error::Busy(_))));
        assert_eq!(retries, 0);
        assert!(sleeps.is_empty(), "Fail must not sleep at all");
    }

    #[test]
    fn backoff_retries_then_succeeds_with_exponential_sleeps() {
        let policy = RetryPolicy::Backoff {
            initial: Duration::from_millis(10),
            cap: Duration::from_millis(40),
            retries: 5,
        };
        let (res, retries, sleeps) = drive(policy, 3);
        assert_eq!(res.unwrap(), 4, "succeeds on the 4th attempt");
        assert_eq!(retries, 3);
        assert_eq!(
            sleeps,
            vec![
                Duration::from_millis(10),
                Duration::from_millis(20),
                Duration::from_millis(40)
            ],
            "doubling, saturating at the cap"
        );
    }

    #[test]
    fn backoff_never_sleeps_after_the_final_attempt() {
        // 2 retries = 3 attempts total; all Busy.  Exactly 2 sleeps — one
        // per *inter-attempt* gap, none trailing the final failure.
        let policy = RetryPolicy::Backoff {
            initial: Duration::from_millis(5),
            cap: Duration::from_millis(80),
            retries: 2,
        };
        let (res, retries, sleeps) = drive(policy, u64::MAX);
        assert!(matches!(res, Err(Error::Busy(_))));
        assert_eq!(retries, 2);
        assert_eq!(sleeps.len(), 2, "no sleep after the last attempt");
    }

    #[test]
    fn deadline_policy_is_bounded_and_clamps_the_last_sleep() {
        // A zero deadline means exactly one attempt and zero sleeps.
        let policy = RetryPolicy::Deadline {
            initial: Duration::from_millis(5),
            cap: Duration::from_millis(80),
            deadline: Duration::ZERO,
        };
        let (res, retries, sleeps) = drive(policy, u64::MAX);
        assert!(matches!(res, Err(Error::Busy(_))));
        assert_eq!(retries, 0);
        assert!(sleeps.is_empty());

        // A real deadline: every recorded sleep fits inside the budget (the
        // remaining-time clamp), and the loop terminates.
        let deadline = Duration::from_millis(30);
        let policy = RetryPolicy::Deadline {
            initial: Duration::from_millis(8),
            cap: Duration::from_millis(80),
            deadline,
        };
        let (res, _retries, sleeps) = drive(policy, u64::MAX);
        assert!(matches!(res, Err(Error::Busy(_))));
        assert!(!sleeps.is_empty(), "a live deadline allows retries");
        assert!(sleeps.iter().all(|d| *d <= deadline), "sleeps clamped to the budget");
    }

    #[test]
    fn non_busy_errors_surface_immediately() {
        let policy = RetryPolicy::backoff(Duration::from_millis(5), 10);
        let sleeps = RefCell::new(0usize);
        let (res, retries) = policy.run_with(
            || -> Result<()> { Err(Error::Timeout("server gone".into())) },
            |_| *sleeps.borrow_mut() += 1,
        );
        assert!(matches!(res, Err(Error::Timeout(_))), "shutdown/IO is not retried");
        assert_eq!(retries, 0);
        assert_eq!(*sleeps.borrow(), 0);
    }

    #[test]
    fn transient_io_class_retries_resets_but_not_app_errors() {
        let policy = RetryPolicy::backoff(Duration::from_millis(1), 4);
        let reset =
            || Error::Io(std::io::Error::new(std::io::ErrorKind::ConnectionReset, "gone"));

        // Busy-only class: an I/O reset surfaces immediately.
        let sleeps = RefCell::new(Vec::new());
        let (res, retries) = policy.run_with_class(
            RetryClass::Busy,
            || -> Result<()> { Err(reset()) },
            |d| sleeps.borrow_mut().push(d),
        );
        assert!(matches!(res, Err(Error::Io(_))));
        assert_eq!((retries, sleeps.borrow().len()), (0, 0));

        // Transient class: the reset is retried and the op can recover.
        let mut calls = 0u64;
        let (res, retries) = policy.run_with_class(
            RetryClass::BusyOrTransientIo,
            || {
                calls += 1;
                if calls <= 2 {
                    Err(reset())
                } else {
                    Ok(calls)
                }
            },
            |_| {},
        );
        assert_eq!(res.unwrap(), 3);
        assert_eq!(retries, 2);

        // ... but authoritative answers still surface on the spot.
        let (res, retries) = policy.run_with_class(
            RetryClass::BusyOrTransientIo,
            || -> Result<()> { Err(Error::KeyNotFound("k".into())) },
            |_| {},
        );
        assert!(matches!(res, Err(Error::KeyNotFound(_))));
        assert_eq!(retries, 0);
    }

    #[test]
    fn transient_io_class_still_honors_the_sleep_audit() {
        let policy = RetryPolicy::Backoff {
            initial: Duration::from_millis(5),
            cap: Duration::from_millis(80),
            retries: 2,
        };
        let sleeps = RefCell::new(Vec::new());
        let (res, retries) = policy.run_with_class(
            RetryClass::BusyOrTransientIo,
            || -> Result<()> {
                Err(Error::Io(std::io::Error::new(std::io::ErrorKind::BrokenPipe, "x")))
            },
            |d| sleeps.borrow_mut().push(d),
        );
        assert!(matches!(res, Err(Error::Io(_))));
        assert_eq!(retries, 2);
        assert_eq!(sleeps.borrow().len(), 2, "no sleep after the final attempt");
    }

    #[test]
    fn governor_skips_under_pressure_and_recovers() {
        let mut gov = PublishGovernor::new(GovernorConfig {
            retry: RetryPolicy::Fail,
            max_stride: 8,
        });
        assert!(gov.should_publish(), "stride starts at 1");
        // Sustained pressure: drops double the stride.
        assert!(gov.publish(|| -> Result<()> { Err(busy()) }).unwrap().is_none());
        assert_eq!(gov.stride(), 2);
        assert!(!gov.should_publish(), "one skip under stride 2");
        assert!(gov.should_publish());
        assert!(gov.publish(|| -> Result<()> { Err(busy()) }).unwrap().is_none());
        assert_eq!(gov.stride(), 4);
        assert!(gov.publish(|| -> Result<()> { Err(busy()) }).unwrap().is_none());
        assert!(gov.publish(|| -> Result<()> { Err(busy()) }).unwrap().is_none());
        assert_eq!(gov.stride(), 8, "stride saturates at max_stride");
        // Relief: successes halve the stride back down to 1.
        assert_eq!(gov.publish(|| Ok(1)).unwrap(), Some(1));
        assert_eq!(gov.stride(), 4);
        assert_eq!(gov.publish(|| Ok(2)).unwrap(), Some(2));
        assert_eq!(gov.publish(|| Ok(3)).unwrap(), Some(3));
        assert_eq!(gov.stride(), 1);
        let stats = gov.stats();
        assert_eq!(stats.published, 3);
        assert_eq!(stats.dropped, 4);
        assert_eq!(stats.skipped, 1);
    }

    #[test]
    fn governor_with_stride_one_surfaces_busy() {
        let mut gov = PublishGovernor::new(GovernorConfig::default());
        let err = gov.publish(|| -> Result<()> { Err(busy()) }).unwrap_err();
        assert!(matches!(err, Error::Busy(_)), "max_stride 1 keeps Busy fatal");
        assert_eq!(gov.stats().dropped, 0);
    }

    #[test]
    fn governor_counts_retries() {
        let mut gov = PublishGovernor::new(GovernorConfig {
            retry: RetryPolicy::Backoff {
                initial: Duration::from_micros(1),
                cap: Duration::from_micros(2),
                retries: 3,
            },
            max_stride: 4,
        });
        let mut calls = 0;
        let out = gov
            .publish(|| {
                calls += 1;
                if calls < 3 {
                    Err(busy())
                } else {
                    Ok(calls)
                }
            })
            .unwrap();
        assert_eq!(out, Some(3));
        assert_eq!(gov.stats().busy_retries, 2);
    }
}
