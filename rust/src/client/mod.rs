//! SmartRedis-analogue client library.
//!
//! The paper's integration claim is that coupling a simulation to the
//! framework costs *one line per operation*: initialize a client, send a
//! tensor, retrieve a tensor, run a model.  This module keeps that surface
//! and makes it **deployment-portable**: the [`DataStore`] trait captures
//! the full operation set (tensors, metadata, polling, models, stats), and
//! both [`Client`] (one co-located database) and [`ClusterClient`]
//! (redis-cluster-style hash-slot routing across shards) implement it.
//! Dataloaders, trainers, and examples are written once against the trait
//! and run unchanged on either deployment.
//!
//! ```no_run
//! use situ::client::{Client, DataStore};
//! use situ::tensor::Tensor;
//! let mut c = Client::connect("127.0.0.1:7700".parse().unwrap()).unwrap();
//! c.put_tensor("field_rank0_step2", &Tensor::from_f32(&[4], vec![0.;4]).unwrap()).unwrap();
//! let t = c.get_tensor("field_rank0_step2").unwrap();
//! ```
//!
//! ## Pipelining
//!
//! Per-epoch training overhead is dominated by round trips (paper Table 2:
//! each ML rank fetches 6 tensors per epoch, polling each key first).  Three
//! batched paths collapse those loops to one request frame each:
//!
//! * [`Pipeline`] builds an ordered command batch executed by
//!   [`DataStore::execute`] — one frame out, one [`Response`] per command
//!   back, errors reported per entry;
//! * [`DataStore::mget_tensors`] gathers many tensors in one round trip,
//!   with every payload in the reply aliasing one frame allocation
//!   (zero-copy, as in the single-tensor path);
//! * [`DataStore::poll_keys`] waits **server-side** until all keys exist,
//!   replacing the old client busy-poll of `exists` requests; the probe
//!   interval backs off exponentially from [`PollConfig::initial`] up to
//!   [`PollConfig::cap`].
//!
//! ```no_run
//! use situ::client::{Client, DataStore, Pipeline};
//! use situ::tensor::Tensor;
//! let mut c = Client::connect("127.0.0.1:7700".parse().unwrap()).unwrap();
//! let t = Tensor::from_f32(&[4], vec![0.; 4]).unwrap();
//! let mut pipe = Pipeline::new();
//! pipe.put_tensor("a", &t).put_tensor("b", &t).put_meta("latest_step", "0");
//! for r in c.execute(pipe).unwrap() {
//!     r.expect_ok().unwrap();
//! }
//! ```
//!
//! On a [`ClusterClient`], single-key commands route to the owning shard;
//! a pipeline is partitioned per shard and results are reassembled in
//! submission order.
//!
//! ## Replication and failover
//!
//! [`ClusterClient::connect_with`] takes a [`ClusterConfig`]: with
//! `replicas = r`, every write lands on the owning shard *and* the next
//! `r − 1` shards in ring order (one extra pipelined sub-batch per replica,
//! not N sequential round trips), and reads walk the same ring on a miss or
//! transport error — a dead shard costs a failover, not the run.  Per-shard
//! health is a consecutive-failure circuit breaker with a timed half-open
//! reconnect probe; aggregate operations degrade to partial results plus a
//! per-shard error report ([`ClusterClient::shard_errors`]) instead of
//! failing outright.  What replication actually did is counted in
//! [`FailoverStats`] and folded into the aggregated [`DbInfo`].  The chaos
//! battery drives this path deterministically by planting a seeded
//! [`crate::util::fault::FaultPlan`] under the real sockets.
//!
//! ## Elastic resharding
//!
//! Routing is by an **epoch-versioned slot table**
//! ([`crate::db::cluster::SlotEpoch`]), not a static shard count.  A shard
//! asked for a slot it no longer owns answers `moved: <epoch>`; the client
//! refetches the table ([`ClusterClient::refresh_slot_table`]), adopts the
//! newest epoch, and retries, so a live reshard is invisible to callers.
//! While a slot is mid-migration, reads additionally fall back to the old
//! owner's ring.  See `docs/cluster.md` for the full protocol.

pub mod backpressure;

pub use backpressure::{GovernorConfig, GovernorStats, PublishGovernor, RetryClass, RetryPolicy};

use std::collections::{HashMap, HashSet};
use std::io::BufReader;
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::db::cluster::{hash_slot, SlotEpoch};
use crate::db::store::RetentionConfig;
use crate::error::{Error, Result};
use crate::proto::frame::{
    begin_split_frame, end_split_frame, read_frame_into_tagged, FrameSink, MID_FRAME_TIMEOUT_MSG,
};
use crate::proto::{message, DbInfo, Device, Request, Response};
use crate::tensor::{Bytes, Tensor};
use crate::util::fault::{FaultPlan, FaultStream};

/// Key scheme used across the framework: tensors are unique per rank and
/// step so nothing is overwritten (paper §2.2).  Step keys are what the
/// store's sliding-window retention groups into generations
/// ([`crate::db::store::parse_step_key`]).
pub fn tensor_key(field: &str, rank: usize, step: u64) -> String {
    format!("{field}_rank{rank}_step{step}")
}

/// Key scheme for the paper's *overwrite* publishing mode: each rank
/// republishes its newest snapshot under a stable key, so the previous
/// generation is retired in place and memory is bounded by construction.
pub fn stable_key(field: &str, rank: usize) -> String {
    format!("{field}_rank{rank}_latest")
}

/// Reject oversized batches *before* streaming them: the server's decoder
/// enforces [`crate::proto::MAX_BATCH`] too, but failing client-side avoids
/// shipping a multi-gigabyte frame only to get a decode error back.
fn check_batch_len(n: usize) -> Result<()> {
    if n > crate::proto::MAX_BATCH {
        return Err(Error::Invalid(format!(
            "batch of {n} entries exceeds MAX_BATCH ({})",
            crate::proto::MAX_BATCH
        )));
    }
    Ok(())
}

/// Polling discipline for [`DataStore::poll_key`]/[`DataStore::poll_keys`]:
/// the probe interval starts at `initial` and doubles up to `cap` (the
/// knob that replaced the old fixed busy-poll interval), giving up after
/// `max_wait`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PollConfig {
    /// First probe interval.
    pub initial: Duration,
    /// Ceiling the exponential backoff saturates at.
    pub cap: Duration,
    /// Total wait budget before `Error::Timeout`.
    pub max_wait: Duration,
}

impl Default for PollConfig {
    fn default() -> Self {
        PollConfig {
            initial: Duration::from_micros(500),
            cap: Duration::from_millis(20),
            max_wait: Duration::from_secs(120),
        }
    }
}

impl PollConfig {
    pub fn new(initial: Duration, cap: Duration, max_wait: Duration) -> PollConfig {
        PollConfig { initial, cap, max_wait }
    }

    /// Default backoff shape with a custom total budget.
    pub fn with_max_wait(max_wait: Duration) -> PollConfig {
        PollConfig { max_wait, ..PollConfig::default() }
    }
}

/// An ordered batch of commands executed in one round trip per database
/// instance (see [`DataStore::execute`]).
///
/// Builder methods append one command each and return `&mut Self` so calls
/// chain; tensors are captured by refcount bump ([`Bytes`] payloads), never
/// deep-copied.  On a cluster, only single-key data-plane commands can be
/// pipelined (each entry must route somewhere); whole-database and model
/// commands return `Error::Invalid` there — use the dedicated trait
/// methods, which broadcast/stage correctly, instead.
#[derive(Debug, Default)]
pub struct Pipeline {
    reqs: Vec<Request>,
}

impl Pipeline {
    pub fn new() -> Pipeline {
        Pipeline::default()
    }

    pub fn put_tensor(&mut self, key: &str, t: &Tensor) -> &mut Pipeline {
        self.push(Request::PutTensor { key: key.to_string(), tensor: t.clone() })
    }

    pub fn get_tensor(&mut self, key: &str) -> &mut Pipeline {
        self.push(Request::GetTensor { key: key.to_string() })
    }

    /// Read a retired key back from the spill-to-disk cold tier (replies
    /// `Tensor` or `NotFound`).  Routes like `get_tensor`, so it pipelines
    /// on a cluster — the dataloader's cold fallback batches these.
    pub fn cold_get(&mut self, key: &str) -> &mut Pipeline {
        self.push(Request::ColdGet { key: key.to_string() })
    }

    pub fn del_tensor(&mut self, key: &str) -> &mut Pipeline {
        self.push(Request::DelTensor { key: key.to_string() })
    }

    pub fn exists(&mut self, key: &str) -> &mut Pipeline {
        self.push(Request::Exists { key: key.to_string() })
    }

    pub fn put_meta(&mut self, key: &str, value: &str) -> &mut Pipeline {
        self.push(Request::PutMeta { key: key.to_string(), value: value.to_string() })
    }

    pub fn get_meta(&mut self, key: &str) -> &mut Pipeline {
        self.push(Request::GetMeta { key: key.to_string() })
    }

    /// Publish a model version (replies `Response::Version` — read it with
    /// [`Response::expect_version`]).
    pub fn put_model(&mut self, key: &str, hlo_text: &str) -> &mut Pipeline {
        self.push(Request::PutModel { key: key.to_string(), hlo_text: hlo_text.to_string() })
    }

    /// Run the *live* version of a model (version 0 on the wire).
    pub fn run_model(
        &mut self,
        key: &str,
        in_keys: &[String],
        out_keys: &[String],
        device: Device,
    ) -> &mut Pipeline {
        self.run_model_version(key, 0, in_keys, out_keys, device)
    }

    /// Run a pinned model version (0 = live).
    pub fn run_model_version(
        &mut self,
        key: &str,
        version: u64,
        in_keys: &[String],
        out_keys: &[String],
        device: Device,
    ) -> &mut Pipeline {
        self.push(Request::RunModel {
            key: key.to_string(),
            version,
            in_keys: in_keys.to_vec(),
            out_keys: out_keys.to_vec(),
            device,
        })
    }

    /// Append an already-built request (escape hatch for ops without a
    /// builder method).
    pub fn push(&mut self, req: Request) -> &mut Pipeline {
        self.reqs.push(req);
        self
    }

    pub fn len(&self) -> usize {
        self.reqs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.reqs.is_empty()
    }

    pub fn requests(&self) -> &[Request] {
        &self.reqs
    }

    pub fn into_requests(self) -> Vec<Request> {
        self.reqs
    }
}

/// The full database operation surface, implemented by both [`Client`]
/// (co-located deployment) and [`ClusterClient`] (clustered deployment).
///
/// Code written against `DataStore` — including via `dyn DataStore` — runs
/// on either deployment unchanged; this is the portability SmartSim
/// promises between Fig-2 deployment modes.
pub trait DataStore {
    /// Send a tensor (the paper's `put_tensor`).
    fn put_tensor(&mut self, key: &str, t: &Tensor) -> Result<()>;

    /// `put_tensor` with `Busy`-aware retry per `policy` (see
    /// [`backpressure::RetryPolicy`]): backpressure from a bounded store
    /// is retried with capped backoff, every other error surfaces
    /// immediately.  Returns the number of retries taken.
    fn put_tensor_retry(&mut self, key: &str, t: &Tensor, policy: &RetryPolicy) -> Result<u64> {
        let (res, retries) = policy.run(|| self.put_tensor(key, t));
        res.map(|()| retries)
    }

    /// Retrieve a tensor (the paper's `unpack_tensor`).
    fn get_tensor(&mut self, key: &str) -> Result<Tensor>;

    /// Gather many tensors in one round trip per database instance.
    /// Errors with `Error::KeyNotFound` on the first missing key.
    fn mget_tensors(&mut self, keys: &[String]) -> Result<Vec<Tensor>>;

    /// Delete a tensor; `Ok(false)` if it wasn't present.
    fn del_tensor(&mut self, key: &str) -> Result<bool>;

    /// Delete many tensors in one round trip per database instance
    /// (partitioned per shard on a cluster).  Returns how many were
    /// actually present and deleted.
    fn del_keys(&mut self, keys: &[String]) -> Result<u64>;

    /// Install a retention / capacity policy (broadcast to every shard on
    /// a cluster, so a clustered deployment's byte budget is
    /// `max_bytes × shards`).
    fn set_retention(&mut self, cfg: RetentionConfig) -> Result<()>;

    fn exists(&mut self, key: &str) -> Result<bool>;

    /// Block until `key` exists (the trainer waiting for the first
    /// snapshot — the paper's "metadata transfer" overhead in Table 2).
    fn poll_key(&mut self, key: &str, poll: &PollConfig) -> Result<()> {
        self.poll_keys(std::slice::from_ref(&key.to_string()), poll)
    }

    /// Block until *every* key exists, in one round trip per database
    /// instance: the server waits with capped exponential backoff instead
    /// of the client re-asking per key.
    fn poll_keys(&mut self, keys: &[String], poll: &PollConfig) -> Result<()>;

    fn put_meta(&mut self, key: &str, value: &str) -> Result<()>;

    fn get_meta(&mut self, key: &str) -> Result<Option<String>>;

    /// All tensor keys with a prefix, sorted (merged across shards on a
    /// cluster).
    fn list_keys(&mut self, prefix: &str) -> Result<Vec<String>>;

    /// Keys resident in the spill-to-disk cold tier with a prefix, sorted
    /// (merged across shards on a cluster).  Empty when the server has no
    /// spill directory configured.
    fn cold_list(&mut self, prefix: &str) -> Result<Vec<String>>;

    /// Read a retired key back from the cold tier.  `KeyNotFound` when the
    /// key was never spilled (or spill is off) — strictly the cold tier;
    /// resident keys are served by [`DataStore::get_tensor`].
    fn cold_get(&mut self, key: &str) -> Result<Tensor>;

    /// Publish a model artifact (HLO or `situ-native` text) into the
    /// versioned model registry.  Re-publishing an existing key hot-swaps
    /// the live pointer; in-flight `run_model` calls on the old version
    /// complete untouched.  Returns the published version (per-key
    /// monotonic from 1).
    fn put_model(&mut self, key: &str, hlo_text: &str) -> Result<u64>;

    /// Publish a model from an artifact file.
    fn put_model_from_file(&mut self, key: &str, path: &std::path::Path) -> Result<u64> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Parse(format!("read {}: {e}", path.display())))?;
        self.put_model(key, &text)
    }

    /// RedisAI-style in-database inference over stored tensors, against the
    /// *live* model version.
    fn run_model(
        &mut self,
        key: &str,
        in_keys: &[String],
        out_keys: &[String],
        device: Device,
    ) -> Result<()> {
        self.run_model_version(key, 0, in_keys, out_keys, device)
    }

    /// `run_model` against a pinned version (0 = live).  Concurrent calls
    /// for the same `(key, version, device)` may coalesce into one stacked
    /// server-side execution — per-request semantics are unchanged.
    fn run_model_version(
        &mut self,
        key: &str,
        version: u64,
        in_keys: &[String],
        out_keys: &[String],
        device: Device,
    ) -> Result<()>;

    /// Registry listing: every model key with its live version, version
    /// count, swap count, and executions (merged across shards on a
    /// cluster).
    fn list_models(&mut self) -> Result<Vec<crate::proto::ModelEntry>>;

    /// Per-device serving statistics (executions, eval and queue-wait
    /// moments; merged across shards on a cluster).
    fn model_stats(&mut self) -> Result<Vec<crate::proto::ModelDeviceStat>>;

    /// Database statistics (aggregated across shards on a cluster).
    fn info(&mut self) -> Result<DbInfo>;

    fn flush_all(&mut self) -> Result<()>;

    /// Execute a [`Pipeline`]: one request frame per database instance, one
    /// [`Response`] per command in submission order.  A failing entry
    /// yields `Response::Error` in its slot; later entries still run.
    fn execute(&mut self, pipeline: Pipeline) -> Result<Vec<Response>>;
}

/// Default per-operation socket deadline for [`Client`] connections: long
/// enough for a loaded shard to stream a large reply, short enough that a
/// hung or partitioned one is detected the same run.  Expiry surfaces as a
/// *retryable* I/O error ([`Error::is_transient_io`]), which is what lets
/// the cluster client fail over instead of blocking forever.
pub const DEFAULT_IO_TIMEOUT: Duration = Duration::from_secs(5);

/// A connection to one database instance.
///
/// Besides the strict request/response [`DataStore`] surface, a client can
/// **multiplex**: [`Client::send_tagged`] puts a request on the wire with a
/// unique tag and returns immediately; [`Client::recv_tagged`] collects its
/// reply whenever it arrives, stashing out-of-order replies for their own
/// `recv_tagged` calls.  Many requests can be in flight on one socket —
/// replies pair by tag, not arrival order.
pub struct Client {
    reader: BufReader<FaultStream>,
    writer: FaultStream,
    buf: Vec<u8>,
    pub addr: SocketAddr,
    io_timeout: Option<Duration>,
    /// Last tag handed out by [`Client::send_tagged`] (0 is reserved for
    /// untagged frames and never allocated).
    next_tag: u32,
    /// Tagged replies read off the socket while waiting for a different
    /// tag, held for their `recv_tagged` calls.
    pending: HashMap<u32, Response>,
    /// Tags issued by [`Client::send_tagged`] whose replies have not been
    /// collected yet.  A reply bearing a tag outside this set is a
    /// protocol violation and fails the connection instead of being
    /// stashed forever.
    outstanding: HashSet<u32>,
    /// Tags the owner abandoned ([`Client::forget_tags`]) before
    /// collecting: their replies are still legitimately in flight, so the
    /// read loops drain and drop them on arrival instead of stashing them
    /// until the bounded stash fills and poisons the connection.
    forgotten: HashSet<u32>,
}

/// Cap on out-of-order replies held for later [`Client::recv_tagged`]
/// calls: a misbehaving server cannot grow client memory without bound.
const MAX_STASHED_REPLIES: usize = 4096;

impl Client {
    /// Connect (the paper's `SmartRedis client initialization`, measured at
    /// ~2 ms in Table 1) with the default I/O deadline and no fault shim.
    pub fn connect(addr: SocketAddr) -> Result<Client> {
        Client::connect_with(addr, Some(DEFAULT_IO_TIMEOUT), None)
    }

    /// Connect with an explicit per-operation socket deadline (`None`
    /// blocks forever, the pre-deadline behaviour) and an optional fault
    /// plan whose next connection-schedule this socket will wear.
    ///
    /// After a deadline expires mid-operation the stream may be desynced (a
    /// late reply could still arrive); callers that retry should reconnect
    /// rather than reuse the connection — [`ClusterClient`] does exactly
    /// that via its per-shard health tracking.
    pub fn connect_with(
        addr: SocketAddr,
        io_timeout: Option<Duration>,
        faults: Option<&Arc<FaultPlan>>,
    ) -> Result<Client> {
        let sock = TcpStream::connect(addr)?;
        sock.set_nodelay(true)?;
        sock.set_read_timeout(io_timeout)?;
        sock.set_write_timeout(io_timeout)?;
        let stream = FaultStream::over(sock, faults.map(|p| p.connection()));
        let writer = stream.try_clone()?;
        Ok(Client {
            reader: BufReader::with_capacity(256 * 1024, stream),
            writer,
            buf: Vec::with_capacity(64 * 1024),
            addr,
            io_timeout,
            next_tag: 0,
            pending: HashMap::new(),
            outstanding: HashSet::new(),
            forgotten: HashSet::new(),
        })
    }

    /// Connect with retries (components race the DB at startup).  Sleeps
    /// `delay` between attempts — not after the last failed one.
    pub fn connect_retry(addr: SocketAddr, tries: usize, delay: Duration) -> Result<Client> {
        Client::connect_retry_with(addr, tries, delay, Some(DEFAULT_IO_TIMEOUT), None)
    }

    /// [`Client::connect_retry`] with the deadline and fault knobs of
    /// [`Client::connect_with`].
    pub fn connect_retry_with(
        addr: SocketAddr,
        tries: usize,
        delay: Duration,
        io_timeout: Option<Duration>,
        faults: Option<&Arc<FaultPlan>>,
    ) -> Result<Client> {
        let tries = tries.max(1);
        let mut last = None;
        for attempt in 0..tries {
            match Client::connect_with(addr, io_timeout, faults) {
                Ok(c) => return Ok(c),
                Err(e) => {
                    last = Some(e);
                    if attempt + 1 < tries {
                        std::thread::sleep(delay);
                    }
                }
            }
        }
        Err(last.unwrap_or_else(|| Error::Invalid("connect_retry with 0 tries".into())))
    }

    /// Read one reply frame (tagged or legacy) and decode it sharing the
    /// frame body — a tensor reply's payload (every tensor in a batch
    /// reply) aliases the freshly-read buffer (zero copy).  Returns the
    /// frame's tag (0 for legacy untagged frames) alongside the response.
    fn read_any_reply(&mut self) -> Result<(u32, Response)> {
        let mut body = Vec::new();
        match read_frame_into_tagged(&mut self.reader, &mut body) {
            Ok(Some((tag, _len))) => {
                Ok((tag, Response::decode_shared(&Bytes::from_vec(body))?))
            }
            Ok(None) => Err(Error::Io(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "server closed connection",
            ))),
            // The socket deadline expired partway through a reply: the
            // stream is desynced, which is a transport failure, not a
            // protocol bug — reclassify so retry/failover logic sees it.
            Err(Error::Protocol(m)) if m == MID_FRAME_TIMEOUT_MSG => {
                Err(Error::Io(std::io::Error::new(
                    std::io::ErrorKind::TimedOut,
                    "reply timed out mid-frame",
                )))
            }
            Err(e) => Err(e),
        }
    }

    /// Read the next *untagged* response.  Tagged replies that arrive
    /// first (possible when [`Client::send_tagged`] requests are still in
    /// flight) are stashed for their own [`Client::recv_tagged`] calls.
    fn read_response(&mut self) -> Result<Response> {
        if let Some(resp) = self.pending.remove(&0) {
            return Ok(resp);
        }
        loop {
            let (tag, resp) = self.read_any_reply()?;
            if tag == 0 {
                return Ok(resp);
            }
            self.stash_reply(tag, resp)?;
        }
    }

    /// Stash an out-of-order reply for the call that will ask for it.
    /// Rejects tagged replies this client never issued a request for, and
    /// bounds the stash — either way the connection is desynced or the
    /// server misbehaving, and failing beats unbounded memory growth.
    fn stash_reply(&mut self, tag: u32, resp: Response) -> Result<()> {
        if self.forgotten.remove(&tag) {
            // An abandoned request's reply finally arrived: drop it.  The
            // connection stays healthy — the frame was well-formed, its
            // owner just stopped caring about the answer.
            drop(resp);
            return Ok(());
        }
        if tag != 0 && !self.outstanding.contains(&tag) {
            return Err(Error::Protocol(format!(
                "reply for unknown tag {tag} (no such request in flight)"
            )));
        }
        if self.pending.len() >= MAX_STASHED_REPLIES {
            return Err(Error::Protocol(format!(
                "more than {MAX_STASHED_REPLIES} uncollected replies stashed; \
                 connection is desynced"
            )));
        }
        self.pending.insert(tag, resp);
        Ok(())
    }

    /// Send one request as a legacy untagged frame and block for its
    /// reply — the one-command building block behind [`DataStore`].
    pub fn call(&mut self, req: &Request) -> Result<Response> {
        self.buf.clear();
        req.encode(&mut self.buf);
        crate::proto::frame::write_frame(&mut self.writer, &self.buf)?;
        self.read_response()
    }

    /// Send a slice of requests as one `Batch` frame and return the
    /// per-entry results.  Tensor payloads are streamed from their owning
    /// buffers (no encode-time copy); this is the transport behind
    /// [`DataStore::execute`] and the cluster's per-shard sub-batches.
    pub fn exec_requests(&mut self, reqs: &[Request]) -> Result<Vec<Response>> {
        if reqs.is_empty() {
            return Ok(Vec::new());
        }
        check_batch_len(reqs.len())?;
        let body = 1 + 4 + reqs.iter().map(|r| r.body_wire_size()).sum::<usize>();
        let mut sink = FrameSink::begin(&mut self.writer, &mut self.buf, body)?;
        sink.encode_with(|b| message::encode_batch_request_header_into(b, reqs.len()))?;
        for r in reqs {
            match r {
                Request::PutTensor { key, tensor } => {
                    sink.encode_with(|b| {
                        message::encode_put_tensor_header_into(b, key, tensor)
                    })?;
                    sink.write(&tensor.data)?;
                }
                other => sink.encode_with(|b| other.encode(b))?,
            }
        }
        sink.finish()?;
        self.read_response()?.expect_batch(reqs.len())
    }

    /// Put `req` on the wire as a **tagged** frame and return its tag
    /// without waiting for the reply.  Any number of tagged requests may
    /// be in flight on this connection at once; the server dispatches
    /// them concurrently and replies in completion order — collect each
    /// reply with [`Client::recv_tagged`].  Tensor payloads are streamed
    /// from their owning buffers exactly like the blocking paths.
    pub fn send_tagged(&mut self, req: &Request) -> Result<u32> {
        self.next_tag = self.next_tag.wrapping_add(1);
        if self.next_tag == 0 {
            self.next_tag = 1;
        }
        let tag = self.next_tag;
        let body = req.body_wire_size();
        let mut sink = FrameSink::begin_tagged(&mut self.writer, &mut self.buf, tag, body)?;
        match req {
            Request::PutTensor { key, tensor } => {
                sink.encode_with(|b| message::encode_put_tensor_header_into(b, key, tensor))?;
                sink.write(&tensor.data)?;
            }
            Request::Batch(entries) => {
                check_batch_len(entries.len())?;
                sink.encode_with(|b| {
                    message::encode_batch_request_header_into(b, entries.len())
                })?;
                for r in entries {
                    match r {
                        Request::PutTensor { key, tensor } => {
                            sink.encode_with(|b| {
                                message::encode_put_tensor_header_into(b, key, tensor)
                            })?;
                            sink.write(&tensor.data)?;
                        }
                        other => sink.encode_with(|b| other.encode(b))?,
                    }
                }
            }
            other => sink.encode_with(|b| other.encode(b))?,
        }
        sink.finish()?;
        self.outstanding.insert(tag);
        Ok(tag)
    }

    /// Block until the reply for `tag` arrives.  Replies for *other* tags
    /// read along the way are stashed and handed out when their tag is
    /// asked for — so callers may collect in-flight requests in any
    /// order, independent of the order the server finished them in.
    pub fn recv_tagged(&mut self, tag: u32) -> Result<Response> {
        if let Some(resp) = self.pending.remove(&tag) {
            self.outstanding.remove(&tag);
            return Ok(resp);
        }
        loop {
            let (got, resp) = self.read_any_reply()?;
            if got == tag {
                self.outstanding.remove(&tag);
                return Ok(resp);
            }
            self.stash_reply(got, resp)?;
        }
    }

    /// Abandon in-flight tagged requests whose replies will never be
    /// collected (a fan-out aborted mid-collect).  Each tag is un-issued:
    /// a reply already stashed is dropped now, one still in flight is
    /// drained and dropped when it arrives.  Without this, abandoned
    /// replies accumulate in the bounded stash until it fills and every
    /// later read fails — a slow leak that poisons the connection.
    pub fn forget_tags(&mut self, tags: impl IntoIterator<Item = u32>) {
        for tag in tags {
            if self.outstanding.remove(&tag) && self.pending.remove(&tag).is_none() {
                self.forgotten.insert(tag);
            }
        }
    }

    /// Issued-but-uncollected tag count (abandoned tags excluded).
    pub fn outstanding_tags(&self) -> usize {
        self.outstanding.len()
    }

    /// Out-of-order replies currently held for later `recv_tagged` calls.
    pub fn stashed_replies(&self) -> usize {
        self.pending.len()
    }

    /// Send every request tagged back-to-back, then collect the replies —
    /// one round of socket writes followed by one round of reads, with
    /// the server free to work on all of them concurrently.  Results come
    /// back in *request* order regardless of completion order.  An error
    /// partway through forgets the tags that will never be collected.
    pub fn call_pipelined(&mut self, reqs: &[Request]) -> Result<Vec<Response>> {
        let mut tags = Vec::with_capacity(reqs.len());
        for r in reqs {
            match self.send_tagged(r) {
                Ok(t) => tags.push(t),
                Err(e) => {
                    self.forget_tags(tags);
                    return Err(e);
                }
            }
        }
        let mut out = Vec::with_capacity(tags.len());
        for (i, &t) in tags.iter().enumerate() {
            match self.recv_tagged(t) {
                Ok(r) => out.push(r),
                Err(e) => {
                    self.forget_tags(tags[i + 1..].iter().copied());
                    return Err(e);
                }
            }
        }
        Ok(out)
    }

    /// Fetch the shard's installed slot-ownership table: `(shard index,
    /// table)`, where shard `u16::MAX` plus an empty table means none is
    /// installed (the server then serves every key unconditionally).
    pub fn cluster_epoch(&mut self) -> Result<(u16, SlotEpoch)> {
        self.call(&Request::ClusterEpoch { install: None })?
            .expect_epoch_table()
    }

    /// Install a slot-ownership table on the shard (no-op if it already
    /// holds a newer epoch) and return what is installed afterwards —
    /// install doubles as fetch, so a raced installer learns the winning
    /// table from the reply.
    pub fn install_epoch(
        &mut self,
        shard: u16,
        replicas: u16,
        table: SlotEpoch,
    ) -> Result<(u16, SlotEpoch)> {
        self.call(&Request::ClusterEpoch { install: Some((shard, replicas, table)) })?
            .expect_epoch_table()
    }

    /// List this shard's resident tensor keys hashing into `lo..=hi`, in
    /// generation order — the transfer manifest for a slot-range
    /// migration or replica backfill.
    pub fn export_slots(&mut self, lo: u16, hi: u16) -> Result<Vec<String>> {
        self.call(&Request::ExportSlots { lo, hi })?.expect_keys()
    }

    /// Write a tensor straight into this shard's cold tier (the
    /// generation-retirement path: exactly one shard archives each
    /// retired key).
    pub fn cold_put(&mut self, key: &str, t: &Tensor) -> Result<()> {
        self.call(&Request::ColdPut { key: key.to_string(), tensor: t.clone() })?
            .expect_ok()
    }
}

impl DataStore for Client {
    /// Writes a split frame: the small header is encoded into the reusable
    /// buffer, the payload goes from the borrowed tensor straight to the
    /// socket — zero payload copies.
    fn put_tensor(&mut self, key: &str, t: &Tensor) -> Result<()> {
        begin_split_frame(&mut self.buf);
        message::encode_put_tensor_header_into(&mut self.buf, key, t);
        end_split_frame(&mut self.writer, &mut self.buf, &t.data)?;
        self.read_response()?.expect_ok()
    }

    /// The returned tensor's payload aliases the response frame read off
    /// the socket — one allocation, no decode-time copy.
    fn get_tensor(&mut self, key: &str) -> Result<Tensor> {
        self.call(&Request::GetTensor { key: key.to_string() })?
            .expect_tensor(key)
    }

    fn mget_tensors(&mut self, keys: &[String]) -> Result<Vec<Tensor>> {
        if keys.is_empty() {
            return Ok(Vec::new());
        }
        check_batch_len(keys.len())?;
        let entries = self
            .call(&Request::MGetTensors { keys: keys.to_vec() })?
            .expect_batch(keys.len())?;
        entries
            .into_iter()
            .zip(keys)
            .map(|(r, k)| r.expect_tensor(k))
            .collect()
    }

    fn del_tensor(&mut self, key: &str) -> Result<bool> {
        self.call(&Request::DelTensor { key: key.to_string() })?
            .expect_deleted()
    }

    fn del_keys(&mut self, keys: &[String]) -> Result<u64> {
        if keys.is_empty() {
            return Ok(0);
        }
        check_batch_len(keys.len())?;
        let entries = self
            .call(&Request::DelKeys { keys: keys.to_vec() })?
            .expect_batch(keys.len())?;
        let mut n = 0;
        for e in entries {
            if e.expect_deleted()? {
                n += 1;
            }
        }
        Ok(n)
    }

    fn set_retention(&mut self, cfg: RetentionConfig) -> Result<()> {
        self.call(&Request::Retention {
            window: cfg.window,
            max_bytes: cfg.max_bytes,
            ttl_ms: cfg.ttl_ms,
        })?
        .expect_ok()
    }

    fn exists(&mut self, key: &str) -> Result<bool> {
        self.call(&Request::Exists { key: key.to_string() })?
            .expect_bool()
    }

    fn poll_keys(&mut self, keys: &[String], poll: &PollConfig) -> Result<()> {
        check_batch_len(keys.len())?;
        let req = Request::PollKeys {
            keys: keys.to_vec(),
            // Round the budget *up* to whole milliseconds: truncation would
            // turn a sub-millisecond remainder (e.g. a cluster poll's last
            // shard) into a zero-timeout single probe.
            timeout_ms: poll.max_wait.as_micros().div_ceil(1000).min(u64::MAX as u128) as u64,
            initial_us: poll.initial.as_micros().min(u64::MAX as u128) as u64,
            cap_us: poll.cap.as_micros().min(u64::MAX as u128) as u64,
        };
        // The server legitimately blocks up to `max_wait` before replying,
        // so the socket deadline must outlast the poll budget; restore the
        // normal deadline afterwards (best-effort — a failing setsockopt
        // here is not worth masking the poll result).
        if let Some(t) = self.io_timeout {
            let widened = poll.max_wait.saturating_add(t);
            let _ = self.reader.get_ref().set_read_timeout(Some(widened));
        }
        let res = self.call(&req);
        if let Some(t) = self.io_timeout {
            let _ = self.reader.get_ref().set_read_timeout(Some(t));
        }
        if res?.expect_bool()? {
            Ok(())
        } else {
            Err(Error::Timeout(format!(
                "keys {keys:?} not all present after {:?}",
                poll.max_wait
            )))
        }
    }

    fn put_meta(&mut self, key: &str, value: &str) -> Result<()> {
        self.call(&Request::PutMeta { key: key.to_string(), value: value.to_string() })?
            .expect_ok()
    }

    fn get_meta(&mut self, key: &str) -> Result<Option<String>> {
        self.call(&Request::GetMeta { key: key.to_string() })?
            .expect_meta()
    }

    fn list_keys(&mut self, prefix: &str) -> Result<Vec<String>> {
        self.call(&Request::ListKeys { prefix: prefix.to_string() })?
            .expect_keys()
    }

    fn cold_list(&mut self, prefix: &str) -> Result<Vec<String>> {
        self.call(&Request::ColdList { prefix: prefix.to_string() })?
            .expect_keys()
    }

    /// Like `get_tensor`, the reply payload aliases the response frame —
    /// cold reads are zero-copy client-side too.
    fn cold_get(&mut self, key: &str) -> Result<Tensor> {
        self.call(&Request::ColdGet { key: key.to_string() })?
            .expect_tensor(key)
    }

    fn put_model(&mut self, key: &str, hlo_text: &str) -> Result<u64> {
        self.call(&Request::PutModel {
            key: key.to_string(),
            hlo_text: hlo_text.to_string(),
        })?
        .expect_version()
    }

    fn run_model_version(
        &mut self,
        key: &str,
        version: u64,
        in_keys: &[String],
        out_keys: &[String],
        device: Device,
    ) -> Result<()> {
        self.call(&Request::RunModel {
            key: key.to_string(),
            version,
            in_keys: in_keys.to_vec(),
            out_keys: out_keys.to_vec(),
            device,
        })?
        .expect_ok()
    }

    fn list_models(&mut self) -> Result<Vec<crate::proto::ModelEntry>> {
        self.call(&Request::ListModels)?.expect_models()
    }

    fn model_stats(&mut self) -> Result<Vec<crate::proto::ModelDeviceStat>> {
        self.call(&Request::ModelStats)?.expect_model_stats()
    }

    fn info(&mut self) -> Result<DbInfo> {
        self.call(&Request::Info)?.expect_info()
    }

    fn flush_all(&mut self) -> Result<()> {
        self.call(&Request::FlushAll)?.expect_ok()
    }

    fn execute(&mut self, pipeline: Pipeline) -> Result<Vec<Response>> {
        self.exec_requests(&pipeline.into_requests())
    }
}

/// How a [`ClusterClient`] connects, replicates writes, and reacts to shard
/// failure.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Copies kept of every write: the owning shard plus the next
    /// `replicas − 1` shards in ring order.  Clamped to `1..=n_shards` at
    /// connect time; `1` (the default) reproduces the unreplicated
    /// behaviour exactly.
    pub replicas: usize,
    /// Per-operation socket deadline for every shard connection
    /// ([`Client::connect_with`]); `None` blocks forever.
    pub io_timeout: Option<Duration>,
    /// Consecutive transient-I/O failures before a shard's circuit breaker
    /// opens (further ops fail fast instead of re-dialing a dead peer).
    pub breaker_threshold: u32,
    /// How long an open breaker rejects before letting one half-open
    /// reconnect probe through.
    pub breaker_cooldown: Duration,
    /// Connection attempts per shard, at connect time and on reconnect.
    pub connect_tries: usize,
    /// Sleep between connection attempts.
    pub connect_delay: Duration,
    /// Optional seeded fault schedule worn by the client side of every
    /// shard connection (the chaos battery's hook).
    pub faults: Option<Arc<FaultPlan>>,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            replicas: 1,
            io_timeout: Some(DEFAULT_IO_TIMEOUT),
            breaker_threshold: 3,
            breaker_cooldown: Duration::from_millis(250),
            connect_tries: 1,
            connect_delay: Duration::from_millis(50),
            faults: None,
        }
    }
}

/// What replication and failover actually did over a [`ClusterClient`]'s
/// lifetime.  Folded into the aggregated [`DbInfo`] by
/// [`ClusterClient::info`] (single servers always report these as zero —
/// they are client-side phenomena).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct FailoverStats {
    /// Successful replica copies of writes beyond the first landed copy.
    pub replicated_writes: u64,
    /// Reads answered by a non-primary target after the primary missed or
    /// transport-failed.
    pub read_failovers: u64,
    /// Shard connections re-established after a failure.
    pub shard_reconnects: u64,
    /// Aggregate/replicated operations that succeeded with at least one
    /// shard unreachable (see [`ClusterClient::shard_errors`]).
    pub degraded_ops: u64,
}

/// One shard's failure from the most recent degraded operation.
#[derive(Debug, Clone)]
pub struct ShardError {
    pub shard: usize,
    pub addr: SocketAddr,
    pub error: String,
}

/// One shard's connection plus its health state.  The connection is
/// dropped on any transient transport error (a desynced stream must never
/// be reused) and re-established lazily, gated by the circuit breaker.
struct ShardConn {
    addr: SocketAddr,
    client: Option<Client>,
    consecutive_failures: u32,
    retry_at: Option<Instant>,
}

impl ShardConn {
    fn new(addr: SocketAddr) -> ShardConn {
        ShardConn { addr, client: None, consecutive_failures: 0, retry_at: None }
    }

    /// Breaker-gated access: while the breaker is open and cooling down,
    /// fail fast with a transient error; past the cooldown, let one
    /// half-open reconnect probe through.
    fn get(&mut self, cfg: &ClusterConfig, stats: &mut FailoverStats) -> Result<&mut Client> {
        if self.client.is_none() {
            if let Some(at) = self.retry_at {
                if Instant::now() < at {
                    return Err(Error::Io(std::io::Error::new(
                        std::io::ErrorKind::NotConnected,
                        format!("shard {} breaker open", self.addr),
                    )));
                }
            }
            let was_down = self.consecutive_failures > 0 || self.retry_at.is_some();
            match Client::connect_retry_with(
                self.addr,
                cfg.connect_tries,
                cfg.connect_delay,
                cfg.io_timeout,
                cfg.faults.as_ref(),
            ) {
                Ok(c) => {
                    if was_down {
                        stats.shard_reconnects += 1;
                    }
                    self.client = Some(c);
                }
                Err(e) => {
                    self.fail(cfg);
                    return Err(e);
                }
            }
        }
        Ok(self.client.as_mut().expect("just connected"))
    }

    fn fail(&mut self, cfg: &ClusterConfig) {
        self.client = None;
        self.consecutive_failures = self.consecutive_failures.saturating_add(1);
        if self.consecutive_failures >= cfg.breaker_threshold {
            self.retry_at = Some(Instant::now() + cfg.breaker_cooldown);
        }
    }

    /// Health bookkeeping for an op's outcome: a transient transport error
    /// poisons the connection (it may be desynced — reconnect before
    /// reuse); any other outcome, including application errors like
    /// `KeyNotFound`, proves the link healthy and closes the breaker.
    fn note<T>(&mut self, res: &Result<T>, cfg: &ClusterConfig) {
        match res {
            Err(e) if e.is_transient_io() => self.fail(cfg),
            _ => {
                self.consecutive_failures = 0;
                self.retry_at = None;
            }
        }
    }
}

/// Whether a routable pipeline entry mutates state — and so must fan out
/// to every replica target — or reads it (first authoritative answer
/// wins).
fn is_write_request(r: &Request) -> bool {
    matches!(
        r,
        Request::PutTensor { .. } | Request::PutMeta { .. } | Request::DelTensor { .. }
    )
}

/// Pool two `(count, mean, std)` summaries into the exact moments of the
/// concatenated sample sets (weighted mean, pooled variance).  Used to
/// merge per-device serving stats across shards.
fn pool_moments(a: (u64, f64, f64), b: (u64, f64, f64)) -> (u64, f64, f64) {
    let (na, ma, sa) = a;
    let (nb, mb, sb) = b;
    let n = na + nb;
    if n == 0 {
        return (0, 0.0, 0.0);
    }
    let (naf, nbf, nf) = (na as f64, nb as f64, n as f64);
    let mean = (naf * ma + nbf * mb) / nf;
    // E[x²] per side is var + mean²; recombine and subtract the new mean².
    let ex2 = (naf * (sa * sa + ma * ma) + nbf * (sb * sb + mb * mb)) / nf;
    let var = (ex2 - mean * mean).max(0.0);
    (n, mean, var.sqrt())
}

/// Response quality for replica merging: an authoritative success beats an
/// authoritative miss (`NotFound` / `Bool(false)` — a replica may still
/// hold the key) beats a busy rejection (retryable) beats any other error.
fn resp_rank(r: &Response) -> u8 {
    match r {
        Response::NotFound | Response::Bool(false) => 2,
        Response::Error(m) if m.starts_with("busy: ") => 1,
        Response::Error(_) => 0,
        _ => 3,
    }
}

/// Client for the clustered deployment: routes each key to the owning shard
/// via an **epoch-versioned** redis-cluster hash-slot table, and implements
/// the complete [`DataStore`] surface — multi-key operations are
/// partitioned per shard and reassembled, models are broadcast to every
/// shard, `info` aggregates.
///
/// The table starts as the static even split over the address list
/// ([`SlotEpoch::initial`] — byte-identical routing to the pre-elastic
/// client).  When a live reshard moves slots, a shard that no longer owns
/// a key answers `moved: <epoch>`; the client then refetches the table
/// from the cluster ([`ClusterClient::refresh_slot_table`]), adopts the
/// newest epoch, and retries — callers never see the move.  While a slot
/// is mid-migration, reads additionally fall back to the *old* owner's
/// ring, so data that has not streamed over yet is still served.
///
/// With [`ClusterConfig::replicas`] > 1, writes fan out to the owner plus
/// the next shards in ring order and reads fail over along the same ring;
/// see the module docs for the full failure semantics.
pub struct ClusterClient {
    shards: Vec<ShardConn>,
    table: SlotEpoch,
    cfg: ClusterConfig,
    stats: FailoverStats,
    last_errors: Vec<ShardError>,
    /// Multiplexed fan-out rounds issued (one per logical operation or
    /// replica offset): every sub-batch in a round is on the wire before
    /// any reply is read.
    mux_rounds: u64,
    /// Per-shard sub-batches sent across all fan-out rounds.
    mux_subs: u64,
    /// Slot-table refetches triggered by `moved:` bounces (transparent
    /// reshard handovers the caller never saw).
    epoch_refreshes: u64,
}

/// How many times an operation refetches the slot table and retries after
/// a `moved:` bounce before surfacing the error.  Each refetch asks every
/// shard and adopts the max epoch, so one round normally suffices; the
/// bound only matters when the shard that bounced us dies before anyone
/// learns its table.
const MAX_MOVED_RETRIES: usize = 3;

/// The epoch a [`Response::Error`] pipeline entry carries when a shard
/// bounced the command for a slot it no longer owns.
fn moved_epoch(r: &Response) -> Option<u64> {
    match r {
        Response::Error(m) => m.strip_prefix("moved: ").and_then(|s| s.parse().ok()),
        _ => None,
    }
}

impl ClusterClient {
    /// Connect with defaults: no replication, the default I/O deadline, no
    /// fault injection.
    pub fn connect(addrs: &[SocketAddr]) -> Result<ClusterClient> {
        ClusterClient::connect_with(addrs, ClusterConfig::default())
    }

    /// Connect every shard eagerly (startup races are the caller's problem
    /// to retry via [`ClusterConfig::connect_tries`]); shards that die
    /// *later* are redialed lazily under the circuit breaker.
    pub fn connect_with(addrs: &[SocketAddr], mut cfg: ClusterConfig) -> Result<ClusterClient> {
        if addrs.is_empty() {
            return Err(Error::Invalid("cluster with no shard addresses".into()));
        }
        cfg.replicas = cfg.replicas.clamp(1, addrs.len());
        let mut shards: Vec<ShardConn> = addrs.iter().map(|a| ShardConn::new(*a)).collect();
        let mut ignored = FailoverStats::default();
        for s in &mut shards {
            s.get(&cfg, &mut ignored)?;
        }
        Ok(ClusterClient {
            table: SlotEpoch::initial(shards.len()),
            shards,
            cfg,
            stats: FailoverStats::default(),
            last_errors: Vec::new(),
            mux_rounds: 0,
            mux_subs: 0,
            epoch_refreshes: 0,
        })
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Effective replication factor (post-clamp).
    pub fn replicas(&self) -> usize {
        self.cfg.replicas
    }

    /// Counters of what replication/failover actually did so far.
    pub fn failover_stats(&self) -> FailoverStats {
        self.stats
    }

    /// Per-shard failures from the most recent operation that succeeded
    /// degraded (partial result).  Empty when it fully succeeded.
    pub fn shard_errors(&self) -> &[ShardError] {
        &self.last_errors
    }

    /// Epoch of the slot table this client is currently routing by.
    pub fn epoch(&self) -> u64 {
        self.table.epoch
    }

    /// The slot table this client is currently routing by.
    pub fn slot_table(&self) -> &SlotEpoch {
        &self.table
    }

    /// Slot-table refetches forced by `moved:` bounces so far — each one
    /// is a reshard handover the caller never saw.
    pub fn epoch_refreshes(&self) -> u64 {
        self.epoch_refreshes
    }

    /// Shards participating in the replica ring: the table's member count,
    /// not the address-list length — a client may hold addresses for
    /// shards the current table does not yet assign slots to (e.g. a
    /// just-added shard before the reshard that populates it).
    fn ring_n(&self) -> usize {
        self.table.n_shards().min(self.shards.len()).max(1)
    }

    /// Adopt a slot table fetched from (or pushed by) the cluster.  Older
    /// epochs are ignored; a table referencing shards beyond the address
    /// list is rejected — this client cannot reach them, so routing by it
    /// would be worse than staying stale.
    pub fn adopt_slot_table(&mut self, table: SlotEpoch) -> Result<()> {
        if table.assignments.is_empty() || table.epoch < self.table.epoch {
            return Ok(());
        }
        table.validate().map_err(Error::Protocol)?;
        if table.n_shards() > self.shards.len() {
            return Err(Error::Invalid(format!(
                "slot table (epoch {}) references {} shards but this client \
                 only has {} addresses; reconnect with the full address list",
                table.epoch,
                table.n_shards(),
                self.shards.len()
            )));
        }
        self.table = table;
        Ok(())
    }

    /// Ask every reachable shard for its installed table and adopt the
    /// newest epoch seen.  Returns the epoch routing now uses.  Shards
    /// with no table installed answer with the unset sentinel and are
    /// skipped — a cluster that never resharded keeps the static split.
    pub fn refresh_slot_table(&mut self) -> Result<u64> {
        let got = self.broadcast_collect(|c| c.cluster_epoch())?;
        let mut best: Option<SlotEpoch> = None;
        for (_, (_, table)) in got {
            if table.assignments.is_empty() {
                continue;
            }
            if best.as_ref().map_or(true, |b| table.epoch > b.epoch) {
                best = Some(table);
            }
        }
        if let Some(t) = best {
            self.adopt_slot_table(t)?;
        }
        Ok(self.table.epoch)
    }

    /// Run `op`, and on a `moved:` bounce refetch the slot table and
    /// retry — the transparent half of the reshard protocol.  Bounded:
    /// each refetch adopts the cluster-wide max epoch, so repeat bounces
    /// mean the bouncing shard's table is unreachable, and the error
    /// surfaces rather than spinning.
    fn moved_retry<T>(
        &mut self,
        mut op: impl FnMut(&mut ClusterClient) -> Result<T>,
    ) -> Result<T> {
        for _ in 0..MAX_MOVED_RETRIES {
            match op(self) {
                Err(Error::Moved(_)) => {
                    self.epoch_refreshes += 1;
                    self.refresh_slot_table()?;
                }
                other => return other,
            }
        }
        op(self)
    }

    /// Shards holding copies of `key`: the hash-slot owner plus the next
    /// `replicas − 1` shards in ring order.
    fn targets(&self, key: &str) -> Vec<usize> {
        let primary = self.table.shard_for_key(key);
        let n = self.ring_n();
        (0..self.cfg.replicas.min(n)).map(|i| (primary + i) % n).collect()
    }

    /// Read-side targets: the owner's ring, then — while the key's slot
    /// is mid-migration — the *old* owner's ring, so reads reach data the
    /// transfer has not landed on the new owner yet.
    fn read_targets(&self, key: &str) -> Vec<usize> {
        let mut t = self.targets(key);
        if let Some(old) = self.table.fallback_for_slot(hash_slot(key)) {
            let n = self.ring_n();
            for i in 0..self.cfg.replicas.min(n) {
                let s = (old + i) % n;
                if !t.contains(&s) {
                    t.push(s);
                }
            }
        }
        t
    }

    /// Partition indices `0..keys.len()` by owning (primary) shard.
    fn partition_keys(&self, keys: &[String]) -> Vec<Vec<usize>> {
        let mut by_shard: Vec<Vec<usize>> = vec![Vec::new(); self.shards.len()];
        for (i, k) in keys.iter().enumerate() {
            by_shard[self.table.shard_for_key(k)].push(i);
        }
        by_shard
    }

    /// Forget one tag on one shard's live connection (the abandoned-round
    /// cleanup half of [`Client::forget_tags`]).
    fn forget_tag(&mut self, shard: usize, tag: u32) {
        if let Some(c) = self.shards[shard].client.as_mut() {
            c.forget_tags([tag]);
        }
    }

    /// One pass of [`DataStore::poll_keys`] under the current slot table.
    fn poll_keys_once(&mut self, keys: &[String], poll: &PollConfig) -> Result<()> {
        let deadline = Instant::now() + poll.max_wait;
        let by_shard = self.partition_keys(keys);
        let nsh = self.ring_n();
        let timeout = || {
            Error::Timeout(format!(
                "keys {keys:?} not all present after {:?}",
                poll.max_wait
            ))
        };
        for (shard, idxs) in by_shard.into_iter().enumerate() {
            if idxs.is_empty() {
                continue;
            }
            let sub: Vec<String> = idxs.iter().map(|&i| keys[i].clone()).collect();
            let mut last: Option<Error> = None;
            let mut done = false;
            for off in 0..self.cfg.replicas.min(nsh) {
                let target = (shard + off) % nsh;
                let remaining = deadline.saturating_duration_since(Instant::now());
                let budget = PollConfig { max_wait: remaining, ..*poll };
                match self.on_shard(target, |c| c.poll_keys(&sub, &budget)) {
                    Ok(()) => {
                        if off > 0 {
                            self.stats.read_failovers += 1;
                        }
                        done = true;
                        break;
                    }
                    Err(e) if e.is_transient_io() => last = Some(e),
                    // Rewrite per-shard timeouts to name the whole key set.
                    Err(Error::Timeout(_)) => last = Some(timeout()),
                    Err(e) => return Err(e),
                }
            }
            if !done {
                return Err(last.unwrap_or_else(timeout));
            }
        }
        Ok(())
    }

    /// One pass of [`DataStore::del_keys`]: one batched round trip per
    /// (shard, replica offset), per-key presence OR-ed across copies.
    fn del_keys_once(&mut self, keys: &[String]) -> Result<u64> {
        self.last_errors.clear();
        let by_shard = self.partition_keys(keys);
        let nsh = self.ring_n();
        let mut deleted = vec![false; keys.len()];
        let mut reached = vec![false; keys.len()];
        let mut errs: Vec<(usize, Error)> = Vec::new();
        let mut moved: Option<u64> = None;
        for off in 0..self.cfg.replicas.min(nsh) {
            for (shard, idxs) in by_shard.iter().enumerate() {
                if idxs.is_empty() {
                    continue;
                }
                let target = (shard + off) % nsh;
                let sub: Vec<Request> = idxs
                    .iter()
                    .map(|&i| Request::DelTensor { key: keys[i].clone() })
                    .collect();
                match self.on_shard(target, |c| c.exec_requests(&sub)) {
                    Ok(resps) => {
                        for (&i, r) in idxs.iter().zip(resps) {
                            match r.expect_deleted() {
                                Ok(b) => {
                                    reached[i] = true;
                                    deleted[i] |= b;
                                }
                                Err(Error::Moved(ep)) => {
                                    moved = Some(moved.map_or(ep, |m| m.max(ep)));
                                }
                                Err(_) => {}
                            }
                        }
                    }
                    Err(e) => errs.push((target, e)),
                }
            }
        }
        if let Some(i) = reached.iter().position(|&r| !r) {
            // An entry that only ever bounced was not unreachable — the
            // table is stale; surface the bounce so the wrapper refetches
            // and re-runs the delete against the current owners.
            if let Some(ep) = moved {
                return Err(Error::Moved(ep));
            }
            return Err(match errs.into_iter().next() {
                Some((_, e)) => e,
                None => Error::KeyNotFound(keys[i].clone()),
            });
        }
        if !errs.is_empty() {
            self.note_degraded(&errs);
        }
        Ok(deleted.iter().filter(|&&b| b).count() as u64)
    }

    /// One delete pass over every replica target of `key`; `true` if any
    /// copy existed.
    fn del_tensor_once(&mut self, key: &str) -> Result<bool> {
        self.last_errors.clear();
        let targets = self.targets(key);
        let mut any = false;
        let mut reached = false;
        let mut errs: Vec<(usize, Error)> = Vec::new();
        for &shard in &targets {
            match self.on_shard(shard, |c| c.del_tensor(key)) {
                Ok(b) => {
                    reached = true;
                    any |= b;
                }
                Err(e) => errs.push((shard, e)),
            }
        }
        if !reached {
            let pick = errs.iter().position(|(_, e)| matches!(e, Error::Moved(_)));
            return Err(errs.swap_remove(pick.unwrap_or(0)).1);
        }
        if !errs.is_empty() {
            self.note_degraded(&errs);
        }
        Ok(any)
    }

    /// Run `op` against shard `i` through the breaker, recording the
    /// outcome in that shard's health state.
    fn on_shard<T>(&mut self, i: usize, op: impl FnOnce(&mut Client) -> Result<T>) -> Result<T> {
        let cfg = self.cfg.clone();
        let res = match self.shards[i].get(&cfg, &mut self.stats) {
            Ok(c) => op(c),
            Err(e) => Err(e),
        };
        self.shards[i].note(&res, &cfg);
        res
    }

    /// Pass 1 of a multiplexed fan-out: put every job's request on the
    /// wire as one tagged frame, breaker-gated per shard, without reading
    /// any reply.  Returns each job's tag (or its send-side error) in job
    /// order; [`ClusterClient::mux_recv`] collects the replies.
    fn mux_send(&mut self, jobs: &[(usize, Request)]) -> Vec<Result<u32>> {
        let cfg = self.cfg.clone();
        if !jobs.is_empty() {
            self.mux_rounds += 1;
            self.mux_subs += jobs.len() as u64;
        }
        jobs.iter()
            .map(|(shard, req)| {
                let res = match self.shards[*shard].get(&cfg, &mut self.stats) {
                    Ok(c) => c.send_tagged(req),
                    Err(e) => Err(e),
                };
                self.shards[*shard].note(&res, &cfg);
                res
            })
            .collect()
    }

    /// Pass 2 of a multiplexed fan-out: block for one job's reply.
    /// Deliberately *not* the breaker-gated `get`: the tag lives on the
    /// connection that sent it, and a reconnect here would orphan the
    /// in-flight reply.
    fn mux_recv(&mut self, shard: usize, tag: u32) -> Result<Response> {
        let cfg = self.cfg.clone();
        let res = match self.shards[shard].client.as_mut() {
            Some(c) => c.recv_tagged(tag),
            None => Err(Error::Io(std::io::Error::new(
                std::io::ErrorKind::NotConnected,
                format!("shard {} dropped mid fan-out", self.shards[shard].addr),
            ))),
        };
        self.shards[shard].note(&res, &cfg);
        res
    }

    /// `(fan-out rounds, per-shard sub-batches)` issued through the
    /// multiplexed paths so far.  Benches assert on the deltas: a 3-shard
    /// gather is one round of three sub-batches, not three rounds.
    pub fn mux_counters(&self) -> (u64, u64) {
        (self.mux_rounds, self.mux_subs)
    }

    /// Record a degraded (partial) success: count it and keep the
    /// per-shard error report for [`ClusterClient::shard_errors`].
    fn note_degraded(&mut self, errs: &[(usize, Error)]) {
        self.stats.degraded_ops += 1;
        self.last_errors = errs
            .iter()
            .map(|(s, e)| ShardError { shard: *s, addr: self.shards[*s].addr, error: e.to_string() })
            .collect();
    }

    /// Apply a write to every replica target of `key` in **one multiplexed
    /// round**: all per-target frames go on the wire tagged before any
    /// reply is read, so a replicated write costs the slowest target, not
    /// the sum (tensor payloads are refcounted — the clones share one
    /// buffer).  Succeeds if at least one copy landed (further copies
    /// count as replicated writes); fails only when *no* target took it,
    /// preferring a `Busy` error — the one failure the publish-side retry
    /// loops know how to wait out.
    fn replicated_write(&mut self, key: &str, op: Request) -> Result<()> {
        self.last_errors.clear();
        let targets = self.targets(key);
        let sends: Vec<(usize, Request)> = targets.iter().map(|&s| (s, op.clone())).collect();
        let tags = self.mux_send(&sends);
        let mut ok = 0usize;
        let mut errs: Vec<(usize, Error)> = Vec::new();
        for (off, (&shard, tag)) in targets.iter().zip(tags).enumerate() {
            let res = tag.and_then(|t| self.mux_recv(shard, t)).and_then(|r| r.expect_ok());
            match res {
                Ok(()) => {
                    ok += 1;
                    if off > 0 {
                        self.stats.replicated_writes += 1;
                    }
                }
                Err(e) => errs.push((shard, e)),
            }
        }
        if ok == 0 {
            // Moved first (a stale table is cheap to fix and the retry
            // wrapper resolves it before the caller sees anything), then
            // Busy — the one failure the publish-side retry loops know
            // how to wait out.
            let pick = errs
                .iter()
                .position(|(_, e)| matches!(e, Error::Moved(_)))
                .or_else(|| errs.iter().position(|(_, e)| matches!(e, Error::Busy(_))));
            return Err(errs.swap_remove(pick.unwrap_or(0)).1);
        }
        if !errs.is_empty() {
            self.note_degraded(&errs);
        }
        Ok(())
    }

    /// Try a read on each replica target in ring order — including, while
    /// the key's slot is mid-migration, the old owner's ring — advancing
    /// past dead targets (transient I/O) and authoritative misses; a
    /// success on a non-primary target counts as a read failover.  If
    /// every reachable copy reported a miss, the miss wins (callers can
    /// fall back to the cold tier); only when *no* target answered does
    /// the transport error surface.
    fn read_any<T>(
        &mut self,
        key: &str,
        op: impl FnMut(&mut Client) -> Result<T>,
        is_miss: impl Fn(&T) -> bool,
    ) -> Result<T> {
        let targets = self.read_targets(key);
        self.read_any_on(&targets, key, op, is_miss)
    }

    /// [`ClusterClient::read_any`] over an explicit target walk order.
    fn read_any_on<T>(
        &mut self,
        targets: &[usize],
        key: &str,
        mut op: impl FnMut(&mut Client) -> Result<T>,
        is_miss: impl Fn(&T) -> bool,
    ) -> Result<T> {
        let mut miss: Option<T> = None;
        let mut not_found: Option<Error> = None;
        let mut moved: Option<Error> = None;
        let mut io_err: Option<Error> = None;
        for (off, &shard) in targets.iter().enumerate() {
            match self.on_shard(shard, &mut op) {
                Ok(v) if is_miss(&v) => {
                    if miss.is_none() {
                        miss = Some(v);
                    }
                }
                Ok(v) => {
                    if off > 0 {
                        self.stats.read_failovers += 1;
                    }
                    return Ok(v);
                }
                Err(e @ Error::KeyNotFound(_)) => not_found = Some(e),
                // A `moved:` bounce from one target must not end the walk:
                // mid-migration the new ring bounces misses while the old
                // ring still holds the data, so keep walking.  It only
                // surfaces when nothing answered — and then ahead of a
                // transport error, because a table refetch can fix it.
                Err(e @ Error::Moved(_)) => moved = Some(e),
                Err(e) if e.is_transient_io() => io_err = Some(e),
                Err(e) => return Err(e),
            }
        }
        if let Some(v) = miss {
            return Ok(v);
        }
        Err(not_found
            .or(moved)
            .or(io_err)
            .unwrap_or_else(|| Error::KeyNotFound(key.to_string())))
    }

    /// Broadcast `op` to every shard, tolerating unreachable ones as long
    /// as at least one succeeds (degraded success, reported via
    /// [`ClusterClient::shard_errors`]).
    fn broadcast(&mut self, mut op: impl FnMut(&mut Client) -> Result<()>) -> Result<()> {
        self.last_errors.clear();
        let mut ok = 0usize;
        let mut errs: Vec<(usize, Error)> = Vec::new();
        for i in 0..self.shards.len() {
            match self.on_shard(i, &mut op) {
                Ok(()) => ok += 1,
                Err(e) => errs.push((i, e)),
            }
        }
        if ok == 0 {
            return Err(errs.swap_remove(0).1);
        }
        if !errs.is_empty() {
            self.note_degraded(&errs);
        }
        Ok(())
    }

    /// Broadcast `op` to every shard and collect each reachable shard's
    /// value.  Like [`ClusterClient::broadcast`], one success is enough:
    /// unreachable shards become a degraded-op report instead of a failure.
    fn broadcast_collect<T>(
        &mut self,
        mut op: impl FnMut(&mut Client) -> Result<T>,
    ) -> Result<Vec<(usize, T)>> {
        self.last_errors.clear();
        let mut got: Vec<(usize, T)> = Vec::new();
        let mut errs: Vec<(usize, Error)> = Vec::new();
        for i in 0..self.shards.len() {
            match self.on_shard(i, &mut op) {
                Ok(v) => got.push((i, v)),
                Err(e) => errs.push((i, e)),
            }
        }
        if got.is_empty() {
            return Err(errs.swap_remove(0).1);
        }
        if !errs.is_empty() {
            self.note_degraded(&errs);
        }
        Ok(got)
    }

    /// Merge sorted key lists from every reachable shard.  Deduped, because
    /// replication stores the same key on several shards.
    fn merged_keys(
        &mut self,
        mut op: impl FnMut(&mut Client) -> Result<Vec<String>>,
    ) -> Result<Vec<String>> {
        self.last_errors.clear();
        let mut all = Vec::new();
        let mut ok = 0usize;
        let mut errs: Vec<(usize, Error)> = Vec::new();
        for i in 0..self.shards.len() {
            match self.on_shard(i, &mut op) {
                Ok(keys) => {
                    ok += 1;
                    all.extend(keys);
                }
                Err(e) => errs.push((i, e)),
            }
        }
        if ok == 0 {
            return Err(errs.swap_remove(0).1);
        }
        if !errs.is_empty() {
            self.note_degraded(&errs);
        }
        all.sort();
        all.dedup();
        Ok(all)
    }

    /// One routing pass of [`DataStore::execute`] over the entries at
    /// `idxs`: partition per owning shard under the current table, one
    /// multiplexed round per replica offset (max-of-shards, not
    /// sum-of-shards), best-ranked response per entry.  With `route_old`,
    /// entries route to their slot's *old* owner instead (the mid-
    /// migration read fallback); entries whose slot is not migrating
    /// route normally.
    fn execute_subset(
        &mut self,
        reqs: &[Request],
        idxs: Vec<usize>,
        route_old: bool,
    ) -> Result<Vec<Response>> {
        let primary: Vec<usize> = idxs
            .iter()
            .map(|&i| {
                let slot = hash_slot(reqs[i].routing_key().expect("validated by execute"));
                if route_old {
                    self.table
                        .fallback_for_slot(slot)
                        .unwrap_or_else(|| self.table.shard_for_slot(slot))
                } else {
                    self.table.shard_for_slot(slot)
                }
            })
            .collect();
        let writes: Vec<bool> = idxs.iter().map(|&i| is_write_request(&reqs[i])).collect();
        let nsh = self.ring_n();
        let m = idxs.len();
        let mut best: Vec<Option<Response>> = (0..m).map(|_| None).collect();
        let mut first_io: Option<Error> = None;
        for off in 0..self.cfg.replicas.min(nsh) {
            let mut by_shard: Vec<Vec<usize>> = vec![Vec::new(); self.shards.len()];
            for j in 0..m {
                let needs = writes[j]
                    || best[j].as_ref().map_or(true, |b| resp_rank(b) < 3);
                if needs {
                    by_shard[(primary[j] + off) % nsh].push(j);
                }
            }
            // One multiplexed round: all sub-batches on the wire, then all
            // replies collected — max-of-shards, not sum-of-shards.
            let mut jobs: Vec<(usize, Vec<usize>)> = Vec::new();
            let mut sends: Vec<(usize, Request)> = Vec::new();
            for (shard, js) in by_shard.into_iter().enumerate() {
                if js.is_empty() {
                    continue;
                }
                let sub: Vec<Request> =
                    js.iter().map(|&j| reqs[idxs[j]].clone()).collect();
                sends.push((shard, Request::Batch(sub)));
                jobs.push((shard, js));
            }
            let tags = self.mux_send(&sends);
            for ((shard, js), tag) in jobs.into_iter().zip(tags) {
                let res = tag
                    .and_then(|t| self.mux_recv(shard, t))
                    .and_then(|r| r.expect_batch(js.len()));
                match res {
                    Ok(resps) => {
                        for (&j, r) in js.iter().zip(resps) {
                            let rank = resp_rank(&r);
                            if off > 0 && rank == 3 {
                                if writes[j] {
                                    self.stats.replicated_writes += 1;
                                } else {
                                    self.stats.read_failovers += 1;
                                }
                            }
                            let better =
                                best[j].as_ref().map_or(true, |b| rank > resp_rank(b));
                            if better {
                                best[j] = Some(r);
                            }
                        }
                    }
                    Err(e) => {
                        if first_io.is_none() {
                            first_io = Some(e);
                        }
                    }
                }
            }
        }
        let mut out = Vec::with_capacity(m);
        for b in best {
            match b {
                Some(r) => out.push(r),
                None => {
                    return Err(first_io.take().unwrap_or_else(|| {
                        Error::Io(std::io::Error::new(
                            std::io::ErrorKind::NotConnected,
                            "no shard reachable for pipeline entry",
                        ))
                    }))
                }
            }
        }
        Ok(out)
    }
}

impl DataStore for ClusterClient {
    /// Fans out to every replica target in one multiplexed round; succeeds
    /// when at least one copy landed.  A `moved:` bounce refetches the
    /// slot table and retries transparently.
    fn put_tensor(&mut self, key: &str, t: &Tensor) -> Result<()> {
        let req = Request::PutTensor { key: key.to_string(), tensor: t.clone() };
        self.moved_retry(|s| s.replicated_write(key, req.clone()))
    }

    /// Primary first, then each replica on a miss or transport error —
    /// falling back to the old owner's ring mid-migration, and refetching
    /// the table on a `moved:` bounce.
    fn get_tensor(&mut self, key: &str) -> Result<Tensor> {
        self.moved_retry(|s| s.read_any(key, |c| c.get_tensor(key), |_| false))
    }

    /// One tagged `MGetTensors` sub-batch per shard that owns any of the
    /// keys, all on the wire before any reply is read — the gather's
    /// wall-clock is the slowest shard, not the sum of all shards.
    /// Sub-batches that hit a dead shard or a missing key fall back to
    /// per-key [`DataStore::get_tensor`], which walks the replicas.
    fn mget_tensors(&mut self, keys: &[String]) -> Result<Vec<Tensor>> {
        check_batch_len(keys.len())?;
        let by_shard = self.partition_keys(keys);
        let mut out: Vec<Option<Tensor>> = keys.iter().map(|_| None).collect();
        let mut retry: Vec<usize> = Vec::new();
        let mut jobs: Vec<(usize, Vec<usize>)> = Vec::new();
        let mut sends: Vec<(usize, Request)> = Vec::new();
        for (shard, idxs) in by_shard.into_iter().enumerate() {
            if idxs.is_empty() {
                continue;
            }
            let sub: Vec<String> = idxs.iter().map(|&i| keys[i].clone()).collect();
            sends.push((shard, Request::MGetTensors { keys: sub }));
            jobs.push((shard, idxs));
        }
        let tags = self.mux_send(&sends);
        let mut pairs = jobs.into_iter().zip(tags);
        while let Some(((shard, idxs), tag)) = pairs.next() {
            let res = tag.and_then(|t| self.mux_recv(shard, t)).and_then(|r| {
                r.expect_batch(idxs.len())?
                    .into_iter()
                    .zip(idxs.iter())
                    .map(|(r, &i)| r.expect_tensor(&keys[i]))
                    .collect::<Result<Vec<Tensor>>>()
            });
            match res {
                Ok(got) => {
                    for (i, t) in idxs.into_iter().zip(got) {
                        out[i] = Some(t);
                    }
                }
                // The whole sub-batch failed (shard down, one key missing
                // aborts the batch, or the shard no longer owns a slot):
                // retry key-by-key with failover — the single-key path
                // walks the replicas and resolves `moved:` bounces.
                // Misses are the exception path, so the extra round trips
                // only happen when something already went wrong.
                Err(e)
                    if e.is_transient_io()
                        || matches!(e, Error::KeyNotFound(_) | Error::Moved(_)) =>
                {
                    retry.extend(idxs);
                }
                Err(e) => {
                    // Aborting the round mid-collect: un-issue the tags we
                    // will never read, so their replies are drained on
                    // arrival instead of rotting in the bounded stash
                    // until it poisons the connection.
                    for ((s, _), t) in pairs.by_ref() {
                        if let Ok(t) = t {
                            self.forget_tag(s, t);
                        }
                    }
                    return Err(e);
                }
            }
        }
        for i in retry {
            out[i] = Some(self.get_tensor(&keys[i])?);
        }
        Ok(out.into_iter().map(|t| t.expect("all partitions filled")).collect())
    }

    /// Deletes every replica copy; `true` if any copy existed.  Refetches
    /// the slot table and retries on a `moved:` bounce.
    fn del_tensor(&mut self, key: &str) -> Result<bool> {
        self.moved_retry(|s| s.del_tensor_once(key))
    }

    /// One batched round trip per (shard, replica offset); per-key
    /// presence is OR-ed across copies so a key deleted from two replicas
    /// still counts once.  Errors only if some key was unreachable on
    /// *every* copy; a `moved:` bounce refetches the table and retries.
    fn del_keys(&mut self, keys: &[String]) -> Result<u64> {
        if keys.is_empty() {
            return Ok(0);
        }
        check_batch_len(keys.len())?;
        self.moved_retry(|s| s.del_keys_once(keys))
    }

    /// Broadcast: each shard instance applies the policy to its own store.
    /// A generation's keys scatter across shards, so each shard windows the
    /// generations *it* holds — cluster-wide, the newest `window`
    /// generations of every field are always fully retained.  Unreachable
    /// shards are tolerated (degraded) and pick the policy back up when
    /// reconfigured after recovery.
    fn set_retention(&mut self, cfg: RetentionConfig) -> Result<()> {
        self.broadcast(|c| c.set_retention(cfg))
    }

    /// `true` if any reachable copy has the key.
    fn exists(&mut self, key: &str) -> Result<bool> {
        self.moved_retry(|s| s.read_any(key, |c| c.exists(key), |&b| !b))
    }

    /// One blocking `PollKeys` per shard that owns any of the keys; the
    /// total budget is shared (each shard gets what remains of `max_wait`).
    /// A dead primary fails over to its replicas — writes fanned out to
    /// them, so the keys appear there too.
    ///
    /// Polls carry no ownership check (a shard legitimately answers for
    /// keys it merely replicates), so a client whose table went stale
    /// *while parked* cannot be bounced mid-poll; instead, a timed-out
    /// poll refetches the table, and if the epoch advanced — the keys may
    /// have been landing on the new owner the whole time — the poll is
    /// retried once against the fresh routing.
    fn poll_keys(&mut self, keys: &[String], poll: &PollConfig) -> Result<()> {
        match self.poll_keys_once(keys, poll) {
            Err(Error::Timeout(m)) => {
                let before = self.table.epoch;
                if self.refresh_slot_table().unwrap_or(before) > before {
                    self.epoch_refreshes += 1;
                    self.poll_keys_once(keys, poll)
                } else {
                    Err(Error::Timeout(m))
                }
            }
            other => other,
        }
    }

    /// Fans out to every replica target, like `put_tensor`.
    fn put_meta(&mut self, key: &str, value: &str) -> Result<()> {
        let req = Request::PutMeta { key: key.to_string(), value: value.to_string() };
        self.moved_retry(|s| s.replicated_write(key, req.clone()))
    }

    /// Primary first, then replicas; `Ok(None)` is a miss that falls
    /// through to the next copy.
    fn get_meta(&mut self, key: &str) -> Result<Option<String>> {
        self.moved_retry(|s| s.read_any(key, |c| c.get_meta(key), |v| v.is_none()))
    }

    /// Keys across all reachable shards (merged + sorted + deduped —
    /// replication stores a key on several shards).
    fn list_keys(&mut self, prefix: &str) -> Result<Vec<String>> {
        self.merged_keys(|c| c.list_keys(prefix))
    }

    /// Cold-tier keys across all reachable shards (merged + sorted +
    /// deduped) — each shard spilled the keys it evicted locally.
    fn cold_list(&mut self, prefix: &str) -> Result<Vec<String>> {
        self.merged_keys(|c| c.cold_list(prefix))
    }

    /// A key spills on the shard that evicted it, so cold routing starts
    /// where hot routing points — the replica walk included, since each
    /// copy's shard may have spilled its copy independently.  But the
    /// cold tier is **node-local and never migrates**: after a reshard
    /// (or a generation retired to a single anchor shard) the spill may
    /// live on a shard the current table no longer points at, so a ring
    /// miss widens to the remaining shards before reporting not-found.
    fn cold_get(&mut self, key: &str) -> Result<Tensor> {
        let mut order = self.read_targets(key);
        for s in 0..self.shards.len() {
            if !order.contains(&s) {
                order.push(s);
            }
        }
        self.read_any_on(&order, key, |c| c.cold_get(key), |_| false)
    }

    /// Models are broadcast to every shard, so `run_model` can execute
    /// wherever its inputs land.  A publish succeeds as long as at least
    /// one shard took it — shards that are down miss the upload (counted
    /// in `degraded_ops` and reported via [`ClusterClient::shard_errors`]),
    /// so one dead shard can't block a checkpoint publish; re-upload after
    /// recovery, or route inference away from them.  Returns the highest
    /// version any shard assigned (shards version independently, and a
    /// shard that missed earlier publishes may lag).
    fn put_model(&mut self, key: &str, hlo_text: &str) -> Result<u64> {
        let got = self.broadcast_collect(|c| c.put_model(key, hlo_text))?;
        Ok(got.into_iter().map(|(_, v)| v).max().unwrap_or(0))
    }

    /// Executes on the shard owning the first input key.  Inputs owned by
    /// other shards are staged onto the target first, and outputs are moved
    /// to their owning shards afterwards, so a later `get_tensor(out_key)`
    /// routes correctly.  Cross-shard tensor movement costs extra round
    /// trips — co-locate inference keys with `{hash tags}` to avoid it.
    fn run_model_version(
        &mut self,
        key: &str,
        version: u64,
        in_keys: &[String],
        out_keys: &[String],
        device: Device,
    ) -> Result<()> {
        let target = in_keys
            .first()
            .map(|k| self.table.shard_for_key(k))
            .unwrap_or(0);
        let mut staged: Vec<&String> = Vec::new();
        for k in in_keys {
            if self.table.shard_for_key(k) != target {
                // Failover-aware read; the staged copy is transient, so it
                // goes to the target only (not replicated).
                let t = self.get_tensor(k)?;
                self.on_shard(target, |c| c.put_tensor(k, &t))?;
                staged.push(k);
            }
        }
        self.on_shard(target, |c| {
            c.run_model_version(key, version, in_keys, out_keys, device)
        })?;
        for k in out_keys {
            let owner = self.table.shard_for_key(k);
            if owner != target {
                let t = self.on_shard(target, |c| c.get_tensor(k))?;
                // Outputs are real data: replicate them like any write so
                // later reads can fail over.  Only scrub the target's
                // staging copy if the target isn't itself a replica home
                // for this key.
                self.put_tensor(k, &t)?;
                if !self.targets(k).contains(&target) {
                    self.on_shard(target, |c| c.del_tensor(k))?;
                }
            }
        }
        for k in staged {
            if !self.targets(k).contains(&target) {
                self.on_shard(target, |c| c.del_tensor(k))?;
            }
        }
        Ok(())
    }

    /// Merged per-key listing: uploads broadcast, so the same key exists on
    /// every shard with independently assigned versions.  Per key, the
    /// live version and version count are the maxima across shards (the
    /// most advanced copy), while swaps and executions sum (every shard
    /// swapped and executed on its own).
    fn list_models(&mut self) -> Result<Vec<crate::proto::ModelEntry>> {
        let got = self.broadcast_collect(|c| c.list_models())?;
        let mut merged: Vec<crate::proto::ModelEntry> = Vec::new();
        for (_, entries) in got {
            for e in entries {
                match merged.iter_mut().find(|m| m.key == e.key) {
                    Some(m) => {
                        m.live_version = m.live_version.max(e.live_version);
                        m.n_versions = m.n_versions.max(e.n_versions);
                        m.swaps += e.swaps;
                        m.executions += e.executions;
                    }
                    None => merged.push(e),
                }
            }
        }
        merged.sort_by(|a, b| a.key.cmp(&b.key));
        Ok(merged)
    }

    /// Merged per-device stats: executions and sample counts sum, and the
    /// eval/queue moments pool exactly (weighted mean, pooled variance) —
    /// the merged row is what one server would have reported had it run
    /// every shard's executions itself.
    fn model_stats(&mut self) -> Result<Vec<crate::proto::ModelDeviceStat>> {
        let got = self.broadcast_collect(|c| c.model_stats())?;
        let mut merged: Vec<crate::proto::ModelDeviceStat> = Vec::new();
        for (_, rows) in got {
            for r in rows {
                match merged.iter_mut().find(|m| m.device == r.device) {
                    Some(m) => {
                        m.executions += r.executions;
                        let (c, mean, std) = pool_moments(
                            (m.eval_count, m.eval_mean_s, m.eval_std_s),
                            (r.eval_count, r.eval_mean_s, r.eval_std_s),
                        );
                        m.eval_count = c;
                        m.eval_mean_s = mean;
                        m.eval_std_s = std;
                        let (c, mean, std) = pool_moments(
                            (m.queue_count, m.queue_mean_s, m.queue_std_s),
                            (r.queue_count, r.queue_mean_s, r.queue_std_s),
                        );
                        m.queue_count = c;
                        m.queue_mean_s = mean;
                        m.queue_std_s = std;
                    }
                    None => merged.push(r),
                }
            }
        }
        merged.sort_by_key(|m| match m.device {
            Device::Cpu => u16::MAX,
            Device::Gpu(i) => i as u16,
        });
        Ok(merged)
    }

    /// Sums keys/bytes/ops and the eviction/high-water/backpressure
    /// counters across shards, and merges per-field pressure by field name
    /// (a field's generations scatter across shards).  `models` is the
    /// per-shard maximum (uploads are broadcast, so summing would
    /// multiply-count); `engine` is the first shard's; the window/TTL
    /// policy is the broadcast value while `retention_max_bytes` sums to
    /// the cluster-wide byte budget.  The summed high-water mark is an
    /// upper bound on cluster-wide peak residency (shards may not peak
    /// simultaneously).
    ///
    /// Unreachable shards are skipped — their counters are simply absent
    /// from the aggregate (degraded, see [`ClusterClient::shard_errors`]).
    /// The four client-side replication/failover counters are filled in
    /// from [`FailoverStats`]: individual servers cannot observe them and
    /// always report zero.
    fn info(&mut self) -> Result<DbInfo> {
        self.last_errors.clear();
        let mut agg = DbInfo::default();
        let mut ok = 0usize;
        let mut errs: Vec<(usize, Error)> = Vec::new();
        for idx in 0..self.shards.len() {
            let i = match self.on_shard(idx, |c| c.info()) {
                Ok(i) => {
                    ok += 1;
                    i
                }
                Err(e) => {
                    errs.push((idx, e));
                    continue;
                }
            };
            agg.keys += i.keys;
            agg.bytes += i.bytes;
            agg.ops += i.ops;
            agg.models = agg.models.max(i.models);
            agg.high_water_bytes += i.high_water_bytes;
            agg.evicted_keys += i.evicted_keys;
            agg.evicted_bytes += i.evicted_bytes;
            agg.busy_rejections += i.busy_rejections;
            agg.ttl_expired_keys += i.ttl_expired_keys;
            agg.retention_window = agg.retention_window.max(i.retention_window);
            agg.retention_max_bytes += i.retention_max_bytes;
            agg.retention_ttl_ms = agg.retention_ttl_ms.max(i.retention_ttl_ms);
            agg.spilled_keys += i.spilled_keys;
            agg.spilled_bytes += i.spilled_bytes;
            agg.spill_segments += i.spill_segments;
            agg.cold_hits += i.cold_hits;
            agg.spill_lost_keys += i.spill_lost_keys;
            agg.model_swaps += i.model_swaps;
            agg.batches += i.batches;
            agg.batched_requests += i.batched_requests;
            if agg.engine.is_empty() {
                agg.engine = i.engine;
            }
            for f in i.fields {
                match agg.fields.iter_mut().find(|a| a.field == f.field) {
                    Some(a) => {
                        a.resident_bytes += f.resident_bytes;
                        a.generations += f.generations;
                        a.evicted_keys += f.evicted_keys;
                        a.evicted_bytes += f.evicted_bytes;
                        // A field's generations scatter across shards, so
                        // its spill records do too — same merge-by-name
                        // path as the resident pressure counters.
                        a.spilled_keys += f.spilled_keys;
                        a.spilled_bytes += f.spilled_bytes;
                    }
                    None => agg.fields.push(f),
                }
            }
        }
        if ok == 0 {
            return Err(errs.swap_remove(0).1);
        }
        if !errs.is_empty() {
            self.note_degraded(&errs);
        }
        agg.fields.sort_by(|a, b| a.field.cmp(&b.field));
        agg.replicated_writes = self.stats.replicated_writes;
        agg.read_failovers = self.stats.read_failovers;
        agg.shard_reconnects = self.stats.shard_reconnects;
        agg.degraded_ops = self.stats.degraded_ops;
        Ok(agg)
    }

    fn flush_all(&mut self) -> Result<()> {
        self.broadcast(|c| c.flush_all())
    }

    /// Partitions the pipeline per owning shard, executes one sub-batch
    /// frame per shard, and reassembles results in submission order.  Every
    /// entry must carry a routing key ([`Request::routing_key`]); use the
    /// dedicated trait methods for whole-database operations.
    ///
    /// With replication there is one *round* of sub-batches per replica
    /// offset, and each round is **multiplexed**: every shard's sub-batch
    /// is sent as one tagged frame before any reply is read, so a round
    /// costs the slowest shard, not the sum of all shards.  Writes run in
    /// every round (fan out); reads only re-run while they lack an
    /// authoritative answer (primary dead or key missing there), and per
    /// entry the best-ranked response wins ([`resp_rank`]): success > miss
    /// > busy > error.  An entry that got *no* response — every target
    /// shard unreachable — fails the call with the first transport error,
    /// which is also the clean `replicas = 1` degradation.
    fn execute(&mut self, pipeline: Pipeline) -> Result<Vec<Response>> {
        let reqs = pipeline.into_requests();
        let n = reqs.len();
        check_batch_len(n)?;
        for (i, r) in reqs.iter().enumerate() {
            if r.routing_key().is_none() {
                return Err(Error::Invalid(format!(
                    "pipeline entry {i} has no routing key ({r:?}); \
                     use the dedicated ClusterClient method instead"
                )));
            }
        }
        let mut out = self.execute_subset(&reqs, (0..n).collect(), false)?;
        // Entries bounced by a shard that no longer owns their slot are
        // re-routed through a refreshed table.  Only the bounced entries
        // re-run, so writes that already applied are not replayed (a
        // replayed `DelTensor` would flip its result to `false`).  Bounced
        // *reads* re-run against the old owner's ring: mid-migration the
        // new ring bounces misses for keys the transfer has not landed
        // yet, and the old ring is where those keys still live.
        for _ in 0..MAX_MOVED_RETRIES {
            let moved: Vec<usize> =
                (0..n).filter(|&i| moved_epoch(&out[i]).is_some()).collect();
            if moved.is_empty() {
                break;
            }
            self.epoch_refreshes += 1;
            self.refresh_slot_table()?;
            let (writes, reads): (Vec<usize>, Vec<usize>) =
                moved.into_iter().partition(|&i| is_write_request(&reqs[i]));
            for (idxs, route_old) in [(writes, false), (reads, true)] {
                if idxs.is_empty() {
                    continue;
                }
                let redo = self.execute_subset(&reqs, idxs.clone(), route_old)?;
                for (i, r) in idxs.into_iter().zip(redo) {
                    out[i] = r;
                }
            }
        }
        // Reads that missed while their slot is mid-migration re-run
        // against the old owner's ring — the transfer may simply not have
        // landed their key on the new owner yet.
        let lagging: Vec<usize> = (0..n)
            .filter(|&i| {
                !is_write_request(&reqs[i])
                    && matches!(&out[i], Response::NotFound | Response::Bool(false))
                    && reqs[i]
                        .routing_key()
                        .map(|k| self.table.fallback_for_slot(hash_slot(k)).is_some())
                        .unwrap_or(false)
            })
            .collect();
        if !lagging.is_empty() {
            let redo = self.execute_subset(&reqs, lagging.clone(), true)?;
            for (i, r) in lagging.into_iter().zip(redo) {
                if resp_rank(&r) == 3 {
                    out[i] = r;
                }
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proto::frame::write_tagged_frame;
    use std::io::Read as _;
    use std::net::TcpListener;

    /// A raw-socket fake server that answers with a tag the client never
    /// issued: the reply must fail the connection cleanly instead of being
    /// stashed forever (unbounded memory on a misbehaving server).
    #[test]
    fn unknown_tag_reply_is_a_protocol_error() {
        let listener = TcpListener::bind("127.0.0.1:0".parse::<SocketAddr>().unwrap()).unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (mut sock, _) = listener.accept().unwrap();
            // Swallow (part of) the client's request frame, then reply
            // with a never-issued tag.
            let mut junk = [0u8; 64];
            let _ = sock.read(&mut junk);
            let mut body = Vec::new();
            Response::Ok.encode(&mut body);
            write_tagged_frame(&mut sock, 9999, &body).unwrap();
            // Hold the socket open so the client fails on the tag check,
            // not on EOF.
            std::thread::sleep(Duration::from_millis(200));
        });
        let mut c = Client::connect_with(addr, Some(Duration::from_secs(2)), None).unwrap();
        let tag = c.send_tagged(&Request::Info).unwrap();
        match c.recv_tagged(tag) {
            Err(Error::Protocol(m)) => {
                assert!(m.contains("unknown tag"), "unexpected message: {m}")
            }
            other => panic!("expected a protocol error, got {other:?}"),
        }
        server.join().unwrap();
    }

    /// Same shape, but the bogus reply arrives while the client is blocked
    /// in the legacy `read_response` path — the guard covers both loops.
    #[test]
    fn unknown_tag_reply_fails_legacy_reads_too() {
        let listener = TcpListener::bind("127.0.0.1:0".parse::<SocketAddr>().unwrap()).unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (mut sock, _) = listener.accept().unwrap();
            let mut junk = [0u8; 64];
            let _ = sock.read(&mut junk);
            let mut body = Vec::new();
            Response::Ok.encode(&mut body);
            write_tagged_frame(&mut sock, 7, &body).unwrap();
            std::thread::sleep(Duration::from_millis(200));
        });
        let mut c = Client::connect_with(addr, Some(Duration::from_secs(2)), None).unwrap();
        match c.call(&Request::Info) {
            Err(Error::Protocol(m)) => {
                assert!(m.contains("unknown tag"), "unexpected message: {m}")
            }
            other => panic!("expected a protocol error, got {other:?}"),
        }
        server.join().unwrap();
    }

    /// Abandoning in-flight tags must not leak: a reply already stashed is
    /// dropped at [`Client::forget_tags`] time, and one still in flight is
    /// drained and dropped when it arrives — instead of accumulating in
    /// the bounded stash until it fills and poisons the connection (the
    /// failure mode when a cluster fan-out aborts mid-collect).
    #[test]
    fn forgotten_tag_replies_are_drained_not_stashed() {
        let listener = TcpListener::bind("127.0.0.1:0".parse::<SocketAddr>().unwrap()).unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (mut sock, _) = listener.accept().unwrap();
            // Swallow (part of) the client's request frames; TCP buffers
            // absorb the rest — this fake never parses its input.
            let mut junk = [0u8; 256];
            let _ = sock.read(&mut junk);
            let reply = |sock: &mut std::net::TcpStream, tag: u32, r: Response| {
                let mut body = Vec::new();
                r.encode(&mut body);
                write_tagged_frame(sock, tag, &body).unwrap();
            };
            // Out-of-order completion: tag 1 first (will be stashed while
            // the client waits for tag 3), then tag 3, then the abandoned
            // tag 2, then the untagged reply for the follow-up call.
            reply(&mut sock, 1, Response::Ok);
            reply(&mut sock, 3, Response::Bool(true));
            reply(&mut sock, 2, Response::Ok);
            reply(&mut sock, 0, Response::Ok);
            std::thread::sleep(Duration::from_millis(200));
        });
        let mut c = Client::connect_with(addr, Some(Duration::from_secs(2)), None).unwrap();
        let t1 = c.send_tagged(&Request::Info).unwrap();
        let t2 = c.send_tagged(&Request::Info).unwrap();
        let t3 = c.send_tagged(&Request::Info).unwrap();
        assert_eq!((t1, t2, t3), (1, 2, 3));
        // Collecting tag 3 first forces tag 1's reply through the stash.
        match c.recv_tagged(t3).unwrap() {
            Response::Bool(true) => {}
            other => panic!("expected tag 3's reply, got {other:?}"),
        }
        assert_eq!(c.stashed_replies(), 1, "tag 1's reply should be stashed");
        // Abandon both: the stashed reply is dropped now, the in-flight
        // one (tag 2) when it arrives.
        c.forget_tags([t1, t2]);
        assert_eq!(c.stashed_replies(), 0, "forgetting must drop the stashed reply");
        // The follow-up call reads past tag 2's late reply (drained, not
        // stashed, not a protocol error) to its own untagged answer.
        match c.call(&Request::Info).unwrap() {
            Response::Ok => {}
            other => panic!("expected the untagged reply, got {other:?}"),
        }
        assert_eq!(c.stashed_replies(), 0, "drained reply must not be stashed");
        assert_eq!(c.outstanding_tags(), 0, "no tags left outstanding");
        server.join().unwrap();
    }
}
