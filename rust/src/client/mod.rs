//! SmartRedis-analogue client library.
//!
//! The paper's integration claim is that coupling a simulation to the
//! framework costs *one line per operation*: initialize a client, send a
//! tensor, retrieve a tensor, run a model.  This module keeps that surface
//! and makes it **deployment-portable**: the [`DataStore`] trait captures
//! the full operation set (tensors, metadata, polling, models, stats), and
//! both [`Client`] (one co-located database) and [`ClusterClient`]
//! (redis-cluster-style hash-slot routing across shards) implement it.
//! Dataloaders, trainers, and examples are written once against the trait
//! and run unchanged on either deployment.
//!
//! ```no_run
//! use situ::client::{Client, DataStore};
//! use situ::tensor::Tensor;
//! let mut c = Client::connect("127.0.0.1:7700".parse().unwrap()).unwrap();
//! c.put_tensor("field_rank0_step2", &Tensor::from_f32(&[4], vec![0.;4]).unwrap()).unwrap();
//! let t = c.get_tensor("field_rank0_step2").unwrap();
//! ```
//!
//! ## Pipelining
//!
//! Per-epoch training overhead is dominated by round trips (paper Table 2:
//! each ML rank fetches 6 tensors per epoch, polling each key first).  Three
//! batched paths collapse those loops to one request frame each:
//!
//! * [`Pipeline`] builds an ordered command batch executed by
//!   [`DataStore::execute`] — one frame out, one [`Response`] per command
//!   back, errors reported per entry;
//! * [`DataStore::mget_tensors`] gathers many tensors in one round trip,
//!   with every payload in the reply aliasing one frame allocation
//!   (zero-copy, as in the single-tensor path);
//! * [`DataStore::poll_keys`] waits **server-side** until all keys exist,
//!   replacing the old client busy-poll of `exists` requests; the probe
//!   interval backs off exponentially from [`PollConfig::initial`] up to
//!   [`PollConfig::cap`].
//!
//! ```no_run
//! use situ::client::{Client, DataStore, Pipeline};
//! use situ::tensor::Tensor;
//! let mut c = Client::connect("127.0.0.1:7700".parse().unwrap()).unwrap();
//! let t = Tensor::from_f32(&[4], vec![0.; 4]).unwrap();
//! let mut pipe = Pipeline::new();
//! pipe.put_tensor("a", &t).put_tensor("b", &t).put_meta("latest_step", "0");
//! for r in c.execute(pipe).unwrap() {
//!     r.expect_ok().unwrap();
//! }
//! ```
//!
//! On a [`ClusterClient`], single-key commands route to the owning shard;
//! a pipeline is partitioned per shard and results are reassembled in
//! submission order.

pub mod backpressure;

pub use backpressure::{GovernorConfig, GovernorStats, PublishGovernor, RetryPolicy};

use std::io::BufReader;
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

use crate::db::cluster::SlotMap;
use crate::db::store::RetentionConfig;
use crate::error::{Error, Result};
use crate::proto::frame::{begin_split_frame, end_split_frame, read_frame, FrameSink};
use crate::proto::{message, DbInfo, Device, Request, Response};
use crate::tensor::{Bytes, Tensor};

/// Key scheme used across the framework: tensors are unique per rank and
/// step so nothing is overwritten (paper §2.2).  Step keys are what the
/// store's sliding-window retention groups into generations
/// ([`crate::db::store::parse_step_key`]).
pub fn tensor_key(field: &str, rank: usize, step: u64) -> String {
    format!("{field}_rank{rank}_step{step}")
}

/// Key scheme for the paper's *overwrite* publishing mode: each rank
/// republishes its newest snapshot under a stable key, so the previous
/// generation is retired in place and memory is bounded by construction.
pub fn stable_key(field: &str, rank: usize) -> String {
    format!("{field}_rank{rank}_latest")
}

/// Reject oversized batches *before* streaming them: the server's decoder
/// enforces [`crate::proto::MAX_BATCH`] too, but failing client-side avoids
/// shipping a multi-gigabyte frame only to get a decode error back.
fn check_batch_len(n: usize) -> Result<()> {
    if n > crate::proto::MAX_BATCH {
        return Err(Error::Invalid(format!(
            "batch of {n} entries exceeds MAX_BATCH ({})",
            crate::proto::MAX_BATCH
        )));
    }
    Ok(())
}

/// Polling discipline for [`DataStore::poll_key`]/[`DataStore::poll_keys`]:
/// the probe interval starts at `initial` and doubles up to `cap` (the
/// knob that replaced the old fixed busy-poll interval), giving up after
/// `max_wait`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PollConfig {
    /// First probe interval.
    pub initial: Duration,
    /// Ceiling the exponential backoff saturates at.
    pub cap: Duration,
    /// Total wait budget before `Error::Timeout`.
    pub max_wait: Duration,
}

impl Default for PollConfig {
    fn default() -> Self {
        PollConfig {
            initial: Duration::from_micros(500),
            cap: Duration::from_millis(20),
            max_wait: Duration::from_secs(120),
        }
    }
}

impl PollConfig {
    pub fn new(initial: Duration, cap: Duration, max_wait: Duration) -> PollConfig {
        PollConfig { initial, cap, max_wait }
    }

    /// Default backoff shape with a custom total budget.
    pub fn with_max_wait(max_wait: Duration) -> PollConfig {
        PollConfig { max_wait, ..PollConfig::default() }
    }
}

/// An ordered batch of commands executed in one round trip per database
/// instance (see [`DataStore::execute`]).
///
/// Builder methods append one command each and return `&mut Self` so calls
/// chain; tensors are captured by refcount bump ([`Bytes`] payloads), never
/// deep-copied.  On a cluster, only single-key data-plane commands can be
/// pipelined (each entry must route somewhere); whole-database and model
/// commands return `Error::Invalid` there — use the dedicated trait
/// methods, which broadcast/stage correctly, instead.
#[derive(Debug, Default)]
pub struct Pipeline {
    reqs: Vec<Request>,
}

impl Pipeline {
    pub fn new() -> Pipeline {
        Pipeline::default()
    }

    pub fn put_tensor(&mut self, key: &str, t: &Tensor) -> &mut Pipeline {
        self.push(Request::PutTensor { key: key.to_string(), tensor: t.clone() })
    }

    pub fn get_tensor(&mut self, key: &str) -> &mut Pipeline {
        self.push(Request::GetTensor { key: key.to_string() })
    }

    /// Read a retired key back from the spill-to-disk cold tier (replies
    /// `Tensor` or `NotFound`).  Routes like `get_tensor`, so it pipelines
    /// on a cluster — the dataloader's cold fallback batches these.
    pub fn cold_get(&mut self, key: &str) -> &mut Pipeline {
        self.push(Request::ColdGet { key: key.to_string() })
    }

    pub fn del_tensor(&mut self, key: &str) -> &mut Pipeline {
        self.push(Request::DelTensor { key: key.to_string() })
    }

    pub fn exists(&mut self, key: &str) -> &mut Pipeline {
        self.push(Request::Exists { key: key.to_string() })
    }

    pub fn put_meta(&mut self, key: &str, value: &str) -> &mut Pipeline {
        self.push(Request::PutMeta { key: key.to_string(), value: value.to_string() })
    }

    pub fn get_meta(&mut self, key: &str) -> &mut Pipeline {
        self.push(Request::GetMeta { key: key.to_string() })
    }

    pub fn put_model(&mut self, key: &str, hlo_text: &str) -> &mut Pipeline {
        self.push(Request::PutModel { key: key.to_string(), hlo_text: hlo_text.to_string() })
    }

    pub fn run_model(
        &mut self,
        key: &str,
        in_keys: &[String],
        out_keys: &[String],
        device: Device,
    ) -> &mut Pipeline {
        self.push(Request::RunModel {
            key: key.to_string(),
            in_keys: in_keys.to_vec(),
            out_keys: out_keys.to_vec(),
            device,
        })
    }

    /// Append an already-built request (escape hatch for ops without a
    /// builder method).
    pub fn push(&mut self, req: Request) -> &mut Pipeline {
        self.reqs.push(req);
        self
    }

    pub fn len(&self) -> usize {
        self.reqs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.reqs.is_empty()
    }

    pub fn requests(&self) -> &[Request] {
        &self.reqs
    }

    pub fn into_requests(self) -> Vec<Request> {
        self.reqs
    }
}

/// The full database operation surface, implemented by both [`Client`]
/// (co-located deployment) and [`ClusterClient`] (clustered deployment).
///
/// Code written against `DataStore` — including via `dyn DataStore` — runs
/// on either deployment unchanged; this is the portability SmartSim
/// promises between Fig-2 deployment modes.
pub trait DataStore {
    /// Send a tensor (the paper's `put_tensor`).
    fn put_tensor(&mut self, key: &str, t: &Tensor) -> Result<()>;

    /// `put_tensor` with `Busy`-aware retry per `policy` (see
    /// [`backpressure::RetryPolicy`]): backpressure from a bounded store
    /// is retried with capped backoff, every other error surfaces
    /// immediately.  Returns the number of retries taken.
    fn put_tensor_retry(&mut self, key: &str, t: &Tensor, policy: &RetryPolicy) -> Result<u64> {
        let (res, retries) = policy.run(|| self.put_tensor(key, t));
        res.map(|()| retries)
    }

    /// Retrieve a tensor (the paper's `unpack_tensor`).
    fn get_tensor(&mut self, key: &str) -> Result<Tensor>;

    /// Gather many tensors in one round trip per database instance.
    /// Errors with `Error::KeyNotFound` on the first missing key.
    fn mget_tensors(&mut self, keys: &[String]) -> Result<Vec<Tensor>>;

    /// Delete a tensor; `Ok(false)` if it wasn't present.
    fn del_tensor(&mut self, key: &str) -> Result<bool>;

    /// Delete many tensors in one round trip per database instance
    /// (partitioned per shard on a cluster).  Returns how many were
    /// actually present and deleted.
    fn del_keys(&mut self, keys: &[String]) -> Result<u64>;

    /// Install a retention / capacity policy (broadcast to every shard on
    /// a cluster, so a clustered deployment's byte budget is
    /// `max_bytes × shards`).
    fn set_retention(&mut self, cfg: RetentionConfig) -> Result<()>;

    fn exists(&mut self, key: &str) -> Result<bool>;

    /// Block until `key` exists (the trainer waiting for the first
    /// snapshot — the paper's "metadata transfer" overhead in Table 2).
    fn poll_key(&mut self, key: &str, poll: &PollConfig) -> Result<()> {
        self.poll_keys(std::slice::from_ref(&key.to_string()), poll)
    }

    /// Block until *every* key exists, in one round trip per database
    /// instance: the server waits with capped exponential backoff instead
    /// of the client re-asking per key.
    fn poll_keys(&mut self, keys: &[String], poll: &PollConfig) -> Result<()>;

    fn put_meta(&mut self, key: &str, value: &str) -> Result<()>;

    fn get_meta(&mut self, key: &str) -> Result<Option<String>>;

    /// All tensor keys with a prefix, sorted (merged across shards on a
    /// cluster).
    fn list_keys(&mut self, prefix: &str) -> Result<Vec<String>>;

    /// Keys resident in the spill-to-disk cold tier with a prefix, sorted
    /// (merged across shards on a cluster).  Empty when the server has no
    /// spill directory configured.
    fn cold_list(&mut self, prefix: &str) -> Result<Vec<String>>;

    /// Read a retired key back from the cold tier.  `KeyNotFound` when the
    /// key was never spilled (or spill is off) — strictly the cold tier;
    /// resident keys are served by [`DataStore::get_tensor`].
    fn cold_get(&mut self, key: &str) -> Result<Tensor>;

    /// Upload a model artifact (HLO text) into the model registry.
    fn put_model(&mut self, key: &str, hlo_text: &str) -> Result<()>;

    /// Upload a model from an artifact file.
    fn put_model_from_file(&mut self, key: &str, path: &std::path::Path) -> Result<()> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Parse(format!("read {}: {e}", path.display())))?;
        self.put_model(key, &text)
    }

    /// RedisAI-style in-database inference over stored tensors.
    fn run_model(
        &mut self,
        key: &str,
        in_keys: &[String],
        out_keys: &[String],
        device: Device,
    ) -> Result<()>;

    /// Database statistics (aggregated across shards on a cluster).
    fn info(&mut self) -> Result<DbInfo>;

    fn flush_all(&mut self) -> Result<()>;

    /// Execute a [`Pipeline`]: one request frame per database instance, one
    /// [`Response`] per command in submission order.  A failing entry
    /// yields `Response::Error` in its slot; later entries still run.
    fn execute(&mut self, pipeline: Pipeline) -> Result<Vec<Response>>;
}

/// A connection to one database instance.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    buf: Vec<u8>,
    pub addr: SocketAddr,
}

impl Client {
    /// Connect (the paper's `SmartRedis client initialization`, measured at
    /// ~2 ms in Table 1).
    pub fn connect(addr: SocketAddr) -> Result<Client> {
        let sock = TcpStream::connect(addr)?;
        sock.set_nodelay(true)?;
        let writer = sock.try_clone()?;
        Ok(Client {
            reader: BufReader::with_capacity(256 * 1024, sock),
            writer,
            buf: Vec::with_capacity(64 * 1024),
            addr,
        })
    }

    /// Connect with retries (components race the DB at startup).  Sleeps
    /// `delay` between attempts — not after the last failed one.
    pub fn connect_retry(addr: SocketAddr, tries: usize, delay: Duration) -> Result<Client> {
        let tries = tries.max(1);
        let mut last = None;
        for attempt in 0..tries {
            match Client::connect(addr) {
                Ok(c) => return Ok(c),
                Err(e) => {
                    last = Some(e);
                    if attempt + 1 < tries {
                        std::thread::sleep(delay);
                    }
                }
            }
        }
        Err(last.unwrap_or_else(|| Error::Invalid("connect_retry with 0 tries".into())))
    }

    /// Read one response frame and decode it sharing the frame body — a
    /// tensor reply's payload (every tensor in a batch reply) aliases the
    /// freshly-read buffer (zero copy).
    fn read_response(&mut self) -> Result<Response> {
        match read_frame(&mut self.reader)? {
            Some(body) => Response::decode_shared(&Bytes::from_vec(body)),
            None => Err(Error::Io(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "server closed connection",
            ))),
        }
    }

    fn call(&mut self, req: &Request) -> Result<Response> {
        self.buf.clear();
        req.encode(&mut self.buf);
        crate::proto::frame::write_frame(&mut self.writer, &self.buf)?;
        self.read_response()
    }

    /// Send a slice of requests as one `Batch` frame and return the
    /// per-entry results.  Tensor payloads are streamed from their owning
    /// buffers (no encode-time copy); this is the transport behind
    /// [`DataStore::execute`] and the cluster's per-shard sub-batches.
    pub fn exec_requests(&mut self, reqs: &[Request]) -> Result<Vec<Response>> {
        if reqs.is_empty() {
            return Ok(Vec::new());
        }
        check_batch_len(reqs.len())?;
        let body = 1 + 4 + reqs.iter().map(|r| r.body_wire_size()).sum::<usize>();
        let mut sink = FrameSink::begin(&mut self.writer, &mut self.buf, body)?;
        sink.encode_with(|b| message::encode_batch_request_header_into(b, reqs.len()))?;
        for r in reqs {
            match r {
                Request::PutTensor { key, tensor } => {
                    sink.encode_with(|b| {
                        message::encode_put_tensor_header_into(b, key, tensor)
                    })?;
                    sink.write(&tensor.data)?;
                }
                other => sink.encode_with(|b| other.encode(b))?,
            }
        }
        sink.finish()?;
        self.read_response()?.expect_batch(reqs.len())
    }
}

impl DataStore for Client {
    /// Writes a split frame: the small header is encoded into the reusable
    /// buffer, the payload goes from the borrowed tensor straight to the
    /// socket — zero payload copies.
    fn put_tensor(&mut self, key: &str, t: &Tensor) -> Result<()> {
        begin_split_frame(&mut self.buf);
        message::encode_put_tensor_header_into(&mut self.buf, key, t);
        end_split_frame(&mut self.writer, &mut self.buf, &t.data)?;
        self.read_response()?.expect_ok()
    }

    /// The returned tensor's payload aliases the response frame read off
    /// the socket — one allocation, no decode-time copy.
    fn get_tensor(&mut self, key: &str) -> Result<Tensor> {
        self.call(&Request::GetTensor { key: key.to_string() })?
            .expect_tensor(key)
    }

    fn mget_tensors(&mut self, keys: &[String]) -> Result<Vec<Tensor>> {
        if keys.is_empty() {
            return Ok(Vec::new());
        }
        check_batch_len(keys.len())?;
        let entries = self
            .call(&Request::MGetTensors { keys: keys.to_vec() })?
            .expect_batch(keys.len())?;
        entries
            .into_iter()
            .zip(keys)
            .map(|(r, k)| r.expect_tensor(k))
            .collect()
    }

    fn del_tensor(&mut self, key: &str) -> Result<bool> {
        self.call(&Request::DelTensor { key: key.to_string() })?
            .expect_deleted()
    }

    fn del_keys(&mut self, keys: &[String]) -> Result<u64> {
        if keys.is_empty() {
            return Ok(0);
        }
        check_batch_len(keys.len())?;
        let entries = self
            .call(&Request::DelKeys { keys: keys.to_vec() })?
            .expect_batch(keys.len())?;
        let mut n = 0;
        for e in entries {
            if e.expect_deleted()? {
                n += 1;
            }
        }
        Ok(n)
    }

    fn set_retention(&mut self, cfg: RetentionConfig) -> Result<()> {
        self.call(&Request::Retention {
            window: cfg.window,
            max_bytes: cfg.max_bytes,
            ttl_ms: cfg.ttl_ms,
        })?
        .expect_ok()
    }

    fn exists(&mut self, key: &str) -> Result<bool> {
        self.call(&Request::Exists { key: key.to_string() })?
            .expect_bool()
    }

    fn poll_keys(&mut self, keys: &[String], poll: &PollConfig) -> Result<()> {
        check_batch_len(keys.len())?;
        let req = Request::PollKeys {
            keys: keys.to_vec(),
            // Round the budget *up* to whole milliseconds: truncation would
            // turn a sub-millisecond remainder (e.g. a cluster poll's last
            // shard) into a zero-timeout single probe.
            timeout_ms: poll.max_wait.as_micros().div_ceil(1000).min(u64::MAX as u128) as u64,
            initial_us: poll.initial.as_micros().min(u64::MAX as u128) as u64,
            cap_us: poll.cap.as_micros().min(u64::MAX as u128) as u64,
        };
        if self.call(&req)?.expect_bool()? {
            Ok(())
        } else {
            Err(Error::Timeout(format!(
                "keys {keys:?} not all present after {:?}",
                poll.max_wait
            )))
        }
    }

    fn put_meta(&mut self, key: &str, value: &str) -> Result<()> {
        self.call(&Request::PutMeta { key: key.to_string(), value: value.to_string() })?
            .expect_ok()
    }

    fn get_meta(&mut self, key: &str) -> Result<Option<String>> {
        self.call(&Request::GetMeta { key: key.to_string() })?
            .expect_meta()
    }

    fn list_keys(&mut self, prefix: &str) -> Result<Vec<String>> {
        self.call(&Request::ListKeys { prefix: prefix.to_string() })?
            .expect_keys()
    }

    fn cold_list(&mut self, prefix: &str) -> Result<Vec<String>> {
        self.call(&Request::ColdList { prefix: prefix.to_string() })?
            .expect_keys()
    }

    /// Like `get_tensor`, the reply payload aliases the response frame —
    /// cold reads are zero-copy client-side too.
    fn cold_get(&mut self, key: &str) -> Result<Tensor> {
        self.call(&Request::ColdGet { key: key.to_string() })?
            .expect_tensor(key)
    }

    fn put_model(&mut self, key: &str, hlo_text: &str) -> Result<()> {
        self.call(&Request::PutModel {
            key: key.to_string(),
            hlo_text: hlo_text.to_string(),
        })?
        .expect_ok()
    }

    fn run_model(
        &mut self,
        key: &str,
        in_keys: &[String],
        out_keys: &[String],
        device: Device,
    ) -> Result<()> {
        self.call(&Request::RunModel {
            key: key.to_string(),
            in_keys: in_keys.to_vec(),
            out_keys: out_keys.to_vec(),
            device,
        })?
        .expect_ok()
    }

    fn info(&mut self) -> Result<DbInfo> {
        self.call(&Request::Info)?.expect_info()
    }

    fn flush_all(&mut self) -> Result<()> {
        self.call(&Request::FlushAll)?.expect_ok()
    }

    fn execute(&mut self, pipeline: Pipeline) -> Result<Vec<Response>> {
        self.exec_requests(&pipeline.into_requests())
    }
}

/// Client for the clustered deployment: routes each key to the owning shard
/// via the redis-cluster hash-slot map, and implements the complete
/// [`DataStore`] surface — multi-key operations are partitioned per shard
/// and reassembled, models are broadcast to every shard, `info` aggregates.
pub struct ClusterClient {
    shards: Vec<Client>,
    slots: SlotMap,
}

impl ClusterClient {
    pub fn connect(addrs: &[SocketAddr]) -> Result<ClusterClient> {
        let shards = addrs
            .iter()
            .map(|a| Client::connect(*a))
            .collect::<Result<Vec<_>>>()?;
        Ok(ClusterClient { slots: SlotMap::new(shards.len()), shards })
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    fn route(&mut self, key: &str) -> &mut Client {
        let i = self.slots.shard_for_key(key);
        &mut self.shards[i]
    }

    /// Partition indices `0..keys.len()` by owning shard.
    fn partition_keys(&self, keys: &[String]) -> Vec<Vec<usize>> {
        let mut by_shard: Vec<Vec<usize>> = vec![Vec::new(); self.shards.len()];
        for (i, k) in keys.iter().enumerate() {
            by_shard[self.slots.shard_for_key(k)].push(i);
        }
        by_shard
    }
}

impl DataStore for ClusterClient {
    fn put_tensor(&mut self, key: &str, t: &Tensor) -> Result<()> {
        self.route(key).put_tensor(key, t)
    }

    fn get_tensor(&mut self, key: &str) -> Result<Tensor> {
        self.route(key).get_tensor(key)
    }

    /// One `MGetTensors` round trip per shard that owns any of the keys.
    fn mget_tensors(&mut self, keys: &[String]) -> Result<Vec<Tensor>> {
        let by_shard = self.partition_keys(keys);
        let mut out: Vec<Option<Tensor>> = keys.iter().map(|_| None).collect();
        for (shard, idxs) in by_shard.into_iter().enumerate() {
            if idxs.is_empty() {
                continue;
            }
            let sub: Vec<String> = idxs.iter().map(|&i| keys[i].clone()).collect();
            let got = self.shards[shard].mget_tensors(&sub)?;
            for (i, t) in idxs.into_iter().zip(got) {
                out[i] = Some(t);
            }
        }
        Ok(out.into_iter().map(|t| t.expect("all partitions filled")).collect())
    }

    fn del_tensor(&mut self, key: &str) -> Result<bool> {
        self.route(key).del_tensor(key)
    }

    /// One `DelKeys` round trip per shard that owns any of the keys.
    fn del_keys(&mut self, keys: &[String]) -> Result<u64> {
        let by_shard = self.partition_keys(keys);
        let mut n = 0;
        for (shard, idxs) in by_shard.into_iter().enumerate() {
            if idxs.is_empty() {
                continue;
            }
            let sub: Vec<String> = idxs.iter().map(|&i| keys[i].clone()).collect();
            n += self.shards[shard].del_keys(&sub)?;
        }
        Ok(n)
    }

    /// Broadcast: each shard instance applies the policy to its own store.
    /// A generation's keys scatter across shards, so each shard windows the
    /// generations *it* holds — cluster-wide, the newest `window`
    /// generations of every field are always fully retained.
    fn set_retention(&mut self, cfg: RetentionConfig) -> Result<()> {
        for c in &mut self.shards {
            c.set_retention(cfg)?;
        }
        Ok(())
    }

    fn exists(&mut self, key: &str) -> Result<bool> {
        self.route(key).exists(key)
    }

    /// One blocking `PollKeys` per shard that owns any of the keys; the
    /// total budget is shared (each shard gets what remains of `max_wait`).
    fn poll_keys(&mut self, keys: &[String], poll: &PollConfig) -> Result<()> {
        let deadline = std::time::Instant::now() + poll.max_wait;
        let by_shard = self.partition_keys(keys);
        for (shard, idxs) in by_shard.into_iter().enumerate() {
            if idxs.is_empty() {
                continue;
            }
            let sub: Vec<String> = idxs.iter().map(|&i| keys[i].clone()).collect();
            let remaining = deadline.saturating_duration_since(std::time::Instant::now());
            let budget = PollConfig { max_wait: remaining, ..*poll };
            self.shards[shard].poll_keys(&sub, &budget).map_err(|e| match e {
                // Rewrite per-shard timeouts to name the whole key set.
                Error::Timeout(_) => Error::Timeout(format!(
                    "keys {keys:?} not all present after {:?}",
                    poll.max_wait
                )),
                other => other,
            })?;
        }
        Ok(())
    }

    fn put_meta(&mut self, key: &str, value: &str) -> Result<()> {
        self.route(key).put_meta(key, value)
    }

    fn get_meta(&mut self, key: &str) -> Result<Option<String>> {
        self.route(key).get_meta(key)
    }

    /// Keys across all shards (merged + sorted).
    fn list_keys(&mut self, prefix: &str) -> Result<Vec<String>> {
        let mut all = Vec::new();
        for c in &mut self.shards {
            all.extend(c.list_keys(prefix)?);
        }
        all.sort();
        Ok(all)
    }

    /// Cold-tier keys across all shards (merged + sorted) — each shard
    /// spilled the keys it evicted locally.
    fn cold_list(&mut self, prefix: &str) -> Result<Vec<String>> {
        let mut all = Vec::new();
        for c in &mut self.shards {
            all.extend(c.cold_list(prefix)?);
        }
        all.sort();
        Ok(all)
    }

    /// Routes to the owning shard: a key spills on the shard it hashes to
    /// (that shard evicted it), so cold routing equals hot routing.
    fn cold_get(&mut self, key: &str) -> Result<Tensor> {
        self.route(key).cold_get(key)
    }

    /// Models are broadcast to every shard, so `run_model` can execute
    /// wherever its inputs land.
    fn put_model(&mut self, key: &str, hlo_text: &str) -> Result<()> {
        for c in &mut self.shards {
            c.put_model(key, hlo_text)?;
        }
        Ok(())
    }

    /// Executes on the shard owning the first input key.  Inputs owned by
    /// other shards are staged onto the target first, and outputs are moved
    /// to their owning shards afterwards, so a later `get_tensor(out_key)`
    /// routes correctly.  Cross-shard tensor movement costs extra round
    /// trips — co-locate inference keys with `{hash tags}` to avoid it.
    fn run_model(
        &mut self,
        key: &str,
        in_keys: &[String],
        out_keys: &[String],
        device: Device,
    ) -> Result<()> {
        let target = in_keys
            .first()
            .map(|k| self.slots.shard_for_key(k))
            .unwrap_or(0);
        let mut staged: Vec<&String> = Vec::new();
        for k in in_keys {
            if self.slots.shard_for_key(k) != target {
                let t = self.route(k).get_tensor(k)?;
                self.shards[target].put_tensor(k, &t)?;
                staged.push(k);
            }
        }
        self.shards[target].run_model(key, in_keys, out_keys, device)?;
        for k in out_keys {
            let owner = self.slots.shard_for_key(k);
            if owner != target {
                let t = self.shards[target].get_tensor(k)?;
                self.shards[owner].put_tensor(k, &t)?;
                self.shards[target].del_tensor(k)?;
            }
        }
        for k in staged {
            self.shards[target].del_tensor(k)?;
        }
        Ok(())
    }

    /// Sums keys/bytes/ops and the eviction/high-water/backpressure
    /// counters across shards, and merges per-field pressure by field name
    /// (a field's generations scatter across shards).  `models` is the
    /// per-shard maximum (uploads are broadcast, so summing would
    /// multiply-count); `engine` is the first shard's; the window/TTL
    /// policy is the broadcast value while `retention_max_bytes` sums to
    /// the cluster-wide byte budget.  The summed high-water mark is an
    /// upper bound on cluster-wide peak residency (shards may not peak
    /// simultaneously).
    fn info(&mut self) -> Result<DbInfo> {
        let mut agg = DbInfo::default();
        for c in &mut self.shards {
            let i = c.info()?;
            agg.keys += i.keys;
            agg.bytes += i.bytes;
            agg.ops += i.ops;
            agg.models = agg.models.max(i.models);
            agg.high_water_bytes += i.high_water_bytes;
            agg.evicted_keys += i.evicted_keys;
            agg.evicted_bytes += i.evicted_bytes;
            agg.busy_rejections += i.busy_rejections;
            agg.ttl_expired_keys += i.ttl_expired_keys;
            agg.retention_window = agg.retention_window.max(i.retention_window);
            agg.retention_max_bytes += i.retention_max_bytes;
            agg.retention_ttl_ms = agg.retention_ttl_ms.max(i.retention_ttl_ms);
            agg.spilled_keys += i.spilled_keys;
            agg.spilled_bytes += i.spilled_bytes;
            agg.spill_segments += i.spill_segments;
            agg.cold_hits += i.cold_hits;
            agg.spill_lost_keys += i.spill_lost_keys;
            if agg.engine.is_empty() {
                agg.engine = i.engine;
            }
            for f in i.fields {
                match agg.fields.iter_mut().find(|a| a.field == f.field) {
                    Some(a) => {
                        a.resident_bytes += f.resident_bytes;
                        a.generations += f.generations;
                        a.evicted_keys += f.evicted_keys;
                        a.evicted_bytes += f.evicted_bytes;
                        // A field's generations scatter across shards, so
                        // its spill records do too — same merge-by-name
                        // path as the resident pressure counters.
                        a.spilled_keys += f.spilled_keys;
                        a.spilled_bytes += f.spilled_bytes;
                    }
                    None => agg.fields.push(f),
                }
            }
        }
        agg.fields.sort_by(|a, b| a.field.cmp(&b.field));
        Ok(agg)
    }

    fn flush_all(&mut self) -> Result<()> {
        for c in &mut self.shards {
            c.flush_all()?;
        }
        Ok(())
    }

    /// Partitions the pipeline per owning shard, executes one sub-batch
    /// frame per shard, and reassembles results in submission order.  Every
    /// entry must carry a routing key ([`Request::routing_key`]); use the
    /// dedicated trait methods for whole-database operations.
    fn execute(&mut self, pipeline: Pipeline) -> Result<Vec<Response>> {
        let reqs = pipeline.into_requests();
        let n = reqs.len();
        let mut by_shard: Vec<Vec<(usize, Request)>> =
            self.shards.iter().map(|_| Vec::new()).collect();
        for (i, r) in reqs.into_iter().enumerate() {
            match r.routing_key() {
                Some(k) => {
                    let shard = self.slots.shard_for_key(k);
                    by_shard[shard].push((i, r));
                }
                None => {
                    return Err(Error::Invalid(format!(
                        "pipeline entry {i} has no routing key ({r:?}); \
                         use the dedicated ClusterClient method instead"
                    )))
                }
            }
        }
        let mut out: Vec<Option<Response>> = (0..n).map(|_| None).collect();
        for (shard, entries) in by_shard.into_iter().enumerate() {
            if entries.is_empty() {
                continue;
            }
            let (idxs, sub): (Vec<usize>, Vec<Request>) = entries.into_iter().unzip();
            let resps = self.shards[shard].exec_requests(&sub)?;
            for (i, r) in idxs.into_iter().zip(resps) {
                out[i] = Some(r);
            }
        }
        Ok(out.into_iter().map(|r| r.expect("all partitions filled")).collect())
    }
}
