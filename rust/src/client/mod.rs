//! SmartRedis-analogue client library.
//!
//! The paper's integration claim is that coupling a simulation to the
//! framework costs *one line per operation*: initialize a client, send a
//! tensor, retrieve a tensor, run a model.  This module keeps that surface:
//!
//! ```no_run
//! use situ::client::Client;
//! use situ::tensor::Tensor;
//! let mut c = Client::connect("127.0.0.1:7700".parse().unwrap()).unwrap();
//! c.put_tensor("field_rank0_step2", &Tensor::from_f32(&[4], vec![0.;4]).unwrap()).unwrap();
//! let t = c.get_tensor("field_rank0_step2").unwrap();
//! ```
//!
//! [`ClusterClient`] adds redis-cluster-style routing across sharded
//! databases for the clustered deployment.

use std::io::BufReader;
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

use crate::db::cluster::SlotMap;
use crate::error::{Error, Result};
use crate::proto::frame::{begin_split_frame, end_split_frame, read_frame, write_frame};
use crate::proto::{Device, Request, Response};
use crate::tensor::{Bytes, Tensor};

/// Key scheme used across the framework: tensors are unique per rank and
/// step so nothing is overwritten (paper §2.2).
pub fn tensor_key(field: &str, rank: usize, step: u64) -> String {
    format!("{field}_rank{rank}_step{step}")
}

/// A connection to one database instance.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    buf: Vec<u8>,
    pub addr: SocketAddr,
}

impl Client {
    /// Connect (the paper's `SmartRedis client initialization`, measured at
    /// ~2 ms in Table 1).
    pub fn connect(addr: SocketAddr) -> Result<Client> {
        let sock = TcpStream::connect(addr)?;
        sock.set_nodelay(true)?;
        let writer = sock.try_clone()?;
        Ok(Client {
            reader: BufReader::with_capacity(256 * 1024, sock),
            writer,
            buf: Vec::with_capacity(64 * 1024),
            addr,
        })
    }

    /// Connect with retries (components race the DB at startup).
    pub fn connect_retry(addr: SocketAddr, tries: usize, delay: Duration) -> Result<Client> {
        let mut last = None;
        for _ in 0..tries.max(1) {
            match Client::connect(addr) {
                Ok(c) => return Ok(c),
                Err(e) => {
                    last = Some(e);
                    std::thread::sleep(delay);
                }
            }
        }
        Err(last.unwrap_or_else(|| Error::Invalid("connect_retry with 0 tries".into())))
    }

    /// Read one response frame and decode it sharing the frame body — a
    /// tensor reply's payload aliases the freshly-read buffer (zero copy).
    fn read_response(&mut self) -> Result<Response> {
        match read_frame(&mut self.reader)? {
            Some(body) => Response::decode_shared(&Bytes::from_vec(body)),
            None => Err(Error::Io(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "server closed connection",
            ))),
        }
    }

    fn call(&mut self, req: &Request) -> Result<Response> {
        self.buf.clear();
        req.encode(&mut self.buf);
        write_frame(&mut self.writer, &self.buf)?;
        self.read_response()
    }

    fn expect_ok(&mut self, req: &Request) -> Result<()> {
        match self.call(req)? {
            Response::Ok => Ok(()),
            Response::Error(m) => Err(Error::Remote(m)),
            other => Err(Error::Protocol(format!("unexpected response {other:?}"))),
        }
    }

    /// Send a tensor (`put_tensor`).  Writes a split frame: the small
    /// header is encoded into the reusable buffer, the payload goes from
    /// the borrowed tensor straight to the socket — zero payload copies.
    pub fn put_tensor(&mut self, key: &str, t: &Tensor) -> Result<()> {
        begin_split_frame(&mut self.buf);
        crate::proto::message::encode_put_tensor_header_into(&mut self.buf, key, t);
        end_split_frame(&mut self.writer, &mut self.buf, &t.data)?;
        match self.read_response()? {
            Response::Ok => Ok(()),
            Response::Error(m) => Err(Error::Remote(m)),
            other => Err(Error::Protocol(format!("unexpected response {other:?}"))),
        }
    }

    /// Retrieve a tensor (`unpack_tensor`).  The returned tensor's payload
    /// aliases the response frame read off the socket — one allocation, no
    /// decode-time copy.
    pub fn get_tensor(&mut self, key: &str) -> Result<Tensor> {
        match self.call(&Request::GetTensor { key: key.to_string() })? {
            Response::Tensor(t) => Ok(t),
            Response::NotFound => Err(Error::KeyNotFound(key.to_string())),
            Response::Error(m) => Err(Error::Remote(m)),
            other => Err(Error::Protocol(format!("unexpected response {other:?}"))),
        }
    }

    pub fn del_tensor(&mut self, key: &str) -> Result<bool> {
        match self.call(&Request::DelTensor { key: key.to_string() })? {
            Response::Ok => Ok(true),
            Response::NotFound => Ok(false),
            Response::Error(m) => Err(Error::Remote(m)),
            other => Err(Error::Protocol(format!("unexpected response {other:?}"))),
        }
    }

    pub fn exists(&mut self, key: &str) -> Result<bool> {
        match self.call(&Request::Exists { key: key.to_string() })? {
            Response::Bool(b) => Ok(b),
            Response::Error(m) => Err(Error::Remote(m)),
            other => Err(Error::Protocol(format!("unexpected response {other:?}"))),
        }
    }

    /// Block until a key exists (the trainer waiting for the first snapshot
    /// — the paper's "metadata transfer" overhead in Table 2).
    pub fn poll_key(&mut self, key: &str, interval: Duration, max_wait: Duration) -> Result<()> {
        let sw = crate::telemetry::Stopwatch::start();
        loop {
            if self.exists(key)? {
                return Ok(());
            }
            if sw.stop() > max_wait.as_secs_f64() {
                return Err(Error::Timeout(format!(
                    "key '{key}' not present after {:?}",
                    max_wait
                )));
            }
            std::thread::sleep(interval);
        }
    }

    pub fn put_meta(&mut self, key: &str, value: &str) -> Result<()> {
        self.expect_ok(&Request::PutMeta { key: key.to_string(), value: value.to_string() })
    }

    pub fn get_meta(&mut self, key: &str) -> Result<Option<String>> {
        match self.call(&Request::GetMeta { key: key.to_string() })? {
            Response::Meta(v) => Ok(Some(v)),
            Response::NotFound => Ok(None),
            Response::Error(m) => Err(Error::Remote(m)),
            other => Err(Error::Protocol(format!("unexpected response {other:?}"))),
        }
    }

    pub fn list_keys(&mut self, prefix: &str) -> Result<Vec<String>> {
        match self.call(&Request::ListKeys { prefix: prefix.to_string() })? {
            Response::Keys(ks) => Ok(ks),
            Response::Error(m) => Err(Error::Remote(m)),
            other => Err(Error::Protocol(format!("unexpected response {other:?}"))),
        }
    }

    /// Upload a model artifact (HLO text) into the database.
    pub fn put_model(&mut self, key: &str, hlo_text: &str) -> Result<()> {
        self.expect_ok(&Request::PutModel { key: key.to_string(), hlo_text: hlo_text.to_string() })
    }

    /// Upload a model from an artifact file.
    pub fn put_model_from_file(&mut self, key: &str, path: &std::path::Path) -> Result<()> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Parse(format!("read {}: {e}", path.display())))?;
        self.put_model(key, &text)
    }

    /// RedisAI-style in-database inference.
    pub fn run_model(
        &mut self,
        key: &str,
        in_keys: &[String],
        out_keys: &[String],
        device: Device,
    ) -> Result<()> {
        self.expect_ok(&Request::RunModel {
            key: key.to_string(),
            in_keys: in_keys.to_vec(),
            out_keys: out_keys.to_vec(),
            device,
        })
    }

    pub fn info(&mut self) -> Result<(u64, u64, u64, u64, String)> {
        match self.call(&Request::Info)? {
            Response::Info { keys, bytes, ops, models, engine } => {
                Ok((keys, bytes, ops, models, engine))
            }
            Response::Error(m) => Err(Error::Remote(m)),
            other => Err(Error::Protocol(format!("unexpected response {other:?}"))),
        }
    }

    pub fn flush_all(&mut self) -> Result<()> {
        self.expect_ok(&Request::FlushAll)
    }
}

/// Client for the clustered deployment: routes each key to the owning shard
/// via the redis-cluster hash-slot map.
pub struct ClusterClient {
    shards: Vec<Client>,
    slots: SlotMap,
}

impl ClusterClient {
    pub fn connect(addrs: &[SocketAddr]) -> Result<ClusterClient> {
        let shards = addrs
            .iter()
            .map(|a| Client::connect(*a))
            .collect::<Result<Vec<_>>>()?;
        Ok(ClusterClient { slots: SlotMap::new(shards.len()), shards })
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    fn route(&mut self, key: &str) -> &mut Client {
        let i = self.slots.shard_for_key(key);
        &mut self.shards[i]
    }

    pub fn put_tensor(&mut self, key: &str, t: &Tensor) -> Result<()> {
        self.route(key).put_tensor(key, t)
    }

    pub fn get_tensor(&mut self, key: &str) -> Result<Tensor> {
        self.route(key).get_tensor(key)
    }

    pub fn del_tensor(&mut self, key: &str) -> Result<bool> {
        self.route(key).del_tensor(key)
    }

    pub fn exists(&mut self, key: &str) -> Result<bool> {
        self.route(key).exists(key)
    }

    /// Keys across all shards (merged + sorted).
    pub fn list_keys(&mut self, prefix: &str) -> Result<Vec<String>> {
        let mut all = Vec::new();
        for c in &mut self.shards {
            all.extend(c.list_keys(prefix)?);
        }
        all.sort();
        Ok(all)
    }

    pub fn flush_all(&mut self) -> Result<()> {
        for c in &mut self.shards {
            c.flush_all()?;
        }
        Ok(())
    }
}
