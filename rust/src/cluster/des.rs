//! Deterministic FIFO-reservation discrete-event core.
//!
//! The scaling workloads are barrier-synchronized (every rank issues its
//! send, the iteration ends when all responses return, then everyone sleeps
//! the same compute time), so the full generality of a heap-based event loop
//! is unnecessary: a *timeline-reservation* server — jobs presented in
//! nondecreasing arrival order, each reserving the earliest available slot —
//! produces the identical FIFO-queueing trajectory with exact arithmetic and
//! no event-ordering nondeterminism.

/// A k-server FIFO resource on the virtual timeline.
#[derive(Debug, Clone)]
pub struct Server {
    /// Earliest time each of the k servers becomes free.
    next_free: Vec<f64>,
    /// Total busy time across servers (utilization accounting).
    busy: f64,
    served: u64,
    /// Largest arrival seen (FIFO discipline check).
    last_arrival: f64,
}

impl Server {
    pub fn new(k: usize) -> Server {
        assert!(k > 0, "server needs at least one slot");
        Server { next_free: vec![0.0; k], busy: 0.0, served: 0, last_arrival: f64::NEG_INFINITY }
    }

    pub fn k(&self) -> usize {
        self.next_free.len()
    }

    /// Reserve the earliest slot for a job arriving at `arrival` needing
    /// `service` seconds.  Presentation order is service order (FIFO): a
    /// job presented after another but stamped with an earlier arrival is
    /// treated as having queued behind it (its effective arrival is clamped
    /// to the latest arrival seen), which is exactly the discipline of a
    /// FIFO queue observed at the server.
    ///
    /// Returns `(start, end)`.
    pub fn reserve(&mut self, arrival: f64, service: f64) -> (f64, f64) {
        assert!(service >= 0.0 && arrival >= 0.0, "negative time");
        let arrival = arrival.max(self.last_arrival);
        self.last_arrival = arrival;
        // Earliest-free slot (ties broken by index: deterministic).
        let (slot, _) = self
            .next_free
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        let start = arrival.max(self.next_free[slot]);
        let end = start + service;
        self.next_free[slot] = end;
        self.busy += service;
        self.served += 1;
        (start, end)
    }

    /// Time at which every reserved job has completed.
    pub fn drained(&self) -> f64 {
        self.next_free.iter().cloned().fold(0.0, f64::max)
    }

    pub fn served(&self) -> u64 {
        self.served
    }

    /// Mean utilization over `[0, horizon]`.
    pub fn utilization(&self, horizon: f64) -> f64 {
        if horizon <= 0.0 {
            0.0
        } else {
            self.busy / (horizon * self.k() as f64)
        }
    }

    /// Reset the timeline but keep counters (between scenario phases).
    pub fn reset_timeline(&mut self) {
        for t in &mut self.next_free {
            *t = 0.0;
        }
        self.last_arrival = f64::NEG_INFINITY;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{check, Gen};

    #[test]
    fn single_server_serializes() {
        let mut s = Server::new(1);
        let (a0, e0) = s.reserve(0.0, 2.0);
        let (a1, e1) = s.reserve(0.5, 2.0);
        assert_eq!((a0, e0), (0.0, 2.0));
        assert_eq!((a1, e1), (2.0, 4.0), "second job queues behind the first");
        assert_eq!(s.drained(), 4.0);
    }

    #[test]
    fn k_servers_run_in_parallel() {
        let mut s = Server::new(3);
        for i in 0..3 {
            let (st, _) = s.reserve(i as f64 * 0.1, 5.0);
            assert_eq!(st, i as f64 * 0.1, "no queueing below capacity");
        }
        let (st, _) = s.reserve(0.3, 5.0);
        assert_eq!(st, 5.0, "4th job waits for the first slot to free");
    }

    #[test]
    fn idle_gap_is_respected() {
        let mut s = Server::new(1);
        s.reserve(0.0, 1.0);
        let (st, en) = s.reserve(10.0, 1.0);
        assert_eq!((st, en), (10.0, 11.0), "server idles until the arrival");
    }

    #[test]
    fn out_of_order_arrival_clamps_to_fifo() {
        // A job presented later with an earlier timestamp queued behind the
        // earlier-presented job: its effective arrival is the FIFO point.
        let mut s = Server::new(1);
        s.reserve(5.0, 1.0);
        let (st, en) = s.reserve(1.0, 1.0);
        assert_eq!((st, en), (6.0, 7.0));
    }

    #[test]
    fn prop_no_slot_overlap_and_conservation() {
        check("server invariants", 100, |g: &mut Gen| {
            let k = g.usize_in(1..=4);
            let n = g.usize_in(1..=60);
            let mut s = Server::new(k);
            // Generate sorted arrivals.
            let mut arrivals: Vec<f64> = (0..n).map(|_| g.f64() * 10.0).collect();
            arrivals.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let mut intervals: Vec<(f64, f64)> = Vec::new();
            let mut total_service = 0.0;
            for a in arrivals {
                let svc = g.f64() * 2.0;
                total_service += svc;
                let (st, en) = s.reserve(a, svc);
                assert!(st >= a, "no time travel");
                assert!((en - st - svc).abs() < 1e-12);
                intervals.push((st, en));
            }
            assert_eq!(s.served(), n as u64);
            // Conservation: total busy == sum of service times.
            assert!((s.utilization(s.drained().max(1e-9)) * s.drained().max(1e-9) * k as f64
                - total_service)
                .abs()
                < 1e-9 * n as f64 + 1e-12);
            // At no instant do more than k jobs run: sweep the interval ends.
            let mut events: Vec<(f64, i32)> = Vec::new();
            for (st, en) in &intervals {
                events.push((*st, 1));
                events.push((*en, -1));
            }
            events.sort_by(|a, b| {
                a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1))
            });
            let mut level = 0i32;
            for (_, d) in events {
                level += d;
                assert!(level <= k as i32, "more than k concurrent jobs");
            }
        });
    }

    #[test]
    fn prop_work_conserving() {
        // A single-server queue never idles while work is waiting: with all
        // arrivals at 0, drained == sum of services.
        check("work conserving", 50, |g: &mut Gen| {
            let mut s = Server::new(1);
            let n = g.usize_in(1..=40);
            let mut total = 0.0;
            for _ in 0..n {
                let svc = 0.1 + g.f64();
                total += svc;
                s.reserve(0.0, svc);
            }
            assert!((s.drained() - total).abs() < 1e-9);
        });
    }
}
