//! Simulated Polaris substrate for the scaling studies (Figs 3-6, 8).
//!
//! The paper's scaling results are queueing/locality phenomena on a machine
//! we do not have (448+ nodes, Slingshot-10, 4×A100 per node).  Per the
//! substitution rule in DESIGN.md we rebuild the substrate:
//!
//! * [`topology`] — node/cluster shapes and component placement,
//! * [`netmodel`] — the transfer + service cost model, with constants
//!   calibrated against the *real* in-repo TCP database on this host,
//! * [`des`]      — a deterministic FIFO-reservation discrete-event core,
//! * [`scaling`]  — the workload runners that produce every scaling series.

pub mod des;
pub mod netmodel;
pub mod scaling;
pub mod topology;

pub use des::Server;
pub use netmodel::CostModel;
pub use scaling::{InferenceStats, TransferStats};
pub use topology::Placement;
