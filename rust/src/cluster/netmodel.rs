//! Transfer + service cost model for the simulated cluster.
//!
//! Defaults approximate the Polaris numbers quoted in the paper (Slingshot
//! 10, two 200 Gb/s NICs per node) and the request-handling costs of the
//! in-repo TCP database measured on this host (`situ calibrate`); the bench
//! harnesses may override them with measured values so the DES and the real
//! single-node runs agree where they overlap.
//!
//! The model captures exactly the mechanisms the paper reasons about:
//!
//! * a **fixed per-request cost** that dominates below 256 KB (paper §3.1.1
//!   hypothesizes "a fixed cost to handle an I/O request ... that, for small
//!   message sizes, dominates"),
//! * a **linear-in-size** component (memcpy + TCP streaming) that dominates
//!   above 256 KB, giving the constant-throughput regime,
//! * an **engine service fraction** reproducing the Redis (8-core) vs KeyDB
//!   (4-core) saturation plateaus of Fig 3,
//! * **locality**: co-located traffic pays loopback latency/bandwidth,
//!   clustered traffic pays the NIC.

use crate::db::Engine;

/// All tunables of the simulated data path.
#[derive(Debug, Clone)]
pub struct CostModel {
    /// Client-side fixed cost per request (serialize + syscall).
    pub client_overhead: f64,
    /// One-way latency, same-node loopback.
    pub local_latency: f64,
    /// One-way latency across the interconnect.
    pub net_latency: f64,
    /// Intra-node effective bandwidth (loopback/shared memory), bytes/s.
    pub local_bw: f64,
    /// Inter-node effective bandwidth (2x200 Gb/s Slingshot), bytes/s.
    pub net_bw: f64,
    /// Server fixed cost per request at full service capacity.
    pub req_fixed: f64,
    /// Server per-byte processing cost (parse + memcpy into the store).
    pub byte_cost: f64,
    /// Uniform jitter fraction applied to client issue times.
    pub jitter_frac: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel {
            client_overhead: 5e-6,
            local_latency: 2e-6,
            net_latency: 5e-6,
            local_bw: 2.4e10,  // ~24 GB/s loopback
            net_bw: 2.2e10,    // ~22 GB/s effective NIC (paper: 2 x 200Gbps)
            // Fixed and per-byte costs are tied by the paper's observed
            // knee: the fixed cost dominates below 256KB and the byte cost
            // above, so req_fixed ~= 256KB * byte_cost.
            req_fixed: 3.0e-5,
            byte_cost: 1.0 / 9.0e9, // ~9 GB/s in-server processing
            jitter_frac: 0.03,
        }
    }
}

impl CostModel {
    /// One-way wire time for `bytes`.
    pub fn transfer(&self, bytes: usize, cross_node: bool) -> f64 {
        if cross_node {
            self.net_latency + bytes as f64 / self.net_bw
        } else {
            self.local_latency + bytes as f64 / self.local_bw
        }
    }

    /// In-server service time for one request carrying `bytes`, under the
    /// given engine and core allocation.  The engine's service fraction
    /// scales the *rate*: fewer cores than the saturation point stretch
    /// every request proportionally (Fig 3).
    pub fn service(&self, bytes: usize, engine: Engine, cores: usize) -> f64 {
        (self.req_fixed + bytes as f64 * self.byte_cost) / engine.service_fraction(cores)
    }

    /// Ideal no-queueing round trip (client overhead + 2 transfers +
    /// service) — the single-client floor.
    pub fn round_trip_floor(
        &self,
        bytes: usize,
        engine: Engine,
        cores: usize,
        cross_node: bool,
    ) -> f64 {
        self.client_overhead
            + self.transfer(bytes, cross_node)
            + self.service(bytes, engine, cores)
            + self.transfer(64, cross_node) // ack frame
    }

    /// Calibrate `req_fixed`/`byte_cost` from two measured round-trip points
    /// of the real database: `(small_bytes, t_small)` and `(big_bytes,
    /// t_big)`.  Linear fit through the two points.
    pub fn calibrate(&mut self, small: (usize, f64), big: (usize, f64)) {
        let (b0, t0) = small;
        let (b1, t1) = big;
        if b1 > b0 && t1 > t0 {
            let slope = (t1 - t0) / (b1 - b0) as f64;
            // Split the slope between the wire and the server evenly: the
            // figures only depend on the sum for single-client runs; the
            // split shifts queueing slightly and 50/50 matches loopback
            // (memcpy-bound both sides).
            self.byte_cost = slope / 2.0;
            self.local_bw = 2.0 / slope;
            self.req_fixed = (t0 - b0 as f64 * slope).max(1e-6);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_requests_are_fixed_cost_dominated() {
        let m = CostModel::default();
        let t1k = m.round_trip_floor(1024, Engine::Redis, 8, false);
        let t64k = m.round_trip_floor(64 * 1024, Engine::Redis, 8, false);
        // Below 256KB the paper sees a near-constant floor.
        assert!(t64k / t1k < 1.3, "{t1k} vs {t64k}");
    }

    #[test]
    fn large_requests_are_linear() {
        let m = CostModel::default();
        let t1m = m.round_trip_floor(1 << 20, Engine::Redis, 8, false);
        let t16m = m.round_trip_floor(16 << 20, Engine::Redis, 8, false);
        let ratio = t16m / t1m;
        assert!(ratio > 6.0 && ratio < 16.0, "approximately linear: {ratio}");
    }

    #[test]
    fn engine_plateaus() {
        let m = CostModel::default();
        let b = 256 * 1024;
        // Redis: flat >= 8 cores, slower below.
        let r8 = m.service(b, Engine::Redis, 8);
        assert_eq!(m.service(b, Engine::Redis, 16), r8);
        assert!(m.service(b, Engine::Redis, 4) > 1.9 * r8);
        // KeyDB: already at peak with 4 cores, equal to redis's plateau.
        assert_eq!(m.service(b, Engine::KeyDb, 4), r8);
    }

    #[test]
    fn cross_node_pays_latency() {
        let m = CostModel::default();
        assert!(m.transfer(0, true) > m.transfer(0, false));
    }

    #[test]
    fn calibrate_fits_two_points() {
        let mut m = CostModel::default();
        m.calibrate((1024, 3.0e-4), (1 << 20, 1.0e-3));
        let slope = (1.0e-3 - 3.0e-4) / ((1 << 20) - 1024) as f64;
        assert!((m.byte_cost - slope / 2.0).abs() < 1e-18);
        assert!(m.req_fixed > 0.0);
    }
}
