//! Scaling workload runners: the data-transfer and inference scenarios the
//! paper measures in §3, executed on the simulated cluster.
//!
//! Every run reproduces the paper's measurement protocol: per-rank costs are
//! averaged over the measured iterations (default 40) after discarding
//! warmup iterations (default 2), with iterations barrier-synchronized by
//! the reproducer's compute phase.

use crate::cluster::des::Server;
use crate::cluster::netmodel::CostModel;
use crate::cluster::topology::Placement;
use crate::config::{Deployment, RunConfig};
use crate::telemetry::StatAccum;
use crate::util::rng::Rng;

/// Cores a clustered (dedicated-node) DB uses: the paper lets it take the
/// full socket.
pub const CLUSTERED_DB_CORES: usize = 32;

/// Small frame size for requests/acks that carry no payload.
const CTRL_BYTES: usize = 64;

/// Result of a data-transfer scaling run (Figs 3-6).
#[derive(Debug, Clone)]
pub struct TransferStats {
    pub send: StatAccum,
    pub retrieve: StatAccum,
    /// Virtual wall-clock of the measured window.
    pub wall: f64,
}

impl TransferStats {
    /// Aggregate throughput (bytes moved per second of send+retrieve time,
    /// per rank) — the paper's loose "throughput" metric of Fig 4b.
    pub fn throughput_per_rank(&self, bytes: usize) -> f64 {
        let t = self.send.mean() + self.retrieve.mean();
        if t <= 0.0 {
            0.0
        } else {
            2.0 * bytes as f64 / t
        }
    }
}

/// One phase: every rank issues one request; returns per-rank response
/// times and records per-rank durations.
#[allow(clippy::too_many_arguments)]
fn run_phase(
    servers: &mut [Server],
    placement: &Placement,
    model: &CostModel,
    engine: crate::db::Engine,
    db_cores: usize,
    ready: &[f64],
    req_bytes: usize,
    resp_bytes: usize,
    service_bytes: usize,
    rng: &mut Rng,
    record: Option<&mut StatAccum>,
) -> Vec<f64> {
    let n = placement.n_ranks;
    let cross = placement.cross_node;
    // Issue with a small jitter (ranks never fire in perfect lockstep).
    // Jitter scales with the *local* client count at the rank's DB — OS
    // scheduling noise among the clients sharing one server — never with
    // total machine size (which would unphysically de-synchronize the
    // co-located deployment at scale).
    let mut arrivals: Vec<(f64, usize, f64)> = Vec::with_capacity(n); // (arrival, rank, issue)
    for rank in 0..n {
        let local = placement.ranks_per_db[placement.db_of_rank[rank]] as f64;
        let jitter = model.client_overhead * rng.f64() * (1.0 + model.jitter_frac * local);
        let issue = ready[rank] + jitter;
        let arrival = issue + model.client_overhead + model.transfer(req_bytes, cross);
        arrivals.push((arrival, rank, issue));
    }
    // FIFO order at each server = arrival order.
    arrivals.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let mut response = vec![0.0f64; n];
    let mut durations = vec![0.0f64; n];
    let service = model.service(service_bytes, engine, db_cores);
    for (arrival, rank, issue) in arrivals {
        let db = placement.db_of_rank[rank];
        let (_start, end) = servers[db].reserve(arrival, service);
        let resp = end + model.transfer(resp_bytes, cross);
        response[rank] = resp;
        durations[rank] = resp - issue;
    }
    if let Some(acc) = record {
        for d in &durations {
            acc.add(*d);
        }
    }
    response
}

/// Simulate the paper's Fortran reproducer data-transfer loop: sleep
/// (compute), send `bytes_per_rank`, retrieve it back; repeat.
pub fn sim_data_transfer(cfg: &RunConfig, model: &CostModel, seed: u64) -> TransferStats {
    let placement = Placement::new(cfg);
    let db_cores = match cfg.deployment {
        Deployment::CoLocated => cfg.db_cores,
        Deployment::Clustered { .. } => CLUSTERED_DB_CORES,
    };
    let mut servers: Vec<Server> = (0..placement.n_db).map(|_| Server::new(1)).collect();
    let mut rng = Rng::new(seed);
    let mut send = StatAccum::new();
    let mut retrieve = StatAccum::new();
    let mut ready = vec![0.0f64; placement.n_ranks];
    let mut measured_start = 0.0;
    for iter in 0..cfg.warmup + cfg.iterations {
        let measuring = iter >= cfg.warmup;
        if iter == cfg.warmup {
            measured_start = ready.iter().cloned().fold(0.0, f64::max);
        }
        // Compute phase (the reproducer sleeps to emulate PDE integration).
        for r in ready.iter_mut() {
            *r += cfg.compute_secs;
        }
        // Send: payload on the request, ack back; server pays payload cost.
        let resp = run_phase(
            &mut servers,
            &placement,
            model,
            cfg.engine,
            db_cores,
            &ready,
            cfg.bytes_per_rank,
            CTRL_BYTES,
            cfg.bytes_per_rank,
            &mut rng,
            if measuring { Some(&mut send) } else { None },
        );
        // Retrieve: small request, payload on the response.
        let resp2 = run_phase(
            &mut servers,
            &placement,
            model,
            cfg.engine,
            db_cores,
            &resp,
            CTRL_BYTES,
            cfg.bytes_per_rank,
            cfg.bytes_per_rank,
            &mut rng,
            if measuring { Some(&mut retrieve) } else { None },
        );
        // Iteration barrier (the reproducer loop is bulk-synchronous).
        let iter_end = resp2.iter().cloned().fold(0.0, f64::max);
        for r in ready.iter_mut() {
            *r = iter_end;
        }
    }
    let wall = ready[0] - measured_start;
    TransferStats { send, retrieve, wall }
}

/// Result of an inference scaling run (Figs 7-8): the three RedisAI steps
/// plus their sum.
#[derive(Debug, Clone)]
pub struct InferenceStats {
    pub send: StatAccum,
    pub eval: StatAccum,
    pub retrieve: StatAccum,
    pub total: StatAccum,
    pub wall: f64,
}

/// Simulate in-situ inference with the co-located deployment: every rank
/// sends a batch, the model runs on the rank's pinned GPU (6 ranks per
/// GPU), the prediction is retrieved.
///
/// `eval_time(batch)` supplies the device execution time — measured from the
/// real PJRT runtime by the calibration pass so the simulated GPUs inherit
/// genuine model costs.
pub fn sim_inference(
    cfg: &RunConfig,
    model: &CostModel,
    batch: usize,
    in_bytes: usize,
    out_bytes: usize,
    eval_time: &dyn Fn(usize) -> f64,
    seed: u64,
) -> InferenceStats {
    let placement = Placement::new(cfg);
    let db_cores = cfg.db_cores;
    let gpus = crate::ai::GPUS_PER_NODE;
    let mut db_servers: Vec<Server> = (0..placement.n_db).map(|_| Server::new(1)).collect();
    let mut gpu_servers: Vec<Server> = (0..cfg.nodes * gpus).map(|_| Server::new(1)).collect();
    let mut rng = Rng::new(seed);
    let (mut send, mut eval, mut retrieve, mut total) =
        (StatAccum::new(), StatAccum::new(), StatAccum::new(), StatAccum::new());
    let mut ready = vec![0.0f64; placement.n_ranks];
    let mut measured_start = 0.0;
    let t_eval = eval_time(batch);

    for iter in 0..cfg.warmup + cfg.iterations {
        let measuring = iter >= cfg.warmup;
        if iter == cfg.warmup {
            measured_start = ready.iter().cloned().fold(0.0, f64::max);
        }
        for r in ready.iter_mut() {
            *r += cfg.compute_secs;
        }
        let issue: Vec<f64> = ready.clone();
        // 1) send inference data.
        let sent = run_phase(
            &mut db_servers,
            &placement,
            model,
            cfg.engine,
            db_cores,
            &ready,
            in_bytes,
            CTRL_BYTES,
            in_bytes,
            &mut rng,
            if measuring { Some(&mut send) } else { None },
        );
        // 2) model evaluation on the pinned GPU (arrival order per GPU).
        let mut by_gpu: Vec<(f64, usize)> = sent.iter().cloned().zip(0..).collect();
        by_gpu.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let mut evaled = vec![0.0f64; placement.n_ranks];
        for (arr, rank) in by_gpu {
            let (node, gpu) = Placement::gpu_of_rank(cfg, rank);
            let srv = &mut gpu_servers[node * gpus + gpu];
            // run_model request itself is a small command to the DB-side
            // runtime; the dominant cost is the device execution.
            let (_s, end) = srv.reserve(arr + model.local_latency, t_eval);
            evaled[rank] = end;
            if measuring {
                eval.add(end - arr);
            }
        }
        // 3) retrieve predictions.
        let done = run_phase(
            &mut db_servers,
            &placement,
            model,
            cfg.engine,
            db_cores,
            &evaled,
            CTRL_BYTES,
            out_bytes,
            out_bytes,
            &mut rng,
            if measuring { Some(&mut retrieve) } else { None },
        );
        if measuring {
            for r in 0..placement.n_ranks {
                total.add(done[r] - issue[r]);
            }
        }
        let iter_end = done.iter().cloned().fold(0.0, f64::max);
        for r in ready.iter_mut() {
            *r = iter_end;
        }
    }
    let wall = ready[0] - measured_start;
    InferenceStats { send, eval, retrieve, total, wall }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::Engine;

    fn base_cfg() -> RunConfig {
        let mut c = RunConfig::default();
        c.iterations = 10;
        c.warmup = 2;
        c
    }

    #[test]
    fn colocated_weak_scaling_is_flat() {
        // The headline result (Fig 5a): per-rank cost independent of nodes.
        let model = CostModel::default();
        let mut costs = Vec::new();
        for nodes in [1usize, 4, 16, 64] {
            let mut cfg = base_cfg();
            cfg.nodes = nodes;
            let st = sim_data_transfer(&cfg, &model, 7);
            costs.push(st.send.mean() + st.retrieve.mean());
        }
        let base = costs[0];
        for c in &costs {
            assert!(
                (c / base - 1.0).abs() < 0.05,
                "weak scaling not flat: {costs:?}"
            );
        }
    }

    #[test]
    fn clustered_fixed_db_degrades_linearly() {
        // Fig 5b: fixed 1-node DB, growing ranks => cost grows ~linearly.
        let model = CostModel::default();
        let mut cfg = base_cfg();
        cfg.deployment = Deployment::Clustered { db_nodes: 1 };
        cfg.nodes = 1;
        let c1 = sim_data_transfer(&cfg, &model, 7).send.mean();
        cfg.nodes = 8;
        let c8 = sim_data_transfer(&cfg, &model, 7).send.mean();
        assert!(c8 > 4.0 * c1, "expected ~8x degradation, got {c1} -> {c8}");
    }

    #[test]
    fn clustered_proportional_sharding_restores_scaling() {
        // Fig 5b: DB nodes scaled with ranks => roughly constant cost.
        let model = CostModel::default();
        let mut costs = Vec::new();
        for (nodes, db_nodes) in [(1usize, 1usize), (4, 4), (16, 16)] {
            let mut cfg = base_cfg();
            cfg.nodes = nodes;
            cfg.deployment = Deployment::Clustered { db_nodes };
            costs.push(sim_data_transfer(&cfg, &model, 7).send.mean());
        }
        let base = costs[0];
        for c in &costs {
            assert!((c / base - 1.0).abs() < 0.10, "sharded not flat: {costs:?}");
        }
    }

    #[test]
    fn strong_scaling_reduces_cost_linearly_until_floor() {
        // Fig 6: fixed total data, more ranks => per-rank time drops.
        let model = CostModel::default();
        let total = 384usize << 20;
        let mut prev = f64::INFINITY;
        for nodes in [1usize, 2, 4] {
            let mut cfg = base_cfg();
            cfg.nodes = nodes;
            cfg.bytes_per_rank = total / (nodes * cfg.ranks_per_node);
            let t = sim_data_transfer(&cfg, &model, 7).send.mean();
            assert!(t < prev, "strong scaling must reduce cost");
            // Roughly linear (halving data never gives more than the ideal
            // 2x plus slack) while >= 256KB/rank.
            if prev.is_finite() && cfg.bytes_per_rank >= 512 * 1024 {
                assert!(t > prev / 4.0);
            }
            prev = t;
        }
    }

    #[test]
    fn redis_needs_8_cores_keydb_4() {
        // Fig 3 shape.
        let model = CostModel::default();
        let mut cfg = base_cfg();
        let at = |engine: Engine, cores: usize, cfg: &mut RunConfig| {
            cfg.engine = engine;
            cfg.db_cores = cores;
            let s = sim_data_transfer(cfg, &model, 3);
            s.send.mean() + s.retrieve.mean()
        };
        let r8 = at(Engine::Redis, 8, &mut cfg);
        let r16 = at(Engine::Redis, 16, &mut cfg);
        let r4 = at(Engine::Redis, 4, &mut cfg);
        let k4 = at(Engine::KeyDb, 4, &mut cfg);
        assert!((r16 / r8 - 1.0).abs() < 0.02, "redis flat >= 8 cores");
        assert!(r4 > 1.5 * r8, "redis degraded at 4 cores");
        assert!((k4 / r8 - 1.0).abs() < 0.05, "keydb already at peak with 4");
    }

    #[test]
    fn inference_weak_scaling_flat() {
        let model = CostModel::default();
        let eval = |_b: usize| 3.0e-3;
        let mut costs = Vec::new();
        for nodes in [1usize, 8, 32] {
            let mut cfg = base_cfg();
            cfg.nodes = nodes;
            let st = sim_inference(&cfg, &model, 4, 4 * 3 * 64 * 64 * 4, 4 * 1000 * 4, &eval, 5);
            costs.push(st.total.mean());
        }
        let base = costs[0];
        for c in &costs {
            assert!((c / base - 1.0).abs() < 0.05, "inference weak scaling: {costs:?}");
        }
    }

    #[test]
    fn inference_components_sum_to_total() {
        let model = CostModel::default();
        let eval = |_b: usize| 2.0e-3;
        let cfg = base_cfg();
        let st = sim_inference(&cfg, &model, 4, 1 << 20, 16_000, &eval, 5);
        let sum = st.send.mean() + st.eval.mean() + st.retrieve.mean();
        let total = st.total.mean();
        assert!((sum / total - 1.0).abs() < 0.05, "sum {sum} vs total {total}");
    }
}
