//! Cluster topology and component placement for both deployments (Fig 2).

use crate::config::{Deployment, RunConfig};

/// Hardware shape of one Polaris node (paper §2.3).
#[derive(Debug, Clone, Copy)]
pub struct NodeSpec {
    /// Logical CPU cores (32 physical, 64 logical).
    pub logical_cores: usize,
    pub gpus: usize,
}

impl Default for NodeSpec {
    fn default() -> Self {
        NodeSpec { logical_cores: 64, gpus: 4 }
    }
}

/// Resolved placement of every component for a run: which DB instance each
/// simulation rank talks to, and whether that hop crosses the network.
#[derive(Debug, Clone)]
pub struct Placement {
    /// Total simulation ranks.
    pub n_ranks: usize,
    /// Number of independent DB instances.
    pub n_db: usize,
    /// DB instance index serving each rank (co-located: the rank's node;
    /// clustered: hash-slot routing is per-key, so this is the *modal* shard
    /// and `cross_node` below is what matters for the cost model).
    pub db_of_rank: Vec<usize>,
    /// Whether rank→DB traffic crosses the network.
    pub cross_node: bool,
    /// Ranks served by each DB instance.
    pub ranks_per_db: Vec<usize>,
}

impl Placement {
    pub fn new(cfg: &RunConfig) -> Placement {
        let n_ranks = cfg.total_ranks();
        match cfg.deployment {
            Deployment::CoLocated => {
                // One DB per node; each rank uses its node-local DB and no
                // traffic leaves the node (the novel deployment).
                let n_db = cfg.nodes;
                let db_of_rank: Vec<usize> =
                    (0..n_ranks).map(|r| r / cfg.ranks_per_node).collect();
                let mut ranks_per_db = vec![0usize; n_db];
                for &d in &db_of_rank {
                    ranks_per_db[d] += 1;
                }
                Placement { n_ranks, n_db, db_of_rank, cross_node: false, ranks_per_db }
            }
            Deployment::Clustered { db_nodes } => {
                // Dedicated DB nodes; keys hash-shard across them, so each
                // rank's requests spread ~uniformly.  For the queueing model
                // we assign ranks round-robin (the per-key expectation).
                let n_db = db_nodes.max(1);
                let db_of_rank: Vec<usize> = (0..n_ranks).map(|r| r % n_db).collect();
                let mut ranks_per_db = vec![0usize; n_db];
                for &d in &db_of_rank {
                    ranks_per_db[d] += 1;
                }
                Placement { n_ranks, n_db, db_of_rank, cross_node: true, ranks_per_db }
            }
        }
    }

    /// GPU slot for a rank under the paper's pinning (6 ranks per GPU on a
    /// 24-rank node with 4 GPUs); inference always runs node-local.
    pub fn gpu_of_rank(cfg: &RunConfig, rank: usize) -> (usize, usize) {
        let node = rank / cfg.ranks_per_node;
        let local = rank % cfg.ranks_per_node;
        let spec = NodeSpec::default();
        (node, local % spec.gpus)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RunConfig;

    #[test]
    fn colocated_is_node_local_and_balanced() {
        let mut cfg = RunConfig::default();
        cfg.nodes = 4;
        let p = Placement::new(&cfg);
        assert_eq!(p.n_db, 4);
        assert!(!p.cross_node);
        assert_eq!(p.ranks_per_db, vec![24, 24, 24, 24]);
        // rank 25 is on node 1.
        assert_eq!(p.db_of_rank[25], 1);
    }

    #[test]
    fn clustered_crosses_network_and_spreads() {
        let mut cfg = RunConfig::default();
        cfg.nodes = 4;
        cfg.deployment = Deployment::Clustered { db_nodes: 2 };
        let p = Placement::new(&cfg);
        assert_eq!(p.n_db, 2);
        assert!(p.cross_node);
        assert_eq!(p.ranks_per_db.iter().sum::<usize>(), 96);
        assert_eq!(p.ranks_per_db[0], 48);
    }

    #[test]
    fn gpu_pinning_six_per_gpu() {
        let cfg = RunConfig::default();
        let mut counts = [0usize; 4];
        for r in 0..24 {
            let (node, gpu) = Placement::gpu_of_rank(&cfg, r);
            assert_eq!(node, 0);
            counts[gpu] += 1;
        }
        assert_eq!(counts, [6, 6, 6, 6]);
    }
}
