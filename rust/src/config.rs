//! Run configuration shared by the orchestrator, the CLI and the benches.
//!
//! Mirrors the paper's experimental knobs: deployment strategy (co-located
//! vs clustered), database engine and core allocation, ranks per node,
//! per-rank payload size, iteration counts (paper: 40 measured + 2 warmup).

use std::time::Duration;

use crate::client::{GovernorConfig, RetryPolicy};
use crate::db::Engine;
use crate::error::{Error, Result};
use crate::util::cli::Args;

/// Where the database lives relative to the application (paper §2.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Deployment {
    /// One database per node, sharing the node with simulation + ML ranks.
    CoLocated,
    /// Dedicated database nodes; keys sharded across them.
    Clustered { db_nodes: usize },
}

impl Deployment {
    pub fn name(&self) -> String {
        match self {
            Deployment::CoLocated => "co-located".into(),
            Deployment::Clustered { db_nodes } => format!("clustered({db_nodes})"),
        }
    }
}

/// Full experiment configuration.
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// Simulation nodes (the paper scales 1..448).
    pub nodes: usize,
    /// Simulation ranks per node (paper: 24; the CPU keeps 8 for the DB).
    pub ranks_per_node: usize,
    /// Logical cores bound to each co-located DB (paper: 8; Fig 3 sweeps it).
    pub db_cores: usize,
    pub engine: Engine,
    pub deployment: Deployment,
    /// Payload each rank sends per iteration (paper default: 256 KB).
    pub bytes_per_rank: usize,
    /// Measured iterations (paper: 40).
    pub iterations: usize,
    /// Discarded warmup iterations (paper: 2).
    pub warmup: usize,
    /// ML (training) ranks per node — one per GPU (paper: 4).
    pub ml_ranks_per_node: usize,
    /// Seconds each reproducer rank "integrates the equations" per step.
    pub compute_secs: f64,
    /// Newest step generations each database retains per field (the
    /// sliding-window retention policy; 0 = keep everything, the paper's
    /// append-forever default).
    pub retention_window: u64,
    /// Byte cap per database instance (0 = unbounded).  Writes that cannot
    /// fit even after eviction get `busy` backpressure.
    pub db_max_bytes: u64,
    /// Wall-clock TTL in milliseconds for data whose producer stalls
    /// (0 = never expire).
    pub db_ttl_ms: u64,
    /// `Busy` retries per publish before the producer gives up on a
    /// snapshot (0 = fail immediately, the seed behavior).
    pub busy_retries: u32,
    /// Initial backoff between `Busy` retries, milliseconds.
    pub busy_backoff_ms: u64,
    /// Ceiling for the producer's adaptive publish stride under sustained
    /// backpressure (1 = never skip a snapshot; `Busy` is then fatal).
    pub governor_max_stride: u64,
    /// Spill-to-disk cold tier: base directory for the segment logs (each
    /// database instance gets its own `db{n}` subdirectory).  `None` =
    /// evicted data is discarded, the seed behavior.
    pub spill_dir: Option<String>,
    /// Byte cap on each instance's cold tier (0 = unbounded); once
    /// exceeded, oldest sealed segments are deleted.
    pub spill_max_bytes: u64,
    /// Copies of every write kept across database instances (the owning
    /// shard plus the next `replicas − 1` in ring order).  1 = no
    /// replication, the seed behavior; clamped to the shard count at
    /// connect time.  Only meaningful for the clustered deployment.
    pub replicas: usize,
    /// Seed for deterministic transport fault injection across the run's
    /// database servers (the chaos harness).  0 = no faults, the
    /// production behavior.
    pub chaos_seed: u64,
    /// Scale factor for the chaos fault probabilities (see
    /// [`crate::util::fault::FaultConfig::with_intensity`]); ignored when
    /// `chaos_seed` is 0.
    pub chaos_intensity: f64,
    /// Reactor (I/O event loop) threads per database server.  0 = auto:
    /// defer to the `SITU_REACTORS` environment variable capped at the
    /// server's cores, defaulting to one reactor (the seed behavior).
    pub reactors: usize,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            nodes: 1,
            ranks_per_node: 24,
            db_cores: 8,
            engine: Engine::Redis,
            deployment: Deployment::CoLocated,
            bytes_per_rank: 256 * 1024,
            iterations: 40,
            warmup: 2,
            ml_ranks_per_node: 4,
            compute_secs: 0.0,
            retention_window: 0,
            db_max_bytes: 0,
            db_ttl_ms: 0,
            busy_retries: 0,
            busy_backoff_ms: 5,
            governor_max_stride: 1,
            spill_dir: None,
            spill_max_bytes: 0,
            replicas: 1,
            chaos_seed: 0,
            chaos_intensity: 1.0,
            reactors: 0,
        }
    }
}

impl RunConfig {
    pub fn total_ranks(&self) -> usize {
        self.nodes * self.ranks_per_node
    }

    pub fn total_ml_ranks(&self) -> usize {
        self.nodes * self.ml_ranks_per_node
    }

    /// Producer flow-control configuration derived from the backpressure
    /// flags (threaded `RunConfig` → `DeploymentPlan` → the CFD producer).
    pub fn governor(&self) -> GovernorConfig {
        let retry = if self.busy_retries == 0 {
            RetryPolicy::Fail
        } else {
            RetryPolicy::backoff(
                Duration::from_millis(self.busy_backoff_ms.max(1)),
                self.busy_retries,
            )
        };
        GovernorConfig { retry, max_stride: self.governor_max_stride.max(1) }
    }

    /// Parse the shared experiment flags off a CLI invocation.
    pub fn from_args(a: &Args) -> Result<RunConfig> {
        let mut c = RunConfig::default();
        c.nodes = a.usize_or("nodes", c.nodes)?;
        c.ranks_per_node = a.usize_or("ranks-per-node", c.ranks_per_node)?;
        c.db_cores = a.usize_or("db-cores", c.db_cores)?;
        c.bytes_per_rank = a.usize_or("bytes", c.bytes_per_rank)?;
        c.iterations = a.usize_or("iters", c.iterations)?;
        c.warmup = a.usize_or("warmup", c.warmup)?;
        c.ml_ranks_per_node = a.usize_or("ml-ranks-per-node", c.ml_ranks_per_node)?;
        c.compute_secs = a.f64_or("compute-secs", c.compute_secs)?;
        c.retention_window = a.usize_or("retention-window", c.retention_window as usize)? as u64;
        c.db_max_bytes = a.usize_or("db-max-bytes", c.db_max_bytes as usize)? as u64;
        c.db_ttl_ms = a.usize_or("db-ttl-ms", c.db_ttl_ms as usize)? as u64;
        c.busy_retries = a.usize_or("busy-retries", c.busy_retries as usize)? as u32;
        c.busy_backoff_ms = a.usize_or("busy-backoff-ms", c.busy_backoff_ms as usize)? as u64;
        c.governor_max_stride =
            a.usize_or("governor-max-stride", c.governor_max_stride as usize)? as u64;
        c.spill_dir = a.str_opt("spill-dir").map(str::to_string);
        c.spill_max_bytes = a.usize_or("spill-max-bytes", c.spill_max_bytes as usize)? as u64;
        c.replicas = a.usize_or("replicas", c.replicas)?;
        c.chaos_seed = a.usize_or("chaos-seed", c.chaos_seed as usize)? as u64;
        c.chaos_intensity = a.f64_or("chaos-intensity", c.chaos_intensity)?;
        c.reactors = a.usize_or("reactors", c.reactors)?;
        if let Some(e) = a.str_opt("engine") {
            c.engine = Engine::parse(e)
                .ok_or_else(|| Error::Invalid(format!("unknown engine '{e}'")))?;
        }
        match a.str_or("deployment", "colocated").as_str() {
            "colocated" | "co-located" => c.deployment = Deployment::CoLocated,
            "clustered" => {
                c.deployment = Deployment::Clustered { db_nodes: a.usize_or("db-nodes", 1)? }
            }
            other => return Err(Error::Invalid(format!("unknown deployment '{other}'"))),
        }
        if c.ranks_per_node == 0 || c.nodes == 0 {
            return Err(Error::Invalid("nodes and ranks-per-node must be > 0".into()));
        }
        if c.replicas == 0 {
            return Err(Error::Invalid("replicas must be >= 1 (1 = no replication)".into()));
        }
        Ok(c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> RunConfig {
        RunConfig::from_args(&Args::parse(s.split_whitespace().map(str::to_string)).unwrap())
            .unwrap()
    }

    #[test]
    fn defaults_match_paper() {
        let c = RunConfig::default();
        assert_eq!(c.ranks_per_node, 24);
        assert_eq!(c.db_cores, 8);
        assert_eq!(c.bytes_per_rank, 256 * 1024);
        assert_eq!(c.iterations, 40);
        assert_eq!(c.warmup, 2);
        assert_eq!(c.ml_ranks_per_node, 4);
        assert_eq!((c.retention_window, c.db_max_bytes), (0, 0), "unbounded by default");
    }

    #[test]
    fn parses_retention_flags() {
        let c = parse("bench --retention-window 6 --db-max-bytes 1048576 --db-ttl-ms 30000");
        assert_eq!(c.retention_window, 6);
        assert_eq!(c.db_max_bytes, 1 << 20);
        assert_eq!(c.db_ttl_ms, 30_000);
    }

    #[test]
    fn parses_spill_flags() {
        let c = parse("bench --spill-dir /tmp/cold --spill-max-bytes 4096");
        assert_eq!(c.spill_dir.as_deref(), Some("/tmp/cold"));
        assert_eq!(c.spill_max_bytes, 4096);
        // Off by default — the seed's discard-on-evict behavior.
        let c = RunConfig::default();
        assert_eq!((c.spill_dir, c.spill_max_bytes), (None, 0));
    }

    #[test]
    fn parses_backpressure_flags_into_a_governor() {
        let c = parse("bench --busy-retries 4 --busy-backoff-ms 10 --governor-max-stride 8");
        assert_eq!(c.busy_retries, 4);
        let gov = c.governor();
        assert_eq!(gov.max_stride, 8);
        assert_eq!(
            gov.retry,
            RetryPolicy::Backoff {
                initial: Duration::from_millis(10),
                cap: Duration::from_millis(320),
                retries: 4,
            }
        );
        // Defaults preserve the seed behavior: fail on first Busy, no skip.
        let c = RunConfig::default();
        assert_eq!(c.governor(), GovernorConfig { retry: RetryPolicy::Fail, max_stride: 1 });
    }

    #[test]
    fn parses_replication_and_chaos_flags() {
        let c = parse("bench --replicas 2 --chaos-seed 7 --chaos-intensity 0.5");
        assert_eq!(c.replicas, 2);
        assert_eq!(c.chaos_seed, 7);
        assert!((c.chaos_intensity - 0.5).abs() < 1e-9);
        // Defaults preserve the seed behavior: one copy, no faults.
        let c = RunConfig::default();
        assert_eq!((c.replicas, c.chaos_seed), (1, 0));
        let a = Args::parse(["x", "--replicas", "0"].map(String::from)).unwrap();
        assert!(RunConfig::from_args(&a).is_err(), "replicas 0 is rejected");
    }

    #[test]
    fn parses_reactor_flag() {
        let c = parse("bench --reactors 4");
        assert_eq!(c.reactors, 4);
        // 0 = auto (env-driven, one reactor when unset) — the default.
        assert_eq!(RunConfig::default().reactors, 0);
    }

    #[test]
    fn parses_flags() {
        let c = parse("bench --nodes 16 --engine keydb --deployment clustered --db-nodes 4");
        assert_eq!(c.nodes, 16);
        assert_eq!(c.engine, Engine::KeyDb);
        assert_eq!(c.deployment, Deployment::Clustered { db_nodes: 4 });
        assert_eq!(c.total_ranks(), 16 * 24);
    }

    #[test]
    fn rejects_bad_values() {
        let a = Args::parse(["x", "--engine", "mongo"].map(String::from)).unwrap();
        assert!(RunConfig::from_args(&a).is_err());
        let a = Args::parse(["x", "--nodes", "0"].map(String::from)).unwrap();
        assert!(RunConfig::from_args(&a).is_err());
    }
}
