//! Redis-cluster-style hash-slot sharding for the *clustered* deployment
//! (Fig 2 right panels; Fig 5b "sharded on multiple nodes").
//!
//! Keys map to one of 16384 slots via CRC16-CCITT (the actual redis-cluster
//! function, including `{hash tag}` support) and slots are split evenly
//! across the database shards.

/// Number of hash slots (redis-cluster constant).
pub const N_SLOTS: u16 = 16384;

/// CRC16-CCITT (XModem), the redis cluster key-hash polynomial (0x1021).
pub fn crc16(data: &[u8]) -> u16 {
    let mut crc: u16 = 0;
    for &b in data {
        crc ^= (b as u16) << 8;
        for _ in 0..8 {
            if crc & 0x8000 != 0 {
                crc = (crc << 1) ^ 0x1021;
            } else {
                crc <<= 1;
            }
        }
    }
    crc
}

/// The redis-cluster hash-tag rule: if the key contains `{...}` with a
/// non-empty body, only the body is hashed (lets clients co-locate related
/// keys on one shard).
pub fn hash_slot(key: &str) -> u16 {
    let bytes = key.as_bytes();
    let tagged = key
        .find('{')
        .and_then(|open| key[open + 1..].find('}').map(|close| (open, open + 1 + close)))
        .filter(|(open, close)| close > &(open + 1))
        .map(|(open, close)| &bytes[open + 1..close]);
    crc16(tagged.unwrap_or(bytes)) % N_SLOTS
}

/// Slot-to-shard routing table for a fixed number of shards.
#[derive(Debug, Clone)]
pub struct SlotMap {
    n_shards: usize,
}

impl SlotMap {
    pub fn new(n_shards: usize) -> SlotMap {
        assert!(n_shards > 0, "cluster needs at least one shard");
        SlotMap { n_shards }
    }

    pub fn n_shards(&self) -> usize {
        self.n_shards
    }

    /// Shard owning a slot: contiguous even ranges, like redis-cluster's
    /// default `cluster create` split.
    pub fn shard_for_slot(&self, slot: u16) -> usize {
        ((slot as usize) * self.n_shards) / N_SLOTS as usize
    }

    pub fn shard_for_key(&self, key: &str) -> usize {
        self.shard_for_slot(hash_slot(key))
    }

    /// Inclusive slot range served by a shard (exactly the preimage of
    /// [`Self::shard_for_slot`], so ranges tile `[0, N_SLOTS)`).
    pub fn slot_range(&self, shard: usize) -> (u16, u16) {
        assert!(shard < self.n_shards);
        let n = self.n_shards;
        let ns = N_SLOTS as usize;
        // shard_for_slot(slot) = floor(slot*n/ns) == s  <=>
        // slot in [ceil(s*ns/n), ceil((s+1)*ns/n) - 1].
        let lo = (shard * ns).div_ceil(n);
        let hi = ((shard + 1) * ns).div_ceil(n) - 1;
        (lo as u16, hi as u16)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{check, Gen};

    #[test]
    fn crc16_known_vectors() {
        // Redis cluster spec: HASH_SLOT("123456789") == 0x31C3 % 16384.
        assert_eq!(crc16(b"123456789"), 0x31c3);
        assert_eq!(hash_slot("123456789"), 0x31c3 % N_SLOTS);
        assert_eq!(crc16(b""), 0);
    }

    #[test]
    fn hash_tags_colocate() {
        assert_eq!(hash_slot("{user1}.field_a"), hash_slot("{user1}.field_b"));
        assert_eq!(hash_slot("{user1}"), hash_slot("prefix{user1}suffix"));
        // Empty tag body falls back to whole-key hashing.
        assert_ne!(hash_slot("{}a"), hash_slot("{}b"));
    }

    #[test]
    fn prop_partition_complete_and_disjoint() {
        // Every slot maps to exactly one shard and ranges tile [0, N_SLOTS).
        check("slotmap partition", 50, |g: &mut Gen| {
            let n = g.usize_in(1..=64);
            let sm = SlotMap::new(n);
            let mut covered = 0u32;
            for s in 0..n {
                let (lo, hi) = sm.slot_range(s);
                assert!(lo <= hi);
                covered += (hi - lo + 1) as u32;
                assert_eq!(sm.shard_for_slot(lo), s);
                assert_eq!(sm.shard_for_slot(hi), s);
            }
            assert_eq!(covered, N_SLOTS as u32);
        });
    }

    #[test]
    fn prop_key_routing_balanced() {
        // Rank/step-structured keys (the framework's key scheme) must spread
        // across shards within a loose balance bound.
        check("slot balance", 10, |g: &mut Gen| {
            let n = g.usize_in(2..=16);
            let sm = SlotMap::new(n);
            let mut counts = vec![0usize; n];
            let keys = 4000;
            for i in 0..keys {
                counts[sm.shard_for_key(&format!("field_rank{}_step{}", i % 97, i / 97))] += 1;
            }
            let mean = keys as f64 / n as f64;
            for c in counts {
                assert!((c as f64) > mean * 0.5 && (c as f64) < mean * 1.5, "imbalance: {c} vs {mean}");
            }
        });
    }

    #[test]
    fn shard_for_key_stable() {
        let sm = SlotMap::new(16);
        assert_eq!(sm.shard_for_key("x"), sm.shard_for_key("x"));
    }
}
