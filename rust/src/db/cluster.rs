//! Redis-cluster-style hash-slot sharding for the *clustered* deployment
//! (Fig 2 right panels; Fig 5b "sharded on multiple nodes").
//!
//! Keys map to one of 16384 slots via CRC16-CCITT (the actual redis-cluster
//! function, including `{hash tag}` support) and slots are split evenly
//! across the database shards.

/// Number of hash slots (redis-cluster constant).
pub const N_SLOTS: u16 = 16384;

/// CRC16-CCITT (XModem), the redis cluster key-hash polynomial (0x1021).
pub fn crc16(data: &[u8]) -> u16 {
    let mut crc: u16 = 0;
    for &b in data {
        crc ^= (b as u16) << 8;
        for _ in 0..8 {
            if crc & 0x8000 != 0 {
                crc = (crc << 1) ^ 0x1021;
            } else {
                crc <<= 1;
            }
        }
    }
    crc
}

/// The redis-cluster hash-tag rule: if the key contains `{...}` with a
/// non-empty body, only the body is hashed (lets clients co-locate related
/// keys on one shard).
pub fn hash_slot(key: &str) -> u16 {
    let bytes = key.as_bytes();
    let tagged = key
        .find('{')
        .and_then(|open| key[open + 1..].find('}').map(|close| (open, open + 1 + close)))
        .filter(|(open, close)| close > &(open + 1))
        .map(|(open, close)| &bytes[open + 1..close]);
    crc16(tagged.unwrap_or(bytes)) % N_SLOTS
}

/// Slot-to-shard routing table for a fixed number of shards.
#[derive(Debug, Clone)]
pub struct SlotMap {
    n_shards: usize,
}

impl SlotMap {
    pub fn new(n_shards: usize) -> SlotMap {
        assert!(n_shards > 0, "cluster needs at least one shard");
        SlotMap { n_shards }
    }

    pub fn n_shards(&self) -> usize {
        self.n_shards
    }

    /// Shard owning a slot: contiguous even ranges, like redis-cluster's
    /// default `cluster create` split.
    pub fn shard_for_slot(&self, slot: u16) -> usize {
        ((slot as usize) * self.n_shards) / N_SLOTS as usize
    }

    pub fn shard_for_key(&self, key: &str) -> usize {
        self.shard_for_slot(hash_slot(key))
    }

    /// Inclusive slot range served by a shard (exactly the preimage of
    /// [`Self::shard_for_slot`], so ranges tile `[0, N_SLOTS)`).
    pub fn slot_range(&self, shard: usize) -> (u16, u16) {
        assert!(shard < self.n_shards);
        let n = self.n_shards;
        let ns = N_SLOTS as usize;
        // shard_for_slot(slot) = floor(slot*n/ns) == s  <=>
        // slot in [ceil(s*ns/n), ceil((s+1)*ns/n) - 1].
        let lo = (shard * ns).div_ceil(n);
        let hi = ((shard + 1) * ns).div_ceil(n) - 1;
        (lo as u16, hi as u16)
    }
}

/// One contiguous slot range and its owner inside a [`SlotEpoch`] table.
///
/// `from` marks a range mid-migration: `shard` is the new owner (all
/// writes route there), while reads may still fall back to `from` until
/// the driver commits the cutover (data has landed on `shard`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SlotAssign {
    /// Inclusive slot range bounds.
    pub lo: u16,
    pub hi: u16,
    /// Owning shard (write target).
    pub shard: u16,
    /// Previous owner while the range's data is still streaming over.
    pub from: Option<u16>,
}

/// Epoch-versioned slot-ownership table: the elastic replacement for
/// [`SlotMap`].  Assignments are sorted, disjoint, and tile
/// `[0, N_SLOTS)` — [`Self::validate`] enforces it, and every
/// constructor in this module produces tables that pass.
///
/// Epoch 0 with `n` shards ([`Self::initial`]) routes byte-identically
/// to `SlotMap::new(n)`; higher epochs are produced only by the reshard
/// driver (`epoch` strictly increases on every membership/ownership
/// change, so "newer table" and "higher epoch" are the same statement).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SlotEpoch {
    pub epoch: u64,
    pub assignments: Vec<SlotAssign>,
}

impl SlotEpoch {
    /// The static even split at epoch 0 — exactly [`SlotMap::new`]'s
    /// layout, so a cluster that never reshards routes as it always has.
    pub fn initial(n_shards: usize) -> SlotEpoch {
        let sm = SlotMap::new(n_shards);
        let assignments = (0..n_shards)
            .map(|s| {
                let (lo, hi) = sm.slot_range(s);
                SlotAssign { lo, hi, shard: s as u16, from: None }
            })
            .collect();
        SlotEpoch { epoch: 0, assignments }
    }

    /// Build a table from a per-slot ownership function, compressing
    /// maximal runs of identical `(shard, from)` into one assignment.
    fn from_slot_fn(epoch: u64, f: impl Fn(u16) -> (u16, Option<u16>)) -> SlotEpoch {
        let mut assignments: Vec<SlotAssign> = Vec::new();
        for slot in 0..N_SLOTS {
            let (shard, from) = f(slot);
            match assignments.last_mut() {
                Some(a) if a.shard == shard && a.from == from && a.hi + 1 == slot => a.hi = slot,
                _ => assignments.push(SlotAssign { lo: slot, hi: slot, shard, from }),
            }
        }
        SlotEpoch { epoch, assignments }
    }

    /// Highest shard index referenced (owners and migration sources),
    /// plus one — the minimum shard-list length a client needs.
    pub fn n_shards(&self) -> usize {
        self.assignments
            .iter()
            .map(|a| a.shard.max(a.from.unwrap_or(0)) as usize + 1)
            .max()
            .unwrap_or(0)
    }

    /// Highest *owning* shard index plus one — the membership the cluster
    /// is heading to.  Differs from [`SlotEpoch::n_shards`] only while a
    /// shrink is in flight (migration sources above every owner); the
    /// server accepts replicated writes under either ring modulus so the
    /// drain's streaming writes land where the committed table will expect
    /// them.
    pub fn owner_count(&self) -> usize {
        self.assignments
            .iter()
            .map(|a| a.shard as usize + 1)
            .max()
            .unwrap_or(0)
    }

    /// The assignment covering `slot` (tables always tile, so this never
    /// fails on a validated table).
    pub fn assign_for_slot(&self, slot: u16) -> &SlotAssign {
        let i = self
            .assignments
            .partition_point(|a| a.hi < slot);
        &self.assignments[i]
    }

    /// Current owner (write target) of a slot.
    pub fn shard_for_slot(&self, slot: u16) -> usize {
        self.assign_for_slot(slot).shard as usize
    }

    pub fn shard_for_key(&self, key: &str) -> usize {
        self.shard_for_slot(hash_slot(key))
    }

    /// Old owner of a mid-migration slot, if any — the read-fallback
    /// target until the range's data has landed on the new owner.
    pub fn fallback_for_slot(&self, slot: u16) -> Option<usize> {
        self.assign_for_slot(slot).from.map(|s| s as usize)
    }

    /// Structural invariants every table on the wire must satisfy:
    /// sorted, disjoint, tiling `[0, N_SLOTS)`, no self-migration.
    pub fn validate(&self) -> Result<(), String> {
        let mut next = 0u32;
        for a in &self.assignments {
            if a.lo as u32 != next {
                return Err(format!("gap/overlap at slot {next}: next range starts at {}", a.lo));
            }
            if a.hi < a.lo {
                return Err(format!("inverted range {}..={}", a.lo, a.hi));
            }
            if a.from == Some(a.shard) {
                return Err(format!("range {}..={} migrates to itself", a.lo, a.hi));
            }
            next = a.hi as u32 + 1;
        }
        if next != N_SLOTS as u32 {
            return Err(format!("table covers [0, {next}), wants [0, {})", N_SLOTS));
        }
        Ok(())
    }

    /// Maximal contiguous ranges whose owner differs between `self` and
    /// `target`, as `(lo, hi, old_owner, new_owner)` — the reshard
    /// driver's transfer work list.
    pub fn moved_ranges(&self, target: &SlotEpoch) -> Vec<(u16, u16, u16, u16)> {
        let mut moves: Vec<(u16, u16, u16, u16)> = Vec::new();
        for slot in 0..N_SLOTS {
            let old = self.shard_for_slot(slot) as u16;
            let new = target.shard_for_slot(slot) as u16;
            if old == new {
                continue;
            }
            match moves.last_mut() {
                Some((_, hi, o, n)) if *o == old && *n == new && *hi + 1 == slot => *hi = slot,
                _ => moves.push((slot, slot, old, new)),
            }
        }
        moves
    }

    /// Next-epoch table with `moves` marked mid-migration: each moved
    /// range is owned by its new shard with `from` pointing at the old
    /// one.  Ranges not listed keep their current owner (and lose any
    /// stale migration marker — one migration is in flight at a time).
    pub fn with_moves(&self, moves: &[(u16, u16, u16, u16)]) -> SlotEpoch {
        Self::from_slot_fn(self.epoch + 1, |slot| {
            for &(lo, hi, old, new) in moves {
                if slot >= lo && slot <= hi {
                    return (new, Some(old));
                }
            }
            (self.shard_for_slot(slot) as u16, None)
        })
    }

    /// Next-epoch table committing every in-flight migration: ownership
    /// unchanged, all `from` markers cleared (data has landed; reads no
    /// longer fall back).
    pub fn committed(&self) -> SlotEpoch {
        Self::from_slot_fn(self.epoch + 1, |slot| (self.shard_for_slot(slot) as u16, None))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{check, Gen};

    #[test]
    fn crc16_known_vectors() {
        // Redis cluster spec: HASH_SLOT("123456789") == 0x31C3 % 16384.
        assert_eq!(crc16(b"123456789"), 0x31c3);
        assert_eq!(hash_slot("123456789"), 0x31c3 % N_SLOTS);
        assert_eq!(crc16(b""), 0);
    }

    #[test]
    fn hash_tags_colocate() {
        assert_eq!(hash_slot("{user1}.field_a"), hash_slot("{user1}.field_b"));
        assert_eq!(hash_slot("{user1}"), hash_slot("prefix{user1}suffix"));
        // Empty tag body falls back to whole-key hashing.
        assert_ne!(hash_slot("{}a"), hash_slot("{}b"));
    }

    #[test]
    fn prop_partition_complete_and_disjoint() {
        // Every slot maps to exactly one shard and ranges tile [0, N_SLOTS).
        check("slotmap partition", 50, |g: &mut Gen| {
            let n = g.usize_in(1..=64);
            let sm = SlotMap::new(n);
            let mut covered = 0u32;
            for s in 0..n {
                let (lo, hi) = sm.slot_range(s);
                assert!(lo <= hi);
                covered += (hi - lo + 1) as u32;
                assert_eq!(sm.shard_for_slot(lo), s);
                assert_eq!(sm.shard_for_slot(hi), s);
            }
            assert_eq!(covered, N_SLOTS as u32);
        });
    }

    #[test]
    fn prop_key_routing_balanced() {
        // Rank/step-structured keys (the framework's key scheme) must spread
        // across shards within a loose balance bound.
        check("slot balance", 10, |g: &mut Gen| {
            let n = g.usize_in(2..=16);
            let sm = SlotMap::new(n);
            let mut counts = vec![0usize; n];
            let keys = 4000;
            for i in 0..keys {
                counts[sm.shard_for_key(&format!("field_rank{}_step{}", i % 97, i / 97))] += 1;
            }
            let mean = keys as f64 / n as f64;
            for c in counts {
                assert!((c as f64) > mean * 0.5 && (c as f64) < mean * 1.5, "imbalance: {c} vs {mean}");
            }
        });
    }

    #[test]
    fn shard_for_key_stable() {
        let sm = SlotMap::new(16);
        assert_eq!(sm.shard_for_key("x"), sm.shard_for_key("x"));
    }

    #[test]
    fn prop_epoch0_routes_identically_to_static_slotmap() {
        // The elastic table at epoch 0 must be a drop-in for SlotMap: same
        // owner for every slot (hence byte-identical request routing), and
        // the assignment ranges are exactly SlotMap's preimages.
        check("epoch0 == slotmap", 25, |g: &mut Gen| {
            let n = g.usize_in(1..=64);
            let sm = SlotMap::new(n);
            let ep = SlotEpoch::initial(n);
            assert_eq!(ep.epoch, 0);
            assert_eq!(ep.n_shards(), n);
            ep.validate().unwrap();
            for slot in 0..N_SLOTS {
                assert_eq!(ep.shard_for_slot(slot), sm.shard_for_slot(slot));
                assert_eq!(ep.fallback_for_slot(slot), None);
            }
            for (s, a) in ep.assignments.iter().enumerate() {
                assert_eq!((a.lo, a.hi), sm.slot_range(s));
            }
            // And the key path composes through the same hash.
            for i in 0..200 {
                let k = format!("f_rank{}_step{}", i % 7, i);
                assert_eq!(ep.shard_for_key(&k), sm.shard_for_key(&k));
            }
        });
    }

    #[test]
    fn prop_resharded_table_partition_complete_and_disjoint() {
        // After a reshard (n -> m shards, mid-migration and committed):
        // every slot owned by exactly one shard, ranges still tile
        // [0, N_SLOTS), and moved_ranges covers exactly the disagreement.
        check("reshard partition", 25, |g: &mut Gen| {
            let n = g.usize_in(1..=16);
            let m = g.usize_in(1..=16);
            let from = SlotEpoch::initial(n);
            let target = SlotEpoch::initial(m);
            let moves = from.moved_ranges(&target);
            let mid = from.with_moves(&moves);
            mid.validate().unwrap();
            assert_eq!(mid.epoch, from.epoch + 1);
            let committed = mid.committed();
            committed.validate().unwrap();
            assert_eq!(committed.epoch, mid.epoch + 1);
            let mut covered = 0u32;
            for a in &committed.assignments {
                covered += (a.hi - a.lo + 1) as u32;
            }
            assert_eq!(covered, N_SLOTS as u32);
            for slot in 0..N_SLOTS {
                // Mid-migration ownership is already the target layout,
                // with the fallback pointing at the old owner iff moved.
                assert_eq!(mid.shard_for_slot(slot), target.shard_for_slot(slot));
                let moved = from.shard_for_slot(slot) != target.shard_for_slot(slot);
                assert_eq!(
                    mid.fallback_for_slot(slot),
                    moved.then_some(from.shard_for_slot(slot)),
                );
                // Committed: same owners, no fallback anywhere.
                assert_eq!(committed.shard_for_slot(slot), target.shard_for_slot(slot));
                assert_eq!(committed.fallback_for_slot(slot), None);
            }
            // moved_ranges is a partition of the disagreement set.
            let mut in_moves = vec![false; N_SLOTS as usize];
            for (lo, hi, old, new) in moves {
                assert_ne!(old, new);
                for s in lo..=hi {
                    assert!(!in_moves[s as usize], "overlapping move at {s}");
                    in_moves[s as usize] = true;
                    assert_eq!(from.shard_for_slot(s), old as usize);
                    assert_eq!(target.shard_for_slot(s), new as usize);
                }
            }
            for slot in 0..N_SLOTS {
                assert_eq!(
                    in_moves[slot as usize],
                    from.shard_for_slot(slot) != target.shard_for_slot(slot),
                );
            }
        });
    }
}
