//! Database engine disciplines: the Redis-vs-KeyDB distinction (paper §2.1,
//! Fig 3).
//!
//! * **Redis** executes commands on a single thread; additional cores only
//!   help the I/O path (`io-threads`), so the service rate plateaus once
//!   enough cores cover socket handling — the paper observes the plateau at
//!   **8 logical cores**.
//! * **KeyDB** runs a multi-threaded, sharded command path and reaches its
//!   plateau already at **4 logical cores**.
//!
//! The same model parameterizes both the *real* TCP server (a global command
//! mutex for redis vs shard-local locking for keydb) and the DES service
//! capacity used for the scaling figures.

use std::sync::{Mutex, MutexGuard};

/// Which execution discipline the database uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Engine {
    Redis,
    KeyDb,
}

impl Engine {
    pub fn parse(s: &str) -> Option<Engine> {
        match s.to_ascii_lowercase().as_str() {
            "redis" => Some(Engine::Redis),
            "keydb" => Some(Engine::KeyDb),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Engine::Redis => "redis",
            Engine::KeyDb => "keydb",
        }
    }

    /// Cores at which the engine's request-service rate saturates (Fig 3:
    /// redis flat for >= 8 cores, keydb already performant at 4).
    pub fn saturation_cores(self) -> usize {
        match self {
            Engine::Redis => 8,
            Engine::KeyDb => 4,
        }
    }

    /// Effective parallel service capacity given a core allocation.
    ///
    /// This is the knob the DES uses: the request-processing rate scales
    /// linearly until the engine saturates.  Expressed as a fraction of the
    /// engine's peak single-node service rate.
    pub fn service_fraction(self, cores: usize) -> f64 {
        let sat = self.saturation_cores() as f64;
        ((cores as f64) / sat).min(1.0)
    }

    /// How many command-execution threads the *real* server runs.  Redis
    /// serializes command execution (1); KeyDB executes on all cores.
    pub fn exec_threads(self, cores: usize) -> usize {
        match self {
            Engine::Redis => 1,
            Engine::KeyDb => cores.max(1),
        }
    }
}

/// Serialization guard implementing the discipline in the real server:
/// `lock()` is contended for Redis (single command thread) and a no-op for
/// KeyDB (shard locks inside [`crate::db::Store`] provide the only mutual
/// exclusion, as in KeyDB's per-slot locking).
pub struct CommandGate {
    engine: Engine,
    gate: Mutex<()>,
}

/// RAII guard; holds the global lock only under the Redis discipline.
pub struct GateGuard<'a> {
    _guard: Option<MutexGuard<'a, ()>>,
}

impl CommandGate {
    pub fn new(engine: Engine) -> CommandGate {
        CommandGate { engine, gate: Mutex::new(()) }
    }

    pub fn engine(&self) -> Engine {
        self.engine
    }

    pub fn enter(&self) -> GateGuard<'_> {
        match self.engine {
            Engine::Redis => GateGuard { _guard: Some(self.gate.lock().unwrap()) },
            Engine::KeyDb => GateGuard { _guard: None },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    #[test]
    fn parse_names() {
        assert_eq!(Engine::parse("redis"), Some(Engine::Redis));
        assert_eq!(Engine::parse("KeyDB"), Some(Engine::KeyDb));
        assert_eq!(Engine::parse("mongo"), None);
    }

    #[test]
    fn service_fraction_plateaus() {
        // Fig 3 shape: redis needs 8 cores for peak, keydb peaks at 4.
        assert!((Engine::Redis.service_fraction(4) - 0.5).abs() < 1e-12);
        assert_eq!(Engine::Redis.service_fraction(8), 1.0);
        assert_eq!(Engine::Redis.service_fraction(32), 1.0);
        assert_eq!(Engine::KeyDb.service_fraction(4), 1.0);
        assert_eq!(Engine::KeyDb.service_fraction(2), 0.5);
    }

    #[test]
    fn redis_gate_serializes() {
        let gate = Arc::new(CommandGate::new(Engine::Redis));
        let inside = Arc::new(AtomicUsize::new(0));
        let peak = Arc::new(AtomicUsize::new(0));
        let mut hs = Vec::new();
        for _ in 0..8 {
            let (gate, inside, peak) = (gate.clone(), inside.clone(), peak.clone());
            hs.push(std::thread::spawn(move || {
                for _ in 0..200 {
                    let _g = gate.enter();
                    let now = inside.fetch_add(1, Ordering::SeqCst) + 1;
                    peak.fetch_max(now, Ordering::SeqCst);
                    inside.fetch_sub(1, Ordering::SeqCst);
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(peak.load(Ordering::SeqCst), 1, "redis discipline is serialized");
    }

    #[test]
    fn keydb_gate_is_concurrent() {
        let gate = Arc::new(CommandGate::new(Engine::KeyDb));
        let inside = Arc::new(AtomicUsize::new(0));
        let peak = Arc::new(AtomicUsize::new(0));
        let mut hs = Vec::new();
        for _ in 0..8 {
            let (gate, inside, peak) = (gate.clone(), inside.clone(), peak.clone());
            hs.push(std::thread::spawn(move || {
                for _ in 0..500 {
                    let _g = gate.enter();
                    let now = inside.fetch_add(1, Ordering::SeqCst) + 1;
                    peak.fetch_max(now, Ordering::SeqCst);
                    std::hint::spin_loop();
                    inside.fetch_sub(1, Ordering::SeqCst);
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        // On a single-core host the scheduler may still serialize, so only
        // assert the gate itself never blocks: peak >= 1 and no deadlock.
        assert!(peak.load(Ordering::SeqCst) >= 1);
    }
}
