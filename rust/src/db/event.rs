//! Readiness polling for the event-driven server core.
//!
//! A thin, dependency-free wrapper over the OS readiness API: `epoll` on
//! Linux (level-triggered), `poll(2)` on other unix targets.  The server's
//! reactor registers every connection socket plus a self-wake pipe and
//! sleeps in [`Poller::wait`] until something is actually ready — an idle
//! server makes **zero** wakeups, where the old thread-per-connection core
//! woke every connection once per `conn_read_timeout` just to re-check the
//! stop flag.
//!
//! The FFI is hand-rolled (no `libc` crate in the dependency tree): `std`
//! already links the platform C library, so declaring the four syscall
//! entry points is enough.
//!
//! [`Waker`]/[`WakeReceiver`] are the cross-thread doorbell: executor
//! threads and the poll hub complete work by pushing to a queue and
//! ringing the waker, which the reactor has registered like any other
//! readable fd.

use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener};
use std::os::unix::io::{AsRawFd, RawFd};
use std::os::unix::net::UnixStream;
use std::time::Duration;

/// One readiness event: which registration fired and how.
#[derive(Debug, Clone, Copy)]
pub struct Event {
    pub token: u64,
    pub readable: bool,
    pub writable: bool,
    /// Error or hangup on the fd — the owner should read to EOF/error and
    /// close.  May accompany `readable`.
    pub hangup: bool,
}

pub use imp::Poller;

#[cfg(target_os = "linux")]
mod imp {
    use super::Event;
    use std::io;
    use std::os::raw::c_int;
    use std::os::unix::io::RawFd;
    use std::time::Duration;

    const EPOLLIN: u32 = 0x001;
    const EPOLLOUT: u32 = 0x004;
    const EPOLLERR: u32 = 0x008;
    const EPOLLHUP: u32 = 0x010;
    /// Peer shut down its write half; surfaces hangups even while read
    /// interest is paused for backpressure.
    const EPOLLRDHUP: u32 = 0x2000;
    const EPOLL_CTL_ADD: c_int = 1;
    const EPOLL_CTL_DEL: c_int = 2;
    const EPOLL_CTL_MOD: c_int = 3;
    const EPOLL_CLOEXEC: c_int = 0o2000000;

    #[repr(C)]
    #[cfg_attr(target_arch = "x86_64", repr(packed))]
    #[derive(Clone, Copy)]
    struct EpollEvent {
        events: u32,
        data: u64,
    }

    extern "C" {
        fn epoll_create1(flags: c_int) -> c_int;
        fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut EpollEvent) -> c_int;
        fn epoll_wait(
            epfd: c_int,
            events: *mut EpollEvent,
            maxevents: c_int,
            timeout: c_int,
        ) -> c_int;
        fn close(fd: c_int) -> c_int;
    }

    fn cvt(r: c_int) -> io::Result<c_int> {
        if r < 0 {
            Err(io::Error::last_os_error())
        } else {
            Ok(r)
        }
    }

    fn mask(read: bool, write: bool) -> u32 {
        let mut m = EPOLLRDHUP;
        if read {
            m |= EPOLLIN;
        }
        if write {
            m |= EPOLLOUT;
        }
        m
    }

    /// Level-triggered epoll instance.  Owned by the reactor thread; all
    /// methods take `&mut self`.
    pub struct Poller {
        epfd: c_int,
        buf: Vec<EpollEvent>,
    }

    impl Poller {
        pub fn new() -> io::Result<Poller> {
            let epfd = cvt(unsafe { epoll_create1(EPOLL_CLOEXEC) })?;
            Ok(Poller { epfd, buf: vec![EpollEvent { events: 0, data: 0 }; 1024] })
        }

        fn ctl(&mut self, op: c_int, fd: RawFd, events: u32, token: u64) -> io::Result<()> {
            let mut ev = EpollEvent { events, data: token };
            cvt(unsafe { epoll_ctl(self.epfd, op, fd, &mut ev) })?;
            Ok(())
        }

        pub fn register(
            &mut self,
            fd: RawFd,
            token: u64,
            read: bool,
            write: bool,
        ) -> io::Result<()> {
            self.ctl(EPOLL_CTL_ADD, fd, mask(read, write), token)
        }

        pub fn rearm(&mut self, fd: RawFd, token: u64, read: bool, write: bool) -> io::Result<()> {
            self.ctl(EPOLL_CTL_MOD, fd, mask(read, write), token)
        }

        pub fn deregister(&mut self, fd: RawFd) -> io::Result<()> {
            self.ctl(EPOLL_CTL_DEL, fd, 0, 0)
        }

        /// Block until readiness or `timeout` (`None` = forever), appending
        /// events to `out`.  A signal interruption returns with no events.
        pub fn wait(&mut self, timeout: Option<Duration>, out: &mut Vec<Event>) -> io::Result<()> {
            let ms: c_int = match timeout {
                None => -1,
                Some(d) if d.is_zero() => 0,
                // Round up so a 500µs deadline can't busy-spin at 0ms.
                Some(d) => d.as_millis().saturating_add(1).min(c_int::MAX as u128) as c_int,
            };
            let n = match cvt(unsafe {
                epoll_wait(self.epfd, self.buf.as_mut_ptr(), self.buf.len() as c_int, ms)
            }) {
                Ok(n) => n as usize,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => 0,
                Err(e) => return Err(e),
            };
            for ev in self.buf.iter().take(n).copied() {
                // Copy the packed fields out by value (no references into a
                // potentially unaligned struct).
                let events = ev.events;
                let token = ev.data;
                out.push(Event {
                    token,
                    readable: events & EPOLLIN != 0,
                    writable: events & EPOLLOUT != 0,
                    hangup: events & (EPOLLERR | EPOLLHUP | EPOLLRDHUP) != 0,
                });
            }
            Ok(())
        }
    }

    impl Drop for Poller {
        fn drop(&mut self) {
            unsafe {
                close(self.epfd);
            }
        }
    }
}

#[cfg(not(target_os = "linux"))]
mod imp {
    use super::Event;
    use std::collections::HashMap;
    use std::io;
    use std::os::raw::{c_int, c_short, c_uint};
    use std::os::unix::io::RawFd;
    use std::time::Duration;

    const POLLIN: c_short = 0x001;
    const POLLOUT: c_short = 0x004;
    const POLLERR: c_short = 0x008;
    const POLLHUP: c_short = 0x010;

    #[repr(C)]
    #[derive(Clone, Copy)]
    struct PollFd {
        fd: c_int,
        events: c_short,
        revents: c_short,
    }

    extern "C" {
        // `nfds_t` is `unsigned int` on the BSDs/macOS, the only targets
        // that reach this fallback (Linux uses the epoll backend).
        fn poll(fds: *mut PollFd, nfds: c_uint, timeout: c_int) -> c_int;
    }

    /// `poll(2)` fallback: rebuilds the fd array per wait from an interest
    /// map.  O(n) per wakeup, which is fine for the non-Linux dev loop.
    pub struct Poller {
        interest: HashMap<RawFd, (u64, bool, bool)>,
        fds: Vec<PollFd>,
    }

    impl Poller {
        pub fn new() -> io::Result<Poller> {
            Ok(Poller { interest: HashMap::new(), fds: Vec::new() })
        }

        pub fn register(
            &mut self,
            fd: RawFd,
            token: u64,
            read: bool,
            write: bool,
        ) -> io::Result<()> {
            self.interest.insert(fd, (token, read, write));
            Ok(())
        }

        pub fn rearm(&mut self, fd: RawFd, token: u64, read: bool, write: bool) -> io::Result<()> {
            self.interest.insert(fd, (token, read, write));
            Ok(())
        }

        pub fn deregister(&mut self, fd: RawFd) -> io::Result<()> {
            self.interest.remove(&fd);
            Ok(())
        }

        pub fn wait(&mut self, timeout: Option<Duration>, out: &mut Vec<Event>) -> io::Result<()> {
            self.fds.clear();
            for (&fd, &(_, read, write)) in &self.interest {
                let mut events = 0;
                if read {
                    events |= POLLIN;
                }
                if write {
                    events |= POLLOUT;
                }
                self.fds.push(PollFd { fd, events, revents: 0 });
            }
            let ms: c_int = match timeout {
                None => -1,
                Some(d) if d.is_zero() => 0,
                Some(d) => d.as_millis().saturating_add(1).min(c_int::MAX as u128) as c_int,
            };
            let n = unsafe { poll(self.fds.as_mut_ptr(), self.fds.len() as c_uint, ms) };
            if n < 0 {
                let e = io::Error::last_os_error();
                if e.kind() == io::ErrorKind::Interrupted {
                    return Ok(());
                }
                return Err(e);
            }
            for pfd in &self.fds {
                if pfd.revents == 0 {
                    continue;
                }
                let (token, _, _) = self.interest[&pfd.fd];
                out.push(Event {
                    token,
                    readable: pfd.revents & POLLIN != 0,
                    writable: pfd.revents & POLLOUT != 0,
                    hangup: pfd.revents & (POLLERR | POLLHUP) != 0,
                });
            }
            Ok(())
        }
    }
}

/// Bind a TCP listener with `SO_REUSEPORT` set *before* the bind, so
/// several listeners can share one address and the kernel load-balances
/// accepted connections across them — the reactor-sharding accept path.
///
/// `std::net::TcpListener::bind` cannot express this (the option must be
/// set between `socket(2)` and `bind(2)`), so the socket is built by hand
/// through the same no-`libc`-crate FFI discipline as the poller.  Returns
/// [`io::ErrorKind::Unsupported`] on targets without the option; callers
/// fall back to a single acceptor that hands sockets to the other reactors
/// over their doorbells.
pub fn bind_reuseport(addr: SocketAddr) -> io::Result<TcpListener> {
    imp_sock::bind_reuseport(addr)
}

/// Runtime capability probe: whether [`bind_reuseport`] works here (one
/// throwaway ephemeral-port bind, checked once per server start).
pub fn reuseport_available() -> bool {
    bind_reuseport("127.0.0.1:0".parse().unwrap()).is_ok()
}

#[cfg(target_os = "linux")]
mod imp_sock {
    use std::io;
    use std::mem;
    use std::net::{SocketAddr, TcpListener};
    use std::os::raw::{c_int, c_uint, c_ushort, c_void};
    use std::os::unix::io::FromRawFd;

    const AF_INET: c_int = 2;
    const AF_INET6: c_int = 10;
    const SOCK_STREAM: c_int = 1;
    const SOCK_CLOEXEC: c_int = 0o2000000;
    const SOL_SOCKET: c_int = 1;
    const SO_REUSEADDR: c_int = 2;
    const SO_REUSEPORT: c_int = 15;
    const LISTEN_BACKLOG: c_int = 1024;

    /// `struct sockaddr_in`: port and address stored in network byte order
    /// (the address as raw memory-order octets).
    #[repr(C)]
    struct SockaddrIn {
        sin_family: c_ushort,
        sin_port: u16,
        sin_addr: u32,
        sin_zero: [u8; 8],
    }

    #[repr(C)]
    struct SockaddrIn6 {
        sin6_family: c_ushort,
        sin6_port: u16,
        sin6_flowinfo: u32,
        sin6_addr: [u8; 16],
        sin6_scope_id: u32,
    }

    extern "C" {
        fn socket(domain: c_int, ty: c_int, protocol: c_int) -> c_int;
        fn setsockopt(
            fd: c_int,
            level: c_int,
            name: c_int,
            val: *const c_void,
            len: c_uint,
        ) -> c_int;
        fn bind(fd: c_int, addr: *const c_void, len: c_uint) -> c_int;
        fn listen(fd: c_int, backlog: c_int) -> c_int;
        fn close(fd: c_int) -> c_int;
    }

    /// Closes the fd on early-error return paths; forgotten once the fd's
    /// ownership transfers to the `TcpListener`.
    struct FdGuard(c_int);

    impl Drop for FdGuard {
        fn drop(&mut self) {
            unsafe {
                close(self.0);
            }
        }
    }

    pub fn bind_reuseport(addr: SocketAddr) -> io::Result<TcpListener> {
        let family = match addr {
            SocketAddr::V4(_) => AF_INET,
            SocketAddr::V6(_) => AF_INET6,
        };
        unsafe {
            let fd = socket(family, SOCK_STREAM | SOCK_CLOEXEC, 0);
            if fd < 0 {
                return Err(io::Error::last_os_error());
            }
            let guard = FdGuard(fd);
            let one: c_int = 1;
            for opt in [SO_REUSEADDR, SO_REUSEPORT] {
                if setsockopt(
                    fd,
                    SOL_SOCKET,
                    opt,
                    &one as *const c_int as *const c_void,
                    mem::size_of::<c_int>() as c_uint,
                ) < 0
                {
                    return Err(io::Error::last_os_error());
                }
            }
            let bound = match addr {
                SocketAddr::V4(v4) => {
                    let sa = SockaddrIn {
                        sin_family: AF_INET as c_ushort,
                        sin_port: v4.port().to_be(),
                        sin_addr: u32::from_ne_bytes(v4.ip().octets()),
                        sin_zero: [0; 8],
                    };
                    bind(
                        fd,
                        &sa as *const SockaddrIn as *const c_void,
                        mem::size_of::<SockaddrIn>() as c_uint,
                    )
                }
                SocketAddr::V6(v6) => {
                    let sa = SockaddrIn6 {
                        sin6_family: AF_INET6 as c_ushort,
                        sin6_port: v6.port().to_be(),
                        sin6_flowinfo: v6.flowinfo(),
                        sin6_addr: v6.ip().octets(),
                        sin6_scope_id: v6.scope_id(),
                    };
                    bind(
                        fd,
                        &sa as *const SockaddrIn6 as *const c_void,
                        mem::size_of::<SockaddrIn6>() as c_uint,
                    )
                }
            };
            if bound < 0 {
                return Err(io::Error::last_os_error());
            }
            if listen(fd, LISTEN_BACKLOG) < 0 {
                return Err(io::Error::last_os_error());
            }
            mem::forget(guard);
            Ok(TcpListener::from_raw_fd(fd))
        }
    }
}

#[cfg(not(target_os = "linux"))]
mod imp_sock {
    use std::io;
    use std::net::{SocketAddr, TcpListener};

    /// `SO_REUSEPORT` exists on the BSDs but does not load-balance accepts
    /// the way the sharded-accept path needs; report unsupported so the
    /// server takes the acceptor-handoff fallback.
    pub fn bind_reuseport(_addr: SocketAddr) -> io::Result<TcpListener> {
        Err(io::Error::new(
            io::ErrorKind::Unsupported,
            "SO_REUSEPORT accept sharding is only wired up on Linux",
        ))
    }
}

/// Write half of the reactor's self-wake pipe.  Cheap, clonable via `Arc`,
/// callable from any thread; coalesces (a full pipe means a wake is already
/// pending, so `WouldBlock` is ignored).
#[derive(Debug)]
pub struct Waker {
    tx: UnixStream,
}

impl Waker {
    pub fn wake(&self) {
        let _ = (&self.tx).write(&[1u8]);
    }
}

/// Read half of the self-wake pipe; the reactor registers its fd and drains
/// it whenever it fires.
#[derive(Debug)]
pub struct WakeReceiver {
    rx: UnixStream,
}

impl WakeReceiver {
    pub fn drain(&self) {
        let mut buf = [0u8; 64];
        while matches!((&self.rx).read(&mut buf), Ok(n) if n > 0) {}
    }
}

impl AsRawFd for WakeReceiver {
    fn as_raw_fd(&self) -> RawFd {
        self.rx.as_raw_fd()
    }
}

/// Build a connected waker pair, both ends nonblocking.
pub fn waker() -> io::Result<(Waker, WakeReceiver)> {
    let (tx, rx) = UnixStream::pair()?;
    tx.set_nonblocking(true)?;
    rx.set_nonblocking(true)?;
    Ok((Waker { tx }, WakeReceiver { rx }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    #[test]
    fn waker_fires_readiness_and_drains() {
        let (wake, recv) = waker().unwrap();
        let mut poller = Poller::new().unwrap();
        poller.register(recv.as_raw_fd(), 42, true, false).unwrap();

        let mut events = Vec::new();
        poller.wait(Some(Duration::from_millis(1)), &mut events).unwrap();
        assert!(events.is_empty(), "no events before wake");

        wake.wake();
        wake.wake(); // coalesces
        poller.wait(Some(Duration::from_millis(500)), &mut events).unwrap();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].token, 42);
        assert!(events[0].readable);

        recv.drain();
        events.clear();
        poller.wait(Some(Duration::from_millis(1)), &mut events).unwrap();
        assert!(events.is_empty(), "drained pipe is quiet again");
    }

    #[test]
    fn write_interest_toggles_via_rearm() {
        let (a, b) = UnixStream::pair().unwrap();
        a.set_nonblocking(true).unwrap();
        let mut poller = Poller::new().unwrap();
        // Read-only interest on an always-writable socket: no events.
        poller.register(a.as_raw_fd(), 1, true, false).unwrap();
        let mut events = Vec::new();
        poller.wait(Some(Duration::from_millis(1)), &mut events).unwrap();
        assert!(events.is_empty());
        // Arm write interest: fires immediately (buffer has room).
        poller.rearm(a.as_raw_fd(), 1, true, true).unwrap();
        poller.wait(Some(Duration::from_millis(500)), &mut events).unwrap();
        assert!(events.iter().any(|e| e.token == 1 && e.writable));
        poller.deregister(a.as_raw_fd()).unwrap();
        drop(b);
    }

    #[test]
    #[cfg(target_os = "linux")]
    fn reuseport_listeners_share_one_port() {
        assert!(reuseport_available());
        let a = bind_reuseport("127.0.0.1:0".parse().unwrap()).unwrap();
        let addr = a.local_addr().unwrap();
        assert_ne!(addr.port(), 0, "ephemeral bind resolved to a real port");
        let b = bind_reuseport(addr).unwrap();
        assert_eq!(b.local_addr().unwrap(), addr);
        a.set_nonblocking(true).unwrap();
        b.set_nonblocking(true).unwrap();
        let _c = std::net::TcpStream::connect(addr).unwrap();
        let deadline = Instant::now() + Duration::from_secs(2);
        let mut accepted = false;
        while Instant::now() < deadline {
            if a.accept().is_ok() || b.accept().is_ok() {
                accepted = true;
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(accepted, "one of the two shared listeners took the connection");
    }

    #[test]
    fn timed_wait_returns_near_deadline_not_after() {
        let (_a, b) = UnixStream::pair().unwrap();
        let mut poller = Poller::new().unwrap();
        poller.register(b.as_raw_fd(), 9, true, false).unwrap();
        let start = Instant::now();
        let mut events = Vec::new();
        poller.wait(Some(Duration::from_millis(30)), &mut events).unwrap();
        let waited = start.elapsed();
        assert!(events.is_empty());
        assert!(waited >= Duration::from_millis(25), "slept close to the deadline: {waited:?}");
        assert!(waited < Duration::from_secs(2), "did not oversleep: {waited:?}");
    }
}
