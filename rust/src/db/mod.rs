//! The in-memory tensor database — the Redis/KeyDB analogue at the center of
//! the framework (DESIGN.md substitutions table).
//!
//! * [`store`] — sharded key-value tensor/metadata store (shared-nothing
//!   within a node; the paper's "key-value store with a shared-nothing
//!   architecture enabling low-latency access to many clients in parallel").
//! * [`engine`] — the two execution disciplines reproduced from the paper's
//!   Redis-vs-KeyDB comparison: a single serialized command thread fed by
//!   I/O threads (redis) vs fully sharded multi-threaded execution (keydb).
//! * [`server`] — TCP server speaking [`crate::proto`]; a readiness-driven
//!   reactor multiplexes every connection (one SmartRedis client per
//!   simulation rank in the paper) over one event loop, with a small
//!   engine-sized executor pool and a timer hub for parked waits.
//! * [`event`] — dependency-free epoll/poll readiness wrapper backing the
//!   server's event loop.
//! * [`cluster`] — redis-cluster-style hash-slot sharding used by the
//!   *clustered* deployment (Fig 2, right panels; Fig 5b sharded DB).

//! * [`spill`] — optional spill-to-disk cold tier: retention victims are
//!   appended to a CRC-checksummed segment log and stay replayable
//!   (`ColdGet`/`ColdList`) after eviction.

pub mod cluster;
pub mod engine;
pub mod event;
pub mod server;
pub mod spill;
pub mod store;

pub use engine::Engine;
pub use server::{DbServer, ServerConfig};
pub use spill::SpillConfig;
pub use store::{parse_step_key, RetentionConfig, Store};
