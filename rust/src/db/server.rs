//! TCP database server: accepts SmartRedis-analogue clients and executes
//! commands against the node-local [`Store`] and [`crate::ai::ModelRuntime`].
//!
//! Threading model mirrors the engines being reproduced: a reader thread per
//! connection (redis io-threads / keydb server threads) with command
//! execution passing through the engine's [`CommandGate`].

use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::ai::ModelRuntime;
use crate::db::engine::{CommandGate, Engine};
use crate::db::store::Store;
use crate::error::{Error, Result};
use crate::proto::{read_frame, write_frame, Request, Response};
use crate::runtime::Executor;

/// Server configuration (one database instance; the clustered deployment
/// launches several of these and routes with [`crate::db::cluster`]).
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Bind address; port 0 picks a free port.
    pub addr: SocketAddr,
    pub engine: Engine,
    /// Logical cores assigned to the DB (the Fig-3 knob).  Recorded in INFO
    /// and used to parameterize the engine model; the real thread count is
    /// connection-driven.
    pub cores: usize,
    /// Enable the model runtime (needs a PJRT executor thread).  Data-only
    /// benches turn this off to skip PJRT startup.
    pub with_models: bool,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:0".parse().unwrap(),
            engine: Engine::Redis,
            cores: 8,
            with_models: true,
        }
    }
}

/// A running database server.  Dropping the handle shuts it down.
pub struct DbServer {
    pub addr: SocketAddr,
    store: Arc<Store>,
    models: Option<Arc<ModelRuntime>>,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
    pub config: ServerConfig,
}

impl DbServer {
    /// Start a server (with a fresh executor thread if models are enabled).
    pub fn start(config: ServerConfig) -> Result<DbServer> {
        let models = if config.with_models {
            Some(Arc::new(ModelRuntime::new(Executor::new()?)))
        } else {
            None
        };
        Self::start_with(config, models)
    }

    /// Start a server sharing an existing model runtime (co-located
    /// deployments reuse one PJRT executor across components).
    pub fn start_with(config: ServerConfig, models: Option<Arc<ModelRuntime>>) -> Result<DbServer> {
        let listener = TcpListener::bind(config.addr)?;
        let addr = listener.local_addr()?;
        let store = Arc::new(Store::new());
        let stop = Arc::new(AtomicBool::new(false));
        let gate = Arc::new(CommandGate::new(config.engine));

        let accept_thread = {
            let store = Arc::clone(&store);
            let models = models.clone();
            let stop = Arc::clone(&stop);
            let engine = config.engine;
            std::thread::Builder::new()
                .name(format!("db-accept-{}", addr.port()))
                .spawn(move || {
                    listener.set_nonblocking(false).ok();
                    // Poll for shutdown with a short accept timeout trick:
                    // switch to nonblocking and sleep-loop.
                    listener.set_nonblocking(true).ok();
                    loop {
                        if stop.load(Ordering::Relaxed) {
                            break;
                        }
                        match listener.accept() {
                            Ok((sock, _peer)) => {
                                sock.set_nodelay(true).ok();
                                let store = Arc::clone(&store);
                                let models = models.clone();
                                let gate = Arc::clone(&gate);
                                let stop = Arc::clone(&stop);
                                std::thread::Builder::new()
                                    .name("db-conn".into())
                                    .spawn(move || {
                                        let _ = serve_conn(sock, &store, models.as_deref(), &gate, &stop, engine);
                                    })
                                    .ok();
                            }
                            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                                std::thread::sleep(std::time::Duration::from_millis(2));
                            }
                            Err(_) => break,
                        }
                    }
                })
                .map_err(Error::Io)?
        };

        Ok(DbServer {
            addr,
            store,
            models,
            stop,
            accept_thread: Some(accept_thread),
            config,
        })
    }

    /// Node-local (in-process) access to the store — the co-located fast
    /// path used by benches to inspect state without a socket round-trip.
    pub fn store(&self) -> &Arc<Store> {
        &self.store
    }

    pub fn models(&self) -> Option<&Arc<ModelRuntime>> {
        self.models.as_ref()
    }

    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }
}

impl Drop for DbServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn serve_conn(
    sock: TcpStream,
    store: &Store,
    models: Option<&ModelRuntime>,
    gate: &CommandGate,
    stop: &AtomicBool,
    engine: Engine,
) -> Result<()> {
    sock.set_read_timeout(Some(std::time::Duration::from_millis(200)))?;
    let mut writer = sock.try_clone()?;
    let mut reader = BufReader::with_capacity(256 * 1024, sock);
    let mut out_buf = Vec::with_capacity(64 * 1024);
    loop {
        if stop.load(Ordering::Relaxed) {
            return Ok(());
        }
        let body = match read_frame(&mut reader) {
            Ok(Some(b)) => b,
            Ok(None) => return Ok(()), // client closed
            Err(Error::Io(ref e))
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue; // idle poll; re-check stop flag
            }
            Err(e) => return Err(e),
        };
        let resp = match Request::decode(&body) {
            Err(e) => Response::Error(e.to_string()),
            Ok(req) => {
                let _g = gate.enter(); // redis: serialize command execution
                execute(req, store, models, engine)
            }
        };
        out_buf.clear();
        resp.encode(&mut out_buf);
        write_frame(&mut writer, &out_buf)?;
    }
}

/// Execute one decoded command (shared by the TCP path and the unit tests).
pub fn execute(
    req: Request,
    store: &Store,
    models: Option<&ModelRuntime>,
    engine: Engine,
) -> Response {
    match req {
        Request::PutTensor { key, tensor } => match store.put_tensor(&key, tensor) {
            Ok(()) => Response::Ok,
            Err(e) => Response::Error(e.to_string()),
        },
        Request::GetTensor { key } => match store.get_tensor(&key) {
            Ok(t) => Response::Tensor(t),
            Err(Error::KeyNotFound(_)) => Response::NotFound,
            Err(e) => Response::Error(e.to_string()),
        },
        Request::DelTensor { key } => {
            if store.del_tensor(&key) {
                Response::Ok
            } else {
                Response::NotFound
            }
        }
        Request::Exists { key } => Response::Bool(store.exists(&key)),
        Request::PutMeta { key, value } => {
            store.put_meta(&key, &value);
            Response::Ok
        }
        Request::GetMeta { key } => match store.get_meta(&key) {
            Ok(v) => Response::Meta(v),
            Err(Error::KeyNotFound(_)) => Response::NotFound,
            Err(e) => Response::Error(e.to_string()),
        },
        Request::ListKeys { prefix } => Response::Keys(store.list_keys(&prefix)),
        Request::PutModel { key, hlo_text } => match models {
            None => Response::Error("model runtime disabled on this server".into()),
            Some(m) => match m.put_model(&key, &hlo_text) {
                Ok(()) => Response::Ok,
                Err(e) => Response::Error(e.to_string()),
            },
        },
        Request::RunModel { key, in_keys, out_keys, device } => match models {
            None => Response::Error("model runtime disabled on this server".into()),
            Some(m) => match m.run_model(store, &key, &in_keys, &out_keys, device) {
                Ok(()) => Response::Ok,
                Err(Error::KeyNotFound(k)) => Response::Error(format!("input key not found: {k}")),
                Err(Error::ModelNotFound(k)) => Response::Error(format!("model not found: {k}")),
                Err(e) => Response::Error(e.to_string()),
            },
        },
        Request::Info => Response::Info {
            keys: store.n_keys(),
            bytes: store.n_bytes(),
            ops: store.n_ops(),
            models: models.map(|m| m.n_models()).unwrap_or(0),
            engine: engine.name().to_string(),
        },
        Request::FlushAll => {
            store.flush_all();
            Response::Ok
        }
    }
}

/// Resolve the default artifacts directory (repo-root relative, overridable
/// via SITU_ARTIFACTS).
pub fn artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("SITU_ARTIFACTS") {
        return PathBuf::from(p);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}
