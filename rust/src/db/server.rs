//! TCP database server: accepts SmartRedis-analogue clients and executes
//! commands against the node-local [`Store`] and [`crate::ai::ModelRuntime`].
//!
//! Threading model mirrors the engines being reproduced: a reader thread per
//! connection (redis io-threads / keydb server threads) with command
//! execution passing through the engine's [`CommandGate`].
//!
//! The request path is zero-copy for tensor payloads: `put_tensor` frames
//! are handed to the store wholesale (the stored tensor is a view into the
//! frame read off the socket) and tensor replies — bare or inside a
//! `Batch`/`MGetTensors` reply — are streamed through a
//! [`crate::proto::frame::FrameSink`] that writes each payload straight
//! from the store's shared buffer.
//!
//! Pipelined commands (`Batch`) execute in order with the command gate taken
//! per entry, and `PollKeys` waits in the connection thread with capped
//! exponential backoff, re-entering the gate per probe — so a blocked
//! consumer never stalls producers on other connections.
//!
//! Memory governance: each server applies its [`ServerConfig::retention`]
//! policy to the store at startup (sliding-window generation retirement
//! plus a byte cap with `busy` backpressure — see [`crate::db::store`]),
//! and clients can adjust it at runtime with `Request::Retention`.
//! Eviction and high-water counters are reported through `INFO`.

use std::io::BufReader;
use std::net::{SocketAddr, TcpListener};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::ai::ModelRuntime;
use crate::db::engine::{CommandGate, Engine};
use crate::db::spill::SpillConfig;
use crate::db::store::{RetentionConfig, Store};
use crate::error::{Error, Result};
use crate::proto::frame::{read_frame_into, FrameSink};
use crate::proto::{message, DbInfo, Request, Response};
use crate::runtime::Executor;
use crate::tensor::Bytes;
use crate::util::fault::{ConnStream, FaultPlan, FaultStream};

/// Default ceiling for the accept loop's adaptive idle backoff.  Tradeoff:
/// a larger value means fewer idle wakeups but up to this much extra
/// latency both for the first `accept` after an idle period and for
/// `shutdown()` joining the accept thread.  Configurable per server via
/// [`ServerConfig::accept_backoff_max`].
const ACCEPT_BACKOFF_MAX: Duration = Duration::from_millis(50);

/// Floor the accept backoff restarts from after any successful accept.
const ACCEPT_BACKOFF_MIN: Duration = Duration::from_millis(1);

/// Default read timeout on connection sockets.  Its only purpose is
/// bounding how long an idle connection thread takes to notice the stop
/// flag, so it is deliberately long: 1 s cuts idle wakeups 5x versus the
/// previous 200 ms, at the cost of up to 1 s of shutdown latency per
/// (detached) connection thread.  `shutdown()` does not join connection
/// threads, so this latency only delays socket teardown, never the caller.
/// Tests that start and stop many servers lower it via
/// [`ServerConfig::conn_read_timeout`].
const CONN_READ_TIMEOUT: Duration = Duration::from_secs(1);

/// Server configuration (one database instance; the clustered deployment
/// launches several of these and routes with [`crate::db::cluster`]).
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Bind address; port 0 picks a free port.
    pub addr: SocketAddr,
    pub engine: Engine,
    /// Logical cores assigned to the DB (the Fig-3 knob).  Recorded in INFO
    /// and used to parameterize the engine model; the real thread count is
    /// connection-driven.
    pub cores: usize,
    /// Enable the model runtime (needs a PJRT executor thread).  Data-only
    /// benches turn this off to skip PJRT startup.
    pub with_models: bool,
    /// Store retention / capacity policy applied at startup (see
    /// [`crate::db::store`]); adjustable at runtime via
    /// `Request::Retention`.  Defaults to unbounded (the seed behavior).
    pub retention: RetentionConfig,
    /// Optional spill-to-disk cold tier: retention victims are appended to
    /// a segment log under this config's directory and stay readable via
    /// `ColdGet`/`ColdList` (see [`crate::db::spill`]).  Server-local —
    /// not adjustable over the wire.  `None` (the default) discards
    /// evicted data, the pre-spill behavior.
    pub spill: Option<SpillConfig>,
    /// Read timeout on connection sockets — bounds how long an idle
    /// connection thread takes to notice shutdown (defaults documented on
    /// `CONN_READ_TIMEOUT`).
    pub conn_read_timeout: Duration,
    /// Ceiling for the accept loop's adaptive idle backoff — bounds both
    /// idle-accept latency and `shutdown()` joining the accept thread.
    pub accept_backoff_max: Duration,
    /// Optional seeded fault schedule: every accepted connection is served
    /// through a [`FaultStream`] drawing decisions from this plan (see
    /// [`crate::util::fault`]).  `None` (the default) serves plain sockets
    /// — the production path pays one `Option` branch per I/O op.
    pub fault: Option<Arc<FaultPlan>>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:0".parse().unwrap(),
            engine: Engine::Redis,
            cores: 8,
            with_models: true,
            retention: RetentionConfig::UNBOUNDED,
            spill: None,
            conn_read_timeout: CONN_READ_TIMEOUT,
            accept_backoff_max: ACCEPT_BACKOFF_MAX,
            fault: None,
        }
    }
}

/// A running database server.  Dropping the handle shuts it down.
pub struct DbServer {
    pub addr: SocketAddr,
    store: Arc<Store>,
    models: Option<Arc<ModelRuntime>>,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
    pub config: ServerConfig,
    /// Set by [`DbServer::simulate_crash`]: teardown skips the clean
    /// shutdown spill barrier, like a real `kill -9` would.
    crashed: bool,
}

impl DbServer {
    /// Start a server (with a fresh executor thread if models are enabled).
    pub fn start(config: ServerConfig) -> Result<DbServer> {
        let models = if config.with_models {
            Some(Arc::new(ModelRuntime::new(Executor::new()?)))
        } else {
            None
        };
        Self::start_with(config, models)
    }

    /// Start a server sharing an existing model runtime (co-located
    /// deployments reuse one PJRT executor across components).
    pub fn start_with(config: ServerConfig, models: Option<Arc<ModelRuntime>>) -> Result<DbServer> {
        let listener = TcpListener::bind(config.addr)?;
        let addr = listener.local_addr()?;
        let store = Arc::new(Store::new());
        // Spill first, so the very first window retirement already lands
        // in the cold tier (opening also crash-recovers an existing log).
        if let Some(spill) = &config.spill {
            store.set_spill(Some(spill.clone()))?;
        }
        if !config.retention.is_unbounded() {
            store.set_retention(config.retention);
        }
        let stop = Arc::new(AtomicBool::new(false));
        let gate = Arc::new(CommandGate::new(config.engine));

        let accept_thread = {
            let store = Arc::clone(&store);
            let models = models.clone();
            let stop = Arc::clone(&stop);
            let engine = config.engine;
            let backoff_max = config.accept_backoff_max;
            let read_timeout = config.conn_read_timeout;
            let fault = config.fault.clone();
            std::thread::Builder::new()
                .name(format!("db-accept-{}", addr.port()))
                .spawn(move || {
                    // Poll for shutdown with a nonblocking accept loop.  The
                    // sleep between polls backs off adaptively: a busy server
                    // accepts with ~1 ms latency, an idle one decays to
                    // `accept_backoff_max` between wakeups (kernel backlog
                    // still completes handshakes meanwhile, so connects are
                    // never dropped, just served up to one backoff later).
                    listener.set_nonblocking(true).ok();
                    let mut backoff = ACCEPT_BACKOFF_MIN;
                    loop {
                        if stop.load(Ordering::Relaxed) {
                            break;
                        }
                        match listener.accept() {
                            Ok((sock, _peer)) => {
                                backoff = ACCEPT_BACKOFF_MIN;
                                sock.set_nodelay(true).ok();
                                let store = Arc::clone(&store);
                                let models = models.clone();
                                let gate = Arc::clone(&gate);
                                let stop = Arc::clone(&stop);
                                // Each connection draws its own decision
                                // stream from the plan; `None` serves the
                                // plain socket (no shim in the type at all).
                                let conn_faults = fault.as_ref().map(|p| p.connection());
                                std::thread::Builder::new()
                                    .name("db-conn".into())
                                    .spawn(move || {
                                        let _ = match conn_faults {
                                            Some(f) => serve_conn(
                                                FaultStream::over(sock, Some(f)),
                                                &store,
                                                models.as_deref(),
                                                &gate,
                                                &stop,
                                                engine,
                                                read_timeout,
                                            ),
                                            None => serve_conn(
                                                sock,
                                                &store,
                                                models.as_deref(),
                                                &gate,
                                                &stop,
                                                engine,
                                                read_timeout,
                                            ),
                                        };
                                    })
                                    .ok();
                            }
                            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                                std::thread::sleep(backoff);
                                backoff = (backoff * 2).min(backoff_max);
                            }
                            Err(_) => break,
                        }
                    }
                })
                .map_err(Error::Io)?
        };

        Ok(DbServer {
            addr,
            store,
            models,
            stop,
            accept_thread: Some(accept_thread),
            config,
            crashed: false,
        })
    }

    /// Node-local (in-process) access to the store — the co-located fast
    /// path used by benches to inspect state without a socket round-trip.
    pub fn store(&self) -> &Arc<Store> {
        &self.store
    }

    pub fn models(&self) -> Option<&Arc<ModelRuntime>> {
        self.models.as_ref()
    }

    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
        // Drain the spill writer before teardown: every record the
        // retention pipeline enqueued is on disk when shutdown returns, so
        // a clean exit never loses queued cold-tier data (no-op without a
        // spill config).  A *crashed* server gets no such courtesy — only
        // what the spill writer already flushed survives, which is exactly
        // what the crash-recovery tests assert against.
        if !self.crashed {
            self.store.spill_sync();
        }
    }

    /// Kill the server the way `kill -9` would, as far as in-process
    /// simulation allows: stop accepting, release the listener port (a
    /// restarted server can rebind it), and *skip* the clean-shutdown
    /// spill barrier so queued cold-tier records are dropped on the floor.
    /// In-flight connection threads wind down at their next idle poll; to
    /// sever them mid-operation deterministically, pair this with
    /// [`FaultPlan::kill`] on the server's fault plan.
    pub fn simulate_crash(&mut self) {
        self.crashed = true;
        if let Some(p) = &self.config.fault {
            p.kill();
        }
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }
}

impl Drop for DbServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Generic over [`ConnStream`] so the same loop serves plain sockets and
/// fault-injected ones — the chaos battery exercises exactly the code the
/// production path runs.
fn serve_conn<S: ConnStream>(
    sock: S,
    store: &Store,
    models: Option<&ModelRuntime>,
    gate: &CommandGate,
    stop: &AtomicBool,
    engine: Engine,
    read_timeout: Duration,
) -> Result<()> {
    sock.set_stream_read_timeout(Some(read_timeout))?;
    let mut writer = sock.try_clone_stream()?;
    let mut reader = BufReader::with_capacity(256 * 1024, sock);
    // Scratch frame buffer, reused across requests the server fully
    // consumes; payload-carrying frames are handed over to the store
    // instead (see below), which leaves a fresh buffer behind.
    let mut scratch: Vec<u8> = Vec::new();
    let mut out_buf = Vec::with_capacity(64 * 1024);
    loop {
        if stop.load(Ordering::Relaxed) {
            return Ok(());
        }
        match read_frame_into(&mut reader, &mut scratch) {
            Ok(Some(_)) => {}
            Ok(None) => return Ok(()), // client closed
            Err(Error::Io(ref e))
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue; // idle poll; re-check stop flag
            }
            Err(e) => return Err(e),
        }
        // One frame == one client round trip (a batch is still one frame).
        store.counters.frames.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let mut handed_over: Option<Bytes> = None;
        let decoded = if Request::frame_holds_payload(&scratch) {
            // Take ownership of the frame: the decoded tensor's payload is
            // a view into it and the store keeps that single allocation
            // alive by refcount — zero copies between socket and store.
            // (On put-heavy connections scratch is consumed every request,
            // so the per-frame allocation moves to the store rather than
            // being amortized — it is the tensor's own storage either way.)
            // Shrink first so a capacity inherited from an earlier larger
            // frame isn't pinned for the stored tensor's lifetime; this is
            // a no-op when scratch was sized for this frame.
            //
            // Tensors put inside one Batch frame all alias this single
            // allocation, so it stays resident until the *last* of them is
            // overwritten or deleted (and n_bytes accounts per-tensor, not
            // per-allocation).  The intended publish pattern — every rank
            // republishing under stable keys each snapshot — retires whole
            // batches together, so the coupling is benign there; callers
            // batching puts with very different lifetimes should use
            // separate put_tensor calls instead.
            scratch.shrink_to_fit();
            let body = Bytes::from_vec(std::mem::take(&mut scratch));
            let req = Request::decode_shared(&body);
            handed_over = Some(body);
            req
        } else {
            Request::decode(&scratch)
        };
        let resp = match decoded {
            Err(e) => Response::Error(e.to_string()),
            Ok(req) => execute_conn(req, store, models, gate, stop, engine),
        };
        if let Some(body) = handed_over.take() {
            // The hand-over was speculative (first opcode only).  If
            // nothing retained a view — a read-only batch, or a failed
            // decode — the refcount is back to 1 and the allocation comes
            // home as next round's scratch buffer.
            if let Ok(v) = body.try_unwrap_vec() {
                scratch = v;
            }
        }
        write_response(&mut writer, &mut out_buf, &resp)?;
    }
}

/// Initial probe interval floor and backoff ceiling for server-side
/// `PollKeys` waits, applied to whatever the client requested.
const POLL_INTERVAL_FLOOR: std::time::Duration = std::time::Duration::from_micros(50);
const POLL_INTERVAL_CEIL: std::time::Duration = std::time::Duration::from_millis(250);

/// Execute one command on behalf of a connection thread.  This is the layer
/// that may *block*: `PollKeys` waits for keys with capped exponential
/// backoff, re-entering the [`CommandGate`] per probe so producers on other
/// connections keep making progress; a `Batch` runs its entries in order,
/// taking the gate per entry (a batch is a pipeline, not a transaction).
fn execute_conn(
    req: Request,
    store: &Store,
    models: Option<&ModelRuntime>,
    gate: &CommandGate,
    stop: &AtomicBool,
    engine: Engine,
) -> Response {
    match req {
        Request::PollKeys { keys, timeout_ms, initial_us, cap_us } => {
            // Clamp the client-controlled budget (24 h ceiling) so a
            // hostile timeout can't overflow `Instant + Duration`.
            let timeout = std::time::Duration::from_millis(timeout_ms.min(86_400_000));
            let deadline = std::time::Instant::now() + timeout;
            let mut interval = std::time::Duration::from_micros(initial_us)
                .clamp(POLL_INTERVAL_FLOOR, POLL_INTERVAL_CEIL);
            let cap = std::time::Duration::from_micros(cap_us)
                .clamp(interval, POLL_INTERVAL_CEIL);
            loop {
                let present = {
                    let _g = gate.enter();
                    store.exists_all(&keys)
                };
                if present {
                    return Response::Bool(true);
                }
                let now = std::time::Instant::now();
                if now >= deadline || stop.load(Ordering::Relaxed) {
                    return Response::Bool(false);
                }
                std::thread::sleep(interval.min(deadline - now));
                interval = (interval * 2).min(cap);
            }
        }
        Request::Batch(entries) => Response::Batch(
            entries
                .into_iter()
                .map(|e| execute_conn(e, store, models, gate, stop, engine))
                .collect(),
        ),
        other => {
            let _g = gate.enter(); // redis: serialize command execution
            execute(other, store, models, engine)
        }
    }
}

/// Write one response frame.  Tensor payloads — bare or inside a batch —
/// are streamed from the store's shared buffers through a [`FrameSink`]:
/// headers coalesce in `scratch`, payloads go to the socket uncopied.
fn write_response<W: std::io::Write>(
    w: &mut W,
    scratch: &mut Vec<u8>,
    resp: &Response,
) -> Result<()> {
    let body = resp.body_wire_size();
    if body > crate::proto::MAX_FRAME {
        // A batch of individually legal tensors can exceed the frame cap
        // in aggregate; answer with an error the client can handle rather
        // than killing the connection on the unsendable reply.
        let err = Response::Error(format!(
            "reply of {body} bytes exceeds the {} byte frame limit; split the batch",
            crate::proto::MAX_FRAME
        ));
        let mut sink = FrameSink::begin(w, scratch, err.body_wire_size())?;
        sink.encode_with(|buf| err.encode(buf))?;
        return sink.finish();
    }
    let mut sink = FrameSink::begin(w, scratch, body)?;
    sink_response(&mut sink, resp)?;
    sink.finish()
}

fn sink_response<W: std::io::Write>(sink: &mut FrameSink<'_, W>, resp: &Response) -> Result<()> {
    match resp {
        Response::Tensor(t) => {
            sink.encode_with(|buf| message::encode_tensor_response_header_into(buf, t))?;
            sink.write(&t.data)
        }
        Response::Batch(entries) => {
            sink.encode_with(|buf| {
                message::encode_batch_response_header_into(buf, entries.len())
            })?;
            for e in entries {
                sink_response(sink, e)?;
            }
            Ok(())
        }
        other => sink.encode_with(|buf| other.encode(buf)),
    }
}

/// Execute one decoded command (shared by the TCP path and the unit tests).
///
/// This layer never blocks: `PollKeys` is a single all-exist probe here (the
/// waiting loop lives in the connection layer, where sleeping doesn't hold
/// the command gate).
pub fn execute(
    req: Request,
    store: &Store,
    models: Option<&ModelRuntime>,
    engine: Engine,
) -> Response {
    match req {
        Request::Batch(entries) => Response::Batch(
            entries
                .into_iter()
                .map(|e| execute(e, store, models, engine))
                .collect(),
        ),
        Request::MGetTensors { keys } => Response::Batch(
            keys.iter()
                .map(|k| match store.get_tensor(k) {
                    Ok(t) => Response::Tensor(t),
                    Err(Error::KeyNotFound(_)) => Response::NotFound,
                    Err(e) => Response::Error(e.to_string()),
                })
                .collect(),
        ),
        Request::PollKeys { keys, .. } => Response::Bool(store.exists_all(&keys)),
        Request::PutTensor { key, tensor } => match store.put_tensor(&key, tensor) {
            Ok(()) => Response::Ok,
            Err(e) => Response::Error(e.to_string()),
        },
        Request::GetTensor { key } => match store.get_tensor(&key) {
            Ok(t) => Response::Tensor(t),
            Err(Error::KeyNotFound(_)) => Response::NotFound,
            Err(e) => Response::Error(e.to_string()),
        },
        Request::DelTensor { key } => {
            if store.del_tensor(&key) {
                Response::Ok
            } else {
                Response::NotFound
            }
        }
        Request::Exists { key } => Response::Bool(store.exists(&key)),
        Request::PutMeta { key, value } => {
            store.put_meta(&key, &value);
            Response::Ok
        }
        Request::GetMeta { key } => match store.get_meta(&key) {
            Ok(v) => Response::Meta(v),
            Err(Error::KeyNotFound(_)) => Response::NotFound,
            Err(e) => Response::Error(e.to_string()),
        },
        Request::ListKeys { prefix } => Response::Keys(store.list_keys(&prefix)),
        Request::PutModel { key, hlo_text } => match models {
            None => Response::Error("model runtime disabled on this server".into()),
            Some(m) => match m.put_model(&key, &hlo_text) {
                Ok(version) => Response::Version(version),
                Err(e) => Response::Error(e.to_string()),
            },
        },
        Request::RunModel { key, version, in_keys, out_keys, device } => match models {
            None => Response::Error("model runtime disabled on this server".into()),
            Some(m) => match m.run_model(store, &key, version, &in_keys, &out_keys, device) {
                Ok(()) => Response::Ok,
                Err(Error::KeyNotFound(k)) => Response::Error(format!("input key not found: {k}")),
                Err(Error::ModelNotFound(k)) => Response::Error(format!("model not found: {k}")),
                Err(e) => Response::Error(e.to_string()),
            },
        },
        Request::ListModels => match models {
            None => Response::Models(Vec::new()),
            Some(m) => Response::Models(m.model_entries()),
        },
        Request::ModelStats => match models {
            None => Response::ModelStats(Vec::new()),
            Some(m) => Response::ModelStats(m.device_stat_rows()),
        },
        Request::DelKeys { keys } => Response::Batch(
            keys.iter()
                .map(|k| {
                    if store.del_tensor(k) {
                        Response::Ok
                    } else {
                        Response::NotFound
                    }
                })
                .collect(),
        ),
        Request::Retention { window, max_bytes, ttl_ms } => {
            store.set_retention(RetentionConfig { window, max_bytes, ttl_ms });
            Response::Ok
        }
        Request::ColdList { prefix } => Response::Keys(store.cold_list(&prefix)),
        Request::ColdGet { key } => match store.cold_get(&key) {
            Ok(t) => Response::Tensor(t),
            Err(Error::KeyNotFound(_)) => Response::NotFound,
            Err(e) => Response::Error(e.to_string()),
        },
        Request::Info => {
            // Opportunistic TTL sweep: stalled producers are reclaimed even
            // when no other field is writing into their index shard (no-op
            // unless a TTL policy is active).
            store.expire_ttl();
            // Spill barrier: every eviction that happened-before this INFO
            // is durable and counted, so the reply's spill counters are
            // exact rather than racing the writer thread (no-op without a
            // cold tier).
            store.spill_sync();
            let retention = store.retention();
            // The codec rejects field lists over MAX_BATCH; keep the reply
            // decodable for pathological field counts by reporting the
            // most-pressured fields (by resident bytes) and dropping the
            // tail, name-sorted again for stable output.
            let mut fields = store.field_pressure();
            if fields.len() > crate::proto::MAX_BATCH {
                fields.sort_by(|a, b| b.resident_bytes.cmp(&a.resident_bytes));
                fields.truncate(crate::proto::MAX_BATCH);
                fields.sort_by(|a, b| a.field.cmp(&b.field));
            }
            let (spilled_keys, spilled_bytes, spill_segments, cold_hits, spill_lost_keys) =
                store.spill_counters();
            Response::Info(DbInfo {
                keys: store.n_keys(),
                bytes: store.n_bytes(),
                ops: store.n_ops(),
                models: models.map(|m| m.n_models()).unwrap_or(0),
                high_water_bytes: store.high_water_bytes(),
                evicted_keys: store.counters.evicted_keys.load(Ordering::Relaxed),
                evicted_bytes: store.counters.evicted_bytes.load(Ordering::Relaxed),
                busy_rejections: store.counters.busy_rejections.load(Ordering::Relaxed),
                ttl_expired_keys: store.counters.ttl_expired_keys.load(Ordering::Relaxed),
                retention_window: retention.window,
                retention_max_bytes: retention.max_bytes,
                retention_ttl_ms: retention.ttl_ms,
                spilled_keys,
                spilled_bytes,
                spill_segments,
                cold_hits,
                spill_lost_keys,
                // Replication/failover are client-side phenomena: a single
                // server cannot observe them.  ClusterClient::info fills
                // these from its own FailoverStats.
                replicated_writes: 0,
                read_failovers: 0,
                shard_reconnects: 0,
                degraded_ops: 0,
                model_swaps: models.map(|m| m.swaps()).unwrap_or(0),
                batches: models.map(|m| m.batch_counters().0).unwrap_or(0),
                batched_requests: models.map(|m| m.batch_counters().1).unwrap_or(0),
                engine: engine.name().to_string(),
                fields,
            })
        }
        Request::FlushAll => {
            store.flush_all();
            Response::Ok
        }
    }
}

/// Resolve the default artifacts directory (repo-root relative, overridable
/// via SITU_ARTIFACTS).
pub fn artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("SITU_ARTIFACTS") {
        return PathBuf::from(p);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}
