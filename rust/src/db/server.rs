//! TCP database server: accepts SmartRedis-analogue clients and executes
//! commands against the node-local [`Store`] and [`crate::ai::ModelRuntime`].
//!
//! # Threading model
//!
//! The core is readiness-driven, not thread-per-connection:
//!
//! * **A fixed set of reactor threads** (`ServerConfig::reactors`, default
//!   one; `SITU_REACTORS` caps at `cores`) each owns a disjoint set of
//!   connection sockets and an epoll-style [`Poller`] (see
//!   [`crate::db::event`]).  A reactor accepts, reads frames, writes
//!   replies, and sleeps until the OS reports readiness — an idle server
//!   (and every idle connection) costs zero wakeups.  With several
//!   reactors, each owns its own `SO_REUSEPORT` listener and the kernel
//!   balances accepts across them; where the option is unavailable,
//!   reactor 0 owns the only listener and deals accepted sockets to its
//!   peers round-robin through their doorbells.  A connection lives on one
//!   reactor for its lifetime, so per-connection state is never shared.
//! * **A small executor pool** (`engine.exec_threads(cores)`, clamped to
//!   16) runs decoded commands through the engine's [`CommandGate`],
//!   pulling from one queue fed by every reactor.  The Redis engine keeps
//!   its single-executor semantics; KeyDb gets one executor per configured
//!   core.
//! * **One poll-hub timer thread** owns parked `PollKeys` waits and the
//!   background TTL sweeper.  A poll that misses its first probe parks as
//!   a timer-driven waiter instead of sleeping an OS thread.  Waiters are
//!   indexed by key: the store's write observer nudges the hub the moment
//!   a watched key lands, so a parked poll resolves at write latency; the
//!   capped exponential backoff probe clock remains as the fallback that
//!   covers timeouts and TTL expiry.
//!
//! # Multiplexing
//!
//! Frames may carry a request tag (see [`crate::proto::frame`]): one
//! socket carries many in-flight tagged requests whose replies return in
//! completion order, each echoing its tag — no head-of-line blocking.
//! Untagged (tag 0) frames are the legacy wire format and keep legacy
//! semantics: at most one executes at a time per connection and replies
//! stay in request order, so old clients — including ones that pipeline
//! several untagged frames back-to-back — round-trip unchanged.
//!
//! The request path is zero-copy for tensor payloads in both directions:
//! `put_tensor` bodies are read into a right-sized buffer handed to the
//! store wholesale (the stored tensor is a view into the frame read off
//! the socket), and large tensor replies are queued as refcounted views
//! of the store's own buffers rather than copied into the outbox.
//!
//! Pipelined commands (`Batch`) execute in order with the command gate
//! taken per entry.  `PollKeys` entries inside a batch share the batch's
//! start time as their deadline base, so a batch waits at most the *max*
//! of its poll budgets, never the sum.
//!
//! Memory governance: each server applies its [`ServerConfig::retention`]
//! policy to the store at startup (sliding-window generation retirement
//! plus a byte cap with `busy` backpressure — see [`crate::db::store`]),
//! and clients can adjust it at runtime with `Request::Retention`.  A TTL
//! policy arms the hub's background sweeper (period `ttl/4`, clamped to
//! 10 ms..1 s) so stalled producers are reclaimed on time rather than
//! only on generation boundaries or `INFO`.  Eviction and high-water
//! counters are reported through `INFO`.

use std::collections::{HashMap, VecDeque};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::os::unix::io::{AsRawFd, RawFd};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::ai::ModelRuntime;
use crate::db::engine::{CommandGate, Engine};
use crate::db::event::{
    bind_reuseport, reuseport_available, waker, Event, Poller, WakeReceiver, Waker,
};
use crate::db::cluster::SlotEpoch;
use crate::db::spill::SpillConfig;
use crate::db::store::{Ownership, RetentionConfig, Store};
use crate::error::{Error, Result};
use crate::proto::frame::FRAME_TAG_FLAG;
use crate::proto::{message, DbInfo, Request, Response, MAX_FRAME};
use crate::runtime::Executor;
use crate::tensor::Bytes;
use crate::util::fault::{FaultPlan, FaultStream};

/// Default mid-frame stall deadline on connection sockets.  With the
/// event loop, an *idle* connection costs nothing regardless of this
/// value; it only bounds how long a connection may sit on a partially
/// received frame (a stalled or byte-dribbling peer) before the server
/// reclaims it.  Tests that exercise teardown latency lower it via
/// [`ServerConfig::conn_read_timeout`].
const CONN_READ_TIMEOUT: Duration = Duration::from_secs(1);

/// Staging-buffer refill size for connection reads.
const READ_CHUNK: usize = 64 * 1024;

/// Per-connection cap on dispatched-but-unanswered requests.  At the cap
/// the reactor stops reading that socket (drops read interest) until
/// completions drain — backpressure instead of unbounded queueing.
const MAX_IN_FLIGHT: usize = 1024;

/// Tensor payloads at or above this size are queued for write as
/// refcounted views of the store's buffer instead of being copied into
/// the coalesced outbox segment.
const SEG_SHARED_MIN: usize = 32 * 1024;

const TOKEN_LISTENER: u64 = 0;
const TOKEN_WAKER: u64 = 1;
const FIRST_CONN_TOKEN: u64 = 2;

/// Initial probe interval floor and backoff ceiling for server-side
/// `PollKeys` waits, applied to whatever the client requested.
const POLL_INTERVAL_FLOOR: Duration = Duration::from_micros(50);
const POLL_INTERVAL_CEIL: Duration = Duration::from_millis(250);

/// Server configuration (one database instance; the clustered deployment
/// launches several of these and routes with [`crate::db::cluster`]).
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Bind address; port 0 picks a free port.
    pub addr: SocketAddr,
    pub engine: Engine,
    /// Logical cores assigned to the DB (the Fig-3 knob).  Recorded in INFO
    /// and used to parameterize the engine model; it also sizes the KeyDb
    /// executor pool.
    pub cores: usize,
    /// Enable the model runtime (needs a PJRT executor thread).  Data-only
    /// benches turn this off to skip PJRT startup.
    pub with_models: bool,
    /// Store retention / capacity policy applied at startup (see
    /// [`crate::db::store`]); adjustable at runtime via
    /// `Request::Retention`.  Defaults to unbounded (the seed behavior).
    pub retention: RetentionConfig,
    /// Optional spill-to-disk cold tier: retention victims are appended to
    /// a segment log under this config's directory and stay readable via
    /// `ColdGet`/`ColdList` (see [`crate::db::spill`]).  Server-local —
    /// not adjustable over the wire.  `None` (the default) discards
    /// evicted data, the pre-spill behavior.
    pub spill: Option<SpillConfig>,
    /// Mid-frame stall deadline: how long a connection may hold a
    /// partially received frame without progress before the server drops
    /// it.  Idle connections (no partial frame) are exempt and cost zero
    /// wakeups (defaults documented on `CONN_READ_TIMEOUT`).
    pub conn_read_timeout: Duration,
    /// Reactor (I/O event loop) threads.  `0` — the default — defers to
    /// the `SITU_REACTORS` environment variable capped at [`Self::cores`],
    /// falling back to a single reactor when the variable is unset.  With
    /// more than one reactor each thread owns its own `SO_REUSEPORT`
    /// listener (kernel-balanced accepts); where the option is
    /// unavailable, reactor 0 owns the only listener and deals accepted
    /// sockets to its peers round-robin through their doorbells.
    pub reactors: usize,
    /// Optional seeded fault schedule: every accepted connection is served
    /// through a [`FaultStream`] drawing decisions from this plan (see
    /// [`crate::util::fault`]).  `None` (the default) serves plain sockets
    /// — the production path pays one `Option` branch per I/O op.
    pub fault: Option<Arc<FaultPlan>>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:0".parse().unwrap(),
            engine: Engine::Redis,
            cores: 8,
            with_models: true,
            retention: RetentionConfig::UNBOUNDED,
            spill: None,
            conn_read_timeout: CONN_READ_TIMEOUT,
            reactors: 0,
            fault: None,
        }
    }
}

/// Resolve the configured reactor count: an explicit `config.reactors`
/// wins; `0` defers to `min(cores, SITU_REACTORS)` when the environment
/// variable is set, else a single reactor (the pre-sharding behavior).
fn resolve_reactors(config: &ServerConfig) -> usize {
    let n = if config.reactors > 0 {
        config.reactors
    } else {
        match std::env::var("SITU_REACTORS").ok().and_then(|v| v.parse::<usize>().ok()) {
            Some(n) if n > 0 => n.min(config.cores.max(1)),
            _ => 1,
        }
    };
    n.clamp(1, 64)
}

/// Identifies one in-flight request: owning reactor + connection token +
/// request tag.
#[derive(Debug, Clone, Copy)]
struct Ticket {
    reactor: u32,
    token: u64,
    tag: u32,
}

/// A finished request on its way back to its reactor.
struct Completion {
    ticket: Ticket,
    resp: Response,
}

/// One reactor's mailboxes, paired with its doorbell: finished requests,
/// and (in the acceptor-handoff fallback) freshly accepted sockets
/// awaiting adoption.
struct ReactorShared {
    completions: Mutex<Vec<Completion>>,
    /// Sockets handed over by reactor 0 when `SO_REUSEPORT` is
    /// unavailable; the owning reactor adopts them on its next wakeup.
    inbox: Mutex<Vec<TcpStream>>,
    waker: Waker,
}

/// State shared between the reactors, executors and the poll hub.
struct Shared {
    reactors: Vec<ReactorShared>,
    stop: AtomicBool,
}

impl Shared {
    fn complete(&self, ticket: Ticket, resp: Response) {
        let r = &self.reactors[ticket.reactor as usize];
        r.completions.lock().unwrap().push(Completion { ticket, resp });
        r.waker.wake();
    }

    fn wake_all(&self) {
        for r in &self.reactors {
            r.waker.wake();
        }
    }
}

/// Work dispatched from the reactor (or resumed from the poll hub) to the
/// executor pool.
enum Job {
    Request { ticket: Ticket, req: Request },
    /// A batch whose in-progress `PollKeys` entry just resolved; push the
    /// poll's result and keep executing the remaining entries.
    Resume { ticket: Ticket, cont: BatchCont, poll_result: bool },
}

/// Progress through a `Request::Batch` that parked on a poll entry.
struct BatchCont {
    rest: std::vec::IntoIter<Request>,
    done: Vec<Response>,
    /// Batch start: every poll entry's deadline is measured from here, so
    /// a batch waits at most the max of its entries' budgets, not the sum.
    start: Instant,
}

/// A `PollKeys` wait whose first probe missed: parked with the hub as a
/// timer-driven waiter instead of occupying a thread.
struct Park {
    keys: Vec<String>,
    deadline: Instant,
    interval: Duration,
    cap: Duration,
    batch: Option<BatchCont>,
}

enum Exec {
    Done(Response),
    Park(Park),
}

/// Closable MPMC job queue feeding the executor pool.
struct JobQueue {
    q: Mutex<(VecDeque<Job>, bool)>,
    cv: Condvar,
}

impl JobQueue {
    fn new() -> JobQueue {
        JobQueue { q: Mutex::new((VecDeque::new(), false)), cv: Condvar::new() }
    }

    fn push(&self, job: Job) {
        let mut g = self.q.lock().unwrap();
        if g.1 {
            return; // closed during teardown: drop late work
        }
        g.0.push_back(job);
        self.cv.notify_one();
    }

    fn pop(&self) -> Option<Job> {
        let mut g = self.q.lock().unwrap();
        loop {
            if let Some(j) = g.0.pop_front() {
                return Some(j);
            }
            if g.1 {
                return None;
            }
            g = self.cv.wait(g).unwrap();
        }
    }

    fn close(&self) {
        let mut g = self.q.lock().unwrap();
        g.1 = true;
        self.cv.notify_all();
    }
}

/// Everything an executor (or the hub) needs to run commands.
#[derive(Clone)]
struct ExecCtx {
    store: Arc<Store>,
    models: Option<Arc<ModelRuntime>>,
    gate: Arc<CommandGate>,
    engine: Engine,
    shared: Arc<Shared>,
    jobs: Arc<JobQueue>,
    hub: Arc<PollHub>,
}

fn run_executor(ctx: ExecCtx) {
    while let Some(job) = ctx.jobs.pop() {
        match job {
            Job::Request { ticket, req } => match execute_mux(req, &ctx) {
                Exec::Done(resp) => ctx.shared.complete(ticket, resp),
                Exec::Park(p) => ctx.hub.register(ticket, p),
            },
            Job::Resume { ticket, mut cont, poll_result } => {
                cont.done.push(Response::Bool(poll_result));
                match run_batch(cont, &ctx) {
                    Exec::Done(resp) => ctx.shared.complete(ticket, resp),
                    Exec::Park(p) => ctx.hub.register(ticket, p),
                }
            }
        }
    }
}

/// Execute one command, parking instead of blocking: a `PollKeys` whose
/// keys aren't there yet returns a [`Park`] for the hub rather than
/// sleeping the executor.
fn execute_mux(req: Request, ctx: &ExecCtx) -> Exec {
    match req {
        Request::PollKeys { keys, timeout_ms, initial_us, cap_us } => {
            match poll_once(keys, timeout_ms, initial_us, cap_us, Instant::now(), ctx) {
                Ok(resp) => Exec::Done(resp),
                Err(park) => Exec::Park(park),
            }
        }
        Request::Batch(entries) => {
            let n = entries.len();
            run_batch(
                BatchCont {
                    rest: entries.into_iter(),
                    done: Vec::with_capacity(n),
                    start: Instant::now(),
                },
                ctx,
            )
        }
        other => Exec::Done(exec_one(other, ctx)),
    }
}

/// Run one non-poll, non-batch command under the gate.  A `Retention`
/// command re-arms the hub's TTL sweeper afterwards so policy changes
/// take effect on the timer immediately.
fn exec_one(req: Request, ctx: &ExecCtx) -> Response {
    let ttl_kick = matches!(req, Request::Retention { .. });
    let resp = {
        let _g = ctx.gate.enter();
        execute(req, &ctx.store, ctx.models.as_deref(), ctx.engine)
    };
    if ttl_kick {
        ctx.hub.set_ttl(ctx.store.retention().ttl());
    }
    resp
}

/// Probe a `PollKeys` once under the gate; park it if the keys aren't all
/// present and the budget hasn't run out.  `start` anchors the deadline —
/// `Instant::now()` for a bare poll, the batch start for polls inside one.
fn poll_once(
    keys: Vec<String>,
    timeout_ms: u64,
    initial_us: u64,
    cap_us: u64,
    start: Instant,
    ctx: &ExecCtx,
) -> std::result::Result<Response, Park> {
    let present = {
        let _g = ctx.gate.enter();
        ctx.store.exists_all(&keys)
    };
    if present {
        return Ok(Response::Bool(true));
    }
    // Clamp the client-controlled budget (24 h ceiling) so a hostile
    // timeout can't overflow `Instant + Duration`.
    let deadline = start + Duration::from_millis(timeout_ms.min(86_400_000));
    if Instant::now() >= deadline || ctx.shared.stop.load(Ordering::Relaxed) {
        return Ok(Response::Bool(false));
    }
    let interval = Duration::from_micros(initial_us).clamp(POLL_INTERVAL_FLOOR, POLL_INTERVAL_CEIL);
    let cap = Duration::from_micros(cap_us).clamp(interval, POLL_INTERVAL_CEIL);
    Err(Park { keys, deadline, interval, cap, batch: None })
}

/// Run a batch's remaining entries in order, taking the gate per entry (a
/// batch is a pipeline, not a transaction).  Parks — with the continuation
/// attached — when a poll entry has to wait.
fn run_batch(mut cont: BatchCont, ctx: &ExecCtx) -> Exec {
    loop {
        let Some(entry) = cont.rest.next() else {
            return Exec::Done(Response::Batch(cont.done));
        };
        match entry {
            Request::PollKeys { keys, timeout_ms, initial_us, cap_us } => {
                match poll_once(keys, timeout_ms, initial_us, cap_us, cont.start, ctx) {
                    Ok(resp) => cont.done.push(resp),
                    Err(mut park) => {
                        park.batch = Some(cont);
                        return Exec::Park(park);
                    }
                }
            }
            // The codec rejects nested batches on decode; defense in depth
            // against a hand-rolled client.
            Request::Batch(_) => cont.done.push(Response::Error("nested batch request".into())),
            other => cont.done.push(exec_one(other, ctx)),
        }
    }
}

/// A parked `PollKeys` owned by the hub.
struct Waiter {
    ticket: Ticket,
    keys: Vec<String>,
    deadline: Instant,
    interval: Duration,
    cap: Duration,
    next_probe: Instant,
    /// The next probe is a *verification* (fresh registration closing the
    /// miss→put race, or a write wakeup), not a backoff expiry: a miss
    /// re-arms the current interval instead of doubling it, so wakeups
    /// never inflate the backoff clock.
    skip_backoff: bool,
    batch: Option<BatchCont>,
}

struct HubState {
    /// Waiter slab, keyed by a hub-local id.
    waiters: HashMap<u64, Waiter>,
    /// key → ids of waiters watching it (the write-wakeup index).  A
    /// waiter appears under every one of its keys; entries are scrubbed
    /// when the waiter is removed.
    by_key: HashMap<String, Vec<u64>>,
    next_id: u64,
    ttl_period: Option<Duration>,
    next_sweep: Option<Instant>,
    stopped: bool,
}

/// Timer hub: owns parked poll waiters and the background TTL sweep.  One
/// thread sleeps to the earliest timer; registrations, policy changes and
/// write notifications nudge it through the condvar.
struct PollHub {
    state: Mutex<HubState>,
    cv: Condvar,
    /// Parked-waiter count readable without the lock: `notify_key` on the
    /// put hot path bails on one atomic load when nobody is waiting.
    parked: AtomicUsize,
    /// Write notifications that advanced at least one parked waiter —
    /// i.e. resolutions delivered strictly before the waiter's next
    /// backoff probe.  The structural gate for the write-wakeup path.
    write_wakeups: AtomicU64,
}

impl PollHub {
    fn new() -> PollHub {
        PollHub {
            state: Mutex::new(HubState {
                waiters: HashMap::new(),
                by_key: HashMap::new(),
                next_id: 0,
                ttl_period: None,
                next_sweep: None,
                stopped: false,
            }),
            cv: Condvar::new(),
            parked: AtomicUsize::new(0),
            write_wakeups: AtomicU64::new(0),
        }
    }

    fn register(&self, ticket: Ticket, p: Park) {
        // Park with an immediate verification probe: a key that landed in
        // the window between the executor's miss and this registration
        // (when `notify_key` had no waiter to find) is caught on the hub's
        // next pass instead of a full backoff interval later.
        self.register_waiter(Waiter {
            ticket,
            keys: p.keys,
            deadline: p.deadline,
            interval: p.interval,
            cap: p.cap,
            next_probe: Instant::now(),
            skip_backoff: true,
            batch: p.batch,
        });
    }

    fn register_waiter(&self, w: Waiter) {
        let mut s = self.state.lock().unwrap();
        let id = s.next_id;
        s.next_id += 1;
        for k in &w.keys {
            s.by_key.entry(k.clone()).or_default().push(id);
        }
        s.waiters.insert(id, w);
        self.parked.store(s.waiters.len(), Ordering::Release);
        self.cv.notify_one();
    }

    /// Wake every waiter parked on `key`: mark it due now so the hub's
    /// next pass probes (and resolves) it.  Invoked by the store's write
    /// observer after each successful put; when nothing is parked the cost
    /// is a single atomic load.
    fn notify_key(&self, key: &str) {
        if self.parked.load(Ordering::Acquire) == 0 {
            return;
        }
        let mut s = self.state.lock().unwrap();
        let ids = match s.by_key.get(key) {
            Some(ids) => ids.clone(),
            None => return,
        };
        let now = Instant::now();
        let mut hit = false;
        for id in ids {
            if let Some(w) = s.waiters.get_mut(&id) {
                if w.next_probe > now {
                    w.next_probe = now;
                    w.skip_backoff = true;
                    hit = true;
                }
            }
        }
        if hit {
            self.write_wakeups.fetch_add(1, Ordering::Relaxed);
            self.cv.notify_one();
        }
    }

    /// (Re)arm the background TTL sweeper: period `ttl/4` clamped to
    /// 10 ms..1 s, or off when no TTL policy is active.
    fn set_ttl(&self, ttl: Option<Duration>) {
        let mut s = self.state.lock().unwrap();
        match ttl {
            Some(ttl) => {
                let period = (ttl / 4).clamp(Duration::from_millis(10), Duration::from_secs(1));
                s.ttl_period = Some(period);
                s.next_sweep = Some(Instant::now() + period);
            }
            None => {
                s.ttl_period = None;
                s.next_sweep = None;
            }
        }
        self.cv.notify_one();
    }

    fn stop(&self) {
        let mut s = self.state.lock().unwrap();
        s.stopped = true;
        self.cv.notify_all();
    }
}

fn run_hub(ctx: ExecCtx) {
    let hub = Arc::clone(&ctx.hub);
    let mut due: Vec<Waiter> = Vec::new();
    loop {
        let mut sweep = false;
        let stopping;
        {
            let mut s = hub.state.lock().unwrap();
            loop {
                if s.stopped {
                    // Resolve every remaining waiter so no connection hangs
                    // through shutdown.
                    let ids: Vec<u64> = s.waiters.keys().copied().collect();
                    for id in ids {
                        due.push(remove_waiter(&mut s, id));
                    }
                    break;
                }
                let now = Instant::now();
                let due_ids: Vec<u64> = s
                    .waiters
                    .iter()
                    .filter(|(_, w)| w.next_probe <= now)
                    .map(|(&id, _)| id)
                    .collect();
                for id in due_ids {
                    due.push(remove_waiter(&mut s, id));
                }
                if let Some(t) = s.next_sweep {
                    if t <= now {
                        sweep = true;
                        s.next_sweep = s.ttl_period.map(|p| now + p);
                    }
                }
                if !due.is_empty() || sweep {
                    break;
                }
                // Sleep to the earliest timer, or indefinitely if none —
                // an idle hub makes zero wakeups.
                let earliest =
                    s.waiters.values().map(|w| w.next_probe).chain(s.next_sweep).min();
                s = match earliest {
                    None => hub.cv.wait(s).unwrap(),
                    Some(t) => {
                        let now = Instant::now();
                        if t <= now {
                            continue;
                        }
                        hub.cv.wait_timeout(s, t - now).unwrap().0
                    }
                };
            }
            hub.parked.store(s.waiters.len(), Ordering::Release);
            stopping = s.stopped;
        }
        // Probes and sweeps run outside the hub lock: they take the
        // command gate and store locks.
        if sweep {
            ctx.store.expire_ttl();
        }
        for w in due.drain(..) {
            probe_waiter(w, stopping, &ctx);
        }
        if stopping {
            return;
        }
    }
}

/// Remove one waiter from the slab, scrubbing its key-index entries.
fn remove_waiter(s: &mut HubState, id: u64) -> Waiter {
    let w = s.waiters.remove(&id).expect("due waiter id is valid");
    for k in &w.keys {
        if let Some(ids) = s.by_key.get_mut(k) {
            ids.retain(|&i| i != id);
            if ids.is_empty() {
                s.by_key.remove(k);
            }
        }
    }
    w
}

/// Probe one due waiter.  Resolved waiters complete directly (bare polls)
/// or resume their batch on the executor pool; unresolved ones re-park —
/// with doubled backoff when a real backoff interval expired, unchanged
/// when the probe was a registration/write-wakeup verification.
fn probe_waiter(mut w: Waiter, stopping: bool, ctx: &ExecCtx) {
    let present = {
        let _g = ctx.gate.enter();
        ctx.store.exists_all(&w.keys)
    };
    let now = Instant::now();
    if present || now >= w.deadline || stopping || ctx.shared.stop.load(Ordering::Relaxed) {
        match w.batch.take() {
            None => ctx.shared.complete(w.ticket, Response::Bool(present)),
            Some(cont) => {
                ctx.jobs.push(Job::Resume { ticket: w.ticket, cont, poll_result: present })
            }
        }
        return;
    }
    if w.skip_backoff {
        w.skip_backoff = false;
    } else {
        w.interval = (w.interval * 2).min(w.cap);
    }
    w.next_probe = now + w.interval.min(w.deadline.saturating_duration_since(now));
    ctx.hub.register_waiter(w);
}

// ---------------------------------------------------------------------------
// Reactor: an event-loop thread owning a disjoint shard of the sockets.
// ---------------------------------------------------------------------------

/// An outbound segment: either bytes owned by the outbox (headers and
/// small replies, coalesced) or a refcounted view of a store buffer
/// (large tensor payloads, zero-copy).
enum SegBuf {
    Owned(Vec<u8>),
    Shared(Bytes),
}

impl SegBuf {
    fn as_slice(&self) -> &[u8] {
        match self {
            SegBuf::Owned(v) => v,
            SegBuf::Shared(b) => b,
        }
    }
}

struct OutSeg {
    data: SegBuf,
    off: usize,
}

/// Direct-read mode for a frame body larger than the staging buffer:
/// bytes land straight in the allocation the store will keep.
struct BodyRead {
    tag: u32,
    buf: Vec<u8>,
    got: usize,
}

/// Work queued behind the currently executing untagged request, keeping
/// legacy pipelined frames strictly in order.
enum LegacyJob {
    Run(Request),
    Reply(Response),
}

struct Conn {
    stream: FaultStream<TcpStream>,
    fd: RawFd,
    /// Staging buffer for reads; `rpos..` is unparsed.
    rbuf: Vec<u8>,
    rpos: usize,
    direct: Option<BodyRead>,
    outbox: VecDeque<OutSeg>,
    legacy_q: VecDeque<LegacyJob>,
    /// An untagged request is dispatched and unanswered; further untagged
    /// frames queue behind it.
    legacy_busy: bool,
    /// Dispatched-but-unanswered requests (tagged + untagged + queued).
    in_flight: usize,
    read_on: bool,
    write_on: bool,
    /// Set while a frame is partially received; drives the stall killer.
    partial_since: Option<Instant>,
}

/// Reactor state that connection handling needs alongside a `&mut Conn`
/// (kept separate from the connection map so the borrows split).
struct ReactorCtx {
    poller: Poller,
    jobs: Arc<JobQueue>,
    shared: Arc<Shared>,
    store: Arc<Store>,
    /// This reactor's index, stamped into every [`Ticket`] so completions
    /// route back to the owning event loop.
    reactor: u32,
    /// Connections currently holding a partial frame; the event loop only
    /// uses a wait timeout when this is non-zero.
    n_partial: usize,
    stall_timeout: Duration,
}

struct Reactor {
    ctx: ReactorCtx,
    conns: HashMap<u64, Conn>,
    /// `None` on reactors 1.. in the acceptor-handoff fallback, where only
    /// reactor 0 listens.
    listener: Option<TcpListener>,
    wake_rx: WakeReceiver,
    fault: Option<Arc<FaultPlan>>,
    next_token: u64,
    index: usize,
    n_reactors: usize,
    /// Deal accepted sockets round-robin to peer inboxes instead of
    /// adopting them all (set on reactor 0 in the fallback mode only).
    handoff: bool,
    next_rr: usize,
}

enum Parsed {
    Frame { tag: u32, body: Vec<u8> },
    Direct,
    NeedMore,
}

enum Filled {
    Bytes,
    WouldBlock,
    Closed,
    Failed,
}

impl Reactor {
    fn run(mut self) {
        let mut events: Vec<Event> = Vec::new();
        loop {
            // Idle server: no partial frames means no timers here — sleep
            // until the OS has something (completions arrive via the waker).
            let timeout =
                if self.ctx.n_partial > 0 { Some(self.ctx.stall_timeout) } else { None };
            events.clear();
            if self.ctx.poller.wait(timeout, &mut events).is_err() {
                break;
            }
            for ev in &events {
                match ev.token {
                    TOKEN_LISTENER => self.accept_ready(),
                    TOKEN_WAKER => self.wake_rx.drain(),
                    t => self.conn_event(t, ev.writable, ev.readable || ev.hangup),
                }
            }
            self.drain_inbox();
            self.drain_completions();
            if self.ctx.n_partial > 0 {
                self.kill_stalled();
            }
            if self.ctx.shared.stop.load(Ordering::Relaxed) {
                break;
            }
        }
        // Dropping the reactor closes the listener (port released) and
        // every connection socket.
    }

    /// Drain the accept backlog.  Readiness-driven: the first connect
    /// after any idle period is served at event latency, not after an
    /// accept-backoff sleep.  With `SO_REUSEPORT` sharding every reactor
    /// runs this against its own listener; in the fallback mode only
    /// reactor 0 listens and deals accepted sockets round-robin to its
    /// peers through their inboxes.
    fn accept_ready(&mut self) {
        loop {
            let Some(listener) = &self.listener else {
                return;
            };
            match listener.accept() {
                Ok((sock, _peer)) => {
                    if !self.handoff {
                        self.adopt(sock);
                        continue;
                    }
                    let target = self.next_rr % self.n_reactors;
                    self.next_rr = self.next_rr.wrapping_add(1);
                    if target == self.index {
                        self.adopt(sock);
                    } else {
                        let slot = &self.ctx.shared.reactors[target];
                        slot.inbox.lock().unwrap().push(sock);
                        slot.waker.wake();
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => break,
            }
        }
    }

    /// Adopt sockets handed over by the accepting reactor (the
    /// non-`SO_REUSEPORT` fallback); a no-op in reuseport mode.
    fn drain_inbox(&mut self) {
        if self.n_reactors == 1 {
            return;
        }
        let handed = {
            let mut g = self.ctx.shared.reactors[self.index].inbox.lock().unwrap();
            std::mem::take(&mut *g)
        };
        for sock in handed {
            self.adopt(sock);
        }
    }

    /// Take ownership of a freshly accepted socket: nonblocking mode,
    /// fault plan, poller registration, connection-table entry.
    fn adopt(&mut self, sock: TcpStream) {
        sock.set_nodelay(true).ok();
        if sock.set_nonblocking(true).is_err() {
            return;
        }
        let fd = sock.as_raw_fd();
        // Each connection draws its own decision stream from the plan;
        // `None` is a passthrough wrapper.
        let conn_faults = self.fault.as_ref().map(|p| p.connection());
        let token = self.next_token;
        self.next_token += 1;
        if self.ctx.poller.register(fd, token, true, false).is_err() {
            return; // drop the socket
        }
        self.conns.insert(
            token,
            Conn {
                stream: FaultStream::over(sock, conn_faults),
                fd,
                rbuf: Vec::new(),
                rpos: 0,
                direct: None,
                outbox: VecDeque::new(),
                legacy_q: VecDeque::new(),
                legacy_busy: false,
                in_flight: 0,
                read_on: true,
                write_on: false,
                partial_since: None,
            },
        );
        // Any bytes already queued on the socket re-announce through the
        // level-triggered poller next wait.
    }

    fn conn_event(&mut self, token: u64, writable: bool, readable: bool) {
        let Some(conn) = self.conns.get_mut(&token) else {
            return;
        };
        let mut alive = true;
        if writable {
            alive = flush_outbox(conn);
        }
        if alive && readable {
            alive = pump_conn(&mut self.ctx, token, conn);
        }
        if alive {
            let conn = self.conns.get_mut(&token).unwrap();
            alive = sync_interest(&mut self.ctx, conn, token);
        }
        if !alive {
            self.close_conn(token);
        }
    }

    /// Deliver finished requests back to their connections and flush.
    fn drain_completions(&mut self) {
        let pending = {
            let mut g = self.ctx.shared.reactors[self.index].completions.lock().unwrap();
            std::mem::take(&mut *g)
        };
        for c in pending {
            let Some(conn) = self.conns.get_mut(&c.ticket.token) else {
                continue; // connection died while the request ran
            };
            let was_paused = conn.in_flight >= MAX_IN_FLIGHT;
            on_complete(&mut self.ctx, c.ticket.token, conn, c.ticket.tag, &c.resp);
            let mut alive = flush_outbox(conn);
            if alive && was_paused && conn.in_flight < MAX_IN_FLIGHT {
                // Reading was paused at the in-flight cap: bytes already
                // staged hold frames no readiness event will re-announce,
                // so pump directly now that there is headroom.
                alive = pump_conn(&mut self.ctx, c.ticket.token, conn);
            }
            if alive {
                let conn = self.conns.get_mut(&c.ticket.token).unwrap();
                alive = sync_interest(&mut self.ctx, conn, c.ticket.token);
            }
            if !alive {
                self.close_conn(c.ticket.token);
            }
        }
    }

    /// Reap connections that sat on a partial frame past the stall
    /// deadline without progress.
    fn kill_stalled(&mut self) {
        let now = Instant::now();
        let stall = self.ctx.stall_timeout;
        let stalled: Vec<u64> = self
            .conns
            .iter()
            .filter(|(_, c)| matches!(c.partial_since, Some(t) if now.duration_since(t) >= stall))
            .map(|(&t, _)| t)
            .collect();
        for t in stalled {
            self.close_conn(t);
        }
    }

    fn close_conn(&mut self, token: u64) {
        if let Some(conn) = self.conns.remove(&token) {
            if conn.partial_since.is_some() {
                self.ctx.n_partial -= 1;
            }
            let _ = self.ctx.poller.deregister(conn.fd);
        }
    }
}

/// Read and dispatch as much as the socket and the in-flight cap allow.
/// Returns `false` when the connection should close.
fn pump_conn(ctx: &mut ReactorCtx, token: u64, conn: &mut Conn) -> bool {
    let mut progressed = false;
    let alive = loop {
        // Direct-mode body read: the header named a payload beyond what
        // staging held; bytes go straight into its final allocation.
        if let Some(body) = &mut conn.direct {
            match conn.stream.read(&mut body.buf[body.got..]) {
                Ok(0) => break false,
                Ok(n) => {
                    body.got += n;
                    progressed = true;
                    if body.got == body.buf.len() {
                        let BodyRead { tag, buf, .. } = conn.direct.take().unwrap();
                        dispatch_frame(ctx, token, conn, tag, buf);
                    }
                    continue;
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break true,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => break false,
            }
        }
        if conn.in_flight >= MAX_IN_FLIGHT {
            // Backpressure: stop parsing (and reading — see
            // `sync_interest`) until completions drain.
            break true;
        }
        match parse_one(conn) {
            Ok(Parsed::Frame { tag, body }) => {
                progressed = true;
                dispatch_frame(ctx, token, conn, tag, body);
            }
            Ok(Parsed::Direct) => progressed = true,
            Ok(Parsed::NeedMore) => match fill_staging(conn) {
                Filled::Bytes => progressed = true,
                Filled::WouldBlock => break true,
                Filled::Closed | Filled::Failed => break false,
            },
            Err(()) => break false, // oversize/corrupt length word
        }
    };
    note_partial(ctx, conn, progressed);
    alive
}

/// Try to lift one frame out of the staging buffer.
fn parse_one(conn: &mut Conn) -> std::result::Result<Parsed, ()> {
    let avail = conn.rbuf.len() - conn.rpos;
    if avail < 4 {
        return Ok(Parsed::NeedMore);
    }
    let word = u32::from_le_bytes(conn.rbuf[conn.rpos..conn.rpos + 4].try_into().unwrap());
    let tagged = word & FRAME_TAG_FLAG != 0;
    let header = if tagged { 8 } else { 4 };
    if avail < header {
        return Ok(Parsed::NeedMore);
    }
    let body_len = (word & !FRAME_TAG_FLAG) as usize;
    if body_len > MAX_FRAME {
        return Err(()); // corrupt stream; drop the connection
    }
    let tag = if tagged {
        u32::from_le_bytes(conn.rbuf[conn.rpos + 4..conn.rpos + 8].try_into().unwrap())
    } else {
        0
    };
    let start = conn.rpos + header;
    if conn.rbuf.len() - start >= body_len {
        // Copy the body out right-sized: payload frames hand this exact
        // allocation to the store, so capacity from unrelated frames must
        // not ride along.
        let body = conn.rbuf[start..start + body_len].to_vec();
        conn.rpos = start + body_len;
        Ok(Parsed::Frame { tag, body })
    } else {
        // Large body: switch to direct reads into a right-sized buffer,
        // seeded with whatever staging already holds.
        let mut buf = Vec::with_capacity(body_len);
        buf.extend_from_slice(&conn.rbuf[start..]);
        let got = buf.len();
        buf.resize(body_len, 0);
        conn.rbuf.clear();
        conn.rpos = 0;
        conn.direct = Some(BodyRead { tag, buf, got });
        Ok(Parsed::Direct)
    }
}

/// Refill the staging buffer with one read.
fn fill_staging(conn: &mut Conn) -> Filled {
    if conn.rpos > 0 {
        // Compact consumed bytes so a long-lived connection's buffer
        // doesn't grow without bound.
        conn.rbuf.drain(..conn.rpos);
        conn.rpos = 0;
    }
    let old = conn.rbuf.len();
    conn.rbuf.resize(old + READ_CHUNK, 0);
    let r = conn.stream.read(&mut conn.rbuf[old..]);
    match r {
        Ok(0) => {
            conn.rbuf.truncate(old);
            Filled::Closed
        }
        Ok(n) => {
            conn.rbuf.truncate(old + n);
            Filled::Bytes
        }
        Err(e)
            if e.kind() == std::io::ErrorKind::WouldBlock
                || e.kind() == std::io::ErrorKind::Interrupted =>
        {
            // Interrupted reads retry on the next level-triggered event.
            conn.rbuf.truncate(old);
            Filled::WouldBlock
        }
        Err(_) => {
            conn.rbuf.truncate(old);
            Filled::Failed
        }
    }
}

/// Decode one frame and hand it to the executor pool (or queue it behind
/// the running untagged request, preserving legacy in-order semantics).
fn dispatch_frame(ctx: &mut ReactorCtx, token: u64, conn: &mut Conn, tag: u32, body: Vec<u8>) {
    // One frame == one client round trip (a batch is still one frame).
    ctx.store.counters.frames.fetch_add(1, Ordering::Relaxed);
    let decoded = if Request::frame_holds_payload(&body) {
        // Hand the frame to the store wholesale: the decoded tensor's
        // payload is a view into it and the store keeps that single
        // allocation alive by refcount — zero copies between socket and
        // store.  Tensors put inside one Batch frame all alias this
        // allocation, so it stays resident until the *last* of them is
        // overwritten or deleted; the intended publish pattern — every
        // rank republishing under stable keys each snapshot — retires
        // whole batches together, so the coupling is benign there.
        let shared = Bytes::from_vec(body);
        Request::decode_shared(&shared)
    } else {
        Request::decode(&body)
    };
    match decoded {
        Err(e) => {
            let resp = Response::Error(e.to_string());
            if tag == 0 && conn.legacy_busy {
                // Keep the error in order behind queued untagged work.
                conn.in_flight += 1;
                conn.legacy_q.push_back(LegacyJob::Reply(resp));
            } else {
                queue_reply(conn, tag, &resp);
            }
        }
        Ok(req) => {
            conn.in_flight += 1;
            let ticket = Ticket { reactor: ctx.reactor, token, tag };
            if tag == 0 {
                if conn.legacy_busy {
                    conn.legacy_q.push_back(LegacyJob::Run(req));
                } else {
                    conn.legacy_busy = true;
                    ctx.jobs.push(Job::Request { ticket, req });
                }
            } else {
                ctx.jobs.push(Job::Request { ticket, req });
            }
        }
    }
}

/// A completed request: queue its reply and release queued legacy work.
fn on_complete(ctx: &mut ReactorCtx, token: u64, conn: &mut Conn, tag: u32, resp: &Response) {
    queue_reply(conn, tag, resp);
    conn.in_flight = conn.in_flight.saturating_sub(1);
    if tag == 0 {
        conn.legacy_busy = false;
        while let Some(job) = conn.legacy_q.pop_front() {
            match job {
                LegacyJob::Reply(r) => {
                    queue_reply(conn, 0, &r);
                    conn.in_flight = conn.in_flight.saturating_sub(1);
                }
                LegacyJob::Run(req) => {
                    conn.legacy_busy = true;
                    let ticket = Ticket { reactor: ctx.reactor, token, tag: 0 };
                    ctx.jobs.push(Job::Request { ticket, req });
                    break;
                }
            }
        }
    }
}

/// Serialize one reply into the connection's outbox.  Headers and small
/// payloads coalesce into owned segments; large tensor payloads are
/// queued as refcounted views of the store's buffers (zero-copy).
fn queue_reply(conn: &mut Conn, tag: u32, resp: &Response) {
    let body = resp.body_wire_size();
    if body > MAX_FRAME {
        // A batch of individually legal tensors can exceed the frame cap
        // in aggregate; answer with an error the client can handle rather
        // than killing the connection on the unsendable reply.
        let err = Response::Error(format!(
            "reply of {body} bytes exceeds the {MAX_FRAME} byte frame limit; split the batch"
        ));
        queue_reply(conn, tag, &err);
        return;
    }
    let mut cur = Vec::with_capacity(64.max(body.min(SEG_SHARED_MIN)) + 8);
    if tag == 0 {
        cur.extend_from_slice(&(body as u32).to_le_bytes());
    } else {
        cur.extend_from_slice(&((body as u32) | FRAME_TAG_FLAG).to_le_bytes());
        cur.extend_from_slice(&tag.to_le_bytes());
    }
    push_reply_body(conn, &mut cur, resp);
    if !cur.is_empty() {
        conn.outbox.push_back(OutSeg { data: SegBuf::Owned(cur), off: 0 });
    }
}

fn push_reply_body(conn: &mut Conn, cur: &mut Vec<u8>, resp: &Response) {
    match resp {
        Response::Tensor(t) => {
            message::encode_tensor_response_header_into(cur, t);
            if t.data.len() >= SEG_SHARED_MIN {
                if !cur.is_empty() {
                    let seg = OutSeg { data: SegBuf::Owned(std::mem::take(cur)), off: 0 };
                    conn.outbox.push_back(seg);
                }
                conn.outbox.push_back(OutSeg { data: SegBuf::Shared(t.data.clone()), off: 0 });
            } else {
                cur.extend_from_slice(&t.data);
            }
        }
        Response::Batch(entries) => {
            message::encode_batch_response_header_into(cur, entries.len());
            for e in entries {
                push_reply_body(conn, cur, e);
            }
        }
        other => other.encode(cur),
    }
}

/// Write as much of the outbox as the socket accepts.  Returns `false`
/// when the connection should close.
fn flush_outbox(conn: &mut Conn) -> bool {
    loop {
        let Some(seg) = conn.outbox.front_mut() else {
            return true;
        };
        let len = seg.data.as_slice().len();
        if seg.off >= len {
            conn.outbox.pop_front();
            continue;
        }
        match conn.stream.write(&seg.data.as_slice()[seg.off..]) {
            Ok(0) => return false,
            Ok(n) => seg.off += n,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return true,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(_) => return false,
        }
    }
}

/// Align poller interest with connection state: read while under the
/// in-flight cap, write while the outbox is non-empty.
fn sync_interest(ctx: &mut ReactorCtx, conn: &mut Conn, token: u64) -> bool {
    let want_read = conn.in_flight < MAX_IN_FLIGHT;
    let want_write = !conn.outbox.is_empty();
    if want_read != conn.read_on || want_write != conn.write_on {
        if ctx.poller.rearm(conn.fd, token, want_read, want_write).is_err() {
            return false;
        }
        conn.read_on = want_read;
        conn.write_on = want_write;
    }
    true
}

/// Track whether this connection holds a partial frame (drives the stall
/// killer and the event loop's wait timeout).
fn note_partial(ctx: &mut ReactorCtx, conn: &mut Conn, progressed: bool) {
    let paused = conn.in_flight >= MAX_IN_FLIGHT;
    let partial = !paused && (conn.direct.is_some() || conn.rpos < conn.rbuf.len());
    match (conn.partial_since.is_some(), partial) {
        (false, true) => {
            conn.partial_since = Some(Instant::now());
            ctx.n_partial += 1;
        }
        (true, false) => {
            conn.partial_since = None;
            ctx.n_partial -= 1;
        }
        (true, true) if progressed => conn.partial_since = Some(Instant::now()),
        _ => {}
    }
}

/// A running database server.  Dropping the handle shuts it down.
pub struct DbServer {
    pub addr: SocketAddr,
    store: Arc<Store>,
    models: Option<Arc<ModelRuntime>>,
    shared: Arc<Shared>,
    jobs: Arc<JobQueue>,
    hub: Arc<PollHub>,
    reactor_threads: Vec<JoinHandle<()>>,
    exec_threads: Vec<JoinHandle<()>>,
    hub_thread: Option<JoinHandle<()>>,
    pub config: ServerConfig,
    /// Set by [`DbServer::simulate_crash`]: teardown skips the clean
    /// shutdown spill barrier, like a real `kill -9` would.
    crashed: bool,
}

impl DbServer {
    /// Start a server (with a fresh executor thread if models are enabled).
    pub fn start(config: ServerConfig) -> Result<DbServer> {
        let models = if config.with_models {
            Some(Arc::new(ModelRuntime::new(Executor::new()?)))
        } else {
            None
        };
        Self::start_with(config, models)
    }

    /// Start a server sharing an existing model runtime (co-located
    /// deployments reuse one PJRT executor across components).
    pub fn start_with(config: ServerConfig, models: Option<Arc<ModelRuntime>>) -> Result<DbServer> {
        let n_reactors = resolve_reactors(&config);
        // Listener strategy: one reactor binds plainly.  Several reactors
        // prefer one SO_REUSEPORT listener each (kernel-balanced accepts);
        // where the option is unavailable, reactor 0 owns the only
        // listener and deals accepted sockets to its peers.
        let mut listeners: Vec<Option<TcpListener>> = Vec::with_capacity(n_reactors);
        let handoff;
        if n_reactors > 1 && reuseport_available() {
            let first = bind_reuseport(config.addr).map_err(Error::Io)?;
            let bound = first.local_addr()?;
            listeners.push(Some(first));
            for _ in 1..n_reactors {
                listeners.push(Some(bind_reuseport(bound).map_err(Error::Io)?));
            }
            handoff = false;
        } else {
            listeners.push(Some(TcpListener::bind(config.addr)?));
            listeners.resize_with(n_reactors, || None);
            handoff = n_reactors > 1;
        }
        let addr =
            listeners[0].as_ref().expect("reactor 0 always owns a listener").local_addr()?;
        for l in listeners.iter().flatten() {
            l.set_nonblocking(true)?;
        }
        let store = Arc::new(Store::new());
        // Spill first, so the very first window retirement already lands
        // in the cold tier (opening also crash-recovers an existing log).
        if let Some(spill) = &config.spill {
            store.set_spill(Some(spill.clone()))?;
        }
        if !config.retention.is_unbounded() {
            store.set_retention(config.retention);
        }
        let gate = Arc::new(CommandGate::new(config.engine));
        let mut reactor_shared = Vec::with_capacity(n_reactors);
        let mut wake_rxs = Vec::with_capacity(n_reactors);
        for _ in 0..n_reactors {
            let (wake, wake_rx) = waker().map_err(Error::Io)?;
            reactor_shared.push(ReactorShared {
                completions: Mutex::new(Vec::new()),
                inbox: Mutex::new(Vec::new()),
                waker: wake,
            });
            wake_rxs.push(wake_rx);
        }
        let shared = Arc::new(Shared { reactors: reactor_shared, stop: AtomicBool::new(false) });
        let jobs = Arc::new(JobQueue::new());
        let hub = Arc::new(PollHub::new());
        hub.set_ttl(store.retention().ttl());
        // Write-triggered poll wakeup: every landed put nudges the hub so
        // parked waiters on that key resolve now, not at their next
        // backoff probe.
        {
            let hub = Arc::clone(&hub);
            store.set_write_observer(Arc::new(move |key: &str| hub.notify_key(key)));
        }
        let ctx = ExecCtx {
            store: Arc::clone(&store),
            models: models.clone(),
            gate,
            engine: config.engine,
            shared: Arc::clone(&shared),
            jobs: Arc::clone(&jobs),
            hub: Arc::clone(&hub),
        };
        let n_exec = config.engine.exec_threads(config.cores).clamp(1, 16);
        let mut exec_threads = Vec::with_capacity(n_exec);
        for i in 0..n_exec {
            let ctx = ctx.clone();
            exec_threads.push(
                std::thread::Builder::new()
                    .name(format!("db-exec-{i}"))
                    .spawn(move || run_executor(ctx))
                    .map_err(Error::Io)?,
            );
        }
        let hub_thread = std::thread::Builder::new()
            .name("db-hub".into())
            .spawn(move || run_hub(ctx))
            .map_err(Error::Io)?;
        let mut reactor_threads = Vec::with_capacity(n_reactors);
        for (i, (listener, wake_rx)) in listeners.into_iter().zip(wake_rxs).enumerate() {
            let mut poller = Poller::new().map_err(Error::Io)?;
            if let Some(l) = &listener {
                poller
                    .register(l.as_raw_fd(), TOKEN_LISTENER, true, false)
                    .map_err(Error::Io)?;
            }
            poller
                .register(wake_rx.as_raw_fd(), TOKEN_WAKER, true, false)
                .map_err(Error::Io)?;
            let reactor = Reactor {
                ctx: ReactorCtx {
                    poller,
                    jobs: Arc::clone(&jobs),
                    shared: Arc::clone(&shared),
                    store: Arc::clone(&store),
                    reactor: i as u32,
                    n_partial: 0,
                    stall_timeout: config.conn_read_timeout,
                },
                conns: HashMap::new(),
                listener,
                wake_rx,
                fault: config.fault.clone(),
                next_token: FIRST_CONN_TOKEN,
                index: i,
                n_reactors,
                handoff: handoff && i == 0,
                next_rr: 0,
            };
            reactor_threads.push(
                std::thread::Builder::new()
                    .name(format!("db-reactor-{}-{i}", addr.port()))
                    .spawn(move || reactor.run())
                    .map_err(Error::Io)?,
            );
        }
        Ok(DbServer {
            addr,
            store,
            models,
            shared,
            jobs,
            hub,
            reactor_threads,
            exec_threads,
            hub_thread: Some(hub_thread),
            config,
            crashed: false,
        })
    }

    /// Node-local (in-process) access to the store — the co-located fast
    /// path used by benches to inspect state without a socket round-trip.
    pub fn store(&self) -> &Arc<Store> {
        &self.store
    }

    pub fn models(&self) -> Option<&Arc<ModelRuntime>> {
        self.models.as_ref()
    }

    /// Write notifications that advanced a parked `PollKeys` waiter —
    /// i.e. poll resolutions delivered at write latency, strictly before
    /// the waiter's next backoff probe would have fired.  Benches use this
    /// to gate the write-wakeup path structurally.
    pub fn poll_write_wakeups(&self) -> u64 {
        self.hub.write_wakeups.load(Ordering::Relaxed)
    }

    /// The number of reactor threads this server is running.
    pub fn reactors(&self) -> usize {
        self.reactor_threads.len()
    }

    /// Stop all threads and close every socket (idempotent).  Shutdown is
    /// signal-driven — the reactor wakes on the self-pipe and the hub on
    /// its condvar — so it completes at event latency, not after a poll
    /// interval.
    fn teardown(&mut self) {
        self.shared.stop.store(true, Ordering::Relaxed);
        self.shared.wake_all();
        self.hub.stop();
        for h in self.reactor_threads.drain(..) {
            let _ = h.join();
        }
        self.jobs.close();
        for h in self.exec_threads.drain(..) {
            let _ = h.join();
        }
        if let Some(h) = self.hub_thread.take() {
            let _ = h.join();
        }
    }

    pub fn shutdown(&mut self) {
        self.teardown();
        // Drain the spill writer before returning: every record the
        // retention pipeline enqueued is on disk when shutdown returns, so
        // a clean exit never loses queued cold-tier data (no-op without a
        // spill config).  A *crashed* server gets no such courtesy — only
        // what the spill writer already flushed survives, which is exactly
        // what the crash-recovery tests assert against.
        if !self.crashed {
            self.store.spill_sync();
        }
    }

    /// Kill the server the way `kill -9` would, as far as in-process
    /// simulation allows: stop serving, release the listener port (a
    /// restarted server can rebind it), and *skip* the clean-shutdown
    /// spill barrier so queued cold-tier records are dropped on the floor.
    /// To sever client I/O mid-operation deterministically, pair this with
    /// [`FaultPlan::kill`] on the server's fault plan (done here when the
    /// server owns a plan).
    pub fn simulate_crash(&mut self) {
        self.crashed = true;
        if let Some(p) = &self.config.fault {
            p.kill();
        }
        self.teardown();
    }
}

impl Drop for DbServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Execute one decoded command (shared by the TCP path and the unit tests).
///
/// This layer never blocks: `PollKeys` is a single all-exist probe here (the
/// waiting lives in the executor/hub layer, where parking doesn't hold the
/// command gate).
pub fn execute(
    req: Request,
    store: &Store,
    models: Option<&ModelRuntime>,
    engine: Engine,
) -> Response {
    match req {
        Request::Batch(entries) => Response::Batch(
            entries
                .into_iter()
                .map(|e| execute(e, store, models, engine))
                .collect(),
        ),
        // Keyed data ops pass the slot-ownership admission check
        // (`Store::check_owned`) first: with an epoch table installed, a
        // shard that no longer owns the key's slot rejects the op with a
        // `moved: <epoch>` error so stale clients refetch their table.
        // Deletes (`DelTensor` is enforced, `DelKeys` is not), aggregate
        // ops, `PollKeys` probes, and the node-local cold tier are exempt —
        // see docs/cluster.md for the exact rules.
        // MGetTensors is deliberately NOT ownership-checked: the reshard
        // driver streams surviving replica copies with it, and a replica's
        // placement under a *previous* ring modulus is not derivable from
        // the current table. Stale clients are still corrected because the
        // per-key fallback path they take on a miss is the enforced
        // GetTensor, which bounces with `moved:` and triggers a refetch.
        Request::MGetTensors { keys } => Response::Batch(
            keys.iter()
                .map(|k| match store.get_tensor(k) {
                    Ok(t) => Response::Tensor(t),
                    Err(Error::KeyNotFound(_)) => Response::NotFound,
                    Err(e) => Response::Error(e.to_string()),
                })
                .collect(),
        ),
        Request::PollKeys { keys, .. } => Response::Bool(store.exists_all(&keys)),
        Request::PutTensor { key, tensor } => {
            match store.check_owned(&key, true).and_then(|_| store.put_tensor(&key, tensor)) {
                Ok(()) => Response::Ok,
                Err(e) => Response::Error(e.to_string()),
            }
        }
        Request::GetTensor { key } => {
            match store.check_owned(&key, false).and_then(|_| store.get_tensor(&key)) {
                Ok(t) => Response::Tensor(t),
                // A miss on a mid-migration slot is not authoritative when
                // this shard is only a *new*-ring member — the transfer may
                // not have landed the key yet.  Bounce instead, so clients
                // holding a pre-migration table refetch and fall back to
                // the old owner rather than trusting a hollow `NotFound`.
                Err(Error::KeyNotFound(_)) => match store.migrating_miss(&key) {
                    Some(ep) => Response::Error(Error::Moved(ep).to_string()),
                    None => Response::NotFound,
                },
                Err(e) => Response::Error(e.to_string()),
            }
        }
        Request::DelTensor { key } => {
            if let Err(e) = store.check_owned(&key, true) {
                return Response::Error(e.to_string());
            }
            if store.del_tensor(&key) {
                Response::Ok
            } else {
                Response::NotFound
            }
        }
        Request::Exists { key } => match store.check_owned(&key, false) {
            Ok(()) => Response::Bool(store.exists(&key)),
            Err(e) => Response::Error(e.to_string()),
        },
        Request::PutMeta { key, value } => match store.check_owned(&key, true) {
            Ok(()) => {
                store.put_meta(&key, &value);
                Response::Ok
            }
            Err(e) => Response::Error(e.to_string()),
        },
        Request::GetMeta { key } => {
            match store.check_owned(&key, false).and_then(|_| store.get_meta(&key)) {
                Ok(v) => Response::Meta(v),
                Err(Error::KeyNotFound(_)) => Response::NotFound,
                Err(e) => Response::Error(e.to_string()),
            }
        }
        Request::ListKeys { prefix } => Response::Keys(store.list_keys(&prefix)),
        Request::PutModel { key, hlo_text } => match models {
            None => Response::Error("model runtime disabled on this server".into()),
            Some(m) => match m.put_model(&key, &hlo_text) {
                Ok(version) => Response::Version(version),
                Err(e) => Response::Error(e.to_string()),
            },
        },
        Request::RunModel { key, version, in_keys, out_keys, device } => match models {
            None => Response::Error("model runtime disabled on this server".into()),
            Some(m) => match m.run_model(store, &key, version, &in_keys, &out_keys, device) {
                Ok(()) => Response::Ok,
                Err(Error::KeyNotFound(k)) => Response::Error(format!("input key not found: {k}")),
                Err(Error::ModelNotFound(k)) => Response::Error(format!("model not found: {k}")),
                Err(e) => Response::Error(e.to_string()),
            },
        },
        Request::ListModels => match models {
            None => Response::Models(Vec::new()),
            Some(m) => Response::Models(m.model_entries()),
        },
        Request::ModelStats => match models {
            None => Response::ModelStats(Vec::new()),
            Some(m) => Response::ModelStats(m.device_stat_rows()),
        },
        Request::DelKeys { keys } => Response::Batch(
            keys.iter()
                .map(|k| {
                    if store.del_tensor(k) {
                        Response::Ok
                    } else {
                        Response::NotFound
                    }
                })
                .collect(),
        ),
        Request::Retention { window, max_bytes, ttl_ms } => {
            store.set_retention(RetentionConfig { window, max_bytes, ttl_ms });
            Response::Ok
        }
        Request::ColdList { prefix } => Response::Keys(store.cold_list(&prefix)),
        Request::ColdGet { key } => match store.cold_get(&key) {
            Ok(t) => Response::Tensor(t),
            Err(Error::KeyNotFound(_)) => Response::NotFound,
            Err(e) => Response::Error(e.to_string()),
        },
        Request::Info => {
            // Opportunistic TTL sweep: keeps INFO counters exact even if
            // the background sweeper hasn't fired yet (no-op unless a TTL
            // policy is active).
            store.expire_ttl();
            // Spill barrier: every eviction that happened-before this INFO
            // is durable and counted, so the reply's spill counters are
            // exact rather than racing the writer thread (no-op without a
            // cold tier).
            store.spill_sync();
            let retention = store.retention();
            // The codec rejects field lists over MAX_BATCH; keep the reply
            // decodable for pathological field counts by reporting the
            // most-pressured fields (by resident bytes) and dropping the
            // tail, name-sorted again for stable output.
            let mut fields = store.field_pressure();
            if fields.len() > crate::proto::MAX_BATCH {
                fields.sort_by(|a, b| b.resident_bytes.cmp(&a.resident_bytes));
                fields.truncate(crate::proto::MAX_BATCH);
                fields.sort_by(|a, b| a.field.cmp(&b.field));
            }
            let (spilled_keys, spilled_bytes, spill_segments, cold_hits, spill_lost_keys) =
                store.spill_counters();
            Response::Info(DbInfo {
                keys: store.n_keys(),
                bytes: store.n_bytes(),
                ops: store.n_ops(),
                models: models.map(|m| m.n_models()).unwrap_or(0),
                high_water_bytes: store.high_water_bytes(),
                evicted_keys: store.counters.evicted_keys.load(Ordering::Relaxed),
                evicted_bytes: store.counters.evicted_bytes.load(Ordering::Relaxed),
                busy_rejections: store.counters.busy_rejections.load(Ordering::Relaxed),
                ttl_expired_keys: store.counters.ttl_expired_keys.load(Ordering::Relaxed),
                retention_window: retention.window,
                retention_max_bytes: retention.max_bytes,
                retention_ttl_ms: retention.ttl_ms,
                spilled_keys,
                spilled_bytes,
                spill_segments,
                cold_hits,
                spill_lost_keys,
                // Replication/failover are client-side phenomena: a single
                // server cannot observe them.  ClusterClient::info fills
                // these from its own FailoverStats.
                replicated_writes: 0,
                read_failovers: 0,
                shard_reconnects: 0,
                degraded_ops: 0,
                model_swaps: models.map(|m| m.swaps()).unwrap_or(0),
                batches: models.map(|m| m.batch_counters().0).unwrap_or(0),
                batched_requests: models.map(|m| m.batch_counters().1).unwrap_or(0),
                engine: engine.name().to_string(),
                fields,
            })
        }
        Request::FlushAll => {
            store.flush_all();
            Response::Ok
        }
        Request::ClusterEpoch { install } => {
            if let Some((shard, replicas, table)) = install {
                // Decode range-checks fields; revalidate the structural
                // invariants (tiling, no self-migration) before adopting.
                if let Err(e) = table.validate() {
                    return Response::Error(format!("invalid slot table: {e}"));
                }
                store.install_ownership(Ownership { shard, replicas, table });
            }
            match store.ownership() {
                Some(own) => {
                    Response::EpochTable { shard: own.shard, table: own.table.clone() }
                }
                None => Response::EpochTable {
                    shard: u16::MAX,
                    table: SlotEpoch { epoch: 0, assignments: Vec::new() },
                },
            }
        }
        Request::ExportSlots { lo, hi } => Response::Keys(store.keys_in_slots(lo, hi)),
        Request::ColdPut { key, tensor } => match store.cold_put(&key, tensor) {
            Ok(()) => Response::Ok,
            Err(e) => Response::Error(e.to_string()),
        },
    }
}

/// Resolve the default artifacts directory (repo-root relative, overridable
/// via SITU_ARTIFACTS).
pub fn artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("SITU_ARTIFACTS") {
        return PathBuf::from(p);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}
