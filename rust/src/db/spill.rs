//! Spill-to-disk cold tier: an append-only, CRC-checksummed segment log
//! that receives every tensor the retention pipeline retires, so bounded-
//! memory runs can replay evicted generations later (post-hoc analysis,
//! offline re-training) without holding them resident.
//!
//! # On-disk format
//!
//! The spill directory holds one subdirectory per *group* (the step-key
//! field, or the `__untracked` catch-all for keys outside the framework
//! scheme), each containing numbered segment files:
//!
//! ```text
//! <spill_dir>/<group>/seg-00000000.spill
//! segment := header | record*
//! header  := b"SITUSEG1" | u32-LE version(1) | u32-LE reserved(0)
//! record  := u32-LE RECORD_MAGIC | u32-LE body_len | u32-LE crc32(body) | body
//! body    := u32-LE key_len | key bytes
//!          | u8 dtype | u8 ndim | u32-LE dims[ndim]
//!          | u64-LE payload_len | payload bytes
//! ```
//!
//! Every record is individually framed and checksummed, so replay can
//! always tell a complete record from a torn or corrupted one:
//!
//! * a **truncated tail** (the writer crashed mid-append) replays as the
//!   valid prefix; reopening the group truncates the file back to the last
//!   complete record and appends resume from there, never clobbering
//!   surviving data;
//! * a **corrupted record** (length smash, payload bitflip) fails its CRC
//!   or bounds check and replay stops at the last valid record of that
//!   segment — framing is length-prefixed, so bytes after a bad length
//!   field cannot be trusted and are skipped, never mis-decoded into a
//!   torn tensor;
//! * none of these cases panic or hang — corruption surfaces as a clean
//!   `Err` from [`replay_segment`] / a `torn` flag, and the tier keeps
//!   serving every record that did survive.
//!
//! # Hot-path discipline
//!
//! The store never writes a spill record inline with a put: eviction hands
//! the retired tensor (a refcount bump on its shared [`Bytes`] payload —
//! no copy) to a dedicated writer thread over a channel, and that thread
//! serializes records with the payload written straight from the shared
//! buffer.  The queue is byte-budgeted ([`default_pending_bytes`],
//! `SITU_SPILL_PENDING_BYTES`): if the writer falls behind the eviction
//! rate, further victims are shed (counted in `backlog_dropped`) rather
//! than pinning evicted payloads in memory against the store's byte cap.
//! Readers (`ColdGet`/`ColdList`, `INFO`) synchronize with the writer via
//! [`Store::spill_sync`](crate::db::store::Store::spill_sync) before
//! touching the log, so governed put throughput stays within noise of a
//! spill-off store (`fig_spill` measures this).
//!
//! Segments rotate at [`SpillConfig::segment_bytes`] (override the default
//! with `SITU_SPILL_SEGMENT_BYTES`; CI runs the recovery tests with tiny
//! segments to exercise rotation).  With `max_bytes > 0`, oldest *sealed*
//! segments are deleted once the tier exceeds the cap — the cold tier is a
//! bounded archive, not an unbounded disk leak.
//!
//! The spill path is deliberately *not* part of [`RetentionConfig`]'s wire
//! surface: the numeric retention policy is broadcast to servers at
//! runtime (`Request::Retention`), while a spill directory is a
//! server-local resource configured at deployment time (`RunConfig
//! --spill-dir` → `DeploymentPlan` → [`ServerConfig`]'s `spill`).
//!
//! [`Bytes`]: crate::tensor::Bytes
//! [`RetentionConfig`]: crate::db::store::RetentionConfig
//! [`ServerConfig`]: crate::db::server::ServerConfig

use std::collections::{HashMap, VecDeque};
use std::fs::{File, OpenOptions};
use std::io::{BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;

use crate::error::{Error, Result};
use crate::tensor::{Bytes, DType, Tensor};

/// 8-byte magic opening every segment file.
pub const SEGMENT_MAGIC: [u8; 8] = *b"SITUSEG1";
/// Segment format version (bumped on layout changes).
pub const SEGMENT_VERSION: u32 = 1;
/// Segment header length: magic + version + reserved.
pub const SEGMENT_HEADER_LEN: u64 = 16;
/// Per-record magic; a replay that lands off a record boundary fails this
/// check instead of mis-decoding arbitrary bytes.
pub const RECORD_MAGIC: u32 = 0x3153_5053; // "SPS1" little-endian
/// Record framing overhead: magic + body_len + crc.
pub const RECORD_HEADER_LEN: u64 = 12;
/// Hard cap on a record body, mirroring the wire frame cap: a corrupted
/// length field can never drive a multi-gigabyte allocation.
pub const MAX_RECORD_BODY: usize = crate::proto::MAX_FRAME;

/// Default segment rotation threshold when `SITU_SPILL_SEGMENT_BYTES` is
/// not set.
pub const DEFAULT_SEGMENT_BYTES: u64 = 64 << 20;

/// Segment rotation threshold: `SITU_SPILL_SEGMENT_BYTES` override or the
/// 64 MiB default.  Tests and CI set a tiny value so rotation and
/// multi-segment replay are exercised constantly.
pub fn default_segment_bytes() -> u64 {
    std::env::var("SITU_SPILL_SEGMENT_BYTES")
        .ok()
        .and_then(|s| s.parse().ok())
        .filter(|b| *b > 0)
        .unwrap_or(DEFAULT_SEGMENT_BYTES)
}

/// Default budget for payload bytes queued to the writer thread.
pub const DEFAULT_PENDING_BYTES: u64 = 256 << 20;

/// In-flight spill queue budget: `SITU_SPILL_PENDING_BYTES` override
/// (0 = unbounded) or the 256 MiB default.  When the writer thread falls
/// behind the eviction rate by more than this, further victims are
/// dropped (counted) instead of pinning evicted payloads in memory.
pub fn default_pending_bytes() -> u64 {
    std::env::var("SITU_SPILL_PENDING_BYTES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(DEFAULT_PENDING_BYTES)
}

/// Configuration of one store's cold tier.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SpillConfig {
    /// Directory holding this instance's segment log.  Each database
    /// instance needs its own directory (the deployment plan derives
    /// per-instance subdirectories from `--spill-dir`).
    pub dir: PathBuf,
    /// Byte cap on the whole cold tier (0 = unbounded): once exceeded,
    /// oldest sealed segments are deleted, oldest first.
    pub max_bytes: u64,
    /// Segment rotation threshold; a segment may exceed it by at most one
    /// record (records never split across segments).
    pub segment_bytes: u64,
}

impl SpillConfig {
    /// Config with the default (env-overridable) segment size and no cap.
    pub fn new(dir: impl Into<PathBuf>) -> SpillConfig {
        SpillConfig { dir: dir.into(), max_bytes: 0, segment_bytes: default_segment_bytes() }
    }
}

// --- CRC32 (IEEE) ------------------------------------------------------------

const fn build_crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = build_crc_table();

/// Streaming CRC32 (IEEE 802.3), fed slice by slice so record checksums
/// cover header-and-payload without concatenating them.
pub struct Crc32(u32);

impl Default for Crc32 {
    fn default() -> Self {
        Self::new()
    }
}

impl Crc32 {
    pub fn new() -> Crc32 {
        Crc32(0xFFFF_FFFF)
    }

    pub fn update(&mut self, data: &[u8]) {
        let mut c = self.0;
        for &b in data {
            c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
        }
        self.0 = c;
    }

    pub fn finish(&self) -> u32 {
        self.0 ^ 0xFFFF_FFFF
    }
}

/// One-shot CRC32 of a single slice.
pub fn crc32(data: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(data);
    c.finish()
}

// --- record codec ------------------------------------------------------------

/// Encode everything of a record body except the payload bytes (the caller
/// streams the payload from its owning buffer, mirroring the wire path's
/// split-frame writes).
fn encode_body_head(buf: &mut Vec<u8>, key: &str, t: &Tensor) {
    buf.extend_from_slice(&(key.len() as u32).to_le_bytes());
    buf.extend_from_slice(key.as_bytes());
    buf.push(t.dtype.tag());
    buf.push(t.shape.len() as u8);
    for d in &t.shape {
        buf.extend_from_slice(&(*d as u32).to_le_bytes());
    }
    buf.extend_from_slice(&(t.data.len() as u64).to_le_bytes());
}

fn body_len(key: &str, t: &Tensor) -> usize {
    4 + key.len() + 1 + 1 + 4 * t.shape.len() + 8 + t.data.len()
}

/// Total on-disk size of one record.
pub fn record_wire_size(key: &str, t: &Tensor) -> u64 {
    RECORD_HEADER_LEN + body_len(key, t) as u64
}

/// Decode one record body (everything after the 12-byte record header).
/// The tensor payload is a zero-copy view into `body`.
fn decode_body(body: &Bytes) -> Result<(String, Tensor)> {
    let b = body.as_slice();
    let err = |m: &str| Error::Protocol(format!("spill record: {m}"));
    let mut i = 0usize;
    let take = |i: &mut usize, n: usize| -> Result<std::ops::Range<usize>> {
        let r = *i..*i + n;
        if r.end > b.len() {
            return Err(Error::Protocol("spill record: truncated body".into()));
        }
        *i = r.end;
        Ok(r)
    };
    let key_len = u32::from_le_bytes(b[take(&mut i, 4)?].try_into().unwrap()) as usize;
    if key_len > b.len() {
        return Err(err("key length exceeds body"));
    }
    let key = String::from_utf8(b[take(&mut i, key_len)?].to_vec())
        .map_err(|_| err("key is not utf8"))?;
    let dtype = DType::from_tag(b[take(&mut i, 1)?][0])?;
    let ndim = b[take(&mut i, 1)?][0] as usize;
    if ndim > 16 {
        return Err(err("ndim too large"));
    }
    let mut shape = Vec::with_capacity(ndim);
    for _ in 0..ndim {
        shape.push(u32::from_le_bytes(b[take(&mut i, 4)?].try_into().unwrap()) as usize);
    }
    let payload_len = u64::from_le_bytes(b[take(&mut i, 8)?].try_into().unwrap()) as usize;
    if payload_len > MAX_RECORD_BODY {
        return Err(err("payload too large"));
    }
    let payload = take(&mut i, payload_len)?;
    if i != b.len() {
        return Err(err("trailing bytes after payload"));
    }
    let t = Tensor { dtype, shape, data: body.slice(payload) };
    t.validate()?;
    Ok((key, t))
}

/// Read one record at the reader's current position.  `Ok(None)` on a
/// clean EOF exactly at a record boundary; `Err` on anything torn,
/// corrupted, or oversized — never a panic, hang, or unbounded allocation.
pub fn read_record<R: Read>(r: &mut R) -> Result<Option<(String, Tensor, u64)>> {
    let mut header = [0u8; RECORD_HEADER_LEN as usize];
    let n = read_up_to(r, &mut header)?;
    if n == 0 {
        return Ok(None);
    }
    if n < header.len() {
        return Err(Error::Protocol("spill record: truncated header".into()));
    }
    let magic = u32::from_le_bytes(header[0..4].try_into().unwrap());
    if magic != RECORD_MAGIC {
        return Err(Error::Protocol("spill record: bad magic".into()));
    }
    let body_len = u32::from_le_bytes(header[4..8].try_into().unwrap()) as usize;
    if body_len > MAX_RECORD_BODY {
        return Err(Error::Protocol(format!("spill record: body of {body_len} bytes")));
    }
    let want_crc = u32::from_le_bytes(header[8..12].try_into().unwrap());
    let mut body = vec![0u8; body_len];
    if read_up_to(r, &mut body)? < body_len {
        return Err(Error::Protocol("spill record: truncated body".into()));
    }
    if crc32(&body) != want_crc {
        return Err(Error::Protocol("spill record: crc mismatch".into()));
    }
    let (key, tensor) = decode_body(&Bytes::from_vec(body))?;
    Ok(Some((key, tensor, RECORD_HEADER_LEN + body_len as u64)))
}

/// `read` until `buf` is full or EOF; returns bytes read (EOF mid-buffer is
/// the caller's torn-record signal, not an io error).
fn read_up_to<R: Read>(r: &mut R, buf: &mut [u8]) -> Result<usize> {
    let mut filled = 0;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) => break,
            Ok(n) => filled += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(Error::Io(e)),
        }
    }
    Ok(filled)
}

/// One replayed record and where it lives in its segment.
#[derive(Debug, Clone)]
pub struct SpillRecord {
    pub key: String,
    pub tensor: Tensor,
    /// Byte offset of the record header within its segment file.
    pub offset: u64,
}

/// Result of replaying one segment file.
#[derive(Debug)]
pub struct SegmentReplay {
    /// The valid record prefix, in append order.
    pub records: Vec<SpillRecord>,
    /// Offset just past the last valid record — the crash-recovery
    /// truncation point for the active segment.
    pub valid_end: u64,
    /// Whether bytes beyond `valid_end` existed (torn tail or corruption);
    /// those bytes are unreachable once a record fails to frame.
    pub torn: bool,
}

/// Replay one segment: validate the header, then decode records until the
/// first torn/corrupt one.  Errors only on file-level problems (unreadable
/// file, not a spill segment); in-stream corruption is reported via the
/// `torn` flag with the surviving prefix, never a panic.
pub fn replay_segment(path: &Path) -> Result<SegmentReplay> {
    let file = File::open(path)?;
    let file_len = file.metadata()?.len();
    let mut r = std::io::BufReader::new(file);
    let mut header = [0u8; SEGMENT_HEADER_LEN as usize];
    if read_up_to(&mut r, &mut header)? < header.len() {
        return Err(Error::Protocol(format!(
            "{}: too short to be a spill segment",
            path.display()
        )));
    }
    if header[0..8] != SEGMENT_MAGIC {
        return Err(Error::Protocol(format!("{}: bad segment magic", path.display())));
    }
    let version = u32::from_le_bytes(header[8..12].try_into().unwrap());
    if version != SEGMENT_VERSION {
        return Err(Error::Protocol(format!(
            "{}: unsupported segment version {version}",
            path.display()
        )));
    }
    let mut records = Vec::new();
    let mut valid_end = SEGMENT_HEADER_LEN;
    let mut torn = false;
    loop {
        match read_record(&mut r) {
            Ok(Some((key, tensor, len))) => {
                records.push(SpillRecord { key, tensor, offset: valid_end });
                valid_end += len;
            }
            Ok(None) => break,
            Err(_) => {
                torn = true;
                break;
            }
        }
    }
    Ok(SegmentReplay { records, valid_end, torn: torn || valid_end < file_len })
}

/// Segment files of a group directory, sorted by segment id.
fn list_segments(dir: &Path) -> Result<Vec<(u64, PathBuf)>> {
    let mut segs = Vec::new();
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if let Some(id) = name
            .strip_prefix("seg-")
            .and_then(|s| s.strip_suffix(".spill"))
            .and_then(|s| s.parse::<u64>().ok())
        {
            segs.push((id, entry.path()));
        }
    }
    segs.sort_by_key(|(id, _)| *id);
    Ok(segs)
}

fn segment_path(dir: &Path, id: u64) -> PathBuf {
    dir.join(format!("seg-{id:08}.spill"))
}

/// Sequence number parsed back out of a `seg-NNNNNNNN.spill` file name.
fn segment_seq(path: &Path) -> Option<u64> {
    path.file_name()?
        .to_str()?
        .strip_prefix("seg-")?
        .strip_suffix(".spill")?
        .parse()
        .ok()
}

/// A sealed (no longer appended-to) segment — the unit the cold byte cap
/// deletes, oldest first.
#[derive(Debug, Clone)]
pub struct SealedSegment {
    pub path: Arc<PathBuf>,
    pub bytes: u64,
}

/// Recovery summary from opening a group directory.
#[derive(Debug, Default)]
pub struct GroupRecovery {
    /// Sealed segments found on disk (everything but the active one), in
    /// id order, with their sizes.
    pub sealed: Vec<SealedSegment>,
    /// Valid records replayed across all segments.
    pub replayed_records: u64,
    /// Segments whose tail was torn or corrupted (their invalid suffix was
    /// skipped; the active segment's was truncated away).
    pub torn_segments: u64,
}

/// The per-field (per-group) append handle: owns the active segment file
/// and rotates it at the configured size.
pub struct SpillWriter {
    dir: PathBuf,
    segment_bytes: u64,
    seg_id: u64,
    path: Arc<PathBuf>,
    file: BufWriter<File>,
    /// Bytes in the active segment, header included.
    written: u64,
    scratch: Vec<u8>,
}

impl SpillWriter {
    /// Open (or create) a group directory, replaying every segment in id
    /// order.  Each valid record is handed to `on_record`; the active
    /// (last) segment is truncated back to its last valid record so
    /// appends resume without clobbering survivors.
    pub fn open(
        dir: &Path,
        segment_bytes: u64,
        mut on_record: impl FnMut(&Arc<PathBuf>, SpillRecord),
    ) -> Result<(SpillWriter, GroupRecovery)> {
        std::fs::create_dir_all(dir)?;
        let mut recovery = GroupRecovery::default();
        let segs = list_segments(dir)?;
        let mut active: Option<(u64, Arc<PathBuf>, u64)> = None;
        for (i, (id, path)) in segs.iter().enumerate() {
            let last = i + 1 == segs.len();
            let path = Arc::new(path.clone());
            match replay_segment(&path) {
                Ok(replay) => {
                    recovery.replayed_records += replay.records.len() as u64;
                    if replay.torn {
                        recovery.torn_segments += 1;
                    }
                    for rec in replay.records {
                        on_record(&path, rec);
                    }
                    if last {
                        if replay.torn {
                            // Crash recovery: drop the torn tail so the next
                            // append lands on a record boundary.
                            let f = OpenOptions::new().write(true).open(&*path)?;
                            f.set_len(replay.valid_end)?;
                        }
                        active = Some((*id, path, replay.valid_end));
                    } else {
                        recovery.sealed.push(SealedSegment {
                            bytes: std::fs::metadata(&*path)?.len(),
                            path,
                        });
                    }
                }
                Err(_) => {
                    // Not a decodable segment at all (foreign file, smashed
                    // header).  Never delete data we cannot parse: the file
                    // is quarantined in place — counted as torn, excluded
                    // from the cap's victim queue (so `enforce_cap` can
                    // never remove it) — and appends go elsewhere.
                    recovery.torn_segments += 1;
                    if last {
                        active = None;
                    }
                }
            }
        }
        let writer = match active {
            Some((id, path, end)) => {
                let mut f = OpenOptions::new().write(true).open(&*path)?;
                f.seek(SeekFrom::Start(end))?;
                SpillWriter {
                    dir: dir.to_path_buf(),
                    segment_bytes,
                    seg_id: id,
                    path,
                    file: BufWriter::new(f),
                    written: end,
                    scratch: Vec::new(),
                }
            }
            None => {
                let next_id = segs.last().map(|(id, _)| id + 1).unwrap_or(0);
                Self::create_segment(dir, segment_bytes, next_id)?
            }
        };
        Ok((writer, recovery))
    }

    fn create_segment(dir: &Path, segment_bytes: u64, id: u64) -> Result<SpillWriter> {
        let path = segment_path(dir, id);
        let mut f = BufWriter::new(
            OpenOptions::new().write(true).create(true).truncate(true).open(&path)?,
        );
        f.write_all(&SEGMENT_MAGIC)?;
        f.write_all(&SEGMENT_VERSION.to_le_bytes())?;
        f.write_all(&0u32.to_le_bytes())?;
        Ok(SpillWriter {
            dir: dir.to_path_buf(),
            segment_bytes,
            seg_id: id,
            path: Arc::new(path),
            file: f,
            written: SEGMENT_HEADER_LEN,
            scratch: Vec::new(),
        })
    }

    /// Append one record.  The payload is written straight from the
    /// tensor's shared buffer (no copy); the record lands in the *current*
    /// segment, then the segment rotates if it crossed the size threshold.
    /// Returns the record's location and, when rotation happened, the
    /// segment just sealed.
    pub fn append(&mut self, key: &str, t: &Tensor) -> Result<AppendOutcome> {
        // Refuse records replay would refuse: writing one would poison the
        // segment (replay stops at it, losing every later record).  This
        // check writes nothing, so the segment stays clean.
        if body_len(key, t) > MAX_RECORD_BODY {
            return Err(Error::Invalid(format!(
                "spill record for '{key}' exceeds the {MAX_RECORD_BODY}-byte body cap"
            )));
        }
        self.scratch.clear();
        encode_body_head(&mut self.scratch, key, t);
        let body = self.scratch.len() + t.data.len();
        let mut crc = Crc32::new();
        crc.update(&self.scratch);
        crc.update(&t.data);
        let mut header = [0u8; RECORD_HEADER_LEN as usize];
        header[0..4].copy_from_slice(&RECORD_MAGIC.to_le_bytes());
        header[4..8].copy_from_slice(&(body as u32).to_le_bytes());
        header[8..12].copy_from_slice(&crc.finish().to_le_bytes());
        let offset = self.written;
        self.file.write_all(&header)?;
        self.file.write_all(&self.scratch)?;
        self.file.write_all(&t.data)?;
        let record_bytes = RECORD_HEADER_LEN + body as u64;
        self.written += record_bytes;
        let mut outcome = AppendOutcome {
            path: Arc::clone(&self.path),
            offset,
            record_bytes,
            sealed: None,
        };
        if self.written >= self.segment_bytes {
            outcome.sealed = Some(self.rotate()?);
        }
        Ok(outcome)
    }

    /// Seal the active segment and open the next one.
    fn rotate(&mut self) -> Result<SealedSegment> {
        self.file.flush()?;
        let sealed = SealedSegment { path: Arc::clone(&self.path), bytes: self.written };
        let next = Self::create_segment(&self.dir, self.segment_bytes, self.seg_id + 1)?;
        *self = next;
        Ok(sealed)
    }

    /// Abandon the active segment after a *failed* append: the file may
    /// hold a partial record at its tail and this writer's offset no
    /// longer matches the file, so appending further would corrupt the
    /// framing of everything behind the tear.  Seal the segment as-is
    /// (replay stops cleanly at the partial record) and continue on a
    /// fresh one.
    pub fn abandon_segment(&mut self) -> Result<SealedSegment> {
        let _ = self.file.flush(); // best effort; the tail is already torn
        let sealed = SealedSegment { path: Arc::clone(&self.path), bytes: self.written };
        let next = Self::create_segment(&self.dir, self.segment_bytes, self.seg_id + 1)?;
        *self = next;
        Ok(sealed)
    }

    /// Flush buffered records to the OS so readers see them.
    pub fn flush(&mut self) -> Result<()> {
        self.file.flush().map_err(Error::Io)
    }

    /// Path of the active segment.
    pub fn active_segment(&self) -> &Arc<PathBuf> {
        &self.path
    }

    /// Bytes in the active segment (header included).
    pub fn active_bytes(&self) -> u64 {
        self.written
    }
}

/// Where an [`SpillWriter::append`] landed.
#[derive(Debug)]
pub struct AppendOutcome {
    pub path: Arc<PathBuf>,
    pub offset: u64,
    pub record_bytes: u64,
    /// Set when this append pushed the segment over its threshold.
    pub sealed: Option<SealedSegment>,
}

// --- shared (reader-visible) state -------------------------------------------

/// Lifetime counters of one store's cold tier (exposed via `INFO`).
#[derive(Debug, Default)]
pub struct SpillStats {
    /// Records appended to the log.
    pub spilled_keys: AtomicU64,
    /// Tensor payload bytes appended.
    pub spilled_bytes: AtomicU64,
    /// Segment files currently on disk.
    pub segments: AtomicU64,
    /// Cold reads served (`ColdGet` hits).
    pub cold_hits: AtomicU64,
    /// Segments found torn or corrupted at replay (their invalid suffix
    /// was skipped; the active segment's was truncated away).
    pub torn_segments: AtomicU64,
    /// Sealed segments deleted by the cold byte cap.
    pub dropped_segments: AtomicU64,
    /// Appends that failed with an I/O error (the victim is gone from both
    /// tiers; surfaced so operators notice a sick disk).
    pub write_errors: AtomicU64,
    /// Victims dropped because the in-flight spill queue exceeded its byte
    /// budget (the writer thread fell behind the eviction rate) — the tier
    /// degrades by shedding history instead of pinning evicted payloads in
    /// memory and defeating the store's byte cap.
    pub backlog_dropped: AtomicU64,
}

#[derive(Clone)]
struct ColdLoc {
    path: Arc<PathBuf>,
    offset: u64,
}

#[derive(Default)]
struct ColdIndex {
    /// Newest cold record per key.
    locs: HashMap<String, ColdLoc>,
    /// Per-group (field) spill counters, merged into `FieldPressure`.
    groups: HashMap<String, (u64, u64)>,
}

/// State shared between the writer thread and readers: the cold index and
/// the stats counters.
pub struct SpillShared {
    pub stats: SpillStats,
    index: Mutex<ColdIndex>,
    /// Records enqueued since the last completed barrier (see
    /// [`SpillShared::mark_dirty`]).
    dirty: std::sync::atomic::AtomicBool,
    /// Serializes barriers so a clean dirty check can never short-circuit
    /// past another reader's in-flight sync.
    sync_lock: Mutex<()>,
    /// Payload bytes currently queued to the writer thread, and the budget
    /// they may not exceed (see [`SpillShared::try_reserve_pending`]).
    pending_bytes: AtomicU64,
    pending_cap: u64,
}

impl SpillShared {
    fn new() -> SpillShared {
        SpillShared {
            stats: SpillStats::default(),
            index: Mutex::new(ColdIndex::default()),
            dirty: std::sync::atomic::AtomicBool::new(false),
            sync_lock: Mutex::new(()),
            pending_bytes: AtomicU64::new(0),
            pending_cap: default_pending_bytes(),
        }
    }

    /// Keys resident in the cold tier with the given prefix, sorted.
    pub fn list(&self, prefix: &str) -> Vec<String> {
        let idx = self.index.lock().unwrap();
        let mut out: Vec<String> =
            idx.locs.keys().filter(|k| k.starts_with(prefix)).cloned().collect();
        out.sort();
        out
    }

    /// Read a key's newest cold record back, verifying its checksum.
    ///
    /// Any failure to produce the record — segment deleted by the cold
    /// byte cap between the index lookup and the open (the cap purges the
    /// index, but this reader may hold a pre-purge location), torn or
    /// corrupt bytes at the offset — degrades to a clean `KeyNotFound`
    /// *miss*, never a hard error: callers' fallback semantics (skip the
    /// generation) must keep working under a live cap.
    pub fn read(&self, key: &str) -> Result<Tensor> {
        let loc = {
            let idx = self.index.lock().unwrap();
            idx.locs.get(key).cloned()
        }
        .ok_or_else(|| Error::KeyNotFound(key.to_string()))?;
        match read_at(&loc, key) {
            Ok(tensor) => {
                self.stats.cold_hits.fetch_add(1, Ordering::Relaxed);
                Ok(tensor)
            }
            Err(_) => Err(Error::KeyNotFound(key.to_string())),
        }
    }

    /// Per-field spill counters `(field, spilled_keys, spilled_bytes)`,
    /// sorted by field name.
    pub fn field_counters(&self) -> Vec<(String, u64, u64)> {
        let idx = self.index.lock().unwrap();
        let mut out: Vec<(String, u64, u64)> =
            idx.groups.iter().map(|(g, (k, b))| (g.clone(), *k, *b)).collect();
        out.sort();
        out
    }

    fn record_append(&self, group: &str, key: &str, payload_bytes: u64, loc: ColdLoc) {
        let mut idx = self.index.lock().unwrap();
        idx.locs.insert(key.to_string(), loc);
        let g = idx.groups.entry(group.to_string()).or_default();
        g.0 += 1;
        g.1 += payload_bytes;
        self.stats.spilled_keys.fetch_add(1, Ordering::Relaxed);
        self.stats.spilled_bytes.fetch_add(payload_bytes, Ordering::Relaxed);
    }

    /// Drop every index entry living in `path` (the segment was deleted).
    fn purge_segment(&self, path: &Arc<PathBuf>) {
        let mut idx = self.index.lock().unwrap();
        idx.locs.retain(|_, loc| !Arc::ptr_eq(&loc.path, path));
    }

    /// Flag raised by the store when it enqueues a record, cleared by a
    /// completed barrier — lets back-to-back cold reads skip the writer
    /// round trip when nothing changed since the last sync.
    pub(crate) fn mark_dirty(&self) {
        self.dirty.store(true, Ordering::SeqCst);
    }

    /// Read-side barrier: when records were enqueued since the last
    /// completed barrier, send a sync marker and wait for the writer
    /// thread to flush everything ahead of it.  Barriers serialize on
    /// `sync_lock`, so a caller that observes a clean flag is guaranteed
    /// the last dirtying record is already durable (it can never
    /// short-circuit past a sync still in flight on another thread);
    /// clean back-to-back cold reads skip the round trip entirely.
    pub(crate) fn barrier(&self, tx: &mpsc::Sender<SpillMsg>) {
        let _serialize = self.sync_lock.lock().unwrap();
        if !self.dirty.swap(false, Ordering::SeqCst) {
            return;
        }
        let (ack_tx, ack_rx) = mpsc::sync_channel(1);
        if tx.send(SpillMsg::Sync(ack_tx)).is_ok() {
            let _ = ack_rx.recv();
        }
    }

    /// Reserve queue budget for one victim's payload before sending it to
    /// the writer thread.  `false` means the writer has fallen behind its
    /// byte budget and the victim must be dropped (counted in
    /// `backlog_dropped`) — an unbounded queue would pin evicted payloads
    /// in memory and defeat the store's byte cap.  A victim arriving at an
    /// empty queue is always admitted, however large.
    pub(crate) fn try_reserve_pending(&self, bytes: u64) -> bool {
        if self.pending_cap == 0 {
            return true;
        }
        let prev = self.pending_bytes.fetch_add(bytes, Ordering::SeqCst);
        if prev > 0 && prev + bytes > self.pending_cap {
            self.pending_bytes.fetch_sub(bytes, Ordering::SeqCst);
            self.stats.backlog_dropped.fetch_add(1, Ordering::Relaxed);
            return false;
        }
        true
    }

    /// Release queue budget once the writer has processed a record.
    pub(crate) fn release_pending(&self, bytes: u64) {
        self.pending_bytes.fetch_sub(bytes, Ordering::SeqCst);
    }
}

/// Open `loc` and decode the record there, verifying key and checksum.
fn read_at(loc: &ColdLoc, key: &str) -> Result<Tensor> {
    let mut f = File::open(&*loc.path)?;
    f.seek(SeekFrom::Start(loc.offset))?;
    match read_record(&mut f)? {
        Some((got_key, tensor, _)) if got_key == key => Ok(tensor),
        Some((got_key, _, _)) => Err(Error::Protocol(format!(
            "cold index desync: wanted '{key}', segment holds '{got_key}'"
        ))),
        None => Err(Error::Protocol(format!("cold record for '{key}' vanished"))),
    }
}

// --- the tier: writer thread + backend ---------------------------------------

/// Messages from the store's eviction paths to the writer thread.
pub(crate) enum SpillMsg {
    /// Persist one retired tensor (payload shared by refcount, no copy).
    Record { key: String, tensor: Tensor },
    /// Flush every group's buffered writes, then ack — the read-side
    /// barrier behind `Store::spill_sync`.
    Sync(mpsc::SyncSender<()>),
}

/// Group a key spills under: its step-key field, or the untracked
/// catch-all.  One group == one directory == one [`SpillWriter`].
pub fn spill_group(key: &str) -> &str {
    match crate::db::store::parse_step_key(key) {
        Some((field, _)) => field,
        None => "__untracked",
    }
}

/// Filesystem-safe encoding of a group name: lowercase alphanumerics,
/// `_`, `-` and (non-leading) `.` pass through, everything else —
/// including uppercase letters — percent-encodes with lowercase hex.  The
/// image contains no uppercase at all, so the mapping stays injective
/// even on case-insensitive filesystems (macOS/Windows): two distinct
/// fields can never share a directory.
fn encode_group_dir(group: &str) -> String {
    let mut out = String::with_capacity(group.len());
    for b in group.bytes() {
        match b {
            b'a'..=b'z' | b'0'..=b'9' | b'_' | b'-' => out.push(b as char),
            b'.' if !out.is_empty() => out.push('.'),
            _ => out.push_str(&format!("%{b:02x}")),
        }
    }
    if out.is_empty() {
        out.push_str("%00empty");
    }
    out
}

struct Backend {
    cfg: SpillConfig,
    shared: Arc<SpillShared>,
    writers: HashMap<String, SpillWriter>,
    /// Sealed segments in creation order — the cold cap's victim queue.
    sealed: VecDeque<SealedSegment>,
    /// Bytes on disk across all segments, sealed and active.
    total_bytes: u64,
}

impl Backend {
    /// Open the tier: scan every group directory, rebuild the cold index,
    /// and recover torn tails.
    fn open(cfg: SpillConfig) -> Result<(Backend, Arc<SpillShared>)> {
        std::fs::create_dir_all(&cfg.dir)?;
        let shared = Arc::new(SpillShared::new());
        let mut backend = Backend {
            writers: HashMap::new(),
            sealed: VecDeque::new(),
            total_bytes: 0,
            shared: Arc::clone(&shared),
            cfg,
        };
        let mut group_dirs: Vec<PathBuf> = Vec::new();
        for entry in std::fs::read_dir(&backend.cfg.dir)? {
            let entry = entry?;
            if entry.file_type()?.is_dir() {
                group_dirs.push(entry.path());
            }
        }
        group_dirs.sort();
        for dir in group_dirs {
            let (writer, recovery) = {
                let shared = &shared;
                SpillWriter::open(&dir, backend.cfg.segment_bytes, |path, rec| {
                    shared.record_append(
                        spill_group(&rec.key),
                        &rec.key,
                        rec.tensor.nbytes() as u64,
                        ColdLoc { path: Arc::clone(path), offset: rec.offset },
                    );
                })?
            };
            backend.total_bytes += writer.active_bytes();
            for s in &recovery.sealed {
                backend.total_bytes += s.bytes;
            }
            shared
                .stats
                .segments
                .fetch_add(1 + recovery.sealed.len() as u64, Ordering::Relaxed);
            shared
                .stats
                .torn_segments
                .fetch_add(recovery.torn_segments, Ordering::Relaxed);
            backend.sealed.extend(recovery.sealed);
            // Writers are keyed by (encoded) directory name; replay
            // re-registered the resident records under their record keys.
            let dir_name = dir
                .file_name()
                .map(|n| n.to_string_lossy().into_owned())
                .unwrap_or_default();
            backend.writers.insert(dir_name, writer);
        }
        Self::sort_sealed_by_age(&mut backend.sealed);
        // Enforce the cap against what restart found on disk: the tier may
        // be over budget because the cap was lowered or data accumulated
        // under a previous config, and waiting for the next rotation could
        // leave it over budget indefinitely.
        backend.enforce_cap();
        Ok((backend, shared))
    }

    fn writer_for(&mut self, group: &str) -> Result<&mut SpillWriter> {
        use std::collections::hash_map::Entry;
        match self.writers.entry(encode_group_dir(group)) {
            Entry::Occupied(e) => Ok(e.into_mut()),
            Entry::Vacant(e) => {
                let dir = self.cfg.dir.join(e.key());
                let shared = Arc::clone(&self.shared);
                let (writer, recovery) =
                    SpillWriter::open(&dir, self.cfg.segment_bytes, |path, rec| {
                        shared.record_append(
                            spill_group(&rec.key),
                            &rec.key,
                            rec.tensor.nbytes() as u64,
                            ColdLoc { path: Arc::clone(path), offset: rec.offset },
                        );
                    })?;
                self.total_bytes += writer.active_bytes();
                for s in &recovery.sealed {
                    self.total_bytes += s.bytes;
                }
                self.shared
                    .stats
                    .segments
                    .fetch_add(1 + recovery.sealed.len() as u64, Ordering::Relaxed);
                if !recovery.sealed.is_empty() {
                    // A lazily-opened group can bring recovered (old)
                    // sealed segments; merge them by age so the cap's
                    // victim order stays oldest-first.
                    self.sealed.extend(recovery.sealed);
                    Self::sort_sealed_by_age(&mut self.sealed);
                }
                Ok(e.insert(writer))
            }
        }
    }

    fn append(&mut self, key: &str, tensor: &Tensor) -> Result<()> {
        let group = spill_group(key).to_string();
        let outcome = match self.writer_for(&group)?.append(key, tensor) {
            Ok(o) => o,
            // `Invalid` is the writer's size-cap rejection, raised before
            // any byte is written — the segment is still clean.
            Err(e @ Error::Invalid(_)) => return Err(e),
            Err(e) => {
                // An I/O failure may leave a partial record at the tail
                // and a writer whose offset no longer matches the file;
                // sticking with it would silently corrupt every later
                // record.  Abandon the segment (replay stops cleanly at
                // the tear) and continue on a fresh one.
                self.abandon_active_segment(&encode_group_dir(&group));
                return Err(e);
            }
        };
        self.total_bytes += outcome.record_bytes;
        self.shared.record_append(
            &group,
            key,
            tensor.nbytes() as u64,
            ColdLoc { path: Arc::clone(&outcome.path), offset: outcome.offset },
        );
        if let Some(sealed) = outcome.sealed {
            self.shared.stats.segments.fetch_add(1, Ordering::Relaxed);
            self.sealed.push_back(sealed);
        }
        // Unconditional (cheap when under cap): also covers sealed
        // segments a lazily-opened group just recovered from disk.
        self.enforce_cap();
        Ok(())
    }

    /// Seal a group's torn active segment after a failed append and move
    /// on to a fresh one; if even creating the replacement fails, drop the
    /// writer so the next append re-runs group recovery (re-registering
    /// its sealed segments is tolerable double accounting on a disk that
    /// is already failing).
    fn abandon_active_segment(&mut self, dir_name: &str) {
        let Some(w) = self.writers.get_mut(dir_name) else { return };
        match w.abandon_segment() {
            Ok(sealed) => {
                self.shared.stats.segments.fetch_add(1, Ordering::Relaxed);
                self.sealed.push_back(sealed);
            }
            Err(_) => {
                self.writers.remove(dir_name);
            }
        }
    }

    /// Best-effort age ordering for the cap's victim queue across
    /// restarts.  Segments are append-only, so a sealed file's mtime is
    /// its seal time — but mtime is coarse (whole seconds on many
    /// filesystems), and a group that rotates tiny segments quickly seals
    /// several inside one tick, leaving their relative order to the
    /// directory listing.  The sequence number in the `seg-NNNNNNNN.spill`
    /// name breaks those ties: within a group it *is* seal order, so the
    /// sort key is (mtime, sequence), with unparseable names sorting after
    /// their same-tick peers.  Without any of this, recovered groups would
    /// queue in directory-name order and the cap could delete a field's
    /// *newest* history before another field's oldest.
    fn sort_sealed_by_age(sealed: &mut VecDeque<SealedSegment>) {
        let mut v: Vec<SealedSegment> = sealed.drain(..).collect();
        v.sort_by_cached_key(|s| {
            let mtime = std::fs::metadata(&*s.path)
                .and_then(|m| m.modified())
                .unwrap_or(std::time::SystemTime::UNIX_EPOCH);
            (mtime, segment_seq(&s.path).unwrap_or(u64::MAX))
        });
        sealed.extend(v);
    }

    /// Delete oldest sealed segments until the tier fits its byte cap.
    fn enforce_cap(&mut self) {
        if self.cfg.max_bytes == 0 {
            return;
        }
        while self.total_bytes > self.cfg.max_bytes {
            let Some(victim) = self.sealed.pop_front() else { break };
            self.shared.purge_segment(&victim.path);
            let _ = std::fs::remove_file(&*victim.path);
            self.total_bytes = self.total_bytes.saturating_sub(victim.bytes);
            self.shared.stats.segments.fetch_sub(1, Ordering::Relaxed);
            self.shared.stats.dropped_segments.fetch_add(1, Ordering::Relaxed);
        }
    }

    fn flush(&mut self) {
        for w in self.writers.values_mut() {
            if w.flush().is_err() {
                self.shared.stats.write_errors.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
}

/// Open the tier and start its writer thread.  Returns the channel the
/// store's eviction paths feed, the shared read-side state, and the thread
/// handle (joined by `Store::set_spill`).
pub(crate) fn spawn(
    cfg: SpillConfig,
) -> Result<(mpsc::Sender<SpillMsg>, Arc<SpillShared>, JoinHandle<()>)> {
    let (mut backend, shared) = Backend::open(cfg)?;
    let (tx, rx) = mpsc::channel::<SpillMsg>();
    let handle = std::thread::Builder::new()
        .name("db-spill".into())
        .spawn(move || {
            while let Ok(msg) = rx.recv() {
                match msg {
                    SpillMsg::Record { key, tensor } => {
                        let nbytes = tensor.nbytes() as u64;
                        if backend.append(&key, &tensor).is_err() {
                            backend
                                .shared
                                .stats
                                .write_errors
                                .fetch_add(1, Ordering::Relaxed);
                        }
                        backend.shared.release_pending(nbytes);
                    }
                    SpillMsg::Sync(ack) => {
                        backend.flush();
                        let _ = ack.send(());
                    }
                }
            }
            // Channel closed (tier disabled or store dropped): leave a
            // clean, fully-flushed log behind.
            backend.flush();
        })
        .map_err(Error::Io)?;
    Ok((tx, shared, handle))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("situ_spill_{}_{name}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn t(vals: Vec<f32>) -> Tensor {
        Tensor::from_f32(&[vals.len()], vals).unwrap()
    }

    #[test]
    fn crc32_known_vectors() {
        // The IEEE check value: CRC32("123456789") == 0xCBF43926.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        // Streaming in parts equals one-shot.
        let mut c = Crc32::new();
        c.update(b"1234");
        c.update(b"56789");
        assert_eq!(c.finish(), 0xCBF4_3926);
    }

    #[test]
    fn append_replay_roundtrip_byte_exact() {
        let dir = tmp_dir("roundtrip");
        let (mut w, rec) = SpillWriter::open(&dir, 1 << 20, |_, _| {}).unwrap();
        assert_eq!(rec.replayed_records, 0);
        let tensors: Vec<Tensor> =
            (0..5).map(|i| t(vec![i as f32; 8 + i as usize])).collect();
        for (i, tensor) in tensors.iter().enumerate() {
            w.append(&format!("f_rank0_step{i}"), tensor).unwrap();
        }
        w.flush().unwrap();
        let replay = replay_segment(w.active_segment()).unwrap();
        assert!(!replay.torn);
        assert_eq!(replay.records.len(), 5);
        for (i, rec) in replay.records.iter().enumerate() {
            assert_eq!(rec.key, format!("f_rank0_step{i}"));
            assert_eq!(rec.tensor, tensors[i], "byte-exact payload");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn truncated_tail_is_recovered_and_appends_resume() {
        let dir = tmp_dir("recover");
        let path = {
            let (mut w, _) = SpillWriter::open(&dir, 1 << 20, |_, _| {}).unwrap();
            for i in 0..3 {
                w.append(&format!("f_rank0_step{i}"), &t(vec![i as f32; 16])).unwrap();
            }
            w.flush().unwrap();
            (**w.active_segment()).clone()
        };
        // Simulate a crash mid-append: chop bytes off the last record.
        let len = std::fs::metadata(&path).unwrap().len();
        OpenOptions::new().write(true).open(&path).unwrap().set_len(len - 7).unwrap();

        let mut replayed = Vec::new();
        let (mut w, rec) =
            SpillWriter::open(&dir, 1 << 20, |_, r| replayed.push(r.key)).unwrap();
        assert_eq!(rec.torn_segments, 1);
        assert_eq!(replayed, vec!["f_rank0_step0", "f_rank0_step1"], "valid prefix only");
        // Appends resume on a clean boundary without clobbering survivors.
        w.append("f_rank0_step3", &t(vec![9.0; 4])).unwrap();
        w.flush().unwrap();
        let replay = replay_segment(&path).unwrap();
        assert!(!replay.torn, "truncation healed the segment");
        let keys: Vec<&str> = replay.records.iter().map(|r| r.key.as_str()).collect();
        assert_eq!(keys, vec!["f_rank0_step0", "f_rank0_step1", "f_rank0_step3"]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn segments_rotate_and_replay_in_order() {
        let dir = tmp_dir("rotate");
        // Tiny threshold: every record rotates.
        let (mut w, _) = SpillWriter::open(&dir, 64, |_, _| {}).unwrap();
        let mut sealed = 0;
        for i in 0..4 {
            let out = w.append(&format!("g_rank0_step{i}"), &t(vec![i as f32; 16])).unwrap();
            if out.sealed.is_some() {
                sealed += 1;
            }
        }
        w.flush().unwrap();
        assert_eq!(sealed, 4, "each oversized record seals its segment");
        let segs = list_segments(&dir).unwrap();
        assert_eq!(segs.len(), 5, "four sealed + one empty active");
        let mut all = Vec::new();
        for (_, p) in &segs {
            all.extend(replay_segment(p).unwrap().records);
        }
        assert_eq!(all.len(), 4);
        for (i, rec) in all.iter().enumerate() {
            assert_eq!(rec.key, format!("g_rank0_step{i}"), "ordered across segments");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn reopened_writer_continues_segment_numbering() {
        let dir = tmp_dir("renumber");
        {
            let (mut w, _) = SpillWriter::open(&dir, 64, |_, _| {}).unwrap();
            w.append("k_rank0_step0", &t(vec![1.0; 16])).unwrap();
            w.flush().unwrap();
        }
        let (w, rec) = SpillWriter::open(&dir, 64, |_, _| {}).unwrap();
        assert_eq!(rec.replayed_records, 1);
        assert_eq!(rec.sealed.len(), 1);
        assert!(w
            .active_segment()
            .to_string_lossy()
            .ends_with("seg-00000001.spill"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn group_dir_encoding_is_injective_and_safe() {
        assert_eq!(encode_group_dir("velocity_x"), "velocity_x");
        assert_eq!(encode_group_dir("a/b"), "a%2fb");
        assert_eq!(encode_group_dir("a%b"), "a%25b");
        assert_ne!(encode_group_dir("a%2fb"), encode_group_dir("a/b"));
        assert_eq!(encode_group_dir(""), "%00empty");
        assert_eq!(encode_group_dir(".."), "%2e.", "no path traversal");
        // Uppercase escapes, so the image is case-canonical and the
        // mapping stays injective on case-insensitive filesystems.
        assert_eq!(encode_group_dir("Temp"), "%54emp");
        assert_ne!(
            encode_group_dir("Temp").to_lowercase(),
            encode_group_dir("temp").to_lowercase(),
            "no collision even after case folding"
        );
    }

    #[test]
    fn foreign_file_in_group_dir_is_a_clean_error() {
        let dir = tmp_dir("foreign");
        std::fs::write(dir.join("seg-00000000.spill"), b"not a segment at all").unwrap();
        assert!(replay_segment(&dir.join("seg-00000000.spill")).is_err());
        // The writer survives it: the unparseable file is sealed aside and
        // appends go to a fresh segment.
        let (mut w, rec) = SpillWriter::open(&dir, 1 << 20, |_, _| {}).unwrap();
        assert_eq!(rec.torn_segments, 1);
        w.append("x_rank0_step0", &t(vec![1.0])).unwrap();
        w.flush().unwrap();
        assert_eq!(replay_segment(w.active_segment()).unwrap().records.len(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Pin every segment file's mtime to one instant, simulating segments
    /// sealed faster than the filesystem's (often 1 s) mtime resolution.
    fn equalize_mtimes(dir: &Path) {
        let when =
            std::time::SystemTime::UNIX_EPOCH + std::time::Duration::from_secs(1_700_000_000);
        for (_, p) in list_segments(dir).unwrap() {
            std::fs::File::options()
                .write(true)
                .open(&p)
                .and_then(|f| f.set_modified(when))
                .unwrap();
        }
    }

    #[test]
    fn sealed_age_order_survives_coarse_mtime_ties() {
        let dir = tmp_dir("mtime_ties");
        let (mut w, _) = SpillWriter::open(&dir, 64, |_, _| {}).unwrap();
        let mut sealed: Vec<SealedSegment> = Vec::new();
        for i in 0..6 {
            if let Some(s) = w.append(&format!("f_rank0_step{i}"), &t(vec![0.0; 16])).unwrap().sealed
            {
                sealed.push(s);
            }
        }
        w.flush().unwrap();
        drop(w);
        assert_eq!(sealed.len(), 6);
        equalize_mtimes(&dir);
        // Regression: with identical mtimes the old sort had no signal at
        // all, so any scrambled recovery order survived and the cap could
        // drop the newest history first.
        let mut q: VecDeque<SealedSegment> = VecDeque::new();
        for &i in &[3usize, 0, 5, 1, 4, 2] {
            q.push_back(sealed[i].clone());
        }
        Backend::sort_sealed_by_age(&mut q);
        let order: Vec<u64> = q.iter().map(|s| segment_seq(&s.path).unwrap()).collect();
        assert_eq!(order, vec![0, 1, 2, 3, 4, 5], "sequence number breaks mtime ties");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn restart_cap_drops_oldest_segments_first_under_fast_rotation() {
        let dir = tmp_dir("cap_oldest");
        let group = dir.join("field");
        {
            // Rotate six tiny segments back-to-back — all sealed within
            // one mtime tick on filesystems with coarse timestamps.
            let (mut w, _) = SpillWriter::open(&group, 64, |_, _| {}).unwrap();
            for i in 0..6 {
                w.append(&format!("field_rank0_step{i}"), &t(vec![i as f32; 16])).unwrap();
            }
            w.flush().unwrap();
        }
        equalize_mtimes(&group);
        let seg_bytes = std::fs::metadata(group.join("seg-00000000.spill")).unwrap().len();
        // Budget for roughly three sealed segments (plus the empty active
        // one): restart must delete the *oldest* three to fit.
        let (backend, shared) = Backend::open(SpillConfig {
            dir: dir.clone(),
            max_bytes: seg_bytes * 3 + seg_bytes / 2,
            segment_bytes: 64,
        })
        .unwrap();
        assert!(
            shared.stats.dropped_segments.load(Ordering::Relaxed) >= 3,
            "restart cap enforcement ran"
        );
        let survivors: Vec<u64> =
            list_segments(&group).unwrap().into_iter().map(|(id, _)| id).collect();
        for old in 0..3 {
            assert!(!survivors.contains(&old), "seg {old} (oldest) must be a victim");
        }
        assert!(
            survivors.contains(&5),
            "the newest sealed segment must survive, got {survivors:?}"
        );
        drop(backend);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
