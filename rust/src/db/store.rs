//! Sharded in-memory key-value store holding tensors and metadata.
//!
//! Keys hash to one of `N_SHARDS` independently-locked shards, so concurrent
//! clients (one per simulation rank) rarely contend — the property the paper
//! relies on for "low-latency access to many clients in parallel".
//!
//! # Capacity governance and retention
//!
//! Keeping training data in memory makes memory the binding constraint for
//! long-running simulations; the paper resolves it by retiring snapshots
//! rather than appending forever (§2, §4 — the same moving-window discipline
//! the SmartSim ocean-modeling and OpenFOAM couplings use).  The store
//! implements that as an optional [`RetentionConfig`]:
//!
//! * **Sliding window** — tensor keys following the framework scheme
//!   `{field}_rank{r}_step{s}` are grouped into *generations* (one per
//!   `(field, step)`).  With `window = W > 0`, once a field accumulates more
//!   than `W` generations the oldest is retired on the spot, so steady-state
//!   footprint is `W` generations per field regardless of run length.
//! * **Byte cap** — with `max_bytes > 0` a write that would exceed the cap
//!   first evicts TTL-expired generations, then the oldest generations
//!   *outside* every field's protected window, then falls back to
//!   least-recently-used eviction of untracked keys (keys that don't parse
//!   as step keys, e.g. the overwrite-mode `{field}_rank{r}_latest`
//!   scheme).  If nothing evictable remains the write is rejected with
//!   [`Error::Busy`] — explicit producer backpressure instead of OOM.
//! * **Wall-clock TTL** — with `ttl_ms > 0` a generation (or untracked key)
//!   untouched for that long is retired even if it sits inside its field's
//!   window.  This covers producers that stall mid-run and never advance
//!   the window: their stale snapshots age out instead of pinning memory
//!   forever.  Expiry runs on generation boundaries of the owning index
//!   shard, during byte-cap eviction (expired data is the first victim),
//!   and on demand via [`Store::expire_ttl`] — which the server's
//!   timer-driven background sweeper calls periodically whenever a TTL
//!   policy is active (plus opportunistically on `INFO`), so stalled
//!   producers are reclaimed on wall-clock time, not only when traffic
//!   happens to cross a generation boundary.
//!
//! Metadata entries are not byte-accounted (they are tiny strings) and are
//! never evicted.  All limits default to 0 (= the seed's unbounded append
//! behavior), and the governed bookkeeping is only engaged when a policy is
//! set: ungoverned puts take exactly the old lock-per-shard fast path.
//!
//! # Index sharding and lock order
//!
//! The retention index is sharded by *field* (by whole key for untracked
//! keys) across `N_INDEX_SHARDS` independently-locked shards, so governed
//! puts to distinct fields proceed in parallel — the same sharded-lock
//! discipline as the data plane, replacing the single index mutex that used
//! to re-serialize every governed operation.  A put takes exactly one index
//! shard lock, held for O(1) bookkeeping; window retirement and TTL expiry
//! only run on generation boundaries (a put that opens a new generation).
//! Byte-cap pressure is handled with an atomic byte *reservation*
//! ([`Store::try_reserve`]): a put that fits under the cap never takes any
//! global lock, and only puts that must evict serialize on their field's
//! **eviction gate** — one gate per index shard (`evict_gates`), so two
//! saturated fields shed load concurrently instead of queueing on a single
//! global gate (other fields' non-evicting puts keep flowing either way).
//! Two evictors may race toward the same victim; eviction is idempotent
//! (a generation already gone is skipped and the loop re-reserves), so the
//! only cost of the race is a retry, never double-accounting.
//!
//! Lock order (outer → inner): eviction gate(s) → one index shard mutex →
//! data shard mutexes.  An evictor holds exactly *one* gate (its key's) and
//! locks index shards one at a time while scanning; policy changes
//! (`set_retention`, `flush_all`) take **all** gates in index order, which
//! excludes every evictor without a cycle (evictors never take a second
//! gate).  Every other path holds at most one index shard lock and only
//! acquires data shard locks under it, so the ordering is acyclic and
//! eviction can never deadlock against writes.
//!
//! Concurrency caveat (documented, deliberate): the byte cap is enforced
//! per reservation against the key's indexed size, with the replaced
//! payload uncharged at reservation time and reconciled at insert — so the
//! byte counter (and the high-water mark sampled from it) never exceeds
//! the cap.  During an in-flight overwrite the counter briefly excludes
//! the not-yet-replaced buffer; two racing writers of the *same* key can
//! widen that window, but the framework's key schemes give every key a
//! single writer, and accounting reconverges to exact either way.
//!
//! # Spill-to-disk cold tier
//!
//! With [`Store::set_spill`] configured, every tensor the retention
//! pipeline retires — window retirement, byte-cap eviction (generations
//! *and* LRU untracked keys), and TTL expiry — is handed to the
//! [`crate::db::spill`] writer thread instead of vanishing: the eviction
//! path sends the removed tensor (a refcount bump on its shared payload,
//! no copy, no disk I/O inline) over a channel, and the spill thread
//! appends it to a CRC-checksummed segment log.  Retired data stays
//! readable through [`Store::cold_get`]/[`Store::cold_list`] (the wire's
//! `ColdGet`/`ColdList`).  Explicit deletes (`del`/`del_keys`) and
//! `flush_all` do *not* spill — only the retention pipeline's victims do.
//!
//! The spill handle's mutex is a leaf in the lock order (eviction gate →
//! index shard → data shard → spill handle): it is only ever taken to
//! clone the channel sender / shared state, never while calling back into
//! the store.

use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

use crate::db::cluster::{hash_slot, SlotEpoch};
use crate::db::spill::{self, SpillConfig, SpillMsg, SpillShared};
use crate::error::{Error, Result};
use crate::proto::message::FieldPressure;
use crate::tensor::Tensor;

const N_SHARDS: usize = 16;
/// Retention index shards (fields hash here; see module docs).
const N_INDEX_SHARDS: usize = 16;

#[derive(Default)]
struct Shard {
    tensors: HashMap<String, Tensor>,
    metas: HashMap<String, String>,
}

/// Retention / capacity policy for one store instance.  `0` disables a
/// limit; the default is fully unbounded (the seed behavior).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct RetentionConfig {
    /// Newest step generations kept per field.  When a field accumulates
    /// more than `window` generations the oldest is retired immediately.
    /// `0` disables the window; under a byte cap only the newest generation
    /// of each field is then protected from eviction.
    pub window: u64,
    /// Byte capacity for tensor payloads.  A write that cannot fit even
    /// after eviction fails with [`Error::Busy`].  `0` = unbounded.
    pub max_bytes: u64,
    /// Wall-clock time-to-live in milliseconds for generations and
    /// untracked keys whose producer has stalled (no writes).  `0` = never
    /// expire.  Expired data is retired even inside the window.
    pub ttl_ms: u64,
}

impl RetentionConfig {
    pub const UNBOUNDED: RetentionConfig =
        RetentionConfig { window: 0, max_bytes: 0, ttl_ms: 0 };

    /// The common window + byte-cap policy (no TTL).
    pub fn windowed(window: u64, max_bytes: u64) -> RetentionConfig {
        RetentionConfig { window, max_bytes, ttl_ms: 0 }
    }

    pub fn is_unbounded(&self) -> bool {
        self.window == 0 && self.max_bytes == 0 && self.ttl_ms == 0
    }

    /// The TTL as a `Duration`, `None` when disabled.  Public so the
    /// server's background sweeper can derive its timer period from it.
    pub fn ttl(&self) -> Option<Duration> {
        (self.ttl_ms > 0).then(|| Duration::from_millis(self.ttl_ms))
    }
}

/// Parse the framework key scheme `{field}_rank{r}_step{s}` into the
/// generation identity `(field, step)`.  Keys that don't follow the scheme
/// (e.g. the overwrite-mode `{field}_rank{r}_latest`) return `None` and
/// fall under LRU retention instead of the sliding window.
pub fn parse_step_key(key: &str) -> Option<(&str, u64)> {
    let si = key.rfind("_step")?;
    let step = parse_digits(&key[si + "_step".len()..])?;
    let head = &key[..si];
    let ri = head.rfind("_rank")?;
    parse_digits(&head[ri + "_rank".len()..])?;
    Some((&head[..ri], step))
}

fn parse_digits(s: &str) -> Option<u64> {
    if s.is_empty() || !s.bytes().all(|b| b.is_ascii_digit()) {
        return None;
    }
    s.parse().ok()
}

/// Operation counters exposed via `INFO` (and consumed by the benches).
#[derive(Debug, Default)]
pub struct Counters {
    pub ops: AtomicU64,
    pub bytes_in: AtomicU64,
    pub bytes_out: AtomicU64,
    /// Request *frames* received over TCP — one per client round trip, so a
    /// batched command counts 1 here while `ops` counts its entries.  The
    /// pipelining tests and the microbench read this to prove a gather
    /// costs one round trip.
    pub frames: AtomicU64,
    /// Tensor keys removed by the retention policy (window retirement,
    /// byte-cap eviction, and TTL expiry); explicit `del` operations do
    /// not count.
    pub evicted_keys: AtomicU64,
    /// Payload bytes freed by eviction.
    pub evicted_bytes: AtomicU64,
    /// Subset of `evicted_keys` removed by wall-clock TTL expiry.
    pub ttl_expired_keys: AtomicU64,
    /// Writes rejected with [`Error::Busy`] because nothing evictable
    /// remained under the byte cap.
    pub busy_rejections: AtomicU64,
}

#[derive(Debug, Clone, Copy)]
struct UntrackedEntry {
    bytes: u64,
    /// Monotonic recency stamp (bumped on put and get) — the LRU key.
    tick: u64,
    /// Last write time — the TTL clock for untracked keys.
    last_put: Instant,
}

/// One step generation of a field: its member keys and the TTL clock.
struct Generation {
    members: Vec<(String, u64)>,
    /// Last write into the generation — the TTL clock.  Refreshed on every
    /// member put (matching untracked keys' `last_put`), so a generation
    /// still being filled by a slow multi-rank producer never expires
    /// under it; only genuinely stalled data does.
    last_put: Instant,
}

/// Per-field retention bookkeeping: resident generations plus the pressure
/// counters surfaced through `INFO`.  Kept (with empty `gens`) after full
/// eviction so eviction-rate counters survive; dropped only when the policy
/// is cleared.
#[derive(Default)]
struct FieldIndex {
    gens: BTreeMap<u64, Generation>,
    resident_bytes: u64,
    evicted_keys: u64,
    evicted_bytes: u64,
}

/// One shard of the retention index.  A field's generations always live in
/// one shard (fields hash to shards), so window retirement takes exactly
/// one lock; untracked keys hash by whole key.
#[derive(Default)]
struct IndexShard {
    fields: HashMap<String, FieldIndex>,
    untracked: HashMap<String, UntrackedEntry>,
}

impl IndexShard {
    fn size_of(&self, key: &str) -> u64 {
        match parse_step_key(key) {
            Some((field, step)) => self
                .fields
                .get(field)
                .and_then(|f| f.gens.get(&step))
                .and_then(|g| g.members.iter().find(|(k, _)| k.as_str() == key))
                .map(|(_, b)| *b)
                .unwrap_or(0),
            None => self.untracked.get(key).map(|e| e.bytes).unwrap_or(0),
        }
    }

    /// Record a write.  Returns `true` when the write opened a *new*
    /// generation — the boundary on which window retirement and TTL expiry
    /// run.
    fn record_put(&mut self, key: &str, bytes: u64, tick: u64, now: Instant) -> bool {
        match parse_step_key(key) {
            Some((field, step)) => {
                let fi = self.fields.entry(field.to_string()).or_default();
                let mut opened = false;
                let gen = fi.gens.entry(step).or_insert_with(|| {
                    opened = true;
                    Generation { members: Vec::new(), last_put: now }
                });
                gen.last_put = now;
                match gen.members.iter_mut().find(|(k, _)| k.as_str() == key) {
                    Some(m) => {
                        fi.resident_bytes = (fi.resident_bytes + bytes).saturating_sub(m.1);
                        m.1 = bytes;
                    }
                    None => {
                        gen.members.push((key.to_string(), bytes));
                        fi.resident_bytes += bytes;
                    }
                }
                opened
            }
            None => {
                self.untracked
                    .insert(key.to_string(), UntrackedEntry { bytes, tick, last_put: now });
                false
            }
        }
    }

    fn record_del(&mut self, key: &str) {
        match parse_step_key(key) {
            Some((field, step)) => {
                if let Some(fi) = self.fields.get_mut(field) {
                    let mut gen_empty = false;
                    if let Some(gen) = fi.gens.get_mut(&step) {
                        if let Some(i) = gen.members.iter().position(|(k, _)| k.as_str() == key) {
                            let (_, b) = gen.members.swap_remove(i);
                            fi.resident_bytes = fi.resident_bytes.saturating_sub(b);
                        }
                        gen_empty = gen.members.is_empty();
                    }
                    if gen_empty {
                        fi.gens.remove(&step);
                    }
                }
            }
            None => {
                self.untracked.remove(key);
            }
        }
    }

    fn touch(&mut self, key: &str, tick: u64) {
        if let Some(e) = self.untracked.get_mut(key) {
            e.tick = tick;
        }
    }

    /// Oldest generation of one field that eviction may take under byte
    /// pressure: one beyond the field's protected window (the newest
    /// `window` generations, or just the newest one when `window == 0`).
    ///
    /// The incoming key's own generation participates in the ordering: an
    /// append that opens generation `W+1` may retire the oldest resident
    /// one to make room for itself, but a *stale* write (a restarted
    /// producer replaying an old step) ranks below the retained window and
    /// therefore may never displace newer data — it gets backpressure
    /// instead.
    fn oldest_evictable_of(
        &self,
        field: &str,
        fi: &FieldIndex,
        window: u64,
        incoming: Option<(&str, u64)>,
    ) -> Option<u64> {
        let protect = if window > 0 { window as usize } else { 1 };
        let inc_step = match incoming {
            Some((f, s)) if f == field => Some(s),
            _ => None,
        };
        // Combined ordering of resident generations plus the incoming one
        // (tiny: at most window + slack entries per field).
        let mut combined: Vec<u64> = fi.gens.keys().copied().collect();
        if let Some(s) = inc_step {
            if !fi.gens.contains_key(&s) {
                combined.push(s);
                combined.sort_unstable();
            }
        }
        if combined.len() <= protect {
            return None;
        }
        let evictable = combined.len() - protect;
        for &step in combined.iter().take(evictable) {
            if inc_step == Some(step) {
                // The generation being written occupies this evictable slot
                // itself; nothing newer is sacrificed for it.
                continue;
            }
            return Some(step);
        }
        None
    }

    fn clear(&mut self) {
        self.fields.clear();
        self.untracked.clear();
    }
}

/// The node-local store.
pub struct Store {
    shards: Vec<Mutex<Shard>>,
    bytes: AtomicU64,
    /// Lifetime high-water mark of `bytes` (never reset, even by flush).
    high_water: AtomicU64,
    /// Whether a retention policy is active.  Checked lock-free on the hot
    /// path so ungoverned stores pay nothing for the subsystem.
    governed: AtomicBool,
    /// The active policy, readable lock-free on the put hot path.
    cfg_window: AtomicU64,
    cfg_max_bytes: AtomicU64,
    cfg_ttl_ms: AtomicU64,
    /// Field-sharded retention index (see module docs).
    index: Vec<Mutex<IndexShard>>,
    /// Per-field eviction gates (one per index shard): a put that must
    /// evict serializes only against evictors of its *own* field's shard,
    /// so saturated fields shed load concurrently.  Policy changes take
    /// all gates in order.  Puts that fit under the cap take none.
    evict_gates: Vec<Mutex<()>>,
    /// Global LRU recency clock for untracked keys.
    lru_tick: AtomicU64,
    /// Spill-to-disk cold tier (writer channel + shared read state),
    /// present while a spill directory is configured.  Leaf lock.
    spill: Mutex<Option<SpillHandle>>,
    /// Lock-free "spill is on" flag checked by the eviction paths.
    spill_on: AtomicBool,
    /// Write observer, set at most once (by the server, which points it at
    /// the poll hub's key wakeup).  Invoked after every successful
    /// `put_tensor` / `put_meta` with the key that just landed — the seam
    /// that lets parked `PollKeys` waiters resolve at write time instead of
    /// at their next backoff probe.  Unset (every bare `Store::new`), the
    /// hot path pays one atomic load.
    write_observer: OnceLock<Arc<dyn Fn(&str) + Send + Sync>>,
    /// Epoch-versioned slot ownership (the cluster's elastic routing
    /// table), installed over the wire by `ClusterEpoch`.  `None` until the
    /// first install — a standalone or legacy-static server serves every
    /// slot.  `owned_gate` mirrors `is_some()` so the keyed hot paths pay
    /// one relaxed atomic load while no table is installed.
    ownership: Mutex<Option<Arc<Ownership>>>,
    owned_gate: AtomicBool,
    pub counters: Counters,
}

/// A shard's view of the cluster's slot ownership: the epoch table plus its
/// own identity within it and the cluster's replication factor (so writes
/// that land here because this shard is a ring *successor* of the slot's
/// owner are accepted, not bounced as moved).
pub struct Ownership {
    /// This server's shard index within `table`.
    pub shard: u16,
    /// Replication factor the cluster client writes with (>= 1).
    pub replicas: u16,
    pub table: SlotEpoch,
}

impl Ownership {
    /// Whether `shard` is within the `replicas`-wide successor ring that
    /// starts at `owner` (wrapping over `n` shards) — the set of shards a
    /// replicated write of an owned slot legitimately lands on.
    fn in_ring(&self, owner: u16, n: u16, shard: u16) -> bool {
        if n == 0 {
            return false;
        }
        let dist = (shard as u32 + n as u32 - owner as u32) % n as u32;
        dist < self.replicas.max(1).min(n) as u32
    }
}

/// Handle on a running spill tier: the channel the eviction paths feed,
/// the reader-visible shared state, and the writer thread to join on
/// teardown.
struct SpillHandle {
    tx: mpsc::Sender<SpillMsg>,
    shared: Arc<SpillShared>,
    thread: std::thread::JoinHandle<()>,
}


impl Default for Store {
    fn default() -> Self {
        Self::new()
    }
}

/// FNV-1a over a string (shared by the data and index shard selectors).
fn fnv1a(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

/// Index shard owning `key`'s bookkeeping: step keys shard by field (all of
/// a field's generations share one lock), everything else by whole key.
fn index_slot(key: &str) -> usize {
    let basis = match parse_step_key(key) {
        Some((field, _)) => field,
        None => key,
    };
    (fnv1a(basis) % N_INDEX_SHARDS as u64) as usize
}

impl Store {
    pub fn new() -> Store {
        Store {
            shards: (0..N_SHARDS).map(|_| Mutex::new(Shard::default())).collect(),
            bytes: AtomicU64::new(0),
            high_water: AtomicU64::new(0),
            governed: AtomicBool::new(false),
            cfg_window: AtomicU64::new(0),
            cfg_max_bytes: AtomicU64::new(0),
            cfg_ttl_ms: AtomicU64::new(0),
            index: (0..N_INDEX_SHARDS)
                .map(|_| Mutex::new(IndexShard::default()))
                .collect(),
            evict_gates: (0..N_INDEX_SHARDS).map(|_| Mutex::new(())).collect(),
            lru_tick: AtomicU64::new(0),
            spill: Mutex::new(None),
            spill_on: AtomicBool::new(false),
            write_observer: OnceLock::new(),
            ownership: Mutex::new(None),
            owned_gate: AtomicBool::new(false),
            counters: Counters::default(),
        }
    }

    /// Install a slot-ownership table if it is not older than the one
    /// already installed (equal epochs re-install — the driver uses that to
    /// refresh `shard`/`replicas` idempotently).  Returns the table that is
    /// current *after* the call, so an install with a stale epoch doubles
    /// as a fetch of the newer one.
    pub fn install_ownership(&self, own: Ownership) -> Arc<Ownership> {
        let mut g = self.ownership.lock().unwrap();
        let newer = match g.as_ref() {
            Some(cur) => own.table.epoch >= cur.table.epoch,
            None => true,
        };
        if newer {
            *g = Some(Arc::new(own));
            self.owned_gate.store(true, Ordering::Release);
        }
        Arc::clone(g.as_ref().unwrap())
    }

    /// The currently installed ownership view, if any.
    pub fn ownership(&self) -> Option<Arc<Ownership>> {
        if !self.owned_gate.load(Ordering::Acquire) {
            return None;
        }
        self.ownership.lock().unwrap().clone()
    }

    /// Slot-ownership admission check for a keyed operation.  With no table
    /// installed every key is served (standalone / legacy-static mode).
    /// With a table: a shard serves keys whose slot it owns (or holds as a
    /// ring successor of the owner, up to the replication factor); during a
    /// migration the *old* owner ring keeps serving reads — the fallback
    /// that makes cutover lossless — but bounces writes to the new owner.
    /// Everything else is rejected with [`Error::Moved`] carrying the
    /// current epoch, telling a stale client to refetch its table.
    pub fn check_owned(&self, key: &str, write: bool) -> Result<()> {
        if !self.owned_gate.load(Ordering::Relaxed) {
            return Ok(());
        }
        let Some(own) = self.ownership() else { return Ok(()) };
        let slot = hash_slot(key);
        let a = own.table.assign_for_slot(slot);
        let n = own.table.n_shards() as u16;
        // A shard whose index is outside the table's membership has been
        // drained out by a shrink: the ring arithmetic below would alias
        // it onto `shard % n` and let it serve keys it no longer holds
        // (its copies were deleted at cutover), so it bounces everything.
        if own.shard >= n {
            return Err(Error::Moved(own.table.epoch));
        }
        if own.in_ring(a.shard, n, own.shard) {
            return Ok(());
        }
        // Mid-shrink the two moduli differ: migration sources sit above
        // every owner, so the ring under the *final* membership
        // (`owner_count`) is narrower than under `n_shards`.  Writes into
        // that final ring are what the drain streams (and what clients on
        // the committed table will send) — accept them under either
        // modulus (but never on a shard the final membership drops).
        let oc = own.table.owner_count() as u16;
        if oc != n && own.shard < oc && own.in_ring(a.shard, oc, own.shard) {
            return Ok(());
        }
        if let Some(old) = a.from {
            if !write && own.in_ring(old, n, own.shard) {
                return Ok(());
            }
        }
        Err(Error::Moved(own.table.epoch))
    }

    /// Whether a *miss* on `key` must bounce instead of answering
    /// `NotFound`: the key's slot is mid-migration and this shard is only
    /// a member of the **new** owner ring — the transfer may simply not
    /// have landed the key here yet, so a miss is not authoritative.  A
    /// client holding a pre-migration table would otherwise read a
    /// confident `NotFound` from the new ring and never consult the old
    /// owner.  Members of the old (`from`) ring answer misses honestly:
    /// they are where the data lives until cutover, so their miss is
    /// authoritative.  Returns the epoch to carry in the bounce.
    pub fn migrating_miss(&self, key: &str) -> Option<u64> {
        if !self.owned_gate.load(Ordering::Relaxed) {
            return None;
        }
        let own = self.ownership()?;
        let slot = hash_slot(key);
        let a = own.table.assign_for_slot(slot);
        let old = a.from?;
        let n = own.table.n_shards() as u16;
        let oc = own.table.owner_count() as u16;
        let in_new = (own.shard < n && own.in_ring(a.shard, n, own.shard))
            || (oc != n && own.shard < oc && own.in_ring(a.shard, oc, own.shard));
        if in_new && !own.in_ring(old, n, own.shard) {
            Some(own.table.epoch)
        } else {
            None
        }
    }

    /// Install the write observer (idempotent-ignore after the first call —
    /// a store serves exactly one server for its lifetime).  Called outside
    /// every store lock and invoked the same way, so the observer may take
    /// its own locks freely.
    pub fn set_write_observer(&self, f: Arc<dyn Fn(&str) + Send + Sync>) {
        let _ = self.write_observer.set(f);
    }

    /// Fire the write observer for a key that just became visible.
    fn notify_write(&self, key: &str) {
        if let Some(f) = self.write_observer.get() {
            f(key);
        }
    }

    /// Enable, replace, or (with `None`) disable the spill-to-disk cold
    /// tier.  Enabling opens (and crash-recovers) the segment log under
    /// `cfg.dir` and starts the writer thread; disabling flushes the log
    /// and joins the thread.  Already-written segments are never deleted
    /// by disabling — the cold tier is durable by design.
    pub fn set_spill(&self, cfg: Option<SpillConfig>) -> Result<()> {
        let old = { self.spill.lock().unwrap().take() };
        self.spill_on.store(false, Ordering::SeqCst);
        if let Some(SpillHandle { tx, thread, .. }) = old {
            drop(tx);
            let _ = thread.join();
        }
        let Some(cfg) = cfg else { return Ok(()) };
        let (tx, shared, thread) = spill::spawn(cfg)?;
        *self.spill.lock().unwrap() = Some(SpillHandle { tx, shared, thread });
        self.spill_on.store(true, Ordering::SeqCst);
        Ok(())
    }

    /// Clone the cold tier's channel + shared state, if enabled.
    fn spill_handle(&self) -> Option<(mpsc::Sender<SpillMsg>, Arc<SpillShared>)> {
        let g = self.spill.lock().unwrap();
        g.as_ref().map(|h| (h.tx.clone(), Arc::clone(&h.shared)))
    }

    /// Barrier with the spill writer thread: every record the retention
    /// pipeline retired before this call is durable (written + flushed)
    /// when it returns.  No-op when spill is off.  The server runs this on
    /// `INFO` and before every cold read, so counters and cold lookups are
    /// exact rather than racing the writer.
    pub fn spill_sync(&self) {
        if let Some((tx, shared)) = self.spill_handle() {
            shared.barrier(&tx);
        }
    }

    /// Read a retired key back from the cold tier (`ColdGet`).  Strictly
    /// the cold tier: a key still resident in memory but never evicted is
    /// `KeyNotFound` here.
    pub fn cold_get(&self, key: &str) -> Result<Tensor> {
        let Some((tx, shared)) = self.spill_handle() else {
            return Err(Error::KeyNotFound(key.to_string()));
        };
        shared.barrier(&tx);
        shared.read(key)
    }

    /// Keys resident in the cold tier with the given prefix, sorted
    /// (`ColdList`).  Empty when spill is off.
    pub fn cold_list(&self, prefix: &str) -> Vec<String> {
        let Some((tx, shared)) = self.spill_handle() else {
            return Vec::new();
        };
        shared.barrier(&tx);
        shared.list(prefix)
    }

    /// Global cold-tier counters `(spilled_keys, spilled_bytes, segments,
    /// cold_hits, lost_keys)`; zeros while spill is off.  `lost_keys` is
    /// the victims that never became durable — append I/O failures plus
    /// backlog shedding — surfaced so silent archive loss is visible in
    /// `INFO` rather than only at a missing `ColdGet`.
    pub fn spill_counters(&self) -> (u64, u64, u64, u64, u64) {
        match self.spill.lock().unwrap().as_ref() {
            Some(h) => {
                let s = &h.shared.stats;
                (
                    s.spilled_keys.load(Ordering::Relaxed),
                    s.spilled_bytes.load(Ordering::Relaxed),
                    s.segments.load(Ordering::Relaxed),
                    s.cold_hits.load(Ordering::Relaxed),
                    s.write_errors.load(Ordering::Relaxed)
                        + s.backlog_dropped.load(Ordering::Relaxed),
                )
            }
            None => (0, 0, 0, 0, 0),
        }
    }

    fn shard(&self, key: &str) -> &Mutex<Shard> {
        &self.shards[(fnv1a(key) % N_SHARDS as u64) as usize]
    }

    fn config(&self) -> RetentionConfig {
        RetentionConfig {
            window: self.cfg_window.load(Ordering::Relaxed),
            max_bytes: self.cfg_max_bytes.load(Ordering::Relaxed),
            ttl_ms: self.cfg_ttl_ms.load(Ordering::Relaxed),
        }
    }

    /// Install (or change) the retention policy and enforce it immediately.
    ///
    /// Enabling governance on a populated store rebuilds the index from the
    /// shards; writes racing the very enable may stay untracked until their
    /// next overwrite (byte accounting stays exact either way — only their
    /// eviction eligibility is delayed).
    pub fn set_retention(&self, cfg: RetentionConfig) {
        // Raise the flag before rebuilding so racing writes start taking
        // the governed (index-maintaining) path while we scan.
        let was = self.governed.swap(!cfg.is_unbounded(), Ordering::SeqCst);
        self.cfg_window.store(cfg.window, Ordering::SeqCst);
        self.cfg_max_bytes.store(cfg.max_bytes, Ordering::SeqCst);
        self.cfg_ttl_ms.store(cfg.ttl_ms, Ordering::SeqCst);
        let _gates = self.lock_all_gates();
        if cfg.is_unbounded() {
            for sh in &self.index {
                sh.lock().unwrap().clear();
            }
            return;
        }
        if !was {
            for sh in &self.index {
                sh.lock().unwrap().clear();
            }
            let now = Instant::now();
            for sh in &self.shards {
                let resident: Vec<(String, u64)> = {
                    let s = sh.lock().unwrap();
                    s.tensors.iter().map(|(k, t)| (k.clone(), t.nbytes() as u64)).collect()
                };
                for (k, b) in resident {
                    let tick = self.lru_tick.fetch_add(1, Ordering::Relaxed) + 1;
                    self.index[index_slot(&k)].lock().unwrap().record_put(&k, b, tick, now);
                }
            }
        }
        self.enforce_locked(&cfg);
    }

    pub fn retention(&self) -> RetentionConfig {
        self.config()
    }

    /// Take every eviction gate in index order — the policy-change barrier
    /// that excludes all concurrent evictors (each holds exactly one gate
    /// and never acquires another, so the ascending acquisition is
    /// cycle-free).
    fn lock_all_gates(&self) -> Vec<std::sync::MutexGuard<'_, ()>> {
        self.evict_gates.iter().map(|g| g.lock().unwrap()).collect()
    }

    /// Replace `key`'s tensor in its data shard, returning the replaced
    /// payload size.  Byte accounting is the caller's job (the governed
    /// path reserves bytes *before* inserting).
    ///
    /// Zero-copy: the shard takes the tensor's shared payload buffer by
    /// refcount — when the caller decoded it with `Request::decode_shared`,
    /// the stored payload *is* the wire frame's allocation.  Overwrites
    /// replace in place: one hash lookup, no post-insert re-hash and no key
    /// `String` re-allocation on the steady-state republish path.
    fn shard_replace(&self, key: &str, t: Tensor) -> Option<u64> {
        let mut s = self.shard(key).lock().unwrap();
        let mut incoming = Some(t);
        let old = s
            .tensors
            .get_mut(key)
            .map(|slot| std::mem::replace(slot, incoming.take().unwrap()).nbytes() as u64);
        if let Some(t) = incoming {
            s.tensors.insert(key.to_string(), t);
        }
        old
    }

    /// Ungoverned insert: shard replace plus byte / high-water accounting.
    fn insert_tensor(&self, key: &str, t: Tensor, new_bytes: u64) {
        let old = self.shard_replace(key, t);
        if let Some(o) = old {
            self.bytes.fetch_sub(o, Ordering::Relaxed);
        }
        let now = self.bytes.fetch_add(new_bytes, Ordering::Relaxed) + new_bytes;
        self.high_water.fetch_max(now, Ordering::Relaxed);
    }

    /// Try to reserve `new_bytes` of capacity for a write of `key` under
    /// `cap`, atomically.  The replaced payload's indexed size is
    /// *uncharged at reservation time* (and reconciled against the actual
    /// replaced size at insert), so `bytes` — and therefore the high-water
    /// mark other threads may sample — never transiently exceeds the cap.
    /// On success returns the uncharged estimate; the caller must complete
    /// the insert.  Never blocks, never takes a global lock.
    fn try_reserve(&self, key: &str, new_bytes: u64, cap: u64) -> Option<u64> {
        let old = self.index[index_slot(key)].lock().unwrap().size_of(key);
        self.bytes
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |cur| {
                let projected = cur.saturating_sub(old) + new_bytes;
                (projected <= cap).then_some(projected)
            })
            .ok()
            .map(|_| old)
    }

    /// Insert or overwrite a tensor (the paper's `put_tensor`).
    ///
    /// Under a byte cap this may evict TTL-expired data, retired
    /// generations, then LRU untracked keys, and fails with
    /// [`Error::Busy`] when the payload cannot fit even then.
    pub fn put_tensor(&self, key: &str, t: Tensor) -> Result<()> {
        t.validate()?;
        let new_bytes = t.nbytes() as u64;
        self.counters.ops.fetch_add(1, Ordering::Relaxed);
        self.counters.bytes_in.fetch_add(new_bytes, Ordering::Relaxed);
        if !self.governed.load(Ordering::Acquire) {
            self.insert_tensor(key, t, new_bytes);
            // Governance may have been enabled while we inserted, in which
            // case the rebuild scan can have passed our shard before the
            // insert landed.  The scan runs after the flag is raised and
            // synchronizes through the shard mutex, so re-checking here is
            // guaranteed to observe the flag — self-heal the index rather
            // than leave a resident key invisible to retention forever.
            if self.governed.load(Ordering::Acquire) {
                let tick = self.lru_tick.fetch_add(1, Ordering::Relaxed) + 1;
                self.index[index_slot(key)].lock().unwrap().record_put(
                    key,
                    new_bytes,
                    tick,
                    Instant::now(),
                );
            }
            self.notify_write(key);
            return Ok(());
        }

        let cfg = self.config();
        let reserved = if cfg.max_bytes > 0 {
            Some(self.make_room(key, new_bytes, &cfg)?)
        } else {
            None
        };

        // One index shard lock for the whole record+insert, so the index
        // mirrors the data shard exactly; puts to fields in other shards
        // proceed in parallel.
        let now = Instant::now();
        let tick = self.lru_tick.fetch_add(1, Ordering::Relaxed) + 1;
        let mut idx = self.index[index_slot(key)].lock().unwrap();
        let old = self.shard_replace(key, t);
        match reserved {
            Some(estimate) => {
                // The reservation charged `new_bytes - estimate`; reconcile
                // against what was actually replaced (equal except under a
                // same-key write race, where this keeps accounting exact).
                let actual = old.unwrap_or(0);
                if actual > estimate {
                    self.bytes.fetch_sub(actual - estimate, Ordering::Relaxed);
                } else {
                    self.bytes.fetch_add(estimate - actual, Ordering::Relaxed);
                }
            }
            None => {
                if let Some(o) = old {
                    self.bytes.fetch_sub(o, Ordering::Relaxed);
                }
                self.bytes.fetch_add(new_bytes, Ordering::Relaxed);
            }
        }
        self.high_water.fetch_max(self.bytes.load(Ordering::Relaxed), Ordering::Relaxed);
        let opened_generation = idx.record_put(key, new_bytes, tick, now);
        if opened_generation {
            // Generation boundary: the only point where window retirement
            // and TTL expiry run (puts within a generation stay O(1)).
            if cfg.window > 0 {
                if let Some((field, _)) = parse_step_key(key) {
                    let field = field.to_string();
                    self.retire_over_window_locked(&mut idx, &field, cfg.window);
                }
            }
            if let Some(ttl) = cfg.ttl() {
                self.expire_shard_locked(&mut idx, ttl, now);
            }
        }
        // Notify outside the index shard lock: the observer takes the poll
        // hub's lock and must stay a leaf in the lock order.
        drop(idx);
        self.notify_write(key);
        Ok(())
    }

    /// Evict (under `key`'s field eviction gate) until a `new_bytes` write
    /// of `key` is reserved under the byte cap.  Victim order: TTL-expired
    /// data, then the globally oldest evictable generation, then the LRU
    /// untracked key.  Returns the reservation's uncharged size estimate
    /// for the caller to reconcile after the insert.  Evictors of distinct
    /// fields run concurrently; a victim raced away by another gate's
    /// evictor is skipped idempotently and the loop re-reserves.
    fn make_room(&self, key: &str, new_bytes: u64, cfg: &RetentionConfig) -> Result<u64> {
        let cap = cfg.max_bytes;
        if new_bytes > cap {
            self.counters.busy_rejections.fetch_add(1, Ordering::Relaxed);
            return Err(Error::Busy(format!(
                "tensor of {new_bytes} bytes exceeds the store capacity of {cap} bytes"
            )));
        }
        if let Some(estimate) = self.try_reserve(key, new_bytes, cap) {
            return Ok(estimate);
        }
        let _gate = self.evict_gates[index_slot(key)].lock().unwrap();
        let mut swept_ttl = false;
        loop {
            if let Some(estimate) = self.try_reserve(key, new_bytes, cap) {
                return Ok(estimate);
            }
            if !swept_ttl {
                swept_ttl = true;
                if cfg.ttl().is_some() && self.expire_ttl() > 0 {
                    continue;
                }
            }
            let incoming = parse_step_key(key);
            if let Some((slot, field, step)) = self.find_oldest_evictable(cfg.window, incoming) {
                let mut idx = self.index[slot].lock().unwrap();
                self.evict_generation_locked(&mut idx, &field, step, false);
                continue;
            }
            if let Some((slot, victim)) = self.find_lru_untracked(key) {
                let mut idx = self.index[slot].lock().unwrap();
                if idx.untracked.remove(&victim).is_some() {
                    self.evict_store_key(&victim, false);
                }
                continue;
            }
            self.counters.busy_rejections.fetch_add(1, Ordering::Relaxed);
            let resident = self.bytes.load(Ordering::Relaxed);
            return Err(Error::Busy(format!(
                "put of {new_bytes} bytes cannot fit under max_bytes={cap} \
                 ({resident} bytes resident, all within the retention window)"
            )));
        }
    }

    /// Globally oldest evictable generation across every index shard
    /// (smallest step number among per-field candidates), locking shards
    /// one at a time.
    fn find_oldest_evictable(
        &self,
        window: u64,
        incoming: Option<(&str, u64)>,
    ) -> Option<(usize, String, u64)> {
        let mut best: Option<(usize, String, u64)> = None;
        for (slot, sh) in self.index.iter().enumerate() {
            let idx = sh.lock().unwrap();
            for (field, fi) in &idx.fields {
                if let Some(step) = idx.oldest_evictable_of(field, fi, window, incoming) {
                    let older = match &best {
                        None => true,
                        Some((_, _, bs)) => step < *bs,
                    };
                    if older {
                        best = Some((slot, field.clone(), step));
                    }
                }
            }
        }
        best
    }

    /// Globally least-recently-used untracked key, excluding the one being
    /// written.
    fn find_lru_untracked(&self, exclude: &str) -> Option<(usize, String)> {
        let mut best: Option<(usize, String, u64)> = None;
        for (slot, sh) in self.index.iter().enumerate() {
            let idx = sh.lock().unwrap();
            for (k, e) in &idx.untracked {
                if k.as_str() == exclude {
                    continue;
                }
                let older = match &best {
                    None => true,
                    Some((_, _, bt)) => e.tick < *bt,
                };
                if older {
                    best = Some((slot, k.clone(), e.tick));
                }
            }
        }
        best.map(|(slot, k, _)| (slot, k))
    }

    /// Retire the oldest generations of `field` until at most `window`
    /// remain (the sliding-window policy).  Caller holds the field's index
    /// shard lock.
    fn retire_over_window_locked(&self, idx: &mut IndexShard, field: &str, window: u64) {
        loop {
            let step = match idx.fields.get(field) {
                Some(fi) if fi.gens.len() > window as usize => {
                    match fi.gens.keys().next().copied() {
                        Some(s) => s,
                        None => return,
                    }
                }
                _ => return,
            };
            self.evict_generation_locked(idx, field, step, false);
        }
    }

    /// Remove every member of generation `(field, step)` from the index
    /// shard (whose lock the caller holds) and the data shards.
    fn evict_generation_locked(&self, idx: &mut IndexShard, field: &str, step: u64, ttl: bool) {
        let members = match idx.fields.get_mut(field).and_then(|fi| fi.gens.remove(&step)) {
            Some(g) => g.members,
            None => return,
        };
        for (key, _) in &members {
            if let Some(b) = self.evict_store_key(key, ttl) {
                if let Some(fi) = idx.fields.get_mut(field) {
                    fi.resident_bytes = fi.resident_bytes.saturating_sub(b);
                    fi.evicted_keys += 1;
                    fi.evicted_bytes += b;
                }
            }
        }
    }

    /// Remove `key` from its data shard, charging eviction counters with
    /// the actual stored size.  Returns the freed bytes.
    ///
    /// With the cold tier enabled the victim is handed to the spill writer
    /// thread instead of dropped: the send moves the tensor (its shared
    /// payload buffer travels by refcount — no copy, no disk I/O on this
    /// path), so eviction stays as cheap as before.  Every retention path
    /// funnels through here, which is exactly the "spill instead of
    /// discard" guarantee; explicit deletes never do.
    fn evict_store_key(&self, key: &str, ttl: bool) -> Option<u64> {
        let removed = { self.shard(key).lock().unwrap().tensors.remove(key) };
        removed.map(|t| {
            let b = t.nbytes() as u64;
            self.bytes.fetch_sub(b, Ordering::Relaxed);
            self.counters.evicted_keys.fetch_add(1, Ordering::Relaxed);
            self.counters.evicted_bytes.fetch_add(b, Ordering::Relaxed);
            if ttl {
                self.counters.ttl_expired_keys.fetch_add(1, Ordering::Relaxed);
            }
            if self.spill_on.load(Ordering::Acquire) {
                if let Some(h) = self.spill.lock().unwrap().as_ref() {
                    // Budget-gated: if the writer thread has fallen behind
                    // by more than the pending-byte budget, shed this
                    // victim (counted) instead of pinning evicted payloads
                    // in memory and defeating the byte cap.
                    if h.shared.try_reserve_pending(b) {
                        let _ = h
                            .tx
                            .send(SpillMsg::Record { key: key.to_string(), tensor: t });
                        // Marked after the send and under the same mutex
                        // the barrier clones the handle through, so a
                        // barrier that observes the flag always finds the
                        // record ahead of its sync marker in the channel.
                        h.shared.mark_dirty();
                    }
                }
            }
            b
        })
    }

    /// TTL expiry for one index shard (lock held by the caller): retire
    /// generations and untracked keys untouched for longer than `ttl`.
    fn expire_shard_locked(&self, idx: &mut IndexShard, ttl: Duration, now: Instant) -> u64 {
        let mut expired = 0u64;
        let victims: Vec<(String, u64)> = idx
            .fields
            .iter()
            .flat_map(|(field, fi)| {
                fi.gens
                    .iter()
                    .filter(|(_, g)| now.duration_since(g.last_put) >= ttl)
                    .map(|(step, _)| (field.clone(), *step))
                    .collect::<Vec<_>>()
            })
            .collect();
        for (field, step) in victims {
            expired += idx
                .fields
                .get(&field)
                .and_then(|fi| fi.gens.get(&step))
                .map(|g| g.members.len() as u64)
                .unwrap_or(0);
            self.evict_generation_locked(idx, &field, step, true);
        }
        let stale: Vec<String> = idx
            .untracked
            .iter()
            .filter(|(_, e)| now.duration_since(e.last_put) >= ttl)
            .map(|(k, _)| k.clone())
            .collect();
        for k in stale {
            idx.untracked.remove(&k);
            if self.evict_store_key(&k, true).is_some() {
                expired += 1;
            }
        }
        expired
    }

    /// Sweep every index shard for TTL-expired data, returning how many
    /// keys were retired.  No-op when governance or TTL is off.  The server
    /// calls this on `INFO`, so stalled producers are reclaimed even when
    /// no other field is writing into their index shard.
    pub fn expire_ttl(&self) -> u64 {
        if !self.governed.load(Ordering::Acquire) {
            return 0;
        }
        let Some(ttl) = self.config().ttl() else { return 0 };
        let now = Instant::now();
        let mut expired = 0;
        for sh in &self.index {
            let mut idx = sh.lock().unwrap();
            expired += self.expire_shard_locked(&mut idx, ttl, now);
        }
        expired
    }

    /// Apply the current policy to the resident set (used when the policy
    /// changes; caller holds every eviction gate): window retirement per field,
    /// TTL expiry, then best-effort eviction down to the byte cap.
    /// Anything left over the cap is protected and will backpressure
    /// future puts instead.
    fn enforce_locked(&self, cfg: &RetentionConfig) {
        let now = Instant::now();
        for sh in &self.index {
            let mut idx = sh.lock().unwrap();
            if cfg.window > 0 {
                let fields: Vec<String> = idx.fields.keys().cloned().collect();
                for field in fields {
                    self.retire_over_window_locked(&mut idx, &field, cfg.window);
                }
            }
            if let Some(ttl) = cfg.ttl() {
                self.expire_shard_locked(&mut idx, ttl, now);
            }
        }
        let cap = cfg.max_bytes;
        if cap > 0 {
            while self.bytes.load(Ordering::Relaxed) > cap {
                if let Some((slot, field, step)) = self.find_oldest_evictable(cfg.window, None) {
                    let mut idx = self.index[slot].lock().unwrap();
                    self.evict_generation_locked(&mut idx, &field, step, false);
                } else if let Some((slot, victim)) = self.find_lru_untracked("") {
                    let mut idx = self.index[slot].lock().unwrap();
                    if idx.untracked.remove(&victim).is_some() {
                        self.evict_store_key(&victim, false);
                    }
                } else {
                    break;
                }
            }
        }
    }

    /// Per-field pressure snapshot (resident bytes, generation count,
    /// eviction counters, spill counters), sorted by field name.  Empty
    /// when governance is off — the index only mirrors the namespace while
    /// a policy is set.  With the cold tier on, per-field spill counters
    /// are merged in by field name (untracked keys spill under the
    /// `__untracked` pseudo-field, which then appears here with zero
    /// resident bytes).
    pub fn field_pressure(&self) -> Vec<FieldPressure> {
        let mut out = Vec::new();
        for sh in &self.index {
            let idx = sh.lock().unwrap();
            for (field, fi) in &idx.fields {
                out.push(FieldPressure {
                    field: field.clone(),
                    resident_bytes: fi.resident_bytes,
                    generations: fi.gens.len() as u64,
                    evicted_keys: fi.evicted_keys,
                    evicted_bytes: fi.evicted_bytes,
                    ..Default::default()
                });
            }
        }
        if let Some((_, shared)) = self.spill_handle() {
            for (field, spilled_keys, spilled_bytes) in shared.field_counters() {
                match out.iter_mut().find(|p| p.field == field) {
                    Some(p) => {
                        p.spilled_keys = spilled_keys;
                        p.spilled_bytes = spilled_bytes;
                    }
                    None => out.push(FieldPressure {
                        field,
                        spilled_keys,
                        spilled_bytes,
                        ..Default::default()
                    }),
                }
            }
        }
        out.sort_by(|a, b| a.field.cmp(&b.field));
        out
    }

    /// Fetch a tensor (the paper's `unpack_tensor`).
    ///
    /// The returned tensor shares the stored payload by refcount — no deep
    /// copy under the shard lock.  A reader's view stays alive and valid
    /// even if the key is overwritten, deleted or evicted afterwards.
    pub fn get_tensor(&self, key: &str) -> Result<Tensor> {
        self.counters.ops.fetch_add(1, Ordering::Relaxed);
        let t = {
            let s = self.shard(key).lock().unwrap();
            s.tensors.get(key).cloned()
        }
        .ok_or_else(|| Error::KeyNotFound(key.to_string()))?;
        self.counters
            .bytes_out
            .fetch_add(t.nbytes() as u64, Ordering::Relaxed);
        // LRU recency for untracked keys under governance.  The key's own
        // index shard lock is taken briefly — distinct stable keys hash to
        // distinct shards, so concurrent overwrite-mode readers don't
        // serialize on one mutex.
        if self.governed.load(Ordering::Relaxed) && parse_step_key(key).is_none() {
            let tick = self.lru_tick.fetch_add(1, Ordering::Relaxed) + 1;
            self.index[index_slot(key)].lock().unwrap().touch(key, tick);
        }
        Ok(t)
    }

    pub fn del_tensor(&self, key: &str) -> bool {
        self.counters.ops.fetch_add(1, Ordering::Relaxed);
        if !self.governed.load(Ordering::Acquire) {
            let removed = { self.shard(key).lock().unwrap().tensors.remove(key) };
            if let Some(t) = removed {
                self.bytes.fetch_sub(t.nbytes() as u64, Ordering::Relaxed);
                // Mirror of the put path's enable-race self-heal: drop any
                // index entry the rebuild scan recorded before our delete.
                if self.governed.load(Ordering::Acquire) {
                    self.index[index_slot(key)].lock().unwrap().record_del(key);
                }
                return true;
            }
            return false;
        }
        let mut idx = self.index[index_slot(key)].lock().unwrap();
        let removed = { self.shard(key).lock().unwrap().tensors.remove(key) };
        match removed {
            Some(t) => {
                self.bytes.fetch_sub(t.nbytes() as u64, Ordering::Relaxed);
                idx.record_del(key);
                true
            }
            None => false,
        }
    }

    pub fn exists(&self, key: &str) -> bool {
        self.counters.ops.fetch_add(1, Ordering::Relaxed);
        let s = self.shard(key).lock().unwrap();
        s.tensors.contains_key(key) || s.metas.contains_key(key)
    }

    /// Whether every key exists (tensor or metadata).  One counted op per
    /// probe regardless of the key count — the `PollKeys` fast path.
    pub fn exists_all(&self, keys: &[String]) -> bool {
        self.counters.ops.fetch_add(1, Ordering::Relaxed);
        keys.iter().all(|key| {
            let s = self.shard(key).lock().unwrap();
            s.tensors.contains_key(key) || s.metas.contains_key(key)
        })
    }

    pub fn put_meta(&self, key: &str, value: &str) {
        self.counters.ops.fetch_add(1, Ordering::Relaxed);
        {
            let mut s = self.shard(key).lock().unwrap();
            s.metas.insert(key.to_string(), value.to_string());
        }
        // `exists_all` answers true for metadata too, so metadata writes
        // must wake parked pollers just like tensor writes.
        self.notify_write(key);
    }

    pub fn get_meta(&self, key: &str) -> Result<String> {
        self.counters.ops.fetch_add(1, Ordering::Relaxed);
        let s = self.shard(key).lock().unwrap();
        s.metas
            .get(key)
            .cloned()
            .ok_or_else(|| Error::KeyNotFound(key.to_string()))
    }

    /// All resident tensor keys whose hash slot falls in `[lo, hi]`,
    /// generation-ordered: step keys sort by `(field, step, key)` so a
    /// reshard transfer window moves whole generations together (and in
    /// step order, oldest first), untracked keys sort lexically among
    /// themselves.  The reshard driver's per-range export manifest
    /// (`ExportSlots`).  Metadata keys are not exported — they are
    /// node-local coordination state, not governed data.
    pub fn keys_in_slots(&self, lo: u16, hi: u16) -> Vec<String> {
        self.counters.ops.fetch_add(1, Ordering::Relaxed);
        let mut out = Vec::new();
        for sh in &self.shards {
            let s = sh.lock().unwrap();
            out.extend(
                s.tensors
                    .keys()
                    .filter(|k| {
                        let slot = hash_slot(k);
                        lo <= slot && slot <= hi
                    })
                    .cloned(),
            );
        }
        fn gen_order(k: &str) -> (&str, u64, &str) {
            match parse_step_key(k) {
                Some((field, step)) => (field, step, k),
                None => (k, 0, k),
            }
        }
        out.sort_by(|a, b| gen_order(a).cmp(&gen_order(b)));
        out
    }

    /// Append a tensor directly to the cold tier, bypassing the resident
    /// store — the cluster-wide retirement path, which lands every member
    /// of a retired generation in exactly one shard's spill log.  Fails
    /// when no spill directory is configured (the caller picked the wrong
    /// shard) or the writer's backlog budget is exhausted (backpressure,
    /// retryable).
    pub fn cold_put(&self, key: &str, t: Tensor) -> Result<()> {
        t.validate()?;
        self.counters.ops.fetch_add(1, Ordering::Relaxed);
        let bytes = t.nbytes() as u64;
        let g = self.spill.lock().unwrap();
        let Some(h) = g.as_ref() else {
            return Err(Error::Invalid(format!(
                "cold_put {key}: no cold tier configured on this shard"
            )));
        };
        if !h.shared.try_reserve_pending(bytes) {
            return Err(Error::Busy(format!(
                "cold tier backlog over budget ({bytes} bytes pending append)"
            )));
        }
        h.tx
            .send(SpillMsg::Record { key: key.to_string(), tensor: t })
            .map_err(|_| Error::Invalid("spill writer thread is gone".into()))?;
        h.shared.mark_dirty();
        Ok(())
    }

    /// All tensor keys with a prefix, sorted (dataloader discovery).
    pub fn list_keys(&self, prefix: &str) -> Vec<String> {
        self.counters.ops.fetch_add(1, Ordering::Relaxed);
        let mut out = Vec::new();
        for sh in &self.shards {
            let s = sh.lock().unwrap();
            out.extend(s.tensors.keys().filter(|k| k.starts_with(prefix)).cloned());
        }
        out.sort();
        out
    }

    pub fn flush_all(&self) {
        self.counters.ops.fetch_add(1, Ordering::Relaxed);
        let _gates = self.lock_all_gates();
        for sh in &self.index {
            sh.lock().unwrap().clear();
        }
        for sh in &self.shards {
            let mut s = sh.lock().unwrap();
            s.tensors.clear();
            s.metas.clear();
        }
        self.bytes.store(0, Ordering::Relaxed);
    }

    pub fn n_keys(&self) -> u64 {
        self.shards
            .iter()
            .map(|sh| {
                let s = sh.lock().unwrap();
                (s.tensors.len() + s.metas.len()) as u64
            })
            .sum()
    }

    pub fn n_bytes(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }

    /// Lifetime high-water mark of resident tensor bytes.
    pub fn high_water_bytes(&self) -> u64 {
        self.high_water.load(Ordering::Relaxed)
    }

    pub fn n_ops(&self) -> u64 {
        self.counters.ops.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::DType;
    use crate::util::propcheck::{check, Gen};
    use std::collections::HashMap;
    use std::sync::Arc;

    fn t(v: Vec<f32>) -> Tensor {
        Tensor::from_f32(&[v.len()], v).unwrap()
    }

    #[test]
    fn put_get_del() {
        let s = Store::new();
        s.put_tensor("a", t(vec![1.0, 2.0])).unwrap();
        assert_eq!(s.get_tensor("a").unwrap().to_f32().unwrap(), vec![1.0, 2.0]);
        assert!(s.exists("a"));
        assert!(s.del_tensor("a"));
        assert!(!s.del_tensor("a"));
        assert!(matches!(s.get_tensor("a"), Err(Error::KeyNotFound(_))));
    }

    #[test]
    fn byte_accounting_on_overwrite() {
        let s = Store::new();
        s.put_tensor("k", t(vec![0.0; 100])).unwrap();
        assert_eq!(s.n_bytes(), 400);
        s.put_tensor("k", t(vec![0.0; 10])).unwrap();
        assert_eq!(s.n_bytes(), 40);
        assert_eq!(s.high_water_bytes(), 400, "high-water survives shrink");
        s.del_tensor("k");
        assert_eq!(s.n_bytes(), 0);
    }

    #[test]
    fn meta_namespace_is_separate() {
        let s = Store::new();
        s.put_meta("step", "41");
        assert_eq!(s.get_meta("step").unwrap(), "41");
        assert!(s.get_tensor("step").is_err());
        assert!(s.exists("step"));
    }

    #[test]
    fn exists_all_spans_tensor_and_meta_namespaces() {
        let s = Store::new();
        s.put_tensor("a", t(vec![1.0])).unwrap();
        s.put_meta("b", "x");
        let have =
            |ks: &[&str]| s.exists_all(&ks.iter().map(|k| k.to_string()).collect::<Vec<_>>());
        assert!(have(&["a", "b"]));
        assert!(!have(&["a", "b", "c"]));
        assert!(have(&[]), "vacuously true on no keys");
    }

    #[test]
    fn list_keys_prefix_sorted() {
        let s = Store::new();
        for k in ["f_r1_s0", "f_r0_s0", "g_r0_s0"] {
            s.put_tensor(k, t(vec![0.0])).unwrap();
        }
        assert_eq!(s.list_keys("f_"), vec!["f_r0_s0", "f_r1_s0"]);
        assert_eq!(s.list_keys(""), vec!["f_r0_s0", "f_r1_s0", "g_r0_s0"]);
    }

    #[test]
    fn flush_resets_everything() {
        let s = Store::new();
        s.put_tensor("a", t(vec![1.0])).unwrap();
        s.put_meta("m", "x");
        s.flush_all();
        assert_eq!(s.n_keys(), 0);
        assert_eq!(s.n_bytes(), 0);
    }

    #[test]
    fn prop_store_matches_hashmap_model() {
        // Model-based property test: random op interleavings agree with a
        // plain HashMap reference model.
        check("store vs model", 100, |g: &mut Gen| {
            let s = Store::new();
            let mut model: HashMap<String, Vec<f32>> = HashMap::new();
            let keys: Vec<String> = (0..g.usize_in(1..=8)).map(|i| format!("k{i}")).collect();
            for _ in 0..g.usize_in(1..=60) {
                let key = g.choose(&keys).clone();
                match g.usize_in(0..=3) {
                    0 => {
                        let v: Vec<f32> = g.vec(1..=16, |g| g.normal_f32());
                        s.put_tensor(&key, t(v.clone())).unwrap();
                        model.insert(key, v);
                    }
                    1 => {
                        let got = s.get_tensor(&key).ok().map(|x| x.to_f32().unwrap());
                        assert_eq!(got, model.get(&key).cloned(), "get {key}");
                    }
                    2 => {
                        assert_eq!(s.del_tensor(&key), model.remove(&key).is_some());
                    }
                    _ => {
                        assert_eq!(s.exists(&key), model.contains_key(&key));
                    }
                }
            }
            let want_bytes: u64 = model.values().map(|v| 4 * v.len() as u64).sum();
            assert_eq!(s.n_bytes(), want_bytes);
            assert_eq!(s.n_keys(), model.len() as u64);
        });
    }

    #[test]
    fn concurrent_distinct_keys() {
        let s = Arc::new(Store::new());
        let mut handles = Vec::new();
        for r in 0..8 {
            let s = Arc::clone(&s);
            handles.push(std::thread::spawn(move || {
                for i in 0..50 {
                    let key = format!("rank{r}_step{i}");
                    s.put_tensor(&key, t(vec![r as f32, i as f32])).unwrap();
                    let back = s.get_tensor(&key).unwrap().to_f32().unwrap();
                    assert_eq!(back, vec![r as f32, i as f32]);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(s.n_keys(), 8 * 50);
    }

    #[test]
    fn get_tensor_is_refcount_clone_not_deep_copy() {
        let s = Store::new();
        let t0 = t(vec![1.0, 2.0, 3.0]);
        let put_handle = t0.data.clone();
        s.put_tensor("k", t0).unwrap();
        let a = s.get_tensor("k").unwrap();
        let b = s.get_tensor("k").unwrap();
        assert!(
            a.data.shares_allocation(&put_handle),
            "stored payload must be the exact buffer that was put"
        );
        assert!(a.data.shares_allocation(&b.data));
        assert_eq!(a.data.as_ptr(), b.data.as_ptr(), "pointer-identical payloads");
    }

    #[test]
    fn outstanding_views_survive_overwrite_and_delete() {
        let s = Store::new();
        s.put_tensor("k", t(vec![1.0, 2.0])).unwrap();
        let old = s.get_tensor("k").unwrap();
        s.put_tensor("k", t(vec![9.0])).unwrap();
        assert_eq!(old.to_f32().unwrap(), vec![1.0, 2.0], "view valid after overwrite");
        let newer = s.get_tensor("k").unwrap();
        assert!(s.del_tensor("k"));
        assert_eq!(newer.to_f32().unwrap(), vec![9.0], "view valid after delete");
        assert_eq!(s.n_bytes(), 0, "accounting ignores outstanding views");
    }

    #[test]
    fn concurrent_get_during_overwrite_no_torn_reads() {
        // Readers hammer a key while a writer overwrites it with
        // constant-valued tensors; aliasing semantics guarantee every read
        // observes one complete buffer, never a mix.
        let s = Arc::new(Store::new());
        s.put_tensor("k", t(vec![0.0; 256])).unwrap();
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let mut readers = Vec::new();
        for _ in 0..4 {
            let s = Arc::clone(&s);
            let stop = Arc::clone(&stop);
            readers.push(std::thread::spawn(move || {
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    let v = s.get_tensor("k").unwrap().to_f32().unwrap();
                    let first = v[0];
                    assert!(v.iter().all(|&x| x == first), "torn read: {first} vs mix");
                }
            }));
        }
        for i in 1..=200 {
            s.put_tensor("k", t(vec![i as f32; 256])).unwrap();
        }
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
        for h in readers {
            h.join().unwrap();
        }
    }

    #[test]
    fn rejects_invalid_tensor() {
        let s = Store::new();
        let bad = Tensor { dtype: DType::F32, shape: vec![4], data: vec![0u8; 3].into() };
        assert!(s.put_tensor("x", bad).is_err());
        assert_eq!(s.n_keys(), 0);
    }

    // --- retention ---------------------------------------------------------

    #[test]
    fn parse_step_key_accepts_the_framework_scheme_only() {
        assert_eq!(parse_step_key("field_rank0_step2"), Some(("field", 2)));
        assert_eq!(parse_step_key("u_x_rank12_step34"), Some(("u_x", 34)));
        assert_eq!(parse_step_key("f_rank0_step007"), Some(("f", 7)));
        assert_eq!(parse_step_key("field_rank0_latest"), None, "overwrite scheme");
        assert_eq!(parse_step_key("field_step2"), None, "no rank segment");
        assert_eq!(parse_step_key("field_rank0_step"), None, "empty step digits");
        assert_eq!(parse_step_key("field_rankx_step2"), None, "non-numeric rank");
        assert_eq!(parse_step_key("field_rank0_step2x"), None, "trailing junk");
        assert_eq!(parse_step_key("plain"), None);
    }

    #[test]
    fn prop_step_key_roundtrips_for_adversarial_field_names() {
        // tensor_key → parse_step_key must round-trip even when the field
        // name itself embeds `_rank`/`_step` substrings (the parser anchors
        // on the *last* occurrences), and the overwrite-mode stable key of
        // the same field must never parse as a step key.
        check("step key roundtrip", 300, |g: &mut Gen| {
            const SEGS: &[&str] =
                &["_rank", "_step", "u", "x9", "_", "7", "field", "_rank3", "_step00", "v_"];
            let n = g.usize_in(0..=5);
            let field: String = (0..n).map(|_| *g.choose(SEGS)).collect();
            let rank = g.usize_in(0..=999);
            let step = g.u64() % 1_000_000;
            let key = crate::client::tensor_key(&field, rank, step);
            assert_eq!(
                parse_step_key(&key),
                Some((field.as_str(), step)),
                "round-trip failed for field {field:?} (key {key:?})"
            );
            let stable = crate::client::stable_key(&field, rank);
            assert_eq!(
                parse_step_key(&stable),
                None,
                "stable key {stable:?} must stay untracked"
            );
        });
    }

    #[test]
    fn sliding_window_retires_oldest_generation() {
        let s = Store::new();
        s.set_retention(RetentionConfig::windowed(2, 0));
        for step in 0..5u64 {
            for rank in 0..3 {
                s.put_tensor(&format!("f_rank{rank}_step{step}"), t(vec![step as f32; 8]))
                    .unwrap();
            }
        }
        let keys = s.list_keys("f_");
        assert_eq!(keys.len(), 2 * 3, "two generations of three ranks");
        assert!(keys.iter().all(|k| k.ends_with("step3") || k.ends_with("step4")), "{keys:?}");
        assert_eq!(s.counters.evicted_keys.load(Ordering::Relaxed), 3 * 3);
        assert_eq!(
            s.counters.evicted_bytes.load(Ordering::Relaxed),
            3 * 3 * 32,
            "every evicted tensor was 32 bytes"
        );
        assert_eq!(s.n_bytes(), 6 * 32, "flat steady state");
    }

    #[test]
    fn windows_are_per_field() {
        let s = Store::new();
        s.set_retention(RetentionConfig::windowed(1, 0));
        for step in 0..3u64 {
            s.put_tensor(&format!("a_rank0_step{step}"), t(vec![1.0])).unwrap();
            s.put_tensor(&format!("b_rank0_step{step}"), t(vec![2.0])).unwrap();
        }
        assert_eq!(s.list_keys(""), vec!["a_rank0_step2", "b_rank0_step2"]);
    }

    #[test]
    fn byte_cap_evicts_lru_untracked_keys() {
        let s = Store::new();
        // 3 × 40-byte untracked tensors fit under 128 bytes; the 4th evicts
        // the least recently *used* one.
        s.set_retention(RetentionConfig::windowed(0, 128));
        s.put_tensor("a", t(vec![0.0; 10])).unwrap();
        s.put_tensor("b", t(vec![0.0; 10])).unwrap();
        s.put_tensor("c", t(vec![0.0; 10])).unwrap();
        s.get_tensor("a").unwrap(); // touch: a is now more recent than b
        s.put_tensor("d", t(vec![0.0; 10])).unwrap();
        assert!(!s.exists("b"), "LRU victim");
        assert!(s.exists("a") && s.exists("c") && s.exists("d"));
        assert_eq!(s.counters.evicted_keys.load(Ordering::Relaxed), 1);
        assert!(s.n_bytes() <= 128);
    }

    #[test]
    fn byte_cap_append_retires_own_field_oldest_generation() {
        let s = Store::new();
        // Cap fits exactly two 40-byte generations; window 2 protects both,
        // but an append opening generation 3 may retire generation 0.
        s.set_retention(RetentionConfig::windowed(2, 80));
        s.put_tensor("f_rank0_step0", t(vec![0.0; 10])).unwrap();
        s.put_tensor("f_rank0_step1", t(vec![1.0; 10])).unwrap();
        s.put_tensor("f_rank0_step2", t(vec![2.0; 10])).unwrap();
        assert!(!s.exists("f_rank0_step0"));
        assert!(s.exists("f_rank0_step1") && s.exists("f_rank0_step2"));
        assert!(s.n_bytes() <= 80);
    }

    #[test]
    fn stale_republish_cannot_displace_newer_generations() {
        // A restarted producer replaying an old step ranks below the
        // retained window: under byte pressure it gets backpressure rather
        // than evicting newer training data...
        let s = Store::new();
        s.set_retention(RetentionConfig::windowed(2, 80));
        s.put_tensor("f_rank0_step5", t(vec![5.0; 10])).unwrap();
        s.put_tensor("f_rank0_step6", t(vec![6.0; 10])).unwrap();
        let err = s.put_tensor("f_rank0_step4", t(vec![4.0; 10])).unwrap_err();
        assert!(matches!(err, Error::Busy(_)), "{err}");
        assert!(s.exists("f_rank0_step5") && s.exists("f_rank0_step6"), "newer data intact");
        // ...and without byte pressure it is admitted, then immediately
        // retired by the window (the newest two generations win).
        let s = Store::new();
        s.set_retention(RetentionConfig::windowed(2, 0));
        s.put_tensor("f_rank0_step5", t(vec![5.0; 10])).unwrap();
        s.put_tensor("f_rank0_step6", t(vec![6.0; 10])).unwrap();
        s.put_tensor("f_rank0_step4", t(vec![4.0; 10])).unwrap();
        assert_eq!(s.list_keys(""), vec!["f_rank0_step5", "f_rank0_step6"]);
    }

    #[test]
    fn busy_when_nothing_evictable() {
        let s = Store::new();
        s.set_retention(RetentionConfig::windowed(2, 80));
        // A payload larger than the whole cap is rejected outright.
        assert!(matches!(s.put_tensor("big", t(vec![0.0; 100])), Err(Error::Busy(_))));
        // Fill the cap with one field's protected window; a *different*
        // field then cannot fit and must get backpressure, not eviction of
        // protected data.
        s.put_tensor("f_rank0_step0", t(vec![0.0; 10])).unwrap();
        s.put_tensor("f_rank0_step1", t(vec![1.0; 10])).unwrap();
        let err = s.put_tensor("g_rank0_step0", t(vec![2.0; 10])).unwrap_err();
        assert!(matches!(err, Error::Busy(_)), "{err}");
        assert!(s.exists("f_rank0_step0") && s.exists("f_rank0_step1"), "window intact");
        assert_eq!(s.counters.busy_rejections.load(Ordering::Relaxed), 2);
        // Overwriting a resident key at the same size always fits.
        s.put_tensor("f_rank0_step1", t(vec![9.0; 10])).unwrap();
    }

    #[test]
    fn enabling_retention_on_a_populated_store_rebuilds_and_enforces() {
        let s = Store::new();
        for step in 0..6u64 {
            s.put_tensor(&format!("f_rank0_step{step}"), t(vec![step as f32; 4])).unwrap();
        }
        assert_eq!(s.n_bytes(), 6 * 16);
        s.set_retention(RetentionConfig::windowed(2, 0));
        assert_eq!(s.list_keys(""), vec!["f_rank0_step4", "f_rank0_step5"]);
        assert_eq!(s.n_bytes(), 2 * 16);
        // Disabling governance restores plain append.
        s.set_retention(RetentionConfig::UNBOUNDED);
        s.put_tensor("f_rank0_step9", t(vec![0.0; 4])).unwrap();
        s.put_tensor("f_rank0_step10", t(vec![0.0; 4])).unwrap();
        assert_eq!(s.list_keys("").len(), 4);
    }

    #[test]
    fn prop_governed_byte_accounting_stays_exact() {
        // Under random puts/dels with retention active, the bytes atomic
        // always equals the sum of resident tensor sizes.
        check("governed accounting", 60, |g: &mut Gen| {
            let s = Store::new();
            s.set_retention(RetentionConfig::windowed(
                g.usize_in(0..=3) as u64,
                (g.usize_in(2..=20) * 16) as u64,
            ));
            for _ in 0..g.usize_in(1..=50) {
                let field = ["u", "v"][g.usize_in(0..=1)];
                let key = if g.bool() {
                    format!("{field}_rank{}_step{}", g.usize_in(0..=1), g.usize_in(0..=9))
                } else {
                    format!("loose{}", g.usize_in(0..=3))
                };
                if g.bool() {
                    let _ = s.put_tensor(&key, t(vec![1.0; g.usize_in(1..=4)]));
                } else {
                    s.del_tensor(&key);
                }
            }
            let resident: u64 = s
                .list_keys("")
                .iter()
                .map(|k| s.get_tensor(k).unwrap().nbytes() as u64)
                .sum();
            assert_eq!(s.n_bytes(), resident, "accounting drift");
            assert!(s.high_water_bytes() >= s.n_bytes());
        });
    }

    #[test]
    fn eviction_is_concurrency_safe_with_readers() {
        // Producers append (driving eviction) while readers fetch; a view
        // handed out before eviction stays byte-valid afterwards.
        let s = Arc::new(Store::new());
        s.set_retention(RetentionConfig::windowed(2, 0));
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let mut readers = Vec::new();
        for _ in 0..3 {
            let s = Arc::clone(&s);
            let stop = Arc::clone(&stop);
            readers.push(std::thread::spawn(move || {
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    for step in 0..60u64 {
                        if let Ok(v) = s.get_tensor(&format!("c_rank0_step{step}")) {
                            let v = v.to_f32().unwrap();
                            assert!(v.iter().all(|&x| x == v[0]), "torn read");
                        }
                    }
                }
            }));
        }
        for step in 0..60u64 {
            s.put_tensor(&format!("c_rank0_step{step}"), t(vec![step as f32; 64])).unwrap();
        }
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
        for h in readers {
            h.join().unwrap();
        }
        assert_eq!(s.list_keys("c_").len(), 2);
        assert_eq!(s.n_bytes(), 2 * 64 * 4);
    }

    // --- sharded index concurrency -----------------------------------------

    /// Find a field name that hashes to a *different* index shard than
    /// `other`'s field.
    fn field_in_other_slot(other: &str) -> String {
        let taken = index_slot(&crate::client::tensor_key(other, 0, 0));
        for i in 0.. {
            let candidate = format!("fb{i}");
            if index_slot(&crate::client::tensor_key(&candidate, 0, 0)) != taken {
                return candidate;
            }
        }
        unreachable!()
    }

    #[test]
    fn governed_puts_to_distinct_fields_do_not_share_a_lock() {
        // The acceptance property of the sharded index: hold field A's
        // index shard mutex and prove a governed put to field B (hashing to
        // a different shard) still completes — under the old global
        // `Mutex<RetentionIndex>` it would block forever.  Byte-capped but
        // non-evicting, so the put must not touch the evict gate either.
        let s = Arc::new(Store::new());
        s.set_retention(RetentionConfig::windowed(4, 1 << 20));
        let field_a = "fa";
        let field_b = field_in_other_slot(field_a);
        let slot_a = index_slot(&crate::client::tensor_key(field_a, 0, 0));

        let guard = s.index[slot_a].lock().unwrap();
        let (tx, rx) = std::sync::mpsc::channel();
        let writer = {
            let s = Arc::clone(&s);
            let key = crate::client::tensor_key(&field_b, 0, 0);
            std::thread::spawn(move || {
                s.put_tensor(&key, t(vec![1.0; 16])).unwrap();
                tx.send(()).unwrap();
            })
        };
        rx.recv_timeout(std::time::Duration::from_secs(10))
            .expect("governed put to another field must not wait on field A's index lock");
        writer.join().unwrap();

        // Control: a put to field A *does* need the held lock — it must
        // still be pending while we hold the guard, and complete after.
        let (tx2, rx2) = std::sync::mpsc::channel();
        let blocked = {
            let s = Arc::clone(&s);
            let key = crate::client::tensor_key(field_a, 0, 0);
            std::thread::spawn(move || {
                s.put_tensor(&key, t(vec![2.0; 16])).unwrap();
                tx2.send(()).unwrap();
            })
        };
        assert!(
            rx2.recv_timeout(std::time::Duration::from_millis(200)).is_err(),
            "a put to the held field's shard should block on its index lock"
        );
        drop(guard);
        rx2.recv_timeout(std::time::Duration::from_secs(10))
            .expect("put completes once the shard lock is released");
        blocked.join().unwrap();
    }

    #[test]
    fn evicting_puts_do_not_serialize_on_one_global_gate() {
        // The acceptance property of per-field eviction gates: hold one
        // index slot's gate and prove an *evicting* put whose key hashes to
        // a different slot still completes — under the old single
        // `evict_gate` it would block forever.  LRU untracked keys keep
        // the victim selection independent of window bookkeeping.
        let s = Arc::new(Store::new());
        let payload = 32usize; // 128 bytes per tensor
        s.set_retention(RetentionConfig { window: 0, max_bytes: 256, ttl_ms: 0 });
        s.put_tensor("c0", t(vec![1.0; payload])).unwrap();
        s.put_tensor("c1", t(vec![1.0; payload])).unwrap();
        assert_eq!(s.n_bytes(), 256, "at the cap; the next distinct put must evict");

        let held_slot = index_slot("blocked");
        let w_key = (0..64)
            .map(|i| format!("w{i}"))
            .find(|k| index_slot(k) != held_slot && k.as_str() != "c0" && k.as_str() != "c1")
            .expect("a key hashing away from the held slot");

        let guard = s.evict_gates[held_slot].lock().unwrap();
        let (tx, rx) = std::sync::mpsc::channel();
        let writer = {
            let s = Arc::clone(&s);
            let key = w_key.clone();
            std::thread::spawn(move || {
                s.put_tensor(&key, t(vec![2.0; payload])).unwrap();
                tx.send(()).unwrap();
            })
        };
        rx.recv_timeout(std::time::Duration::from_secs(10))
            .expect("an evicting put must not wait on another field's eviction gate");
        writer.join().unwrap();
        drop(guard);
        assert!(s.n_bytes() <= 256, "cap still enforced after the concurrent eviction");
        assert!(s.exists(&w_key), "the evicting put landed");
    }

    #[test]
    fn concurrent_governed_producers_on_distinct_fields() {
        // Many producers, one field each, under full governance (window +
        // cap sized to never starve): all complete, accounting exact, each
        // field flat at its window.
        let n_fields = 6usize;
        let window = 3u64;
        let steps = 40u64;
        let payload = 32 * 4u64;
        let s = Arc::new(Store::new());
        s.set_retention(RetentionConfig::windowed(
            window,
            (window + 2) * n_fields as u64 * payload,
        ));
        let mut handles = Vec::new();
        for f in 0..n_fields {
            let s = Arc::clone(&s);
            handles.push(std::thread::spawn(move || {
                for step in 0..steps {
                    let key = format!("cfield{f}_rank0_step{step}");
                    s.put_tensor(&key, t(vec![step as f32; 32])).unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(s.n_bytes(), n_fields as u64 * window * payload, "flat per-field windows");
        let pressure = s.field_pressure();
        assert_eq!(pressure.len(), n_fields);
        for p in &pressure {
            assert_eq!(p.generations, window, "{}", p.field);
            assert_eq!(p.resident_bytes, window * payload, "{}", p.field);
            assert_eq!(p.evicted_keys, steps - window, "{}", p.field);
        }
    }

    #[test]
    fn field_pressure_reports_per_field_state() {
        let s = Store::new();
        s.set_retention(RetentionConfig::windowed(2, 0));
        for step in 0..4u64 {
            s.put_tensor(&format!("u_rank0_step{step}"), t(vec![0.0; 8])).unwrap();
        }
        s.put_tensor("v_rank0_step0", t(vec![0.0; 4])).unwrap();
        let p = s.field_pressure();
        assert_eq!(p.len(), 2);
        assert_eq!(p[0].field, "u");
        assert_eq!(p[0].generations, 2);
        assert_eq!(p[0].resident_bytes, 2 * 32);
        assert_eq!(p[0].evicted_keys, 2);
        assert_eq!(p[0].evicted_bytes, 2 * 32);
        assert_eq!(p[1].field, "v");
        assert_eq!(p[1].generations, 1);
        assert_eq!(p[1].resident_bytes, 16);
        assert_eq!(p[1].evicted_keys, 0);
    }

    // --- wall-clock TTL -----------------------------------------------------

    #[test]
    fn ttl_expires_stalled_generations_on_sweep() {
        let s = Store::new();
        s.set_retention(RetentionConfig { window: 4, max_bytes: 0, ttl_ms: 150 });
        s.put_tensor("stall_rank0_step0", t(vec![0.0; 8])).unwrap();
        s.put_tensor("stall_rank1_step0", t(vec![0.0; 8])).unwrap();
        assert_eq!(s.expire_ttl(), 0, "fresh generation survives");
        std::thread::sleep(Duration::from_millis(300));
        assert_eq!(s.expire_ttl(), 2, "both members of the stalled generation retired");
        assert_eq!(s.n_bytes(), 0);
        assert_eq!(s.counters.ttl_expired_keys.load(Ordering::Relaxed), 2);
        assert_eq!(s.counters.evicted_keys.load(Ordering::Relaxed), 2, "TTL counts as eviction");
        assert!(matches!(s.get_tensor("stall_rank0_step0"), Err(Error::KeyNotFound(_))));
    }

    #[test]
    fn ttl_expires_stalled_untracked_keys() {
        let s = Store::new();
        s.set_retention(RetentionConfig { window: 0, max_bytes: 0, ttl_ms: 150 });
        s.put_tensor("stable_rank0_latest", t(vec![1.0; 8])).unwrap();
        std::thread::sleep(Duration::from_millis(300));
        assert_eq!(s.expire_ttl(), 1);
        assert!(!s.exists("stable_rank0_latest"));
    }

    #[test]
    fn ttl_expired_data_is_first_eviction_victim_under_byte_pressure() {
        // A stalled field's expired window must not force Busy on an active
        // field: make_room reclaims expired data before giving up.
        let s = Store::new();
        // Cap fits two 40-byte generations total; both fields have window 2
        // protection, so without TTL the second field would get Busy.
        s.set_retention(RetentionConfig { window: 2, max_bytes: 80, ttl_ms: 120 });
        s.put_tensor("dead_rank0_step0", t(vec![0.0; 10])).unwrap();
        s.put_tensor("dead_rank0_step1", t(vec![1.0; 10])).unwrap();
        std::thread::sleep(Duration::from_millis(250));
        s.put_tensor("live_rank0_step0", t(vec![2.0; 10])).unwrap();
        assert!(s.exists("live_rank0_step0"));
        assert!(!s.exists("dead_rank0_step0") && !s.exists("dead_rank0_step1"));
        assert!(s.counters.ttl_expired_keys.load(Ordering::Relaxed) >= 2);
        assert_eq!(s.counters.busy_rejections.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn active_producers_never_hit_the_ttl() {
        // A producer advancing its window keeps every retained generation
        // younger than the TTL, so expiry is a no-op for it.
        let s = Store::new();
        s.set_retention(RetentionConfig { window: 2, max_bytes: 0, ttl_ms: 10_000 });
        for step in 0..5u64 {
            s.put_tensor(&format!("act_rank0_step{step}"), t(vec![0.0; 4])).unwrap();
        }
        assert_eq!(s.expire_ttl(), 0);
        assert_eq!(s.list_keys("act_").len(), 2);
    }

    // --- spill-to-disk cold tier --------------------------------------------

    fn spill_dir(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("situ_store_spill_{}_{name}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn window_retirement_feeds_the_cold_tier() {
        let dir = spill_dir("window");
        let s = Store::new();
        s.set_spill(Some(SpillConfig::new(&dir))).unwrap();
        s.set_retention(RetentionConfig::windowed(2, 0));
        for step in 0..5u64 {
            s.put_tensor(&format!("f_rank0_step{step}"), t(vec![step as f32; 8])).unwrap();
        }
        s.spill_sync();
        // The three retired generations replay byte-exact from the log...
        for step in 0..3u64 {
            let back = s.cold_get(&format!("f_rank0_step{step}")).unwrap();
            assert_eq!(back.to_f32().unwrap(), vec![step as f32; 8], "step {step}");
        }
        // ...while resident generations are hot-only.
        assert!(matches!(s.cold_get("f_rank0_step4"), Err(Error::KeyNotFound(_))));
        assert_eq!(
            s.cold_list("f_"),
            vec!["f_rank0_step0", "f_rank0_step1", "f_rank0_step2"]
        );
        let (keys, bytes, segments, hits, lost) = s.spill_counters();
        assert_eq!(keys, 3);
        assert_eq!(bytes, 3 * 32, "payload bytes, mirroring evicted_bytes");
        assert!(segments >= 1);
        assert_eq!(hits, 3);
        assert_eq!(lost, 0, "nothing shed or failed");
        // Per-field pressure carries the spill counters.
        let p = s.field_pressure();
        assert_eq!(p.len(), 1);
        assert_eq!((p[0].spilled_keys, p[0].spilled_bytes), (3, 3 * 32));
        assert_eq!(p[0].evicted_keys, 3, "spilled == evicted here");
        s.set_spill(None).unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn explicit_deletes_and_flush_do_not_spill() {
        let dir = spill_dir("nodel");
        let s = Store::new();
        s.set_spill(Some(SpillConfig::new(&dir))).unwrap();
        s.set_retention(RetentionConfig::windowed(4, 0));
        s.put_tensor("d_rank0_step0", t(vec![1.0; 4])).unwrap();
        s.put_tensor("d_rank0_step1", t(vec![2.0; 4])).unwrap();
        assert!(s.del_tensor("d_rank0_step0"));
        s.flush_all();
        s.spill_sync();
        assert_eq!(s.spill_counters().0, 0, "only retention victims spill");
        assert!(s.cold_list("").is_empty());
        s.set_spill(None).unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn cap_and_ttl_victims_spill_too() {
        let dir = spill_dir("capttl");
        let s = Store::new();
        s.set_spill(Some(SpillConfig::new(&dir))).unwrap();
        // LRU untracked victim under a byte cap spills under __untracked.
        s.set_retention(RetentionConfig::windowed(0, 128));
        s.put_tensor("loose_a", t(vec![1.0; 10])).unwrap();
        s.put_tensor("loose_b", t(vec![2.0; 10])).unwrap();
        s.put_tensor("loose_c", t(vec![3.0; 10])).unwrap();
        s.put_tensor("loose_d", t(vec![4.0; 10])).unwrap(); // evicts loose_a
        s.spill_sync();
        assert_eq!(
            s.cold_get("loose_a").unwrap().to_f32().unwrap(),
            vec![1.0; 10],
            "cap victim recoverable"
        );
        let p = s.field_pressure();
        assert!(
            p.iter().any(|f| f.field == "__untracked" && f.spilled_keys == 1),
            "untracked spill reported: {p:?}"
        );
        // TTL victims spill as well.  Clear the loose keys first (explicit
        // deletes — these never spill) so only the stalled field expires.
        for k in ["loose_a", "loose_b", "loose_c", "loose_d"] {
            s.del_tensor(k);
        }
        s.set_retention(RetentionConfig { window: 4, max_bytes: 0, ttl_ms: 100 });
        s.put_tensor("ttlf_rank0_step0", t(vec![7.0; 6])).unwrap();
        std::thread::sleep(Duration::from_millis(250));
        assert_eq!(s.expire_ttl(), 1);
        s.spill_sync();
        assert_eq!(
            s.cold_get("ttlf_rank0_step0").unwrap().to_f32().unwrap(),
            vec![7.0; 6]
        );
        s.set_spill(None).unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn cold_tier_survives_restart() {
        let dir = spill_dir("restart");
        {
            let s = Store::new();
            s.set_spill(Some(SpillConfig::new(&dir))).unwrap();
            s.set_retention(RetentionConfig::windowed(1, 0));
            for step in 0..3u64 {
                s.put_tensor(&format!("r_rank0_step{step}"), t(vec![step as f32; 8]))
                    .unwrap();
            }
            s.set_spill(None).unwrap(); // flush + join, like a clean shutdown
        }
        // A fresh store over the same directory replays the log and serves
        // the retired generations without any hot-tier state.
        let s = Store::new();
        s.set_spill(Some(SpillConfig::new(&dir))).unwrap();
        assert_eq!(s.cold_list("r_"), vec!["r_rank0_step0", "r_rank0_step1"]);
        for step in 0..2u64 {
            let back = s.cold_get(&format!("r_rank0_step{step}")).unwrap();
            assert_eq!(back.to_f32().unwrap(), vec![step as f32; 8]);
        }
        s.set_spill(None).unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn cold_reads_without_spill_are_clean_misses() {
        let s = Store::new();
        assert!(matches!(s.cold_get("anything"), Err(Error::KeyNotFound(_))));
        assert!(s.cold_list("").is_empty());
        assert_eq!(s.spill_counters(), (0, 0, 0, 0, 0));
        s.spill_sync(); // no-op, must not wedge
    }
}
