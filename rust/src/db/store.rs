//! Sharded in-memory key-value store holding tensors and metadata.
//!
//! Keys hash to one of `N_SHARDS` independently-locked shards, so concurrent
//! clients (one per simulation rank) rarely contend — the property the paper
//! relies on for "low-latency access to many clients in parallel".

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::error::{Error, Result};
use crate::tensor::Tensor;

const N_SHARDS: usize = 16;

#[derive(Default)]
struct Shard {
    tensors: HashMap<String, Tensor>,
    metas: HashMap<String, String>,
}

/// Operation counters exposed via `INFO` (and consumed by the benches).
#[derive(Debug, Default)]
pub struct Counters {
    pub ops: AtomicU64,
    pub bytes_in: AtomicU64,
    pub bytes_out: AtomicU64,
    /// Request *frames* received over TCP — one per client round trip, so a
    /// batched command counts 1 here while `ops` counts its entries.  The
    /// pipelining tests and the microbench read this to prove a gather
    /// costs one round trip.
    pub frames: AtomicU64,
}

/// The node-local store.
pub struct Store {
    shards: Vec<Mutex<Shard>>,
    bytes: AtomicU64,
    pub counters: Counters,
}

impl Default for Store {
    fn default() -> Self {
        Self::new()
    }
}

impl Store {
    pub fn new() -> Store {
        Store {
            shards: (0..N_SHARDS).map(|_| Mutex::new(Shard::default())).collect(),
            bytes: AtomicU64::new(0),
            counters: Counters::default(),
        }
    }

    fn shard(&self, key: &str) -> &Mutex<Shard> {
        // FNV-1a over the key.
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for b in key.as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(0x1000_0000_01b3);
        }
        &self.shards[(h % N_SHARDS as u64) as usize]
    }

    /// Insert or overwrite a tensor (the paper's `put_tensor`).
    ///
    /// Zero-copy: the shard takes the tensor's shared payload buffer by
    /// refcount — when the caller decoded it with `Request::decode_shared`,
    /// the stored payload *is* the wire frame's allocation.
    pub fn put_tensor(&self, key: &str, t: Tensor) -> Result<()> {
        t.validate()?;
        let new_bytes = t.nbytes() as u64;
        self.counters.ops.fetch_add(1, Ordering::Relaxed);
        self.counters.bytes_in.fetch_add(new_bytes, Ordering::Relaxed);
        let mut s = self.shard(key).lock().unwrap();
        // Overwrite in place: the steady-state path (each rank republishing
        // under a stable key) is one hash lookup with no post-insert
        // re-hash and no key `String` re-allocation.
        let mut incoming = Some(t);
        let old_bytes = s
            .tensors
            .get_mut(key)
            .map(|slot| std::mem::replace(slot, incoming.take().unwrap()).nbytes() as u64);
        if let Some(t) = incoming {
            s.tensors.insert(key.to_string(), t);
        }
        drop(s);
        if let Some(o) = old_bytes {
            self.bytes.fetch_sub(o, Ordering::Relaxed);
        }
        self.bytes.fetch_add(new_bytes, Ordering::Relaxed);
        Ok(())
    }

    /// Fetch a tensor (the paper's `unpack_tensor`).
    ///
    /// The returned tensor shares the stored payload by refcount — no deep
    /// copy under the shard lock.  A reader's view stays alive and valid
    /// even if the key is overwritten or deleted afterwards.
    pub fn get_tensor(&self, key: &str) -> Result<Tensor> {
        self.counters.ops.fetch_add(1, Ordering::Relaxed);
        let s = self.shard(key).lock().unwrap();
        let t = s
            .tensors
            .get(key)
            .cloned()
            .ok_or_else(|| Error::KeyNotFound(key.to_string()))?;
        self.counters
            .bytes_out
            .fetch_add(t.nbytes() as u64, Ordering::Relaxed);
        Ok(t)
    }

    pub fn del_tensor(&self, key: &str) -> bool {
        self.counters.ops.fetch_add(1, Ordering::Relaxed);
        let mut s = self.shard(key).lock().unwrap();
        if let Some(t) = s.tensors.remove(key) {
            self.bytes.fetch_sub(t.nbytes() as u64, Ordering::Relaxed);
            true
        } else {
            false
        }
    }

    pub fn exists(&self, key: &str) -> bool {
        self.counters.ops.fetch_add(1, Ordering::Relaxed);
        let s = self.shard(key).lock().unwrap();
        s.tensors.contains_key(key) || s.metas.contains_key(key)
    }

    /// Whether every key exists (tensor or metadata).  One counted op per
    /// probe regardless of the key count — the `PollKeys` fast path.
    pub fn exists_all(&self, keys: &[String]) -> bool {
        self.counters.ops.fetch_add(1, Ordering::Relaxed);
        keys.iter().all(|key| {
            let s = self.shard(key).lock().unwrap();
            s.tensors.contains_key(key) || s.metas.contains_key(key)
        })
    }

    pub fn put_meta(&self, key: &str, value: &str) {
        self.counters.ops.fetch_add(1, Ordering::Relaxed);
        let mut s = self.shard(key).lock().unwrap();
        s.metas.insert(key.to_string(), value.to_string());
    }

    pub fn get_meta(&self, key: &str) -> Result<String> {
        self.counters.ops.fetch_add(1, Ordering::Relaxed);
        let s = self.shard(key).lock().unwrap();
        s.metas
            .get(key)
            .cloned()
            .ok_or_else(|| Error::KeyNotFound(key.to_string()))
    }

    /// All tensor keys with a prefix, sorted (dataloader discovery).
    pub fn list_keys(&self, prefix: &str) -> Vec<String> {
        self.counters.ops.fetch_add(1, Ordering::Relaxed);
        let mut out = Vec::new();
        for sh in &self.shards {
            let s = sh.lock().unwrap();
            out.extend(s.tensors.keys().filter(|k| k.starts_with(prefix)).cloned());
        }
        out.sort();
        out
    }

    pub fn flush_all(&self) {
        self.counters.ops.fetch_add(1, Ordering::Relaxed);
        for sh in &self.shards {
            let mut s = sh.lock().unwrap();
            s.tensors.clear();
            s.metas.clear();
        }
        self.bytes.store(0, Ordering::Relaxed);
    }

    pub fn n_keys(&self) -> u64 {
        self.shards
            .iter()
            .map(|sh| {
                let s = sh.lock().unwrap();
                (s.tensors.len() + s.metas.len()) as u64
            })
            .sum()
    }

    pub fn n_bytes(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }

    pub fn n_ops(&self) -> u64 {
        self.counters.ops.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::DType;
    use crate::util::propcheck::{check, Gen};
    use std::collections::HashMap;
    use std::sync::Arc;

    fn t(v: Vec<f32>) -> Tensor {
        Tensor::from_f32(&[v.len()], v).unwrap()
    }

    #[test]
    fn put_get_del() {
        let s = Store::new();
        s.put_tensor("a", t(vec![1.0, 2.0])).unwrap();
        assert_eq!(s.get_tensor("a").unwrap().to_f32().unwrap(), vec![1.0, 2.0]);
        assert!(s.exists("a"));
        assert!(s.del_tensor("a"));
        assert!(!s.del_tensor("a"));
        assert!(matches!(s.get_tensor("a"), Err(Error::KeyNotFound(_))));
    }

    #[test]
    fn byte_accounting_on_overwrite() {
        let s = Store::new();
        s.put_tensor("k", t(vec![0.0; 100])).unwrap();
        assert_eq!(s.n_bytes(), 400);
        s.put_tensor("k", t(vec![0.0; 10])).unwrap();
        assert_eq!(s.n_bytes(), 40);
        s.del_tensor("k");
        assert_eq!(s.n_bytes(), 0);
    }

    #[test]
    fn meta_namespace_is_separate() {
        let s = Store::new();
        s.put_meta("step", "41");
        assert_eq!(s.get_meta("step").unwrap(), "41");
        assert!(s.get_tensor("step").is_err());
        assert!(s.exists("step"));
    }

    #[test]
    fn exists_all_spans_tensor_and_meta_namespaces() {
        let s = Store::new();
        s.put_tensor("a", t(vec![1.0])).unwrap();
        s.put_meta("b", "x");
        let have = |ks: &[&str]| s.exists_all(&ks.iter().map(|k| k.to_string()).collect::<Vec<_>>());
        assert!(have(&["a", "b"]));
        assert!(!have(&["a", "b", "c"]));
        assert!(have(&[]), "vacuously true on no keys");
    }

    #[test]
    fn list_keys_prefix_sorted() {
        let s = Store::new();
        for k in ["f_r1_s0", "f_r0_s0", "g_r0_s0"] {
            s.put_tensor(k, t(vec![0.0])).unwrap();
        }
        assert_eq!(s.list_keys("f_"), vec!["f_r0_s0", "f_r1_s0"]);
        assert_eq!(s.list_keys(""), vec!["f_r0_s0", "f_r1_s0", "g_r0_s0"]);
    }

    #[test]
    fn flush_resets_everything() {
        let s = Store::new();
        s.put_tensor("a", t(vec![1.0])).unwrap();
        s.put_meta("m", "x");
        s.flush_all();
        assert_eq!(s.n_keys(), 0);
        assert_eq!(s.n_bytes(), 0);
    }

    #[test]
    fn prop_store_matches_hashmap_model() {
        // Model-based property test: random op interleavings agree with a
        // plain HashMap reference model.
        check("store vs model", 100, |g: &mut Gen| {
            let s = Store::new();
            let mut model: HashMap<String, Vec<f32>> = HashMap::new();
            let keys: Vec<String> = (0..g.usize_in(1..=8)).map(|i| format!("k{i}")).collect();
            for _ in 0..g.usize_in(1..=60) {
                let key = g.choose(&keys).clone();
                match g.usize_in(0..=3) {
                    0 => {
                        let v: Vec<f32> = g.vec(1..=16, |g| g.normal_f32());
                        s.put_tensor(&key, t(v.clone())).unwrap();
                        model.insert(key, v);
                    }
                    1 => {
                        let got = s.get_tensor(&key).ok().map(|x| x.to_f32().unwrap());
                        assert_eq!(got, model.get(&key).cloned(), "get {key}");
                    }
                    2 => {
                        assert_eq!(s.del_tensor(&key), model.remove(&key).is_some());
                    }
                    _ => {
                        assert_eq!(s.exists(&key), model.contains_key(&key));
                    }
                }
            }
            let want_bytes: u64 = model.values().map(|v| 4 * v.len() as u64).sum();
            assert_eq!(s.n_bytes(), want_bytes);
            assert_eq!(s.n_keys(), model.len() as u64);
        });
    }

    #[test]
    fn concurrent_distinct_keys() {
        let s = Arc::new(Store::new());
        let mut handles = Vec::new();
        for r in 0..8 {
            let s = Arc::clone(&s);
            handles.push(std::thread::spawn(move || {
                for i in 0..50 {
                    let key = format!("rank{r}_step{i}");
                    s.put_tensor(&key, t(vec![r as f32, i as f32])).unwrap();
                    let back = s.get_tensor(&key).unwrap().to_f32().unwrap();
                    assert_eq!(back, vec![r as f32, i as f32]);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(s.n_keys(), 8 * 50);
    }

    #[test]
    fn get_tensor_is_refcount_clone_not_deep_copy() {
        let s = Store::new();
        let t0 = t(vec![1.0, 2.0, 3.0]);
        let put_handle = t0.data.clone();
        s.put_tensor("k", t0).unwrap();
        let a = s.get_tensor("k").unwrap();
        let b = s.get_tensor("k").unwrap();
        assert!(
            a.data.shares_allocation(&put_handle),
            "stored payload must be the exact buffer that was put"
        );
        assert!(a.data.shares_allocation(&b.data));
        assert_eq!(a.data.as_ptr(), b.data.as_ptr(), "pointer-identical payloads");
    }

    #[test]
    fn outstanding_views_survive_overwrite_and_delete() {
        let s = Store::new();
        s.put_tensor("k", t(vec![1.0, 2.0])).unwrap();
        let old = s.get_tensor("k").unwrap();
        s.put_tensor("k", t(vec![9.0])).unwrap();
        assert_eq!(old.to_f32().unwrap(), vec![1.0, 2.0], "view valid after overwrite");
        let newer = s.get_tensor("k").unwrap();
        assert!(s.del_tensor("k"));
        assert_eq!(newer.to_f32().unwrap(), vec![9.0], "view valid after delete");
        assert_eq!(s.n_bytes(), 0, "accounting ignores outstanding views");
    }

    #[test]
    fn concurrent_get_during_overwrite_no_torn_reads() {
        // Readers hammer a key while a writer overwrites it with
        // constant-valued tensors; aliasing semantics guarantee every read
        // observes one complete buffer, never a mix.
        let s = Arc::new(Store::new());
        s.put_tensor("k", t(vec![0.0; 256])).unwrap();
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let mut readers = Vec::new();
        for _ in 0..4 {
            let s = Arc::clone(&s);
            let stop = Arc::clone(&stop);
            readers.push(std::thread::spawn(move || {
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    let v = s.get_tensor("k").unwrap().to_f32().unwrap();
                    let first = v[0];
                    assert!(v.iter().all(|&x| x == first), "torn read: {first} vs mix");
                }
            }));
        }
        for i in 1..=200 {
            s.put_tensor("k", t(vec![i as f32; 256])).unwrap();
        }
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
        for h in readers {
            h.join().unwrap();
        }
    }

    #[test]
    fn rejects_invalid_tensor() {
        let s = Store::new();
        let bad = Tensor { dtype: DType::F32, shape: vec![4], data: vec![0u8; 3].into() };
        assert!(s.put_tensor("x", bad).is_err());
        assert_eq!(s.n_keys(), 0);
    }
}
