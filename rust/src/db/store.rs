//! Sharded in-memory key-value store holding tensors and metadata.
//!
//! Keys hash to one of `N_SHARDS` independently-locked shards, so concurrent
//! clients (one per simulation rank) rarely contend — the property the paper
//! relies on for "low-latency access to many clients in parallel".
//!
//! # Capacity governance and retention
//!
//! Keeping training data in memory makes memory the binding constraint for
//! long-running simulations; the paper resolves it by retiring snapshots
//! rather than appending forever (§2, §4 — the same moving-window discipline
//! the SmartSim ocean-modeling and OpenFOAM couplings use).  The store
//! implements that as an optional [`RetentionConfig`]:
//!
//! * **Sliding window** — tensor keys following the framework scheme
//!   `{field}_rank{r}_step{s}` are grouped into *generations* (one per
//!   `(field, step)`).  With `window = W > 0`, once a field accumulates more
//!   than `W` generations the oldest is retired on the spot, so steady-state
//!   footprint is `W` generations per field regardless of run length.
//! * **Byte cap** — with `max_bytes > 0` a write that would exceed the cap
//!   first evicts the oldest generations *outside* every field's protected
//!   window, then falls back to least-recently-used eviction of untracked
//!   keys (keys that don't parse as step keys, e.g. the overwrite-mode
//!   `{field}_rank{r}_latest` scheme).  If nothing evictable remains the
//!   write is rejected with [`Error::Busy`] — explicit producer
//!   backpressure instead of OOM.
//!
//! Metadata entries are not byte-accounted (they are tiny strings) and are
//! never evicted.  Both limits default to 0 (= the seed's unbounded append
//! behavior), and the governed bookkeeping is only engaged when a policy is
//! set: ungoverned puts take exactly the old lock-per-shard fast path.
//!
//! Lock order: the retention index mutex is always acquired *before* any
//! shard mutex, never the reverse — eviction (index → shards) can therefore
//! never deadlock against writes.

use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

use crate::error::{Error, Result};
use crate::tensor::Tensor;

const N_SHARDS: usize = 16;

#[derive(Default)]
struct Shard {
    tensors: HashMap<String, Tensor>,
    metas: HashMap<String, String>,
}

/// Retention / capacity policy for one store instance.  `0` disables a
/// limit; the default is fully unbounded (the seed behavior).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct RetentionConfig {
    /// Newest step generations kept per field.  When a field accumulates
    /// more than `window` generations the oldest is retired immediately.
    /// `0` disables the window; under a byte cap only the newest generation
    /// of each field is then protected from eviction.
    pub window: u64,
    /// Byte capacity for tensor payloads.  A write that cannot fit even
    /// after eviction fails with [`Error::Busy`].  `0` = unbounded.
    pub max_bytes: u64,
}

impl RetentionConfig {
    pub const UNBOUNDED: RetentionConfig = RetentionConfig { window: 0, max_bytes: 0 };

    pub fn is_unbounded(&self) -> bool {
        self.window == 0 && self.max_bytes == 0
    }
}

/// Parse the framework key scheme `{field}_rank{r}_step{s}` into the
/// generation identity `(field, step)`.  Keys that don't follow the scheme
/// (e.g. the overwrite-mode `{field}_rank{r}_latest`) return `None` and
/// fall under LRU retention instead of the sliding window.
pub fn parse_step_key(key: &str) -> Option<(&str, u64)> {
    let si = key.rfind("_step")?;
    let step = parse_digits(&key[si + "_step".len()..])?;
    let head = &key[..si];
    let ri = head.rfind("_rank")?;
    parse_digits(&head[ri + "_rank".len()..])?;
    Some((&head[..ri], step))
}

fn parse_digits(s: &str) -> Option<u64> {
    if s.is_empty() || !s.bytes().all(|b| b.is_ascii_digit()) {
        return None;
    }
    s.parse().ok()
}

/// Operation counters exposed via `INFO` (and consumed by the benches).
#[derive(Debug, Default)]
pub struct Counters {
    pub ops: AtomicU64,
    pub bytes_in: AtomicU64,
    pub bytes_out: AtomicU64,
    /// Request *frames* received over TCP — one per client round trip, so a
    /// batched command counts 1 here while `ops` counts its entries.  The
    /// pipelining tests and the microbench read this to prove a gather
    /// costs one round trip.
    pub frames: AtomicU64,
    /// Tensor keys removed by the retention policy (window retirement plus
    /// byte-cap eviction); explicit `del` operations do not count.
    pub evicted_keys: AtomicU64,
    /// Payload bytes freed by eviction.
    pub evicted_bytes: AtomicU64,
    /// Writes rejected with [`Error::Busy`] because nothing evictable
    /// remained under the byte cap.
    pub busy_rejections: AtomicU64,
}

#[derive(Debug, Clone, Copy)]
struct UntrackedEntry {
    bytes: u64,
    /// Monotonic recency stamp (bumped on put and get) — the LRU key.
    tick: u64,
}

/// Bookkeeping behind the retention policy.  Mirrors the tensor namespace
/// exactly while governance is enabled: every tensor key is either a member
/// of a `(field, step)` generation or an untracked LRU entry.
#[derive(Default)]
struct RetentionIndex {
    cfg: RetentionConfig,
    /// field → step → members `(key, bytes)` of that generation.
    gens: BTreeMap<String, BTreeMap<u64, Vec<(String, u64)>>>,
    untracked: HashMap<String, UntrackedEntry>,
    tick: u64,
}

impl RetentionIndex {
    fn size_of(&self, key: &str) -> u64 {
        match parse_step_key(key) {
            Some((field, step)) => self
                .gens
                .get(field)
                .and_then(|steps| steps.get(&step))
                .and_then(|m| m.iter().find(|(k, _)| k.as_str() == key))
                .map(|(_, b)| *b)
                .unwrap_or(0),
            None => self.untracked.get(key).map(|e| e.bytes).unwrap_or(0),
        }
    }

    fn record_put(&mut self, key: &str, bytes: u64) {
        match parse_step_key(key) {
            Some((field, step)) => {
                let members = self
                    .gens
                    .entry(field.to_string())
                    .or_default()
                    .entry(step)
                    .or_default();
                match members.iter_mut().find(|(k, _)| k.as_str() == key) {
                    Some(m) => m.1 = bytes,
                    None => members.push((key.to_string(), bytes)),
                }
            }
            None => {
                self.tick += 1;
                let tick = self.tick;
                self.untracked.insert(key.to_string(), UntrackedEntry { bytes, tick });
            }
        }
    }

    fn record_del(&mut self, key: &str) {
        match parse_step_key(key) {
            Some((field, step)) => {
                let mut field_empty = false;
                if let Some(steps) = self.gens.get_mut(field) {
                    let mut gen_empty = false;
                    if let Some(members) = steps.get_mut(&step) {
                        members.retain(|(k, _)| k.as_str() != key);
                        gen_empty = members.is_empty();
                    }
                    if gen_empty {
                        steps.remove(&step);
                    }
                    field_empty = steps.is_empty();
                }
                if field_empty {
                    self.gens.remove(field);
                }
            }
            None => {
                self.untracked.remove(key);
            }
        }
    }

    fn touch(&mut self, key: &str) {
        self.tick += 1;
        let tick = self.tick;
        if let Some(e) = self.untracked.get_mut(key) {
            e.tick = tick;
        }
    }

    fn gen_count(&self, field: &str) -> usize {
        self.gens.get(field).map_or(0, |s| s.len())
    }

    fn oldest_step(&self, field: &str) -> Option<u64> {
        self.gens.get(field).and_then(|s| s.keys().next().copied())
    }

    /// Oldest generation eviction may take under byte pressure: one beyond
    /// its field's protected window (the newest `window` generations, or
    /// just the newest one when `window == 0`).
    ///
    /// The incoming key's own generation participates in the ordering: an
    /// append that opens generation `W+1` may retire the oldest resident
    /// one to make room for itself, but a *stale* write (a restarted
    /// producer replaying an old step) ranks below the retained window and
    /// therefore may never displace newer data — it gets backpressure
    /// instead.
    fn oldest_evictable_gen(&self, incoming: Option<(&str, u64)>) -> Option<(String, u64)> {
        let protect = if self.cfg.window > 0 { self.cfg.window as usize } else { 1 };
        let mut best: Option<(String, u64)> = None;
        for (field, steps) in &self.gens {
            let inc_step = match incoming {
                Some((f, s)) if f == field.as_str() => Some(s),
                _ => None,
            };
            // Combined ordering of resident generations plus the incoming
            // one (tiny: at most window + slack entries per field).
            let mut combined: Vec<u64> = steps.keys().copied().collect();
            if let Some(s) = inc_step {
                if !steps.contains_key(&s) {
                    combined.push(s);
                    combined.sort_unstable();
                }
            }
            if combined.len() <= protect {
                continue;
            }
            let evictable = combined.len() - protect;
            for &step in combined.iter().take(evictable) {
                if inc_step == Some(step) {
                    // The generation being written occupies this evictable
                    // slot itself; nothing newer is sacrificed for it.
                    continue;
                }
                let older = match &best {
                    None => true,
                    Some((_, bs)) => step < *bs,
                };
                if older {
                    best = Some((field.clone(), step));
                }
                break;
            }
        }
        best
    }

    /// Least-recently-used untracked key, excluding the one being written.
    fn lru_untracked(&self, exclude: &str) -> Option<String> {
        self.untracked
            .iter()
            .filter(|(k, _)| k.as_str() != exclude)
            .min_by_key(|(_, e)| e.tick)
            .map(|(k, _)| k.clone())
    }

    fn clear(&mut self) {
        self.gens.clear();
        self.untracked.clear();
    }
}

/// The node-local store.
pub struct Store {
    shards: Vec<Mutex<Shard>>,
    bytes: AtomicU64,
    /// Lifetime high-water mark of `bytes` (never reset, even by flush).
    high_water: AtomicU64,
    /// Whether a retention policy is active.  Checked lock-free on the hot
    /// path so ungoverned stores pay nothing for the subsystem.
    governed: AtomicBool,
    retention: Mutex<RetentionIndex>,
    pub counters: Counters,
}

impl Default for Store {
    fn default() -> Self {
        Self::new()
    }
}

impl Store {
    pub fn new() -> Store {
        Store {
            shards: (0..N_SHARDS).map(|_| Mutex::new(Shard::default())).collect(),
            bytes: AtomicU64::new(0),
            high_water: AtomicU64::new(0),
            governed: AtomicBool::new(false),
            retention: Mutex::new(RetentionIndex::default()),
            counters: Counters::default(),
        }
    }

    fn shard(&self, key: &str) -> &Mutex<Shard> {
        // FNV-1a over the key.
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for b in key.as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(0x1000_0000_01b3);
        }
        &self.shards[(h % N_SHARDS as u64) as usize]
    }

    /// Install (or change) the retention policy and enforce it immediately.
    ///
    /// Enabling governance on a populated store rebuilds the index from the
    /// shards; writes racing the very enable may stay untracked until their
    /// next overwrite (byte accounting stays exact either way — only their
    /// eviction eligibility is delayed).
    pub fn set_retention(&self, cfg: RetentionConfig) {
        // Raise the flag before rebuilding so racing writes start taking
        // the governed (index-maintaining) path while we scan.
        let was = self.governed.swap(!cfg.is_unbounded(), Ordering::SeqCst);
        let mut ret = self.retention.lock().unwrap();
        ret.cfg = cfg;
        if cfg.is_unbounded() {
            ret.clear();
            return;
        }
        if !was {
            ret.clear();
            for sh in &self.shards {
                let s = sh.lock().unwrap();
                for (k, t) in &s.tensors {
                    ret.record_put(k, t.nbytes() as u64);
                }
            }
        }
        self.enforce(&mut ret);
    }

    pub fn retention(&self) -> RetentionConfig {
        self.retention.lock().unwrap().cfg
    }

    /// Shard insert plus byte / high-water accounting, shared by the
    /// governed and ungoverned put paths.
    ///
    /// Zero-copy: the shard takes the tensor's shared payload buffer by
    /// refcount — when the caller decoded it with `Request::decode_shared`,
    /// the stored payload *is* the wire frame's allocation.  Overwrites
    /// replace in place: one hash lookup, no post-insert re-hash and no key
    /// `String` re-allocation on the steady-state republish path.
    fn insert_tensor(&self, key: &str, t: Tensor, new_bytes: u64) {
        let mut s = self.shard(key).lock().unwrap();
        let mut incoming = Some(t);
        let old_bytes = s
            .tensors
            .get_mut(key)
            .map(|slot| std::mem::replace(slot, incoming.take().unwrap()).nbytes() as u64);
        if let Some(t) = incoming {
            s.tensors.insert(key.to_string(), t);
        }
        drop(s);
        if let Some(o) = old_bytes {
            self.bytes.fetch_sub(o, Ordering::Relaxed);
        }
        let now = self.bytes.fetch_add(new_bytes, Ordering::Relaxed) + new_bytes;
        self.high_water.fetch_max(now, Ordering::Relaxed);
    }

    /// Insert or overwrite a tensor (the paper's `put_tensor`).
    ///
    /// Under a byte cap this may evict retired generations / LRU untracked
    /// keys first, and fails with [`Error::Busy`] when the payload cannot
    /// fit even then.
    pub fn put_tensor(&self, key: &str, t: Tensor) -> Result<()> {
        t.validate()?;
        let new_bytes = t.nbytes() as u64;
        self.counters.ops.fetch_add(1, Ordering::Relaxed);
        self.counters.bytes_in.fetch_add(new_bytes, Ordering::Relaxed);
        if !self.governed.load(Ordering::Acquire) {
            self.insert_tensor(key, t, new_bytes);
            // Governance may have been enabled while we inserted, in which
            // case the rebuild scan can have passed our shard before the
            // insert landed.  The scan runs after the flag is raised and
            // synchronizes through the shard mutex, so re-checking here is
            // guaranteed to observe the flag — self-heal the index rather
            // than leave a resident key invisible to retention forever.
            if self.governed.load(Ordering::Acquire) {
                self.retention.lock().unwrap().record_put(key, new_bytes);
            }
            return Ok(());
        }
        let mut ret = self.retention.lock().unwrap();
        if ret.cfg.max_bytes > 0 {
            self.make_room(&mut ret, key, new_bytes)?;
        }
        self.insert_tensor(key, t, new_bytes);
        ret.record_put(key, new_bytes);
        if ret.cfg.window > 0 {
            if let Some((field, _)) = parse_step_key(key) {
                let field = field.to_string();
                self.retire_over_window(&mut ret, &field);
            }
        }
        Ok(())
    }

    /// Evict until a `new_bytes` write of `key` fits under the byte cap.
    fn make_room(&self, ret: &mut RetentionIndex, key: &str, new_bytes: u64) -> Result<()> {
        let cap = ret.cfg.max_bytes;
        if new_bytes > cap {
            self.counters.busy_rejections.fetch_add(1, Ordering::Relaxed);
            return Err(Error::Busy(format!(
                "tensor of {new_bytes} bytes exceeds the store capacity of {cap} bytes"
            )));
        }
        let incoming = parse_step_key(key);
        loop {
            let resident = self.bytes.load(Ordering::Relaxed);
            let projected = resident.saturating_sub(ret.size_of(key)) + new_bytes;
            if projected <= cap {
                return Ok(());
            }
            if let Some((field, step)) = ret.oldest_evictable_gen(incoming) {
                self.evict_generation(ret, &field, step);
            } else if let Some(victim) = ret.lru_untracked(key) {
                self.evict_untracked(ret, &victim);
            } else {
                self.counters.busy_rejections.fetch_add(1, Ordering::Relaxed);
                return Err(Error::Busy(format!(
                    "put of {new_bytes} bytes cannot fit under max_bytes={cap} \
                     ({resident} bytes resident, all within the retention window)"
                )));
            }
        }
    }

    /// Retire the oldest generations of `field` until at most `window`
    /// remain (the sliding-window policy).
    fn retire_over_window(&self, ret: &mut RetentionIndex, field: &str) {
        let window = ret.cfg.window as usize;
        while ret.gen_count(field) > window {
            let Some(step) = ret.oldest_step(field) else { break };
            self.evict_generation(ret, field, step);
        }
    }

    /// Remove every member of generation `(field, step)` from the index and
    /// the shards.
    fn evict_generation(&self, ret: &mut RetentionIndex, field: &str, step: u64) {
        let mut field_empty = false;
        let members = match ret.gens.get_mut(field) {
            Some(steps) => match steps.remove(&step) {
                Some(m) => {
                    field_empty = steps.is_empty();
                    m
                }
                None => return,
            },
            None => return,
        };
        if field_empty {
            ret.gens.remove(field);
        }
        for (key, _) in &members {
            self.evict_one(key);
        }
    }

    fn evict_untracked(&self, ret: &mut RetentionIndex, key: &str) {
        ret.untracked.remove(key);
        self.evict_one(key);
    }

    /// Remove `key` from its shard, charging eviction counters with the
    /// actual stored size.
    fn evict_one(&self, key: &str) {
        let removed = { self.shard(key).lock().unwrap().tensors.remove(key) };
        if let Some(t) = removed {
            let b = t.nbytes() as u64;
            self.bytes.fetch_sub(b, Ordering::Relaxed);
            self.counters.evicted_keys.fetch_add(1, Ordering::Relaxed);
            self.counters.evicted_bytes.fetch_add(b, Ordering::Relaxed);
        }
    }

    /// Apply the current policy to the resident set (used when the policy
    /// changes): window retirement per field, then best-effort eviction
    /// down to the byte cap.  Anything left over the cap is protected and
    /// will backpressure future puts instead.
    fn enforce(&self, ret: &mut RetentionIndex) {
        if ret.cfg.window > 0 {
            let fields: Vec<String> = ret.gens.keys().cloned().collect();
            for field in fields {
                self.retire_over_window(ret, &field);
            }
        }
        let cap = ret.cfg.max_bytes;
        if cap > 0 {
            while self.bytes.load(Ordering::Relaxed) > cap {
                if let Some((field, step)) = ret.oldest_evictable_gen(None) {
                    self.evict_generation(ret, &field, step);
                } else if let Some(victim) = ret.lru_untracked("") {
                    self.evict_untracked(ret, &victim);
                } else {
                    break;
                }
            }
        }
    }

    /// Fetch a tensor (the paper's `unpack_tensor`).
    ///
    /// The returned tensor shares the stored payload by refcount — no deep
    /// copy under the shard lock.  A reader's view stays alive and valid
    /// even if the key is overwritten, deleted or evicted afterwards.
    pub fn get_tensor(&self, key: &str) -> Result<Tensor> {
        self.counters.ops.fetch_add(1, Ordering::Relaxed);
        let t = {
            let s = self.shard(key).lock().unwrap();
            s.tensors.get(key).cloned()
        }
        .ok_or_else(|| Error::KeyNotFound(key.to_string()))?;
        self.counters
            .bytes_out
            .fetch_add(t.nbytes() as u64, Ordering::Relaxed);
        // LRU recency for untracked keys under governance (the shard lock
        // is already released — retention before shard, never after).
        if self.governed.load(Ordering::Relaxed) && parse_step_key(key).is_none() {
            self.retention.lock().unwrap().touch(key);
        }
        Ok(t)
    }

    pub fn del_tensor(&self, key: &str) -> bool {
        self.counters.ops.fetch_add(1, Ordering::Relaxed);
        if !self.governed.load(Ordering::Acquire) {
            let removed = { self.shard(key).lock().unwrap().tensors.remove(key) };
            if let Some(t) = removed {
                self.bytes.fetch_sub(t.nbytes() as u64, Ordering::Relaxed);
                // Mirror of the put path's enable-race self-heal: drop any
                // index entry the rebuild scan recorded before our delete.
                if self.governed.load(Ordering::Acquire) {
                    self.retention.lock().unwrap().record_del(key);
                }
                return true;
            }
            return false;
        }
        let mut ret = self.retention.lock().unwrap();
        let removed = { self.shard(key).lock().unwrap().tensors.remove(key) };
        match removed {
            Some(t) => {
                self.bytes.fetch_sub(t.nbytes() as u64, Ordering::Relaxed);
                ret.record_del(key);
                true
            }
            None => false,
        }
    }

    pub fn exists(&self, key: &str) -> bool {
        self.counters.ops.fetch_add(1, Ordering::Relaxed);
        let s = self.shard(key).lock().unwrap();
        s.tensors.contains_key(key) || s.metas.contains_key(key)
    }

    /// Whether every key exists (tensor or metadata).  One counted op per
    /// probe regardless of the key count — the `PollKeys` fast path.
    pub fn exists_all(&self, keys: &[String]) -> bool {
        self.counters.ops.fetch_add(1, Ordering::Relaxed);
        keys.iter().all(|key| {
            let s = self.shard(key).lock().unwrap();
            s.tensors.contains_key(key) || s.metas.contains_key(key)
        })
    }

    pub fn put_meta(&self, key: &str, value: &str) {
        self.counters.ops.fetch_add(1, Ordering::Relaxed);
        let mut s = self.shard(key).lock().unwrap();
        s.metas.insert(key.to_string(), value.to_string());
    }

    pub fn get_meta(&self, key: &str) -> Result<String> {
        self.counters.ops.fetch_add(1, Ordering::Relaxed);
        let s = self.shard(key).lock().unwrap();
        s.metas
            .get(key)
            .cloned()
            .ok_or_else(|| Error::KeyNotFound(key.to_string()))
    }

    /// All tensor keys with a prefix, sorted (dataloader discovery).
    pub fn list_keys(&self, prefix: &str) -> Vec<String> {
        self.counters.ops.fetch_add(1, Ordering::Relaxed);
        let mut out = Vec::new();
        for sh in &self.shards {
            let s = sh.lock().unwrap();
            out.extend(s.tensors.keys().filter(|k| k.starts_with(prefix)).cloned());
        }
        out.sort();
        out
    }

    pub fn flush_all(&self) {
        self.counters.ops.fetch_add(1, Ordering::Relaxed);
        let mut ret = self.retention.lock().unwrap();
        ret.clear();
        for sh in &self.shards {
            let mut s = sh.lock().unwrap();
            s.tensors.clear();
            s.metas.clear();
        }
        self.bytes.store(0, Ordering::Relaxed);
    }

    pub fn n_keys(&self) -> u64 {
        self.shards
            .iter()
            .map(|sh| {
                let s = sh.lock().unwrap();
                (s.tensors.len() + s.metas.len()) as u64
            })
            .sum()
    }

    pub fn n_bytes(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }

    /// Lifetime high-water mark of resident tensor bytes.
    pub fn high_water_bytes(&self) -> u64 {
        self.high_water.load(Ordering::Relaxed)
    }

    pub fn n_ops(&self) -> u64 {
        self.counters.ops.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::DType;
    use crate::util::propcheck::{check, Gen};
    use std::collections::HashMap;
    use std::sync::Arc;

    fn t(v: Vec<f32>) -> Tensor {
        Tensor::from_f32(&[v.len()], v).unwrap()
    }

    #[test]
    fn put_get_del() {
        let s = Store::new();
        s.put_tensor("a", t(vec![1.0, 2.0])).unwrap();
        assert_eq!(s.get_tensor("a").unwrap().to_f32().unwrap(), vec![1.0, 2.0]);
        assert!(s.exists("a"));
        assert!(s.del_tensor("a"));
        assert!(!s.del_tensor("a"));
        assert!(matches!(s.get_tensor("a"), Err(Error::KeyNotFound(_))));
    }

    #[test]
    fn byte_accounting_on_overwrite() {
        let s = Store::new();
        s.put_tensor("k", t(vec![0.0; 100])).unwrap();
        assert_eq!(s.n_bytes(), 400);
        s.put_tensor("k", t(vec![0.0; 10])).unwrap();
        assert_eq!(s.n_bytes(), 40);
        assert_eq!(s.high_water_bytes(), 400, "high-water survives shrink");
        s.del_tensor("k");
        assert_eq!(s.n_bytes(), 0);
    }

    #[test]
    fn meta_namespace_is_separate() {
        let s = Store::new();
        s.put_meta("step", "41");
        assert_eq!(s.get_meta("step").unwrap(), "41");
        assert!(s.get_tensor("step").is_err());
        assert!(s.exists("step"));
    }

    #[test]
    fn exists_all_spans_tensor_and_meta_namespaces() {
        let s = Store::new();
        s.put_tensor("a", t(vec![1.0])).unwrap();
        s.put_meta("b", "x");
        let have = |ks: &[&str]| s.exists_all(&ks.iter().map(|k| k.to_string()).collect::<Vec<_>>());
        assert!(have(&["a", "b"]));
        assert!(!have(&["a", "b", "c"]));
        assert!(have(&[]), "vacuously true on no keys");
    }

    #[test]
    fn list_keys_prefix_sorted() {
        let s = Store::new();
        for k in ["f_r1_s0", "f_r0_s0", "g_r0_s0"] {
            s.put_tensor(k, t(vec![0.0])).unwrap();
        }
        assert_eq!(s.list_keys("f_"), vec!["f_r0_s0", "f_r1_s0"]);
        assert_eq!(s.list_keys(""), vec!["f_r0_s0", "f_r1_s0", "g_r0_s0"]);
    }

    #[test]
    fn flush_resets_everything() {
        let s = Store::new();
        s.put_tensor("a", t(vec![1.0])).unwrap();
        s.put_meta("m", "x");
        s.flush_all();
        assert_eq!(s.n_keys(), 0);
        assert_eq!(s.n_bytes(), 0);
    }

    #[test]
    fn prop_store_matches_hashmap_model() {
        // Model-based property test: random op interleavings agree with a
        // plain HashMap reference model.
        check("store vs model", 100, |g: &mut Gen| {
            let s = Store::new();
            let mut model: HashMap<String, Vec<f32>> = HashMap::new();
            let keys: Vec<String> = (0..g.usize_in(1..=8)).map(|i| format!("k{i}")).collect();
            for _ in 0..g.usize_in(1..=60) {
                let key = g.choose(&keys).clone();
                match g.usize_in(0..=3) {
                    0 => {
                        let v: Vec<f32> = g.vec(1..=16, |g| g.normal_f32());
                        s.put_tensor(&key, t(v.clone())).unwrap();
                        model.insert(key, v);
                    }
                    1 => {
                        let got = s.get_tensor(&key).ok().map(|x| x.to_f32().unwrap());
                        assert_eq!(got, model.get(&key).cloned(), "get {key}");
                    }
                    2 => {
                        assert_eq!(s.del_tensor(&key), model.remove(&key).is_some());
                    }
                    _ => {
                        assert_eq!(s.exists(&key), model.contains_key(&key));
                    }
                }
            }
            let want_bytes: u64 = model.values().map(|v| 4 * v.len() as u64).sum();
            assert_eq!(s.n_bytes(), want_bytes);
            assert_eq!(s.n_keys(), model.len() as u64);
        });
    }

    #[test]
    fn concurrent_distinct_keys() {
        let s = Arc::new(Store::new());
        let mut handles = Vec::new();
        for r in 0..8 {
            let s = Arc::clone(&s);
            handles.push(std::thread::spawn(move || {
                for i in 0..50 {
                    let key = format!("rank{r}_step{i}");
                    s.put_tensor(&key, t(vec![r as f32, i as f32])).unwrap();
                    let back = s.get_tensor(&key).unwrap().to_f32().unwrap();
                    assert_eq!(back, vec![r as f32, i as f32]);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(s.n_keys(), 8 * 50);
    }

    #[test]
    fn get_tensor_is_refcount_clone_not_deep_copy() {
        let s = Store::new();
        let t0 = t(vec![1.0, 2.0, 3.0]);
        let put_handle = t0.data.clone();
        s.put_tensor("k", t0).unwrap();
        let a = s.get_tensor("k").unwrap();
        let b = s.get_tensor("k").unwrap();
        assert!(
            a.data.shares_allocation(&put_handle),
            "stored payload must be the exact buffer that was put"
        );
        assert!(a.data.shares_allocation(&b.data));
        assert_eq!(a.data.as_ptr(), b.data.as_ptr(), "pointer-identical payloads");
    }

    #[test]
    fn outstanding_views_survive_overwrite_and_delete() {
        let s = Store::new();
        s.put_tensor("k", t(vec![1.0, 2.0])).unwrap();
        let old = s.get_tensor("k").unwrap();
        s.put_tensor("k", t(vec![9.0])).unwrap();
        assert_eq!(old.to_f32().unwrap(), vec![1.0, 2.0], "view valid after overwrite");
        let newer = s.get_tensor("k").unwrap();
        assert!(s.del_tensor("k"));
        assert_eq!(newer.to_f32().unwrap(), vec![9.0], "view valid after delete");
        assert_eq!(s.n_bytes(), 0, "accounting ignores outstanding views");
    }

    #[test]
    fn concurrent_get_during_overwrite_no_torn_reads() {
        // Readers hammer a key while a writer overwrites it with
        // constant-valued tensors; aliasing semantics guarantee every read
        // observes one complete buffer, never a mix.
        let s = Arc::new(Store::new());
        s.put_tensor("k", t(vec![0.0; 256])).unwrap();
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let mut readers = Vec::new();
        for _ in 0..4 {
            let s = Arc::clone(&s);
            let stop = Arc::clone(&stop);
            readers.push(std::thread::spawn(move || {
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    let v = s.get_tensor("k").unwrap().to_f32().unwrap();
                    let first = v[0];
                    assert!(v.iter().all(|&x| x == first), "torn read: {first} vs mix");
                }
            }));
        }
        for i in 1..=200 {
            s.put_tensor("k", t(vec![i as f32; 256])).unwrap();
        }
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
        for h in readers {
            h.join().unwrap();
        }
    }

    #[test]
    fn rejects_invalid_tensor() {
        let s = Store::new();
        let bad = Tensor { dtype: DType::F32, shape: vec![4], data: vec![0u8; 3].into() };
        assert!(s.put_tensor("x", bad).is_err());
        assert_eq!(s.n_keys(), 0);
    }

    // --- retention ---------------------------------------------------------

    #[test]
    fn parse_step_key_accepts_the_framework_scheme_only() {
        assert_eq!(parse_step_key("field_rank0_step2"), Some(("field", 2)));
        assert_eq!(parse_step_key("u_x_rank12_step34"), Some(("u_x", 34)));
        assert_eq!(parse_step_key("f_rank0_step007"), Some(("f", 7)));
        assert_eq!(parse_step_key("field_rank0_latest"), None, "overwrite scheme");
        assert_eq!(parse_step_key("field_step2"), None, "no rank segment");
        assert_eq!(parse_step_key("field_rank0_step"), None, "empty step digits");
        assert_eq!(parse_step_key("field_rankx_step2"), None, "non-numeric rank");
        assert_eq!(parse_step_key("field_rank0_step2x"), None, "trailing junk");
        assert_eq!(parse_step_key("plain"), None);
    }

    #[test]
    fn sliding_window_retires_oldest_generation() {
        let s = Store::new();
        s.set_retention(RetentionConfig { window: 2, max_bytes: 0 });
        for step in 0..5u64 {
            for rank in 0..3 {
                s.put_tensor(&format!("f_rank{rank}_step{step}"), t(vec![step as f32; 8]))
                    .unwrap();
            }
        }
        let keys = s.list_keys("f_");
        assert_eq!(keys.len(), 2 * 3, "two generations of three ranks");
        assert!(keys.iter().all(|k| k.ends_with("step3") || k.ends_with("step4")), "{keys:?}");
        assert_eq!(s.counters.evicted_keys.load(Ordering::Relaxed), 3 * 3);
        assert_eq!(
            s.counters.evicted_bytes.load(Ordering::Relaxed),
            3 * 3 * 32,
            "every evicted tensor was 32 bytes"
        );
        assert_eq!(s.n_bytes(), 6 * 32, "flat steady state");
    }

    #[test]
    fn windows_are_per_field() {
        let s = Store::new();
        s.set_retention(RetentionConfig { window: 1, max_bytes: 0 });
        for step in 0..3u64 {
            s.put_tensor(&format!("a_rank0_step{step}"), t(vec![1.0])).unwrap();
            s.put_tensor(&format!("b_rank0_step{step}"), t(vec![2.0])).unwrap();
        }
        assert_eq!(s.list_keys(""), vec!["a_rank0_step2", "b_rank0_step2"]);
    }

    #[test]
    fn byte_cap_evicts_lru_untracked_keys() {
        let s = Store::new();
        // 3 × 40-byte untracked tensors fit under 128 bytes; the 4th evicts
        // the least recently *used* one.
        s.set_retention(RetentionConfig { window: 0, max_bytes: 128 });
        s.put_tensor("a", t(vec![0.0; 10])).unwrap();
        s.put_tensor("b", t(vec![0.0; 10])).unwrap();
        s.put_tensor("c", t(vec![0.0; 10])).unwrap();
        s.get_tensor("a").unwrap(); // touch: a is now more recent than b
        s.put_tensor("d", t(vec![0.0; 10])).unwrap();
        assert!(!s.exists("b"), "LRU victim");
        assert!(s.exists("a") && s.exists("c") && s.exists("d"));
        assert_eq!(s.counters.evicted_keys.load(Ordering::Relaxed), 1);
        assert!(s.n_bytes() <= 128);
    }

    #[test]
    fn byte_cap_append_retires_own_field_oldest_generation() {
        let s = Store::new();
        // Cap fits exactly two 40-byte generations; window 2 protects both,
        // but an append opening generation 3 may retire generation 0.
        s.set_retention(RetentionConfig { window: 2, max_bytes: 80 });
        s.put_tensor("f_rank0_step0", t(vec![0.0; 10])).unwrap();
        s.put_tensor("f_rank0_step1", t(vec![1.0; 10])).unwrap();
        s.put_tensor("f_rank0_step2", t(vec![2.0; 10])).unwrap();
        assert!(!s.exists("f_rank0_step0"));
        assert!(s.exists("f_rank0_step1") && s.exists("f_rank0_step2"));
        assert!(s.n_bytes() <= 80);
    }

    #[test]
    fn stale_republish_cannot_displace_newer_generations() {
        // A restarted producer replaying an old step ranks below the
        // retained window: under byte pressure it gets backpressure rather
        // than evicting newer training data...
        let s = Store::new();
        s.set_retention(RetentionConfig { window: 2, max_bytes: 80 });
        s.put_tensor("f_rank0_step5", t(vec![5.0; 10])).unwrap();
        s.put_tensor("f_rank0_step6", t(vec![6.0; 10])).unwrap();
        let err = s.put_tensor("f_rank0_step4", t(vec![4.0; 10])).unwrap_err();
        assert!(matches!(err, Error::Busy(_)), "{err}");
        assert!(s.exists("f_rank0_step5") && s.exists("f_rank0_step6"), "newer data intact");
        // ...and without byte pressure it is admitted, then immediately
        // retired by the window (the newest two generations win).
        let s = Store::new();
        s.set_retention(RetentionConfig { window: 2, max_bytes: 0 });
        s.put_tensor("f_rank0_step5", t(vec![5.0; 10])).unwrap();
        s.put_tensor("f_rank0_step6", t(vec![6.0; 10])).unwrap();
        s.put_tensor("f_rank0_step4", t(vec![4.0; 10])).unwrap();
        assert_eq!(s.list_keys(""), vec!["f_rank0_step5", "f_rank0_step6"]);
    }

    #[test]
    fn busy_when_nothing_evictable() {
        let s = Store::new();
        s.set_retention(RetentionConfig { window: 2, max_bytes: 80 });
        // A payload larger than the whole cap is rejected outright.
        assert!(matches!(s.put_tensor("big", t(vec![0.0; 100])), Err(Error::Busy(_))));
        // Fill the cap with one field's protected window; a *different*
        // field then cannot fit and must get backpressure, not eviction of
        // protected data.
        s.put_tensor("f_rank0_step0", t(vec![0.0; 10])).unwrap();
        s.put_tensor("f_rank0_step1", t(vec![1.0; 10])).unwrap();
        let err = s.put_tensor("g_rank0_step0", t(vec![2.0; 10])).unwrap_err();
        assert!(matches!(err, Error::Busy(_)), "{err}");
        assert!(s.exists("f_rank0_step0") && s.exists("f_rank0_step1"), "window intact");
        assert_eq!(s.counters.busy_rejections.load(Ordering::Relaxed), 2);
        // Overwriting a resident key at the same size always fits.
        s.put_tensor("f_rank0_step1", t(vec![9.0; 10])).unwrap();
    }

    #[test]
    fn enabling_retention_on_a_populated_store_rebuilds_and_enforces() {
        let s = Store::new();
        for step in 0..6u64 {
            s.put_tensor(&format!("f_rank0_step{step}"), t(vec![step as f32; 4])).unwrap();
        }
        assert_eq!(s.n_bytes(), 6 * 16);
        s.set_retention(RetentionConfig { window: 2, max_bytes: 0 });
        assert_eq!(s.list_keys(""), vec!["f_rank0_step4", "f_rank0_step5"]);
        assert_eq!(s.n_bytes(), 2 * 16);
        // Disabling governance restores plain append.
        s.set_retention(RetentionConfig::UNBOUNDED);
        s.put_tensor("f_rank0_step9", t(vec![0.0; 4])).unwrap();
        s.put_tensor("f_rank0_step10", t(vec![0.0; 4])).unwrap();
        assert_eq!(s.list_keys("").len(), 4);
    }

    #[test]
    fn prop_governed_byte_accounting_stays_exact() {
        // Under random puts/dels with retention active, the bytes atomic
        // always equals the sum of resident tensor sizes.
        check("governed accounting", 60, |g: &mut Gen| {
            let s = Store::new();
            s.set_retention(RetentionConfig {
                window: g.usize_in(0..=3) as u64,
                max_bytes: (g.usize_in(2..=20) * 16) as u64,
            });
            for _ in 0..g.usize_in(1..=50) {
                let field = ["u", "v"][g.usize_in(0..=1)];
                let key = if g.bool() {
                    format!("{field}_rank{}_step{}", g.usize_in(0..=1), g.usize_in(0..=9))
                } else {
                    format!("loose{}", g.usize_in(0..=3))
                };
                if g.bool() {
                    let _ = s.put_tensor(&key, t(vec![1.0; g.usize_in(1..=4)]));
                } else {
                    s.del_tensor(&key);
                }
            }
            let resident: u64 = s
                .list_keys("")
                .iter()
                .map(|k| s.get_tensor(k).unwrap().nbytes() as u64)
                .sum();
            assert_eq!(s.n_bytes(), resident, "accounting drift");
            assert!(s.high_water_bytes() >= s.n_bytes());
        });
    }

    #[test]
    fn eviction_is_concurrency_safe_with_readers() {
        // Producers append (driving eviction) while readers fetch; a view
        // handed out before eviction stays byte-valid afterwards.
        let s = Arc::new(Store::new());
        s.set_retention(RetentionConfig { window: 2, max_bytes: 0 });
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let mut readers = Vec::new();
        for _ in 0..3 {
            let s = Arc::clone(&s);
            let stop = Arc::clone(&stop);
            readers.push(std::thread::spawn(move || {
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    for step in 0..60u64 {
                        if let Ok(v) = s.get_tensor(&format!("c_rank0_step{step}")) {
                            let v = v.to_f32().unwrap();
                            assert!(v.iter().all(|&x| x == v[0]), "torn read");
                        }
                    }
                }
            }));
        }
        for step in 0..60u64 {
            s.put_tensor(&format!("c_rank0_step{step}"), t(vec![step as f32; 64])).unwrap();
        }
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
        for h in readers {
            h.join().unwrap();
        }
        assert_eq!(s.list_keys("c_").len(), 2);
        assert_eq!(s.n_bytes(), 2 * 64 * 4);
    }
}
