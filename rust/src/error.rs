//! Crate-wide error type.  Hand-rolled (the build is offline and
//! dependency-light); semantically equivalent to a `thiserror` enum.

use std::fmt;

/// All failure modes surfaced by the framework.
#[derive(Debug)]
pub enum Error {
    /// Underlying socket / file I/O failure.
    Io(std::io::Error),
    /// Malformed frame or message on the wire.
    Protocol(String),
    /// Key not present in the database.
    KeyNotFound(String),
    /// Model not present in the database model registry.
    ModelNotFound(String),
    /// Tensor shape/dtype mismatch.
    Shape(String),
    /// PJRT / XLA failure.
    Xla(String),
    /// Manifest or config parse failure.
    Parse(String),
    /// Remote side reported an error.
    Remote(String),
    /// Component misuse or invariant violation.
    Invalid(String),
    /// Operation timed out (e.g. polling for a key).
    Timeout(String),
    /// Write rejected by capacity governance: the store could not fit the
    /// payload under its byte cap even after evicting everything the
    /// retention policy allows.  Backpressure, not corruption — the caller
    /// may retry once the consumer has advanced (or raise the cap/window).
    Busy(String),
    /// The shard no longer owns the request's hash slot: the cluster is at
    /// the carried ownership epoch and the client's routing table is
    /// stale.  Refetch the table and retry — the data moved, it isn't
    /// gone.  The cluster client handles this transparently; user code
    /// only sees it if it dials shards directly.
    Moved(u64),
}

impl Error {
    /// True for transport failures that say nothing about the request
    /// itself — the connection died, timed out, or was refused — so the
    /// operation is safe to retry on the same shard (after reconnecting)
    /// or on a replica.  Application-level errors (`KeyNotFound`, `Remote`,
    /// `Busy`, ...) are deliberately excluded: they are authoritative
    /// answers, not weather.
    pub fn is_transient_io(&self) -> bool {
        match self {
            Error::Io(e) => matches!(
                e.kind(),
                std::io::ErrorKind::ConnectionReset
                    | std::io::ErrorKind::ConnectionAborted
                    | std::io::ErrorKind::ConnectionRefused
                    | std::io::ErrorKind::BrokenPipe
                    | std::io::ErrorKind::UnexpectedEof
                    | std::io::ErrorKind::NotConnected
                    | std::io::ErrorKind::TimedOut
                    | std::io::ErrorKind::WouldBlock
            ),
            _ => false,
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Protocol(m) => write!(f, "protocol error: {m}"),
            Error::KeyNotFound(k) => write!(f, "key not found: {k}"),
            Error::ModelNotFound(k) => write!(f, "model not found: {k}"),
            Error::Shape(m) => write!(f, "shape error: {m}"),
            Error::Xla(m) => write!(f, "xla error: {m}"),
            Error::Parse(m) => write!(f, "parse error: {m}"),
            Error::Remote(m) => write!(f, "remote error: {m}"),
            Error::Invalid(m) => write!(f, "invalid: {m}"),
            Error::Timeout(m) => write!(f, "timeout: {m}"),
            // The "busy: " / "moved: " prefixes are load-bearing: remote
            // errors travel as strings and the client maps them back to
            // `Error::Busy` / `Error::Moved`.
            Error::Busy(m) => write!(f, "busy: {m}"),
            Error::Moved(epoch) => write!(f, "moved: {epoch}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;
