//! # situ — in situ framework for coupling simulation and machine learning
//!
//! A reproduction of *"In Situ Framework for Coupling Simulation and Machine
//! Learning with Application to CFD"* (Balin et al., 2023) as a three-layer
//! rust + JAX/Pallas stack:
//!
//! * **L3 (this crate)** — the paper's contribution: an in-memory tensor
//!   database ([`db`], the Redis/KeyDB analogue) with co-located and
//!   clustered deployments, a one-line-per-op client library ([`client`],
//!   the SmartRedis analogue), in-database model execution ([`ai`], the
//!   RedisAI analogue), and an orchestrator ([`orchestrator`], the
//!   SmartSim-IL analogue).  The scaling substrate (Polaris-like topology and
//!   a discrete-event simulator) lives in [`cluster`]; the data producers
//!   (a real Navier-Stokes solver and the paper's §3 reproducer) in [`sim`];
//!   the data consumer (distributed in-situ trainer) in [`ml`].
//! * **L2** — `python/compile/model.py`: the QuadConv autoencoder and its
//!   fused `train_step` (fwd+bwd+Adam), AOT-lowered to HLO text.
//! * **L1** — `python/compile/kernels/quadconv.py`: the QuadConv quadrature
//!   contraction as Pallas kernels.
//!
//! Python never runs on the request path: `make artifacts` lowers the graphs
//! once; [`runtime`] loads and executes them through the PJRT C API.

pub mod ai;
pub mod client;
pub mod cluster;
pub mod config;
pub mod db;
pub mod error;
pub mod ml;
pub mod orchestrator;
pub mod proto;
pub mod runtime;
pub mod sim;
pub mod telemetry;
pub mod tensor;
pub mod util;

pub use error::{Error, Result};
pub use tensor::{DType, Tensor};
