//! # situ — in situ framework for coupling simulation and machine learning
//!
//! A reproduction of *"In Situ Framework for Coupling Simulation and Machine
//! Learning with Application to CFD"* (Balin et al., 2023) as a three-layer
//! rust + JAX/Pallas stack:
//!
//! * **L3 (this crate)** — the paper's contribution: an in-memory tensor
//!   database ([`db`], the Redis/KeyDB analogue) with co-located and
//!   clustered deployments, a one-line-per-op client library ([`client`],
//!   the SmartRedis analogue), in-database model execution ([`ai`], the
//!   RedisAI analogue), and an orchestrator ([`orchestrator`], the
//!   SmartSim-IL analogue).  The scaling substrate (Polaris-like topology and
//!   a discrete-event simulator) lives in [`cluster`]; the data producers
//!   (a real Navier-Stokes solver and the paper's §3 reproducer) in [`sim`];
//!   the data consumer (distributed in-situ trainer) in [`ml`].
//! * **L2** — `python/compile/model.py`: the QuadConv autoencoder and its
//!   fused `train_step` (fwd+bwd+Adam), AOT-lowered to HLO text.
//! * **L1** — `python/compile/kernels/quadconv.py`: the QuadConv quadrature
//!   contraction as Pallas kernels.
//!
//! Python never runs on the request path: `make artifacts` lowers the graphs
//! once; [`runtime`] loads and executes them through the PJRT C API.
//!
//! ## Zero-copy tensor data plane
//!
//! The crate's core value type, [`Tensor`], carries its payload in a
//! shared, reference-counted [`Bytes`] buffer.  That single design choice
//! removes every avoidable payload copy on the paper's hot path:
//!
//! * the client's `put_tensor` writes a split frame straight from the
//!   borrowed tensor (no encode copy);
//! * the server decodes the frame with `Request::decode_shared`, so the
//!   stored tensor *is* a view into the frame read off the socket;
//! * `Store::get_tensor` hands tensors out by refcount bump, and readers'
//!   views stay valid across overwrites and deletes;
//! * tensor replies are written as header + borrowed payload slice, never
//!   re-materialized in an output buffer.
//!
//! One `put_tensor`/`get_tensor` round trip thus allocates the payload
//! once per direction (the socket read) instead of copying it 4–5 times.
//!
//! ## Unified client surface + pipelining
//!
//! All database operations live on the [`client::DataStore`] trait,
//! implemented by both [`client::Client`] (co-located) and
//! [`client::ClusterClient`] (sharded) — consumers are written once and run
//! on either deployment.  Round-trip-bound paths are batched:
//! [`client::Pipeline`] sends many commands in one frame with per-entry
//! results, and the `MGetTensors`/`PollKeys` wire fast paths make the
//! dataloader's per-epoch gather and wait cost one request frame each
//! (server-side waiting with capped exponential backoff), with the
//! zero-copy payload plane preserved through batch replies.
//!
//! ## Bounded memory
//!
//! Long-running simulations cannot append snapshots forever: each database
//! instance enforces an optional [`db::RetentionConfig`] — a sliding
//! window of step generations per field, a byte cap with explicit
//! `busy` backpressure ([`Error::Busy`]) when nothing evictable remains,
//! and a wall-clock TTL that reclaims data from stalled producers (see
//! [`db::store`]).  The retention index is sharded by field, so governed
//! puts keep the data plane's sharded-lock parallelism; per-field pressure
//! (resident bytes vs. cap, eviction rates) travels in `INFO`.  The
//! consumer trains on a moving window (`DataLoader::gather_window`), the
//! producer can alternatively republish under stable keys (the paper's
//! overwrite mode, flat by construction), and the orchestrator threads the
//! policy from `RunConfig` through deployment to every server.
//!
//! ## Spill-to-disk cold tier
//!
//! Bounded-memory runs no longer *lose* what they evict: with a spill
//! directory configured ([`db::SpillConfig`], `--spill-dir`), every
//! retention victim is appended — by a background writer thread, off the
//! put hot path — to a CRC-checksummed segment log ([`db::spill`]) and
//! stays replayable byte-exact over the wire (`ColdGet`/`ColdList` on
//! [`client::DataStore`]).  `DataLoader::gather_window` falls back to the
//! cold tier transparently, so deep training windows spanning retired
//! generations complete instead of skipping.  The log is crash-safe: torn
//! tails truncate on reopen, corrupt records are skipped cleanly — proven
//! by the corruption/recovery battery in `tests/spill_recovery.rs`.
//!
//! ## Adaptive backpressure
//!
//! `Error::Busy` is a flow-control signal, not a failure: the client
//! carries a pluggable [`client::RetryPolicy`] (immediate-fail / capped
//! exponential backoff / deadline), and the CFD producer runs an adaptive
//! [`client::PublishGovernor`] that under sustained pressure drops
//! snapshots and widens its publish stride (skipped steps merge into the
//! next published snapshot) instead of stopping the solver — so a run with
//! a stalled consumer survives to completion.  Skip/retry/drop counters
//! surface in the run report and `situ info`.
//!
//! ## Replication, failover, and the chaos harness
//!
//! The clustered data plane tolerates shard loss: [`client::ClusterClient`]
//! fans every write out to `replicas` consecutive shards on the hash ring
//! (pipelined — one frame per shard, not N round trips), reads fall back
//! primary → replicas on transient I/O errors or misses, and a per-shard
//! circuit breaker (consecutive-failure threshold, timed half-open
//! reconnect) keeps a dead shard from stalling every operation.  Aggregate
//! operations degrade partially instead of failing whole, with per-shard
//! errors reported via [`client::ClusterClient::shard_errors`].  Client
//! sockets carry an I/O deadline so a hung shard surfaces as a retryable
//! timeout, never a hang.  All of it is testable deterministically: a
//! seeded fault plan ([`util::fault`]) injects delays, truncations and
//! severed connections at the transport layer (`--chaos-seed`), servers
//! can crash without their clean-shutdown spill barrier
//! (`DbServer::simulate_crash`), and the chaos battery in
//! `tests/chaos_cluster.rs` proves runs complete with exact accounting
//! while shards die mid-flight.  Failure semantics are documented in
//! `docs/failures.md`.
//!
//! ## Versioned model serving
//!
//! In-database inference is a first-class workload: every `put_model`
//! publishes an immutable `(key, version)` artifact into [`ai::Registry`]
//! and atomically swaps the live pointer — in-flight requests finish on
//! the version they resolved, pinned requests (`run_model_version`) keep
//! working across swaps, and a trainer republishing checkpoints
//! (`--checkpoint-key`) hot-swaps serving clients mid-run with zero failed
//! calls.  Concurrent `run_model` calls for the same (key, version,
//! device) coalesce through [`ai::Batcher`] into one stacked backend
//! execution (window armed only on bursts, per-entry errors, exact
//! de-stacking).  The serving loop closes in [`sim::cfd::HybridSolver`]:
//! the pressure Poisson solve runs on the live surrogate, validated per
//! step by a residual check, with the numeric solver as a counted
//! warm-started fallback.  Registry/batching counters travel in `INFO`;
//! semantics are documented in `docs/serving.md`.

pub mod ai;
pub mod client;
pub mod cluster;
pub mod config;
pub mod db;
pub mod error;
pub mod ml;
pub mod orchestrator;
pub mod proto;
pub mod runtime;
pub mod sim;
pub mod telemetry;
pub mod tensor;
pub mod util;

pub use error::{Error, Result};
pub use tensor::{Bytes, DType, Tensor};
