//! `situ` — command-line entrypoint for the in-situ coupling framework.
//!
//! Subcommands:
//!   serve        run a database server
//!   info         query a running database
//!   reshard      live-rebalance cluster slots (or backfill a restarted shard)
//!   retire       archive one generation to exactly one cold tier, drop hot copies
//!   calibrate    measure real DB + PJRT costs, print CostModel constants
//!   train        end-to-end in-situ training (paper §4, scaled)
//!   bench-transfer / bench-inference   DES scaling sweeps (Figs 3-6, 8)

use std::io::Write as _;
use std::net::SocketAddr;
use std::sync::Arc;
use std::time::Duration;

use situ::client::{Client, ClusterClient, ClusterConfig, DataStore};
use situ::cluster::netmodel::CostModel;
use situ::cluster::scaling;
use situ::config::RunConfig;
use situ::db::{DbServer, Engine, RetentionConfig, ServerConfig};
use situ::error::{Error, Result};
use situ::orchestrator::driver::{
    run_hybrid_serving, run_insitu_training, HybridServingConfig, InSituTrainingConfig,
};
use situ::runtime::Executor;
use situ::sim::reproducer::{self, ReproducerConfig};
use situ::telemetry::Table;
use situ::util::cli::Args;
use situ::util::fault::{FaultConfig, FaultPlan};
use situ::util::fmt;

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> Result<()> {
    match args.command.as_deref() {
        Some("serve") => cmd_serve(args),
        Some("info") => cmd_info(args),
        Some("reshard") => cmd_reshard(args),
        Some("retire") => cmd_retire(args),
        Some("calibrate") => cmd_calibrate(args),
        Some("train") => cmd_train(args),
        Some("hybrid") => cmd_hybrid(args),
        Some("bench-transfer") => cmd_bench_transfer(args),
        Some("bench-inference") => cmd_bench_inference(args),
        Some("help") | None => {
            print_help();
            Ok(())
        }
        Some(other) => Err(Error::Invalid(format!("unknown command '{other}'"))),
    }
}

fn print_help() {
    println!(
        "situ — in situ simulation/ML coupling framework (Balin et al. 2023 reproduction)

USAGE: situ <command> [flags]

  serve            --port 7700 --engine redis|keydb --cores 8 [--no-models]
                   [--reactors N]
                   [--retention-window W] [--max-bytes B] [--ttl-ms T]
                   [--spill-dir DIR --spill-max-bytes B]
                   [--chaos-seed S --chaos-intensity F]
                   [--chaos-crash-every-ms MS --chaos-downtime-ms MS]
                   bounded-memory store (window / byte cap / stalled-producer
                   TTL) + spill-to-disk cold tier for retired generations;
                   the chaos flags inject seeded transport faults and an
                   optional crash/restart loop for failover testing
  info             --addr 127.0.0.1:7700   stats incl. per-field pressure
                   and spill-to-disk cold-tier counters; or
                   --addrs a:p,b:p,... [--replicas N]  aggregate a cluster
                   (adds client-side replication/failover counters)
  reshard          --addrs a:p,b:p,...  [--from N] [--replicas R] [--window K]
                   live-rebalance the cluster to an even slot split over the
                   given (full) address list: installs an epoch-versioned
                   ownership table, streams moved slot ranges between shards
                   in pipelined windows with old-owner read fallback, then
                   commits and cleans up — zero governed-data loss under
                   load.  --from N seeds the pre-reshard shard count for a
                   cluster that never held a table; --to N shrinks onto the
                   first N shards (the full list is still needed to drain
                   the rest).
                   --backfill S  instead repopulates restarted shard S from
                   its replica ring (same streaming path)
  retire           --addrs a:p,b:p,... --field F --step N
                   archive generation N of field F to exactly one cold tier
                   (each key's slot owner), then delete every hot copy
  calibrate        [--artifacts DIR]   measure real costs, print CostModel
  train            [--epochs N --sim-ranks R --ml-ranks M --steps S]
                   [--window W --overwrite --retention-window W --db-max-bytes B
                    --db-ttl-ms T --busy-retries N --busy-backoff-ms MS
                    --governor-max-stride K --spill-dir DIR --spill-max-bytes B]
                   [--checkpoint-key KEY --checkpoint-every N]
                   bounded-memory + backpressure + cold-tier knobs; the
                   checkpoint flags publish trainer checkpoints into the
                   model registry as versioned, hot-swapped artifacts
  hybrid           [--steps N --accept-tol T --publish-every K
                    --model-key KEY --grid nx,ny,nz]
                   CFD run whose pressure solve is served by the live
                   surrogate model, validated per step with numeric
                   fallback; checkpoints improve mid-run
  bench-transfer   --nodes-list 1,4,16 --deployment colocated|clustered ...
  bench-inference  --nodes-list 1,4,16 --batch 4 ...
"
    );
}

fn cmd_serve(args: &Args) -> Result<()> {
    let port = args.usize_or("port", 7700)? as u16;
    let engine = Engine::parse(&args.str_or("engine", "redis"))
        .ok_or_else(|| Error::Invalid("bad --engine".into()))?;
    let spill = match args.str_opt("spill-dir") {
        Some(dir) => Some(situ::db::SpillConfig {
            dir: dir.into(),
            max_bytes: args.usize_or("spill-max-bytes", 0)? as u64,
            segment_bytes: situ::db::spill::default_segment_bytes(),
        }),
        None => None,
    };
    // Chaos harness: a nonzero seed wraps every accepted connection in a
    // seeded fault stream; the crash flags add a kill/rebind loop on top.
    let chaos_seed = args.usize_or("chaos-seed", 0)? as u64;
    let fault = if chaos_seed != 0 {
        let intensity = args.f64_or("chaos-intensity", 1.0)?;
        Some(Arc::new(FaultPlan::new(FaultConfig::with_intensity(chaos_seed, intensity))))
    } else {
        None
    };
    let cfg = ServerConfig {
        addr: SocketAddr::from(([127, 0, 0, 1], port)),
        engine,
        cores: args.usize_or("cores", 8)?,
        with_models: !args.bool("no-models"),
        retention: RetentionConfig {
            window: args.usize_or("retention-window", 0)? as u64,
            max_bytes: args.usize_or("max-bytes", 0)? as u64,
            ttl_ms: args.usize_or("ttl-ms", 0)? as u64,
        },
        spill,
        fault: fault.clone(),
        reactors: args.usize_or("reactors", 0)?,
        ..Default::default()
    };
    let mut server = DbServer::start(cfg.clone())?;
    println!(
        "situ db listening on {} (engine={}, reactors={})",
        server.addr,
        engine.name(),
        server.reactors()
    );
    // Tests parse this line from a pipe (`--port 0` prints the real port),
    // and piped stdout is block-buffered — flush or they hang.
    std::io::stdout().flush().ok();

    let crash_every = args.usize_or("chaos-crash-every-ms", 0)? as u64;
    if crash_every == 0 {
        loop {
            std::thread::sleep(Duration::from_secs(3600));
        }
    }
    let downtime = args.usize_or("chaos-downtime-ms", 250)? as u64;
    // Rebind the concrete port the first bind picked, so clients' failover
    // reconnects find the restarted instance at the same address.
    let rebind = ServerConfig { addr: server.addr, ..cfg };
    loop {
        std::thread::sleep(Duration::from_millis(crash_every));
        server.simulate_crash();
        println!("situ db {}: simulated crash (down {downtime} ms)", rebind.addr);
        std::io::stdout().flush().ok();
        std::thread::sleep(Duration::from_millis(downtime));
        if let Some(p) = &fault {
            p.revive();
        }
        server = DbServer::start(rebind.clone())?;
        println!("situ db {}: restarted", server.addr);
        std::io::stdout().flush().ok();
    }
}

fn cmd_info(args: &Args) -> Result<()> {
    // `--addrs a,b,c` aggregates a whole cluster through `ClusterClient`
    // (partial results if some shards are down); `--addr` asks one server.
    let (i, model_entries, model_stats) = if let Some(list) = args.str_opt("addrs") {
        let addrs = list
            .split(',')
            .map(|s| s.trim().parse::<SocketAddr>())
            .collect::<std::result::Result<Vec<_>, _>>()
            .map_err(|_| Error::Invalid("bad --addrs".into()))?;
        let replicas = args.usize_or("replicas", 1)?;
        let mut c = ClusterClient::connect_with(
            &addrs,
            ClusterConfig { replicas, ..ClusterConfig::default() },
        )?;
        let i = c.info()?;
        for e in c.shard_errors() {
            eprintln!("warning: shard {} ({}) unreachable: {}", e.shard, e.addr, e.error);
        }
        let entries = c.list_models().unwrap_or_default();
        let stats = c.model_stats().unwrap_or_default();
        (i, entries, stats)
    } else {
        let addr: SocketAddr = args
            .str_or("addr", "127.0.0.1:7700")
            .parse()
            .map_err(|_| Error::Invalid("bad --addr".into()))?;
        let mut c = Client::connect(addr)?;
        let i = c.info()?;
        let entries = c.list_models().unwrap_or_default();
        let stats = c.model_stats().unwrap_or_default();
        (i, entries, stats)
    };
    println!(
        "engine={} keys={} bytes={} ops={} models={}",
        i.engine,
        i.keys,
        fmt::bytes(i.bytes),
        i.ops,
        i.models
    );
    println!(
        "high_water={} evicted_keys={} evicted_bytes={} busy_rejections={} ttl_expired={}",
        fmt::bytes(i.high_water_bytes),
        i.evicted_keys,
        fmt::bytes(i.evicted_bytes),
        i.busy_rejections,
        i.ttl_expired_keys
    );
    println!(
        "retention: window={} max_bytes={} ttl_ms={}",
        i.retention_window,
        fmt::bytes(i.retention_max_bytes),
        i.retention_ttl_ms
    );
    println!(
        "spill: keys={} bytes={} segments={} cold_hits={} lost={}",
        i.spilled_keys,
        fmt::bytes(i.spilled_bytes),
        i.spill_segments,
        i.cold_hits,
        i.spill_lost_keys
    );
    if i.replicated_writes + i.read_failovers + i.shard_reconnects + i.degraded_ops > 0 {
        situ::telemetry::failover_table(&i).print();
    }
    if i.models + i.model_swaps + i.batches + i.batched_requests > 0 {
        situ::telemetry::serving_table(&i).print();
    }
    if !model_entries.is_empty() {
        situ::telemetry::models_table(&model_entries).print();
    }
    if !model_stats.is_empty() {
        situ::telemetry::model_stats_table(&model_stats).print();
    }
    if !i.fields.is_empty() {
        situ::telemetry::field_pressure_table(&i).print();
    }
    Ok(())
}

fn parse_addrs(args: &Args) -> Result<Vec<SocketAddr>> {
    args.str_opt("addrs")
        .ok_or_else(|| Error::Invalid("--addrs a:p,b:p,... is required".into()))?
        .split(',')
        .map(|s| s.trim().parse::<SocketAddr>())
        .collect::<std::result::Result<Vec<_>, _>>()
        .map_err(|_| Error::Invalid("bad --addrs".into()))
}

/// Live-rebalance the cluster (`situ reshard`), or with `--backfill S`
/// repopulate a restarted shard through the same streaming machinery.
fn cmd_reshard(args: &Args) -> Result<()> {
    let addrs = parse_addrs(args)?;
    let replicas = args.usize_or("replicas", 1)?;
    let window = args.usize_or("window", 0)?;
    if let Some(shard) = args.str_opt("backfill") {
        let shard = shard
            .parse::<usize>()
            .map_err(|_| Error::Invalid("bad --backfill shard index".into()))?;
        let rep = situ::orchestrator::backfill(&situ::orchestrator::BackfillConfig {
            addrs,
            shard,
            replicas,
            window,
        })?;
        println!(
            "backfilled shard {shard}: epoch={} ranges={} keys={} bytes={} rounds={}",
            rep.epoch,
            rep.ranges,
            rep.keys,
            fmt::bytes(rep.bytes),
            rep.transfer_rounds
        );
        return Ok(());
    }
    let rep = situ::orchestrator::reshard(&situ::orchestrator::ReshardConfig {
        addrs,
        from_shards: args.usize_or("from", 0)?,
        to_shards: args.usize_or("to", 0)?,
        replicas,
        window,
    })?;
    println!(
        "resharded: epoch {} -> {} moved_ranges={} keys={} bytes={} rounds={}",
        rep.from_epoch,
        rep.to_epoch,
        rep.moved_ranges,
        rep.moved_keys,
        fmt::bytes(rep.moved_bytes),
        rep.transfer_rounds
    );
    if !rep.unreachable_shards.is_empty() {
        eprintln!(
            "warning: shards {:?} were unreachable during the reshard; run \
             `situ reshard --backfill <shard>` once they are back",
            rep.unreachable_shards
        );
    }
    Ok(())
}

/// Retire one governed generation to exactly one cold tier cluster-wide.
fn cmd_retire(args: &Args) -> Result<()> {
    let addrs = parse_addrs(args)?;
    let field = args
        .str_opt("field")
        .ok_or_else(|| Error::Invalid("--field is required".into()))?
        .to_string();
    let step = args.usize_or("step", usize::MAX)?;
    if step == usize::MAX {
        return Err(Error::Invalid("--step is required".into()));
    }
    let rep = situ::orchestrator::retire_generation(&situ::orchestrator::RetireConfig {
        addrs,
        field,
        step: step as u64,
    })?;
    println!(
        "retired step {step}: archived={} bytes={} deleted_copies={} missing={}",
        rep.archived,
        fmt::bytes(rep.archived_bytes),
        rep.deleted_copies,
        rep.missing
    );
    Ok(())
}

/// Measure the real database and PJRT costs on this host and print the
/// calibrated CostModel constants (consumed by the DES benches).
fn cmd_calibrate(args: &Args) -> Result<()> {
    let artifacts = std::path::PathBuf::from(
        args.str_or("artifacts", situ::db::server::artifacts_dir().to_str().unwrap()),
    );
    println!("== situ calibrate ==");

    // 1) DB round-trip costs at two sizes.
    let server = DbServer::start(ServerConfig { with_models: false, ..Default::default() })?;
    let small = measure_roundtrip(server.addr, 1024, 200)?;
    let big = measure_roundtrip(server.addr, 1 << 20, 50)?;
    println!("db round-trip   1KB: {}", fmt::duration(small));
    println!("db round-trip   1MB: {}", fmt::duration(big));
    let mut model = CostModel::default();
    model.calibrate((1024, small), (1 << 20, big));
    println!(
        "calibrated: req_fixed={} byte_cost={:.3e} s/B",
        fmt::duration(model.req_fixed),
        model.byte_cost
    );

    // 2) PJRT eval times for the inference model (feeds Fig 7/8 DES).
    if artifacts.join("manifest.json").exists() {
        let exec = Executor::new()?;
        let mut table =
            Table::new("resnet_lite eval time (real PJRT)", &["batch", "mean", "per-sample"]);
        for b in [1usize, 4, 16] {
            let name = format!("resnet_lite_b{b}");
            let path = artifacts.join(format!("{name}.hlo.txt"));
            if !path.exists() {
                continue;
            }
            exec.load_artifact(&name, &path)?;
            let acc = reproducer::run_inline_baseline(&exec, &name, &[b, 3, 64, 64], 10, 2)?;
            table.row(&[
                b.to_string(),
                fmt::duration(acc.mean()),
                fmt::duration(acc.mean() / b as f64),
            ]);
        }
        table.print();
    } else {
        println!("(artifacts not built; skipping PJRT calibration)");
    }
    Ok(())
}

fn measure_roundtrip(addr: SocketAddr, bytes: usize, iters: usize) -> Result<f64> {
    let times = reproducer::run_data_loop(&ReproducerConfig {
        addr,
        ranks: 1,
        bytes_per_rank: bytes,
        iterations: iters,
        warmup: 3,
        compute_secs: 0.0,
        retry: situ::client::RetryPolicy::Fail,
    })?;
    let snap = times.snapshot();
    Ok(snap["send"].mean() + snap["retrieve"].mean())
}

fn cmd_train(args: &Args) -> Result<()> {
    let mut cfg = InSituTrainingConfig::default();
    cfg.epochs = args.usize_or("epochs", cfg.epochs)?;
    cfg.sim_ranks = args.usize_or("sim-ranks", cfg.sim_ranks)?;
    cfg.ml_ranks = args.usize_or("ml-ranks", cfg.ml_ranks)?;
    cfg.solver_steps = args.usize_or("steps", cfg.solver_steps as usize)? as u64;
    cfg.window = args.usize_or("window", cfg.window as usize)? as u64;
    cfg.overwrite = args.bool("overwrite");
    cfg.retention_window = args.usize_or("retention-window", cfg.retention_window as usize)? as u64;
    cfg.db_max_bytes = args.usize_or("db-max-bytes", cfg.db_max_bytes as usize)? as u64;
    cfg.db_ttl_ms = args.usize_or("db-ttl-ms", cfg.db_ttl_ms as usize)? as u64;
    cfg.spill_dir = args.str_opt("spill-dir").map(std::path::PathBuf::from);
    cfg.spill_max_bytes = args.usize_or("spill-max-bytes", cfg.spill_max_bytes as usize)? as u64;
    {
        // Backpressure knobs share the RunConfig flag names and semantics.
        let mut bp = situ::config::RunConfig::default();
        bp.busy_retries = args.usize_or("busy-retries", bp.busy_retries as usize)? as u32;
        bp.busy_backoff_ms = args.usize_or("busy-backoff-ms", bp.busy_backoff_ms as usize)? as u64;
        bp.governor_max_stride =
            args.usize_or("governor-max-stride", bp.governor_max_stride as usize)? as u64;
        cfg.governor = bp.governor();
    }
    if let Some(dir) = args.str_opt("artifacts") {
        cfg.artifacts_dir = dir.into();
    }
    cfg.checkpoint_key = args.str_opt("checkpoint-key");
    cfg.checkpoint_every = args.usize_or("checkpoint-every", cfg.checkpoint_every)?;
    println!(
        "== in situ training: {} epochs, {} sim ranks, {} ml ranks, {} solver steps ==",
        cfg.epochs, cfg.sim_ranks, cfg.ml_ranks, cfg.solver_steps
    );
    let report = run_insitu_training(&cfg)?;
    report.solver_table.print();
    report.trainer_table.print();
    let mut curve = Table::new(
        "Fig 10: convergence during in situ training",
        &["epoch", "train_loss", "val_loss", "val_rel_err"],
    );
    let stride = (report.history.len() / 20).max(1);
    for log in report.history.iter().step_by(stride) {
        curve.row(&[
            log.epoch.to_string(),
            format!("{:.6}", log.train_loss),
            format!("{:.6}", log.val_loss),
            format!("{:.4}", log.val_rel_err),
        ]);
    }
    curve.print();
    println!(
        "framework overhead on solver: {:.4}%  (paper: <<1%)",
        report.solver_overhead_frac * 100.0
    );
    println!("spatial compression factor: {:.0}x", report.compression_factor);
    situ::telemetry::counter_table(
        "backpressure (producer governor + trainer window)",
        &[
            ("snapshots published", report.governor.published),
            ("snapshots skipped (stride)", report.governor.skipped),
            ("snapshots dropped (busy)", report.governor.dropped),
            ("busy retries", report.governor.busy_retries),
            ("store busy rejections", report.db.busy_rejections),
            ("trainer generations skipped", report.trainer_skipped_generations),
        ],
    )
    .print();
    if report.db.spilled_keys > 0 {
        situ::telemetry::counter_table(
            "spill-to-disk cold tier",
            &[
                ("spilled keys", report.db.spilled_keys),
                ("spilled bytes", report.db.spilled_bytes),
                ("segments", report.db.spill_segments),
                ("cold hits", report.db.cold_hits),
                ("lost (write errors + backlog)", report.db.spill_lost_keys),
            ],
        )
        .print();
    }
    if !report.db.fields.is_empty() {
        situ::telemetry::field_pressure_table(&report.db).print();
    }
    if report.checkpoints_published > 0 {
        println!("trainer checkpoints published: {}", report.checkpoints_published);
        situ::telemetry::serving_table(&report.db).print();
    }
    Ok(())
}

fn cmd_hybrid(args: &Args) -> Result<()> {
    let mut cfg = HybridServingConfig::default();
    cfg.steps = args.usize_or("steps", cfg.steps as usize)? as u64;
    cfg.accept_tol = args.f64_or("accept-tol", cfg.accept_tol)?;
    cfg.publish_every = args.usize_or("publish-every", cfg.publish_every as usize)? as u64;
    if let Some(k) = args.str_opt("model-key") {
        cfg.model_key = k;
    }
    let grid = args.usize_list_or("grid", &[cfg.grid.0, cfg.grid.1, cfg.grid.2])?;
    if grid.len() != 3 {
        return Err(Error::Invalid("--grid wants nx,ny,nz".into()));
    }
    cfg.grid = (grid[0], grid[1], grid[2]);
    println!(
        "== hybrid serving: {} steps on {}x{}x{}, checkpoint every {} steps ==",
        cfg.steps, cfg.grid.0, cfg.grid.1, cfg.grid.2, cfg.publish_every
    );
    let report = run_hybrid_serving(&cfg)?;
    let s = &report.stats;
    situ::telemetry::counter_table(
        "hybrid pressure solve",
        &[
            ("solver steps", s.steps),
            ("surrogate accepted", s.accepted),
            ("numeric fallbacks", s.fallbacks),
            ("inference errors", s.surrogate_errors),
            ("checkpoints published", report.checkpoints_published),
        ],
    )
    .print();
    if s.residuals.count() > 0 {
        println!(
            "surrogate residual: mean {:.3e}, worst {:.3e}; acceptance {:.0}%",
            s.residuals.mean(),
            s.residuals.max(),
            100.0 * s.acceptance_rate()
        );
    }
    situ::telemetry::models_table(&report.models).print();
    situ::telemetry::model_stats_table(&report.device_stats).print();
    situ::telemetry::serving_table(&report.db).print();
    println!(
        "flow quality: mean |div| {:.3e}, kinetic energy {:.4}",
        report.mean_abs_divergence, report.kinetic_energy
    );
    Ok(())
}

fn cmd_bench_transfer(args: &Args) -> Result<()> {
    let cfg0 = RunConfig::from_args(args)?;
    let nodes_list = args.usize_list_or("nodes-list", &[cfg0.nodes])?;
    let model = CostModel::default();
    let mut table = Table::new(
        &format!(
            "data transfer scaling ({} / {}, {} per rank)",
            cfg0.deployment.name(),
            cfg0.engine.name(),
            fmt::bytes(cfg0.bytes_per_rank as u64)
        ),
        &["nodes", "ranks", "send mean", "send σ", "retrieve mean", "throughput/rank"],
    );
    for nodes in nodes_list {
        let mut cfg = cfg0.clone();
        cfg.nodes = nodes;
        let st = scaling::sim_data_transfer(&cfg, &model, 42);
        table.row(&[
            nodes.to_string(),
            cfg.total_ranks().to_string(),
            fmt::duration(st.send.mean()),
            fmt::duration(st.send.std()),
            fmt::duration(st.retrieve.mean()),
            fmt::throughput(st.throughput_per_rank(cfg.bytes_per_rank)),
        ]);
    }
    table.print();
    Ok(())
}

fn cmd_bench_inference(args: &Args) -> Result<()> {
    let cfg0 = RunConfig::from_args(args)?;
    let nodes_list = args.usize_list_or("nodes-list", &[cfg0.nodes])?;
    let batch = args.usize_or("batch", 4)?;
    let eval_ms = args.f64_or("eval-ms", 3.0)?;
    let model = CostModel::default();
    let eval = move |_b: usize| eval_ms * 1e-3;
    let in_bytes = batch * 3 * 64 * 64 * 4;
    let out_bytes = batch * 1000 * 4;
    let mut table = Table::new(
        &format!("inference scaling (batch {batch})"),
        &["nodes", "ranks", "send", "eval", "retrieve", "total"],
    );
    for nodes in nodes_list {
        let mut cfg = cfg0.clone();
        cfg.nodes = nodes;
        let st = scaling::sim_inference(&cfg, &model, batch, in_bytes, out_bytes, &eval, 17);
        table.row(&[
            nodes.to_string(),
            cfg.total_ranks().to_string(),
            fmt::duration(st.send.mean()),
            fmt::duration(st.eval.mean()),
            fmt::duration(st.retrieve.mean()),
            fmt::duration(st.total.mean()),
        ]);
    }
    table.print();
    Ok(())
}
