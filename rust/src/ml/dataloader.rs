//! Dataloader pulling training snapshots from the database.
//!
//! The paper's key claim for training integration: "the distributed training
//! workload remains largely untouched as the data loader gathers batches
//! from the database rather than from files" (§2.1).  Each ML rank gathers
//! the tensors produced by its share of simulation ranks (24 sim ranks / 4
//! ML ranks = 6 tensors per rank per epoch on Polaris) and stacks mini
//! batches for `train_step`/`grad_step`.
//!
//! The loader is generic over [`DataStore`], so the same code drives a
//! co-located [`crate::client::Client`] and a clustered
//! [`crate::client::ClusterClient`].  Both per-epoch database interactions
//! are single round trips per database instance: [`DataLoader::wait_for_step`]
//! issues one `PollKeys` (the server waits, with backoff) and
//! [`DataLoader::gather`] issues one `MGetTensors` instead of one
//! `get_tensor` per owned rank.
//!
//! Bounded-memory runs train on a *moving window*:
//! [`DataLoader::gather_window`] fetches the newest `W` step generations in
//! one pipelined frame, skipping generations the store's retention policy
//! has already retired, and [`DataLoader::gather_latest`] consumes the
//! overwrite-mode stable keys (`{field}_rank{r}_latest`) where the store
//! holds exactly one generation per field by construction.
//!
//! When the database runs a spill-to-disk cold tier, `gather_window`
//! transparently falls back to it: generations already evicted from memory
//! are re-fetched with one pipelined `ColdGet` pass (only when something
//! was actually missing — the hot path stays one frame), so a slow
//! consumer reads retired-but-spilled history instead of skipping it.
//! Generations absent from both tiers are still skipped cleanly.

use crate::client::{stable_key, tensor_key, DataStore, Pipeline, PollConfig};
use crate::error::{Error, Result};
use crate::proto::Response;
use crate::tensor::{DType, Tensor};
use crate::util::rng::Rng;

/// Partition `n_sim` simulation ranks over `n_ml` ML ranks (contiguous
/// blocks, like the paper's 6-per-GPU pinning).
pub fn partition(n_sim: usize, n_ml: usize, ml_rank: usize) -> Vec<usize> {
    (0..n_sim).filter(|r| r * n_ml / n_sim == ml_rank).collect()
}

/// Stack `[C, N]` samples into the `[B, C, N]` batch `train_step` expects,
/// repeating samples round-robin if fewer than `b` are available.
pub fn stack_batch(samples: &[&Tensor], b: usize) -> Result<Tensor> {
    if samples.is_empty() {
        return Err(Error::Invalid("stack_batch with no samples".into()));
    }
    let shape = &samples[0].shape;
    if shape.len() != 2 {
        return Err(Error::Shape(format!("expected [C, N] samples, got {shape:?}")));
    }
    for s in samples {
        if &s.shape != shape || s.dtype != DType::F32 {
            return Err(Error::Shape("inconsistent sample shapes".into()));
        }
    }
    let mut data = Vec::with_capacity(b * samples[0].nbytes());
    for i in 0..b {
        data.extend_from_slice(&samples[i % samples.len()].data);
    }
    Ok(Tensor {
        dtype: DType::F32,
        shape: vec![b, shape[0], shape[1]],
        data: data.into(),
    })
}

/// Gathers snapshots for one ML rank through any [`DataStore`].
pub struct DataLoader<C: DataStore> {
    pub client: C,
    /// Simulation ranks this ML rank is responsible for.
    pub sim_ranks: Vec<usize>,
    pub field: String,
    rng: Rng,
    /// Generations inside a requested window that had already been retired
    /// by the store when gathered (reported in the trainer's final report).
    gens_skipped: u64,
    /// Generations completed from the spill-to-disk cold tier (at least
    /// one member came back via `ColdGet` after eviction).
    gens_cold: u64,
}

impl<C: DataStore> DataLoader<C> {
    pub fn new(client: C, sim_ranks: Vec<usize>, field: &str, seed: u64) -> DataLoader<C> {
        DataLoader {
            client,
            sim_ranks,
            field: field.to_string(),
            rng: Rng::new(seed),
            gens_skipped: 0,
            gens_cold: 0,
        }
    }

    /// Generations skipped (retired from memory and absent from the cold
    /// tier) across all `gather_window` calls so far.
    pub fn gens_skipped(&self) -> u64 {
        self.gens_skipped
    }

    /// Generations recovered from the spill-to-disk cold tier across all
    /// `gather_window` calls so far.
    pub fn gens_cold(&self) -> u64 {
        self.gens_cold
    }

    /// Keys of every owned snapshot at `step`.
    fn step_keys(&self, step: u64) -> Vec<String> {
        self.sim_ranks
            .iter()
            .map(|&r| tensor_key(&self.field, r, step))
            .collect()
    }

    /// Wait until the producer has published step `step` for all owned sim
    /// ranks (the "metadata transfer" wait of Table 2) — one request frame
    /// per database instance, the server does the waiting.
    pub fn wait_for_step(&mut self, step: u64, poll: &PollConfig) -> Result<()> {
        self.client.poll_keys(&self.step_keys(step), poll)
    }

    /// Gather every owned tensor at `step` (`[C, N]` each) in one batched
    /// round trip per database instance.
    pub fn gather(&mut self, step: u64) -> Result<Vec<Tensor>> {
        self.client.mget_tensors(&self.step_keys(step))
    }

    /// Gather the newest `window` step generations ending at `latest`: one
    /// pipelined request frame per database instance, plus (only when
    /// something was missing) one pipelined `ColdGet` pass over the spill
    /// tier.
    ///
    /// Bounded-memory runs race the producer: a generation inside the
    /// requested window may already have been retired by the store's
    /// retention policy.  With a cold tier configured its members come
    /// back from disk transparently (byte-exact — the spill log stores the
    /// evicted payloads verbatim); without one, the generation is skipped
    /// (clean `NotFound` entries).  The `latest` generation must be
    /// complete across both tiers — a key missing there is an error,
    /// because `wait_for_step(latest)` just saw it.
    pub fn gather_window(&mut self, latest: u64, window: u64) -> Result<Vec<Tensor>> {
        let w = window.max(1);
        let lo = latest.saturating_sub(w - 1);
        let n = self.sim_ranks.len();
        let mut pipe = Pipeline::new();
        for step in lo..=latest {
            for key in self.step_keys(step) {
                pipe.get_tensor(&key);
            }
        }
        let resps = self.client.execute(pipe)?;
        // One slot per (step, rank), in request order; hot hits fill
        // immediately, misses get one batched shot at the cold tier.
        let mut slots: Vec<Option<Tensor>> = Vec::with_capacity(resps.len());
        let mut missing: Vec<(usize, String)> = Vec::new();
        let mut it = resps.into_iter();
        for step in lo..=latest {
            for &rank in &self.sim_ranks {
                let resp = it.next().expect("pipeline reply arity");
                let key = tensor_key(&self.field, rank, step);
                match resp {
                    Response::NotFound => {
                        missing.push((slots.len(), key));
                        slots.push(None);
                    }
                    other => slots.push(Some(other.expect_tensor(&key)?)),
                }
            }
        }
        let mut cold_filled = vec![false; slots.len()];
        if !missing.is_empty() {
            let mut pipe = Pipeline::new();
            for (_, key) in &missing {
                pipe.cold_get(key);
            }
            let cold = self.client.execute(pipe)?;
            for ((slot, key), resp) in missing.into_iter().zip(cold) {
                match resp {
                    Response::NotFound => {}
                    other => {
                        slots[slot] = Some(other.expect_tensor(&key)?);
                        cold_filled[slot] = true;
                    }
                }
            }
        }
        let mut out = Vec::with_capacity(slots.len());
        for (si, step) in (lo..=latest).enumerate() {
            let members = &mut slots[si * n..(si + 1) * n];
            if members.iter().all(|s| s.is_some()) {
                if cold_filled[si * n..(si + 1) * n].iter().any(|&c| c) {
                    self.gens_cold += 1;
                }
                out.extend(members.iter_mut().map(|s| s.take().expect("checked some")));
            } else if step == latest {
                let ri = members
                    .iter()
                    .position(|s| s.is_none())
                    .expect("incomplete generation has a hole");
                return Err(Error::KeyNotFound(tensor_key(
                    &self.field,
                    self.sim_ranks[ri],
                    step,
                )));
            } else {
                self.gens_skipped += 1;
            }
        }
        Ok(out)
    }

    /// Stable keys of every owned rank (the overwrite publishing mode).
    fn latest_keys(&self) -> Vec<String> {
        self.sim_ranks
            .iter()
            .map(|&r| stable_key(&self.field, r))
            .collect()
    }

    /// Wait until every owned rank has published its overwrite-mode
    /// snapshot at least once.
    pub fn wait_latest(&mut self, poll: &PollConfig) -> Result<()> {
        self.client.poll_keys(&self.latest_keys(), poll)
    }

    /// Gather every owned overwrite-mode snapshot in one batched round
    /// trip per database instance.
    pub fn gather_latest(&mut self) -> Result<Vec<Tensor>> {
        self.client.mget_tensors(&self.latest_keys())
    }

    /// Split gathered samples into a random train/val pair: the paper
    /// validates on "one of the six tensors grabbed by each ML rank at
    /// random ... at the beginning of each epoch".
    pub fn split_validation<'a>(
        &mut self,
        samples: &'a [Tensor],
    ) -> (Vec<&'a Tensor>, Option<&'a Tensor>) {
        if samples.len() < 2 {
            return (samples.iter().collect(), None);
        }
        let v = self.rng.below(samples.len());
        let train = samples
            .iter()
            .enumerate()
            .filter(|(i, _)| *i != v)
            .map(|(_, t)| t)
            .collect();
        (train, Some(&samples[v]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_covers_all_ranks_once() {
        for (n_sim, n_ml) in [(24, 4), (10, 3), (7, 7), (5, 8)] {
            let mut seen = vec![0usize; n_sim];
            for ml in 0..n_ml {
                for r in partition(n_sim, n_ml, ml) {
                    seen[r] += 1;
                }
            }
            assert!(seen.iter().all(|&c| c == 1), "{n_sim}/{n_ml}: {seen:?}");
        }
    }

    #[test]
    fn partition_is_balanced() {
        let sizes: Vec<usize> = (0..4).map(|ml| partition(24, 4, ml).len()).collect();
        assert_eq!(sizes, vec![6, 6, 6, 6], "paper: 6 tensors per ML rank");
    }

    #[test]
    fn stack_batch_shapes_and_repeat() {
        let a = Tensor::from_f32(&[2, 3], vec![1.0; 6]).unwrap();
        let b = Tensor::from_f32(&[2, 3], vec![2.0; 6]).unwrap();
        let batch = stack_batch(&[&a, &b], 4).unwrap();
        assert_eq!(batch.shape, vec![4, 2, 3]);
        let v = batch.to_f32().unwrap();
        assert_eq!(&v[0..6], &[1.0; 6]);
        assert_eq!(&v[6..12], &[2.0; 6]);
        assert_eq!(&v[12..18], &[1.0; 6], "round-robin repeat");
    }

    #[test]
    fn stack_batch_rejects_mismatch() {
        let a = Tensor::from_f32(&[2, 3], vec![0.0; 6]).unwrap();
        let b = Tensor::from_f32(&[3, 2], vec![0.0; 6]).unwrap();
        assert!(stack_batch(&[&a, &b], 2).is_err());
        assert!(stack_batch(&[], 2).is_err());
    }
}
