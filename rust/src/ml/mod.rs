//! The data consumer: distributed in-situ training of the QuadConv
//! autoencoder from live simulation data (paper §4).
//!
//! Python never appears here — the fused `train_step` (fwd + bwd + Adam) and
//! `eval_step` artifacts are executed through PJRT.  Rank parallelism follows
//! the paper's DDP setup: each ML rank gathers its share of snapshots from
//! the (co-located) database, computes gradients on its mini-batch, the
//! gradients are allreduce-averaged, and one Adam update is applied — the
//! `grad_step`/`apply_adam` artifact pair mirrors exactly that
//! decomposition.

pub mod dataloader;
pub mod state;
pub mod trainer;

pub use dataloader::{partition, stack_batch, DataLoader};
pub use state::ParamState;
pub use trainer::{EpochLog, Trainer, TrainerConfig};
