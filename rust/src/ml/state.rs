//! Parameter + optimizer state management, manifest-ordered.

use std::path::Path;

use crate::error::{Error, Result};
use crate::runtime::Manifest;
use crate::tensor::{DType, Tensor};

/// Flat, canonically-ordered model parameters plus Adam moments.
#[derive(Debug, Clone)]
pub struct ParamState {
    /// Parameter tensors in `manifest.param_order`.
    pub params: Vec<Tensor>,
    pub m: Vec<Tensor>,
    pub v: Vec<Tensor>,
    pub step: i32,
}

impl ParamState {
    /// Load the initial parameters exported by `aot.py`
    /// (`params_init.bin`) and zero moments.
    pub fn load_init(manifest: &Manifest, artifacts_dir: &Path) -> Result<ParamState> {
        let bytes = crate::tensor::Bytes::from_vec(
            std::fs::read(artifacts_dir.join("params_init.bin"))
                .map_err(|e| Error::Parse(format!("params_init.bin: {e}")))?,
        );
        if bytes.len() != manifest.model.n_params_total * 4 {
            return Err(Error::Parse(format!(
                "params_init.bin is {} bytes, manifest wants {}",
                bytes.len(),
                manifest.model.n_params_total * 4
            )));
        }
        let mut params = Vec::with_capacity(manifest.param_table.len());
        let mut m = Vec::with_capacity(manifest.param_table.len());
        let mut v = Vec::with_capacity(manifest.param_table.len());
        for row in &manifest.param_table {
            let start = row.offset * 4;
            let end = start + row.len * 4;
            // Every parameter tensor is a view into the one file read —
            // zero-copy load, and `train_step_inputs`' clones stay refcount
            // bumps from here on.
            params.push(Tensor {
                dtype: DType::F32,
                shape: row.shape.clone(),
                data: bytes.slice(start..end),
            });
            m.push(Tensor::zeros(DType::F32, &row.shape));
            v.push(Tensor::zeros(DType::F32, &row.shape));
        }
        Ok(ParamState { params, m, v, step: 0 })
    }

    pub fn n_tensors(&self) -> usize {
        self.params.len()
    }

    /// Inputs for the fused `train_step` artifact:
    /// `params..., m..., v..., step, batch`.
    pub fn train_step_inputs(&self, batch: Tensor) -> Vec<Tensor> {
        let mut v: Vec<Tensor> = Vec::with_capacity(3 * self.params.len() + 2);
        v.extend(self.params.iter().cloned());
        v.extend(self.m.iter().cloned());
        v.extend(self.v.iter().cloned());
        v.push(Tensor::scalar_i32(self.step));
        v.push(batch);
        v
    }

    /// Absorb the outputs of `train_step`:
    /// `params'..., m'..., v'..., step', loss`.  Returns the loss.
    pub fn absorb_train_step(&mut self, mut out: Vec<Tensor>) -> Result<f32> {
        let p = self.params.len();
        if out.len() != 3 * p + 2 {
            return Err(Error::Shape(format!(
                "train_step returned {} tensors, wanted {}",
                out.len(),
                3 * p + 2
            )));
        }
        let loss = out.pop().unwrap().first_f32()?;
        let step = out.pop().unwrap().to_i32()?[0];
        self.v = out.split_off(2 * p);
        self.m = out.split_off(p);
        self.params = out;
        self.step = step;
        Ok(loss)
    }

    /// Inputs for `grad_step`: `params..., batch`.
    pub fn grad_step_inputs(&self, batch: Tensor) -> Vec<Tensor> {
        let mut v: Vec<Tensor> = Vec::with_capacity(self.params.len() + 1);
        v.extend(self.params.iter().cloned());
        v.push(batch);
        v
    }

    /// Inputs for `apply_adam`: `params..., m..., v..., step, grads...`.
    pub fn apply_adam_inputs(&self, grads: Vec<Tensor>) -> Vec<Tensor> {
        let mut v: Vec<Tensor> = Vec::with_capacity(4 * self.params.len() + 1);
        v.extend(self.params.iter().cloned());
        v.extend(self.m.iter().cloned());
        v.extend(self.v.iter().cloned());
        v.push(Tensor::scalar_i32(self.step));
        v.extend(grads);
        v
    }

    /// Absorb `apply_adam` outputs: `params'..., m'..., v'..., step'`.
    pub fn absorb_apply_adam(&mut self, mut out: Vec<Tensor>) -> Result<()> {
        let p = self.params.len();
        if out.len() != 3 * p + 1 {
            return Err(Error::Shape(format!(
                "apply_adam returned {} tensors, wanted {}",
                out.len(),
                3 * p + 1
            )));
        }
        let step = out.pop().unwrap().to_i32()?[0];
        self.v = out.split_off(2 * p);
        self.m = out.split_off(p);
        self.params = out;
        self.step = step;
        Ok(())
    }
}

/// Element-wise mean of per-rank gradient sets — the allreduce of DDP.
pub fn allreduce_mean(per_rank: &[Vec<Tensor>]) -> Result<Vec<Tensor>> {
    let r = per_rank.len();
    if r == 0 {
        return Err(Error::Invalid("allreduce over zero ranks".into()));
    }
    let n = per_rank[0].len();
    let mut out = Vec::with_capacity(n);
    for t in 0..n {
        let first = &per_rank[0][t];
        let mut acc = first.to_f32()?;
        for rank in per_rank.iter().skip(1) {
            if rank.len() != n || rank[t].shape != first.shape {
                return Err(Error::Shape("gradient shape mismatch across ranks".into()));
            }
            for (a, b) in acc.iter_mut().zip(rank[t].to_f32()?) {
                *a += b;
            }
        }
        let inv = 1.0 / r as f32;
        for a in acc.iter_mut() {
            *a *= inv;
        }
        out.push(Tensor::from_f32(&first.shape, acc)?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(shape: &[usize], v: Vec<f32>) -> Tensor {
        Tensor::from_f32(shape, v).unwrap()
    }

    #[test]
    fn allreduce_means_elementwise() {
        let r0 = vec![t(&[2], vec![1.0, 2.0]), t(&[1], vec![10.0])];
        let r1 = vec![t(&[2], vec![3.0, 6.0]), t(&[1], vec![-10.0])];
        let avg = allreduce_mean(&[r0, r1]).unwrap();
        assert_eq!(avg[0].to_f32().unwrap(), vec![2.0, 4.0]);
        assert_eq!(avg[1].to_f32().unwrap(), vec![0.0]);
    }

    #[test]
    fn allreduce_single_rank_is_identity() {
        let r0 = vec![t(&[3], vec![1.0, -1.0, 5.0])];
        let avg = allreduce_mean(std::slice::from_ref(&r0)).unwrap();
        assert_eq!(avg[0].to_f32().unwrap(), vec![1.0, -1.0, 5.0]);
    }

    #[test]
    fn allreduce_rejects_mismatch() {
        let r0 = vec![t(&[2], vec![1.0, 2.0])];
        let r1 = vec![t(&[3], vec![1.0, 2.0, 3.0])];
        assert!(allreduce_mean(&[r0, r1]).is_err());
    }

    #[test]
    fn train_step_io_roundtrip_shapes() {
        let mut st = ParamState {
            params: vec![t(&[2], vec![1.0, 2.0]), t(&[1], vec![3.0])],
            m: vec![Tensor::zeros(DType::F32, &[2]), Tensor::zeros(DType::F32, &[1])],
            v: vec![Tensor::zeros(DType::F32, &[2]), Tensor::zeros(DType::F32, &[1])],
            step: 0,
        };
        let batch = t(&[1, 4], vec![0.0; 4]);
        let inputs = st.train_step_inputs(batch);
        assert_eq!(inputs.len(), 8);
        // Fake outputs: shift params by 1.
        let mut out: Vec<Tensor> = inputs[..6].to_vec();
        out.push(Tensor::scalar_i32(1));
        out.push(Tensor::scalar_f32(0.5));
        let loss = st.absorb_train_step(out).unwrap();
        assert_eq!(loss, 0.5);
        assert_eq!(st.step, 1);
        assert_eq!(st.params[0].to_f32().unwrap(), vec![1.0, 2.0]);
    }
}
