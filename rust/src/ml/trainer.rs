//! The distributed in-situ trainer (paper §4).
//!
//! Epoch structure mirrors the paper exactly: at the start of each epoch
//! every ML rank gathers its 6 snapshots from the co-located database
//! (waiting/polling if the producer hasn't published yet — the Table-2
//! "metadata transfer" cost), holds one out for validation, then runs
//! mini-batch SGD (Adam) over the rest.  DDP semantics: per-rank `grad_step`
//! + gradient allreduce + one `apply_adam`; with a single ML rank the fused
//! `train_step` fast path is used instead.

use std::net::SocketAddr;
use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

use crate::client::{Client, DataStore, PollConfig};
use crate::error::{Error, Result};
use crate::ml::dataloader::{self, DataLoader};
use crate::ml::state::{allreduce_mean, ParamState};
use crate::runtime::{Executor, Manifest};
use crate::telemetry::{ComponentTimes, Stopwatch};
use crate::tensor::Tensor;

/// Trainer configuration.
#[derive(Debug, Clone)]
pub struct TrainerConfig {
    pub db_addr: SocketAddr,
    /// Number of ML ranks (paper: 4 per node, one per GPU).
    pub ml_ranks: usize,
    /// Number of simulation ranks producing snapshots.
    pub sim_ranks: usize,
    pub epochs: usize,
    /// Field prefix the producer publishes under.
    pub field: String,
    /// Polling discipline while waiting on the producer (backoff shape and
    /// per-wait budget; the snapshot step consumed per epoch advances when
    /// the producer publishes faster than the trainer consumes).
    pub poll: PollConfig,
    /// Train on the newest `window` step generations each epoch (1 = the
    /// paper's single-snapshot behavior).  On bounded-memory deployments
    /// this must not exceed the store's retention window; generations
    /// retired mid-gather are skipped.
    pub window: u64,
    /// Consume the producer's overwrite-mode stable keys instead of step
    /// keys.  The store then holds exactly one generation per field, so
    /// `window` is moot and ignored.
    pub overwrite: bool,
    /// Publish the encoder artifact into the database's model registry
    /// under this key as training progresses (`None` = don't).  Each
    /// publish allocates the next immutable version and hot-swaps the live
    /// pointer, so servers running inference against the key pick up the
    /// newer checkpoint on their next call — the serving half of the
    /// in-situ loop.
    pub checkpoint_key: Option<String>,
    /// Publish after every `checkpoint_every` epochs (0 = only once, after
    /// the final epoch).  Ignored without `checkpoint_key`.
    pub checkpoint_every: usize,
}

impl Default for TrainerConfig {
    fn default() -> Self {
        TrainerConfig {
            db_addr: "127.0.0.1:0".parse().unwrap(),
            ml_ranks: 4,
            sim_ranks: 24,
            epochs: 100,
            field: "field".into(),
            poll: PollConfig::default(),
            window: 1,
            overwrite: false,
            checkpoint_key: None,
            checkpoint_every: 0,
        }
    }
}

/// One epoch's record (the Fig-10 curves).
#[derive(Debug, Clone)]
pub struct EpochLog {
    pub epoch: usize,
    pub step: i32,
    pub train_loss: f32,
    pub val_loss: f32,
    pub val_rel_err: f32,
}

/// The trainer itself.
pub struct Trainer {
    pub cfg: TrainerConfig,
    pub manifest: Manifest,
    pub state: ParamState,
    exec: Executor,
    loaders: Vec<DataLoader<Client>>,
    artifacts_dir: std::path::PathBuf,
    pub times: Arc<ComponentTimes>,
    pub history: Vec<EpochLog>,
    /// Model versions published under `checkpoint_key` so far.
    pub checkpoints_published: u64,
}

impl Trainer {
    /// Connect every ML rank's client and load artifacts + initial params.
    pub fn new(cfg: TrainerConfig, artifacts_dir: &Path, exec: Executor) -> Result<Trainer> {
        let manifest = Manifest::load_dir(artifacts_dir)?;
        for name in ["train_step", "grad_step", "apply_adam", "eval_step"] {
            let art = manifest.artifact(name)?;
            exec.load_artifact(name, &artifacts_dir.join(&art.file))?;
        }
        let state = ParamState::load_init(&manifest, artifacts_dir)?;
        let times = Arc::new(ComponentTimes::new());
        let mut loaders = Vec::with_capacity(cfg.ml_ranks);
        for ml in 0..cfg.ml_ranks {
            let sw = Stopwatch::start();
            let client = Client::connect_retry(cfg.db_addr, 100, Duration::from_millis(20))?;
            times.record("client_init", sw.stop());
            let ranks = dataloader::partition(cfg.sim_ranks, cfg.ml_ranks, ml);
            loaders.push(DataLoader::new(client, ranks, &cfg.field, 1000 + ml as u64));
        }
        Ok(Trainer {
            cfg,
            manifest,
            state,
            exec,
            loaders,
            artifacts_dir: artifacts_dir.to_path_buf(),
            times,
            history: Vec::new(),
            checkpoints_published: 0,
        })
    }

    /// Publish the current encoder as a serving checkpoint (no-op unless
    /// `checkpoint_key` is configured).  The stub PJRT backend cannot
    /// re-serialize updated weights, so every publish ships the artifact
    /// file — what matters to the serving side is real either way: a new
    /// immutable version, a live-pointer swap, and in-flight inference on
    /// the prior version completing untouched.
    pub fn publish_checkpoint(&mut self) -> Result<Option<u64>> {
        let Some(key) = self.cfg.checkpoint_key.clone() else { return Ok(None) };
        let sw = Stopwatch::start();
        let art = self.manifest.artifact("encoder")?;
        let path = self.artifacts_dir.join(&art.file);
        let version = self.loaders[0].client.put_model_from_file(&key, &path)?;
        self.checkpoints_published += 1;
        self.times.record("checkpoint_publish", sw.stop());
        Ok(Some(version))
    }

    /// Latest snapshot step the producer has announced (via metadata key
    /// `latest_step`), or an error after the poll budget.  `PollKeys` spans
    /// the metadata namespace, so the wait is server-side and costs one
    /// round trip plus the `get_meta` read — no client busy-poll.
    pub fn wait_latest_step(&mut self) -> Result<u64> {
        let sw = Stopwatch::start();
        let poll = self.cfg.poll;
        self.loaders[0]
            .client
            .poll_key("latest_step", &poll)
            .map_err(|e| match e {
                Error::Timeout(_) => {
                    Error::Timeout("producer never published latest_step".into())
                }
                other => other,
            })?;
        let v = self.loaders[0]
            .client
            .get_meta("latest_step")?
            .ok_or_else(|| Error::Invalid("latest_step vanished after poll".into()))?;
        self.times.record("metadata", sw.stop());
        v.parse()
            .map_err(|_| Error::Parse(format!("bad latest_step '{v}'")))
    }

    /// Run one epoch against snapshot `step`.  Returns the epoch log.
    pub fn epoch(&mut self, epoch: usize, step: u64) -> Result<EpochLog> {
        let b = self.manifest.model.batch;
        // --- gather phase (Table 2: "training data retrieve") -------------
        let sw = Stopwatch::start();
        // Two request frames per rank per epoch: one server-side wait for
        // all owned keys, one batched (windowed) gather.
        let poll = self.cfg.poll;
        let (window, overwrite) = (self.cfg.window, self.cfg.overwrite);
        let mut per_rank_samples: Vec<Vec<Tensor>> = Vec::with_capacity(self.loaders.len());
        for l in &mut self.loaders {
            if overwrite {
                l.wait_latest(&poll)?;
                per_rank_samples.push(l.gather_latest()?);
            } else {
                l.wait_for_step(step, &poll)?;
                per_rank_samples.push(l.gather_window(step, window)?);
            }
        }
        self.times.record("retrieve", sw.stop());

        // --- train phase ----------------------------------------------------
        let sw = Stopwatch::start();
        let train_loss;
        if self.loaders.len() == 1 {
            // Fused fast path.
            let (train, _val) = self.loaders[0].split_validation(&per_rank_samples[0]);
            let batch = dataloader::stack_batch(&train, b)?;
            let out = self.exec.execute("train_step", self.state.train_step_inputs(batch))?;
            train_loss = self.state.absorb_train_step(out)?;
        } else {
            // DDP: per-rank grads, allreduce, one Adam application.
            let mut grads = Vec::with_capacity(self.loaders.len());
            let mut losses = Vec::with_capacity(self.loaders.len());
            for (l, samples) in self.loaders.iter_mut().zip(&per_rank_samples) {
                let (train, _val) = l.split_validation(samples);
                let batch = dataloader::stack_batch(&train, b)?;
                let mut out = self.exec.execute("grad_step", self.state.grad_step_inputs(batch))?;
                // outputs: loss, g...
                let g = out.split_off(1);
                losses.push(out.pop().unwrap().first_f32()?);
                grads.push(g);
            }
            let mean = allreduce_mean(&grads)?;
            let out = self.exec.execute("apply_adam", self.state.apply_adam_inputs(mean))?;
            self.state.absorb_apply_adam(out)?;
            train_loss = losses.iter().sum::<f32>() / losses.len() as f32;
        }
        self.times.record("train", sw.stop());

        // --- validation (paper: one held-out tensor per rank) --------------
        let sw = Stopwatch::start();
        let mut val_loss = 0.0f32;
        let mut val_err = 0.0f32;
        let mut val_n = 0usize;
        for (l, samples) in self.loaders.iter_mut().zip(&per_rank_samples) {
            let (_train, val) = l.split_validation(samples);
            let sample = val.unwrap_or(&samples[0]);
            let batch = dataloader::stack_batch(&[sample], b)?;
            let mut inputs = self.state.params.clone();
            inputs.push(batch);
            let out = self.exec.execute("eval_step", inputs)?;
            val_loss += out[0].first_f32()?;
            val_err += out[1].first_f32()?;
            val_n += 1;
        }
        val_loss /= val_n.max(1) as f32;
        val_err /= val_n.max(1) as f32;
        self.times.record("validate", sw.stop());

        let log = EpochLog {
            epoch,
            step: self.state.step,
            train_loss,
            val_loss,
            val_rel_err: val_err,
        };
        self.history.push(log.clone());
        Ok(log)
    }

    /// Run the full training loop: each epoch consumes the latest published
    /// snapshot (epochs proceed even if the producer is slower — the paper
    /// completes ~20 epochs per snapshot and reports convergence insensitive
    /// to that ratio).
    pub fn run(&mut self) -> Result<()> {
        let sw = Stopwatch::start();
        for e in 0..self.cfg.epochs {
            let step = self.wait_latest_step()?;
            self.epoch(e, step)?;
            if self.cfg.checkpoint_every > 0 && (e + 1) % self.cfg.checkpoint_every == 0 {
                self.publish_checkpoint()?;
            }
        }
        // With no periodic cadence (or a cadence the epoch count never
        // hit), still ship the final model.
        if self.cfg.checkpoint_key.is_some() && self.checkpoints_published == 0 {
            self.publish_checkpoint()?;
        }
        self.times.record("total_training", sw.stop());
        Ok(())
    }

    /// Paper-style Table 2.
    pub fn table(&self) -> crate::telemetry::Table {
        self.times
            .to_table("ML training components during in situ training (averaged across ranks)")
    }

    /// Window generations the loaders requested but found already retired
    /// (racing the store's retention policy) — the consumer-side half of
    /// the backpressure accounting in the run report.
    pub fn skipped_generations(&self) -> u64 {
        self.loaders.iter().map(|l| l.gens_skipped()).sum()
    }
}
