//! Deployment planning: how many database instances, with which engine and
//! core binding, for a given run configuration (paper Fig 2).

use std::path::PathBuf;
use std::sync::Arc;

use crate::client::{ClusterConfig, GovernorConfig};
use crate::config::{Deployment, RunConfig};
use crate::db::spill::default_segment_bytes;
use crate::db::{Engine, RetentionConfig, ServerConfig, SpillConfig};
use crate::util::fault::{FaultConfig, FaultPlan};

/// One database instance to launch.
#[derive(Debug, Clone)]
pub struct DbSpec {
    /// Logical node hosting this instance.
    pub node: usize,
    pub engine: Engine,
    pub cores: usize,
    pub with_models: bool,
    /// Retention / capacity policy applied to this instance's store.
    pub retention: RetentionConfig,
    /// Spill-to-disk cold tier for this instance (its own subdirectory of
    /// the run's `--spill-dir`, so instances never share a segment log).
    pub spill: Option<SpillConfig>,
    /// Reactor threads for this instance (0 = auto; see
    /// [`ServerConfig::reactors`]).
    pub reactors: usize,
}

/// The resolved plan.
#[derive(Debug, Clone)]
pub struct DeploymentPlan {
    pub dbs: Vec<DbSpec>,
    pub deployment: Deployment,
    /// Sim ranks per node and total.
    pub ranks_per_node: usize,
    pub nodes: usize,
    /// Producer-side backpressure handling (retry + adaptive snapshot
    /// skipping) every publishing component of this deployment uses.
    pub governor: GovernorConfig,
    /// Write replication factor clients of this deployment use (1 = none).
    pub replicas: usize,
    /// Chaos-harness knobs carried through from the run config: seed 0
    /// means no fault injection anywhere.
    pub chaos_seed: u64,
    pub chaos_intensity: f64,
}

impl DeploymentPlan {
    pub fn new(cfg: &RunConfig, with_models: bool) -> DeploymentPlan {
        let retention = RetentionConfig {
            window: cfg.retention_window,
            max_bytes: cfg.db_max_bytes,
            ttl_ms: cfg.db_ttl_ms,
        };
        // Each instance spills into its own subdirectory of the run's base
        // spill dir (two stores sharing one segment log would corrupt it).
        let spill_base: Option<PathBuf> = cfg.spill_dir.as_deref().map(PathBuf::from);
        let spill_for = |node: usize| {
            spill_base.as_ref().map(|base| SpillConfig {
                dir: base.join(format!("db{node}")),
                max_bytes: cfg.spill_max_bytes,
                segment_bytes: default_segment_bytes(),
            })
        };
        let dbs = match cfg.deployment {
            Deployment::CoLocated => (0..cfg.nodes)
                .map(|node| DbSpec {
                    node,
                    engine: cfg.engine,
                    cores: cfg.db_cores,
                    with_models,
                    retention,
                    spill: spill_for(node),
                    reactors: cfg.reactors,
                })
                .collect(),
            Deployment::Clustered { db_nodes } => (0..db_nodes.max(1))
                .map(|i| DbSpec {
                    node: cfg.nodes + i, // dedicated nodes after the sim nodes
                    engine: cfg.engine,
                    cores: crate::cluster::scaling::CLUSTERED_DB_CORES,
                    with_models,
                    retention,
                    spill: spill_for(cfg.nodes + i),
                    reactors: cfg.reactors,
                })
                .collect(),
        };
        DeploymentPlan {
            dbs,
            deployment: cfg.deployment,
            ranks_per_node: cfg.ranks_per_node,
            nodes: cfg.nodes,
            governor: cfg.governor(),
            replicas: cfg.replicas.max(1),
            chaos_seed: cfg.chaos_seed,
            chaos_intensity: cfg.chaos_intensity,
        }
    }

    /// Total nodes the job occupies (clustered pays for extra DB nodes —
    /// the paper's argument for preferring co-location).
    pub fn total_nodes(&self) -> usize {
        match self.deployment {
            Deployment::CoLocated => self.nodes,
            Deployment::Clustered { db_nodes } => self.nodes + db_nodes,
        }
    }

    pub fn server_configs(&self) -> Vec<ServerConfig> {
        self.dbs
            .iter()
            .map(|d| ServerConfig {
                addr: "127.0.0.1:0".parse().unwrap(),
                engine: d.engine,
                cores: d.cores,
                with_models: d.with_models,
                retention: d.retention,
                spill: d.spill.clone(),
                fault: self.fault_plan_for(d.node),
                reactors: d.reactors,
                ..Default::default()
            })
            .collect()
    }

    /// The seeded fault plan for one database instance, or `None` when the
    /// chaos harness is off.  Each instance gets its own plan, seeded from
    /// `(chaos_seed, node)` so the whole deployment's failure schedule is a
    /// pure function of the run's `--chaos-seed` — instance `n` misbehaves
    /// identically across runs regardless of launch order.
    pub fn fault_plan_for(&self, node: usize) -> Option<Arc<FaultPlan>> {
        if self.chaos_seed == 0 {
            return None;
        }
        let seed = self
            .chaos_seed
            .wrapping_add((node as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15));
        Some(Arc::new(FaultPlan::new(FaultConfig::with_intensity(
            seed,
            self.chaos_intensity,
        ))))
    }

    /// How clients should connect to this deployment's shard set:
    /// replication factor from the run config, everything else default.
    pub fn cluster_config(&self) -> ClusterConfig {
        ClusterConfig { replicas: self.replicas, ..ClusterConfig::default() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn colocated_one_db_per_node() {
        let mut cfg = RunConfig::default();
        cfg.nodes = 3;
        let plan = DeploymentPlan::new(&cfg, false);
        assert_eq!(plan.dbs.len(), 3);
        assert_eq!(plan.total_nodes(), 3);
        assert_eq!(plan.dbs[1].node, 1);
        assert_eq!(plan.dbs[0].cores, 8);
    }

    #[test]
    fn plan_threads_retention_policy_to_every_instance() {
        let mut cfg = RunConfig::default();
        cfg.nodes = 2;
        cfg.retention_window = 5;
        cfg.db_max_bytes = 1 << 20;
        cfg.db_ttl_ms = 45_000;
        let want = RetentionConfig { window: 5, max_bytes: 1 << 20, ttl_ms: 45_000 };
        for deployment in [Deployment::CoLocated, Deployment::Clustered { db_nodes: 2 }] {
            cfg.deployment = deployment;
            let plan = DeploymentPlan::new(&cfg, false);
            for sc in plan.server_configs() {
                assert_eq!(sc.retention, want);
            }
        }
    }

    #[test]
    fn plan_threads_spill_config_with_per_instance_dirs() {
        let mut cfg = RunConfig::default();
        cfg.nodes = 2;
        cfg.spill_dir = Some("/tmp/situ-cold".into());
        cfg.spill_max_bytes = 1 << 20;
        for deployment in [Deployment::CoLocated, Deployment::Clustered { db_nodes: 2 }] {
            cfg.deployment = deployment;
            let plan = DeploymentPlan::new(&cfg, false);
            let dirs: Vec<PathBuf> = plan
                .server_configs()
                .iter()
                .map(|sc| sc.spill.as_ref().expect("spill threaded").dir.clone())
                .collect();
            assert_eq!(dirs.len(), 2);
            assert_ne!(dirs[0], dirs[1], "instances never share a segment log");
            for (sc, d) in plan.server_configs().iter().zip(&plan.dbs) {
                let spill = sc.spill.as_ref().unwrap();
                assert_eq!(spill.max_bytes, 1 << 20);
                assert_eq!(spill.dir, PathBuf::from(format!("/tmp/situ-cold/db{}", d.node)));
            }
        }
        // No --spill-dir → no cold tier anywhere.
        cfg.spill_dir = None;
        let plan = DeploymentPlan::new(&cfg, false);
        assert!(plan.server_configs().iter().all(|sc| sc.spill.is_none()));
    }

    #[test]
    fn plan_threads_governor_config() {
        let mut cfg = RunConfig::default();
        cfg.busy_retries = 3;
        cfg.governor_max_stride = 4;
        let plan = DeploymentPlan::new(&cfg, false);
        assert_eq!(plan.governor, cfg.governor());
        assert_eq!(plan.governor.max_stride, 4);
    }

    #[test]
    fn plan_threads_replication_and_chaos() {
        let mut cfg = RunConfig::default();
        cfg.nodes = 2;
        cfg.replicas = 2;
        cfg.chaos_seed = 9;
        let plan = DeploymentPlan::new(&cfg, false);
        assert_eq!(plan.replicas, 2);
        assert_eq!(plan.cluster_config().replicas, 2);
        // Every instance wears a fault plan, each with a distinct seed.
        let scs = plan.server_configs();
        assert!(scs.iter().all(|sc| sc.fault.is_some()));
        let s0 = scs[0].fault.as_ref().unwrap().config().seed;
        let s1 = scs[1].fault.as_ref().unwrap().config().seed;
        assert_ne!(s0, s1, "per-instance schedules are independent");
        // And the schedule is a pure function of the chaos seed.
        assert_eq!(s0, DeploymentPlan::new(&cfg, false).fault_plan_for(plan.dbs[0].node).unwrap().config().seed);
        // Seed 0 = chaos off everywhere, the production default.
        cfg.chaos_seed = 0;
        let plan = DeploymentPlan::new(&cfg, false);
        assert!(plan.server_configs().iter().all(|sc| sc.fault.is_none()));
        assert_eq!(plan.cluster_config().replicas, 2);
    }

    #[test]
    fn clustered_dedicated_nodes_full_socket() {
        let mut cfg = RunConfig::default();
        cfg.nodes = 4;
        cfg.deployment = Deployment::Clustered { db_nodes: 2 };
        let plan = DeploymentPlan::new(&cfg, false);
        assert_eq!(plan.dbs.len(), 2);
        assert_eq!(plan.total_nodes(), 6, "clustered costs extra nodes");
        assert_eq!(plan.dbs[0].node, 4);
        assert_eq!(plan.dbs[0].cores, 32);
    }
}
