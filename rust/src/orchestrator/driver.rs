//! The driver: launches database instances, the CFD producer and the
//! in-situ trainer, wires them together, and reports the paper's Tables 1-2
//! and Fig-10 curves.

use std::net::SocketAddr;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::client::{Client, ClusterClient, DataStore, GovernorConfig, GovernorStats, PollConfig};
use crate::config::RunConfig;
use crate::db::{DbServer, ServerConfig};
use crate::error::{Error, Result};
use crate::ml::{Trainer, TrainerConfig};
use crate::orchestrator::deployment::DeploymentPlan;
use crate::proto::{DbInfo, Device, ModelDeviceStat, ModelEntry};
use crate::runtime::Executor;
use crate::sim::cfd::{
    hybrid, run_producer, CfdProducerConfig, ChannelFlow, Grid, HybridConfig, HybridSolver,
    HybridStats,
};
use crate::telemetry::{ComponentTimes, Table};

/// A launched deployment: the database instances and their addresses.
pub struct Driver {
    pub servers: Vec<DbServer>,
    pub plan: DeploymentPlan,
}

impl Driver {
    /// Launch every database in the plan (in-process; each server carries
    /// its own threads, which is the single-host analogue of the IL
    /// launching jobs through the scheduler).
    pub fn launch(cfg: &RunConfig, with_models: bool) -> Result<Driver> {
        let plan = DeploymentPlan::new(cfg, with_models);
        let mut servers = Vec::with_capacity(plan.dbs.len());
        for sc in plan.server_configs() {
            servers.push(DbServer::start(sc)?);
        }
        Ok(Driver { servers, plan })
    }

    /// Launch with an externally shared PJRT executor (so DB-side inference
    /// and the trainer share one compiled-artifact cache).
    pub fn launch_shared_exec(
        cfg: &RunConfig,
        exec: &Executor,
    ) -> Result<Driver> {
        let plan = DeploymentPlan::new(cfg, true);
        let mut servers = Vec::with_capacity(plan.dbs.len());
        for sc in plan.server_configs() {
            let models = Some(Arc::new(crate::ai::ModelRuntime::new(exec.clone())));
            servers.push(DbServer::start_with(
                ServerConfig { with_models: true, ..sc },
                models,
            )?);
        }
        Ok(Driver { servers, plan })
    }

    pub fn addrs(&self) -> Vec<SocketAddr> {
        self.servers.iter().map(|s| s.addr).collect()
    }

    pub fn primary_addr(&self) -> SocketAddr {
        self.servers[0].addr
    }

    /// Cluster client over every launched shard, configured per the plan
    /// (replication factor from `--replicas`).
    pub fn cluster_client(&self) -> Result<ClusterClient> {
        ClusterClient::connect_with(&self.addrs(), self.plan.cluster_config())
    }

    /// Crash one shard the way `kill -9` would (no clean-shutdown spill
    /// barrier; in-flight connections severed if the instance wears a
    /// fault plan) — the chaos battery's kill switch.
    pub fn crash_server(&mut self, i: usize) {
        self.servers[i].simulate_crash();
    }

    pub fn shutdown(&mut self) {
        for s in &mut self.servers {
            s.shutdown();
        }
    }
}

/// Configuration of the end-to-end in-situ training run (paper §4 scaled to
/// this host — the knobs keep the paper's ratios: 24 sim ranks : 4 ML ranks
/// per node, snapshots every 2 steps, ~20 epochs per snapshot).
#[derive(Debug, Clone)]
pub struct InSituTrainingConfig {
    pub artifacts_dir: PathBuf,
    /// Solver grid (PHASTA stand-in).
    pub grid: (usize, usize, usize),
    pub nu: f64,
    /// Simulated "PHASTA ranks" publishing partitions (each samples the
    /// shared flow onto its own mesh offset).
    pub sim_ranks: usize,
    pub ml_ranks: usize,
    pub epochs: usize,
    /// Publish a snapshot every `snapshot_every` solver steps (paper: 2).
    pub snapshot_every: u64,
    /// Total solver steps to integrate.
    pub solver_steps: u64,
    pub seed: u64,
    /// Trainer window: each epoch trains on the newest `window` snapshot
    /// generations (1 = the paper's single-snapshot behavior).
    pub window: u64,
    /// Producer overwrite mode: republish each rank's snapshot under a
    /// stable key (the paper's bounded-memory alternative to append).
    pub overwrite: bool,
    /// Store retention: newest generations kept per field (0 = keep all).
    /// Must be ≥ `window` so the trainer's moving window stays resident.
    pub retention_window: u64,
    /// Store byte cap per database instance (0 = unbounded).
    pub db_max_bytes: u64,
    /// Wall-clock TTL for stalled producers' data, milliseconds (0 = off).
    pub db_ttl_ms: u64,
    /// Spill-to-disk cold tier: base directory for the database's segment
    /// log (`None` = retired generations are discarded).  Retired training
    /// snapshots stay replayable via `ColdGet` for post-hoc analysis.
    pub spill_dir: Option<PathBuf>,
    /// Byte cap on the cold tier (0 = unbounded).
    pub spill_max_bytes: u64,
    /// Producer backpressure handling: `Busy` retry policy plus the
    /// adaptive snapshot-skip stride ceiling.
    pub governor: GovernorConfig,
    /// Publish trainer checkpoints into the database's model registry
    /// under this key (`None` = training only, no serving).  Implies the
    /// deployment launches with the model runtime enabled.
    pub checkpoint_key: Option<String>,
    /// Trainer checkpoint cadence in epochs (0 = once, after training).
    pub checkpoint_every: usize,
}

impl Default for InSituTrainingConfig {
    fn default() -> Self {
        InSituTrainingConfig {
            artifacts_dir: crate::db::server::artifacts_dir(),
            grid: (24, 16, 12),
            nu: 2e-3,
            sim_ranks: 4,
            ml_ranks: 2,
            epochs: 60,
            snapshot_every: 2,
            solver_steps: 40,
            seed: 0,
            window: 1,
            overwrite: false,
            retention_window: 0,
            db_max_bytes: 0,
            db_ttl_ms: 0,
            spill_dir: None,
            spill_max_bytes: 0,
            governor: GovernorConfig::default(),
            checkpoint_key: None,
            checkpoint_every: 0,
        }
    }
}

/// Everything the e2e run reports.
pub struct InSituTrainingReport {
    pub solver_table: Table,
    pub trainer_table: Table,
    pub history: Vec<crate::ml::EpochLog>,
    pub compression_factor: f64,
    /// Fractional overhead of the framework on the solver
    /// (client init + metadata + sends vs equation formation + solution).
    pub solver_overhead_frac: f64,
    /// Final database statistics — resident/high-water bytes, eviction and
    /// per-field pressure counters that prove (or disprove) bounded memory.
    pub db: DbInfo,
    /// Producer-side flow control: publishes, skips, retries, drops.
    pub governor: GovernorStats,
    /// Fully published generations (what `latest_step` reached + 1).
    pub snapshots_published: u64,
    /// Window generations the trainer requested but found already retired.
    pub trainer_skipped_generations: u64,
    /// Model versions the trainer published into the registry.
    pub checkpoints_published: u64,
}

/// Run the full §4 workflow: co-located DB + CFD producer + in-situ trainer.
pub fn run_insitu_training(cfg: &InSituTrainingConfig) -> Result<InSituTrainingReport> {
    // --- deployment: one co-located DB ---------------------------------
    let mut run_cfg = RunConfig::default();
    run_cfg.nodes = 1;
    run_cfg.ranks_per_node = cfg.sim_ranks;
    run_cfg.ml_ranks_per_node = cfg.ml_ranks;
    run_cfg.retention_window = cfg.retention_window;
    run_cfg.db_max_bytes = cfg.db_max_bytes;
    run_cfg.db_ttl_ms = cfg.db_ttl_ms;
    run_cfg.spill_dir = cfg.spill_dir.as_ref().map(|p| p.display().to_string());
    run_cfg.spill_max_bytes = cfg.spill_max_bytes;
    let mut driver = Driver::launch(&run_cfg, cfg.checkpoint_key.is_some())?;
    let addr = driver.primary_addr();

    // --- producer: the CFD solver thread (see sim::cfd::producer) --------
    let solver_times = Arc::new(ComponentTimes::new());
    let stop = Arc::new(AtomicBool::new(false));
    let producer = {
        let times = Arc::clone(&solver_times);
        let stop = Arc::clone(&stop);
        let p_cfg = CfdProducerConfig {
            addr,
            artifacts_dir: cfg.artifacts_dir.clone(),
            grid: cfg.grid,
            nu: cfg.nu,
            sim_ranks: cfg.sim_ranks,
            snapshot_every: cfg.snapshot_every,
            solver_steps: cfg.solver_steps,
            seed: cfg.seed,
            overwrite: cfg.overwrite,
            governor: cfg.governor,
        };
        std::thread::Builder::new()
            .name("cfd-producer".into())
            .spawn(move || run_producer(&p_cfg, &times, &stop))
            .map_err(Error::Io)?
    };

    // --- consumer: the trainer ------------------------------------------
    let t_cfg = TrainerConfig {
        db_addr: addr,
        ml_ranks: cfg.ml_ranks,
        sim_ranks: cfg.sim_ranks,
        epochs: cfg.epochs,
        field: "field".into(),
        poll: PollConfig::with_max_wait(Duration::from_secs(300)),
        window: cfg.window,
        overwrite: cfg.overwrite,
        checkpoint_key: cfg.checkpoint_key.clone(),
        checkpoint_every: cfg.checkpoint_every,
    };
    let exec = Executor::new()?;
    let mut trainer = Trainer::new(t_cfg, &cfg.artifacts_dir, exec)?;
    let train_result = trainer.run();

    stop.store(true, Ordering::Relaxed);
    let outcome = producer.join().expect("producer thread panicked")?;
    train_result?;

    // --- report -----------------------------------------------------------
    let solver_table =
        solver_times.to_table("PHASTA-standin solver components during in situ training");
    let trainer_table = trainer.table();
    let snap = solver_times.snapshot();
    let solver_work: f64 = ["equation_formation", "equation_solution"]
        .iter()
        .filter_map(|k| snap.get(*k))
        .map(|s| s.sum())
        .sum();
    let overhead: f64 = ["client_init", "send", "metadata"]
        .iter()
        .filter_map(|k| snap.get(*k))
        .map(|s| s.sum())
        .sum();
    let db = {
        let mut c = Client::connect(addr)?;
        c.info()?
    };
    let report = InSituTrainingReport {
        solver_table,
        trainer_table,
        history: trainer.history.clone(),
        compression_factor: trainer.manifest.model.compression_factor,
        solver_overhead_frac: if solver_work > 0.0 { overhead / solver_work } else { 0.0 },
        db,
        governor: outcome.governor,
        snapshots_published: outcome.published,
        trainer_skipped_generations: trainer.skipped_generations(),
        checkpoints_published: trainer.checkpoints_published,
    };
    driver.shutdown();
    Ok(report)
}

/// Configuration of the hybrid serving run: a CFD integration whose
/// pressure Poisson solve is served by the database's live surrogate, with
/// the publisher shipping improved checkpoints mid-run.
#[derive(Debug, Clone)]
pub struct HybridServingConfig {
    pub grid: (usize, usize, usize),
    pub nu: f64,
    pub seed: u64,
    /// Solver steps to integrate.
    pub steps: u64,
    /// Registry key the surrogate is served under.
    pub model_key: String,
    /// Residual acceptance threshold for predictions.
    pub accept_tol: f64,
    /// The surrogate "training curve": iteration budgets of successive
    /// checkpoints.  Checkpoint `k` (0-based) is published just before
    /// solver step `(k + 1) * publish_every`, so the run starts with *no*
    /// model (exercising the fallback) and ends on the best one.
    pub checkpoint_iters: Vec<usize>,
    /// Steps between checkpoint publishes.
    pub publish_every: u64,
    /// Device the inference calls are pinned to.
    pub device: Device,
}

impl Default for HybridServingConfig {
    fn default() -> Self {
        HybridServingConfig {
            grid: (12, 10, 8),
            nu: 2e-3,
            seed: 0,
            steps: 9,
            model_key: "pressure_surrogate".into(),
            accept_tol: 1e-4,
            checkpoint_iters: vec![3, 2000],
            publish_every: 3,
            device: Device::Gpu(0),
        }
    }
}

/// Everything the hybrid serving run reports.
pub struct HybridServingReport {
    /// Accept/fallback accounting plus the residual curve.
    pub stats: HybridStats,
    /// Checkpoints the publisher shipped mid-run.
    pub checkpoints_published: u64,
    /// Registry contents at the end of the run (`ListModels`).
    pub models: Vec<ModelEntry>,
    /// Per-device execution/queue-wait statistics (`ModelStats`).
    pub device_stats: Vec<ModelDeviceStat>,
    /// Final database counters (model swaps, batches, ...).
    pub db: DbInfo,
    /// Post-run flow quality: the projection must stay near-solenoidal
    /// regardless of which path served each step.
    pub mean_abs_divergence: f64,
    pub kinetic_energy: f64,
}

/// Run the hybrid solver scenario end to end against a freshly launched
/// co-located database with the model runtime enabled.
pub fn run_hybrid_serving(cfg: &HybridServingConfig) -> Result<HybridServingReport> {
    let mut run_cfg = RunConfig::default();
    run_cfg.nodes = 1;
    let mut driver = Driver::launch(&run_cfg, true)?;
    let addr = driver.primary_addr();

    let grid = Grid::channel(cfg.grid.0, cfg.grid.1, cfg.grid.2);
    let mut flow = ChannelFlow::new(grid.clone(), cfg.nu, cfg.seed, 0.08);
    let h_cfg = HybridConfig {
        model_key: cfg.model_key.clone(),
        rank: 0,
        accept_tol: cfg.accept_tol,
        cg_tol: flow.cg_tol,
        cg_max_iter: flow.cg_max_iter,
        device: cfg.device,
    };
    let mut publisher = Client::connect(addr)?;
    let mut solver = HybridSolver::new(Client::connect(addr)?, h_cfg);

    let mut checkpoints_published = 0u64;
    let mut next = 0usize;
    for s in 0..cfg.steps {
        if s > 0
            && cfg.publish_every > 0
            && s % cfg.publish_every == 0
            && next < cfg.checkpoint_iters.len()
        {
            let text = hybrid::poisson_model_text(&grid, 1e-8, cfg.checkpoint_iters[next]);
            publisher.put_model(&cfg.model_key, &text)?;
            next += 1;
            checkpoints_published += 1;
        }
        solver.step(&mut flow);
    }

    let report = HybridServingReport {
        stats: solver.stats.clone(),
        checkpoints_published,
        models: publisher.list_models()?,
        device_stats: publisher.model_stats()?,
        db: publisher.info()?,
        mean_abs_divergence: flow.mean_abs_divergence(),
        kinetic_energy: flow.kinetic_energy(),
    };
    driver.shutdown();
    Ok(report)
}
