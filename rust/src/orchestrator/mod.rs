//! The SmartSim-IL analogue: the driver that deploys the database(s), the
//! data producer and the data consumer according to a deployment plan, then
//! monitors and tears them down.

pub mod deployment;
pub mod driver;
pub mod reshard;

pub use deployment::DeploymentPlan;
pub use driver::{
    Driver, HybridServingConfig, HybridServingReport, InSituTrainingConfig, InSituTrainingReport,
};
pub use reshard::{
    backfill, reshard, retire_generation, BackfillConfig, BackfillReport, ReshardConfig,
    ReshardReport, RetireConfig, RetireReport,
};
