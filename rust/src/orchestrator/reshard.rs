//! Driver-coordinated elastic-cluster operations.
//!
//! Three jobs share one streaming engine ([`stream_range`]):
//!
//! - [`reshard`] — live topology change (grow or shrink the shard count).
//!   Computes the moved slot ranges between the installed table and the
//!   even split over the new address list, installs the *migrating* table
//!   (epoch `e+1`, `from` markers set) on every shard, streams each moved
//!   range from the old owner's ring to the new owner's ring, installs the
//!   *committed* table (epoch `e+2`, markers cleared), then deletes the
//!   transferred copies from ring members that no longer serve them.
//! - [`backfill`] — repopulate a restarted (empty) shard from its ring
//!   peers, using the same manifest + windowed streaming path.
//! - [`retire_generation`] — archive one governed generation to **exactly
//!   one** cold tier (each key's current slot owner) and then delete every
//!   hot copy cluster-wide.
//!
//! ## Why ordering gives zero loss
//!
//! The migrating table is installed on the old owner **before** the
//! transfer manifest is taken.  From that moment the old owner bounces
//! writes for the moved slots with `moved: <epoch>`, so clients re-route
//! to the new ring and the manifest is a complete snapshot of everything
//! that will ever live on the old side.  Reads keep working throughout:
//! stale clients are bounced to refetch, fresh clients fall back to the
//! old ring for keys the stream has not landed yet.
//!
//! ## Transfer cost
//!
//! Each window is one pipelined read batch from the source plus one
//! **multiplexed tagged write round** across the destination ring — the
//! window's wall-clock cost is the *max* over destinations, not the sum
//! (`benches/fig_reshard.rs` gates this as rounds, not per-shard sends).
//!
//! ## Fault tolerance
//!
//! Every per-shard RPC is allowed to fail: an unreachable source is
//! skipped (later sources cover its keys — with `--replicas 2` every
//! moved key has a second copy somewhere), an unreachable destination is
//! skipped as long as at least one ring member takes each key, and an
//! unreachable shard misses the table install (it picks the table up at
//! `backfill` time).  The one hard failure is a key that *no* destination
//! accepted — that aborts the reshard with the migrating table still
//! installed, so reads keep falling back to the old owner and the
//! operation can simply be re-run.

use std::collections::{BTreeSet, HashSet};
use std::net::SocketAddr;

use crate::client::{Client, DataStore};
use crate::db::cluster::SlotEpoch;
use crate::error::{Error, Result};
use crate::proto::{Request, Response};
use crate::tensor::Tensor;

/// Keys per transfer window when the caller does not pick one.
pub const DEFAULT_WINDOW: usize = 64;

/// Inputs for [`reshard`].
#[derive(Debug, Clone)]
pub struct ReshardConfig {
    /// The **full** post-reshard address list; index is the shard id.
    pub addrs: Vec<SocketAddr>,
    /// Shard count before the reshard.  Only consulted when no epoch
    /// table is installed anywhere yet (a cluster that has never been
    /// resharded); `0` means "assume the cluster already spans `addrs`".
    pub from_shards: usize,
    /// Shard count after the reshard (`0` = `addrs.len()`).  Pass fewer
    /// than `addrs.len()` to *shrink*: the surplus shards' slots stream
    /// back onto the survivors, but the full address list is still needed
    /// to reach the shards being drained.
    pub to_shards: usize,
    /// Replication factor (clamped to `1..=addrs.len()`); must match what
    /// the writing clients use.
    pub replicas: usize,
    /// Keys per transfer window (`0` → [`DEFAULT_WINDOW`]).
    pub window: usize,
}

/// What [`reshard`] did.
#[derive(Debug, Clone)]
pub struct ReshardReport {
    /// Epoch of the table the reshard started from (0 = static split).
    pub from_epoch: u64,
    /// Committed epoch every reachable shard ended on.
    pub to_epoch: u64,
    /// Contiguous slot ranges that changed owner.
    pub moved_ranges: usize,
    /// Tensors streamed to their new ring.
    pub moved_keys: u64,
    /// Payload bytes streamed.
    pub moved_bytes: u64,
    /// Read + write rounds spent streaming (each write round covers the
    /// whole destination ring via tagged multiplexing).
    pub transfer_rounds: u64,
    /// Shards that could not be reached during the run (they missed the
    /// install and/or their copies; `backfill` heals them on restart).
    pub unreachable_shards: Vec<usize>,
}

/// Inputs for [`backfill`].
#[derive(Debug, Clone)]
pub struct BackfillConfig {
    /// The full cluster address list; index is the shard id.
    pub addrs: Vec<SocketAddr>,
    /// The restarted (empty) shard to repopulate.
    pub shard: usize,
    /// Replication factor the cluster runs with.
    pub replicas: usize,
    /// Keys per transfer window (`0` → [`DEFAULT_WINDOW`]).
    pub window: usize,
}

/// What [`backfill`] did.
#[derive(Debug, Clone)]
pub struct BackfillReport {
    /// Epoch of the table the shard was (re-)enrolled under.
    pub epoch: u64,
    /// Slot ranges whose ring contains the shard.
    pub ranges: usize,
    /// Tensors restored onto the shard.
    pub keys: u64,
    /// Payload bytes restored.
    pub bytes: u64,
    /// Read + write rounds spent streaming.
    pub transfer_rounds: u64,
}

/// Inputs for [`retire_generation`].
#[derive(Debug, Clone)]
pub struct RetireConfig {
    /// The full cluster address list; index is the shard id.
    pub addrs: Vec<SocketAddr>,
    /// Field whose generation is being retired (keys are
    /// `{field}_rank{r}_step{step}`).
    pub field: String,
    /// The generation (simulation step) to retire.
    pub step: u64,
}

/// What [`retire_generation`] did.
#[derive(Debug, Clone)]
pub struct RetireReport {
    /// Keys archived to a cold tier (exactly one copy each).
    pub archived: u64,
    /// Payload bytes archived.
    pub archived_bytes: u64,
    /// Hot copies deleted cluster-wide (replicas make this larger than
    /// `archived`).
    pub deleted_copies: u64,
    /// Keys of the generation that were already gone everywhere.
    pub missing: u64,
}

/// Lazily-connected per-shard admin connections.  A failed RPC drops the
/// connection; the next use reconnects, so a shard that comes back
/// mid-operation rejoins transparently.
struct Fleet {
    addrs: Vec<SocketAddr>,
    conns: Vec<Option<Client>>,
}

impl Fleet {
    fn new(addrs: &[SocketAddr]) -> Fleet {
        Fleet { addrs: addrs.to_vec(), conns: addrs.iter().map(|_| None).collect() }
    }

    fn len(&self) -> usize {
        self.addrs.len()
    }

    fn client(&mut self, shard: usize) -> Result<&mut Client> {
        if self.conns[shard].is_none() {
            self.conns[shard] = Some(Client::connect(self.addrs[shard])?);
        }
        Ok(self.conns[shard].as_mut().expect("just connected"))
    }

    /// Forget a connection after a failed RPC — the stream may be
    /// desynced, and reconnecting is the only safe retry.
    fn drop_conn(&mut self, shard: usize) {
        self.conns[shard] = None;
    }
}

/// The replica ring for `owner` under a membership of `n` shards.
fn ring(owner: usize, replicas: usize, n: usize) -> Vec<usize> {
    let n = n.max(1);
    (0..replicas.max(1).min(n)).map(|i| (owner + i) % n).collect()
}

/// Highest-epoch table installed on any reachable shard, if any.
fn installed_table(fleet: &mut Fleet) -> Option<SlotEpoch> {
    let mut best: Option<SlotEpoch> = None;
    for i in 0..fleet.len() {
        let table = match fleet.client(i).and_then(|c| c.cluster_epoch()) {
            Ok((_, t)) => t,
            Err(_) => {
                fleet.drop_conn(i);
                continue;
            }
        };
        if table.assignments.is_empty() {
            continue;
        }
        if best.as_ref().map_or(true, |b| table.epoch > b.epoch) {
            best = Some(table);
        }
    }
    best
}

/// Install `table` on every reachable shard (each learns its own index).
/// Returns the shards that could not be reached; errors only when *no*
/// shard took the install.
fn install_all(fleet: &mut Fleet, replicas: usize, table: &SlotEpoch) -> Result<Vec<usize>> {
    let mut missed = Vec::new();
    let mut landed = 0usize;
    for i in 0..fleet.len() {
        let r = fleet
            .client(i)
            .and_then(|c| c.install_epoch(i as u16, replicas as u16, table.clone()));
        match r {
            Ok(_) => landed += 1,
            Err(_) => {
                fleet.drop_conn(i);
                missed.push(i);
            }
        }
    }
    if landed == 0 {
        return Err(Error::Invalid(format!(
            "no shard reachable to install epoch {}",
            table.epoch
        )));
    }
    Ok(missed)
}

/// Streaming counters shared by the three entry points.
#[derive(Default)]
struct Transfer {
    keys: u64,
    bytes: u64,
    rounds: u64,
}

/// Stream every key hashing into `lo..=hi` that any shard in `sources`
/// holds onto every shard in `dests`, `window` keys at a time.  `done`
/// dedupes across sources (replica copies of the same key stream once)
/// and doubles as the caller's transfer manifest.
///
/// Sources are consulted in order; an unreachable one is skipped.  Each
/// window is one `MGetTensors` read from the source plus one multiplexed
/// tagged `Batch(PutTensor..)` round across the destinations.  A key that
/// lands on zero destinations is a hard error — the caller must not
/// proceed to a state where the source copies get deleted.
fn stream_range(
    fleet: &mut Fleet,
    sources: &[usize],
    dests: &[usize],
    lo: u16,
    hi: u16,
    window: usize,
    done: &mut HashSet<String>,
    xfer: &mut Transfer,
) -> Result<()> {
    let window = window.max(1);
    for &src in sources {
        let manifest = match fleet.client(src).and_then(|c| c.export_slots(lo, hi)) {
            Ok(keys) => keys,
            Err(_) => {
                // Dead or desynced source: its keys either already
                // streamed from an earlier source or stream from a later
                // replica holder.
                fleet.drop_conn(src);
                continue;
            }
        };
        let manifest: Vec<String> =
            manifest.into_iter().filter(|k| !done.contains(k)).collect();
        for win in manifest.chunks(window) {
            // Read round: bulk-fetch from the source.  MGetTensors is
            // ownership-exempt, so a surviving replica whose placement the
            // new table cannot describe is still readable here.
            let resp = match fleet
                .client(src)
                .and_then(|c| c.call(&Request::MGetTensors { keys: win.to_vec() }))
            {
                Ok(r) => r,
                Err(_) => {
                    fleet.drop_conn(src);
                    break;
                }
            };
            xfer.rounds += 1;
            let mut pairs: Vec<(&String, Tensor)> = Vec::with_capacity(win.len());
            for (key, entry) in win.iter().zip(resp.expect_batch(win.len())?) {
                match entry {
                    Response::Tensor(t) => pairs.push((key, t)),
                    // Evicted between manifest and read: the retention
                    // policy retired it, which is governance, not loss.
                    Response::NotFound => {}
                    other => {
                        other.expect_ok()?;
                        return Err(Error::Protocol(
                            "unexpected MGetTensors entry during reshard".into(),
                        ));
                    }
                }
            }
            if pairs.is_empty() {
                continue;
            }
            // Write round: one tagged batch per destination, all in
            // flight before any reply is collected — max-of-ring cost.
            let batch = Request::Batch(
                pairs
                    .iter()
                    .map(|(k, t)| Request::PutTensor { key: (*k).clone(), tensor: t.clone() })
                    .collect(),
            );
            let mut tags: Vec<(usize, u32)> = Vec::with_capacity(dests.len());
            for &d in dests {
                match fleet.client(d).and_then(|c| c.send_tagged(&batch)) {
                    Ok(t) => tags.push((d, t)),
                    Err(_) => fleet.drop_conn(d),
                }
            }
            xfer.rounds += 1;
            let mut landed = vec![0usize; pairs.len()];
            for (d, tag) in tags {
                let per = match fleet.client(d) {
                    Ok(c) => c
                        .recv_tagged(tag)
                        .and_then(|r| r.expect_batch(pairs.len()))
                        .ok(),
                    Err(_) => None,
                };
                match per {
                    Some(entries) => {
                        for (j, e) in entries.into_iter().enumerate() {
                            if e.expect_ok().is_ok() {
                                landed[j] += 1;
                            }
                        }
                    }
                    None => fleet.drop_conn(d),
                }
            }
            for (j, (key, t)) in pairs.into_iter().enumerate() {
                if landed[j] == 0 {
                    return Err(Error::Invalid(format!(
                        "transfer of {key} landed on no destination shard; \
                         aborting before any source copy is dropped"
                    )));
                }
                xfer.keys += 1;
                xfer.bytes += t.data.len() as u64;
                done.insert(key.clone());
            }
        }
    }
    Ok(())
}

/// Live-reshard the cluster to the even slot split over `cfg.addrs`.
/// Safe to re-run after a partial failure: the computation starts from
/// whatever table is installed, and streaming is idempotent.
pub fn reshard(cfg: &ReshardConfig) -> Result<ReshardReport> {
    let n = cfg.addrs.len();
    if n == 0 {
        return Err(Error::Invalid("reshard needs at least one shard address".into()));
    }
    let replicas = cfg.replicas.clamp(1, n);
    let window = if cfg.window == 0 { DEFAULT_WINDOW } else { cfg.window };
    let mut fleet = Fleet::new(&cfg.addrs);

    let cur = installed_table(&mut fleet).unwrap_or_else(|| {
        SlotEpoch::initial(if cfg.from_shards == 0 { n } else { cfg.from_shards })
    });
    let from_epoch = cur.epoch;
    let old_n = cur.n_shards().max(1);
    if old_n > n {
        return Err(Error::Invalid(format!(
            "installed table spans {old_n} shards but only {n} addresses were \
             given; pass the full cluster address list"
        )));
    }

    let to = if cfg.to_shards == 0 { n } else { cfg.to_shards };
    if to > n {
        return Err(Error::Invalid(format!(
            "--to {to} exceeds the {n} addresses given"
        )));
    }
    let target = SlotEpoch::initial(to);
    let moves = cur.moved_ranges(&target);
    if moves.is_empty() {
        // Topology already matches — still converge every shard on a
        // committed table so ownership is enforced at one epoch.
        let committed = cur.committed();
        let unreachable = install_all(&mut fleet, replicas, &committed)?;
        return Ok(ReshardReport {
            from_epoch,
            to_epoch: committed.epoch,
            moved_ranges: 0,
            moved_keys: 0,
            moved_bytes: 0,
            transfer_rounds: 0,
            unreachable_shards: unreachable,
        });
    }

    // Phase 1 — cutover for writes.  Once the old owner holds the
    // migrating table it bounces writes for the moved slots, so the
    // manifests taken below are complete snapshots.
    let migrating = cur.with_moves(&moves);
    let mut unreachable = install_all(&mut fleet, replicas, &migrating)?;

    // Phase 2 — stream each moved range old ring → new ring.
    let mut xfer = Transfer::default();
    let mut manifests: Vec<(u16, Vec<usize>, Vec<usize>, HashSet<String>)> = Vec::new();
    for &(lo, hi, old, new) in &moves {
        // Source order: the old owner's ring under the *old* membership
        // count (that is where the copies were written), then every other
        // shard — a surviving replica of a crashed owner can sit on a
        // shard no ring under the new membership describes.
        let mut sources = ring(old as usize, replicas, old_n);
        for s in 0..n {
            if !sources.contains(&s) {
                sources.push(s);
            }
        }
        // Destination ring under the *final* membership (`to`), which is
        // what the committed table will enforce; during the migration the
        // server accepts writes under either modulus (`check_owned`).
        let dests = ring(new as usize, replicas, to);
        let mut done = HashSet::new();
        stream_range(&mut fleet, &sources, &dests, lo, hi, window, &mut done, &mut xfer)?;
        manifests.push((lo, ring(old as usize, replicas, old_n), dests, done));
    }

    // Phase 3 — commit: clear the `from` markers so reads stop falling
    // back and misses become authoritative.
    let committed = migrating.committed();
    for i in install_all(&mut fleet, replicas, &committed)? {
        if !unreachable.contains(&i) {
            unreachable.push(i);
        }
    }

    // Phase 4 — drop the transferred copies from old-ring members that
    // are not part of the new ring.  Best-effort: a copy that survives a
    // failed delete is unreachable garbage (reads no longer route there),
    // reclaimed by retention or the shard's next backfill.  Deleting
    // *after* commit keeps the fallback reads of phase 2/3 lossless, at
    // the cost of a brief window where `DelKeys` on the old copy races
    // the cleanup (documented in docs/cluster.md).
    for (_lo, old_ring, dests, done) in &manifests {
        if done.is_empty() {
            continue;
        }
        let keys: Vec<String> = done.iter().cloned().collect();
        for &m in old_ring {
            if dests.contains(&m) {
                continue;
            }
            if fleet.client(m).and_then(|c| c.del_keys(&keys)).is_err() {
                fleet.drop_conn(m);
            }
        }
    }

    Ok(ReshardReport {
        from_epoch,
        to_epoch: committed.epoch,
        moved_ranges: moves.len(),
        moved_keys: xfer.keys,
        moved_bytes: xfer.bytes,
        transfer_rounds: xfer.rounds,
        unreachable_shards: unreachable,
    })
}

/// Repopulate a restarted (empty) shard from its ring peers and re-enroll
/// it under the cluster's current epoch table.
pub fn backfill(cfg: &BackfillConfig) -> Result<BackfillReport> {
    let n = cfg.addrs.len();
    if cfg.shard >= n {
        return Err(Error::Invalid(format!(
            "backfill target {} out of range ({n} addresses)",
            cfg.shard
        )));
    }
    let replicas = cfg.replicas.clamp(1, n);
    let window = if cfg.window == 0 { DEFAULT_WINDOW } else { cfg.window };
    let mut fleet = Fleet::new(&cfg.addrs);

    let table = installed_table(&mut fleet).unwrap_or_else(|| SlotEpoch::initial(n));
    // The restart wiped the shard's installed table along with its data —
    // put it back first so the shard enforces ownership like its peers.
    fleet
        .client(cfg.shard)?
        .install_epoch(cfg.shard as u16, replicas as u16, table.clone())?;

    let m = table.n_shards().max(1);
    let mut xfer = Transfer::default();
    let mut ranges = 0usize;
    for a in &table.assignments {
        let r = ring(a.shard as usize, replicas, m);
        if !r.contains(&cfg.shard) {
            continue;
        }
        ranges += 1;
        // Ring peers first (they hold the replicas), then everyone else
        // in case copies are mid-flight from an unfinished reshard.
        let mut sources: Vec<usize> = r.iter().copied().filter(|&s| s != cfg.shard).collect();
        for s in 0..n {
            if s != cfg.shard && !sources.contains(&s) {
                sources.push(s);
            }
        }
        let mut done = HashSet::new();
        stream_range(
            &mut fleet,
            &sources,
            &[cfg.shard],
            a.lo,
            a.hi,
            window,
            &mut done,
            &mut xfer,
        )?;
    }
    Ok(BackfillReport {
        epoch: table.epoch,
        ranges,
        keys: xfer.keys,
        bytes: xfer.bytes,
        transfer_rounds: xfer.rounds,
    })
}

/// Retire one governed generation cluster-wide: archive each key to the
/// cold tier of its current slot owner (**exactly one** archived copy per
/// key), then delete every hot copy.  A key is only deleted once its
/// archive write was acknowledged.
pub fn retire_generation(cfg: &RetireConfig) -> Result<RetireReport> {
    let n = cfg.addrs.len();
    if n == 0 {
        return Err(Error::Invalid("retire needs at least one shard address".into()));
    }
    let mut fleet = Fleet::new(&cfg.addrs);
    let table = installed_table(&mut fleet).unwrap_or_else(|| SlotEpoch::initial(n));
    let m = table.n_shards().max(1).min(n);

    // The generation's keys, unioned across every reachable shard —
    // replicas produce duplicates, the set removes them.
    let prefix = format!("{}_rank", cfg.field);
    let suffix = format!("_step{}", cfg.step);
    let mut keys: BTreeSet<String> = BTreeSet::new();
    for i in 0..n {
        match fleet.client(i).and_then(|c| c.list_keys(&prefix)) {
            Ok(ks) => keys.extend(ks.into_iter().filter(|k| k.ends_with(&suffix))),
            Err(_) => fleet.drop_conn(i),
        }
    }

    let mut report = RetireReport {
        archived: 0,
        archived_bytes: 0,
        deleted_copies: 0,
        missing: 0,
    };
    let mut archived: Vec<String> = Vec::new();
    for key in &keys {
        // The deterministic archive home: the key's current slot owner.
        let anchor = table.shard_for_key(key) % n;
        // Find a readable copy — anchor's ring first, then any shard;
        // hot tier first, then an existing cold copy.
        let mut holders = ring(anchor, m, m);
        for s in 0..n {
            if !holders.contains(&s) {
                holders.push(s);
            }
        }
        let mut tensor: Option<Tensor> = None;
        let mut already_cold_at_anchor = false;
        'find: for pass in 0..2 {
            for &h in &holders {
                let req = if pass == 0 {
                    Request::MGetTensors { keys: vec![key.clone()] }
                } else {
                    Request::ColdGet { key: key.clone() }
                };
                let got = match fleet.client(h).and_then(|c| c.call(&req)) {
                    Ok(r) => r,
                    Err(_) => {
                        fleet.drop_conn(h);
                        continue;
                    }
                };
                let entry = if pass == 0 {
                    got.expect_batch(1)?.pop().expect("arity checked")
                } else {
                    got
                };
                match entry {
                    Response::Tensor(t) => {
                        already_cold_at_anchor = pass == 1 && h == anchor;
                        tensor = Some(t);
                        break 'find;
                    }
                    _ => continue,
                }
            }
        }
        let Some(t) = tensor else {
            report.missing += 1;
            continue;
        };
        if !already_cold_at_anchor {
            // Exactly-once placement: only the anchor archives.  If the
            // anchor is down or has no cold tier configured, fail rather
            // than delete the hot copies.
            fleet.client(anchor)?.cold_put(key, &t)?;
        }
        report.archived += 1;
        report.archived_bytes += t.data.len() as u64;
        archived.push(key.clone());
    }

    // Delete the hot copies of everything that is safely archived, on
    // every shard (the wire `DelKeys` op is ownership-exempt — it is the
    // driver's cleanup primitive).
    if !archived.is_empty() {
        for i in 0..n {
            match fleet.client(i).and_then(|c| c.del_keys(&archived)) {
                Ok(d) => report.deleted_copies += d,
                Err(_) => fleet.drop_conn(i),
            }
        }
    }
    Ok(report)
}
