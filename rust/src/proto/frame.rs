//! Length-prefixed framing over any `Read`/`Write` stream.
//!
//! Two zero-copy additions over the classic read/write pair:
//!
//! * [`read_frame_into`] reads a frame body into a caller-owned scratch
//!   buffer, so a connection serving many small requests performs no
//!   per-request allocation at all.  When the decoded message needs to
//!   *retain* the body (a `put_tensor` payload), the caller hands the
//!   scratch `Vec` over wholesale instead (see `db::server`).
//! * [`begin_split_frame`]/[`end_split_frame`] write a frame as a small
//!   copied header plus a borrowed payload slice, so a `get_tensor` reply
//!   never re-materializes the payload in an output buffer.
//!
//! **Tagged frames** extend the format for connection multiplexing: bit 31
//! of the length word ([`FRAME_TAG_FLAG`]) marks a frame that carries a
//! u32-LE request tag between the length prefix and the body.  Replies to
//! tagged requests echo the tag, so one socket can hold many requests in
//! flight and pair possibly out-of-order replies.  Tag 0 is reserved for
//! the legacy untagged round-trip: [`write_tagged_frame`] with tag 0 emits
//! bytes identical to [`write_frame`], and [`read_frame_into_tagged`] maps
//! an unflagged frame to tag 0 — so pre-multiplexing peers interoperate
//! unchanged.  The flag bit is unambiguous because [`MAX_FRAME`] keeps
//! legitimate lengths below it (a legacy reader rejects a flagged length
//! as oversize rather than desyncing).

use std::io::{Read, Write};

use crate::error::{Error, Result};

/// Hard cap on a single frame (body) size.  The largest legitimate payload is
/// a per-rank training tensor (hundreds of MB would indicate a protocol
/// error or an attack, so we refuse it rather than OOM).
pub const MAX_FRAME: usize = 1 << 30; // 1 GiB

/// Bit 31 of the length word: this frame carries a u32-LE request tag
/// between the length prefix and the body.  Never set on legacy frames —
/// `MAX_FRAME` keeps real lengths clear of it.
pub const FRAME_TAG_FLAG: u32 = 1 << 31;

/// Message of the protocol error produced when a read times out *mid-frame*
/// (bytes already consumed, stream position lost).  Exported so the client
/// can recognize it and treat the connection as dead/retryable — the string
/// is part of the de-facto wire contract and must not change.
pub const MID_FRAME_TIMEOUT_MSG: &str = "read timeout mid-frame (stream desynced)";

/// Write one frame: u32-LE length prefix, then the body.
pub fn write_frame<W: Write>(w: &mut W, body: &[u8]) -> Result<()> {
    if body.len() > MAX_FRAME {
        return Err(Error::Protocol(format!("frame too large: {} bytes", body.len())));
    }
    w.write_all(&(body.len() as u32).to_le_bytes())?;
    w.write_all(body)?;
    w.flush()?;
    Ok(())
}

/// Write one tagged frame: flagged u32-LE length, u32-LE tag, body.  Tag 0
/// degrades to the legacy untagged encoding, byte-identical to
/// [`write_frame`] — the compat rule that lets one writer serve both peers.
pub fn write_tagged_frame<W: Write>(w: &mut W, tag: u32, body: &[u8]) -> Result<()> {
    if tag == 0 {
        return write_frame(w, body);
    }
    if body.len() > MAX_FRAME {
        return Err(Error::Protocol(format!("frame too large: {} bytes", body.len())));
    }
    w.write_all(&((body.len() as u32) | FRAME_TAG_FLAG).to_le_bytes())?;
    w.write_all(&tag.to_le_bytes())?;
    w.write_all(body)?;
    w.flush()?;
    Ok(())
}

/// Read one frame body into `scratch`, returning `(tag, body_len)` — tag 0
/// for a legacy unflagged frame; `Ok(None)` on clean EOF at a frame
/// boundary.  Timeout semantics match [`read_frame_into`].
pub fn read_frame_into_tagged<R: Read>(
    r: &mut R,
    scratch: &mut Vec<u8>,
) -> Result<Option<(u32, usize)>> {
    let mut len_buf = [0u8; 4];
    match r.read(&mut len_buf[..1])? {
        0 => return Ok(None),
        1 => {}
        _ => unreachable!(),
    }
    read_exact_mid_frame(r, &mut len_buf[1..])?;
    let word = u32::from_le_bytes(len_buf);
    let (tag, len) = if word & FRAME_TAG_FLAG != 0 {
        let mut tag_buf = [0u8; 4];
        read_exact_mid_frame(r, &mut tag_buf)?;
        (u32::from_le_bytes(tag_buf), (word & !FRAME_TAG_FLAG) as usize)
    } else {
        (0, word as usize)
    };
    if len > MAX_FRAME {
        return Err(Error::Protocol(format!("frame too large: {len} bytes")));
    }
    scratch.resize(len, 0);
    read_exact_mid_frame(r, &mut scratch[..])?;
    Ok(Some((tag, len)))
}

/// Start a split frame in `buf`: clears it and reserves the 4-byte length
/// prefix.  The caller appends the (small) header bytes, then finishes with
/// [`end_split_frame`], which supplies the payload from its owner.
pub fn begin_split_frame(buf: &mut Vec<u8>) {
    buf.clear();
    buf.extend_from_slice(&[0u8; 4]);
}

/// Finish a split frame started with [`begin_split_frame`]: patch the
/// length prefix and emit `buf` then `payload` as two writes.  The payload
/// goes straight from its owning buffer to the socket — the frame is never
/// materialized contiguously.
pub fn end_split_frame<W: Write>(w: &mut W, buf: &mut Vec<u8>, payload: &[u8]) -> Result<()> {
    debug_assert!(buf.len() >= 4, "begin_split_frame not called");
    let body_len = buf.len() - 4 + payload.len();
    if body_len > MAX_FRAME {
        return Err(Error::Protocol(format!("frame too large: {body_len} bytes")));
    }
    buf[..4].copy_from_slice(&(body_len as u32).to_le_bytes());
    w.write_all(buf)?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

/// Parts at or above this size bypass [`FrameSink`]'s coalescing buffer and
/// go to the writer directly, so large tensor payloads are written straight
/// from their owning buffer while small headers batch into few syscalls.
const SINK_COALESCE: usize = 32 * 1024;

/// Incremental writer for a frame whose body mixes copied header bytes and
/// borrowed payload slices — the generalization of
/// [`begin_split_frame`]/[`end_split_frame`] to any number of payloads
/// (batch replies carry one per tensor).
///
/// The caller declares the exact body length up front (computed
/// arithmetically via `body_wire_size`), then emits the body in order;
/// [`FrameSink::finish`] verifies the accounting, flushes, and returns the
/// borrowed scratch buffer empty for reuse.  Small writes coalesce in the
/// scratch buffer; slices of [`SINK_COALESCE`] bytes or more are handed to
/// the writer directly — zero payload copies, bounded syscall count.
pub struct FrameSink<'a, W: Write> {
    w: &'a mut W,
    pending: &'a mut Vec<u8>,
    remaining: usize,
}

impl<'a, W: Write> FrameSink<'a, W> {
    /// Start a frame of exactly `body_len` body bytes.  `scratch` is
    /// cleared and used as the coalescing buffer.
    pub fn begin(w: &'a mut W, scratch: &'a mut Vec<u8>, body_len: usize) -> Result<Self> {
        if body_len > MAX_FRAME {
            return Err(Error::Protocol(format!("frame too large: {body_len} bytes")));
        }
        scratch.clear();
        scratch.extend_from_slice(&(body_len as u32).to_le_bytes());
        Ok(FrameSink { w, pending: scratch, remaining: body_len })
    }

    /// Start a *tagged* frame of exactly `body_len` body bytes.  Tag 0
    /// delegates to [`FrameSink::begin`] — the same compat rule as
    /// [`write_tagged_frame`].
    pub fn begin_tagged(
        w: &'a mut W,
        scratch: &'a mut Vec<u8>,
        tag: u32,
        body_len: usize,
    ) -> Result<Self> {
        if tag == 0 {
            return Self::begin(w, scratch, body_len);
        }
        if body_len > MAX_FRAME {
            return Err(Error::Protocol(format!("frame too large: {body_len} bytes")));
        }
        scratch.clear();
        scratch.extend_from_slice(&((body_len as u32) | FRAME_TAG_FLAG).to_le_bytes());
        scratch.extend_from_slice(&tag.to_le_bytes());
        Ok(FrameSink { w, pending: scratch, remaining: body_len })
    }

    fn take(&mut self, n: usize) -> Result<()> {
        if n > self.remaining {
            return Err(Error::Protocol(format!(
                "frame overrun: {n} bytes written with {} remaining",
                self.remaining
            )));
        }
        self.remaining -= n;
        Ok(())
    }

    fn flush_pending(&mut self) -> Result<()> {
        if !self.pending.is_empty() {
            self.w.write_all(self.pending)?;
            self.pending.clear();
        }
        Ok(())
    }

    /// Emit body bytes; large slices go straight to the writer.
    pub fn write(&mut self, part: &[u8]) -> Result<()> {
        self.take(part.len())?;
        if part.len() >= SINK_COALESCE {
            self.flush_pending()?;
            self.w.write_all(part)?;
        } else {
            self.pending.extend_from_slice(part);
            if self.pending.len() >= SINK_COALESCE {
                self.flush_pending()?;
            }
        }
        Ok(())
    }

    /// Emit body bytes produced by an encoder appending to a `Vec` (the
    /// message-header encode helpers), without an intermediate buffer.
    pub fn encode_with(&mut self, f: impl FnOnce(&mut Vec<u8>)) -> Result<()> {
        let before = self.pending.len();
        f(self.pending);
        let n = self.pending.len() - before;
        if n > self.remaining {
            self.pending.truncate(before); // keep the stream uncorrupted
            return Err(Error::Protocol(format!(
                "frame overrun: {n} bytes encoded with {} remaining",
                self.remaining
            )));
        }
        self.remaining -= n;
        if self.pending.len() >= SINK_COALESCE {
            self.flush_pending()?;
        }
        Ok(())
    }

    /// Verify the declared length was written exactly, then flush.
    pub fn finish(mut self) -> Result<()> {
        if self.remaining != 0 {
            return Err(Error::Protocol(format!(
                "frame underrun: {} declared bytes never written",
                self.remaining
            )));
        }
        self.flush_pending()?;
        self.w.flush()?;
        Ok(())
    }
}

/// Read one frame body; `Ok(None)` on a clean EOF at a frame boundary.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Option<Vec<u8>>> {
    let mut body = Vec::new();
    match read_frame_into(r, &mut body)? {
        Some(_) => Ok(Some(body)),
        None => Ok(None),
    }
}

/// Read one frame body into `scratch` (resized to exactly the body length),
/// returning that length; `Ok(None)` on a clean EOF at a frame boundary.
/// Reusing one scratch buffer across requests amortizes the allocation away.
///
/// A socket read timeout *before the first byte* surfaces as the
/// `WouldBlock`/`TimedOut` io error (the idle-poll signal the server loop
/// retries on).  A timeout *mid-frame* is not retryable — bytes are already
/// consumed, so retrying would desync the stream — and surfaces as a
/// protocol error instead, closing the connection.
pub fn read_frame_into<R: Read>(r: &mut R, scratch: &mut Vec<u8>) -> Result<Option<usize>> {
    let mut len_buf = [0u8; 4];
    // A clean shutdown arrives as EOF before any length byte.
    match r.read(&mut len_buf[..1])? {
        0 => return Ok(None),
        1 => {}
        _ => unreachable!(),
    }
    read_exact_mid_frame(r, &mut len_buf[1..])?;
    let len = u32::from_le_bytes(len_buf) as usize;
    if len > MAX_FRAME {
        return Err(Error::Protocol(format!("frame too large: {len} bytes")));
    }
    scratch.resize(len, 0);
    read_exact_mid_frame(r, &mut scratch[..])?;
    Ok(Some(len))
}

/// `read_exact` that converts a read-timeout into a non-retryable protocol
/// error: once frame bytes have been consumed, a timeout means the stream
/// position is lost.
fn read_exact_mid_frame<R: Read>(r: &mut R, buf: &mut [u8]) -> Result<()> {
    r.read_exact(buf).map_err(|e| {
        if e.kind() == std::io::ErrorKind::WouldBlock
            || e.kind() == std::io::ErrorKind::TimedOut
        {
            Error::Protocol(MID_FRAME_TIMEOUT_MSG.into())
        } else {
            Error::Io(e)
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn roundtrip_frames() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        write_frame(&mut buf, b"").unwrap();
        write_frame(&mut buf, &[7u8; 1000]).unwrap();
        let mut c = Cursor::new(buf);
        assert_eq!(read_frame(&mut c).unwrap().unwrap(), b"hello");
        assert_eq!(read_frame(&mut c).unwrap().unwrap(), b"");
        assert_eq!(read_frame(&mut c).unwrap().unwrap(), vec![7u8; 1000]);
        assert!(read_frame(&mut c).unwrap().is_none(), "clean EOF");
    }

    #[test]
    fn read_into_reuses_scratch() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &[1u8; 64]).unwrap();
        write_frame(&mut buf, &[2u8; 8]).unwrap();
        let mut c = Cursor::new(buf);
        let mut scratch = Vec::new();
        assert_eq!(read_frame_into(&mut c, &mut scratch).unwrap(), Some(64));
        assert_eq!(scratch, vec![1u8; 64]);
        let cap = scratch.capacity();
        assert_eq!(read_frame_into(&mut c, &mut scratch).unwrap(), Some(8));
        assert_eq!(scratch, vec![2u8; 8]);
        assert_eq!(scratch.capacity(), cap, "no reallocation for smaller frame");
        assert_eq!(read_frame_into(&mut c, &mut scratch).unwrap(), None, "clean EOF");
    }

    #[test]
    fn split_frame_matches_contiguous_write() {
        let header = [9u8, 8, 7];
        let payload = [1u8; 100];
        let mut contiguous = Vec::new();
        let mut whole: Vec<u8> = header.to_vec();
        whole.extend_from_slice(&payload);
        write_frame(&mut contiguous, &whole).unwrap();

        let mut split = Vec::new();
        let mut head_buf = Vec::new();
        begin_split_frame(&mut head_buf);
        head_buf.extend_from_slice(&header);
        end_split_frame(&mut split, &mut head_buf, &payload).unwrap();
        assert_eq!(split, contiguous, "split write is byte-identical");

        let mut c = Cursor::new(split);
        assert_eq!(read_frame(&mut c).unwrap().unwrap(), whole);
    }

    #[test]
    fn split_frame_empty_payload() {
        let mut out = Vec::new();
        let mut head = Vec::new();
        begin_split_frame(&mut head);
        head.push(42);
        end_split_frame(&mut out, &mut head, &[]).unwrap();
        let mut c = Cursor::new(out);
        assert_eq!(read_frame(&mut c).unwrap().unwrap(), vec![42]);
    }

    #[test]
    fn frame_sink_matches_contiguous_write() {
        // Mixed small/large parts produce the same bytes as one write_frame.
        let header = [1u8, 2, 3];
        let big = vec![7u8; SINK_COALESCE + 11];
        let tail = [9u8; 5];
        let mut whole: Vec<u8> = header.to_vec();
        whole.extend_from_slice(&big);
        whole.extend_from_slice(&tail);
        let mut contiguous = Vec::new();
        write_frame(&mut contiguous, &whole).unwrap();

        let mut sunk = Vec::new();
        let mut scratch = Vec::new();
        let mut sink = FrameSink::begin(&mut sunk, &mut scratch, whole.len()).unwrap();
        sink.encode_with(|b| b.extend_from_slice(&header)).unwrap();
        sink.write(&big).unwrap();
        sink.write(&tail).unwrap();
        sink.finish().unwrap();
        assert_eq!(sunk, contiguous, "sink output is byte-identical");
        assert!(scratch.is_empty(), "scratch returned empty for reuse");
    }

    #[test]
    fn frame_sink_rejects_overrun_and_underrun() {
        let mut out = Vec::new();
        let mut scratch = Vec::new();
        let mut sink = FrameSink::begin(&mut out, &mut scratch, 2).unwrap();
        sink.write(&[1, 2]).unwrap();
        assert!(sink.write(&[3]).is_err(), "overrun detected");

        let mut out = Vec::new();
        let mut scratch = Vec::new();
        let mut sink = FrameSink::begin(&mut out, &mut scratch, 4).unwrap();
        sink.write(&[1]).unwrap();
        assert!(sink.finish().is_err(), "underrun detected");
    }

    #[test]
    fn truncated_body_is_error() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        buf.truncate(buf.len() - 2);
        let mut c = Cursor::new(buf);
        assert!(read_frame(&mut c).is_err());
    }

    #[test]
    fn truncated_header_is_error() {
        let mut c = Cursor::new(vec![5u8, 0u8]); // half a length prefix
        assert!(read_frame(&mut c).is_err());
    }

    #[test]
    fn oversize_frame_rejected_without_alloc() {
        let mut buf = (u32::MAX).to_le_bytes().to_vec();
        buf.extend_from_slice(b"x");
        let mut c = Cursor::new(buf);
        assert!(read_frame(&mut c).is_err());
    }

    #[test]
    fn tagged_roundtrip_preserves_tag() {
        let mut buf = Vec::new();
        write_tagged_frame(&mut buf, 7, b"hello").unwrap();
        write_tagged_frame(&mut buf, u32::MAX, b"").unwrap();
        write_frame(&mut buf, b"legacy").unwrap();
        let mut c = Cursor::new(buf);
        let mut scratch = Vec::new();
        assert_eq!(read_frame_into_tagged(&mut c, &mut scratch).unwrap(), Some((7, 5)));
        assert_eq!(scratch, b"hello");
        assert_eq!(read_frame_into_tagged(&mut c, &mut scratch).unwrap(), Some((u32::MAX, 0)));
        // Legacy unflagged frames read as tag 0 through the same reader.
        assert_eq!(read_frame_into_tagged(&mut c, &mut scratch).unwrap(), Some((0, 6)));
        assert_eq!(scratch, b"legacy");
        assert_eq!(read_frame_into_tagged(&mut c, &mut scratch).unwrap(), None, "clean EOF");
    }

    #[test]
    fn tag_zero_is_byte_identical_to_legacy() {
        let mut tagged = Vec::new();
        write_tagged_frame(&mut tagged, 0, b"payload").unwrap();
        let mut legacy = Vec::new();
        write_frame(&mut legacy, b"payload").unwrap();
        assert_eq!(tagged, legacy, "tag 0 is the legacy encoding");
    }

    #[test]
    fn legacy_reader_rejects_tagged_frames_as_oversize() {
        // A pre-multiplexing reader sees the flag bit as an absurd length
        // and refuses the frame instead of desyncing on the tag word.
        let mut buf = Vec::new();
        write_tagged_frame(&mut buf, 3, b"x").unwrap();
        let mut c = Cursor::new(buf);
        assert!(read_frame(&mut c).is_err());
    }

    #[test]
    fn sink_begin_tagged_matches_write_tagged_frame() {
        let body = {
            let mut b = vec![1u8, 2, 3];
            b.extend_from_slice(&vec![9u8; SINK_COALESCE + 5]);
            b
        };
        let mut contiguous = Vec::new();
        write_tagged_frame(&mut contiguous, 42, &body).unwrap();

        let mut sunk = Vec::new();
        let mut scratch = Vec::new();
        let mut sink = FrameSink::begin_tagged(&mut sunk, &mut scratch, 42, body.len()).unwrap();
        sink.write(&body[..3]).unwrap();
        sink.write(&body[3..]).unwrap();
        sink.finish().unwrap();
        assert_eq!(sunk, contiguous, "tagged sink output is byte-identical");

        let mut sunk0 = Vec::new();
        let mut scratch0 = Vec::new();
        let mut sink = FrameSink::begin_tagged(&mut sunk0, &mut scratch0, 0, 2).unwrap();
        sink.write(&[5, 6]).unwrap();
        sink.finish().unwrap();
        let mut legacy = Vec::new();
        write_frame(&mut legacy, &[5, 6]).unwrap();
        assert_eq!(sunk0, legacy, "tag 0 sink degrades to the legacy frame");
    }

    #[test]
    fn truncated_tag_word_is_error() {
        let mut buf = Vec::new();
        write_tagged_frame(&mut buf, 9, b"abc").unwrap();
        buf.truncate(6); // length word + half the tag
        let mut c = Cursor::new(buf);
        let mut scratch = Vec::new();
        assert!(read_frame_into_tagged(&mut c, &mut scratch).is_err());
    }
}
