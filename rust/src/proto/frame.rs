//! Length-prefixed framing over any `Read`/`Write` stream.

use std::io::{Read, Write};

use crate::error::{Error, Result};

/// Hard cap on a single frame (body) size.  The largest legitimate payload is
/// a per-rank training tensor (hundreds of MB would indicate a protocol
/// error or an attack, so we refuse it rather than OOM).
pub const MAX_FRAME: usize = 1 << 30; // 1 GiB

/// Write one frame: u32-LE length prefix, then the body.
pub fn write_frame<W: Write>(w: &mut W, body: &[u8]) -> Result<()> {
    if body.len() > MAX_FRAME {
        return Err(Error::Protocol(format!("frame too large: {} bytes", body.len())));
    }
    w.write_all(&(body.len() as u32).to_le_bytes())?;
    w.write_all(body)?;
    w.flush()?;
    Ok(())
}

/// Read one frame body; `Ok(None)` on a clean EOF at a frame boundary.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Option<Vec<u8>>> {
    let mut len_buf = [0u8; 4];
    // A clean shutdown arrives as EOF before any length byte.
    match r.read(&mut len_buf[..1])? {
        0 => return Ok(None),
        1 => {}
        _ => unreachable!(),
    }
    r.read_exact(&mut len_buf[1..])?;
    let len = u32::from_le_bytes(len_buf) as usize;
    if len > MAX_FRAME {
        return Err(Error::Protocol(format!("frame too large: {len} bytes")));
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body)?;
    Ok(Some(body))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn roundtrip_frames() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        write_frame(&mut buf, b"").unwrap();
        write_frame(&mut buf, &[7u8; 1000]).unwrap();
        let mut c = Cursor::new(buf);
        assert_eq!(read_frame(&mut c).unwrap().unwrap(), b"hello");
        assert_eq!(read_frame(&mut c).unwrap().unwrap(), b"");
        assert_eq!(read_frame(&mut c).unwrap().unwrap(), vec![7u8; 1000]);
        assert!(read_frame(&mut c).unwrap().is_none(), "clean EOF");
    }

    #[test]
    fn truncated_body_is_error() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        buf.truncate(buf.len() - 2);
        let mut c = Cursor::new(buf);
        assert!(read_frame(&mut c).is_err());
    }

    #[test]
    fn truncated_header_is_error() {
        let mut c = Cursor::new(vec![5u8, 0u8]); // half a length prefix
        assert!(read_frame(&mut c).is_err());
    }

    #[test]
    fn oversize_frame_rejected_without_alloc() {
        let mut buf = (u32::MAX).to_le_bytes().to_vec();
        buf.extend_from_slice(b"x");
        let mut c = Cursor::new(buf);
        assert!(read_frame(&mut c).is_err());
    }
}
